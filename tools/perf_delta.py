"""Compare two perf-baseline JSONs (tools/perf_baseline.py output) and
report the commit-over-commit wall-clock / rows-per-second movement.

CI runs the reproducibility lane's sweep with --events, distills the
stream with perf_baseline.py, restores the previous commit's baseline
from the actions cache, and calls this tool: matching runs (same name)
get a per-run wall_s / rows_per_s delta, printed as CSV and — when
$GITHUB_STEP_SUMMARY is set — appended there as a markdown table.

This is tracking, not gating, by default: wall-clock on shared CI
runners is noisy, so the tool always exits 0 unless --max-regression is
given (fractional slowdown on wall_s above which it exits 1, e.g. 0.5
= fail when more than 50% slower).  A missing baseline (first run, or
an expired cache) is a clean exit with a note, never a failure.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def load(path: str) -> dict:
    with open(path) as fh:
        return json.load(fh)


def deltas(new: dict, base: dict):
    """Per-run comparison rows: (name, new_run, base_run_or_None,
    wall_ratio_or_None)."""
    out = []
    for name, run in sorted(new.get("runs", {}).items()):
        b = base.get("runs", {}).get(name)
        ratio = None
        if b and b.get("wall_s") and run.get("wall_s") is not None:
            ratio = run["wall_s"] / b["wall_s"]
        out.append((name, run, b, ratio))
    return out


def markdown(rows, new_sha, base_sha) -> str:
    lines = ["### Perf delta (wall-clock, informational)",
             f"- new: `{(new_sha or 'unknown')[:12]}` vs baseline: "
             f"`{(base_sha or 'unknown')[:12]}`", "",
             "| run | rows | wall_s | baseline wall_s | ratio | rows/s |",
             "|---|---|---|---|---|---|"]
    for name, run, b, ratio in rows:
        bw = f"{b['wall_s']:.2f}" if b else "—"
        rt = f"{ratio:.2f}x" if ratio is not None else "—"
        rps = (f"{run['rows_per_s']:.3f}"
               if run.get("rows_per_s") else "—")
        lines.append(f"| {name} | {run['rows']} | {run['wall_s']:.2f} "
                     f"| {bw} | {rt} | {rps} |")
    return "\n".join(lines) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("new", help="this commit's perf-baseline JSON")
    ap.add_argument("baseline", nargs="?", default=None,
                    help="previous perf-baseline JSON (omit or point at "
                         "a missing file on the first run)")
    ap.add_argument("--max-regression", type=float, default=None,
                    metavar="FRAC",
                    help="exit 1 when any matching run's wall_s grew by "
                         "more than this fraction (default: never gate)")
    args = ap.parse_args(argv if argv is not None else sys.argv[1:])

    new = load(args.new)
    if not args.baseline or not os.path.exists(args.baseline):
        print("perf_delta: no previous baseline — first run, nothing to "
              "compare")
        for name, run in sorted(new.get("runs", {}).items()):
            print(f"perf_delta,{name},0,wall_s={run['wall_s']:.2f};"
                  "baseline=none")
        return 0
    base = load(args.baseline)
    rows = deltas(new, base)
    worst = None
    for name, run, b, ratio in rows:
        if ratio is None:
            print(f"perf_delta,{name},0,wall_s={run['wall_s']:.2f};"
                  "baseline=none")
            continue
        print(f"perf_delta,{name},0,wall_s={run['wall_s']:.2f};"
              f"baseline_wall_s={b['wall_s']:.2f};ratio={ratio:.3f}")
        if worst is None or ratio > worst[1]:
            worst = (name, ratio)
    step_summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if step_summary:
        with open(step_summary, "a") as fh:
            fh.write(markdown(rows, new.get("git_sha"),
                              base.get("git_sha")))
    if args.max_regression is not None and worst \
            and worst[1] > 1.0 + args.max_regression:
        print(f"perf_delta: {worst[0]} is {worst[1]:.2f}x the baseline "
              f"wall-clock (limit {1.0 + args.max_regression:.2f}x)")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
