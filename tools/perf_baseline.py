"""Distill a sweep's JSONL events stream into a perf-baseline JSON.

``availability_sweep.py --events PATH`` records one JSON object per
result row with real wall-clock position/deltas.  This tool reduces one
or more such streams to the stable perf surface CI tracks commit over
commit: per run (keyed by the spec name, falling back to metric) the
total wall-clock, row count, rows-per-second, and per-row-kind wall
time; stamped with the producing commit.  ``tools/perf_delta.py``
compares two of these files and renders the comparison into the GitHub
step summary.

Usage:
    python tools/perf_baseline.py events.jsonl [more.jsonl ...] \
        --out perf_baseline.json [--git-sha SHA]

Multiple runs in one stream (run_batch) are split on their run_start
records.  Rows before any run_start are ignored; a stream whose run_end
is missing (killed run) still contributes its rows with wall_s taken
from the last row's t_s.
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys


def _git_sha():
    try:
        return subprocess.run(["git", "rev-parse", "HEAD"],
                              capture_output=True, text=True,
                              check=True).stdout.strip()
    except (OSError, subprocess.CalledProcessError):
        return None


def parse_events(paths):
    """events JSONL → list of per-run dicts (name, spec_sha256, rows,
    wall_s, rows_per_s, kinds{kind: {rows, wall_s}})."""
    runs = []
    cur = None
    for path in paths:
        with open(path) as fh:
            for line in fh:
                line = line.strip()
                if not line:
                    continue
                ev = json.loads(line)
                kind = ev.get("event")
                if kind == "run_start":
                    cur = {"name": ev.get("name") or ev.get("metric", ""),
                           "metric": ev.get("metric"),
                           "backend": ev.get("backend"),
                           "spec_sha256": ev.get("spec_sha256"),
                           "rows": 0, "wall_s": 0.0, "kinds": {}}
                    runs.append(cur)
                elif kind == "row" and cur is not None:
                    cur["rows"] += 1
                    cur["wall_s"] = max(cur["wall_s"], ev.get("t_s", 0.0))
                    k = ev.get("kind") or "?"
                    bucket = cur["kinds"].setdefault(
                        k, {"rows": 0, "wall_s": 0.0})
                    bucket["rows"] += 1
                    bucket["wall_s"] += ev.get("dt_s", 0.0)
                elif kind == "run_end" and cur is not None:
                    if ev.get("wall_s") is not None:
                        cur["wall_s"] = ev["wall_s"]
                    cur = None
    for r in runs:
        r["rows_per_s"] = (r["rows"] / r["wall_s"]
                           if r["wall_s"] > 0 else None)
    return runs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("events", nargs="+",
                    help="one or more --events JSONL streams")
    ap.add_argument("--out", required=True, metavar="PATH",
                    help="perf-baseline JSON to write")
    ap.add_argument("--git-sha", default=None,
                    help="commit to stamp (default: git rev-parse HEAD)")
    args = ap.parse_args(argv if argv is not None else sys.argv[1:])

    runs = parse_events(args.events)
    if not runs:
        print(f"perf_baseline: no run_start records in "
              f"{', '.join(args.events)}", file=sys.stderr)
        return 1
    doc = {"schema_version": 1,
           "git_sha": args.git_sha or _git_sha(),
           "runs": {r["name"] or f"run{i}": r
                    for i, r in enumerate(runs)}}
    with open(args.out, "w") as fh:
        json.dump(doc, fh, indent=1, sort_keys=True)
    for name, r in sorted(doc["runs"].items()):
        rps = f"{r['rows_per_s']:.3f}" if r["rows_per_s"] else "—"
        print(f"perf,{name},0,rows={r['rows']};wall_s={r['wall_s']:.2f};"
              f"rows_per_s={rps}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
