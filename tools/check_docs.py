"""Docs lane: fail CI on broken intra-repo markdown links and on missing
module docstrings in the protocol/kernels packages.

Two cheap checks, no dependencies beyond the stdlib:

1. Every relative link target in a tracked ``*.md`` file must exist on
   disk (resolved against the file's own directory, ``#fragment``
   stripped).  External schemes (http/https/mailto) and pure in-page
   anchors are skipped.
2. Every module under ``src/repro/core`` and ``src/repro/kernels``
   (``__init__.py`` exempt) must carry a module docstring of at least
   ``MIN_DOCSTRING_CHARS`` characters — the documentation floor
   docs/ARCHITECTURE.md's invariants section relies on.

Run from anywhere: paths are anchored to the repo root (parent of this
file's directory).  Exit code 1 with a per-finding report on failure.
"""
from __future__ import annotations

import ast
import re
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
MIN_DOCSTRING_CHARS = 40
DOCSTRING_PACKAGES = ("src/repro/core", "src/repro/kernels")
SKIP_DIRS = {".git", "__pycache__", ".pytest_cache", ".ruff_cache",
             ".hypothesis", ".venv", "venv", "node_modules", ".tox",
             "build", "dist", ".claude"}

# [text](target) — good enough for the hand-written markdown in this
# repo; images (![alt](target)) match too, which is what we want
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def iter_files(suffix: str):
    """Tracked files first (so vendored/venv markdown the repo doesn't own
    never fails the lane); filesystem walk with a skip list as the
    fallback outside a git checkout."""
    try:
        out = subprocess.run(
            ["git", "ls-files", "-z", "--cached", "--others",
             "--exclude-standard", f"*{suffix}"], cwd=REPO,
            capture_output=True, text=True, check=True)
        paths = [REPO / rel
                 for rel in sorted(filter(None, out.stdout.split("\0")))]
    except (OSError, subprocess.CalledProcessError):
        paths = sorted(REPO.rglob(f"*{suffix}"))
    for path in paths:
        if path.exists() and not SKIP_DIRS.intersection(
                p.name for p in path.parents):
            yield path


def check_markdown_links() -> list:
    failures = []
    for md in iter_files(".md"):
        text = md.read_text(encoding="utf-8")
        for m in _LINK.finditer(text):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            if not (md.parent / rel).exists():
                line = text[:m.start()].count("\n") + 1
                failures.append(f"{md.relative_to(REPO)}:{line}: "
                                f"broken link -> {target}")
    return failures


def check_module_docstrings() -> list:
    failures = []
    for pkg in DOCSTRING_PACKAGES:
        for py in sorted((REPO / pkg).rglob("*.py")):
            if py.name == "__init__.py":
                continue
            rel = py.relative_to(REPO)
            try:
                tree = ast.parse(py.read_text(encoding="utf-8"))
            except SyntaxError as e:
                failures.append(f"{rel}: does not parse: {e}")
                continue
            doc = ast.get_docstring(tree)
            if not doc:
                failures.append(f"{rel}: missing module docstring")
            elif len(doc) < MIN_DOCSTRING_CHARS:
                failures.append(
                    f"{rel}: module docstring under "
                    f"{MIN_DOCSTRING_CHARS} chars ({len(doc)})")
    return failures


def main() -> int:
    failures = check_markdown_links() + check_module_docstrings()
    for f in failures:
        print(f"docs: {f}")
    if failures:
        print(f"DOCS CHECK FAILED: {len(failures)} finding(s)")
        return 1
    n_md = sum(1 for _ in iter_files(".md"))
    print(f"docs ok: links in {n_md} markdown files, module docstrings "
          f"in {', '.join(DOCSTRING_PACKAGES)}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
