"""End-to-end behaviour tests for the paper's system.

The headline claims, executed:
  1. linearizable KV service stays available and correct through a leader
     failure at RF=2 (log-free failover, per-key dup-res);
  2. zero-downtime rolling restart at RF=2 (SuperMajority);
  3. the training stack keeps committing checkpoints through a worker
     failure while the equal-storage quorum-log baseline pauses.
"""
import numpy as np
import pytest

from repro.checkpoint import LarkStore, QuorumLogStore
from repro.core.linearizability import check_history
from repro.core.simulator import LarkSim


def test_e2e_failover_linearizable():
    sim = LarkSim(num_nodes=5, rf=2, num_partitions=2)
    sim.recluster(); sim.settle(); sim.run_migrations()
    assert sim.client_write(0, "k", "v1") > 0
    sim.settle()
    leader = sim.leader_of(0)
    sim.fail_node(leader)
    sim.settle(); sim.run_migrations()
    assert sim.leader_of(0) is not None and sim.leader_of(0) != leader
    w2 = sim.client_write(0, "k", "v2"); sim.settle()
    assert sim.result(w2).ok
    r = sim.client_read(0, "k"); sim.settle()
    assert sim.result(r).value == "v2"
    assert all(check_history(sim.finalize_history()).values())


def test_e2e_rolling_restart_zero_downtime():
    P = 4
    sim = LarkSim(num_nodes=5, rf=2, num_partitions=P)
    sim.recluster(); sim.settle(); sim.run_migrations()
    for victim in range(5):
        sim.fail_node(victim)
        sim.settle(); sim.run_migrations()
        # every partition stays available (SuperMajority: < RF missing)
        assert all(sim.leader_of(p) is not None for p in range(P))
        for p in range(P):
            op = sim.client_write(p, f"key-{p}", f"v{victim}")
            sim.settle()
            assert sim.result(op).ok
        sim.recover_node(victim)
        sim.settle(); sim.run_migrations()
    for p in range(P):
        op = sim.client_read(p, f"key-{p}")
        sim.settle()
        assert sim.result(op).value == "v4"


def test_e2e_training_outage_lark_vs_baseline():
    lark = LarkStore(4, rf=2, num_partitions=32)
    base = QuorumLogStore(4, rf=2, num_partitions=32,
                          partition_bytes=1e9, bandwidth=5e6)
    lark_ok = base_ok = 0
    for step in range(40):
        if step == 10:
            lark.fail_node(3)
            base.fail_node(3)
        base.advance(5.0)
        lark_ok += lark.put(f"s{step}", step)
        base_ok += base.put(f"s{step}", step)
    assert lark_ok == 40            # LARK never pauses
    assert base_ok < 40             # baseline's no-commit window is visible
