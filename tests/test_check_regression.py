"""Nightly regression gate: row matching and the 2-sigma drift rule."""
import importlib.util
from pathlib import Path

_spec = importlib.util.spec_from_file_location(
    "check_regression",
    Path(__file__).resolve().parents[1] / "benchmarks" / "check_regression.py")
check_regression = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_regression)


def _row(kind="iid", scenario=None, rf=2, p=1e-3, u=1e-4, um=3e-4, ci=1e-5):
    r = {"kind": kind, "rf": rf, "p": p, "u_lark": u, "u_maj": um,
         "ci_lark": ci, "ci_maj": ci}
    if scenario:
        r["scenario"] = scenario
    return r


def test_identical_runs_pass_even_with_zero_ci():
    doc = {"rows": [_row(ci=0.0), _row(kind="scenario", scenario="flapping")]}
    failures, notes, checked = check_regression.compare(doc, doc, 2.0)
    assert not failures and checked == 2


def test_drift_beyond_sigma_fails_and_within_passes():
    base = {"rows": [_row(u=1e-4, ci=1e-5)]}
    # 2 sigma of combined se = 2*sqrt(2)*(1e-5/1.96) ~ 1.44e-5
    ok = {"rows": [_row(u=1e-4 + 1e-5, ci=1e-5)]}
    bad = {"rows": [_row(u=1e-4 + 5e-5, ci=1e-5)]}
    assert not check_regression.compare(ok, base, 2.0)[0]
    failures = check_regression.compare(bad, base, 2.0)[0]
    assert failures and "u_lark" in failures[0]


def test_missing_baseline_row_fails_and_new_row_is_noted():
    base = {"rows": [_row(), _row(kind="scenario", scenario="rack-pairs")]}
    new = {"rows": [_row(), _row(kind="scenario", scenario="flapping"),
                    {"kind": "autotune", "block_p": 256}]}
    failures, notes, checked = check_regression.compare(new, base, 2.0)
    assert any("missing" in f for f in failures)
    assert any("flapping" in s for s in notes)
    assert checked == 1          # only the shared iid row is gated


def _dt_row(model=None, pause=0.4, ci=1e-3):
    r = {"kind": "downtime", "scenario": "iid", "rf": 2, "p": 1e-3,
         "pause_lark": 1e-3, "pause_quorum": pause,
         "ci_pause_lark": ci, "ci_pause_quorum": ci}
    if model is not None:
        r["rebuild_model"] = model
    return r


def test_downtime_rows_keyed_by_rebuild_model():
    # a reconfig row never gates against a fixed row at the same (rf, p),
    # and a baseline without the field is a fixed-model row
    base = {"rows": [_dt_row(model=None, pause=0.4)]}
    new = {"rows": [_dt_row(model="fixed", pause=0.4),
                    _dt_row(model="reconfig", pause=0.9)]}
    failures, notes, checked = check_regression.compare(new, base, 2.0)
    assert not failures
    assert checked == 1                       # only the fixed row is shared
    assert any("reconfig" in s for s in notes)


def test_null_gated_value_skips_the_gate_with_a_note():
    good = _dt_row(model="fixed")
    nulled = dict(_dt_row(model="fixed"), pause_quorum=None)
    failures, notes, checked = check_regression.compare(
        {"rows": [nulled]}, {"rows": [good]}, 2.0)
    assert not failures and checked == 1
    assert any("null pause_quorum" in s for s in notes)
    # symmetric: a null in the baseline is skipped too
    failures, notes, _ = check_regression.compare(
        {"rows": [good]}, {"rows": [nulled]}, 2.0)
    assert not failures
    assert any("null pause_quorum" in s for s in notes)


def test_loader_rejects_non_finite_json(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text('{"rows": [{"kind": "iid", "rf": 2, "p": 1e-3, '
                   '"ratio": Infinity}]}')
    import pytest
    with pytest.raises(ValueError, match="non-finite"):
        check_regression.load_rows(str(bad))
    ok = tmp_path / "ok.json"
    ok.write_text('{"rows": [{"kind": "iid", "rf": 2, "p": 1e-3, '
                  '"ratio": null}]}')
    doc = check_regression.load_rows(str(ok))
    assert doc["rows"][0]["ratio"] is None


def test_sweep_json_serializes_non_finite_as_null(tmp_path):
    """End to end: a ratio over a zero denominator reaches --json as
    null, never as the non-RFC Infinity token."""
    import importlib.util
    import json
    from pathlib import Path
    spec = importlib.util.spec_from_file_location(
        "availability_sweep",
        Path(__file__).resolve().parents[1] / "benchmarks" /
        "availability_sweep.py")
    sweep = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(sweep)
    row = {"kind": "downtime", "ratio": float("inf"), "pause_lark": 0.0}
    safe = sweep._json_safe(row)
    assert safe["ratio"] is None and safe["pause_lark"] == 0.0
    out = tmp_path / "dump.json"
    with open(out, "w") as fh:
        json.dump({"rows": [safe]}, fh, allow_nan=False)
    assert "Infinity" not in out.read_text()
    assert check_regression.load_rows(str(out))["rows"][0]["ratio"] is None
