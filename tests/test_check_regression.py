"""Nightly regression gate: row matching and the 2-sigma drift rule."""
import importlib.util
from pathlib import Path

_spec = importlib.util.spec_from_file_location(
    "check_regression",
    Path(__file__).resolve().parents[1] / "benchmarks" / "check_regression.py")
check_regression = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_regression)


def _row(kind="iid", scenario=None, rf=2, p=1e-3, u=1e-4, um=3e-4, ci=1e-5):
    r = {"kind": kind, "rf": rf, "p": p, "u_lark": u, "u_maj": um,
         "ci_lark": ci, "ci_maj": ci}
    if scenario:
        r["scenario"] = scenario
    return r


def test_identical_runs_pass_even_with_zero_ci():
    doc = {"rows": [_row(ci=0.0), _row(kind="scenario", scenario="flapping")]}
    failures, notes, checked, _ = check_regression.compare(doc, doc, 2.0)
    assert not failures and checked == 2


def test_drift_beyond_sigma_fails_and_within_passes():
    base = {"rows": [_row(u=1e-4, ci=1e-5)]}
    # 2 sigma of combined se = 2*sqrt(2)*(1e-5/1.96) ~ 1.44e-5
    ok = {"rows": [_row(u=1e-4 + 1e-5, ci=1e-5)]}
    bad = {"rows": [_row(u=1e-4 + 5e-5, ci=1e-5)]}
    assert not check_regression.compare(ok, base, 2.0)[0]
    failures = check_regression.compare(bad, base, 2.0)[0]
    assert failures and "u_lark" in failures[0]


def test_missing_baseline_row_fails_and_new_row_is_noted():
    base = {"rows": [_row(), _row(kind="scenario", scenario="rack-pairs")]}
    new = {"rows": [_row(), _row(kind="scenario", scenario="flapping"),
                    {"kind": "autotune", "block_p": 256}]}
    failures, notes, checked, _ = check_regression.compare(new, base, 2.0)
    assert any("missing" in f for f in failures)
    assert any("flapping" in s for s in notes)
    assert checked == 1          # only the shared iid row is gated


def _dt_row(model=None, pause=0.4, ci=1e-3):
    r = {"kind": "downtime", "scenario": "iid", "rf": 2, "p": 1e-3,
         "pause_lark": 1e-3, "pause_quorum": pause,
         "ci_pause_lark": ci, "ci_pause_quorum": ci}
    if model is not None:
        r["rebuild_model"] = model
    return r


def test_downtime_rows_keyed_by_rebuild_model():
    # a reconfig row never gates against a fixed row at the same (rf, p),
    # and a baseline without the field is a fixed-model row
    base = {"rows": [_dt_row(model=None, pause=0.4)]}
    new = {"rows": [_dt_row(model="fixed", pause=0.4),
                    _dt_row(model="reconfig", pause=0.9)]}
    failures, notes, checked, _ = check_regression.compare(new, base, 2.0)
    assert not failures
    assert checked == 1                       # only the fixed row is shared
    assert any("reconfig" in s for s in notes)


def test_null_gated_value_skips_the_gate_with_a_note():
    good = _dt_row(model="fixed")
    nulled = dict(_dt_row(model="fixed"), pause_quorum=None)
    failures, notes, checked, _ = check_regression.compare(
        {"rows": [nulled]}, {"rows": [good]}, 2.0)
    assert not failures and checked == 1
    assert any("null pause_quorum" in s for s in notes)
    # symmetric: a null in the baseline is skipped too
    failures, notes, _, _ = check_regression.compare(
        {"rows": [good]}, {"rows": [nulled]}, 2.0)
    assert not failures
    assert any("null pause_quorum" in s for s in notes)


def test_loader_rejects_non_finite_json(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text('{"rows": [{"kind": "iid", "rf": 2, "p": 1e-3, '
                   '"ratio": Infinity}]}')
    import pytest
    with pytest.raises(ValueError, match="non-finite"):
        check_regression.load_rows(str(bad))
    ok = tmp_path / "ok.json"
    ok.write_text('{"rows": [{"kind": "iid", "rf": 2, "p": 1e-3, '
                  '"ratio": null}]}')
    doc = check_regression.load_rows(str(ok))
    assert doc["rows"][0]["ratio"] is None


def test_sweep_json_serializes_non_finite_as_null(tmp_path):
    """End to end: a ratio over a zero denominator reaches --json as
    null, never as the non-RFC Infinity token."""
    import importlib.util
    import json
    from pathlib import Path
    spec = importlib.util.spec_from_file_location(
        "availability_sweep",
        Path(__file__).resolve().parents[1] / "benchmarks" /
        "availability_sweep.py")
    sweep = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(sweep)
    row = {"kind": "downtime", "ratio": float("inf"), "pause_lark": 0.0}
    safe = sweep._json_safe(row)
    assert safe["ratio"] is None and safe["pause_lark"] == 0.0
    out = tmp_path / "dump.json"
    with open(out, "w") as fh:
        json.dump({"rows": [safe]}, fh, allow_nan=False)
    assert "Infinity" not in out.read_text()
    assert check_regression.load_rows(str(out))["rows"][0]["ratio"] is None


def _lat_row(scenario="iid", rf=2, p=1e-3, lat=0.5, ci=1e-2,
             read_frac=0.8, key_zipf=1.0, slo_ticks=8, rpt=32.0,
             dupres=1):
    return {"kind": "latency" if scenario == "iid" else "latency_scenario",
            "scenario": scenario, "rf": rf, "p": p,
            "lat_lark": lat, "lat_quorum": 4.0,
            "ci_lat_lark": ci, "ci_lat_quorum": ci,
            "rebuild_model": "fixed", "read_frac": read_frac,
            "key_zipf": key_zipf, "slo_ticks": slo_ticks,
            "requests_per_tick": rpt, "dupres_ticks": dupres}


def test_latency_rows_gated_on_lat_columns():
    base = {"rows": [_lat_row(lat=0.5, ci=1e-2)]}
    ok = {"rows": [_lat_row(lat=0.505, ci=1e-2)]}
    bad = {"rows": [_lat_row(lat=0.6, ci=1e-2)]}
    assert not check_regression.compare(ok, base, 2.0)[0]
    failures = check_regression.compare(bad, base, 2.0)[0]
    assert failures and "lat_lark" in failures[0]


def test_latency_rows_keyed_by_workload_knobs():
    """A different read mix, skew, SLO, request rate, or dup-res cost is
    a different measurement — it must never gate against a baseline row
    of another workload, whichever knob differs."""
    base = {"rows": [_lat_row(lat=0.5)]}
    for knob in ({"read_frac": 0.5}, {"key_zipf": 0.0}, {"slo_ticks": 4},
                 {"rpt": 64.0}, {"dupres": 8}):
        new = {"rows": [_lat_row(lat=9.9, **knob)]}
        failures, notes, checked, _ = check_regression.compare(
            new, base, 2.0)
        # no shared key: the run's row is new, the baseline row missing
        assert checked == 0, knob
        assert any("new row" in s for s in notes), knob
        assert any("missing" in f for f in failures), knob


def _eng_row(engine="hermes", scenario="iid", rf=2, p=1e-3, pause=0.3,
             ci=1e-3, lease=40, vc=0, model="reconfig"):
    kind = "downtime_engine" if scenario == "iid" \
        else "downtime_engine_scenario"
    return {"kind": kind, "engine": engine, "scenario": scenario,
            "rf": rf, "p": p, "pause": pause, "ci_pause": ci,
            "lease_ticks": lease, "view_change_ticks": vc,
            "rebuild_model": model}


def test_engine_rows_keyed_by_engine_name():
    """A hermes row and a spinnaker row at the same grid point are
    different measurements — without the engine in the key, either would
    silently gate against the other's pause column."""
    base = {"rows": [_eng_row(engine="hermes", pause=0.3),
                     _eng_row(engine="spinnaker", pause=0.9, vc=200)]}
    new = {"rows": [_eng_row(engine="hermes", pause=0.3),
                    _eng_row(engine="spinnaker", pause=0.9, vc=200)]}
    failures, notes, checked, _ = check_regression.compare(new, base, 2.0)
    assert not failures and checked == 2
    # swap the two engines' pauses: both rows must now fail on "pause"
    swapped = {"rows": [_eng_row(engine="hermes", pause=0.9),
                        _eng_row(engine="spinnaker", pause=0.3, vc=200)]}
    failures = check_regression.compare(swapped, base, 2.0)[0]
    assert len(failures) == 2 and all("pause" in f for f in failures)


def test_engine_rows_keyed_by_zoo_knobs():
    # a different lease / view-change window is a different row family
    base = {"rows": [_eng_row(lease=40)]}
    new = {"rows": [_eng_row(lease=80, pause=9.9)]}
    failures, notes, checked, _ = check_regression.compare(new, base, 2.0)
    assert checked == 0
    assert any("new row" in s for s in notes)
    assert any("missing" in f for f in failures)


def test_engine_rows_gate_pause_not_the_quorum_columns():
    assert check_regression.row_cols(_eng_row()) == (("pause", "ci_pause"),)
    assert check_regression.row_cols(_eng_row(scenario="rolling-restart")) \
        == (("pause", "ci_pause"),)
    # the broader downtime family still gates the lark/quorum pair
    assert check_regression.row_cols(_dt_row()) == \
        (("pause_lark", "ci_pause_lark"),
         ("pause_quorum", "ci_pause_quorum"))


def test_loader_rejects_missing_or_unknown_engine(tmp_path):
    import json
    import pytest
    missing = tmp_path / "missing.json"
    row = _eng_row()
    del row["engine"]
    missing.write_text(json.dumps({"rows": [row]}))
    with pytest.raises(ValueError, match="without an 'engine' field"):
        check_regression.load_rows(str(missing))
    unknown = tmp_path / "unknown.json"
    unknown.write_text(json.dumps({"rows": [_eng_row(engine="raft")]}))
    with pytest.raises(ValueError, match="unknown engine 'raft'"):
        check_regression.load_rows(str(unknown))
    ok = tmp_path / "ok.json"
    ok.write_text(json.dumps({"rows": [_eng_row()]}))
    assert check_regression.load_rows(str(ok))["rows"][0]["engine"] == \
        "hermes"


def test_compare_records_carry_status_and_z():
    base = {"rows": [_lat_row(lat=0.5, ci=1e-2), _row()]}
    new = {"rows": [_lat_row(lat=0.6, ci=1e-2), _row(),
                    _lat_row(scenario="flapping")]}
    failures, notes, checked, records = check_regression.compare(
        new, base, 2.0)
    by_status = {}
    for c in records:
        by_status.setdefault(c["status"], []).append(c)
    assert len(by_status["fail"]) == 1
    fail = by_status["fail"][0]
    assert fail["column"] == "lat_lark"
    assert fail["z"] > 2.0
    assert fail["drift"] == abs(0.6 - 0.5)
    # ok verdicts carry z too, new rows carry only key+status
    assert all("z" in c for c in by_status["ok"])
    assert by_status["new-row"][0]["key"][0] == "latency"


def test_summary_json_and_step_summary(tmp_path, monkeypatch):
    import json
    base = tmp_path / "base.json"
    new = tmp_path / "new.json"
    base.write_text(json.dumps({"rows": [_lat_row(lat=0.5, ci=1e-2)]}))
    new.write_text(json.dumps({"rows": [_lat_row(lat=0.6, ci=1e-2)]}))
    summary = tmp_path / "summary.json"
    step = tmp_path / "step.md"
    monkeypatch.setenv("GITHUB_STEP_SUMMARY", str(step))
    rc = check_regression.main([str(new), str(base), "--sigma", "2",
                                "--summary-json", str(summary)])
    assert rc == 1
    doc = json.loads(summary.read_text())
    assert doc["failures"] == 1 and doc["checked"] == 1
    assert any(c["status"] == "fail" for c in doc["records"])
    md = step.read_text()
    assert "Regression gate" in md and "lat_lark" in md
    # green run: roll-up line only, no table
    monkeypatch.setenv("GITHUB_STEP_SUMMARY", str(tmp_path / "green.md"))
    rc = check_regression.main([str(base), str(base), "--sigma", "2",
                                "--summary-json", str(summary)])
    assert rc == 0
    assert json.loads(summary.read_text())["failures"] == 0
    green = (tmp_path / "green.md").read_text()
    assert "flagged: 0" in green and "|" not in green


# -- provenance-stamped loads --------------------------------------------

def _stamped_doc(rows, spec=None, **prov_overrides):
    """A minimal schema_version-1 dump with an internally consistent
    provenance stamp (spec_sha256 recomputed the same way the checker
    does)."""
    spec = dict(spec or {"name": "t", "metric": "availability",
                         "backend": "numpy", "trials": 2})
    prov = {"spec_sha256": check_regression._spec_sha256(spec),
            "config_path": None, "config_sha256": None}
    prov.update(prov_overrides)
    return {"meta": {"schema_version": 1, "spec": spec,
                     "provenance": prov},
            "rows": rows}


def _dump(tmp_path, name, doc):
    import json
    p = tmp_path / name
    p.write_text(json.dumps(doc))
    return str(p)


def test_pre_provenance_dump_loads_with_deprecation_note(tmp_path):
    path = _dump(tmp_path, "old.json", {"rows": [_row()]})
    notes = []
    doc = check_regression.load_rows(path, notes)
    assert doc["rows"]
    assert any("pre-provenance" in s for s in notes)
    assert any("benchmarks/configs/" in s for s in notes)


def test_stamped_dump_loads_clean(tmp_path):
    path = _dump(tmp_path, "new.json", _stamped_doc([_row()]))
    notes = []
    check_regression.load_rows(path, notes)
    assert notes == []


def test_unknown_schema_version_is_rejected(tmp_path):
    import pytest
    doc = _stamped_doc([_row()])
    doc["meta"]["schema_version"] = 99
    path = _dump(tmp_path, "v99.json", doc)
    with pytest.raises(ValueError, match="unknown meta.schema_version 99"):
        check_regression.load_rows(path)


def test_stamp_without_spec_or_provenance_is_rejected(tmp_path):
    import pytest
    for missing in ("spec", "provenance"):
        doc = _stamped_doc([_row()])
        del doc["meta"][missing]
        path = _dump(tmp_path, f"no_{missing}.json", doc)
        with pytest.raises(ValueError, match="meta.spec / meta.provenance"):
            check_regression.load_rows(path)


def test_spec_hash_mismatch_is_rejected(tmp_path):
    import pytest
    doc = _stamped_doc([_row()])
    # hand-edit the embedded spec after stamping — the classic stale/
    # tampered artifact
    doc["meta"]["spec"]["trials"] = 16
    path = _dump(tmp_path, "edited.json", doc)
    with pytest.raises(ValueError, match="spec_sha256 .* does not match"):
        check_regression.load_rows(path)


def test_spec_hash_ignores_the_name_field(tmp_path):
    # name is display-only, never identity: renaming the embedded spec
    # must not invalidate the stamp
    doc = _stamped_doc([_row()])
    doc["meta"]["spec"]["name"] = "renamed"
    path = _dump(tmp_path, "renamed.json", doc)
    check_regression.load_rows(path, [])


def test_changed_config_file_is_rejected(tmp_path):
    import hashlib
    import pytest
    cfg = tmp_path / "exp.toml"
    cfg.write_text('metric = "availability"\n')
    sha = hashlib.sha256(cfg.read_bytes()).hexdigest()
    good = _stamped_doc([_row()], config_path=str(cfg), config_sha256=sha)
    check_regression.load_rows(_dump(tmp_path, "good.json", good), [])
    cfg.write_text('metric = "availability"\ntrials = 9\n')
    with pytest.raises(ValueError, match="changed since this dump"):
        check_regression.load_rows(_dump(tmp_path, "stale.json", good))
    # a config that no longer exists on disk cannot be verified — load
    # proceeds (moving an artifact between machines must not fail it)
    gone = _stamped_doc([_row()], config_path=str(tmp_path / "gone.toml"),
                        config_sha256=sha)
    check_regression.load_rows(_dump(tmp_path, "gone.json", gone), [])


# -- the --identical byte-identity gate ----------------------------------

def test_compare_identical_passes_on_equal_and_names_diff_keys():
    rows = [_row(), _dt_row(model="fixed")]
    failures, checked = check_regression.compare_identical(
        {"rows": rows}, {"rows": [dict(r) for r in rows]})
    assert not failures and checked == 2
    perturbed = [dict(_row(), u_lark=9.9e-4, ticks=1),
                 _dt_row(model="fixed")]
    failures, _ = check_regression.compare_identical(
        {"rows": perturbed}, {"rows": rows})
    assert len(failures) == 1
    assert "row 0" in failures[0]
    assert "ticks" in failures[0] and "u_lark" in failures[0]


def test_compare_identical_flags_row_count_mismatch():
    failures, checked = check_regression.compare_identical(
        {"rows": [_row()]}, {"rows": [_row(), _row(rf=3)]})
    assert any("row count differs" in f for f in failures)
    assert checked == 1


def test_identical_mode_end_to_end(tmp_path):
    same = _dump(tmp_path, "same.json", _stamped_doc([_row(), _dt_row()]))
    assert check_regression.main([same, same, "--identical"]) == 0
    other = _dump(tmp_path, "other.json",
                  _stamped_doc([_row(u=2e-4), _dt_row()]))
    assert check_regression.main([other, same, "--identical"]) == 1
