"""Nightly regression gate: row matching and the 2-sigma drift rule."""
import importlib.util
from pathlib import Path

_spec = importlib.util.spec_from_file_location(
    "check_regression",
    Path(__file__).resolve().parents[1] / "benchmarks" / "check_regression.py")
check_regression = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_regression)


def _row(kind="iid", scenario=None, rf=2, p=1e-3, u=1e-4, um=3e-4, ci=1e-5):
    r = {"kind": kind, "rf": rf, "p": p, "u_lark": u, "u_maj": um,
         "ci_lark": ci, "ci_maj": ci}
    if scenario:
        r["scenario"] = scenario
    return r


def test_identical_runs_pass_even_with_zero_ci():
    doc = {"rows": [_row(ci=0.0), _row(kind="scenario", scenario="flapping")]}
    failures, notes, checked = check_regression.compare(doc, doc, 2.0)
    assert not failures and checked == 2


def test_drift_beyond_sigma_fails_and_within_passes():
    base = {"rows": [_row(u=1e-4, ci=1e-5)]}
    # 2 sigma of combined se = 2*sqrt(2)*(1e-5/1.96) ~ 1.44e-5
    ok = {"rows": [_row(u=1e-4 + 1e-5, ci=1e-5)]}
    bad = {"rows": [_row(u=1e-4 + 5e-5, ci=1e-5)]}
    assert not check_regression.compare(ok, base, 2.0)[0]
    failures = check_regression.compare(bad, base, 2.0)[0]
    assert failures and "u_lark" in failures[0]


def test_missing_baseline_row_fails_and_new_row_is_noted():
    base = {"rows": [_row(), _row(kind="scenario", scenario="rack-pairs")]}
    new = {"rows": [_row(), _row(kind="scenario", scenario="flapping"),
                    {"kind": "autotune", "block_p": 256}]}
    failures, notes, checked = check_regression.compare(new, base, 2.0)
    assert any("missing" in f for f in failures)
    assert any("flapping" in s for s in notes)
    assert checked == 1          # only the shared iid row is gated
