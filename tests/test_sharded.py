"""Trials-axis sharding: the shard_map code path must be bit-identical to
the plain single-device scan for the same counter-based seed.

The in-process tests exercise the shard_map path on a 1-device "trials"
mesh (the container exposes one CPU device); the slow test re-runs the
comparison in a subprocess with XLA_FLAGS=--xla_force_host_platform_
device_count=8 — the flag must be set before any jax import, which this
process is long past."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro.core.availability_batched import simulate_availability_batched

_KW = dict(n=13, partitions=32, rf=2, p=5e-3, trials=4, max_ticks=4_000,
           min_ticks=10**9, chunk_steps=64, max_steps=600, seed=11,
           trajectory=True)


def test_shard_map_path_identical_on_one_device():
    plain = simulate_availability_batched(backend="jax", **_KW)
    mesh1 = simulate_availability_batched(backend="jax", devices=1,
                                          use_shard_map=True, **_KW)
    for k in plain.trajectory:
        assert np.array_equal(plain.trajectory[k], mesh1.trajectory[k]), k
    assert plain.u_lark == mesh1.u_lark and plain.u_maj == mesh1.u_maj
    assert np.array_equal(plain.u_lark_trials, mesh1.u_lark_trials)


def test_shard_map_path_identical_with_scenario_knobs():
    kw = dict(_KW, pair_fail_prob=0.5, restart_period=700, wave_width=2)
    plain = simulate_availability_batched(backend="jax", **kw)
    mesh1 = simulate_availability_batched(backend="jax", devices=1,
                                          use_shard_map=True, **kw)
    for k in plain.trajectory:
        assert np.array_equal(plain.trajectory[k], mesh1.trajectory[k]), k


def test_sharding_validation():
    with pytest.raises(ValueError, match="numpy"):
        simulate_availability_batched(backend="numpy", devices=2, **_KW)
    with pytest.raises(ValueError, match="divide"):
        simulate_availability_batched(backend="jax", devices=3, **_KW)
    with pytest.raises(ValueError, match="devices"):
        simulate_availability_batched(backend="jax", devices=0, **_KW)


@pytest.mark.slow
def test_eight_device_run_bit_identical_to_single():
    """The acceptance-criterion comparison, on a forced 8-host-device mesh:
    --devices 8 == --devices 4 == --devices 1, bit for bit."""
    script = textwrap.dedent("""
        import numpy as np
        from repro.core.availability_batched import \\
            simulate_availability_batched
        kw = dict(n=13, partitions=32, rf=2, p=5e-3, trials=8,
                  max_ticks=4_000, min_ticks=10**9, chunk_steps=64,
                  max_steps=600, seed=11, backend="jax", trajectory=True,
                  pair_fail_prob=0.3, restart_period=900)
        r1 = simulate_availability_batched(devices=1, **kw)
        for d in (4, 8):
            rd = simulate_availability_batched(devices=d, **kw)
            for k in r1.trajectory:
                assert np.array_equal(r1.trajectory[k],
                                      rd.trajectory[k]), (d, k)
            assert r1.u_lark == rd.u_lark and r1.u_maj == rd.u_maj
            assert np.array_equal(r1.u_lark_trials, rd.u_lark_trials)
            assert r1.lark_events == rd.lark_events
        print("OK")
    """)
    env = dict(os.environ,
               JAX_PLATFORMS="cpu",
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=str(Path(__file__).resolve().parents[1] / "src"))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr
    assert "OK" in out.stdout


@pytest.mark.slow
def test_eight_device_bandwidth_contended_run_bit_identical():
    """The bandwidth-contended reconfig model specifically: its per-node
    in-flight-rebuild scatter-add is the engine's first cross-partition
    coupling inside a step, so this pins that the reduction still
    commutes with trials-axis sharding — devices 8 == devices 4 ==
    devices 1, bit for bit, and the sharded jax run equals the
    unsharded numpy and pallas-interpret runs."""
    script = textwrap.dedent("""
        import numpy as np
        from repro.core.downtime_batched import simulate_downtime_batched
        kw = dict(n=13, partitions=32, rf=2, p=5e-3, trials=8,
                  max_ticks=4_000, min_ticks=10**9, chunk_steps=64,
                  max_steps=600, seed=11, trajectory=True,
                  pair_fail_prob=0.3, restart_period=900,
                  rebuild_model="reconfig", rebuild_ticks_per_gib=64,
                  size_dist="zipf", size_skew=1.2,
                  node_bandwidth_gibps=1.0)
        r1 = simulate_downtime_batched(backend="jax", devices=1, **kw)
        for backend in ("numpy", "pallas"):
            rb = simulate_downtime_batched(backend=backend, devices=1,
                                           **kw)
            for k in r1.trajectory:
                assert np.array_equal(r1.trajectory[k],
                                      rb.trajectory[k]), (backend, k)
            assert r1.pause_quorum == rb.pause_quorum
            assert np.array_equal(r1.hist_quorum, rb.hist_quorum)
        for d in (4, 8):
            rd = simulate_downtime_batched(backend="jax", devices=d, **kw)
            for k in r1.trajectory:
                assert np.array_equal(r1.trajectory[k],
                                      rd.trajectory[k]), (d, k)
            assert r1.pause_lark == rd.pause_lark
            assert r1.pause_quorum == rd.pause_quorum
            assert np.array_equal(r1.hist_lark, rd.hist_lark)
            assert np.array_equal(r1.hist_quorum, rd.hist_quorum)
            assert r1.lark_events == rd.lark_events
            assert r1.quorum_events == rd.quorum_events
            assert np.array_equal(r1.pause_quorum_trials,
                                  rd.pause_quorum_trials)
        print("OK")
    """)
    env = dict(os.environ,
               JAX_PLATFORMS="cpu",
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=str(Path(__file__).resolve().parents[1] / "src"))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr
    assert "OK" in out.stdout


@pytest.mark.slow
def test_eight_device_fused_packed_run_bit_identical():
    """The fused-megakernel acceptance criterion: the packed (B, W, P)
    carry + fused pallas step on an 8-device trials mesh must land on
    the exact same trajectories as devices=1 AND as the unpacked boolean
    jax run — packing/fusion are layout-only, sharding included."""
    script = textwrap.dedent("""
        import numpy as np
        from repro.core.downtime_batched import simulate_downtime_batched
        kw = dict(n=13, partitions=32, rf=2, p=5e-3, trials=8,
                  max_ticks=4_000, min_ticks=10**9, chunk_steps=64,
                  max_steps=600, seed=11, trajectory=True,
                  pair_fail_prob=0.3, restart_period=900,
                  rebuild_model="reconfig", rebuild_ticks_per_gib=64,
                  size_dist="zipf", size_skew=1.2,
                  node_bandwidth_gibps=1.0)
        ref = simulate_downtime_batched(backend="jax", devices=1, **kw)
        for backend in ("jax", "pallas"):
            for d in (1, 8):
                rp = simulate_downtime_batched(backend=backend, devices=d,
                                               packed=True, **kw)
                for k in ref.trajectory:
                    assert np.array_equal(ref.trajectory[k],
                                          rp.trajectory[k]), \\
                        (backend, d, k)
                assert ref.pause_lark == rp.pause_lark
                assert ref.pause_quorum == rp.pause_quorum
                assert np.array_equal(ref.hist_lark, rp.hist_lark)
                assert np.array_equal(ref.hist_quorum, rp.hist_quorum)
                assert np.array_equal(ref.pause_quorum_trials,
                                      rp.pause_quorum_trials)
        print("OK")
    """)
    env = dict(os.environ,
               JAX_PLATFORMS="cpu",
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=str(Path(__file__).resolve().parents[1] / "src"))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr
    assert "OK" in out.stdout


# (the 8-device downtime/zoo matrix now lives in the consolidated
# tests/test_conformance.py)


@pytest.mark.slow
def test_eight_device_latency_run_bit_identical_to_single():
    """The client-latency layer under the devices acceptance criterion:
    raw per-trial accumulators (dup / qhist / qslo / qsum) and every
    reported latency column must be byte-identical between --devices 1
    and a forced 8-device mesh, unpacked jax AND the packed pallas
    carry — the latency leaves ride the generic trials-axis cspec, so
    any drift here is a sharding bug in the carry layout.  Run twice:
    the legacy workload and the sharpened knobs (write skew + a finite
    fixed-model bandwidth + SLO curves) at once."""
    script = textwrap.dedent("""
        import numpy as np
        from repro.core.client_latency import simulate_client_latency
        base = dict(n=6, rf=2, p=2e-4, partitions=64, trials=8,
                    max_ticks=8_000, min_ticks=8_000, chunk_steps=64,
                    seed=11, dupres_ticks=4, requests_per_tick=8.0,
                    key_zipf=1.0, read_frac=0.8, slo_ticks=2)
        sharp = dict(base, write_skew=1.0, node_bandwidth_gibps=0.5,
                     slo_curve_bins=8)
        for kw in (base, sharp):
            r1 = simulate_client_latency(backend="jax", devices=1, **kw)
            raw1 = r1.downtime.latency_raw
            keys = ("dup", "qhist", "qslo", "qsum", "now")
            if "dupw" in raw1:
                keys = keys + ("dupw",)
            for backend, packed in (("jax", False), ("pallas", True)):
                for d in (4, 8):
                    rd = simulate_client_latency(backend=backend,
                                                 devices=d,
                                                 packed=packed, **kw)
                    rawd = rd.downtime.latency_raw
                    for k in keys:
                        assert np.array_equal(raw1[k], rawd[k]), \\
                            (backend, packed, d, k)
                    assert r1.lat_lark == rd.lat_lark
                    assert r1.lat_quorum == rd.lat_quorum
                    assert r1.p999_quorum == rd.p999_quorum
                    assert r1.slo_quorum == rd.slo_quorum
                    assert (r1.slo_curve_quorum is None
                            or np.array_equal(r1.slo_curve_quorum,
                                              rd.slo_curve_quorum))
        print("OK")
    """)
    env = dict(os.environ,
               JAX_PLATFORMS="cpu",
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=str(Path(__file__).resolve().parents[1] / "src"))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr
    assert "OK" in out.stdout
