"""Bit-packed cluster state (kernels/bitpack.py) and the fused step
megakernel (kernels/fused_step.py): the packing-is-layout-only invariant.

Property tests pin packed-word PAC/downtime evaluation == the boolean
oracles on random states, rosters, rf and voters (exact equality — the
math is integer/bit arithmetic, never approximate), and the fused
pallas_call (interpret mode on CPU) == the same oracles, invariant to the
(block_t, block_p) tile choice."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import bitpack, fused_step
from repro.kernels.pac_np import (downtime_eval_rank_np, pac_eval_rank_np,
                                  rebuild_node_counts_np)

RNG = np.random.default_rng(7)


def _state(R, n_pad, n_real, seed):
    rng = np.random.default_rng(seed)
    up = rng.random((R, n_pad)) < 0.9
    full = rng.random((R, n_pad)) < 0.4
    up[:, n_real:] = False
    full[:, n_real:] = False
    return up, full


def _planes(bools, xp):
    words = bitpack.pack_words(bools, xp)
    return [words[..., k] for k in range(words.shape[-1])]


# ---------------------------------------------------------------------------
# word-level primitives
# ---------------------------------------------------------------------------

def test_pack_unpack_roundtrip():
    b = RNG.random((5, 77)) < 0.5
    w = bitpack.pack_words(b, np)
    assert w.shape == (5, 3) and w.dtype == np.uint32
    assert np.array_equal(bitpack.unpack_words(w, 77, np), b)


def test_popcount_matches_python_bitcount():
    v = RNG.integers(0, 2 ** 32, size=257, dtype=np.uint32)
    want = np.array([int(x).bit_count() for x in v], dtype=np.int32)
    assert np.array_equal(bitpack.popcount32(v, np), want)
    assert np.array_equal(np.asarray(bitpack.popcount32(jnp.asarray(v),
                                                        jnp)), want)


def test_prefix_masks_select_first_count_lanes():
    for count in (0, 1, 31, 32, 33, 64, 155, 160, 200):
        masks = bitpack.prefix_masks(count, 155)
        bits = sum(int(m).bit_count() for m in masks)
        assert bits == min(count, 155)
        # masks are prefixes: unpacking gives lanes [0, count)
        w = np.asarray(masks, dtype=np.uint32)[None, :]
        lanes = bitpack.unpack_words(w, 155, np)[0]
        assert np.array_equal(lanes, np.arange(155) < count)


def test_lowest_set_bits_keeps_first_k_up_lanes():
    up = RNG.random((64, 96)) < 0.5
    planes = _planes(up, np)
    kept = bitpack.lowest_set_bits(planes, 3, np)
    got = bitpack.unpack_words(np.stack(kept, axis=-1), 96, np)
    want = up & (np.cumsum(up, axis=1) <= 3)
    assert np.array_equal(got, want)


def test_select_bit_reads_ranks_and_padding():
    up = RNG.random((32, 40)) < 0.6
    planes = _planes(up, np)
    rank = RNG.integers(0, 40, size=32).astype(np.int32)
    got = bitpack.select_bit(planes, rank, np)
    want = up[np.arange(32), rank].astype(np.int32)
    assert np.array_equal(got, want)
    # out-of-range ranks read as 0, like masked padding lanes
    assert np.array_equal(
        bitpack.select_bit(planes, np.full(32, 64, np.int32), np),
        np.zeros(32, np.int32))


# ---------------------------------------------------------------------------
# packed eval == boolean oracle (property-style, random rosters/rf/voters)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(2, 90), st.integers(1, 5), st.integers(1, 9),
       st.integers(0, 10 ** 6))
def test_pac_packed_equals_boolean_oracle(n_real, rf, voters, seed):
    rf = min(rf, n_real)
    n_pad = n_real + (-n_real % 8)
    up, full = _state(64, n_pad, n_real, seed)
    lark, maj, creps = pac_eval_rank_np(up, full, rf=rf, voters=voters,
                                        n_real=n_real)
    pl, pm, pc = bitpack.pac_eval_packed(
        _planes(up, np), _planes(full, np), rf=rf, voters=voters,
        n_real=n_real, xp=np)
    assert np.array_equal(pl, lark)
    assert np.array_equal(pm, maj)
    got = bitpack.unpack_words(np.stack(pc, axis=-1), n_pad, np)
    assert np.array_equal(got, creps)


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 90), st.integers(1, 5),
       st.sampled_from([False, True]), st.integers(0, 10 ** 6))
def test_downtime_packed_equals_boolean_oracle(n_real, rf, with_roster,
                                               seed):
    rf = min(rf, n_real)
    n_pad = n_real + (-n_real % 8)
    up, full = _state(64, n_pad, n_real, seed)
    rng = np.random.default_rng(seed + 1)
    roster = rng.integers(0, n_real, (64, rf)).astype(np.int32) \
        if with_roster else None
    want = downtime_eval_rank_np(up, full, rf=rf, n_real=n_real,
                                 roster=roster)
    rost = None if roster is None else \
        [roster[:, j] for j in range(rf)]
    got = bitpack.downtime_eval_packed(
        _planes(up, np), _planes(full, np), rf=rf, n_real=n_real,
        roster=rost, xp=np)
    for w, g in zip(want[:5], got[:5]):
        assert np.array_equal(np.asarray(g), np.asarray(w))
    creps = bitpack.unpack_words(np.stack(got[5], axis=-1), n_pad, np)
    assert np.array_equal(creps, want[5])


def test_packed_eval_identical_across_numpy_and_jnp():
    up, full = _state(128, 160, 155, seed=5)
    args = dict(rf=3, voters=5, n_real=155)
    a = bitpack.pac_eval_packed(_planes(up, np), _planes(full, np),
                                xp=np, **args)
    b = bitpack.pac_eval_packed(_planes(jnp.asarray(up), jnp),
                                _planes(jnp.asarray(full), jnp),
                                xp=jnp, **args)
    assert np.array_equal(np.asarray(b[0]), a[0])
    assert np.array_equal(np.asarray(b[1]), a[1])
    for x, y in zip(a[2], b[2]):
        assert np.array_equal(np.asarray(y), x)


# ---------------------------------------------------------------------------
# fused megakernel (interpret mode) == oracle, block-size invariant
# ---------------------------------------------------------------------------

def _packed_words(bools):
    return jnp.moveaxis(bitpack.pack_words(
        jnp.asarray(bools), jnp), -1, 1)


def test_fused_pac_kernel_matches_oracle_any_blocks():
    B, P, n_real, n_pad = 4, 64, 37, 40
    up, full = _state(B * P, n_pad, n_real, seed=9)
    lark, maj, creps = pac_eval_rank_np(up, full, rf=3, voters=5,
                                        n_real=n_real)
    upw = _packed_words(up.reshape(B, P, n_pad))
    fullw = _packed_words(full.reshape(B, P, n_pad))
    for bt, bp in ((1, 16), (2, 64), (4, 32)):
        l, m, cw = fused_step.fused_pac_eval(
            upw, fullw, rf=3, voters=5, n_real=n_real, block_t=bt,
            block_p=bp, interpret=True)
        assert np.array_equal(np.asarray(l).ravel(), lark)
        assert np.array_equal(np.asarray(m).ravel(), maj)
        got = bitpack.unpack_words(
            np.moveaxis(np.asarray(cw), 1, -1), n_pad, np)
        assert np.array_equal(got.reshape(B * P, n_pad), creps)


def test_fused_downtime_kernel_roster_counts_match_oracles():
    B, P, n_real, n_pad = 4, 64, 37, 40
    up, full = _state(B * P, n_pad, n_real, seed=11)
    rng = np.random.default_rng(13)
    roster = rng.integers(0, n_real, (B * P, 3)).astype(np.int32)
    recruit = rng.integers(0, n_real + 1, (B, P)).astype(np.int32)
    active = rng.random((B, P)) < 0.5
    want = downtime_eval_rank_np(up, full, rf=3, n_real=n_real,
                                 roster=roster)
    want_counts = rebuild_node_counts_np(recruit, active, n_real=n_real)
    upw = _packed_words(up.reshape(B, P, n_pad))
    fullw = _packed_words(full.reshape(B, P, n_pad))
    rost = jnp.moveaxis(jnp.asarray(roster.reshape(B, P, 3)), -1, 1)
    outs = fused_step.fused_downtime_eval(
        upw, fullw, rf=3, n_real=n_real, block_t=2, block_p=32,
        interpret=True, roster=rost, recruit=jnp.asarray(recruit),
        active=jnp.asarray(active))
    for w, g in zip(want[:5], outs[:5]):
        assert np.array_equal(np.asarray(g).ravel(), np.asarray(w))
    creps = bitpack.unpack_words(
        np.moveaxis(np.asarray(outs[5]), 1, -1), n_pad, np)
    assert np.array_equal(creps.reshape(B * P, n_pad), want[5])
    # counts accumulate across partition tiles; columns >= n_real are
    # sentinel padding the caller (ops.step_eval) slices off
    assert np.array_equal(np.asarray(outs[6])[:, :n_real], want_counts)


def test_fused_kernel_rejects_non_tiling_blocks():
    upw = jnp.zeros((4, 2, 48), dtype=jnp.uint32)
    with pytest.raises(ValueError, match="tile"):
        fused_step.fused_pac_eval(upw, upw, rf=2, voters=3, n_real=40,
                                  block_t=3, block_p=16, interpret=True)
    with pytest.raises(ValueError, match="tile"):
        fused_step.fused_downtime_eval(upw, upw, rf=2, n_real=40,
                                       block_t=2, block_p=36,
                                       interpret=True)


def test_packed_state_bytes_reduction():
    # five uint32 words replace a 256-lane bool tile at n=155: the carry
    # shrinks ~7.8x, the capacity half of the megakernel story
    packed = bitpack.packed_state_bytes(1024, 4096, 155)
    boolean = 1024 * 4096 * 155
    assert packed == 1024 * 5 * 4096 * 4
    assert boolean / packed > 7.5
