"""PAC conditions (§3) and safety lemmas 3.1-3.4 as hypothesis properties."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.pac import (ALL_CONDITIONS, evaluate_pac,
                            majority_quorum_available)
from repro.core.succession import succession_list

N = 9
RF = 3
ROSTER = list(range(N))


def pac(cluster, pid=0, full=frozenset(), conditions=ALL_CONDITIONS, rf=RF):
    succ = succession_list(pid, ROSTER)
    return evaluate_pac(cluster=set(cluster), roster=ROSTER, succession=succ,
                        rf=rf, full_nodes=set(full), conditions=conditions)


def test_super_majority():
    succ = succession_list(0, ROSTER)
    missing2 = set(ROSTER) - set(succ[:2])      # 7 nodes, 2 roster reps gone
    assert pac(missing2).available
    assert pac(missing2).condition == "super_majority"
    missing3 = set(ROSTER) - set(succ[:3])      # RF nodes missing
    assert pac(missing3, full=set()).available is False


def test_all_roster_replicas():
    succ = succession_list(0, ROSTER)
    just_reps = set(succ[:RF])                  # minority but all roster reps
    res = pac(just_reps)
    assert res.available and res.condition == "all_roster_replicas"


def test_simple_majority_needs_full_and_roster_rep():
    succ = succession_list(0, ROSTER)
    # majority present, only the LAST roster replica present, spare is full
    cluster = set(succ[2:3]) | set(succ[RF:RF + 4])
    assert len(cluster) == 5
    assert not pac(cluster, conditions=("simple_majority",)).available
    assert pac(cluster, full={succ[RF]},
               conditions=("simple_majority",)).available


def test_half_roster_requires_leader():
    succ = succession_list(0, ROSTER[:8])
    roster8 = ROSTER[:8]

    def pac8(cluster, full=frozenset(), conditions=ALL_CONDITIONS):
        return evaluate_pac(cluster=set(cluster), roster=roster8,
                            succession=succ, rf=RF, full_nodes=set(full),
                            conditions=conditions)
    half_with_leader = set(succ[:1]) | set(succ[5:8])
    assert len(half_with_leader) == 4
    assert pac8(half_with_leader, full={succ[0]},
                conditions=("half_roster",)).available
    half_no_leader = set(succ[4:8])
    assert not pac8(half_no_leader, full={succ[4]},
                    conditions=("half_roster",)).available


subsets = st.sets(st.sampled_from(ROSTER), min_size=0, max_size=N)


@given(subsets, subsets)
@settings(max_examples=300, deadline=None)
def test_lemma_31_roster_replica_included(cluster, full):
    """Lemma 3.1: any PAC-satisfying cluster includes a roster replica."""
    res = pac(cluster, full=full)
    if res.available:
        succ = succession_list(0, ROSTER)
        assert any(n in cluster for n in succ[:RF]), res


@given(subsets, subsets, subsets, subsets)
@settings(max_examples=300, deadline=None)
def test_lemma_32_33_intersection(c1, c2, f1, f2):
    """Lemmas 3.2/3.3: two disjoint clusters can't both satisfy PAC."""
    if c1 & c2:
        return
    r1, r2 = pac(c1, full=f1), pac(c2, full=f2)
    assert not (r1.available and r2.available), (c1, c2, r1, r2)


@given(subsets, st.sets(st.sampled_from(ROSTER), min_size=0, max_size=N))
@settings(max_examples=200, deadline=None)
def test_lemma_34_successor_includes_c1_replica(c1, c2):
    """Lemma 3.4 (structural form): if C1 was available with cluster replicas
    R1 (all full after its regime), and C2 is available with full set ⊆ R1,
    then C2 contains a member of R1."""
    succ = succession_list(0, ROSTER)
    r1 = pac(c1, full=set(succ[:RF]))
    if not r1.available:
        return
    from repro.core.succession import cluster_replicas
    creps1 = set(cluster_replicas(succ, set(c1), RF))
    r2 = pac(c2, full=creps1)
    if r2.available:
        if r2.condition in ("simple_majority", "half_roster"):
            assert creps1 & set(c2)
        elif r2.condition in ("super_majority", "all_roster_replicas"):
            # both clusters contain >= n-RF+1 or all roster reps: intersect
            assert (set(c1) & set(c2)) or not c1


def test_majority_baseline():
    succ = succession_list(0, ROSTER)
    voters = succ[:2 * (RF - 1) + 1]
    assert majority_quorum_available(set(voters[:3]), succ, RF)
    assert not majority_quorum_available(set(voters[:2]), succ, RF)
