"""Per-architecture smoke tests (assignment deliverable f): reduced config,
one forward/train step on CPU, output shapes + no NaNs; decode/prefill
parity against the full forward."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, reduced_config
from repro.configs.base import ShapeConfig
from repro.models import build_model, make_batch
from repro.training import make_train_step

S, B = 24, 2
RNG = np.random.default_rng(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_shapes_and_no_nans(arch):
    cfg = reduced_config(arch)
    init_fn, step_fn, _ = make_train_step(cfg, peak_lr=1e-3)
    params, opt_state = init_fn(jax.random.PRNGKey(0))
    batch = make_batch(cfg, ShapeConfig("t", S, B, "train"), RNG)
    params2, opt_state2, m = jax.jit(step_fn)(params, opt_state, batch)
    assert np.isfinite(float(m["loss"])), arch
    assert np.isfinite(float(m["grad_norm"])), arch
    # params changed but kept structure/shapes
    same = jax.tree.map(lambda a, b: a.shape == b.shape, params, params2)
    assert all(jax.tree.leaves(same))
    moved = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        params, params2))
    assert max(moved) > 0, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_full_forward(arch):
    cfg = reduced_config(arch)
    if cfg.moe is not None:  # disable capacity drops for exact parity
        cfg = cfg.replace(moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.num_experts)))
    m = build_model(cfg)
    params = m["init_params"](jax.random.PRNGKey(0))
    batch = make_batch(cfg, ShapeConfig("t", S, B, "train"), RNG)
    batch.pop("labels")

    if cfg.is_encoder_decoder:
        pre = {"audio_embeds": batch["audio_embeds"],
               "tokens": batch["tokens"][:, :S - 1]}
        last = batch["tokens"][:, S - 1]
        full = dict(pre, tokens=batch["tokens"])
    elif cfg.embeds_input:
        pre = {"embeds": batch["embeds"][:, :S - 1]}
        if cfg.position_inputs:
            pre["positions"] = batch["positions"][:, :, :S - 1]
        last = batch["embeds"][:, S - 1]
        full = {"embeds": batch["embeds"]}
        if cfg.position_inputs:
            full["positions"] = batch["positions"]
    else:
        pre = {"tokens": batch["tokens"][:, :S - 1]}
        last = batch["tokens"][:, S - 1]
        full = {"tokens": batch["tokens"]}

    _, state = jax.jit(m["prefill"], static_argnames="max_len")(
        params, pre, max_len=S)
    kw = {}
    if cfg.position_inputs:
        kw["positions"] = batch["positions"][:, :, S - 1:S]
    logits_dec, _ = jax.jit(m["decode_step"])(params, state, last,
                                              jnp.int32(S - 1), **kw)
    logits_full, _ = jax.jit(m["prefill"], static_argnames="max_len")(
        params, full, max_len=S)
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full), atol=1e-4, rtol=1e-3)


def test_sliding_window_restricts_attention():
    """SWA: a token far outside the window can't influence the output."""
    cfg = reduced_config("mixtral_8x7b").replace(window=8)
    m = build_model(cfg)
    params = m["init_params"](jax.random.PRNGKey(0))
    toks = RNG.integers(0, cfg.vocab_size, (1, 32)).astype(np.int32)
    toks2 = toks.copy()
    toks2[0, 0] = (toks2[0, 0] + 7) % cfg.vocab_size   # outside window of last
    l1, _ = m["prefill"](params, {"tokens": jnp.asarray(toks)}, 32)
    l2, _ = m["prefill"](params, {"tokens": jnp.asarray(toks2)}, 32)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=2e-5)


def test_loss_decreases_quickly_on_tiny_model():
    cfg = reduced_config("smollm_360m")
    from repro.data import SyntheticLMData
    data = SyntheticLMData(cfg, batch=4, seq=32)
    init_fn, step_fn, _ = make_train_step(cfg, peak_lr=5e-3)
    params, opt = init_fn(jax.random.PRNGKey(1))
    step = jax.jit(step_fn, donate_argnums=(0, 1))
    losses = []
    for i in range(30):
        b = jax.tree.map(jnp.asarray, data.batch_at(i % 4))
        params, opt, m = step(params, opt, b)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses[:3] + losses[-3:]
