import os
import sys
from pathlib import Path

# Tests see the single real CPU device (the 512-device override is reserved
# for the dry-run entrypoint, per the assignment).
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

# Property tests want hypothesis (installed by the `dev` extra); hermetic
# containers without it fall back to a deterministic smoke-level shim so the
# suite still collects and runs.
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, str(Path(__file__).resolve().parent))
    import _hypothesis_fallback
    _hypothesis_fallback.install()
