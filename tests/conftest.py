import os
import sys
from pathlib import Path

# Tests see the single real CPU device (the 512-device override is reserved
# for the dry-run entrypoint, per the assignment).
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
