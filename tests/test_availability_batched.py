"""Batched availability Monte Carlo: cross-backend agreement (numpy / jax /
pallas-interpret vs the event engine's evaluate), bit-identical seeded
trajectories, scenario semantics, and statistical agreement with the scalar
event engine on the reduced §5.1 grid."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.availability import evaluate_rank_state, simulate_availability
from repro.core.availability_batched import simulate_availability_batched
from repro.core.succession import succession_matrix_fast
from repro.kernels.ops import PAC_BACKENDS, pac_eval_batch

RNG = np.random.default_rng(7)


def _random_state(R, n, density=0.85):
    up = RNG.random((R, n)) < density
    full = RNG.random((R, n)) < 0.4
    return up, full


# ---------------------------------------------------------------------------
# backend agreement on random cluster states
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rf", [2, 3, 4])
def test_pac_backends_agree_random_states(rf):
    R, n = 128, 23
    voters = 2 * (rf - 1) + 1
    up, full = _random_state(R, n)
    outs = {}
    for b in PAC_BACKENDS:
        u = up if b == "numpy" else jnp.asarray(up)
        f = full if b == "numpy" else jnp.asarray(full)
        outs[b] = tuple(np.asarray(o) for o in pac_eval_batch(
            u, f, rf=rf, voters=voters, n_real=n, backend=b))
    for b in PAC_BACKENDS[1:]:
        for ref_o, o in zip(outs[PAC_BACKENDS[0]], outs[b]):
            assert np.array_equal(ref_o, o), b


def test_pac_backends_agree_with_padding():
    # padded node columns (rank >= n_real) must not affect any backend
    R, n_real, n_pad = 64, 19, 40
    up, full = _random_state(R, n_pad)
    outs = [tuple(np.asarray(o) for o in pac_eval_batch(
        up if b == "numpy" else jnp.asarray(up),
        full if b == "numpy" else jnp.asarray(full),
        rf=2, voters=3, n_real=n_real, backend=b)) for b in PAC_BACKENDS]
    for o in outs[1:]:
        for a, c in zip(outs[0], o):
            assert np.array_equal(a, c)
    # creps never selects padding columns
    assert not outs[0][2][:, n_real:].any()


def test_event_engine_evaluate_matches_backends():
    """The scalar event engine's per-event evaluation (PAC + frozen-holder
    refresh) is the numpy backend applied to one cluster state."""
    n, P, rf, voters = 17, 64, 2, 3
    succ = succession_matrix_fast(P, range(n), seed=1)
    up = RNG.random(n) < 0.7
    full_succ = RNG.random((P, n)) < 0.5
    full_event = full_succ.copy()

    unl, unm, up_succ = evaluate_rank_state(up, succ, full_event,
                                            rf=rf, voters=voters)
    lark, maj, creps = pac_eval_batch(jnp.asarray(up[succ]),
                                      jnp.asarray(full_succ), rf=rf,
                                      voters=voters, n_real=n, backend="jax")
    lark, maj, creps = (np.asarray(o) for o in (lark, maj, creps))
    assert unl == int((~lark).sum())
    assert unm == int((~maj).sum())
    assert np.array_equal(full_event,
                          np.where(lark[:, None], creps, full_succ))


# ---------------------------------------------------------------------------
# bit-identical seeded trajectories across backends
# ---------------------------------------------------------------------------

def test_trajectory_identical_across_backends():
    kw = dict(n=13, partitions=32, rf=2, p=5e-3, trials=3, max_ticks=4_000,
              min_ticks=10**9, chunk_steps=64, max_steps=600, seed=11,
              trajectory=True)
    results = {b: simulate_availability_batched(backend=b, **kw)
               for b in PAC_BACKENDS}
    base = results[PAC_BACKENDS[0]]
    for b in PAC_BACKENDS[1:]:
        r = results[b]
        for k in base.trajectory:
            assert np.array_equal(base.trajectory[k], r.trajectory[k]), \
                (b, k)
        assert r.u_lark == base.u_lark and r.u_maj == base.u_maj
        assert np.array_equal(r.u_lark_trials, base.u_lark_trials)
        assert r.lark_events == base.lark_events
    # trials are genuinely independent trajectories
    tr = base.trajectory["times"]
    assert not np.array_equal(tr[:, 0], tr[:, 1])


# ---------------------------------------------------------------------------
# scenario semantics
# ---------------------------------------------------------------------------

def test_correlated_pair_failures_hurt_availability():
    kw = dict(n=16, partitions=64, rf=2, p=5e-3, trials=4, max_ticks=60_000,
              min_ticks=10**9, seed=3, backend="numpy")
    iid = simulate_availability_batched(**kw)
    dual = simulate_availability_batched(pair_fail_prob=0.9, **kw)
    # rack-correlated double failures turn O(p^2) partition outages into
    # O(p) ones — the effect is large, not marginal
    assert dual.u_lark > 2 * iid.u_lark
    assert dual.u_maj > iid.u_maj


def test_rolling_restart_is_zero_downtime():
    # §5.3: serial restarts with rf=2 never lose availability (one node
    # down at a time keeps majority + a roster replica + a full holder)
    r = simulate_availability_batched(
        n=12, partitions=64, rf=2, p=1e-7, trials=2, max_ticks=30_000,
        min_ticks=10**9, restart_period=1_000, backend="numpy",
        trajectory=True)
    assert r.u_lark == 0.0 and r.lark_events == 0
    assert r.u_maj == 0.0
    # the restarts actually happened: events at the scheduled cadence
    times = r.trajectory["times"][:, 0]
    assert {1_000, 2_000, 3_000} <= set(times.tolist())
    # serial (wave width 1) maintenance never has two nodes down at once
    assert int(r.trajectory["nodes_up"].min()) >= 12 - 1


# ---------------------------------------------------------------------------
# statistical agreement with the scalar event engine
# ---------------------------------------------------------------------------

def test_batched_matches_analytic_small_fast():
    r = simulate_availability_batched(
        n=31, partitions=128, rf=2, p=5e-3, trials=4, min_ticks=20_000,
        max_ticks=60_000, seed=1, backend="jax")
    assert 0 < r.u_lark < r.u_maj
    assert 1.5 < r.improvement < 6.0


@pytest.mark.slow
@pytest.mark.parametrize("rf,p", [(2, 1e-3), (2, 3e-3), (2, 1e-2),
                                  (3, 1e-2), (4, 3e-2)])
def test_batched_within_event_ci_reduced_grid(rf, p):
    """Satellite acceptance: batched u_lark/u_maj agree with the event
    engine within 95% confidence on the reduced grid (combined half-widths,
    since both estimates carry sampling error)."""
    ev = simulate_availability(n=63, partitions=512, rf=rf, p=p,
                               max_ticks=250_000, min_ticks=30_000, seed=0)
    rb = simulate_availability_batched(
        n=63, partitions=512, rf=rf, p=p, trials=8, max_ticks=250_000,
        min_ticks=30_000, seed=0, backend="jax")
    assert abs(rb.u_lark - ev.u_lark) <= ev.ci_lark + rb.ci_lark
    assert abs(rb.u_maj - ev.u_maj) <= ev.ci_maj + rb.ci_maj
