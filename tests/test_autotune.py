"""Deterministic PAC block-size autotuner: candidate enumeration, choice
stability, the interpret-safe CPU fallback, and block-size invariance of
the kernel results — for both the 1-D block_p tuner and the fused
megakernel's 2-D (block_t, block_p) tuner."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ops import (_AUTOTUNE_CACHE, autotune_block_p,
                               autotune_fused_blocks, block_p_candidates,
                               fused_block_candidates, fused_vmem_bytes,
                               pac_eval_batch, pac_vmem_bytes)
from repro.kernels.pac_eval import pac_eval

RNG = np.random.default_rng(3)


def test_candidates_are_a_pure_function_of_the_shape():
    a = block_p_candidates(4096, 64)
    b = block_p_candidates(4096, 64)
    assert a == b and a
    assert all(4096 % bp == 0 for bp in a)
    assert all(pac_vmem_bytes(bp, 64) <= 8 * 2 ** 20 for bp in a)
    # a tighter VMEM budget prunes the big blocks
    small = block_p_candidates(4096, 64, vmem_limit_bytes=pac_vmem_bytes(64, 64))
    assert max(small) <= 64


def test_candidates_never_empty():
    # odd row counts still get the heuristic block
    assert block_p_candidates(7 * 31, 64)


def test_autotune_same_candidates_same_choice():
    fake = lambda R, n, bp: {16: 9.0, 32: 4.0, 64: 4.0, 128: 6.0}[bp]
    kw = dict(rf=3, voters=5, n_real=63, candidates=(16, 32, 64, 128),
              measure=fake)
    r1 = autotune_block_p(1024, 64, **kw)
    r2 = autotune_block_p(1024, 64, **kw)
    assert r1.block_p == r2.block_p == 32       # tie 32/64 -> smaller block
    assert r1 == r2
    assert r1.source == "measured"
    assert r1.timings_us[128] == 6.0


def test_autotune_rejects_non_tiling_candidates():
    with pytest.raises(ValueError, match="divide"):
        autotune_block_p(1000, 64, rf=2, voters=3, n_real=63,
                         candidates=(33,), measure=lambda *a: 1.0)


def test_autotune_cpu_fallback_is_deterministic_heuristic():
    # no injected measure + no TPU -> static heuristic, never a timing race
    r1 = autotune_block_p(2048, 64, rf=2, voters=3, n_real=63)
    r2 = autotune_block_p(2048, 64, rf=2, voters=3, n_real=63)
    assert r1.source == "heuristic-fallback"
    assert r1.block_p == r2.block_p == 256
    assert r1.timings_us == {}


@pytest.mark.slow
def test_forced_measurement_path_runs_off_tpu():
    # force=True exercises the real timing harness (interpret mode here:
    # functional coverage, not a timing proxy)
    r = autotune_block_p(128, 64, rf=2, voters=3, n_real=31,
                         candidates=(64, 128), iters=1, force=True)
    assert r.source == "measured"
    assert r.block_p in (64, 128)
    assert set(r.timings_us) == {64, 128}


@pytest.mark.slow
@pytest.mark.parametrize("kernel", ["downtime", "downtime_roster"])
def test_forced_measurement_races_the_downtime_kernels(kernel):
    """--metric downtime autotunes the kernel the grid actually runs (and
    the roster variant under --rebuild-model reconfig), not pac_eval."""
    r = autotune_block_p(128, 64, rf=3, voters=5, n_real=31,
                         candidates=(64, 128), iters=1, force=True,
                         kernel=kernel)
    assert r.source == "measured"
    assert set(r.timings_us) == {64, 128}


def test_kernel_selection_is_part_of_the_cache_key_and_validated():
    fake = lambda R, n, bp: float(bp)
    kw = dict(rf=2, voters=3, n_real=63, candidates=(32, 64), measure=fake)
    a = autotune_block_p(512, 64, kernel="pac", **kw)
    b = autotune_block_p(512, 64, kernel="downtime", **kw)
    assert a.block_p == b.block_p == 32          # same fake, same choice
    with pytest.raises(ValueError, match="autotune kernel"):
        autotune_block_p(512, 64, kernel="mystery", **kw)


# ---------------------------------------------------------------------------
# fused megakernel 2-D (block_t, block_p) tuner
# ---------------------------------------------------------------------------

def test_fused_candidates_pure_function_of_shape_and_budget():
    a = fused_block_candidates(8, 4096, 160, rf=3,
                               kernel="fused_downtime_roster")
    assert a == fused_block_candidates(8, 4096, 160, rf=3,
                                       kernel="fused_downtime_roster")
    assert a
    for bt, bp in a:
        assert 8 % bt == 0 and 4096 % bp == 0
        assert fused_vmem_bytes(bt, bp, 160, rf=3,
                                kernel="fused_downtime_roster") \
            <= 8 * 2 ** 20
    # a tighter budget prunes the fat tiles but never empties the set
    floor = fused_vmem_bytes(1, 8, 160, rf=3, kernel="fused_pac")
    small = fused_block_candidates(8, 4096, 160, rf=3,
                                   vmem_limit_bytes=floor)
    assert small
    assert max(bt * bp for bt, bp in small) <= 8


def test_fused_autotune_ties_break_toward_the_smaller_tile():
    fake = lambda B, P, n, bt, bp: {(1, 16): 4.0, (2, 16): 4.0,
                                    (2, 32): 4.0, (4, 32): 9.0}[(bt, bp)]
    kw = dict(rf=3, voters=5, n_real=63,
              candidates=((1, 16), (2, 16), (2, 32), (4, 32)),
              measure=fake)
    r1 = autotune_fused_blocks(4, 64, 64, **kw)
    r2 = autotune_fused_blocks(4, 64, 64, **kw)
    assert (r1.block_t, r1.block_p) == (r2.block_t, r2.block_p) == (1, 16)
    assert r1.source == "measured"
    assert r1.timings_us[(4, 32)] == 9.0


def test_fused_autotune_rejects_bad_candidates_and_kernels():
    with pytest.raises(ValueError, match="does not tile"):
        autotune_fused_blocks(4, 64, 64, rf=2, voters=3, n_real=63,
                              candidates=((3, 16),),
                              measure=lambda *a: 1.0)
    with pytest.raises(ValueError, match="fused autotune kernel"):
        autotune_fused_blocks(4, 64, 64, rf=2, voters=3, n_real=63,
                              kernel="mystery")


def test_fused_autotune_cpu_fallback_is_deterministic_heuristic():
    kw = dict(rf=2, voters=3, n_real=63)
    r1 = autotune_fused_blocks(2048, 64, 64, **kw)
    r2 = autotune_fused_blocks(2048, 64, 64, **kw)
    assert r1.source == "heuristic-fallback"
    assert (r1.block_t, r1.block_p) == (r2.block_t, r2.block_p)
    assert 2048 % r1.block_t == 0 and 64 % r1.block_p == 0
    assert r1.timings_us == {}


def test_fused_cache_key_cannot_alias_a_block_p_entry():
    """The 2-D tuner's cache entries are tagged "fused" + kernel kind +
    full geometry; identical numeric prefixes from the 1-D tuner land on
    distinct keys, so the wrong-kernel cache race can't come back."""
    autotune_block_p(512, 64, rf=2, voters=3, n_real=63)
    autotune_fused_blocks(512, 64, 64, rf=2, voters=3, n_real=63)
    tags = {k[0] for k in _AUTOTUNE_CACHE}
    assert {"block_p", "fused"} <= tags
    for k in _AUTOTUNE_CACHE:
        assert k[0] in ("block_p", "fused")


@pytest.mark.slow
@pytest.mark.parametrize("kernel", ["fused_pac", "fused_downtime",
                                    "fused_downtime_roster"])
def test_forced_fused_measurement_races_every_kernel_kind(kernel):
    r = autotune_fused_blocks(4, 32, 64, rf=3, voters=5, n_real=63,
                              candidates=((1, 16), (2, 32)), iters=1,
                              force=True, kernel=kernel)
    assert r.source == "measured"
    assert (r.block_t, r.block_p) in ((1, 16), (2, 32))
    assert set(r.timings_us) == {(1, 16), (2, 32)}


def test_block_size_does_not_change_kernel_results():
    R, n = 512, 64
    up = jnp.asarray(RNG.random((R, n)) < 0.9)
    full = jnp.asarray(RNG.random((R, n)) < 0.3)
    outs = [tuple(np.asarray(o) for o in pac_eval_batch(
        up, full, rf=3, voters=5, n_real=63, backend="pallas", block_p=bp))
        for bp in (32, 128, 512)]
    for o in outs[1:]:
        for a, b in zip(outs[0], o):
            assert np.array_equal(a, b)


def test_pac_eval_rejects_non_tiling_block():
    up = jnp.zeros((96, 128), dtype=bool)
    with pytest.raises(ValueError, match="tile"):
        pac_eval(up, up, rf=2, voters=3, n_real=63, block_p=64,
                 interpret=True)
