"""Checkpoint substrate: LARK store vs quorum-log baseline, disk, async."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.checkpoint import (AsyncCheckpointer, LarkStore, QuorumLogStore,
                              load_pytree, save_pytree)


def test_lark_store_put_get():
    s = LarkStore(4, rf=2, num_partitions=8)
    assert s.put("a", 123)
    ok, v = s.get("a")
    assert ok and v == 123


def test_lark_store_survives_node_failure():
    s = LarkStore(4, rf=2, num_partitions=8)
    for i in range(16):
        assert s.put(f"k{i}", i)
    s.fail_node(0)
    assert s.available_fraction() == 1.0        # PAC keeps all partitions up
    for i in range(16):
        ok, v = s.get(f"k{i}")
        assert ok and v == i
    assert s.put("new-key", "during-outage")
    s.recover_node(0)
    ok, v = s.get("new-key")
    assert ok and v == "during-outage"


def test_lark_vs_baseline_commit_window():
    lark = LarkStore(4, rf=2, num_partitions=16)
    base = QuorumLogStore(4, rf=2, num_partitions=16,
                          partition_bytes=1e9, bandwidth=5e6)  # 200s rebuild
    lark.fail_node(3)
    base.fail_node(3)
    base.advance(10)
    lark_ok = sum(lark.put(f"k{i}", i) for i in range(32))
    base_ok = sum(base.put(f"k{i}", i) for i in range(32))
    assert lark_ok == 32
    assert base_ok < 32          # partitions with node3 as data replica pause
    base.advance(300)            # rebuild complete
    assert sum(base.put(f"k2{i}", i) for i in range(32)) == 32


def test_lark_store_pytree_roundtrip():
    s = LarkStore(4, rf=2, num_partitions=8)
    tree = {"w": np.arange(6).reshape(2, 3), "b": np.float32(1.5)}
    ok, total = s.put_pytree("ckpt", tree)
    assert ok == total
    good, back = s.get_pytree("ckpt", tree)
    assert good
    np.testing.assert_array_equal(back["w"], tree["w"])


def test_disk_roundtrip(tmp_path):
    tree = {"a": jnp.arange(4.0), "b": {"c": jnp.ones((2, 2))}}
    save_pytree(tmp_path, tree, step=7, regime=3)
    back, manifest = load_pytree(tmp_path, tree)
    assert manifest["step"] == 7 and manifest["regime"] == 3
    np.testing.assert_array_equal(np.asarray(back["a"]), np.arange(4.0))


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(tmp_path)
    tree = {"x": jnp.full((8,), 3.0)}
    for step in (0, 1, 2):
        ck.save(tree, step=step, regime=1)
    ck.close()
    assert not ck.errors
    back, manifest = load_pytree(tmp_path, tree)
    assert manifest["step"] == 2
