"""The unified step API (kernels.ops.StepSpec / step_eval), the
DowntimeParams knob dataclass, and the deprecated legacy wrappers.

Pins: spec/argument validation errors all fire at construction/dispatch
with the messages callers match on; the packed (bit-word) layout is
bit-identical to the boolean layout across every backend; params= and
loose keywords drive simulate_downtime_batched to identical results; the
legacy per-kernel entry points warn but still return their exact legacy
tuples."""
import warnings

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.downtime_batched import (DowntimeParams,
                                         simulate_downtime_batched)
from repro.kernels import bitpack
from repro.kernels.ops import (PAC_BACKENDS, StepSpec, downtime_eval_batch,
                               pac_eval_batch, rebuild_node_counts,
                               step_eval, step_hbm_bytes)

RNG = np.random.default_rng(23)

_KW = dict(n=13, partitions=32, rf=2, p=5e-3, trials=3, max_ticks=4_000,
           min_ticks=10**9, chunk_steps=64, max_steps=600, seed=11,
           trajectory=True)


def _state(R, n_pad, n_real, seed=3):
    rng = np.random.default_rng(seed)
    up = rng.random((R, n_pad)) < 0.85
    full = rng.random((R, n_pad)) < 0.4
    up[:, n_real:] = False
    full[:, n_real:] = False
    return up, full


def _pack(bools, B, P, n_pad):
    return jnp.moveaxis(bitpack.pack_words(
        jnp.asarray(bools).reshape(B, P, n_pad), jnp), -1, 1)


# ---------------------------------------------------------------------------
# StepSpec construction and derived properties
# ---------------------------------------------------------------------------

def test_stepspec_validation_errors():
    ok = dict(metric="downtime", rf=3, n_real=9)
    StepSpec(**ok)                                   # sanity
    with pytest.raises(ValueError, match="step metric"):
        StepSpec(**{**ok, "metric": "latency"})
    with pytest.raises(ValueError, match="rebuild_model"):
        StepSpec(**ok, rebuild_model="raid")
    with pytest.raises(ValueError, match="rf="):
        StepSpec(metric="downtime", rf=10, n_real=9)
    with pytest.raises(ValueError, match="rf="):
        StepSpec(metric="downtime", rf=0, n_real=9)
    with pytest.raises(ValueError, match="voters"):
        StepSpec(**ok, voters=0)
    with pytest.raises(ValueError, match="must be >= 0"):
        StepSpec(**ok, dupres_ticks=-1)
    with pytest.raises(ValueError, match="must be >= 0"):
        StepSpec(**ok, rebuild_steps=-1)


def test_stepspec_is_frozen_and_hashable():
    spec = StepSpec(metric="availability", rf=3, n_real=155)
    with pytest.raises(Exception):
        spec.rf = 4
    assert spec == StepSpec(metric="availability", rf=3, n_real=155)
    assert len({spec, StepSpec(metric="availability", rf=3, n_real=155,
                               packed=True)}) == 2


def test_stepspec_resolved_voters_follow_the_paper():
    # availability: 2*(rf-1)+1 majority voters; downtime: rf replicas
    assert StepSpec(metric="availability", rf=3,
                    n_real=9).resolved_voters == 5
    assert StepSpec(metric="downtime", rf=3, n_real=9).resolved_voters == 3
    assert StepSpec(metric="downtime", rf=3, n_real=9,
                    voters=7).resolved_voters == 7


def test_stepspec_fused_kernel_kinds():
    assert StepSpec(metric="availability", rf=3,
                    n_real=9).fused_kernel == "fused_pac"
    assert StepSpec(metric="downtime", rf=3,
                    n_real=9).fused_kernel == "fused_downtime"
    assert StepSpec(metric="downtime", rf=3, n_real=9,
                    rebuild_model="reconfig").fused_kernel \
        == "fused_downtime_roster"


# ---------------------------------------------------------------------------
# step_eval argument validation
# ---------------------------------------------------------------------------

def test_step_eval_rejects_mismatched_arguments():
    up, full = _state(8, 16, 13)
    avail = StepSpec(metric="availability", rf=2, n_real=13)
    fixed = StepSpec(metric="downtime", rf=2, n_real=13)
    roster = np.zeros((8, 2), np.int32)
    with pytest.raises(ValueError, match="roster"):
        step_eval(fixed, up, full, roster=roster, backend="numpy")
    with pytest.raises(ValueError, match="together"):
        step_eval(fixed, up, full, recruit=np.zeros((1, 8), np.int32),
                  backend="numpy")
    with pytest.raises(ValueError, match="downtime"):
        step_eval(avail, up, full, recruit=np.zeros((1, 8), np.int32),
                  active=np.ones((1, 8), bool), backend="numpy")
    with pytest.raises(ValueError, match="backend"):
        step_eval(avail, up, full, backend="torch")


# ---------------------------------------------------------------------------
# layout bit-identity: packed x every backend == unpacked numpy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", PAC_BACKENDS)
def test_step_eval_availability_packed_matches_unpacked(backend):
    B, P, n_real, n_pad = 4, 32, 13, 16
    up, full = _state(B * P, n_pad, n_real)
    spec = StepSpec(metric="availability", rf=2, n_real=n_real)
    want = step_eval(spec, up, full, backend="numpy")
    upw, fullw = _pack(up, B, P, n_pad), _pack(full, B, P, n_pad)
    if backend == "numpy":
        upw, fullw = np.asarray(upw), np.asarray(fullw)
    got = step_eval(StepSpec(metric="availability", rf=2, n_real=n_real,
                             packed=True), upw, fullw, backend=backend)
    assert np.array_equal(np.asarray(got.lark).ravel(), want.lark)
    assert np.array_equal(np.asarray(got.maj).ravel(), want.maj)
    creps = bitpack.unpack_words(
        np.moveaxis(np.asarray(got.creps), 1, -1), n_pad, np)
    assert np.array_equal(creps.reshape(B * P, n_pad), want.creps)
    assert got.leader is None and got.counts is None


@pytest.mark.parametrize("backend", PAC_BACKENDS)
def test_step_eval_reconfig_packed_matches_unpacked(backend):
    B, P, n_real, n_pad = 4, 32, 13, 16
    up, full = _state(B * P, n_pad, n_real, seed=5)
    rng = np.random.default_rng(7)
    roster = rng.integers(0, n_real, (B * P, 3)).astype(np.int32)
    recruit = rng.integers(0, n_real + 1, (B, P)).astype(np.int32)
    active = rng.random((B, P)) < 0.5
    spec = StepSpec(metric="downtime", rf=3, n_real=n_real,
                    rebuild_model="reconfig")
    want = step_eval(spec, up, full, roster=roster, recruit=recruit,
                     active=active, backend="numpy")
    upw, fullw = _pack(up, B, P, n_pad), _pack(full, B, P, n_pad)
    # packed step_eval takes the engine's carried (B, P, rf) roster layout
    rost = jnp.asarray(roster.reshape(B, P, 3))
    rec, act = jnp.asarray(recruit), jnp.asarray(active)
    if backend == "numpy":
        upw, fullw = np.asarray(upw), np.asarray(fullw)
        rost, rec, act = roster.reshape(B, P, 3), recruit, active
    got = step_eval(StepSpec(metric="downtime", rf=3, n_real=n_real,
                             rebuild_model="reconfig", packed=True),
                    upw, fullw, roster=rost, recruit=rec, active=act,
                    backend=backend)
    for name in ("lark", "maj", "leader", "leader_full", "nrep"):
        assert np.array_equal(np.asarray(getattr(got, name)).ravel(),
                              getattr(want, name)), (backend, name)
    creps = bitpack.unpack_words(
        np.moveaxis(np.asarray(got.creps), 1, -1), n_pad, np)
    assert np.array_equal(creps.reshape(B * P, n_pad), want.creps)
    assert np.array_equal(np.asarray(got.counts), want.counts)


def test_step_hbm_bytes_reports_fused_savings():
    spec = StepSpec(metric="downtime", rf=3, n_real=155,
                    rebuild_model="reconfig", packed=True)
    hbm = step_hbm_bytes(spec, 8, 4096, 160)
    assert hbm["fused_bytes"] <= hbm["unfused_bytes"]
    assert hbm["ratio"] > 1


# ---------------------------------------------------------------------------
# DowntimeParams: one home for the §6 knob rules
# ---------------------------------------------------------------------------

def test_downtime_params_defaults_are_valid_and_fixed_model():
    p = DowntimeParams()
    assert not p.reconfig and not p.bandwidth_shared


def test_downtime_params_validation_errors():
    with pytest.raises(ValueError, match="dupres_ticks"):
        DowntimeParams(dupres_ticks=-1)
    with pytest.raises(ValueError, match="rebuild_steps"):
        DowntimeParams(rebuild_steps=-1)
    with pytest.raises(ValueError, match="hist_bins"):
        DowntimeParams(hist_bins=1)
    with pytest.raises(ValueError, match="hist_bins"):
        DowntimeParams(hist_bins=31)
    with pytest.raises(ValueError, match="rebuild_model"):
        DowntimeParams(rebuild_model="raid")
    with pytest.raises(ValueError, match="rebuild_ticks_per_gib"):
        DowntimeParams(rebuild_model="reconfig", rebuild_ticks_per_gib=-1)
    with pytest.raises(ValueError, match="size_dist"):
        DowntimeParams(rebuild_model="reconfig", size_dist="pareto")
    with pytest.raises(ValueError, match="size_skew"):
        DowntimeParams(rebuild_model="reconfig", size_skew=-0.1)
    with pytest.raises(ValueError, match="quantum"):
        DowntimeParams(rebuild_model="reconfig", node_bandwidth_gibps=0)
    # the size knobs describe reconfig's data-sized catch-ups only;
    # node_bandwidth_gibps now applies to both rebuild models
    with pytest.raises(ValueError, match="reconfig"):
        DowntimeParams(size_dist="zipf")
    p = DowntimeParams(node_bandwidth_gibps=4.0)
    assert p.bandwidth_shared and not p.reconfig
    with pytest.raises(ValueError, match="quantum"):
        DowntimeParams(node_bandwidth_gibps=0.003)
    with pytest.raises(ValueError, match="write_skew"):
        DowntimeParams(write_skew=-0.1)
    with pytest.raises(ValueError, match="write_skew"):
        DowntimeParams(write_skew=9.0)
    with pytest.raises(ValueError, match="slo_curve_bins"):
        DowntimeParams(slo_curve_bins=-1)
    with pytest.raises(ValueError, match="slo_curve_bins"):
        DowntimeParams(hist_bins=16, slo_curve_bins=17)


def test_downtime_params_reconfig_properties():
    p = DowntimeParams(rebuild_model="reconfig", size_dist="zipf",
                       size_skew=1.2, node_bandwidth_gibps=2.0)
    assert p.reconfig and p.bandwidth_shared


def test_engine_accepts_params_identical_to_loose_kwargs():
    knobs = dict(rebuild_model="reconfig", size_dist="zipf", size_skew=1.0,
                 node_bandwidth_gibps=2.0, dupres_ticks=2,
                 rebuild_steps=60)
    legacy = simulate_downtime_batched(backend="numpy", **knobs, **_KW)
    via_params = simulate_downtime_batched(
        backend="numpy", params=DowntimeParams(**knobs), **_KW)
    for k in legacy.trajectory:
        assert np.array_equal(legacy.trajectory[k],
                              via_params.trajectory[k]), k
    assert legacy.pause_lark == via_params.pause_lark
    assert legacy.pause_quorum == via_params.pause_quorum
    assert np.array_equal(legacy.hist_quorum, via_params.hist_quorum)


# (packed-vs-unpacked engine identity now lives in the consolidated
# matrix: tests/test_conformance.py)


# ---------------------------------------------------------------------------
# deprecated wrappers: warn, but return the exact legacy tuples
# ---------------------------------------------------------------------------

def test_pac_eval_batch_deprecated_but_faithful():
    up, full = _state(64, 16, 13)
    spec = StepSpec(metric="availability", rf=2, voters=3, n_real=13)
    want = step_eval(spec, up, full, backend="numpy")
    with pytest.warns(DeprecationWarning, match="step_eval"):
        lark, maj, creps = pac_eval_batch(up, full, rf=2, voters=3,
                                          n_real=13, backend="numpy")
    assert np.array_equal(lark, want.lark)
    assert np.array_equal(maj, want.maj)
    assert np.array_equal(creps, want.creps)


def test_downtime_eval_batch_deprecated_but_faithful():
    up, full = _state(64, 16, 13, seed=9)
    roster = RNG.integers(0, 13, (64, 2)).astype(np.int32)
    with pytest.warns(DeprecationWarning, match="step_eval"):
        legacy = downtime_eval_batch(up, full, rf=2, n_real=13,
                                     backend="numpy", roster=roster)
    spec = StepSpec(metric="downtime", rf=2, n_real=13,
                    rebuild_model="reconfig")
    want = step_eval(spec, up, full, roster=roster, backend="numpy")
    assert len(legacy) == 6
    for got, exp in zip(legacy, (want.lark, want.maj, want.leader,
                                 want.leader_full, want.nrep, want.creps)):
        assert np.array_equal(got, exp)


def test_rebuild_node_counts_deprecated_but_faithful():
    recruit = RNG.integers(0, 14, (4, 32)).astype(np.int32)
    active = RNG.random((4, 32)) < 0.5
    with pytest.warns(DeprecationWarning):
        counts = rebuild_node_counts(recruit, active, n_real=13,
                                     backend="numpy")
    spec = StepSpec(metric="downtime", rf=2, n_real=13,
                    rebuild_model="reconfig")
    up = np.zeros((4 * 32, 16), bool)
    up[:, 0] = True
    roster = np.zeros((4 * 32, 2), np.int32)
    want = step_eval(spec, up, up, roster=roster, recruit=recruit,
                     active=active, backend="numpy")
    assert np.array_equal(counts, want.counts)


def test_new_entry_point_does_not_warn():
    up, full = _state(16, 16, 13)
    spec = StepSpec(metric="availability", rf=2, n_real=13)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        step_eval(spec, up, full, backend="numpy")
