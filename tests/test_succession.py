"""Placement properties (paper §2.1-2.2): determinism, uniformity, minimal
disruption under membership change."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.succession import (cluster_replicas, key_partition,
                                   succession_list, succession_matrix_fast)


def test_deterministic():
    assert succession_list(7, range(10)) == succession_list(7, range(10))
    assert key_partition("abc") == key_partition("abc")


def test_uniform_partition_distribution():
    counts = np.zeros(64)
    for i in range(20000):
        counts[key_partition(f"key-{i}", 64)] += 1
    # chi-square-ish: no partition more than 2x the mean
    assert counts.max() < 2 * counts.mean()
    assert counts.min() > 0.5 * counts.mean()


def test_uniform_leader_load():
    n, P = 10, 512
    leaders = np.zeros(n)
    for p in range(P):
        leaders[succession_list(p, range(n))[0]] += 1
    assert leaders.max() < 2.5 * P / n


@given(st.integers(0, 1000), st.integers(3, 12))
@settings(max_examples=30, deadline=None)
def test_left_shift_on_removal(pid, n):
    """Removing a node only left-shifts lists where it appears (fig 3b)."""
    roster = list(range(n))
    full = succession_list(pid, roster)
    removed = full[2] if n > 2 else full[0]
    without = succession_list(pid, [x for x in roster if x != removed])
    assert without == [x for x in full if x != removed]


@given(st.integers(0, 1000), st.integers(3, 12))
@settings(max_examples=30, deadline=None)
def test_insertion_preserves_relative_order(pid, n):
    """Adding a node right-shifts lower-ranked nodes only (fig 3c/§2.2)."""
    roster = list(range(n))
    with_new = succession_list(pid, roster + [n + 100])
    assert [x for x in with_new if x != n + 100] == succession_list(pid, roster)


def test_cluster_replicas_first_rf_present():
    succ = [3, 1, 4, 0, 2]
    assert cluster_replicas(succ, {0, 1, 2}, 2) == [1, 0]
    assert cluster_replicas(succ, {2}, 2) == [2]
    assert cluster_replicas(succ, set(), 2) == []


def test_matrix_fast_shape_and_permutation():
    m = succession_matrix_fast(32, range(9))
    assert m.shape == (32, 9)
    for row in m:
        assert sorted(row.tolist()) == list(range(9))
