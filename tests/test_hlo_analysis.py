"""HLO analyzer: loop-trip multipliers, dot flops, collective bytes."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze_hlo


def test_scan_flops_multiplied_by_trip_count():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    args = (jax.ShapeDtypeStruct((64, 64), jnp.float32),
            jax.ShapeDtypeStruct((64, 64), jnp.float32))
    compiled = jax.jit(f).lower(*args).compile()
    r = analyze_hlo(compiled.as_text())
    want = 2 * 64 * 64 * 64 * 10
    assert r["flops"] == pytest.approx(want, rel=0.05), r["flops"]
    # XLA's own analysis counts the body once — ours must be ~10x larger
    ca = compiled.cost_analysis()
    if isinstance(ca, list):        # older jax returns [dict], newer a dict
        ca = ca[0]
    assert r["flops"] > 5 * ca["flops"]


def test_single_dot_flops():
    f = lambda a, b: a @ b
    args = (jax.ShapeDtypeStruct((128, 256), jnp.float32),
            jax.ShapeDtypeStruct((256, 32), jnp.float32))
    compiled = jax.jit(f).lower(*args).compile()
    r = analyze_hlo(compiled.as_text())
    assert r["flops"] == pytest.approx(2 * 128 * 256 * 32, rel=0.01)


def test_hbm_bytes_reasonable_for_elementwise():
    f = lambda a: a * 2.0 + 1.0
    args = (jax.ShapeDtypeStruct((1024, 1024), jnp.float32),)
    compiled = jax.jit(f).lower(*args).compile()
    r = analyze_hlo(compiled.as_text())
    nbytes = 1024 * 1024 * 4
    assert nbytes * 1.5 <= r["hbm_bytes"] <= nbytes * 4
