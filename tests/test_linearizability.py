"""Linearizability: checker unit tests + randomized protocol schedules.

The randomized tests drive the full protocol (failures, reclustering,
deferred rebalances, migrations, interleaved reads/writes) and check every
per-key history with the Wing-Gong search — the executable analogue of
Theorems B.9-B.11.
"""
import random

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.linearizability import (Op, check_history, check_linearizable,
                                        history_to_ops)
from repro.core.simulator import LarkSim

INF = float("inf")


# ---------------------------------------------------------------------------
# checker unit tests
# ---------------------------------------------------------------------------

def test_simple_sequential_ok():
    ops = [Op(1, "write", "a", 0, 1, True), Op(2, "read", "a", 2, 3, True)]
    assert check_linearizable(ops)


def test_stale_read_rejected():
    ops = [Op(1, "write", "a", 0, 1, True), Op(2, "write", "b", 2, 3, True),
           Op(3, "read", "a", 4, 5, True)]
    assert not check_linearizable(ops)


def test_concurrent_overlap_ok():
    # read overlapping two writes may return either
    ops = [Op(1, "write", "a", 0, 10, True), Op(2, "write", "b", 0, 10, True),
           Op(3, "read", "a", 0, 10, True)]
    assert check_linearizable(ops)


def test_optional_write_may_or_may_not_apply():
    base = [Op(1, "write", "a", 0, 1, True)]
    pending = Op(2, "write", "b", 2, INF, False)
    read_old = Op(3, "read", "a", 3, 4, True)
    read_new = Op(4, "read", "b", 5, 6, True)
    assert check_linearizable(base + [pending, read_old])
    assert check_linearizable(base + [pending, read_new])
    # but a mandatory write must be observed by a later read
    mand = Op(2, "write", "b", 2, 3, True)
    assert not check_linearizable(base + [mand, Op(3, "read", "a", 4, 5, True)])


def test_real_time_order_enforced():
    ops = [Op(1, "write", "a", 0, 1, True), Op(2, "write", "b", 2, 3, True),
           Op(3, "read", "a", 10, 11, True)]
    assert not check_linearizable(ops)


# ---------------------------------------------------------------------------
# randomized protocol schedules
# ---------------------------------------------------------------------------

def run_random_schedule(seed: int, n=5, rf=2, events=16, return_sim=False):
    rng = random.Random(seed)
    sim = LarkSim(num_nodes=n, rf=rf, num_partitions=1, seed=seed)
    sim.recluster()
    sim.settle()
    sim.run_migrations()
    vcount = 0
    ops = 0
    for i in range(events):
        roll = rng.random()
        if roll < 0.2 and len(sim.alive) > n // 2 + 1:
            victim = rng.choice(sorted(sim.alive))
            sim.fail_node(victim)
            sim.settle()
            if rng.random() < 0.7:
                sim.run_migrations()
        elif roll < 0.4 and len(sim.alive) < n:
            back = rng.choice(sorted(set(range(n)) - sim.alive))
            sim.recover_node(back)
            sim.settle()
            if rng.random() < 0.7:
                sim.run_migrations()
        elif roll < 0.7 and ops < 15:
            vcount += 1
            ops += 1
            sim.client_write(0, "k0", f"v{vcount}")
            if rng.random() < 0.8:
                sim.settle()
        elif ops < 15:
            ops += 1
            sim.client_read(0, "k0")
            if rng.random() < 0.8:
                sim.settle()
    sim.settle()
    if return_sim:
        return sim.finalize_history(), sim
    return sim.finalize_history()


@pytest.mark.parametrize("seed", range(25))
def test_random_schedules_linearizable(seed):
    hist = run_random_schedule(seed)
    results = check_history(hist)
    assert all(results.values()), (seed, results)


@pytest.mark.parametrize("seed", range(10))
def test_random_schedules_rf3(seed):
    hist = run_random_schedule(seed + 1000, n=6, rf=3, events=24)
    results = check_history(hist)
    assert all(results.values()), (seed, results)


def test_replicated_versions_form_chain():
    """Theorem B.9 audit: versions that reached 'replicated' status anywhere
    are a function of their logical clock (no two distinct replicated values
    share an LC => the version lineage is a single LC-ordered chain)."""
    for seed in range(10):
        _, sim = run_random_schedule(seed, return_sim=True)
        by_lc = {}
        for node in sim.nodes.values():
            entries = [(k, lc, v) for (k, lc, v, status) in node.accept_log
                       if status == "replicated"]
            for pid in node.last_replicated:
                entries += [(k, ver.lc, ver.value)
                            for k, ver in node.last_replicated[pid].items()]
            for k, lc, v in entries:
                key = (k, tuple(lc))
                assert by_lc.setdefault(key, v) == v, \
                    f"seed {seed}: two replicated values at LC {key}"
