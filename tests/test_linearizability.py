"""Linearizability: checker unit tests + randomized protocol schedules.

The randomized tests drive the full protocol (failures, reclustering,
deferred rebalances, migrations, interleaved reads/writes) and check every
per-key history with the Wing-Gong search — the executable analogue of
Theorems B.9-B.11.
"""
import random

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.linearizability import (Op, check_history, check_linearizable,
                                        history_to_ops)
from repro.core.simulator import LarkSim

INF = float("inf")


# ---------------------------------------------------------------------------
# checker unit tests
# ---------------------------------------------------------------------------

def test_simple_sequential_ok():
    ops = [Op(1, "write", "a", 0, 1, True), Op(2, "read", "a", 2, 3, True)]
    assert check_linearizable(ops)


def test_stale_read_rejected():
    ops = [Op(1, "write", "a", 0, 1, True), Op(2, "write", "b", 2, 3, True),
           Op(3, "read", "a", 4, 5, True)]
    assert not check_linearizable(ops)


def test_concurrent_overlap_ok():
    # read overlapping two writes may return either
    ops = [Op(1, "write", "a", 0, 10, True), Op(2, "write", "b", 0, 10, True),
           Op(3, "read", "a", 0, 10, True)]
    assert check_linearizable(ops)


def test_optional_write_may_or_may_not_apply():
    base = [Op(1, "write", "a", 0, 1, True)]
    pending = Op(2, "write", "b", 2, INF, False)
    read_old = Op(3, "read", "a", 3, 4, True)
    read_new = Op(4, "read", "b", 5, 6, True)
    assert check_linearizable(base + [pending, read_old])
    assert check_linearizable(base + [pending, read_new])
    # but a mandatory write must be observed by a later read
    mand = Op(2, "write", "b", 2, 3, True)
    assert not check_linearizable(base + [mand, Op(3, "read", "a", 4, 5, True)])


def test_real_time_order_enforced():
    ops = [Op(1, "write", "a", 0, 1, True), Op(2, "write", "b", 2, 3, True),
           Op(3, "read", "a", 10, 11, True)]
    assert not check_linearizable(ops)


# ---------------------------------------------------------------------------
# adversarial histories: crafted schedules the checker must reject
# ---------------------------------------------------------------------------

def test_read_of_never_written_value_rejected():
    ops = [Op(1, "write", "a", 0, 1, True),
           Op(2, "read", "ghost", 2, 3, True)]
    assert not check_linearizable(ops)


def test_initial_value_cannot_reappear_after_mandatory_write():
    ops = [Op(1, "write", "a", 0, 1, True), Op(2, "read", None, 2, 3, True)]
    assert not check_linearizable(ops)
    # ...but observing the initial value before the write is fine
    assert check_linearizable([Op(2, "read", None, 0, 1, True),
                               Op(1, "write", "a", 2, 3, True)])


def test_fresh_then_stale_read_rejected():
    """Once any reader observed the newer version, the older one is gone
    for good — a later read of it has no linearization point."""
    ops = [Op(1, "write", "a", 0, 1, True), Op(2, "write", "b", 2, 3, True),
           Op(3, "read", "b", 4, 5, True), Op(4, "read", "a", 6, 7, True)]
    assert not check_linearizable(ops)


def test_readers_cannot_disagree_on_concurrent_write_order():
    """Two writes race; both complete before any read.  Sequential
    readers then observing a-then-b would need the second write to
    linearize between the two reads — after its response — so no total
    order exists."""
    ops = [Op(1, "write", "a", 0, 10, True), Op(2, "write", "b", 0, 10, True),
           Op(3, "read", "a", 11, 12, True), Op(4, "read", "b", 13, 14, True)]
    assert not check_linearizable(ops)


def test_interleaved_overlap_has_a_witness_order():
    """Contrast case: while a write is still in flight, readers may
    straddle it — same observations as above become legal when the
    second write's interval covers the second read."""
    ops = [Op(1, "write", "a", 0, 10, True), Op(2, "write", "b", 0, 14, True),
           Op(3, "read", "a", 11, 12, True), Op(4, "read", "b", 13, 14, True)]
    assert check_linearizable(ops)


# ---------------------------------------------------------------------------
# duplicate-resolution reorderings (LARK's optional-write semantics)
# ---------------------------------------------------------------------------

def test_failed_write_may_win_duplicate_resolution_later():
    """A client-visible write failure whose replica later wins dup-res:
    the value surfaces to a subsequent read, and that is linearizable —
    the optional op linearizes inside its interval."""
    ops = [Op(1, "write", "a", 0, 1, True),
           Op(2, "write", "b", 2, INF, False),     # failed at the client
           Op(3, "read", "b", 5, 6, True)]
    assert check_linearizable(ops)


def test_resurfaced_failed_write_cannot_unapply():
    """Dup-res reordering limit: once the failed write's version was
    observed, a later read cannot roll back to the pre-failure value."""
    ops = [Op(1, "write", "a", 0, 1, True),
           Op(2, "write", "b", 2, INF, False),
           Op(3, "read", "b", 5, 6, True),
           Op(4, "read", "a", 7, 8, True)]
    assert not check_linearizable(ops)


def test_indeterminate_write_cannot_take_effect_before_invocation():
    ops = [Op(1, "write", "a", 0, 1, True),
           Op(2, "read", "b", 3, 4, True),
           Op(3, "write", "b", 5, INF, False)]     # invoked after the read
    assert not check_linearizable(ops)


def test_two_failed_writes_resolve_in_either_order():
    """Two dup-res candidates with open intervals: reads may observe
    them in whichever order resolution picked — both orders have a
    witness, including one value being dropped entirely."""
    base = [Op(1, "write", "a", 0, 1, True),
            Op(2, "write", "b", 2, INF, False),
            Op(3, "write", "c", 3, INF, False)]
    assert check_linearizable(base + [Op(4, "read", "b", 10, 11, True),
                                      Op(5, "read", "c", 12, 13, True)])
    assert check_linearizable(base + [Op(4, "read", "c", 10, 11, True),
                                      Op(5, "read", "b", 12, 13, True)])
    assert check_linearizable(base + [Op(4, "read", "c", 10, 11, True)])
    # but an observed resolution still pins real-time order afterwards
    assert not check_linearizable(base +
                                  [Op(4, "read", "c", 10, 11, True),
                                   Op(5, "read", "b", 12, 13, True),
                                   Op(6, "read", "c", 14, 15, True)])


# ---------------------------------------------------------------------------
# property: sequential histories are always linearizable
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=0, max_value=10 ** 6))
def test_random_sequential_histories_linearizable(seed):
    """Any non-overlapping history whose reads return the latest
    completed write (with optional writes either applied at their slot
    or dropped) has the trivial witness order — the checker must accept
    every one of them, regardless of op-list order."""
    rng = random.Random(seed)
    t, last, ops, vcount = 0.0, None, [], 0
    for i in range(rng.randint(1, 12)):
        t += 1.0
        roll = rng.random()
        if roll < 0.45:
            vcount += 1
            ops.append(Op(i, "write", f"v{vcount}", t, t + 0.5, True))
            last = f"v{vcount}"
        elif roll < 0.6:
            vcount += 1
            applied = rng.random() < 0.5       # dup-res keeps or drops it
            ops.append(Op(i, "write", f"v{vcount}", t,
                          t + 0.5 if rng.random() < 0.5 else INF, False))
            if applied:
                last = f"v{vcount}"
        else:
            ops.append(Op(i, "read", last, t, t + 0.5, True))
    rng.shuffle(ops)                            # checker is order-free
    assert check_linearizable(ops)


# ---------------------------------------------------------------------------
# randomized protocol schedules
# ---------------------------------------------------------------------------

def run_random_schedule(seed: int, n=5, rf=2, events=16, return_sim=False):
    rng = random.Random(seed)
    sim = LarkSim(num_nodes=n, rf=rf, num_partitions=1, seed=seed)
    sim.recluster()
    sim.settle()
    sim.run_migrations()
    vcount = 0
    ops = 0
    for i in range(events):
        roll = rng.random()
        if roll < 0.2 and len(sim.alive) > n // 2 + 1:
            victim = rng.choice(sorted(sim.alive))
            sim.fail_node(victim)
            sim.settle()
            if rng.random() < 0.7:
                sim.run_migrations()
        elif roll < 0.4 and len(sim.alive) < n:
            back = rng.choice(sorted(set(range(n)) - sim.alive))
            sim.recover_node(back)
            sim.settle()
            if rng.random() < 0.7:
                sim.run_migrations()
        elif roll < 0.7 and ops < 15:
            vcount += 1
            ops += 1
            sim.client_write(0, "k0", f"v{vcount}")
            if rng.random() < 0.8:
                sim.settle()
        elif ops < 15:
            ops += 1
            sim.client_read(0, "k0")
            if rng.random() < 0.8:
                sim.settle()
    sim.settle()
    if return_sim:
        return sim.finalize_history(), sim
    return sim.finalize_history()


@pytest.mark.parametrize("seed", range(25))
def test_random_schedules_linearizable(seed):
    hist = run_random_schedule(seed)
    results = check_history(hist)
    assert all(results.values()), (seed, results)


@pytest.mark.parametrize("seed", range(10))
def test_random_schedules_rf3(seed):
    hist = run_random_schedule(seed + 1000, n=6, rf=3, events=24)
    results = check_history(hist)
    assert all(results.values()), (seed, results)


def test_replicated_versions_form_chain():
    """Theorem B.9 audit: versions that reached 'replicated' status anywhere
    are a function of their logical clock (no two distinct replicated values
    share an LC => the version lineage is a single LC-ordered chain)."""
    for seed in range(10):
        _, sim = run_random_schedule(seed, return_sim=True)
        by_lc = {}
        for node in sim.nodes.values():
            entries = [(k, lc, v) for (k, lc, v, status) in node.accept_log
                       if status == "replicated"]
            for pid in node.last_replicated:
                entries += [(k, ver.lc, ver.value)
                            for k, ver in node.last_replicated[pid].items()]
            for k, lc, v in entries:
                key = (k, tuple(lc))
                assert by_lc.setdefault(key, v) == v, \
                    f"seed {seed}: two replicated values at LC {key}"
