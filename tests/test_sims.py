"""§5.1 availability sim + §5.2 microsim reproduce the paper's numbers."""
import numpy as np
import pytest

from repro.core.analytical import (improvement_factor, lark_unavailability,
                                   node_unavailability, raft_unavailability)
from repro.core.availability import simulate_availability
from repro.core.microsim import MicroConfig, run_table, table_configs


def test_analytical_factors():
    assert improvement_factor(1) == 3
    assert improvement_factor(2) == 10
    assert improvement_factor(3) == 35
    u = node_unavailability(1e-3, 10)
    assert abs(u - 0.00990) < 1e-4
    assert raft_unavailability(u, 1) / lark_unavailability(u, 1) \
        == pytest.approx(3.0)


@pytest.mark.slow
def test_availability_rf2_matches_analytic():
    r = simulate_availability(rf=2, p=1e-3, partitions=512,
                              max_ticks=300_000, seed=3)
    u = node_unavailability(1e-3)
    assert r.u_lark == pytest.approx(lark_unavailability(u, 1), rel=0.5)
    assert r.improvement == pytest.approx(3.0, rel=0.25)


def test_availability_small_fast():
    r = simulate_availability(n=31, partitions=128, rf=2, p=5e-3,
                              min_ticks=20_000, max_ticks=60_000, seed=1)
    assert 0 < r.u_lark < r.u_maj
    assert 1.5 < r.improvement < 6.0


def test_microsim_row1_matches_table3():
    cfg = MicroConfig(rs=1e3, ps=0.1e9, bw=5e6, u=0.5, lf=0.5)
    r = run_table([cfg], ticks=400_000)[0]
    assert r["lark"]["throughput"] == pytest.approx(2500, rel=0.02)
    assert r["base"]["throughput"] == pytest.approx(2364, rel=0.03)
    assert r["lark_backfill_s"] == pytest.approx(66, abs=5)
    assert r["base_down_s"] == pytest.approx(20, abs=1)


def test_microsim_downtime_model():
    # BASE downtime = min(ps/bw, 300): rows 2, 5 of table 3
    cfgs = [MicroConfig(rs=1e3, ps=0.1e9, bw=50e6, u=0.5, lf=0.5),
            MicroConfig(rs=1e3, ps=10e9, bw=5e6, u=0.5, lf=0.5)]
    rs = run_table(cfgs, ticks=320_000)
    assert rs[0]["base_down_s"] == pytest.approx(2, abs=0.5)
    assert rs[1]["base_down_s"] == pytest.approx(300, abs=1)


def test_microsim_throughput_formula():
    # lambda = u*bw / (0.8 rs + 0.2*2*lf*rs): exact cells from the paper
    assert MicroConfig(rs=1e3, ps=1e9, bw=5e6, u=0.5, lf=0.5).arrival_rate \
        == pytest.approx(2500)
    assert MicroConfig(rs=1e3, ps=1e9, bw=5e6, u=0.8, lf=1.0).arrival_rate \
        == pytest.approx(3333.3, rel=1e-3)
    assert MicroConfig(rs=10e3, ps=1e9, bw=50e6, u=0.8, lf=1.0).arrival_rate \
        == pytest.approx(3333.3, rel=1e-3)
