"""Per-kernel interpret-mode validation vs pure-jnp oracles: shape/dtype
sweeps + hypothesis properties (assignment deliverable c)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.mlstm_chunk import mlstm_chunkwise as mlstm_kernel
from repro.kernels.pac_eval import pac_eval as pac_kernel
from repro.kernels.rglru_scan import rglru_scan as rglru_kernel

RNG = np.random.default_rng(0)


def randn(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,S,D", [(1, 1, 128, 32), (2, 3, 256, 64),
                                     (1, 2, 512, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
def test_flash_attention_sweep(B, H, S, D, dtype, causal, window):
    q, k, v = randn((B, H, S, D), dtype), randn((B, H, S, D), dtype), \
        randn((B, H, S, D), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          interpret=True, block_q=64, block_k=64)
    t = lambda x: x.transpose(0, 2, 1, 3)
    want = t(ref.attention_ref(t(q), t(k), t(v), causal=causal, window=window))
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


def test_flash_attention_uneven_blocks():
    q, k, v = (randn((1, 1, 192, 32)) for _ in range(3))
    out = flash_attention(q, k, v, interpret=True, block_q=64, block_k=64)
    t = lambda x: x.transpose(0, 2, 1, 3)
    want = t(ref.attention_ref(t(q), t(k), t(v)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


# ---------------------------------------------------------------------------
# mLSTM chunkwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,S,D,chunk", [(1, 1, 128, 16, 32),
                                           (2, 2, 256, 32, 64),
                                           (1, 2, 256, 64, 128)])
def test_mlstm_kernel_vs_ref(B, H, S, D, chunk):
    q, k, v = (randn((B, H, S, D)) for _ in range(3))
    lf = jnp.asarray(jax.nn.log_sigmoid(randn((B, H, S)) * 2 + 2))
    li = randn((B, H, S))
    hk, _ = mlstm_kernel(q, k, v, lf, li, chunk=chunk, interpret=True)
    hr, _ = ref.mlstm_chunkwise(q, k, v, lf, li, chunk=chunk)
    np.testing.assert_allclose(np.asarray(hk), np.asarray(hr),
                               atol=5e-5, rtol=5e-4)


def test_mlstm_chunkwise_matches_stepwise():
    B, H, S, D = 1, 2, 96, 16
    q, k, v = (randn((B, H, S, D)) for _ in range(3))
    lf = jnp.asarray(jax.nn.log_sigmoid(randn((B, H, S)) + 1))
    li = randn((B, H, S))
    hr, (C, n, m) = ref.mlstm_chunkwise(q, k, v, lf, li, chunk=32)
    state = (jnp.zeros((B, H, D, D)), jnp.zeros((B, H, D)),
             jnp.full((B, H), -1e30))
    hs = []
    for t in range(S):
        h1, state = ref.mlstm_step(q[:, :, t], k[:, :, t], v[:, :, t],
                                   lf[:, :, t], li[:, :, t], state)
        hs.append(h1)
    np.testing.assert_allclose(np.asarray(hr), np.asarray(jnp.stack(hs, 2)),
                               atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(C), np.asarray(state[0]),
                               atol=2e-4, rtol=2e-3)


def test_mlstm_chunk_size_invariance():
    B, H, S, D = 1, 1, 128, 16
    q, k, v = (randn((B, H, S, D)) for _ in range(3))
    lf = jnp.asarray(jax.nn.log_sigmoid(randn((B, H, S))))
    li = randn((B, H, S))
    h32, _ = ref.mlstm_chunkwise(q, k, v, lf, li, chunk=32)
    h128, _ = ref.mlstm_chunkwise(q, k, v, lf, li, chunk=128)
    np.testing.assert_allclose(np.asarray(h32), np.asarray(h128),
                               atol=5e-5, rtol=5e-4)


# ---------------------------------------------------------------------------
# RG-LRU scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,W,bs,bw", [(1, 256, 128, 64, 128),
                                         (2, 512, 256, 128, 128)])
def test_rglru_kernel_vs_ref(B, S, W, bs, bw):
    x = randn((B, S, W))
    la = -jnp.asarray(RNG.uniform(0.01, 2.0, (B, S, W)), jnp.float32)
    hk = rglru_kernel(x, la, block_s=bs, block_w=bw, interpret=True)
    hr = ref.rglru_scan_ref(x, la)
    np.testing.assert_allclose(np.asarray(hk), np.asarray(hr),
                               atol=1e-5, rtol=1e-4)


def test_rglru_scan_matches_stepwise():
    B, S, W = 2, 64, 8
    x = randn((B, S, W))
    la = -jnp.asarray(RNG.uniform(0.01, 1.0, (B, S, W)), jnp.float32)
    hr = ref.rglru_scan_ref(x, la)
    h = jnp.zeros((B, W))
    for t in range(S):
        h = ref.rglru_step(x[:, t], la[:, t], h)
    np.testing.assert_allclose(np.asarray(hr[:, -1]), np.asarray(h),
                               atol=1e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# PAC kernel
# ---------------------------------------------------------------------------

@given(st.integers(0, 2**31 - 1), st.integers(2, 4))
@settings(max_examples=20, deadline=None)
def test_pac_kernel_vs_ref_random(seed, rf):
    rng = np.random.default_rng(seed)
    P, n, npad = 256, 155, 256
    up = jnp.asarray(rng.random((P, npad)) < rng.uniform(0.5, 0.99))
    full = jnp.asarray(rng.random((P, npad)) < 0.4)
    voters = 2 * (rf - 1) + 1
    outs_k = pac_kernel(up, full, rf=rf, voters=voters, n_real=n,
                        block_p=128, interpret=True)
    outs_r = ref.pac_eval_rank_ref(up, full, rf=rf, voters=voters, n_real=n)
    for a, b in zip(outs_k, outs_r):
        assert bool(jnp.all(a == b))
