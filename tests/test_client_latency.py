"""Client-latency engine invariants (core/client_latency.py).

The load-bearing guarantees, each pinned here:
  * the zipf workload tables are mean-pinned (weights sum to exactly 1;
    key_zipf=0 is the exactly-uniform 1/P table) — skew moves traffic
    between partitions, never adds offered load;
  * the zero-knob limit (dupres_ticks=0, uniform keys, 100% reads) lands
    at exactly 0 added latency on every reported column;
  * all three backends, packed and unpacked carries, produce
    bit-identical raw accumulators (the devices 1-vs-8 half lives in
    tests/test_sharded.py);
  * percentiles/means are monotone in dupres_ticks and in zipf skew
    (LARK's charged fraction falls as traffic concentrates on hot keys);
  * p999 >= p99 >= p50 on every emitted row, adversarially sampled.
"""
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.client_latency import (_percentile, key_bucket_shares,
                                       partition_request_weights,
                                       simulate_client_latency)
from repro.core.downtime_batched import DowntimeParams, \
    simulate_downtime_batched

# small but failure-rich: rf=2 at a high p on a tiny cluster produces
# leader changes, rebuilds, and majority-down spells within a few
# thousand ticks
_KW = dict(n=6, rf=2, p=2e-4, partitions=64, trials=4, max_ticks=12_000,
           min_ticks=12_000, chunk_steps=64, seed=3,
           dupres_ticks=4, requests_per_tick=8.0, key_zipf=1.0,
           read_frac=0.8, slo_ticks=2)


def _raw(r):
    return r.downtime.latency_raw


# ---------------------------------------------------------------------------
# workload tables
# ---------------------------------------------------------------------------

def test_uniform_weights_exact():
    w = partition_request_weights(0, 128, key_zipf=0.0)
    assert np.all(w == 1.0 / 128)


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=1, max_value=64),
       st.integers(min_value=0, max_value=12),
       st.integers(min_value=0, max_value=1000))
def test_weights_mean_pinned(partitions, zipf_quarters, seed):
    """Sum(w) == 1 to float64 round-off for any skew/seed/P — i.e. the
    mean weight is pinned at 1/P and skew never changes offered load."""
    w = partition_request_weights(seed, partitions,
                                  key_zipf=zipf_quarters / 4.0,
                                  keys_per_partition=64)
    assert w.shape == (partitions,)
    assert np.all(w >= 0)
    assert abs(w.sum() - 1.0) < 1e-12


def test_bucket_shares_partition_unity():
    for z in (0.0, 0.7, 1.0, 2.5):
        f, g = key_bucket_shares(z)
        assert abs(f.sum() - 1.0) < 1e-12
        assert abs(g.sum() - 1.0) < 1e-12
        assert np.all(f > 0) and np.all(g > 0)
    # uniform popularity: traffic share == key-count share exactly
    f0, g0 = key_bucket_shares(0.0)
    assert np.allclose(f0, g0, rtol=0, atol=1e-15)


def test_params_validation():
    with pytest.raises(ValueError):
        DowntimeParams(key_zipf=-0.1)
    with pytest.raises(ValueError):
        DowntimeParams(key_zipf=100.0)
    with pytest.raises(ValueError):
        DowntimeParams(read_frac=1.5)
    with pytest.raises(ValueError):
        DowntimeParams(read_frac=-0.01)
    with pytest.raises(ValueError):
        DowntimeParams(requests_per_tick=-1.0)
    with pytest.raises(ValueError):
        DowntimeParams(requests_per_tick=math.inf)
    with pytest.raises(ValueError):
        DowntimeParams(slo_ticks=-1)


# ---------------------------------------------------------------------------
# zero-knob limit and plain-downtime inertness
# ---------------------------------------------------------------------------

def test_zero_knob_limit_exactly_zero():
    r = simulate_client_latency(backend="jax", **{
        **_KW, "dupres_ticks": 0, "key_zipf": 0.0, "read_frac": 1.0})
    for col in ("lat_lark", "lat_quorum", "lat_hermes",
                "p50_lark", "p99_lark", "p999_lark",
                "p50_quorum", "p99_quorum", "p999_quorum",
                "p50_hermes", "p99_hermes", "p999_hermes",
                "slo_lark", "slo_quorum", "slo_hermes"):
        assert getattr(r, col) == 0.0, col
    assert np.all(_raw(r)["dup"] == 0.0)
    assert np.all(_raw(r)["qhist"] == 0.0)


def test_plain_downtime_has_no_latency_state():
    """Without a latency plan the engine must not grow its carry or
    allocate accumulators — the workload knobs are inert defaults."""
    r = simulate_downtime_batched(
        n=6, rf=2, p=2e-4, partitions=32, trials=2, max_ticks=4_000,
        min_ticks=4_000, chunk_steps=64, seed=0, backend="numpy")
    assert r.latency_raw is None


# ---------------------------------------------------------------------------
# backend matrix / packed-carry bit-identity
# ---------------------------------------------------------------------------

def test_backend_matrix_bit_identical():
    base = simulate_client_latency(backend="numpy", **_KW)
    for backend in ("jax", "pallas"):
        other = simulate_client_latency(backend=backend, **_KW)
        for k in ("dup", "qhist", "qslo", "qsum", "now"):
            assert np.array_equal(_raw(base)[k], _raw(other)[k]), \
                (backend, k)
        assert base.lat_lark == other.lat_lark
        assert base.lat_quorum == other.lat_quorum
        assert base.p999_quorum == other.p999_quorum


def test_packed_carry_bit_identical():
    base = simulate_client_latency(backend="jax", **_KW)
    packed = simulate_client_latency(backend="jax", packed=True, **_KW)
    for k in ("dup", "qhist", "qslo", "qsum", "now"):
        assert np.array_equal(_raw(base)[k], _raw(packed)[k]), k
    assert base.lat_lark == packed.lat_lark
    assert base.slo_quorum == packed.slo_quorum


def test_shard_map_path_identical_on_one_device():
    base = simulate_client_latency(backend="jax", **_KW)
    sharded = simulate_client_latency(backend="jax", use_shard_map=True,
                                      devices=1, **_KW)
    for k in ("dup", "qhist", "qslo", "qsum", "now"):
        assert np.array_equal(_raw(base)[k], _raw(sharded)[k]), k


# ---------------------------------------------------------------------------
# monotonicity
# ---------------------------------------------------------------------------

def test_latency_monotone_in_dupres_ticks():
    """LARK percentiles/mean/SLO are non-decreasing in the dup-res cost:
    the charged request fraction is dupres-independent (the dirty-key
    process never sees the price), so the mean scales linearly and the
    percentile values ride the charge upward."""
    prev = None
    for d in (0, 1, 2, 4, 8):
        r = simulate_client_latency(backend="jax", **{**_KW,
                                                      "dupres_ticks": d})
        cur = (r.lat_lark, r.p50_lark, r.p99_lark, r.p999_lark,
               r.lat_hermes, r.slo_lark)
        if prev is not None:
            assert all(c >= p for c, p in zip(cur, prev)), (d, prev, cur)
        prev = cur


def test_lark_latency_monotone_in_zipf_skew():
    """More key skew -> strictly less LARK dup-res traffic: concentrating
    requests on a few hot keys means a failover dirties the same K keys
    but far fewer distinct keys ever get touched (hot ones are cleaned
    within a tick or two, the cold tail is never read), so the charged
    fraction — and with it mean/percentiles/SLO — falls."""
    prev = None
    for z in (0.0, 0.5, 1.0, 2.0):
        r = simulate_client_latency(backend="jax", **{**_KW,
                                                      "key_zipf": z})
        cur = (r.lat_lark, r.p99_lark, r.p999_lark, r.slo_lark)
        if prev is not None:
            assert all(c <= p for c, p in zip(cur, prev)), (z, prev, cur)
        prev = cur


# ---------------------------------------------------------------------------
# percentile ordering — unit-level adversarial + emitted rows
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(st.integers(min_value=0, max_value=10**9),
       st.integers(min_value=1, max_value=8))
def test_percentile_walk_ordering(seed, n_masses):
    """p999 >= p99 >= p50 for arbitrary point-mass distributions,
    including zero-total, all-zero-latency, and charged > total edge
    noise."""
    rng = np.random.default_rng(seed)
    masses = [(float(rng.integers(0, 100)), float(rng.uniform(0, 50)))
              for _ in range(n_masses)]
    total = float(rng.uniform(0, 2) * sum(m[1] for m in masses) + 1e-9)
    p50 = _percentile(masses, total, 0.5)
    p99 = _percentile(masses, total, 0.99)
    p999 = _percentile(masses, total, 0.999)
    assert 0.0 <= p50 <= p99 <= p999


def test_emitted_rows_percentiles_ordered():
    """Every row the sweep emits must satisfy the ordering for all three
    protocols — run a grid of workload corners and check each."""
    corners = [
        {},                                          # defaults of _KW
        {"read_frac": 0.0},                          # all writes
        {"read_frac": 1.0},                          # all reads
        {"key_zipf": 0.0},
        {"key_zipf": 3.0, "dupres_ticks": 16},
        {"requests_per_tick": 0.5, "slo_ticks": 0},
    ]
    for c in corners:
        r = simulate_client_latency(backend="numpy", **{**_KW, **c})
        for proto in ("lark", "quorum", "hermes"):
            p50 = getattr(r, f"p50_{proto}")
            p99 = getattr(r, f"p99_{proto}")
            p999 = getattr(r, f"p999_{proto}")
            assert 0.0 <= p50 <= p99 <= p999, (c, proto, p50, p99, p999)
        assert 0.0 <= r.slo_lark <= 1.0
        assert 0.0 <= r.slo_quorum <= 1.0
        assert r.slo_hermes <= r.slo_lark


# ---------------------------------------------------------------------------
# cross-metric consistency
# ---------------------------------------------------------------------------

def test_hermes_is_write_fraction_of_lark():
    r = simulate_client_latency(backend="jax", **_KW)
    assert r.lat_hermes == (1.0 - _KW["read_frac"]) * r.lat_lark
    assert r.slo_hermes == (1.0 - _KW["read_frac"]) * r.slo_lark


def test_charged_fraction_bounded_by_offered_load():
    """The analytic first-touch count can never exceed offered requests
    (1 - e^-x <= x per bucket-interval), and quorum can never charge more
    SLO violations than writes arrive."""
    r = simulate_client_latency(backend="jax", **_KW)
    raw = _raw(r)
    req = _KW["requests_per_tick"] * raw["now"].sum()
    assert raw["dup"].sum() <= req * 1.0000001
    assert raw["qslo"].sum() <= req * (1 - _KW["read_frac"]) * 1.0000001
