"""Client-latency engine invariants (core/client_latency.py).

The load-bearing guarantees, each pinned here:
  * the zipf workload tables are mean-pinned (weights sum to exactly 1;
    key_zipf=0 is the exactly-uniform 1/P table) — skew moves traffic
    between partitions, never adds offered load;
  * the zero-knob limit (dupres_ticks=0, uniform keys, 100% reads) lands
    at exactly 0 added latency on every reported column;
  * all three backends, packed and unpacked carries, produce
    bit-identical raw accumulators (the devices 1-vs-8 half lives in
    tests/test_sharded.py);
  * percentiles/means are monotone in dupres_ticks and in zipf skew
    (LARK's charged fraction falls as traffic concentrates on hot keys);
  * p999 >= p99 >= p50 on every emitted row, adversarially sampled.
"""
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.client_latency import (_percentile, key_bucket_shares,
                                       partition_request_weights,
                                       partition_write_fractions,
                                       simulate_client_latency)
from repro.core.downtime_batched import DowntimeParams, \
    simulate_downtime_batched

# small but failure-rich: rf=2 at a high p on a tiny cluster produces
# leader changes, rebuilds, and majority-down spells within a few
# thousand ticks
_KW = dict(n=6, rf=2, p=2e-4, partitions=64, trials=4, max_ticks=12_000,
           min_ticks=12_000, chunk_steps=64, seed=3,
           dupres_ticks=4, requests_per_tick=8.0, key_zipf=1.0,
           read_frac=0.8, slo_ticks=2)


def _raw(r):
    return r.downtime.latency_raw


# ---------------------------------------------------------------------------
# workload tables
# ---------------------------------------------------------------------------

def test_uniform_weights_exact():
    w = partition_request_weights(0, 128, key_zipf=0.0)
    assert np.all(w == 1.0 / 128)


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=1, max_value=64),
       st.integers(min_value=0, max_value=12),
       st.integers(min_value=0, max_value=1000))
def test_weights_mean_pinned(partitions, zipf_quarters, seed):
    """Sum(w) == 1 to float64 round-off for any skew/seed/P — i.e. the
    mean weight is pinned at 1/P and skew never changes offered load."""
    w = partition_request_weights(seed, partitions,
                                  key_zipf=zipf_quarters / 4.0,
                                  keys_per_partition=64)
    assert w.shape == (partitions,)
    assert np.all(w >= 0)
    assert abs(w.sum() - 1.0) < 1e-12


def test_bucket_shares_partition_unity():
    for z in (0.0, 0.7, 1.0, 2.5):
        f, g = key_bucket_shares(z)
        assert abs(f.sum() - 1.0) < 1e-12
        assert abs(g.sum() - 1.0) < 1e-12
        assert np.all(f > 0) and np.all(g > 0)
    # uniform popularity: traffic share == key-count share exactly
    f0, g0 = key_bucket_shares(0.0)
    assert np.allclose(f0, g0, rtol=0, atol=1e-15)


def test_params_validation():
    with pytest.raises(ValueError):
        DowntimeParams(key_zipf=-0.1)
    with pytest.raises(ValueError):
        DowntimeParams(key_zipf=100.0)
    with pytest.raises(ValueError):
        DowntimeParams(read_frac=1.5)
    with pytest.raises(ValueError):
        DowntimeParams(read_frac=-0.01)
    with pytest.raises(ValueError):
        DowntimeParams(requests_per_tick=-1.0)
    with pytest.raises(ValueError):
        DowntimeParams(requests_per_tick=math.inf)
    with pytest.raises(ValueError):
        DowntimeParams(slo_ticks=-1)


# ---------------------------------------------------------------------------
# zero-knob limit and plain-downtime inertness
# ---------------------------------------------------------------------------

def test_zero_knob_limit_exactly_zero():
    r = simulate_client_latency(backend="jax", **{
        **_KW, "dupres_ticks": 0, "key_zipf": 0.0, "read_frac": 1.0})
    for col in ("lat_lark", "lat_quorum", "lat_hermes",
                "p50_lark", "p99_lark", "p999_lark",
                "p50_quorum", "p99_quorum", "p999_quorum",
                "p50_hermes", "p99_hermes", "p999_hermes",
                "slo_lark", "slo_quorum", "slo_hermes"):
        assert getattr(r, col) == 0.0, col
    assert np.all(_raw(r)["dup"] == 0.0)
    assert np.all(_raw(r)["qhist"] == 0.0)


def test_plain_downtime_has_no_latency_state():
    """Without a latency plan the engine must not grow its carry or
    allocate accumulators — the workload knobs are inert defaults."""
    r = simulate_downtime_batched(
        n=6, rf=2, p=2e-4, partitions=32, trials=2, max_ticks=4_000,
        min_ticks=4_000, chunk_steps=64, seed=0, backend="numpy")
    assert r.latency_raw is None


# ---------------------------------------------------------------------------
# backend matrix / packed-carry bit-identity
# ---------------------------------------------------------------------------

def test_backend_matrix_bit_identical():
    base = simulate_client_latency(backend="numpy", **_KW)
    for backend in ("jax", "pallas"):
        other = simulate_client_latency(backend=backend, **_KW)
        for k in ("dup", "qhist", "qslo", "qsum", "now"):
            assert np.array_equal(_raw(base)[k], _raw(other)[k]), \
                (backend, k)
        assert base.lat_lark == other.lat_lark
        assert base.lat_quorum == other.lat_quorum
        assert base.p999_quorum == other.p999_quorum


def test_packed_carry_bit_identical():
    base = simulate_client_latency(backend="jax", **_KW)
    packed = simulate_client_latency(backend="jax", packed=True, **_KW)
    for k in ("dup", "qhist", "qslo", "qsum", "now"):
        assert np.array_equal(_raw(base)[k], _raw(packed)[k]), k
    assert base.lat_lark == packed.lat_lark
    assert base.slo_quorum == packed.slo_quorum


def test_shard_map_path_identical_on_one_device():
    base = simulate_client_latency(backend="jax", **_KW)
    sharded = simulate_client_latency(backend="jax", use_shard_map=True,
                                      devices=1, **_KW)
    for k in ("dup", "qhist", "qslo", "qsum", "now"):
        assert np.array_equal(_raw(base)[k], _raw(sharded)[k]), k


# ---------------------------------------------------------------------------
# monotonicity
# ---------------------------------------------------------------------------

def test_latency_monotone_in_dupres_ticks():
    """LARK percentiles/mean/SLO are non-decreasing in the dup-res cost:
    the charged request fraction is dupres-independent (the dirty-key
    process never sees the price), so the mean scales linearly and the
    percentile values ride the charge upward."""
    prev = None
    for d in (0, 1, 2, 4, 8):
        r = simulate_client_latency(backend="jax", **{**_KW,
                                                      "dupres_ticks": d})
        cur = (r.lat_lark, r.p50_lark, r.p99_lark, r.p999_lark,
               r.lat_hermes, r.slo_lark)
        if prev is not None:
            assert all(c >= p for c, p in zip(cur, prev)), (d, prev, cur)
        prev = cur


def test_lark_latency_monotone_in_zipf_skew():
    """More key skew -> strictly less LARK dup-res traffic: concentrating
    requests on a few hot keys means a failover dirties the same K keys
    but far fewer distinct keys ever get touched (hot ones are cleaned
    within a tick or two, the cold tail is never read), so the charged
    fraction — and with it mean/percentiles/SLO — falls."""
    prev = None
    for z in (0.0, 0.5, 1.0, 2.0):
        r = simulate_client_latency(backend="jax", **{**_KW,
                                                      "key_zipf": z})
        cur = (r.lat_lark, r.p99_lark, r.p999_lark, r.slo_lark)
        if prev is not None:
            assert all(c <= p for c, p in zip(cur, prev)), (z, prev, cur)
        prev = cur


# ---------------------------------------------------------------------------
# percentile ordering — unit-level adversarial + emitted rows
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(st.integers(min_value=0, max_value=10**9),
       st.integers(min_value=1, max_value=8))
def test_percentile_walk_ordering(seed, n_masses):
    """p999 >= p99 >= p50 for arbitrary point-mass distributions,
    including zero-total, all-zero-latency, and charged > total edge
    noise."""
    rng = np.random.default_rng(seed)
    masses = [(float(rng.integers(0, 100)), float(rng.uniform(0, 50)))
              for _ in range(n_masses)]
    total = float(rng.uniform(0, 2) * sum(m[1] for m in masses) + 1e-9)
    p50 = _percentile(masses, total, 0.5)
    p99 = _percentile(masses, total, 0.99)
    p999 = _percentile(masses, total, 0.999)
    assert 0.0 <= p50 <= p99 <= p999


def test_emitted_rows_percentiles_ordered():
    """Every row the sweep emits must satisfy the ordering for all three
    protocols — run a grid of workload corners and check each."""
    corners = [
        {},                                          # defaults of _KW
        {"read_frac": 0.0},                          # all writes
        {"read_frac": 1.0},                          # all reads
        {"key_zipf": 0.0},
        {"key_zipf": 3.0, "dupres_ticks": 16},
        {"requests_per_tick": 0.5, "slo_ticks": 0},
    ]
    for c in corners:
        r = simulate_client_latency(backend="numpy", **{**_KW, **c})
        for proto in ("lark", "quorum", "hermes"):
            p50 = getattr(r, f"p50_{proto}")
            p99 = getattr(r, f"p99_{proto}")
            p999 = getattr(r, f"p999_{proto}")
            assert 0.0 <= p50 <= p99 <= p999, (c, proto, p50, p99, p999)
        assert 0.0 <= r.slo_lark <= 1.0
        assert 0.0 <= r.slo_quorum <= 1.0
        assert r.slo_hermes <= r.slo_lark


# ---------------------------------------------------------------------------
# cross-metric consistency
# ---------------------------------------------------------------------------

def test_hermes_is_write_fraction_of_lark():
    r = simulate_client_latency(backend="jax", **_KW)
    assert r.lat_hermes == (1.0 - _KW["read_frac"]) * r.lat_lark
    assert r.slo_hermes == (1.0 - _KW["read_frac"]) * r.slo_lark


def test_charged_fraction_bounded_by_offered_load():
    """The analytic first-touch count can never exceed offered requests
    (1 - e^-x <= x per bucket-interval), and quorum can never charge more
    SLO violations than writes arrive."""
    r = simulate_client_latency(backend="jax", **_KW)
    raw = _raw(r)
    req = _KW["requests_per_tick"] * raw["now"].sum()
    assert raw["dup"].sum() <= req * 1.0000001
    assert raw["qslo"].sum() <= req * (1 - _KW["read_frac"]) * 1.0000001


# ---------------------------------------------------------------------------
# _percentile boundary semantics (adversarial pins)
# ---------------------------------------------------------------------------

def test_percentile_exact_cdf_landing_takes_value():
    """A cumulative mass landing *exactly* on q * total selects that
    value (the walk uses >=), never the next one up."""
    masses = [(1.0, 32.0), (2.0, 32.0)]
    assert _percentile(masses, 64.0, 0.5) == 1.0
    assert _percentile(masses, 64.0, 0.75) == 2.0
    # the zero-latency mass exactly covering q returns 0.0, not the
    # smallest positive value
    assert _percentile([(5.0, 1.0)], 100.0, 0.99) == 0.0
    assert _percentile([(5.0, 1.0)], 100.0, 0.995) == 5.0


def test_percentile_zero_mass_and_zero_total():
    assert _percentile([], 100.0, 0.999) == 0.0
    assert _percentile([(3.0, 0.0)], 100.0, 0.5) == 0.0
    assert _percentile([(3.0, 1.0)], 0.0, 0.5) == 0.0
    assert _percentile([(3.0, 1.0)], -1.0, 0.999) == 0.0


def test_percentile_single_bucket_and_overcharged_total():
    # one point mass covering everything: every quantile lands on it
    for q in (0.5, 0.99, 0.999):
        assert _percentile([(7.0, 10.0)], 10.0, q) == 7.0
    # charged mass exceeding the total (float drift): the zero mass is
    # clamped at 0 and the walk still terminates on the charged values
    assert _percentile([(3.0, 200.0)], 100.0, 0.5) == 3.0
    assert _percentile([(3.0, 200.0)], 100.0, 0.999) == 3.0
    # unsorted input is sorted by value before walking
    assert _percentile([(9.0, 1.0), (2.0, 99.0)], 100.0, 0.5) == 2.0


# ---------------------------------------------------------------------------
# strict-> SLO threshold (slo_ticks=0 is a live edge, not a sentinel)
# ---------------------------------------------------------------------------

def test_slo_strict_threshold_semantics():
    """A request violates iff its added latency strictly exceeds
    slo_ticks: LARK's charge is exactly dupres_ticks per dup-res, so
    slo_ticks == dupres_ticks charges nothing and slo_ticks just below
    charges every dup-res."""
    at = simulate_client_latency(backend="numpy",
                                 **{**_KW, "slo_ticks": 4})
    below = simulate_client_latency(backend="numpy",
                                    **{**_KW, "slo_ticks": 3})
    assert at.slo_lark == 0.0 and at.slo_hermes == 0.0
    assert below.slo_lark > 0.0
    # slo_ticks=0 is live under strict >: any positive added latency
    # violates, so the LARK fraction equals any other threshold below
    # dupres_ticks and quorum counts at least as many waits
    live = simulate_client_latency(backend="numpy",
                                   **{**_KW, "slo_ticks": 0})
    assert live.slo_lark == below.slo_lark > 0.0
    assert live.slo_quorum >= below.slo_quorum


# ---------------------------------------------------------------------------
# SLO curves
# ---------------------------------------------------------------------------

def test_slo_curve_monotone_and_endpoint_exact():
    r = simulate_client_latency(
        backend="numpy", **{**_KW, "slo_ticks": 3, "slo_curve_bins": 8})
    edges = np.asarray(r.slo_curve_edges)
    assert edges.tolist() == [(1 << j) - 1 for j in range(8)]
    for curve in (r.slo_curve_lark, r.slo_curve_quorum,
                  r.slo_curve_hermes):
        c = np.asarray(curve)
        assert c.shape == (8,)
        assert np.all((c >= 0.0) & (c <= 1.0))
        assert np.all(np.diff(c) <= 0.0)        # non-increasing
    # slo_ticks=3 sits on curve edge 2^2 - 1: the curve reproduces the
    # scalar columns there bitwise
    j = int(np.flatnonzero(edges == 3)[0])
    assert r.slo_curve_lark[j] == r.slo_lark
    assert r.slo_curve_quorum[j] == r.slo_quorum
    assert r.slo_curve_hermes[j] == r.slo_hermes


def test_slo_curve_off_threshold_still_monotone():
    # slo_ticks=2 is not a 2^j - 1 edge: no substitution happens, the
    # curve must still be monotone and bounded
    r = simulate_client_latency(backend="numpy",
                                **{**_KW, "slo_curve_bins": 6})
    for curve in (r.slo_curve_lark, r.slo_curve_quorum,
                  r.slo_curve_hermes):
        c = np.asarray(curve)
        assert np.all(np.diff(c) <= 0.0) and np.all((c >= 0) & (c <= 1))


def test_slo_curve_off_by_default():
    r = simulate_client_latency(backend="numpy", **_KW)
    assert r.slo_curve_bins == 0
    assert r.slo_curve_edges is None and r.slo_curve_lark is None


# ---------------------------------------------------------------------------
# per-partition write mix
# ---------------------------------------------------------------------------

def test_write_fractions_uniform_at_zero_skew():
    w = partition_write_fractions(7, 64, read_frac=0.8, write_skew=0.0)
    assert np.all(w == 1.0 - 0.8)


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=2, max_value=128),
       st.integers(min_value=1, max_value=32),
       st.integers(min_value=0, max_value=4),
       st.integers(min_value=0, max_value=1000))
def test_write_fractions_mean_pinned(partitions, skew_quarters,
                                     rf_quarters, seed):
    """mean(w) == 1 - read_frac to float64 round-off for any skew, even
    deep into saturation (the waterfilling pin), and every fraction stays
    a valid probability."""
    rf = rf_quarters / 4.0
    w = partition_write_fractions(seed, partitions, read_frac=rf,
                                  write_skew=skew_quarters / 4.0)
    assert w.shape == (partitions,)
    assert np.all((w >= 0.0) & (w <= 1.0))
    assert abs(w.mean() - (1.0 - rf)) < 1e-12


def test_write_skew_leaves_lark_path_untouched():
    """The write mix reweights lamw (the quorum/hermes write-arrival
    table) only — LARK's dup-res charges ride the full request stream
    and must stay bit-identical under skew."""
    base = simulate_client_latency(backend="numpy", **_KW)
    sk = simulate_client_latency(backend="numpy", write_skew=1.0, **_KW)
    assert np.array_equal(_raw(base)["dup"], _raw(sk)["dup"])
    assert sk.lat_lark == base.lat_lark
    assert sk.p999_lark == base.p999_lark
    assert "dupw" in _raw(sk) and "dupw" not in _raw(base)
    assert sk.write_skew == 1.0 and base.write_skew == 0.0


# ---------------------------------------------------------------------------
# fixed-model bandwidth contention
# ---------------------------------------------------------------------------

def test_fixed_bandwidth_contention_changes_waits():
    """A tight shared-bandwidth budget stretches fixed-model rebuilds,
    so quorum waits grow; the knob must actually bite."""
    base = simulate_client_latency(backend="numpy", **_KW)
    tight = simulate_client_latency(backend="numpy",
                                    node_bandwidth_gibps=0.25, **_KW)
    assert tight.rebuild_model == "fixed"
    assert math.isfinite(tight.node_bandwidth_gibps)
    assert tight.lat_quorum > base.lat_quorum


def test_new_knobs_backend_matrix_bit_identical():
    """All three knobs live at once: numpy, jax, jax-packed, and pallas
    must agree bit-for-bit on every raw accumulator and on the curve."""
    kw = {**_KW, "write_skew": 1.0, "node_bandwidth_gibps": 0.5,
          "slo_curve_bins": 8}
    base = simulate_client_latency(backend="numpy", **kw)
    for backend, extra in (("jax", {}), ("jax", {"packed": True}),
                           ("pallas", {})):
        other = simulate_client_latency(backend=backend, **extra, **kw)
        for k in ("dup", "dupw", "qhist", "qslo", "qsum", "now"):
            assert np.array_equal(_raw(base)[k], _raw(other)[k]), \
                (backend, extra, k)
        assert base.lat_lark == other.lat_lark
        assert base.lat_quorum == other.lat_quorum
        assert base.lat_hermes == other.lat_hermes
        assert np.array_equal(base.slo_curve_quorum,
                              other.slo_curve_quorum)
