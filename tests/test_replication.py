"""Algorithms 1-4 behaviour: write/read paths, dup-res, rollback, regimes."""
import pytest

from repro.core.messages import ReplicaWrite
from repro.core.node import REPLICATED, UNREPLICATED
from repro.core.simulator import LarkSim


def fresh(n=5, rf=2, parts=2, **kw):
    sim = LarkSim(num_nodes=n, rf=rf, num_partitions=parts, **kw)
    sim.recluster()
    sim.settle()
    sim.run_migrations()
    return sim


def test_write_then_read():
    sim = fresh()
    w = sim.client_write(0, "k", "v1")
    sim.settle()
    assert sim.result(w).ok
    r = sim.client_read(0, "k")
    sim.settle()
    assert sim.result(r).ok and sim.result(r).value == "v1"


def test_write_replicates_to_rf_nodes():
    sim = fresh()
    sim.client_write(0, "k", "v")
    sim.settle()
    holders = [n for n in sim.nodes.values()
               if n.records[0].get("k") is not None]
    assert len(holders) == 2
    assert all(h.records[0]["k"].status == REPLICATED for h in holders)


def test_rf3_mark_replicated_advice():
    sim = fresh(n=5, rf=3)
    sim.client_write(0, "k", "v")
    sim.settle()
    holders = [n for n in sim.nodes.values()
               if n.records[0].get("k") is not None]
    assert len(holders) == 3
    # after MarkReplicated advice settles, every copy is replicated
    assert all(h.records[0]["k"].status == REPLICATED for h in holders)


def test_non_leader_write_rejected():
    sim = fresh()
    leader = sim.leader_of(0)
    other = next(n for n in sim.alive if n != leader)
    op, msgs = sim.nodes[other].client_write(0, "k", "v")
    assert sim.nodes[other].results[op].ok is False
    assert sim.nodes[other].results[op].reason == "not-leader"


def test_failed_replica_write_rolls_back_leader():
    sim = LarkSim(num_nodes=3, rf=2, num_partitions=1)
    sim.set_succession(0, [0, 1, 2])
    sim.recluster()
    sim.settle()
    sim.run_migrations()
    w0 = sim.client_write(0, "k", "v0")
    sim.settle()
    assert sim.result(w0).ok
    # second write: replica rejects (simulate by making node1 believe a new
    # regime that excludes node0) -> leader must roll back to v0
    w = sim.client_write(0, "k", "v1")
    held = sim.net.pop_matching(lambda m: isinstance(m, ReplicaWrite))
    sim.nodes[1].p[0].nodes_in_cluster = frozenset({1, 2})  # kick leader out
    for m in held:
        sim.deliver(m)
    sim.settle()
    assert sim.result(w).ok is False
    rec = sim.nodes[0].records[0]["k"]
    assert rec.value == "v0" and rec.status == REPLICATED


def test_leader_failover_with_dupres():
    sim = fresh(n=5, rf=2, parts=1)
    w1 = sim.client_write(0, "k", "v1")
    sim.settle()
    leader = sim.leader_of(0)
    sim.fail_node(leader)
    sim.settle()          # no migrations: new leader must dup-res per key
    w2 = sim.client_write(0, "k", "v2")
    sim.settle()
    assert sim.result(w2).ok
    r = sim.client_read(0, "k")
    sim.settle()
    assert sim.result(r).value == "v2"


def test_regime_increases_monotonically():
    sim = fresh(n=4, rf=2, parts=1)
    ers = [sim.er_counter]
    for victim in (0, 1):
        sim.fail_node(victim)
        sim.settle()
        ers.append(sim.er_counter)
        sim.recover_node(victim)
        sim.settle()
        ers.append(sim.er_counter)
    assert ers == sorted(ers) and len(set(ers)) == len(ers)


def test_read_after_unavailable_partition_fails():
    sim = fresh(n=4, rf=2, parts=1)
    succ = sim.successions[0]
    # kill a majority: PAC cannot hold
    for v in succ[:3]:
        sim.fail_node(v, recluster=False)
    sim.recluster()
    sim.settle()
    assert sim.leader_of(0) is None
    op = sim.client_read(0, "k")
    assert op == -1 or sim.result(op).ok is False


def test_lc_ordering_regime_then_vn():
    sim = fresh(n=3, rf=2, parts=1)
    sim.client_write(0, "k", "a")
    sim.settle()
    sim.client_write(0, "k", "b")
    sim.settle()
    leader = sim.leader_of(0)
    lc1 = sim.nodes[leader].records[0]["k"].lc
    sim.fail_node(next(n for n in sim.alive if n != leader))
    sim.settle()
    sim.run_migrations()
    sim.client_write(0, "k", "c")
    sim.settle()
    lc2 = sim.nodes[sim.leader_of(0)].records[0]["k"].lc
    assert lc2 > lc1 and lc2[0] > lc1[0]
