"""Batched §6 downtime/commit-pause engine: cross-backend and shard_map
bit-identity, the dup-res and rebuild degeneracy properties (pause
fractions must collapse *exactly* to the instantaneous engine's
integrals when the knobs are zeroed), protocol-semantics monotonicity,
duration-histogram accounting, and the reconfiguring quorum-log
baseline (roster reconfiguration + data-sized catch-ups)."""
import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core.availability_batched import simulate_availability_batched
from repro.core.downtime_batched import (SIZE_DISTS, _hist_add,
                                         _partition_rebuild_ticks,
                                         partition_sizes_gib,
                                         simulate_downtime_batched)
from repro.core.scenarios import get_scenario, scenario_names
from repro.kernels.ops import (PAC_BACKENDS, downtime_eval_batch,
                               rebuild_node_counts)

RNG = np.random.default_rng(17)

_KW = dict(n=13, partitions=32, rf=2, p=5e-3, trials=3, max_ticks=4_000,
           min_ticks=10**9, chunk_steps=64, max_steps=600, seed=11,
           trajectory=True)


# ---------------------------------------------------------------------------
# per-step op: backend agreement
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rf,n_real,n_pad", [(2, 23, 23), (3, 19, 40)])
def test_downtime_eval_backends_agree(rf, n_real, n_pad):
    R = 128
    up = RNG.random((R, n_pad)) < 0.8
    full = RNG.random((R, n_pad)) < 0.4
    up[0] = False                       # dead partition: leader sentinel
    outs = {}
    for b in PAC_BACKENDS:
        u = up if b == "numpy" else jnp.asarray(up)
        f = full if b == "numpy" else jnp.asarray(full)
        outs[b] = tuple(np.asarray(o) for o in downtime_eval_batch(
            u, f, rf=rf, n_real=n_real, backend=b))
    for b in PAC_BACKENDS[1:]:
        for i, (a, c) in enumerate(zip(outs[PAC_BACKENDS[0]], outs[b])):
            assert np.array_equal(a, c), (b, i)
    lark, qmaj, leader, lfull, nrep, creps = outs["numpy"]
    assert leader[0] == n_real and not lfull[0]          # no node up
    assert ((2 * nrep > rf) == qmaj).all()
    assert (nrep <= rf).all()
    assert not creps[:, n_real:].any()                   # padding untouched
    # the leader is the first up node: rank-space argmax over the up mask
    up_m = up & (np.arange(n_pad) < n_real)
    exp = np.where(up_m.any(axis=1), up_m.argmax(axis=1), n_real)
    assert np.array_equal(leader, exp)


@pytest.mark.parametrize("rf,n_real,n_pad", [(2, 23, 23), (3, 19, 40)])
def test_roster_aware_eval_backends_agree(rf, n_real, n_pad):
    """The reconfiguring baseline's per-step op: qmaj/nrep over a carried
    roster of succession ranks, bit-identical across all three backends,
    and exactly the static result for the identity roster."""
    R = 128
    up = RNG.random((R, n_pad)) < 0.8
    full = RNG.random((R, n_pad)) < 0.4
    roster = np.stack([RNG.permutation(n_real)[:rf] for _ in range(R)]) \
        .astype(np.int32)
    outs = {}
    for b in PAC_BACKENDS:
        u = up if b == "numpy" else jnp.asarray(up)
        f = full if b == "numpy" else jnp.asarray(full)
        ro = roster if b == "numpy" else jnp.asarray(roster)
        outs[b] = tuple(np.asarray(o) for o in downtime_eval_batch(
            u, f, rf=rf, n_real=n_real, backend=b, roster=ro))
    for b in PAC_BACKENDS[1:]:
        for i, (a, c) in enumerate(zip(outs[PAC_BACKENDS[0]], outs[b])):
            assert np.array_equal(a, c), (b, i)
    lark, qmaj, leader, lfull, nrep, creps = outs["numpy"]
    # nrep/qmaj really count the roster members, nothing else
    up_m = up & (np.arange(n_pad) < n_real)
    exp_nrep = np.take_along_axis(up_m, roster, axis=1).sum(axis=1)
    assert np.array_equal(nrep, exp_nrep)
    assert np.array_equal(qmaj, 2 * exp_nrep > rf)
    # roster-independent outputs match the non-roster op exactly
    base = tuple(np.asarray(o) for o in downtime_eval_batch(
        up, full, rf=rf, n_real=n_real, backend="numpy"))
    for i in (0, 2, 3, 5):                    # lark, leader, lfull, creps
        assert np.array_equal(outs["numpy"][i], base[i]), i
    # identity roster == static first-rf replica set, bit for bit
    ident = np.broadcast_to(np.arange(rf, dtype=np.int32), (R, rf)).copy()
    with_id = tuple(np.asarray(o) for o in downtime_eval_batch(
        up, full, rf=rf, n_real=n_real, backend="numpy", roster=ident))
    for a, c in zip(base, with_id):
        assert np.array_equal(a, c)


# ---------------------------------------------------------------------------
# bit-identical seeded trajectories across backends and sharding
# ---------------------------------------------------------------------------

# (cross-backend / packed-layout / shard-map identity now lives in the
# consolidated matrix: tests/test_conformance.py)


def test_sharding_and_knob_validation():
    with pytest.raises(ValueError, match="numpy"):
        simulate_downtime_batched(backend="numpy", devices=2, **_KW)
    with pytest.raises(ValueError, match="divide"):
        simulate_downtime_batched(backend="jax", devices=2, **_KW)
    with pytest.raises(ValueError, match="dupres_ticks"):
        simulate_downtime_batched(backend="numpy", dupres_ticks=-1, **_KW)
    with pytest.raises(ValueError, match="hist_bins"):
        simulate_downtime_batched(backend="numpy", hist_bins=1, **_KW)


# ---------------------------------------------------------------------------
# degeneracy properties: zeroed knobs collapse to instantaneous integrals
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=2, max_value=3),
       st.sampled_from([3e-3, 8e-3]),
       st.integers(min_value=0, max_value=3))
def test_zero_knobs_degenerate_to_instantaneous_integrals(rf, p, seed):
    """Satellite acceptance: dupres_ticks=0 makes LARK downtime equal the
    instantaneous-PAC unavailability integral, and rebuild_steps=0 makes
    the quorum-log baseline plain majority-of-replica-set availability
    (voters=rf in the instantaneous engine) — exactly, not statistically,
    because both engines replay the same counter-RNG trajectory."""
    kw = dict(n=11, partitions=16, p=p, trials=2, max_ticks=1_500,
              min_ticks=10**9, chunk_steps=32, max_steps=200, seed=seed,
              backend="numpy", trajectory=True)
    dt = simulate_downtime_batched(rf=rf, dupres_ticks=0, rebuild_steps=0,
                                   **kw)
    av = simulate_availability_batched(rf=rf, voters=rf, **kw)
    assert dt.pause_lark == av.u_lark
    assert dt.pause_quorum == av.u_maj
    assert np.array_equal(dt.pause_lark_trials, av.u_lark_trials)
    assert np.array_equal(dt.pause_quorum_trials, av.u_maj_trials)
    assert np.array_equal(dt.trajectory["times"], av.trajectory["times"])
    assert np.array_equal(dt.trajectory["paused_lark"],
                          av.trajectory["unavail_lark"])
    assert np.array_equal(dt.trajectory["paused_quorum"],
                          av.trajectory["unavail_maj"])
    # event-count accounting regression: both engines count per-partition
    # down-transitions, so at zero knobs the counts are *equal*, not just
    # close — the availability engine's old net-per-trial delta counting
    # cancelled a partition recovering in the same step another failed
    assert dt.lark_events == av.lark_events
    assert dt.quorum_events == av.maj_events


def test_dupres_and_rebuild_only_add_pause():
    base = simulate_downtime_batched(dupres_ticks=0, rebuild_steps=0, **_KW)
    dup = simulate_downtime_batched(dupres_ticks=5, rebuild_steps=0, **_KW)
    reb = simulate_downtime_batched(dupres_ticks=0, rebuild_steps=50, **_KW)
    reb2 = simulate_downtime_batched(dupres_ticks=0, rebuild_steps=200,
                                     **_KW)
    assert dup.pause_lark > base.pause_lark
    assert dup.pause_quorum == base.pause_quorum     # knob is LARK-only
    assert reb.pause_quorum > base.pause_quorum
    assert reb2.pause_quorum > reb.pause_quorum      # monotone in rebuild
    assert reb.pause_lark == base.pause_lark         # knob is quorum-only


def test_lark_outpauses_nothing_quorum_pays_rebuilds():
    """The §6 headline: equal storage budget, same trajectory — LARK's
    commit-pause fraction stays well below the rebuilding quorum-log's."""
    r = simulate_downtime_batched(backend="numpy", **_KW)
    assert r.pause_lark < r.pause_quorum
    assert r.availability_ratio > 2.0


# ---------------------------------------------------------------------------
# duration-histogram accounting
# ---------------------------------------------------------------------------

def test_histogram_accounting():
    r = simulate_downtime_batched(backend="numpy", **_KW)
    assert r.hist_edges.tolist() == [1 << k for k in range(16)]
    # every completed run was opened by a counted pause-start event
    # (runs still open at the horizon are censored, so <=)
    assert 0 < int(r.hist_lark.sum()) <= r.lark_events
    assert 0 < int(r.hist_quorum.sum()) <= r.quorum_events
    # dup-res penalties land in the bucket holding dupres_ticks
    zero = simulate_downtime_batched(dupres_ticks=0, **_KW)
    pen8 = simulate_downtime_batched(dupres_ticks=8, **_KW)
    extra = pen8.hist_lark - zero.hist_lark
    assert extra[3] > 0                        # bucket [8, 16)
    assert (extra[:3] == 0).all() and (extra[4:] == 0).all()


def test_quorum_rebuild_durations_reflect_the_countdown():
    """With a failure-free rebuild window, every quorum pause caused by a
    single replica loss lasts >= rebuild_steps ticks — the histogram mass
    sits at or above the rebuild bucket."""
    r = simulate_downtime_batched(
        n=12, partitions=32, rf=3, p=1e-3, trials=2, max_ticks=20_000,
        min_ticks=10**9, seed=7, backend="numpy", dupres_ticks=0,
        rebuild_steps=64)
    assert int(r.hist_quorum.sum()) > 0
    assert r.hist_quorum[:6].sum() == 0        # no run shorter than 64


# ---------------------------------------------------------------------------
# scenario registry compatibility
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", scenario_names())
def test_every_scenario_runs_under_the_downtime_engine(name):
    sc = get_scenario(name)
    rf, p = sc.grid[0]
    r = simulate_downtime_batched(
        rf=rf, p=p, n=13, partitions=32, trials=2, max_ticks=2_000,
        min_ticks=10**9, chunk_steps=32, max_steps=120, seed=5,
        backend="numpy", **sc.kwargs(n=13, rf=rf, p=p))
    assert 0.0 <= r.pause_lark and 0.0 <= r.pause_quorum <= 1.0


@pytest.mark.slow
def test_batched_downtime_matches_reduced_scale_expectations():
    """Reduced-grid row at the sweep's scale: LARK pause ~ u_lark level,
    quorum pays heavily for rebuilds."""
    r = simulate_downtime_batched(
        n=63, partitions=512, rf=2, p=3e-3, trials=4, max_ticks=120_000,
        min_ticks=20_000, seed=0, backend="jax")
    assert 0 < r.pause_lark < 0.1
    assert r.pause_quorum > r.pause_lark
    assert r.availability_ratio > 5


# ---------------------------------------------------------------------------
# histogram binning edges (zero-length runs are not pauses)
# ---------------------------------------------------------------------------

def test_hist_add_binning_edges():
    """Power-of-two bucket edges, including the regression cases: d=0
    (a run opened and closed at the same tick by coincident events) must
    be dropped, not mis-binned into [1, 2); 2^k lands in bucket k; the
    top bucket is open-ended."""
    bins = 16
    cases = [(0, None), (1, 0), (2, 1), (3, 1)] + \
        [(1 << k, k) for k in range(2, bins)] + \
        [((1 << (bins - 1)) + 1, bins - 1), ((1 << bins), bins - 1)]
    d = np.array([[c[0] for c in cases]], dtype=np.int64)
    mask = np.ones_like(d, dtype=bool)
    hist = _hist_add(np, bins, np.zeros((1, bins), dtype=np.int32), mask, d)
    expected = np.zeros(bins, dtype=np.int32)
    for _, bucket in cases:
        if bucket is not None:
            expected[bucket] += 1
    assert np.array_equal(hist[0], expected)
    assert int(hist.sum()) == sum(1 for _, b in cases if b is not None)


def test_hist_add_masks_zero_duration_even_when_selected():
    # the d=0 drop applies inside the mask, so a coincident open/close
    # that *is* flagged as a completed run still contributes nothing
    bins = 4
    d = np.array([[0, 0, 5]])
    mask = np.array([[True, True, True]])
    hist = _hist_add(np, bins, np.zeros((1, bins), dtype=np.int32), mask, d)
    assert hist[0].tolist() == [0, 0, 1, 0]


# ---------------------------------------------------------------------------
# the reconfiguring quorum-log baseline
# ---------------------------------------------------------------------------

def test_partition_sizes_are_deterministic_and_bounded():
    s1 = partition_sizes_gib(11, 256)
    s2 = partition_sizes_gib(11, 256)
    assert np.array_equal(s1, s2)
    assert ((s1 >= 1.0) & (s1 < 2.0)).all()
    assert len(np.unique(s1)) > 200              # actually varied
    assert not np.array_equal(s1, partition_sizes_gib(12, 256))
    t = _partition_rebuild_ticks(11, 256, 100)
    assert t.dtype == np.int32
    assert ((t >= 100) & (t < 200)).all()
    assert (_partition_rebuild_ticks(11, 256, 0) == 0).all()


def test_fixed_model_is_the_default_and_unchanged():
    """`--rebuild-model fixed` is the degenerate case: the default-args
    run and an explicit fixed run are the same computation, bit for bit
    (the committed BENCH_downtime.json pins this against the pre-roster
    baseline at sweep scale)."""
    base = simulate_downtime_batched(**_KW)
    fixed = simulate_downtime_batched(rebuild_model="fixed", **_KW)
    for k in base.trajectory:
        assert np.array_equal(base.trajectory[k], fixed.trajectory[k]), k
    assert base.pause_lark == fixed.pause_lark
    assert base.pause_quorum == fixed.pause_quorum
    assert np.array_equal(base.hist_lark, fixed.hist_lark)
    assert np.array_equal(base.hist_quorum, fixed.hist_quorum)
    assert base.rebuild_model == "fixed"
    assert base.rebuild_ticks_per_gib == 0       # knob inert under fixed


@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=2, max_value=3),
       st.sampled_from([3e-3, 8e-3]),
       st.integers(min_value=0, max_value=3))
def test_reconfig_never_pauses_less_than_fixed_on_iid_grid(rf, p, seed):
    """With matched knobs (rebuild_ticks_per_gib == rebuild_steps and
    partition sizes >= 1 GiB, so every catch-up >= the fixed constant),
    the reconfiguring baseline pauses at least as much as the static one
    on the same i.i.d. short-downtime trajectory: the roster tracks live
    nodes, which exposes more up-time to failure (more losses, each with
    a >= catch-up) — and LARK, which has no replica set to rebuild, is
    bit-identical.  This is a regime property, not a theorem: under long
    node downtimes (flapping / hetero-mttf scenarios) reconfiguration
    avoids the static set's long majority-down stalls and pauses *less*
    (see docs/ARCHITECTURE.md); the whole rf x p x seed space asserted
    here was verified exhaustively, so hypothesis sampling cannot flake."""
    kw = dict(n=11, partitions=16, p=p, trials=2, max_ticks=1_500,
              min_ticks=10**9, chunk_steps=32, max_steps=200, seed=seed,
              backend="numpy", trajectory=True, dupres_ticks=1)
    fx = simulate_downtime_batched(rf=rf, rebuild_steps=100, **kw)
    rc = simulate_downtime_batched(rf=rf, rebuild_model="reconfig",
                                   rebuild_ticks_per_gib=100, **kw)
    assert np.array_equal(fx.trajectory["times"], rc.trajectory["times"])
    assert rc.pause_quorum >= fx.pause_quorum
    assert (rc.pause_quorum_trials >= fx.pause_quorum_trials).all()
    assert rc.pause_lark == fx.pause_lark
    assert rc.lark_events == fx.lark_events
    assert np.array_equal(rc.hist_lark, fx.hist_lark)
    assert np.array_equal(rc.trajectory["paused_lark"],
                          fx.trajectory["paused_lark"])


def test_reconfig_zero_ticks_degenerates_to_roster_availability():
    """rebuild_ticks_per_gib=0 is free instant reconfiguration: every
    loss immediately recruits an up node, so with plenty of spare nodes
    the roster majority never fails and the baseline's pause collapses to
    zero — strictly below the static fixed-set baseline, which keeps
    paying for its dead members.  (The catch-up cost is the *only* thing
    that makes the reconfiguring baseline pause; that is the point of the
    §6 data-sized-rebuild comparison.)"""
    kw = dict(n=13, partitions=32, rf=2, p=2e-2, trials=3, max_ticks=4_000,
              min_ticks=10**9, chunk_steps=64, max_steps=600, seed=3,
              backend="numpy", dupres_ticks=0)
    fx = simulate_downtime_batched(rebuild_steps=0, **kw)
    rc = simulate_downtime_batched(rebuild_model="reconfig",
                                   rebuild_ticks_per_gib=0, **kw)
    assert fx.pause_quorum > 0                   # the static set does pause
    assert rc.pause_quorum < fx.pause_quorum
    assert rc.pause_quorum == 0.0                # n=13 always has 2 up nodes


def test_reconfig_validation():
    with pytest.raises(ValueError, match="rebuild_model"):
        simulate_downtime_batched(rebuild_model="paxos", **_KW)
    with pytest.raises(ValueError, match="rebuild_ticks_per_gib"):
        simulate_downtime_batched(rebuild_model="reconfig",
                                  rebuild_ticks_per_gib=-1, **_KW)


# ---------------------------------------------------------------------------
# hot-partition size distributions
# ---------------------------------------------------------------------------

def test_size_dists_share_the_uniform_mean_budget():
    """Every distribution pins the uniform model's 1.5 GiB mean: skew
    redistributes bytes between partitions, never changes the total
    dataset the §6 equal-storage comparison is about."""
    for dist, skew in [("zipf", 0.0), ("zipf", 1.0), ("zipf", 2.5),
                       ("lognormal", 0.0), ("lognormal", 1.5)]:
        s = partition_sizes_gib(11, 1024, dist=dist, skew=skew)
        assert s.shape == (1024,)
        assert (s >= 0).all()
        assert abs(s.mean() - 1.5) < 1e-12, (dist, skew)
        assert np.array_equal(s, partition_sizes_gib(11, 1024, dist=dist,
                                                     skew=skew))


def test_uniform_dist_is_the_original_table_bit_for_bit():
    base = partition_sizes_gib(11, 256)
    assert np.array_equal(base, partition_sizes_gib(11, 256,
                                                    dist="uniform"))
    # the skew knob is inert under uniform
    assert np.array_equal(base, partition_sizes_gib(11, 256,
                                                    dist="uniform",
                                                    skew=7.0))


def test_zero_skew_collapses_to_constant_uniform_mean():
    """Satellite: --size-skew 0 zipf matches the uniform moments — the
    mean is *exactly* the uniform 1.5 GiB (every partition constant)."""
    for dist in ("zipf", "lognormal"):
        s = partition_sizes_gib(11, 256, dist=dist, skew=0.0)
        assert (s == 1.5).all(), dist


def test_skew_produces_hot_partitions_and_sub_gib_tails():
    uni = partition_sizes_gib(11, 2048, dist="uniform")
    zipf = partition_sizes_gib(11, 2048, dist="zipf", skew=1.0)
    logn = partition_sizes_gib(11, 2048, dist="lognormal", skew=1.0)
    for s in (zipf, logn):
        assert s.max() > uni.max()        # a few hot partitions...
        assert (s < 1.0).mean() > 0.25    # ...push the bulk below 1 GiB
    # more skew = hotter head, at the same total
    zipf2 = partition_sizes_gib(11, 2048, dist="zipf", skew=2.0)
    assert zipf2.max() > zipf.max()


def test_size_dist_validation():
    with pytest.raises(ValueError, match="dist"):
        partition_sizes_gib(11, 64, dist="pareto")
    with pytest.raises(ValueError, match="skew"):
        partition_sizes_gib(11, 64, dist="zipf", skew=-0.5)
    # skews past the float64 overflow point are rejected, not NaN-poisoned
    with pytest.raises(ValueError, match="skew"):
        partition_sizes_gib(11, 64, dist="zipf", skew=100.0)
    with pytest.raises(ValueError, match="size_skew"):
        simulate_downtime_batched(rebuild_model="reconfig",
                                  size_dist="zipf", size_skew=100.0, **_KW)
    with pytest.raises(ValueError, match="size_dist"):
        simulate_downtime_batched(rebuild_model="reconfig",
                                  size_dist="pareto", **_KW)
    # the size knobs describe reconfig catch-ups only; bandwidth sharing
    # now applies to the fixed model too
    with pytest.raises(ValueError, match="reconfig"):
        simulate_downtime_batched(size_dist="zipf", **_KW)
    simulate_downtime_batched(node_bandwidth_gibps=1.0, **_KW)
    with pytest.raises(ValueError, match="quantum"):
        simulate_downtime_batched(node_bandwidth_gibps=0.003, **_KW)
    with pytest.raises(ValueError, match="node_bandwidth_gibps"):
        simulate_downtime_batched(rebuild_model="reconfig",
                                  node_bandwidth_gibps=0.0, **_KW)
    # below the 1/256 fixed-point quantum every catch-up would round to
    # zero progress and silently never finish — rejected, not degenerate
    with pytest.raises(ValueError, match="quantum"):
        simulate_downtime_batched(rebuild_model="reconfig",
                                  node_bandwidth_gibps=0.003, **_KW)
    simulate_downtime_batched(rebuild_model="reconfig",
                              node_bandwidth_gibps=1.0 / 256, **_KW)
    assert "uniform" in SIZE_DISTS and "zipf" in SIZE_DISTS


def test_sub_gib_countdowns_clamp_to_one_tick():
    """Satellite: skewed draws go below 1 GiB; a catch-up of any size
    still costs at least one tick (ticks_per_gib > 0), while a free
    rebuild (ticks_per_gib == 0) stays free."""
    sizes = partition_sizes_gib(11, 2048, dist="zipf", skew=2.0)
    assert (sizes * 100 < 1.0).any()      # sub-tick raw countdowns exist
    t = _partition_rebuild_ticks(11, 2048, 100, dist="zipf", skew=2.0)
    assert t.dtype == np.int32
    assert (t >= 1).all()
    assert (t == 1).any()                 # the clamp actually fired
    assert (_partition_rebuild_ticks(11, 2048, 0, dist="zipf",
                                     skew=2.0) == 0).all()
    # the cap keeps huge hot-partition countdowns in int32 territory
    capped = _partition_rebuild_ticks(11, 2048, 10**6, dist="zipf",
                                      skew=2.5, cap=4_001)
    assert capped.max() == 4_001


def test_one_tick_rebuilds_bin_into_the_first_bucket():
    """Edge-binning satellite: with every partition sub-GiB enough that
    its clamped countdown is exactly 1 tick, completed single-loss
    quorum pauses are real 1-tick pauses — counted in bucket [1, 2),
    never dropped with the zero-length runs."""
    kw = dict(n=12, partitions=32, rf=3, p=1e-3, trials=2, max_ticks=20_000,
              min_ticks=10**9, seed=7, backend="numpy", dupres_ticks=0,
              rebuild_model="reconfig", rebuild_ticks_per_gib=1,
              size_dist="zipf", size_skew=3.0)
    t = _partition_rebuild_ticks(7, 32, 1, dist="zipf", skew=3.0)
    assert (t == 1).mean() > 0.7          # the bulk clamps to one tick
    assert (1 * partition_sizes_gib(7, 32, dist="zipf",
                                    skew=3.0) < 1).any()
    r = simulate_downtime_batched(**kw)
    assert int(r.hist_quorum.sum()) > 0
    assert r.hist_quorum[0] > 0           # mass in [1, 2)


# ---------------------------------------------------------------------------
# the per-node reduction op (bandwidth-contended rebuilds)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,P,n_real", [(3, 32, 13), (4, 100, 31)])
def test_rebuild_node_counts_backends_agree(B, P, n_real):
    rec = RNG.integers(0, n_real + 1, (B, P)).astype(np.int32)  # incl sentinel
    act = RNG.random((B, P)) < 0.4
    outs = {}
    for b in PAC_BACKENDS:
        r = rec if b == "numpy" else jnp.asarray(rec)
        a = act if b == "numpy" else jnp.asarray(act)
        outs[b] = np.asarray(rebuild_node_counts(r, a, n_real=n_real,
                                                 backend=b))
    exp = np.zeros((B, n_real), np.int32)
    for i in range(B):
        for p_ in range(P):
            if act[i, p_] and rec[i, p_] < n_real:
                exp[i, rec[i, p_]] += 1
    for b in PAC_BACKENDS:
        assert np.array_equal(outs[b], exp), b
    # inactive partitions and sentinel/out-of-range ids contribute nothing
    assert outs["numpy"].sum() == int((act & (rec < n_real)).sum())


def test_rebuild_node_counts_never_crosses_trials():
    """The reduction that makes bandwidth contention work is per-trial:
    permuting whole trial rows permutes the output rows and nothing
    else — the property that lets trials-axis sharding commute with it."""
    rec = RNG.integers(0, 9, (4, 64)).astype(np.int32)
    act = RNG.random((4, 64)) < 0.5
    base = rebuild_node_counts(rec, act, n_real=8, backend="numpy")
    perm = np.array([2, 0, 3, 1])
    swapped = rebuild_node_counts(rec[perm], act[perm], n_real=8,
                                  backend="numpy")
    assert np.array_equal(swapped, base[perm])


# ---------------------------------------------------------------------------
# bandwidth-contended rebuilds (engine level)
# ---------------------------------------------------------------------------

_SKEW_KW = dict(_KW, rebuild_model="reconfig", rebuild_ticks_per_gib=64,
                size_dist="zipf", size_skew=1.2, node_bandwidth_gibps=1.0)


def test_infinite_bandwidth_is_the_unshared_model_bit_for_bit():
    """Satellite degenerate limit: --size-dist uniform
    --node-bandwidth-gibps inf is the PR-4 reconfig baseline (the
    committed BENCH_downtime_reconfig.json pins the same thing at sweep
    scale, across devices 1 vs 8)."""
    import math
    kw = dict(_KW, rebuild_model="reconfig", rebuild_ticks_per_gib=64)
    base = simulate_downtime_batched(**kw)
    expl = simulate_downtime_batched(size_dist="uniform",
                                     node_bandwidth_gibps=math.inf, **kw)
    for k in base.trajectory:
        assert np.array_equal(base.trajectory[k], expl.trajectory[k]), k
    assert base.pause_lark == expl.pause_lark
    assert base.pause_quorum == expl.pause_quorum
    assert np.array_equal(base.hist_quorum, expl.hist_quorum)
    assert np.array_equal(base.hist_lark, expl.hist_lark)
    assert base.quorum_events == expl.quorum_events
    assert base.node_bandwidth_gibps == math.inf
    assert base.size_skew == 0.0          # knob inert under uniform


def test_zero_skew_zipf_matches_uniform_within_ci():
    """Satellite: zipf at skew 0 (constant 1.5 GiB) must land within the
    runs' combined CI of the uniform baseline — same mean catch-up cost,
    same trajectories, only the per-partition spread differs."""
    kw = dict(_KW, rebuild_model="reconfig", rebuild_ticks_per_gib=64)
    uni = simulate_downtime_batched(**kw)
    z0 = simulate_downtime_batched(size_dist="zipf", size_skew=0.0, **kw)
    assert np.array_equal(uni.trajectory["times"], z0.trajectory["times"])
    assert z0.pause_lark == uni.pause_lark         # LARK has no sizes
    assert abs(z0.pause_quorum - uni.pause_quorum) <= \
        uni.ci_quorum + z0.ci_quorum


def test_bandwidth_contention_only_adds_quorum_pause():
    """Sharing a recruit's ingest bandwidth can only stretch catch-ups:
    quorum pause is monotone down in bandwidth, per trial, and LARK —
    which rebuilds nothing — is bit-identical at every setting."""
    kw = dict(_KW, rebuild_model="reconfig", rebuild_ticks_per_gib=64,
              size_dist="zipf", size_skew=1.2)
    inf_r = simulate_downtime_batched(**kw)
    bw2 = simulate_downtime_batched(node_bandwidth_gibps=2.0, **kw)
    bw1 = simulate_downtime_batched(node_bandwidth_gibps=1.0, **kw)
    assert bw1.pause_quorum >= bw2.pause_quorum >= inf_r.pause_quorum
    assert bw1.pause_quorum > inf_r.pause_quorum   # contention really bites
    assert (bw1.pause_quorum_trials >= inf_r.pause_quorum_trials).all()
    for r in (bw1, bw2):
        assert r.pause_lark == inf_r.pause_lark
        assert np.array_equal(r.hist_lark, inf_r.hist_lark)
        assert np.array_equal(r.trajectory["paused_lark"],
                              inf_r.trajectory["paused_lark"])
        assert np.array_equal(r.trajectory["times"],
                              inf_r.trajectory["times"])


def test_skew_plus_contention_heavier_pause_tail():
    """The acceptance criterion at test scale: zipf sizes + unit
    bandwidth shift quorum pause-duration mass into strictly higher
    power-of-two buckets than the uniform/inf baseline on the same
    trajectory (hot partitions rebuild for longer, and concurrent
    catch-ups serialize)."""
    kw = dict(_KW, rebuild_model="reconfig", rebuild_ticks_per_gib=64)
    base = simulate_downtime_batched(**kw)
    skew = simulate_downtime_batched(size_dist="zipf", size_skew=1.2,
                                     node_bandwidth_gibps=1.0, **kw)
    top = lambda h: max(i for i, v in enumerate(h) if v)
    assert top(skew.hist_quorum) > top(base.hist_quorum)
    cut = top(base.hist_quorum)
    assert skew.hist_quorum[cut:].sum() > base.hist_quorum[cut:].sum()


def test_shard_map_path_identical_with_bandwidth_contention():
    plain = simulate_downtime_batched(backend="jax", **_SKEW_KW)
    mesh1 = simulate_downtime_batched(backend="jax", devices=1,
                                      use_shard_map=True, **_SKEW_KW)
    for k in plain.trajectory:
        assert np.array_equal(plain.trajectory[k], mesh1.trajectory[k]), k
    assert plain.pause_quorum == mesh1.pause_quorum
    assert np.array_equal(plain.hist_quorum, mesh1.hist_quorum)
