"""Batched §6 downtime/commit-pause engine: cross-backend and shard_map
bit-identity, the dup-res and rebuild degeneracy properties (pause
fractions must collapse *exactly* to the instantaneous engine's
integrals when the knobs are zeroed), protocol-semantics monotonicity,
and duration-histogram accounting."""
import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from repro.core.availability_batched import simulate_availability_batched
from repro.core.downtime_batched import simulate_downtime_batched
from repro.core.scenarios import get_scenario, scenario_names
from repro.kernels.ops import PAC_BACKENDS, downtime_eval_batch

RNG = np.random.default_rng(17)

_KW = dict(n=13, partitions=32, rf=2, p=5e-3, trials=3, max_ticks=4_000,
           min_ticks=10**9, chunk_steps=64, max_steps=600, seed=11,
           trajectory=True)


# ---------------------------------------------------------------------------
# per-step op: backend agreement
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rf,n_real,n_pad", [(2, 23, 23), (3, 19, 40)])
def test_downtime_eval_backends_agree(rf, n_real, n_pad):
    R = 128
    up = RNG.random((R, n_pad)) < 0.8
    full = RNG.random((R, n_pad)) < 0.4
    up[0] = False                       # dead partition: leader sentinel
    outs = {}
    for b in PAC_BACKENDS:
        u = up if b == "numpy" else jnp.asarray(up)
        f = full if b == "numpy" else jnp.asarray(full)
        outs[b] = tuple(np.asarray(o) for o in downtime_eval_batch(
            u, f, rf=rf, n_real=n_real, backend=b))
    for b in PAC_BACKENDS[1:]:
        for i, (a, c) in enumerate(zip(outs[PAC_BACKENDS[0]], outs[b])):
            assert np.array_equal(a, c), (b, i)
    lark, qmaj, leader, lfull, nrep, creps = outs["numpy"]
    assert leader[0] == n_real and not lfull[0]          # no node up
    assert ((2 * nrep > rf) == qmaj).all()
    assert (nrep <= rf).all()
    assert not creps[:, n_real:].any()                   # padding untouched
    # the leader is the first up node: rank-space argmax over the up mask
    up_m = up & (np.arange(n_pad) < n_real)
    exp = np.where(up_m.any(axis=1), up_m.argmax(axis=1), n_real)
    assert np.array_equal(leader, exp)


# ---------------------------------------------------------------------------
# bit-identical seeded trajectories across backends and sharding
# ---------------------------------------------------------------------------

def test_trajectory_identical_across_backends():
    results = {b: simulate_downtime_batched(backend=b, **_KW)
               for b in PAC_BACKENDS}
    base = results[PAC_BACKENDS[0]]
    for b in PAC_BACKENDS[1:]:
        r = results[b]
        for k in base.trajectory:
            assert np.array_equal(base.trajectory[k], r.trajectory[k]), \
                (b, k)
        assert r.pause_lark == base.pause_lark
        assert r.pause_quorum == base.pause_quorum
        assert np.array_equal(r.hist_lark, base.hist_lark)
        assert np.array_equal(r.hist_quorum, base.hist_quorum)
        assert r.lark_events == base.lark_events
        assert r.quorum_events == base.quorum_events
    # paused-partition counts really vary over time (the engine is live)
    assert base.trajectory["paused_quorum"].max() > 0


def test_shard_map_path_identical_on_one_device():
    plain = simulate_downtime_batched(backend="jax", **_KW)
    mesh1 = simulate_downtime_batched(backend="jax", devices=1,
                                      use_shard_map=True, **_KW)
    for k in plain.trajectory:
        assert np.array_equal(plain.trajectory[k], mesh1.trajectory[k]), k
    assert plain.pause_lark == mesh1.pause_lark
    assert plain.pause_quorum == mesh1.pause_quorum
    assert np.array_equal(plain.hist_lark, mesh1.hist_lark)
    assert np.array_equal(plain.pause_lark_trials, mesh1.pause_lark_trials)


def test_sharding_and_knob_validation():
    with pytest.raises(ValueError, match="numpy"):
        simulate_downtime_batched(backend="numpy", devices=2, **_KW)
    with pytest.raises(ValueError, match="divide"):
        simulate_downtime_batched(backend="jax", devices=2, **_KW)
    with pytest.raises(ValueError, match="dupres_ticks"):
        simulate_downtime_batched(backend="numpy", dupres_ticks=-1, **_KW)
    with pytest.raises(ValueError, match="hist_bins"):
        simulate_downtime_batched(backend="numpy", hist_bins=1, **_KW)


# ---------------------------------------------------------------------------
# degeneracy properties: zeroed knobs collapse to instantaneous integrals
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=2, max_value=3),
       st.sampled_from([3e-3, 8e-3]),
       st.integers(min_value=0, max_value=3))
def test_zero_knobs_degenerate_to_instantaneous_integrals(rf, p, seed):
    """Satellite acceptance: dupres_ticks=0 makes LARK downtime equal the
    instantaneous-PAC unavailability integral, and rebuild_steps=0 makes
    the quorum-log baseline plain majority-of-replica-set availability
    (voters=rf in the instantaneous engine) — exactly, not statistically,
    because both engines replay the same counter-RNG trajectory."""
    kw = dict(n=11, partitions=16, p=p, trials=2, max_ticks=1_500,
              min_ticks=10**9, chunk_steps=32, max_steps=200, seed=seed,
              backend="numpy", trajectory=True)
    dt = simulate_downtime_batched(rf=rf, dupres_ticks=0, rebuild_steps=0,
                                   **kw)
    av = simulate_availability_batched(rf=rf, voters=rf, **kw)
    assert dt.pause_lark == av.u_lark
    assert dt.pause_quorum == av.u_maj
    assert np.array_equal(dt.pause_lark_trials, av.u_lark_trials)
    assert np.array_equal(dt.pause_quorum_trials, av.u_maj_trials)
    assert np.array_equal(dt.trajectory["times"], av.trajectory["times"])
    assert np.array_equal(dt.trajectory["paused_lark"],
                          av.trajectory["unavail_lark"])
    assert np.array_equal(dt.trajectory["paused_quorum"],
                          av.trajectory["unavail_maj"])


def test_dupres_and_rebuild_only_add_pause():
    base = simulate_downtime_batched(dupres_ticks=0, rebuild_steps=0, **_KW)
    dup = simulate_downtime_batched(dupres_ticks=5, rebuild_steps=0, **_KW)
    reb = simulate_downtime_batched(dupres_ticks=0, rebuild_steps=50, **_KW)
    reb2 = simulate_downtime_batched(dupres_ticks=0, rebuild_steps=200,
                                     **_KW)
    assert dup.pause_lark > base.pause_lark
    assert dup.pause_quorum == base.pause_quorum     # knob is LARK-only
    assert reb.pause_quorum > base.pause_quorum
    assert reb2.pause_quorum > reb.pause_quorum      # monotone in rebuild
    assert reb.pause_lark == base.pause_lark         # knob is quorum-only


def test_lark_outpauses_nothing_quorum_pays_rebuilds():
    """The §6 headline: equal storage budget, same trajectory — LARK's
    commit-pause fraction stays well below the rebuilding quorum-log's."""
    r = simulate_downtime_batched(backend="numpy", **_KW)
    assert r.pause_lark < r.pause_quorum
    assert r.availability_ratio > 2.0


# ---------------------------------------------------------------------------
# duration-histogram accounting
# ---------------------------------------------------------------------------

def test_histogram_accounting():
    r = simulate_downtime_batched(backend="numpy", **_KW)
    assert r.hist_edges.tolist() == [1 << k for k in range(16)]
    # every completed run was opened by a counted pause-start event
    # (runs still open at the horizon are censored, so <=)
    assert 0 < int(r.hist_lark.sum()) <= r.lark_events
    assert 0 < int(r.hist_quorum.sum()) <= r.quorum_events
    # dup-res penalties land in the bucket holding dupres_ticks
    zero = simulate_downtime_batched(dupres_ticks=0, **_KW)
    pen8 = simulate_downtime_batched(dupres_ticks=8, **_KW)
    extra = pen8.hist_lark - zero.hist_lark
    assert extra[3] > 0                        # bucket [8, 16)
    assert (extra[:3] == 0).all() and (extra[4:] == 0).all()


def test_quorum_rebuild_durations_reflect_the_countdown():
    """With a failure-free rebuild window, every quorum pause caused by a
    single replica loss lasts >= rebuild_steps ticks — the histogram mass
    sits at or above the rebuild bucket."""
    r = simulate_downtime_batched(
        n=12, partitions=32, rf=3, p=1e-3, trials=2, max_ticks=20_000,
        min_ticks=10**9, seed=7, backend="numpy", dupres_ticks=0,
        rebuild_steps=64)
    assert int(r.hist_quorum.sum()) > 0
    assert r.hist_quorum[:6].sum() == 0        # no run shorter than 64


# ---------------------------------------------------------------------------
# scenario registry compatibility
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", scenario_names())
def test_every_scenario_runs_under_the_downtime_engine(name):
    sc = get_scenario(name)
    rf, p = sc.grid[0]
    r = simulate_downtime_batched(
        rf=rf, p=p, n=13, partitions=32, trials=2, max_ticks=2_000,
        min_ticks=10**9, chunk_steps=32, max_steps=120, seed=5,
        backend="numpy", **sc.kwargs(n=13, rf=rf, p=p))
    assert 0.0 <= r.pause_lark and 0.0 <= r.pause_quorum <= 1.0


@pytest.mark.slow
def test_batched_downtime_matches_reduced_scale_expectations():
    """Reduced-grid row at the sweep's scale: LARK pause ~ u_lark level,
    quorum pays heavily for rebuilds."""
    r = simulate_downtime_batched(
        n=63, partitions=512, rf=2, p=3e-3, trials=4, max_ticks=120_000,
        min_ticks=20_000, seed=0, backend="jax")
    assert 0 < r.pause_lark < 0.1
    assert r.pause_quorum > r.pause_lark
    assert r.availability_ratio > 5
