"""Framework substrate: data determinism, optimizers, elastic, serving."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import reduced_config
from repro.data import SyntheticLMData
from repro.optim import adafactor, adamw, clip_by_global_norm, warmup_cosine
from repro.serving import LarkSessionStore, ServeLoop
from repro.training.elastic import ElasticTrainer


def test_data_deterministic_and_sharded():
    cfg = reduced_config("smollm_360m")
    d = SyntheticLMData(cfg, batch=8, seq=16)
    b1 = d.batch_at(3)
    b2 = d.batch_at(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # host shards tile the global batch
    h0 = d.batch_at(3, host_id=0, num_hosts=2)
    h1 = d.batch_at(3, host_id=1, num_hosts=2)
    np.testing.assert_array_equal(
        np.concatenate([h0["tokens"], h1["tokens"]]), b1["tokens"])
    assert b1["labels"][0, 0] == b1["tokens"][0, 1]  # next-token labels


@pytest.mark.parametrize("opt_name", ["adamw", "adafactor"])
def test_optimizers_minimize_quadratic(opt_name):
    lr = warmup_cosine(0.1, warmup=5, total=200)
    opt = adamw(lr) if opt_name == "adamw" else adafactor(lr)
    params = {"w": jnp.asarray([[3.0, -2.0], [1.0, 4.0]])}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(jnp.square(p["w"]))

    for _ in range(150):
        g = jax.grad(loss)(params)
        updates, state = opt.update(g, state, params)
        params = jax.tree.map(lambda p, u: p + u, params, updates)
    assert float(loss(params)) < 0.05


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(20.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-3)


def test_elastic_trainer_remesh_and_restore():
    calls = []

    def make_step(workers):
        calls.append(tuple(workers))
        return lambda x: x + len(workers)

    et = ElasticTrainer(4, make_step)
    state = {"x": np.float32(1.0)}
    assert et.checkpoint(state)
    assert et.run_step(1) == 5
    restored = et.on_membership_change([0, 1, 2], state, state)
    assert et.state.regime == 2
    assert calls[-1] == (0, 1, 2)
    assert float(restored["x"]) == 1.0          # restored from LARK store
    assert et.run_step(1) == 4                  # remeshed to 3 workers


def test_serve_resume_matches_uninterrupted():
    cfg = reduced_config("smollm_360m")
    from repro.models import build_model
    model = build_model(cfg)
    params = model["init_params"](jax.random.PRNGKey(0))
    sess = LarkSessionStore(num_nodes=4, rf=2)
    loop = ServeLoop(cfg, params, max_len=48, session_store=sess,
                     checkpoint_every=4)
    batch = {"tokens": jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 8)),
        jnp.int32)}
    full = loop.generate(batch, steps=8, session_id="s")
    # session checkpointed at step 8: resume must match continued generation
    sess.fail_server(0)                         # failover
    resumed = loop.resume("s", steps=4)
    assert resumed is not None
    np.testing.assert_array_equal(resumed[:, :8], full)


def test_compression_error_feedback_identity():
    """int8 EF quantization: single-pod mesh means passthrough."""
    import jax
    from jax.sharding import Mesh
    from repro.training.compression import (compressed_pod_psum,
                                            init_error_state)
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    g = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    e = init_error_state(g)
    out, e2 = compressed_pod_psum(g, e, mesh)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(g["w"]))
