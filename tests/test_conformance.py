"""The protocol-zoo conformance matrix, in one place.

Every engine configuration below runs the same shared node trajectories
through every backend x state-layout combination — {numpy, jnp,
pallas-interpret} x {unpacked bool tiles, bit-packed uint32 words} — and
a forced 8-device trials mesh, and every gated output (pause fractions,
event counts, duration histograms, per-trial arrays, step trajectories)
must be *bit-identical*, never approximately equal.  This consolidates
the per-PR identity tests that used to be copy-pasted across
test_downtime_batched.py / test_sharded.py / test_step_api.py; new
engines join the zoo by adding a config here, not a new test file.

The degenerate-limit pins are the second half of the contract: each zoo
engine's knob at zero must collapse *exactly* onto the baseline it
generalizes (Hermes lease_ticks=0 -> the zero-knob LARK trace;
Spinnaker view_change_ticks=0 -> the PR-4 reconfig quorum baseline),
because the engines consume no randomness beyond the shared
_make_node_advance closure — the proof is arithmetic identity of the
f32 accumulator expressions, and these tests pin it bitwise.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

from repro.core.downtime_batched import ENGINES, simulate_downtime_batched
from repro.kernels.ops import PAC_BACKENDS

_KW = dict(n=13, partitions=32, rf=2, p=5e-3, trials=3, max_ticks=4_000,
           min_ticks=10**9, chunk_steps=64, max_steps=600, seed=11,
           trajectory=True)

#: engine-grid configurations; each runs the full backend x layout
#: matrix.  The first three pin the pre-zoo engines (fixed model,
#: reconfiguring baseline, the PR-5 skew + bandwidth-contention
#: tentpole); the last two pin the zoo with its knobs live.
CONFIGS = {
    "fixed": {},
    "reconfig": dict(rebuild_model="reconfig", rebuild_ticks_per_gib=64),
    "skew-contended": dict(rebuild_model="reconfig",
                           rebuild_ticks_per_gib=64, size_dist="zipf",
                           size_skew=1.0, node_bandwidth_gibps=1.0),
    "hermes-fixed": dict(engines=("lark", "quorum", "hermes"),
                         lease_ticks=40),
    "zoo-reconfig": dict(engines=ENGINES, rebuild_model="reconfig",
                         rebuild_ticks_per_gib=64, lease_ticks=40,
                         view_change_ticks=200),
}


def _fingerprint(r):
    """Every gated output of a run, as comparable numpy values."""
    fp = {
        "pause_lark": r.pause_lark, "pause_quorum": r.pause_quorum,
        "lark_events": r.lark_events, "quorum_events": r.quorum_events,
        "hist_lark": r.hist_lark, "hist_quorum": r.hist_quorum,
        "pause_lark_trials": r.pause_lark_trials,
        "pause_quorum_trials": r.pause_quorum_trials,
    }
    for k, v in (r.trajectory or {}).items():
        fp[f"traj:{k}"] = v
    for engine in r.engines:
        if engine in ("lark", "quorum"):
            continue
        s = r.engine_stats(engine)
        fp[f"{engine}:pause"] = s["pause"]
        fp[f"{engine}:events"] = s["events"]
        fp[f"{engine}:hist"] = s["hist"]
        fp[f"{engine}:pause_trials"] = s["pause_trials"]
    return fp


def _assert_identical(a, b, label):
    fa, fb = _fingerprint(a), _fingerprint(b)
    assert fa.keys() == fb.keys(), (label, set(fa) ^ set(fb))
    for k in fa:
        assert np.array_equal(np.asarray(fa[k]), np.asarray(fb[k])), \
            (label, k)


@pytest.mark.parametrize("config", list(CONFIGS))
def test_backend_layout_matrix_bit_identical(config):
    """numpy == jax == pallas-interpret, unpacked == packed, for every
    engine configuration (pallas runs interpret mode on CPU)."""
    kw = dict(_KW, **CONFIGS[config])
    base = simulate_downtime_batched(backend=PAC_BACKENDS[0], **kw)
    # trajectories really move, or the identity is vacuous
    assert base.trajectory["paused_quorum"].max() > 0
    for backend in PAC_BACKENDS:
        for packed in (False, True):
            if (backend, packed) == (PAC_BACKENDS[0], False):
                continue
            r = simulate_downtime_batched(backend=backend, packed=packed,
                                          **kw)
            _assert_identical(base, r, (config, backend, packed))


@pytest.mark.parametrize("config", ["fixed", "zoo-reconfig"])
def test_shard_map_path_identical_on_one_device(config):
    kw = dict(_KW, **CONFIGS[config])
    plain = simulate_downtime_batched(backend="jax", **kw)
    mesh1 = simulate_downtime_batched(backend="jax", devices=1,
                                      use_shard_map=True, **kw)
    _assert_identical(plain, mesh1, config)


@pytest.mark.slow
def test_eight_device_matrix_bit_identical_to_single():
    """devices {1, 8} leg of the matrix: pause fractions, histograms,
    per-engine stats and trajectories byte-identical between --devices 1
    and a forced 8-device mesh, for the fixed model, the reconfiguring
    baseline, and the full four-engine zoo (whose hermes/spinnaker
    leaves ride the sharded scan carry)."""
    script = textwrap.dedent("""
        import numpy as np
        from repro.core.downtime_batched import (ENGINES,
                                                 simulate_downtime_batched)
        base_kw = dict(n=13, partitions=32, rf=2, p=5e-3, trials=8,
                       max_ticks=4_000, min_ticks=10**9, chunk_steps=64,
                       max_steps=600, seed=11, backend="jax",
                       trajectory=True, pair_fail_prob=0.3,
                       restart_period=900)
        for model_kw in (dict(rebuild_model="fixed"),
                         dict(rebuild_model="reconfig",
                              rebuild_ticks_per_gib=64),
                         dict(rebuild_model="reconfig",
                              rebuild_ticks_per_gib=64, engines=ENGINES,
                              lease_ticks=40, view_change_ticks=200)):
            kw = dict(base_kw, **model_kw)
            r1 = simulate_downtime_batched(devices=1, **kw)
            for d in (4, 8):
                rd = simulate_downtime_batched(devices=d, **kw)
                for k in r1.trajectory:
                    assert np.array_equal(r1.trajectory[k],
                                          rd.trajectory[k]), (d, k)
                assert r1.pause_lark == rd.pause_lark
                assert r1.pause_quorum == rd.pause_quorum
                assert np.array_equal(r1.hist_lark, rd.hist_lark)
                assert np.array_equal(r1.hist_quorum, rd.hist_quorum)
                assert r1.lark_events == rd.lark_events
                assert r1.quorum_events == rd.quorum_events
                for engine in r1.engines:
                    if engine in ("lark", "quorum"):
                        continue
                    s1 = r1.engine_stats(engine)
                    sd = rd.engine_stats(engine)
                    assert s1["pause"] == sd["pause"], (d, engine)
                    assert s1["events"] == sd["events"], (d, engine)
                    assert np.array_equal(s1["hist"], sd["hist"]), \\
                        (d, engine)
                    assert np.array_equal(s1["pause_trials"],
                                          sd["pause_trials"]), (d, engine)
        print("OK")
    """)
    env = dict(os.environ,
               JAX_PLATFORMS="cpu",
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=str(Path(__file__).resolve().parents[1] / "src"))
    out = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr
    assert "OK" in out.stdout


# ---------------------------------------------------------------------------
# degenerate limits: knob at zero == the baseline the engine generalizes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", PAC_BACKENDS)
def test_hermes_lease_zero_pins_lark_exactly(backend):
    """lease_ticks=0 (writes never block on suspicion) makes the Hermes
    pause predicate ~lark: with dupres_ticks=0 its accounting is the
    same f32 expression as LARK's, so every output is bitwise equal —
    zero drift, not 2 sigma."""
    kw = dict(_KW, backend=backend, dupres_ticks=0,
              engines=("lark", "quorum", "hermes"), lease_ticks=0)
    r = simulate_downtime_batched(**kw)
    s = r.engine_stats("hermes")
    assert s["pause"] == r.pause_lark
    assert s["events"] == r.lark_events
    assert np.array_equal(s["hist"], r.hist_lark)
    assert np.array_equal(s["pause_trials"], r.pause_lark_trials)
    assert np.array_equal(r.trajectory["paused_hermes"],
                          r.trajectory["paused_lark"])


@pytest.mark.parametrize("backend", PAC_BACKENDS)
def test_spinnaker_vc_zero_pins_reconfig_quorum_exactly(backend):
    """view_change_ticks=0 with unshared (infinite) bandwidth makes the
    Spinnaker pause predicate ~qmaj | rebuilding — the PR-4 reconfig
    quorum baseline, bit for bit."""
    kw = dict(_KW, backend=backend, rebuild_model="reconfig",
              rebuild_ticks_per_gib=64,
              engines=("lark", "quorum", "spinnaker"),
              view_change_ticks=0)
    r = simulate_downtime_batched(**kw)
    s = r.engine_stats("spinnaker")
    assert s["pause"] == r.pause_quorum
    assert s["events"] == r.quorum_events
    assert np.array_equal(s["hist"], r.hist_quorum)
    assert np.array_equal(s["pause_trials"], r.pause_quorum_trials)
    assert np.array_equal(r.trajectory["paused_spinnaker"],
                          r.trajectory["paused_quorum"])


def test_zoo_engines_leave_base_outputs_untouched():
    """Enabling the zoo must not perturb the lark/quorum outputs at all —
    the committed BENCH_downtime*.json baselines regen byte-identical
    whether or not --engines grows the row set."""
    kw = dict(_KW, rebuild_model="reconfig", rebuild_ticks_per_gib=64)
    base = simulate_downtime_batched(**kw)
    zoo = simulate_downtime_batched(engines=ENGINES, lease_ticks=40,
                                    view_change_ticks=200, **kw)
    assert zoo.pause_lark == base.pause_lark
    assert zoo.pause_quorum == base.pause_quorum
    assert zoo.lark_events == base.lark_events
    assert zoo.quorum_events == base.quorum_events
    assert np.array_equal(zoo.hist_lark, base.hist_lark)
    assert np.array_equal(zoo.hist_quorum, base.hist_quorum)
    for k in base.trajectory:
        assert np.array_equal(zoo.trajectory[k], base.trajectory[k]), k


def test_zoo_knob_and_engine_validation():
    with pytest.raises(ValueError, match="unknown engine"):
        simulate_downtime_batched(engines=("lark", "raft"), **_KW)
    with pytest.raises(ValueError, match="duplicate"):
        simulate_downtime_batched(engines=("lark", "lark"), **_KW)
    with pytest.raises(ValueError, match="lease_ticks"):
        simulate_downtime_batched(lease_ticks=5, **_KW)
    with pytest.raises(ValueError, match="view_change_ticks"):
        simulate_downtime_batched(view_change_ticks=5, **_KW)
    with pytest.raises(ValueError, match="reconfig"):
        simulate_downtime_batched(engines=("lark", "quorum", "spinnaker"),
                                  **_KW)
    with pytest.raises(ValueError, match="disable predicates"):
        simulate_downtime_batched(_disable_predicates=("bogus",), **_KW)
