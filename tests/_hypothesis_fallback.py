"""Minimal stand-in for `hypothesis` when it is not installed.

The dev extra (`pip install -e .[dev]`) brings in the real hypothesis and
this module is never imported.  Without it (hermetic containers), the four
property-test modules would fail at collection on `from hypothesis import
given, settings, strategies as st` — so conftest.py registers this shim in
sys.modules instead.  It implements just the strategy surface those tests
use (integers / sampled_from / sets), drawing a bounded number of
deterministic pseudo-random examples per test.  It is NOT hypothesis: no
shrinking, no database, no edge-case bias — a smoke-level fallback only.
"""
from __future__ import annotations

import random
import sys
import types
import zlib

_MAX_EXAMPLES_CAP = 100


class SearchStrategy:
    def __init__(self, draw):
        self.draw = draw


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(lambda r: r.randint(min_value, max_value))


def sampled_from(elements) -> SearchStrategy:
    elements = list(elements)
    return SearchStrategy(lambda r: r.choice(elements))


def sets(elements: SearchStrategy, min_size: int = 0,
         max_size: int = None) -> SearchStrategy:
    def draw(r):
        hi = max_size if max_size is not None else min_size + 8
        want = r.randint(min_size, hi)
        out = set()
        for _ in range(20 * max(want, 1)):      # collisions shrink the set;
            if len(out) >= want:                # retry a bounded number of
                break                           # times, then settle
            out.add(elements.draw(r))
        return out
    return SearchStrategy(draw)


def settings(max_examples: int = 100, deadline=None, **_kw):
    def deco(fn):
        fn._fallback_settings = {"max_examples": max_examples}
        return fn
    return deco


def given(*strategies):
    def deco(fn):
        def wrapper(*args, **kwargs):
            cfg = getattr(wrapper, "_fallback_settings", None) or \
                getattr(fn, "_fallback_settings", {})
            examples = min(cfg.get("max_examples", 100), _MAX_EXAMPLES_CAP)
            # per-test deterministic stream, stable across runs
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(examples):
                fn(*args, *[s.draw(rng) for s in strategies], **kwargs)
        # NOT functools.wraps: __wrapped__ would make pytest resolve the
        # strategy parameters of the original signature as fixtures
        for attr in ("__name__", "__qualname__", "__doc__", "__module__"):
            setattr(wrapper, attr, getattr(fn, attr))
        return wrapper
    return deco


def install() -> None:
    """Register shim modules as `hypothesis` / `hypothesis.strategies`."""
    hyp = types.ModuleType("hypothesis")
    strat = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "sampled_from", "sets"):
        setattr(strat, name, globals()[name])
    strat.SearchStrategy = SearchStrategy
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = strat
    hyp.__version__ = "0.0-fallback"
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = strat
