"""Deferred-rebalance staleness: a rebalance queued in regime k must only
be releasable while the node is still in regime k (the tautological
`er == er` guard previously replayed rebalances from dead regimes)."""
from repro.core.simulator import LarkSim


def _sim():
    sim = LarkSim(num_nodes=4, rf=2, num_partitions=1)
    sim.set_succession(0, [0, 1, 2, 3])
    sim.recluster()
    sim.settle()
    sim.run_migrations()
    return sim


def test_fresh_deferred_rebalance_released():
    sim = _sim()
    er = sim.recluster(defer_rebalance=[2])
    sim.settle()
    assert sim.nodes[2].p[0].pr < er          # rebalance still pending
    sim.run_deferred_rebalance(2)
    sim.settle()
    assert sim._pending_rebalance == []
    assert sim.nodes[2].p[0].pr == er         # released into its regime


def test_stale_deferred_rebalance_dropped():
    sim = _sim()
    er2 = sim.recluster(defer_rebalance=[2])  # deferral queued: members
    sim.settle()                              # {0, 1, 2, 3}
    sim.fail_node(3, recluster=False)
    er3 = sim.recluster()                     # regime moves on (node 2's er
    sim.settle()                              # advances past the deferral)
    assert er3 > er2
    assert sim.nodes[2].p[0].nodes_in_cluster == frozenset({0, 1, 2})
    queue_before = len(sim.net.queue)
    sim.run_deferred_rebalance(2)
    assert sim._pending_rebalance == []       # stale entry dropped ...
    assert len(sim.net.queue) == queue_before  # ... without sending anything
    # no rollback onto the dead regime's membership view
    assert sim.nodes[2].p[0].nodes_in_cluster == frozenset({0, 1, 2})
    assert sim.nodes[2].p[0].pr == er3
