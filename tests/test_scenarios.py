"""Scenario registry: naming, kwargs hygiene, and one behavioral property
per registered failure model (the batched engine's mechanism knobs are
covered in test_availability_batched.py; these pin the *policies*)."""
import numpy as np
import pytest

from repro.core.availability_batched import simulate_availability_batched
from repro.core.scenarios import (SCENARIOS, get_scenario, register_scenario,
                                  scenario_names)

_TINY = dict(n=13, partitions=32, trials=2, max_ticks=2_000,
             min_ticks=10**9, chunk_steps=32, max_steps=120, seed=5,
             backend="numpy")


# ---------------------------------------------------------------------------
# registry hygiene
# ---------------------------------------------------------------------------

def test_registry_exposes_at_least_four_named_scenarios():
    names = scenario_names()
    assert len(names) >= 4
    assert "independent" in names
    for name in names:
        sc = get_scenario(name)
        assert sc.name == name and sc.summary
        assert sc.grid, name
        for rf, p in sc.grid:
            assert rf >= 2 and 0 < p < 1


def test_unknown_scenario_lists_registered_names():
    with pytest.raises(KeyError, match="independent"):
        get_scenario("nope")


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError, match="duplicate"):
        register_scenario("independent", "again", ((2, 1e-3),))(lambda **kw: {})


def test_scenarios_cannot_override_sweep_owned_kwargs():
    sc = register_scenario("_bad_tmp", "overrides rf", ((2, 1e-3),))(
        lambda **kw: {"rf": 3})
    try:
        with pytest.raises(ValueError, match="sweep-owned"):
            get_scenario("_bad_tmp").kwargs(n=8, rf=2, p=1e-3)
    finally:
        del SCENARIOS["_bad_tmp"], sc


@pytest.mark.parametrize("name", scenario_names())
def test_every_scenario_runs_under_the_batched_engine(name):
    sc = get_scenario(name)
    rf, p = sc.grid[0]
    r = simulate_availability_batched(rf=rf, p=p,
                                      **sc.kwargs(n=13, rf=rf, p=p), **_TINY)
    assert 0.0 <= r.u_lark <= 1.0 and 0.0 <= r.u_maj <= 1.0


# ---------------------------------------------------------------------------
# behavioral properties, one per failure model
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("wave_width", [2, 3])
def test_maintenance_wave_never_exceeds_wave_width(wave_width):
    """A maintenance wave may take down at most `wave_width` nodes at once
    (failure rate ~0 — at p=1e-7 a background failure still sneaks inside
    the horizon every ~1e3 redraws, enough to flake — waves spaced beyond
    the downtime)."""
    n = 12
    r = simulate_availability_batched(
        n=n, partitions=32, rf=2, p=1e-9, trials=2, max_ticks=10_000,
        min_ticks=10**9, restart_period=400, wave_width=wave_width,
        downtime=50, backend="numpy", trajectory=True)
    nodes_up = r.trajectory["nodes_up"]
    assert int(nodes_up.min()) >= n - wave_width
    # ... and the waves really do take that many down together
    assert int(nodes_up.min()) == n - wave_width


def test_wave_of_width_one_is_the_rolling_restart_scenario():
    """wave_width=1 must reproduce the serial rolling restart bit-for-bit
    (the registry's rolling-restart grid rides on the same mechanism)."""
    kw = dict(n=12, partitions=32, rf=2, p=1e-5, trials=2, max_ticks=8_000,
              min_ticks=10**9, restart_period=500, backend="numpy",
              trajectory=True)
    a = simulate_availability_batched(wave_width=1, **kw)
    b = simulate_availability_batched(**kw)          # default width
    for k in a.trajectory:
        assert np.array_equal(a.trajectory[k], b.trajectory[k]), k


def test_flapping_nodes_hurt_availability():
    sc = get_scenario("flapping")
    base = dict(n=16, partitions=64, rf=2, p=2e-3, trials=4,
                max_ticks=50_000, min_ticks=10**9, seed=3, backend="numpy")
    iid = simulate_availability_batched(**base)
    flap = simulate_availability_batched(
        **base, **sc.kwargs(n=16, rf=2, p=2e-3))
    # 20x-rate flappers dominate the failure budget even with fast recovery
    assert flap.u_lark > iid.u_lark
    assert flap.u_maj > iid.u_maj


def test_hetero_mttf_tiers_hurt_availability():
    sc = get_scenario("hetero-mttf")
    base = dict(n=15, partitions=64, rf=2, p=2e-3, trials=4,
                max_ticks=50_000, min_ticks=10**9, seed=4, backend="numpy")
    iid = simulate_availability_batched(**base)
    het = simulate_availability_batched(
        **base, **sc.kwargs(n=15, rf=2, p=2e-3))
    # the 4x tier raises the mean failure rate to ~1.8x the base
    assert het.u_lark > iid.u_lark


def test_rack_pairs_scenario_matches_mechanism_knob():
    """The registered scenario is exactly the pair_fail_prob mechanism —
    same trajectory as passing the knob directly."""
    sc = get_scenario("rack-pairs")
    kw = dict(n=14, partitions=32, rf=2, p=5e-3, trials=2, max_ticks=5_000,
              min_ticks=10**9, chunk_steps=64, max_steps=300, seed=9,
              backend="numpy", trajectory=True)
    a = simulate_availability_batched(**kw, **sc.kwargs(n=14, rf=2, p=5e-3))
    b = simulate_availability_batched(**kw, pair_fail_prob=0.5)
    for k in a.trajectory:
        assert np.array_equal(a.trajectory[k], b.trajectory[k]), k


def test_per_node_inputs_validated():
    with pytest.raises(ValueError, match="shape"):
        simulate_availability_batched(p_node=np.full(5, 1e-3), **_TINY, p=1e-3,
                                      rf=2)
    with pytest.raises(ValueError, match="downtime_node"):
        simulate_availability_batched(
            downtime_node=np.zeros(13, dtype=int), **_TINY, p=1e-3, rf=2)
    with pytest.raises(ValueError, match="wave_width"):
        simulate_availability_batched(wave_width=99, **_TINY, p=1e-3, rf=2)
