"""Declarative experiment layer: spec gating, config loading, the
flag↔config↔programmatic equivalence contract, and the runner's
events/provenance stamping (engines stubbed — orchestration only)."""
import importlib.util
import json
from pathlib import Path
from types import SimpleNamespace

import pytest

from repro.core.downtime_batched import ENGINES
from repro.experiments import runner as runner_mod
from repro.experiments import schema
from repro.experiments.runner import ExperimentRunner, run_batch
from repro.experiments.spec import (ExperimentSpec, SpecError,
                                    _loads_flat_toml)

REPO = Path(__file__).resolve().parents[1]
CONFIGS = REPO / "benchmarks" / "configs"

_spec = importlib.util.spec_from_file_location(
    "availability_sweep", REPO / "benchmarks" / "availability_sweep.py")
sweep = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(sweep)


# -- spec construction & gating ------------------------------------------

def test_unknown_key_rejected_with_nearest_match():
    with pytest.raises(SpecError, match=r"did you mean 'metric'"):
        ExperimentSpec.create(metrc="downtime")
    with pytest.raises(SpecError, match="unknown spec key"):
        ExperimentSpec.create(zzz_not_a_knob=1)
    with pytest.raises(SpecError, match=r"did you mean 'trials'"):
        ExperimentSpec.create(trails=8)


@pytest.mark.parametrize("kwargs, match", [
    (dict(metric="availability", dupres_ticks=3), "metric 'downtime'"),
    (dict(metric="latency", engines="lark,quorum,hermes"), "protocol zoo"),
    (dict(key_zipf=2.0), "request workload"),
    (dict(metric="downtime", rebuild_ticks_per_gib=5),
     "reconfig-model knob"),
    (dict(metric="downtime", rebuild_model="reconfig", rebuild_steps=7),
     "fixed-model knob"),
    (dict(metric="downtime", size_dist="zipf"), "rebuild_model 'reconfig'"),
    (dict(metric="downtime", rebuild_model="reconfig", size_skew=2.0),
     "zipf/lognormal"),
    (dict(backend="event", metric="latency"), "batched engines"),
    (dict(backend="event", packed=True), "batched engines"),
    (dict(backend="jax", autotune=True), "pallas"),
    (dict(backend="numpy", trials=4, devices=2), "jax"),
    (dict(backend="jax", trials=5, devices=2), "multiple"),
    (dict(scenarios=["rolling-restrt"]), "rolling-restart"),
    (dict(metric="downtime", engines="lark,quorum,raft"), "raft"),
    (dict(metric="downtime", lease_ticks=40), "hermes"),
], ids=lambda v: str(sorted(v))[:40] if isinstance(v, dict) else v)
def test_gated_and_invalid_knobs_rejected(kwargs, match):
    with pytest.raises(SpecError, match=match):
        ExperimentSpec.create(**kwargs)


def test_canonical_round_trip_is_lossless():
    specs = [
        ExperimentSpec.create(),
        ExperimentSpec.create(metric="latency", backend="jax", trials=8,
                              devices=8, smoke=True, scenarios=["all"]),
        ExperimentSpec.create(metric="downtime", backend="jax", trials=8,
                              devices=8, smoke=True,
                              rebuild_model="reconfig", size_dist="zipf",
                              size_skew=1.0, node_bandwidth_gibps=1.0,
                              scenarios=["all"]),
        ExperimentSpec.create(metric="downtime", backend="pallas",
                              trials=2, smoke=True, packed=True,
                              autotune=True),
    ]
    for s in specs:
        rt = ExperimentSpec.create(**s.canonical())
        assert rt == s
        assert rt.content_hash() == s.content_hash()
        # the canonical form itself survives a JSON round trip
        again = ExperimentSpec.create(**json.loads(
            json.dumps(s.canonical())))
        assert again == s


def test_scenarios_resolve_and_dedupe():
    s = ExperimentSpec.create(scenarios=["all"])
    from repro.core.scenarios import scenario_names
    assert s.scenarios == tuple(scenario_names())
    s2 = ExperimentSpec.create(scenarios=["rack-pairs,flapping"])
    assert s2.scenarios == ("rack-pairs", "flapping")
    # scenarios_only with no selection = every registered scenario
    s3 = ExperimentSpec.create(scenarios_only=True, backend="jax",
                               trials=4)
    assert s3.scenarios == tuple(scenario_names()) and s3.scenarios_only


def test_schema_constants_pin_the_engine_stack():
    # the stdlib-only schema must never drift from the engine registry
    assert schema.KNOWN_ENGINES == ENGINES
    assert schema.SCHEMA_VERSION in schema.KNOWN_SCHEMA_VERSIONS
    # every declared row kind has key fields and gated columns
    for kind, (key_fam, col_fam) in schema.KIND_FAMILIES.items():
        assert key_fam in schema.ROW_KEY_FIELDS, kind
        assert col_fam in schema.GATED_COLS, kind


# -- committed configs ---------------------------------------------------

#: the flag spelling documented in each config header — the CLI/config
#: equivalence contract, pinned for every committed baseline
FLAG_LINES = {
    "sweep.toml": ["--backend", "jax", "--trials", "8", "--devices", "8",
                   "--scenario", "all"],
    "downtime.toml": ["--backend", "jax", "--trials", "8", "--devices",
                      "8", "--metric", "downtime", "--smoke",
                      "--scenario", "all"],
    "downtime_reconfig.toml": ["--backend", "jax", "--trials", "8",
                               "--devices", "8", "--metric", "downtime",
                               "--smoke", "--rebuild-model", "reconfig",
                               "--scenario", "all"],
    "downtime_skew.toml": ["--backend", "jax", "--trials", "8",
                           "--devices", "8", "--metric", "downtime",
                           "--smoke", "--rebuild-model", "reconfig",
                           "--size-dist", "zipf", "--size-skew", "1",
                           "--node-bandwidth-gibps", "1",
                           "--scenario", "all"],
    "latency.toml": ["--backend", "jax", "--trials", "8", "--devices",
                     "8", "--metric", "latency", "--smoke",
                     "--scenario", "all"],
    "shootout.toml": ["--backend", "jax", "--trials", "8", "--devices",
                      "8", "--metric", "downtime", "--smoke",
                      "--rebuild-model", "reconfig", "--engines",
                      "lark,quorum,hermes,spinnaker", "--lease-ticks",
                      "40", "--view-change-ticks", "200",
                      "--scenario", "rolling-restart"],
}


def test_every_committed_config_has_a_pinned_flag_line():
    tomls = sorted(p.name for p in CONFIGS.glob("*.toml"))
    assert tomls == sorted(FLAG_LINES), (
        "add the new config's flag spelling to FLAG_LINES")


@pytest.mark.parametrize("name", sorted(FLAG_LINES))
def test_cli_built_spec_equals_config_built_spec(name):
    cfg = ExperimentSpec.from_file(str(CONFIGS / name))
    cli, _ = sweep.build_spec(FLAG_LINES[name])
    assert cli == cfg
    assert cli.content_hash() == cfg.content_hash()
    assert cfg.name == Path(name).stem


@pytest.mark.parametrize("name", sorted(FLAG_LINES))
def test_fallback_toml_parser_agrees_with_from_file(name):
    # on 3.11+ from_file goes through tomllib; the flat fallback (the
    # 3.10 container path) must parse the committed configs identically
    flat = _loads_flat_toml((CONFIGS / name).read_text())
    assert ExperimentSpec.create(**flat) == \
        ExperimentSpec.from_file(str(CONFIGS / name))


@pytest.mark.parametrize("name", sorted(FLAG_LINES))
def test_config_meta_matches_committed_baseline_meta(name):
    """legacy_meta() of each config reproduces its committed BENCH
    meta key for key — the byte-compat contract for summary dumps
    (provenance-stamped dumps only ever add keys on top of these)."""
    bench = REPO / "benchmarks" / f"BENCH_{Path(name).stem}.json"
    committed = json.loads(bench.read_text())["meta"]
    spec = ExperimentSpec.from_file(str(CONFIGS / name))
    meta = spec.legacy_meta()
    legacy_keys = {k: v for k, v in meta.items()}
    assert legacy_keys == committed


def test_config_flag_conflict_is_an_error():
    with pytest.raises(SystemExit):
        sweep.build_spec(["--config", str(CONFIGS / "shootout.toml"),
                          "--trials", "4"])


def test_toml_fallback_parser_rejects_what_it_cannot_parse(tmp_path):
    with pytest.raises(SpecError, match="tables are not supported"):
        _loads_flat_toml("[section]\nkey = 1")
    with pytest.raises(SpecError, match="key = value"):
        _loads_flat_toml("just words")
    with pytest.raises(SpecError, match="cannot parse"):
        _loads_flat_toml("x = {a = 1}")
    # inline comments, quoted '#', inf, arrays all survive
    data = _loads_flat_toml(
        'a = "with # hash"  # comment\nb = inf\nc = [1, "two", true]\n')
    assert data == {"a": "with # hash", "b": float("inf"),
                    "c": [1, "two", True]}
    bad = tmp_path / "bad.toml"
    bad.write_text('name = "x"\nmetrc = "downtime"\n')
    with pytest.raises(SpecError, match="did you mean 'metric'"):
        ExperimentSpec.from_file(str(bad))


# -- runner orchestration (engines stubbed) ------------------------------

def _fake_avail(**kw):
    return SimpleNamespace(u_lark=1e-4, u_maj=2e-4, ci_lark=1e-5,
                           ci_maj=1e-5, ticks=1000)


def test_runner_streams_events_and_stamps_provenance(tmp_path, monkeypatch):
    monkeypatch.setattr(runner_mod, "simulate_availability_batched",
                        _fake_avail)
    spec = ExperimentSpec.create(backend="numpy", smoke=True, trials=2,
                                 scenarios=["rack-pairs"])
    lines = []
    ev = tmp_path / "events.jsonl"
    runner = ExperimentRunner(spec, events_path=str(ev),
                              emit=lines.append)
    rows = runner.run()
    assert [r["kind"] for r in rows[:2]] == ["iid", "iid"]  # smoke grid
    assert {r["kind"] for r in rows[2:]} == {"scenario"}
    assert len(lines) == len(rows)
    assert lines[0].startswith("availability,rf2_p")
    assert lines[-1].startswith("availability_scenario,rack-pairs_")

    events = [json.loads(x) for x in ev.read_text().splitlines()]
    assert events[0]["event"] == "run_start"
    assert events[0]["spec_sha256"] == spec.content_hash()
    assert events[-1]["event"] == "run_end"
    assert events[-1]["rows"] == len(rows)
    row_events = [e for e in events if e["event"] == "row"]
    assert len(row_events) == len(rows)
    assert all(e["dt_s"] >= 0 and e["t_s"] >= e["dt_s"] - 1e-9
               for e in row_events)
    assert row_events[0]["label"].startswith("iid_2_")

    doc = runner.summary()
    meta = doc["meta"]
    assert meta["schema_version"] == schema.SCHEMA_VERSION
    assert meta["backend"] == "numpy" and meta["smoke"] is True
    prov = meta["provenance"]
    assert prov["spec_sha256"] == spec.content_hash()
    assert prov["rng_salts"] == {"size": 0x94D049BB, "key": 0xC2B2AE35}
    assert prov["requested"] == {"backend": "numpy", "devices": 1,
                                 "trials": 2}
    assert prov["wall_s"] is not None and prov["started_unix"] > 0
    # the embedded spec reproduces the spec exactly (lossless meta)
    assert ExperimentSpec.create(**meta["spec"]) == spec
    # rows in the document are json-safe (no non-finite floats)
    json.dumps(doc, allow_nan=False)


def test_run_batch_returns_one_summary_per_spec(tmp_path, monkeypatch):
    monkeypatch.setattr(runner_mod, "simulate_availability_batched",
                        _fake_avail)
    a = ExperimentSpec.create(backend="numpy", smoke=True, trials=1)
    b = ExperimentSpec.create(backend="numpy", smoke=True, trials=2,
                              scenarios=["flapping"], scenarios_only=True)
    ev = tmp_path / "batch.jsonl"
    docs = run_batch([a, b], events_path=str(ev), emit=lambda _: None)
    assert len(docs) == 2
    assert docs[0]["meta"]["trials"] == 1
    assert docs[1]["meta"]["scenarios"] == ["flapping"]
    starts = [json.loads(x) for x in ev.read_text().splitlines()
              if json.loads(x)["event"] == "run_start"]
    assert len(starts) == 2


def test_write_summary_round_trips_through_the_gate(tmp_path, monkeypatch):
    """End to end: a provenance-stamped dump written by the runner loads
    clean through check_regression's strict loader and gates green
    against itself."""
    monkeypatch.setattr(runner_mod, "simulate_availability_batched",
                        _fake_avail)
    spec = ExperimentSpec.create(backend="numpy", smoke=True, trials=2)
    out = tmp_path / "dump.json"
    ExperimentRunner(spec, emit=None).write_summary(str(out))

    cr_spec = importlib.util.spec_from_file_location(
        "check_regression",
        REPO / "benchmarks" / "check_regression.py")
    check_regression = importlib.util.module_from_spec(cr_spec)
    cr_spec.loader.exec_module(check_regression)
    notes = []
    doc = check_regression.load_rows(str(out), notes)
    assert not notes                     # provenance-stamped: no nag
    assert doc["meta"]["schema_version"] == schema.SCHEMA_VERSION
    rc = check_regression.main([str(out), str(out), "--identical"])
    assert rc == 0


# -- run.py unknown-flag contract ----------------------------------------

def test_run_py_flags_unknown_flags_with_suggestion():
    run_spec = importlib.util.spec_from_file_location(
        "bench_run", REPO / "benchmarks" / "run.py")
    bench_run = importlib.util.module_from_spec(run_spec)
    run_spec.loader.exec_module(bench_run)
    suite = SimpleNamespace(cli_options=lambda: ("--trials", "--backend"))
    assert bench_run._unknown_flags(["--trials", "8"], [suite]) == []
    unknown = bench_run._unknown_flags(["--trails=8"], [suite])
    assert unknown == [("--trails", "--trials")]
    # every real suite publishes cli_options, and the sweep's surface
    # covers the flags run.py forwards in CI
    opts = sweep.cli_options()
    assert "--config" in opts and "--metric" in opts


def test_sweep_main_still_accepts_loose_parsing_for_run_py():
    # run.py passes every suite the same argv with strict=False; a flag
    # the sweep doesn't know must not kill it there
    spec, _ = sweep.build_spec(["--backend", "numpy", "--smoke",
                                "--some-other-suites-flag"], strict=False)
    assert spec.backend == "numpy" and spec.smoke


def test_every_benchmark_module_is_suite_or_standalone_tool():
    """run.py's suite tuple plus STANDALONE_TOOLS must cover every
    benchmarks/*.py module, with no overlap — a new tool can never be
    silently neither (run under the shared argv it would crash or drop
    flags; left off both lists it would never run at all)."""
    run_spec = importlib.util.spec_from_file_location(
        "bench_run_cov", REPO / "benchmarks" / "run.py")
    bench_run = importlib.util.module_from_spec(run_spec)
    run_spec.loader.exec_module(bench_run)
    suites = set(bench_run.SUITE_NAMES)
    tools = set(bench_run.STANDALONE_TOOLS)
    modules = {p.stem for p in (REPO / "benchmarks").glob("*.py")
               if p.stem != "run"}
    assert suites | tools == modules, \
        (suites | tools) ^ modules
    assert not suites & tools


# -- latency rows: sharpening knobs add columns only when set ------------

def test_latency_row_degenerate_knobs_add_no_columns():
    """write_skew=0 / bw=inf / slo_curve_bins=0 rows must carry exactly
    the pre-knob key set — that is what keeps regenerated baselines
    byte-identical to the committed ones row for row."""
    import math

    import numpy as np
    base = dict(
        rf=2, p=1e-4, lat_lark=0.1, lat_quorum=0.2, lat_hermes=0.02,
        ci_lat_lark=0.01, ci_lat_quorum=0.01,
        p50_lark=0.0, p99_lark=1.0, p999_lark=4.0,
        p50_quorum=0.0, p99_quorum=1.0, p999_quorum=4.0,
        p50_hermes=0.0, p99_hermes=1.0, p999_hermes=4.0,
        slo_lark=0.0, slo_quorum=0.0, slo_hermes=0.0, req_total=100.0,
        hist_edges=np.arange(3), hist_quorum_req=np.zeros(3),
        dupres_ticks=1, rebuild_model="fixed", key_zipf=1.0,
        read_frac=0.8, requests_per_tick=32.0, slo_ticks=8, ticks=1000,
        write_skew=0.0, node_bandwidth_gibps=math.inf, slo_curve_bins=0,
        slo_curve_edges=None, slo_curve_lark=None,
        slo_curve_quorum=None, slo_curve_hermes=None)
    deg = runner_mod._latency_row(SimpleNamespace(**base),
                                  kind="latency", scenario="iid")
    for key in ("write_skew", "node_bandwidth_gibps", "slo_curve_bins",
                "slo_curve_edges", "slo_curve_lark", "slo_curve_quorum",
                "slo_curve_hermes"):
        assert key not in deg, key
    curves = np.zeros(4)
    knobbed = runner_mod._latency_row(
        SimpleNamespace(**{**base, "write_skew": 1.0,
                           "node_bandwidth_gibps": 0.5,
                           "slo_curve_bins": 4, "slo_curve_edges": curves,
                           "slo_curve_lark": curves,
                           "slo_curve_quorum": curves,
                           "slo_curve_hermes": curves}),
        kind="latency", scenario="iid")
    assert knobbed["write_skew"] == 1.0
    assert knobbed["node_bandwidth_gibps"] == 0.5
    assert knobbed["slo_curve_bins"] == 4
    assert knobbed["slo_curve_quorum"] == [0.0] * 4
    # and the two rows key differently under the schema (the knobs are
    # part of the row identity, so a knobbed rerun can't shadow a
    # baseline row)
    assert schema.row_key(deg) != schema.row_key(knobbed)
