"""Appendix A: each Replica-Write guard condition is necessary.

Each example replays the paper's schedule twice: with the full protocol the
delayed/stale write is rejected; with exactly one condition disabled it is
accepted — producing the safety violation the paper describes (a version
the serving leader never observed becomes durable/'replicated').
"""
import pytest

from repro.core.messages import DupResReply, DupResReq, ReplicaWrite
from repro.core.simulator import LarkSim


def _deliver_dupres(sim, rounds=3):
    for _ in range(rounds):
        for m in sim.net.pop_matching(
                lambda m: isinstance(m, (DupResReq, DupResReply))):
            sim.deliver(m)


def example1(disable=()):
    """RF=2, N1..N3 (here 0..2): delayed write accepted unless
    LeaderInCluster."""
    sim = LarkSim(num_nodes=3, rf=2, num_partitions=1,
                  disable_conditions=disable)
    sim.set_succession(0, [0, 1, 2])
    sim.recluster(); sim.settle(); sim.run_migrations()
    sim.fail_node(1); sim.settle()      # cluster {0 (full), 2}
    sim.client_write(0, "k", "V")
    held = sim.net.pop_matching(
        lambda m: isinstance(m, ReplicaWrite) and m.dst == 2)
    assert held
    sim.settle()
    sim.recover_node(1, recluster=False)
    sim.fail_node(0, recluster=False)
    sim.recluster(); sim.settle()       # cluster {1, 2}; 1 becomes leader
    assert sim.leader_of(0) == 1
    sim.client_write(0, "k", "VP")      # dup-res first
    _deliver_dupres(sim)
    for m in held:                      # delayed write for V arrives
        sim.deliver(m)
    sim.settle()
    return [e for e in sim.nodes[2].accept_log if e[2] == "V"]


def test_example1_leader_in_cluster():
    assert example1() == []
    bad = example1(disable=("LeaderInCluster",))
    assert bad and bad[0][3] == "replicated"


def example2(disable=()):
    """Example 2 (LeaderNotTooOld), condition-matrix form.

    Note (also DESIGN.md §9): replaying the paper's Example-2 schedule
    literally, the delayed write is *accepted via SameLeaderRegime* — the
    stale replica's LR still carries the old leader's election regime
    because leader retention propagates LR unchanged, so LRM == LR.
    LeaderNotTooOld binds when the leader's election era HAS advanced in the
    replica's view (e.g. an acting-leader re-election bumps LR to the new
    PR) while the replica itself lags one regime.  We construct exactly that
    state and check the condition matrix of Algorithm 3.
    """
    from repro.core.node import LarkNode
    from repro.core.succession import succession_list
    succ = {0: [0, 1, 2, 3, 4]}
    n2 = LarkNode(2, [0, 1, 2, 3, 4], succ, rf=3,
                  disable_conditions=disable)
    # node2's durable state: rebalanced at regime 2 where node0 was
    # *re-elected* (acting leader after slipping out of the replica set),
    # so LR was set to the new PR (= 2).  node2 has since seen ER = 3
    # (clustering updated the exchange number, rebalance deferred).
    st = n2.p[0]
    st.pr = 2
    st.lr = 2
    st.leader = 0
    st.nodes_in_cluster = frozenset({0, 1, 2, 3, 4})
    st.is_replica = True
    st.available = True
    n2.er = 3
    # delayed write from node0's FIRST leadership era: RR = 1, LRM = 1
    msg = ReplicaWrite(src=0, dst=2, op_id=99, partition=0, key="k",
                       leader=0, rr=1, lc=(1, 0), lrm=1, value="V")
    n2.handle(msg)
    return [e for e in n2.accept_log if e[2] == "V"]


def test_example2_leader_not_too_old():
    # all conditions on: RR+1 = 2 < ER = 3 and LRM(1) != LR(2) -> rejected
    assert example2() == []
    # disabling LeaderNotTooOld lets the two-regime-old write through
    bad = example2(disable=("LeaderNotTooOld",))
    assert bad


def example3(disable=()):
    """RF=3: a node that lags regimes (LeaderNotTooNew) must not accept."""
    sim = LarkSim(num_nodes=5, rf=3, num_partitions=1,
                  disable_conditions=disable)
    sim.set_succession(0, [0, 1, 2, 3, 4])
    sim.recluster(); sim.settle(); sim.run_migrations()    # regime 1
    # regime 2: {1, 2, 3}: N0, N4 down; node2 defers rebalance (PR stays 1)
    sim.fail_node(0, recluster=False)
    sim.fail_node(4, recluster=False)
    sim.recluster(defer_rebalance=[2]); sim.settle()
    assert sim.leader_of(0) == 1
    sim.client_write(0, "k", "V")       # node1's write; to node2 delayed
    held = sim.net.pop_matching(
        lambda m: isinstance(m, ReplicaWrite) and m.dst == 2)
    sim.settle()
    # regime 3: {0, 2, 4}: node2 still not rebalanced (PR=1, ER=3)
    sim.recover_node(0, recluster=False)
    sim.recover_node(4, recluster=False)
    sim.fail_node(1, recluster=False)
    sim.fail_node(3, recluster=False)
    sim.recluster(defer_rebalance=[2]); sim.settle()
    assert sim.leader_of(0) == 0
    sim.client_write(0, "k", "VP")
    _deliver_dupres(sim)
    for m in held:
        sim.deliver(m)
    sim.settle()
    return [e for e in sim.nodes[2].accept_log if e[2] == "V"]


def test_example3_leader_not_too_new():
    assert example3() == []
    bad = example3(disable=("LeaderNotTooNew",))
    assert bad


def example4(disable=()):
    """RF=2, N1..N4 (0..3): a non-replica must not accept (NodeInReplicaSet),
    else it silently holds data nobody will dup-res."""
    sim = LarkSim(num_nodes=4, rf=2, num_partitions=1,
                  disable_conditions=disable)
    sim.set_succession(0, [0, 1, 2, 3])
    sim.recluster(); sim.settle(); sim.run_migrations()    # regime 1: {0,1} reps
    # regime 2: {0, 3}: node3 defers rebalance (PR=1, ER=2): NOT a replica
    # in its own regime-1 view ({0,1,2,3} -> replicas {0,1})
    sim.fail_node(1, recluster=False)
    sim.fail_node(2, recluster=False)
    sim.recluster(defer_rebalance=[3]); sim.settle()
    sim.client_write(0, "k", "V")
    sim.settle()
    return [e for e in sim.nodes[3].accept_log if e[2] == "V"]


def test_example4_node_in_replica_set():
    assert example4() == []
    bad = example4(disable=("NodeInReplicaSet",))
    assert bad


# ---------------------------------------------------------------------------
# protocol-zoo engine predicates: same necessity discipline at the Monte
# Carlo level.  The conformance matrix (tests/test_conformance.py) proves
# the engines are bit-identical across backends — it cannot prove a
# transition predicate is load-bearing, because a dead disjunct is
# identically dead everywhere.  Flipping exactly one predicate off must
# move at least one gated output at smoke scale.
# ---------------------------------------------------------------------------

_ZOO_KW = dict(n=13, partitions=32, rf=3, p=5e-3, trials=3,
               max_ticks=4_000, min_ticks=10**9, chunk_steps=32,
               max_steps=400, seed=7, backend="numpy",
               rebuild_model="reconfig", lease_ticks=40,
               view_change_ticks=500)


def _zoo_run(disable=()):
    from repro.core.downtime_batched import (ENGINES,
                                             simulate_downtime_batched)
    return simulate_downtime_batched(engines=ENGINES,
                                     _disable_predicates=disable, **_ZOO_KW)


def _zoo_outputs(r):
    return {
        "pause_lark": r.pause_lark, "pause_quorum": r.pause_quorum,
        "pause_hermes": r.pause_hermes,
        "pause_spinnaker": r.pause_spinnaker,
        "hermes_events": r.hermes_events,
        "spinnaker_events": r.spinnaker_events,
    }


def test_disable_predicates_cover_every_zoo_transition():
    from repro.core.downtime_batched import DISABLE_PREDICATES
    assert set(DISABLE_PREDICATES) == {
        "lease-expiry", "view-change-trigger", "roster-recruit"}


@pytest.mark.parametrize("predicate", ["lease-expiry",
                                       "view-change-trigger",
                                       "roster-recruit"])
def test_zoo_predicate_is_load_bearing(predicate):
    base = _zoo_outputs(_zoo_run())
    flipped = _zoo_outputs(_zoo_run(disable=(predicate,)))
    assert flipped != base, (predicate, base)


def test_lease_expiry_pins_hermes_not_the_others():
    """The lease knob is hermes-local: disabling expiry freezes the
    write-block window open (pause inflates), while every other engine's
    outputs stay bitwise put — the knob can't leak across engines."""
    base = _zoo_run()
    flipped = _zoo_run(disable=("lease-expiry",))
    assert flipped.pause_hermes > base.pause_hermes
    assert flipped.pause_lark == base.pause_lark
    assert flipped.pause_quorum == base.pause_quorum
    assert flipped.pause_spinnaker == base.pause_spinnaker


def test_view_change_trigger_pins_spinnaker_not_the_others():
    base = _zoo_run()
    flipped = _zoo_run(disable=("view-change-trigger",))
    assert flipped.pause_spinnaker < base.pause_spinnaker
    assert flipped.pause_lark == base.pause_lark
    assert flipped.pause_quorum == base.pause_quorum
    assert flipped.pause_hermes == base.pause_hermes
