"""Zero-downtime rolling restart at RF=2 (paper §1, SuperMajority).

Restart every node of a 5-node cluster one at a time.  Under SuperMajority
(fewer than RF=2 roster nodes missing) every partition stays available
throughout: when one original replica reboots, the other serves with an
interim second copy; on return, only accrued deltas flow back (the interim
accepted only new updates).  Writes continue during every phase.

Run:  PYTHONPATH=src python examples/rolling_restart.py
"""
from repro.core.simulator import LarkSim
from repro.core.linearizability import check_history

NODES, RF, PARTS = 5, 2, 8

sim = LarkSim(num_nodes=NODES, rf=RF, num_partitions=PARTS)
sim.recluster(); sim.settle(); sim.run_migrations()

writes = 0
unavailable_any = 0
for victim in range(NODES):
    sim.fail_node(victim)
    sim.settle(); sim.run_migrations()
    avail = sum(1 for p in range(PARTS) if sim.leader_of(p) is not None)
    unavailable_any += PARTS - avail
    # keep writing during the restart window
    for p in range(PARTS):
        op = sim.client_write(p, f"key-{p}", f"v{victim}-{p}")
        sim.settle()
        writes += 1 if sim.result(op).ok else 0
    sim.recover_node(victim)
    sim.settle(); sim.run_migrations()
    print(f"restarted node {victim}: partitions available during window: "
          f"{avail}/{PARTS}, regime {sim.er_counter}")

reads_ok = 0
for p in range(PARTS):
    op = sim.client_read(p, f"key-{p}")
    sim.settle()
    r = sim.result(op)
    reads_ok += 1 if (r.ok and r.value == f"v{NODES-1}-{p}") else 0

print(f"\nwrites committed during restarts: {writes}/{NODES*PARTS}")
print(f"final reads correct: {reads_ok}/{PARTS}")
print(f"partition-unavailability events: {unavailable_any} (expect 0)")
print("linearizable:", all(check_history(sim.finalize_history()).values()))
