"""Quickstart: the LARK protocol + the training stack in ~60 lines.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core.simulator import LarkSim
from repro.core.linearizability import check_history
from repro.configs import reduced_config
from repro.configs.base import ShapeConfig
from repro.data import SyntheticLMData
from repro.training import make_train_step

# --- 1. The paper's protocol: linearizable KV over a 5-node cluster -------
sim = LarkSim(num_nodes=5, rf=2, num_partitions=4)
sim.recluster(); sim.settle(); sim.run_migrations()

pid = 0
print("leader of partition 0:", sim.leader_of(pid))
w = sim.client_write(pid, "bank-balance", 100); sim.settle()
leader = sim.leader_of(pid)
sim.fail_node(leader)                 # leader dies
sim.settle(); sim.run_migrations()    # PAC keeps the partition available
print("new leader:", sim.leader_of(pid), "(regime", sim.er_counter, ")")
w2 = sim.client_write(pid, "bank-balance", 250); sim.settle()
r = sim.client_read(pid, "bank-balance"); sim.settle()
print("read after failover:", sim.result(r).value)
print("linearizable:", check_history(sim.finalize_history()))

# --- 2. The training stack: a tiny LM trained for a few steps -------------
cfg = reduced_config("smollm_360m")
data = SyntheticLMData(cfg, batch=4, seq=64)
init_fn, step_fn, _ = make_train_step(cfg, peak_lr=3e-3)
params, opt_state = init_fn(jax.random.PRNGKey(0))
step = jax.jit(step_fn, donate_argnums=(0, 1))
for i in range(5):
    batch = jax.tree.map(jnp.asarray, data.batch_at(i))
    params, opt_state, m = step(params, opt_state, batch)
    print(f"step {i}: loss {float(m['loss']):.4f}")
