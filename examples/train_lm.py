"""End-to-end training driver: train a small LM for a few hundred steps with
LARK-replicated checkpointing and a mid-run worker failure.

Default is a ~8M-param llama-family model (CPU-sized; pass --big for a
~110M config if you have time/cores — the code path is identical, and the
full 360M+ configs run through repro.launch.dryrun on the production mesh).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--big]
"""
import argparse

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--big", action="store_true")
    args = ap.parse_args()
    arch = "smollm_360m"
    argv = ["--arch", arch, "--reduced", "--steps", str(args.steps),
            "--batch", "8", "--seq", "128", "--lr", "3e-3",
            "--fail-worker-at", str(args.steps // 2),
            "--recover-worker-at", str(args.steps // 2 + 20)]
    if args.big:
        argv += ["--batch", "4"]
    metrics = train_main(argv)
    first, last = metrics[0]["loss"], metrics[-1]["loss"]
    print(f"loss {first:.3f} -> {last:.3f} ({'OK' if last < first else 'WARN'})")
