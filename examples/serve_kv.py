"""Serving example: batched decode with LARK-replicated session state.

A decode session survives the failure of the server holding it: the session
store (the paper's protocol) fails over per-key with a dup-res round trip,
and generation resumes from the last committed decode state.

Run:  PYTHONPATH=src python examples/serve_kv.py
"""
from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    serve_main(["--arch", "smollm_360m", "--fail-server"])
