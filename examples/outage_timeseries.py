"""Reproduce the paper's §5.2 single-outage timeline and plot the
throughput/backfill time-series for one table row (ASCII plot).

Also runs the same failure scenario against the framework's checkpoint
stores: LARK keeps committing while the quorum-log baseline pauses for its
hydration window — the training-stack analogue of Tables 3-4.

Finishes with a §5.1-style partition-unavailability timeline from the
batched Monte Carlo (core/availability_batched.py): a rolling restart
cycling through a small cluster, LARK vs the quorum baseline, rendered
from one trial's event trajectory.

Run:  PYTHONPATH=src python examples/outage_timeseries.py
"""
import numpy as np

from repro.core.availability_batched import simulate_availability_batched
from repro.core.microsim import MicroConfig, run_table, RECOVER_T, FAIL_T
from repro.checkpoint import LarkStore, QuorumLogStore

cfg = MicroConfig(rs=1e3, ps=1e9, bw=5e6, u=0.5, lf=0.5)
print(f"row: rs=1KB ps=1GB bw=5MB/s u=0.5 lf=0.5 (Table 3 row 3)")
res = run_table([cfg], ticks=520_000)[0]
print(f"LARK {res['lark']['throughput']:.0f} ops/s vs BASE "
      f"{res['base']['throughput']:.0f} ops/s (ratio {res['throughput_ratio']:.2f}); "
      f"backfill {res['lark_backfill_s']:.0f}s, baseline down {res['base_down_s']:.0f}s")

# ASCII throughput time-series (1s buckets)
for name, ts in (("LARK", res["lark_ts"]), ("BASE", res["base_ts"])):
    per_s = ts[:520_000].reshape(-1, 1000).sum(1)
    buckets = per_s.reshape(-1, 20).mean(1)  # 20s buckets
    peak = buckets.max()
    bars = "".join("#" if b > 0.9 * peak else ("+" if b > 0.1 * peak else ".")
                   for b in buckets)
    print(f"{name:5s} |{bars}| 0..520s  (fail@2s recover@302s)")

# Training-stack analogue: checkpoint commit availability through an outage
lark = LarkStore(num_nodes=4, rf=2, num_partitions=32)
base = QuorumLogStore(num_nodes=4, rf=2, num_partitions=32,
                      partition_bytes=1e9, bandwidth=5e6)
lark_ok = base_ok = 0
N_STEPS = 60
for step in range(N_STEPS):
    if step == 10:
        lark.fail_node(3)
        base.fail_node(3)
    if step == 40:
        lark.recover_node(3)
        base.recover_node(3)
    base.advance(10.0)  # 10s per "step"
    k = f"ckpt/step{step}"
    lark_ok += lark.put(k, step)
    base_ok += base.put(k, step)
print(f"\ncheckpoint commits during outage run: LARK {lark_ok}/{N_STEPS}, "
      f"quorum-log baseline {base_ok}/{N_STEPS}")

# §5.1 batched-MC analogue: rolling restart over a small cluster, rendered
# from the per-event trajectory of trial 0 (numpy backend: no jit warmup).
HORIZON = 40_000
res = simulate_availability_batched(
    n=12, partitions=64, rf=2, p=5e-4, trials=2, max_ticks=HORIZON,
    min_ticks=HORIZON, restart_period=1_500, backend="numpy",
    chunk_steps=128, trajectory=True)
traj = res.trajectory
t = traj["times"][:, 0]
buckets = 64
print(f"\nrolling restart MC (n=12 rf=2 P=64, restart every 1500 ticks): "
      f"u_lark={res.u_lark:.2e} u_maj={res.u_maj:.2e}")
for name, series in (("LARK", traj["unavail_lark"][:, 0]),
                     ("MAJ", traj["unavail_maj"][:, 0])):
    # max unavailable partitions per time bucket: events inside the bucket,
    # plus the step-function value held entering it (an outage spanning a
    # bucket boundary must render in both buckets)
    per_bucket = np.zeros(buckets)
    idx = np.minimum((t * buckets) // HORIZON, buckets - 1)
    np.maximum.at(per_bucket, idx, series)
    edges = np.arange(buckets) * (HORIZON // buckets)
    enter_idx = np.searchsorted(t, edges, side="right") - 1
    entering = np.where(enter_idx >= 0, series[np.maximum(enter_idx, 0)], 0)
    per_bucket = np.maximum(per_bucket, entering)
    bars = "".join("#" if b >= 4 else ("+" if b > 0 else ".")
                   for b in per_bucket)
    print(f"{name:5s}|{bars}| 0..{HORIZON} ticks  "
          f"(peak {int(per_bucket.max())} partitions down)")
