"""Reproduce the paper's §5.2 single-outage timeline and plot the
throughput/backfill time-series for one table row (ASCII plot).

Also runs the same failure scenario against the framework's checkpoint
stores: LARK keeps committing while the quorum-log baseline pauses for its
hydration window — the training-stack analogue of Tables 3-4.

Run:  PYTHONPATH=src python examples/outage_timeseries.py
"""
import numpy as np

from repro.core.microsim import MicroConfig, run_table, RECOVER_T, FAIL_T
from repro.checkpoint import LarkStore, QuorumLogStore

cfg = MicroConfig(rs=1e3, ps=1e9, bw=5e6, u=0.5, lf=0.5)
print(f"row: rs=1KB ps=1GB bw=5MB/s u=0.5 lf=0.5 (Table 3 row 3)")
res = run_table([cfg], ticks=520_000)[0]
print(f"LARK {res['lark']['throughput']:.0f} ops/s vs BASE "
      f"{res['base']['throughput']:.0f} ops/s (ratio {res['throughput_ratio']:.2f}); "
      f"backfill {res['lark_backfill_s']:.0f}s, baseline down {res['base_down_s']:.0f}s")

# ASCII throughput time-series (1s buckets)
for name, ts in (("LARK", res["lark_ts"]), ("BASE", res["base_ts"])):
    per_s = ts[:520_000].reshape(-1, 1000).sum(1)
    buckets = per_s.reshape(-1, 20).mean(1)  # 20s buckets
    peak = buckets.max()
    bars = "".join("#" if b > 0.9 * peak else ("+" if b > 0.1 * peak else ".")
                   for b in buckets)
    print(f"{name:5s} |{bars}| 0..520s  (fail@2s recover@302s)")

# Training-stack analogue: checkpoint commit availability through an outage
lark = LarkStore(num_nodes=4, rf=2, num_partitions=32)
base = QuorumLogStore(num_nodes=4, rf=2, num_partitions=32,
                      partition_bytes=1e9, bandwidth=5e6)
lark_ok = base_ok = 0
N_STEPS = 60
for step in range(N_STEPS):
    if step == 10:
        lark.fail_node(3)
        base.fail_node(3)
    if step == 40:
        lark.recover_node(3)
        base.recover_node(3)
    base.advance(10.0)  # 10s per "step"
    k = f"ckpt/step{step}"
    lark_ok += lark.put(k, step)
    base_ok += base.put(k, step)
print(f"\ncheckpoint commits during outage run: LARK {lark_ok}/{N_STEPS}, "
      f"quorum-log baseline {base_ok}/{N_STEPS}")
