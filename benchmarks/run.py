"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  * heartbeat_crossover  — §4.1 footnote 6 (n* ≈ 157)
  * availability         — §5.1 Fig 6 / Table 2 (reduced grid; --full for
                           the paper's n=155, P=4096 sweep)
  * microsim_t3/t4       — §5.2 Tables 3 and 4 (all 24 cells)
  * kernel_*             — Pallas-oracle micro-timings
  * roofline             — per (arch x shape) terms from the dry-run

The same argv goes to every suite, but each suite parses it with
``strict=False`` (parse_known_args), so suite-specific flags like the
sweep's --backend/--trials/--devices pass harmlessly through the suites
that don't know them.  A flag *no* suite recognizes is a typo, not a
pass-through: every suite publishes its option strings via
``cli_options()``, and a token outside the union gets a loud warning
naming the nearest valid flag — or, under $CI (or a --config run, where
a silently-dropped override would corrupt a pinned experiment), a hard
error.  Run a suite standalone to get strict parsing back.

``STANDALONE_TOOLS`` names the benchmarks/ modules that are deliberately
NOT suites: they parse their own argv strictly, emit no ``name,us,...``
CSV, and must be invoked directly (``python -m benchmarks.<tool>``) —
running them under the shared argv would either crash on the sweep's
flags or silently ignore their own.  The exclusion is explicit (and
pinned by tests/test_experiments.py) so a tool documented in
docs/BENCHMARKS.md is always either in the suites tuple or in this list.
"""
from __future__ import annotations

import difflib
import importlib
import os
import sys
import time

#: the run.py suites, in execution order — every one parses the shared
#: argv with strict=False and emits ``name,us,...`` CSV rows
SUITE_NAMES = ("heartbeat_crossover", "kernel_bench",
               "availability_sweep", "microsim_tables", "roofline")

#: benchmarks/ modules that are standalone CLIs, not run.py suites — see
#: the module docstring.  perf_probe re-lowers single cells under
#: config/sharding variants (strict own argv, sets XLA_FLAGS at import);
#: make_experiments_md regenerates EXPERIMENTS.md from committed dry-run
#: artifacts (no flags at all).
STANDALONE_TOOLS = ("perf_probe", "make_experiments_md",
                    "check_regression")


def _unknown_flags(argv, suites):
    """argv tokens that look like flags but appear in no suite's
    cli_options() — each as (token, suggestion-or-None)."""
    known = set()
    for suite in suites:
        known.update(suite.cli_options())
    known.update(("-h", "--help"))
    unknown = []
    for tok in argv:
        if not tok.startswith("-") or tok == "-":
            continue
        flag = tok.split("=", 1)[0]
        if flag in known:
            continue
        close = difflib.get_close_matches(flag, sorted(known), n=1)
        unknown.append((flag, close[0] if close else None))
    return unknown


def main() -> int:
    argv = sys.argv[1:]
    # `python benchmarks/run.py` puts benchmarks/ (not the repo root) on
    # sys.path; add the root so the package import below works either way
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if root not in sys.path:
        sys.path.insert(0, root)
    suites = tuple(importlib.import_module(f"benchmarks.{name}")
                   for name in SUITE_NAMES)
    unknown = _unknown_flags(argv, suites)
    if unknown:
        for flag, close in unknown:
            hint = f" (did you mean {close!r}?)" if close else ""
            print(f"run.py: warning: no benchmark suite recognizes "
                  f"{flag!r}{hint} — it would be silently dropped",
                  file=sys.stderr)
        if os.environ.get("CI") or "--config" in {t.split("=", 1)[0]
                                                  for t in argv}:
            print("run.py: error: refusing to run with unrecognized "
                  "flags (CI/spec mode)", file=sys.stderr)
            return 2

    t0 = time.time()
    for suite in suites:
        suite.main(argv, strict=False)
    print(f"benchmarks_total,all,{(time.time()-t0)*1e6:.0f},seconds="
          f"{time.time()-t0:.1f}")
    return 0


if __name__ == '__main__':
    sys.exit(main())
