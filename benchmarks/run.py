"""Benchmark driver — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  * heartbeat_crossover  — §4.1 footnote 6 (n* ≈ 157)
  * availability         — §5.1 Fig 6 / Table 2 (reduced grid; --full for
                           the paper's n=155, P=4096 sweep)
  * microsim_t3/t4       — §5.2 Tables 3 and 4 (all 24 cells)
  * kernel_*             — Pallas-oracle micro-timings
  * roofline             — per (arch x shape) terms from the dry-run

The same argv goes to every suite, but each suite parses it with
``strict=False`` (parse_known_args), so suite-specific flags like the
sweep's --backend/--trials/--devices pass harmlessly through the suites
that don't know them.  Run a suite standalone to get strict parsing back
(unknown flags fail loudly there).
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    argv = sys.argv[1:]
    from benchmarks import (availability_sweep, heartbeat_crossover,
                            kernel_bench, microsim_tables, roofline)

    t0 = time.time()
    for suite in (heartbeat_crossover, kernel_bench, availability_sweep,
                  microsim_tables, roofline):
        suite.main(argv, strict=False)
    print(f"benchmarks_total,all,{(time.time()-t0)*1e6:.0f},seconds="
          f"{time.time()-t0:.1f}")


if __name__ == '__main__':
    main()
