"""Gate a sweep run against a committed baseline (nightly CI regression).

Compares an ``availability_sweep.py --json`` dump row-by-row with a
baseline produced by the same command and exits 1 when any shared row's
gated columns (u_lark/u_maj for availability rows, pause_lark /
pause_quorum for --metric downtime rows, lat_lark/lat_quorum for
--metric latency rows) drift more than --sigma combined standard errors
(CI half-widths are 95% → se = ci/1.96).  Row identity and the gated
column pairs come from one declarative table shared with the experiment
layer that produces the rows — ``repro.experiments.schema`` — so the
producer and the gate can never disagree about what a row *is*:
downtime rows are keyed by rebuild_model and the size/bandwidth knobs,
protocol-zoo engine rows by their explicit ``engine`` plus the zoo
knobs, latency rows by the workload knobs
(read_frac/key_zipf/slo_ticks/requests_per_tick/dupres_ticks) — the
same trajectories under a different knob set are a different
measurement, not drift.

Loads are strict RFC JSON (``Infinity``/``NaN`` tokens are rejected);
a null gated value (a serialized non-finite) skips that column's gate
with a note.  Provenance-stamped dumps (``meta.schema_version`` ≥ 1)
are verified on load: an unknown schema version is an error, the
recorded ``provenance.spec_sha256`` must match the embedded
``meta.spec``, and when the recorded config file still exists on disk
its sha256 must match ``provenance.config_sha256`` (an edited config
with a stale artifact fails loudly).  Pre-provenance dumps (the PR-1..8
baselines, no ``schema_version``) still load, with a deprecation note
asking for a regen.

--identical swaps the sigma gate for a byte-identity gate: every row
must serialize to exactly the same JSON as its baseline row, in the
same order.  This is the CI reproducibility lane's check that a
committed ``benchmarks/configs/*.toml`` regenerates its BENCH baseline
row for row (the Monte Carlo draws counter-based randomness, so an
unchanged tree reproduces the baseline exactly).

--summary-json PATH additionally writes a machine-readable per-column
verdict list (status ok/fail/null-skipped plus new-row/missing-row
entries, each with drift, se, and z-score) — the CI workflow renders it
into the GitHub Actions step summary, and when $GITHUB_STEP_SUMMARY is
set the script appends a markdown table there directly.

Drift within sigma allows for intentional stopping-rule or scenario
retunes; anything beyond it means a semantic change that should come
with a refreshed baseline.  Every committed baseline regenerates from
its experiment config (the flag spellings in docs/BENCHMARKS.md remain
equivalent):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    python benchmarks/availability_sweep.py \
        --config benchmarks/configs/sweep.toml \
        --json benchmarks/BENCH_sweep.json

and likewise downtime.toml, downtime_reconfig.toml, downtime_skew.toml,
latency.toml, shootout.toml → their BENCH_<name>.json.

Fused-megakernel rows (--packed, bit-packed state + the fused pallas
step kernel) are keyed identically to their unpacked counterparts ON
PURPOSE: packing is layout-only, so a --packed run gated against an
unpacked baseline must land at zero drift — the CI fused lane uses this
as its bit-identity gate, and any nonzero drift on a fused row is a
fusion bug, not noise.  Autotune rows (1-D block_p and the fused 2-D
block_t x block_p race) carry kind "autotune" and are never gated.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import math
import os
import sys

try:
    from repro.experiments import schema as _schema
except ImportError:                      # pragma: no cover - path fallback
    # this gate runs before PYTHONPATH=src in some CI lanes; the schema
    # module is stdlib-only, so pulling it straight from the tree is safe
    sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                    os.pardir, "src"))
    from repro.experiments import schema as _schema

_SE_FLOOR = 1e-12   # deterministic RNG: identical runs pass at se == 0

#: shared row-identity/column tables (repro.experiments.schema) — the
#: same objects the runner uses to label its JSONL events
row_key = _schema.row_key
row_cols = _schema.row_cols
_KNOWN_ENGINES = _schema.KNOWN_ENGINES


def compare(new: dict, base: dict, sigma: float):
    """Row-by-row gate.  Returns (failures, notes, checked, records):
    records is the machine-readable per-column verdict list behind
    --summary-json — one entry per gated column of every shared row
    (status "ok"/"fail"/"null-skipped" with drift/se/z), plus one per
    unmatched row ("new-row"/"missing-row")."""
    base_rows = {row_key(r): r for r in base["rows"]
                 if row_key(r) is not None}
    failures, notes, records = [], [], []
    checked = 0
    seen = set()
    for r in new["rows"]:
        k = row_key(r)
        if k is None:
            continue
        seen.add(k)
        b = base_rows.get(k)
        if b is None:
            notes.append(f"new row (not in baseline, skipped): {k}")
            records.append({"key": list(k), "status": "new-row"})
            continue
        checked += 1
        for col, ci_col in row_cols(r):
            if any(v is None for v in (r[col], r[ci_col],
                                       b[col], b[ci_col])):
                # a null is a serialized non-finite (e.g. a ratio over a
                # zero denominator) — there is nothing to gate
                notes.append(f"null {col} (gate skipped): {k}")
                records.append({"key": list(k), "column": col,
                                "status": "null-skipped"})
                continue
            se = max(math.hypot(r[ci_col] / 1.96, b[ci_col] / 1.96),
                     _SE_FLOOR)
            drift = abs(r[col] - b[col])
            z = drift / se
            status = "fail" if drift > sigma * se else "ok"
            records.append({"key": list(k), "column": col,
                            "new": r[col], "baseline": b[col],
                            "drift": drift, "se": se, "z": z,
                            "status": status})
            if status == "fail":
                failures.append(
                    f"{k} {col}: {b[col]:.4e} -> {r[col]:.4e} "
                    f"(drift {drift:.2e} > {sigma:g}*se {sigma * se:.2e})")
    for k in base_rows:
        if k not in seen:
            failures.append(f"baseline row missing from run: {k}")
            records.append({"key": list(k), "status": "missing-row"})
    return failures, notes, checked, records


def compare_identical(new: dict, base: dict):
    """Byte-identity gate: the run's rows must serialize to exactly the
    baseline's rows, same order, same values — the reproducibility
    lane's proof that a config regenerates its committed baseline.
    Returns (failures, checked)."""
    nr, br = new["rows"], base["rows"]
    failures = []
    if len(nr) != len(br):
        failures.append(f"row count differs: run has {len(nr)}, "
                        f"baseline has {len(br)}")
    for i, (a, b) in enumerate(zip(nr, br)):
        ja = json.dumps(a, sort_keys=True, allow_nan=False)
        jb = json.dumps(b, sort_keys=True, allow_nan=False)
        if ja != jb:
            diff_keys = sorted(
                k for k in set(a) | set(b) if a.get(k) != b.get(k))
            failures.append(
                f"row {i} ({row_key(b) or b.get('kind')}) differs in: "
                f"{', '.join(diff_keys)}")
            if len(failures) >= 20:
                failures.append("... (further diffs suppressed)")
                break
    return failures, min(len(nr), len(br))


def summary_markdown(records, sigma: float, checked: int) -> str:
    """GitHub Actions step-summary table: every non-ok verdict in full,
    ok rows as one roll-up line (a green run should read as one line,
    a red one should show exactly what moved)."""
    bad = [c for c in records if c.get("status") != "ok"]
    n_ok = len(records) - len(bad)
    lines = ["### Regression gate",
             f"- gated rows: {checked}; columns ok: {n_ok}; "
             f"flagged: {len(bad)}; sigma: {sigma:g}", ""]
    if bad:
        lines += ["| row | column | baseline | new | z | status |",
                  "|---|---|---|---|---|---|"]
        for c in bad:
            key = " ".join(str(x) for x in c["key"])
            z = f"{c['z']:.2f}" if "z" in c else "—"
            lines.append(f"| {key} | {c.get('column', '—')} "
                         f"| {c.get('baseline', '—')} | {c.get('new', '—')} "
                         f"| {z} | {c['status']} |")
    return "\n".join(lines) + "\n"


def _spec_sha256(spec_mapping: dict) -> str:
    """Recompute ExperimentSpec.content_hash() from an embedded
    ``meta.spec`` mapping without importing the spec layer (this gate
    must stay stdlib-only): the hash is sha256 over the sorted-key
    compact JSON of the identity fields (everything but ``name``)."""
    ident = {k: v for k, v in spec_mapping.items() if k != "name"}
    blob = json.dumps(ident, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode("utf-8")).hexdigest()


def _check_provenance(path: str, meta: dict, notes: list):
    """Validate a dump's schema_version / provenance stamp.  Unknown
    versions and internally-inconsistent stamps raise; a pre-provenance
    dump (no schema_version) only collects a deprecation note."""
    version = meta.get("schema_version")
    if version is None:
        notes.append(
            f"{path}: pre-provenance dump (no meta.schema_version) — "
            "still loadable, but regenerate it from its "
            "benchmarks/configs/ spec to pick up the provenance stamp")
        return
    if version not in _schema.KNOWN_SCHEMA_VERSIONS:
        raise ValueError(
            f"{path}: unknown meta.schema_version {version!r}; this "
            f"checker knows {list(_schema.KNOWN_SCHEMA_VERSIONS)} — "
            "update the tools or regenerate the dump")
    spec = meta.get("spec")
    prov = meta.get("provenance")
    if not isinstance(spec, dict) or not isinstance(prov, dict):
        raise ValueError(
            f"{path}: schema_version {version} dump without the "
            "meta.spec / meta.provenance mappings — regenerate it with "
            "availability_sweep.py --json")
    recorded = prov.get("spec_sha256")
    actual = _spec_sha256(spec)
    if recorded != actual:
        raise ValueError(
            f"{path}: provenance.spec_sha256 {recorded!r} does not match "
            f"the embedded meta.spec (expected {actual!r}) — the dump "
            "was hand-edited or the stamp is stale; regenerate it")
    config_path = prov.get("config_path")
    if config_path and prov.get("config_sha256") \
            and os.path.exists(config_path):
        h = hashlib.sha256()
        with open(config_path, "rb") as fh:
            h.update(fh.read())
        if h.hexdigest() != prov["config_sha256"]:
            raise ValueError(
                f"{path}: config {config_path} changed since this dump "
                "was produced (sha256 mismatch vs "
                "provenance.config_sha256) — regenerate the dump from "
                "the current config")


def load_rows(path: str, notes: list | None = None) -> dict:
    """Strict-RFC JSON load: `Infinity`/`NaN`/`-Infinity` tokens (which
    python's json writes and reads happily, but jq and most parsers
    reject) fail loudly — a current sweep serializes non-finite values as
    null, so their presence means a stale or hand-edited dump.  Also
    validates the provenance stamp (see _check_provenance) and rejects
    engine rows whose engine field is missing or unknown rather than
    letting them silently match the quorum baseline columns."""
    def _reject(token):
        raise ValueError(
            f"{path}: non-finite JSON value {token!r} is not RFC JSON — "
            "regenerate the dump with availability_sweep.py --json "
            "(non-finite ratios serialize as null)")
    with open(path) as fh:
        doc = json.load(fh, parse_constant=_reject)
    collected = notes if notes is not None else []
    _check_provenance(path, doc.get("meta", {}), collected)
    if notes is None:
        for s in collected:
            print(f"note: {s}")
    for r in doc.get("rows", ()):
        if str(r.get("kind", "")).startswith("downtime_engine"):
            engine = r.get("engine")
            if engine is None:
                raise ValueError(
                    f"{path}: downtime_engine row without an 'engine' "
                    f"field (rf={r.get('rf')}, p={r.get('p')}) — the "
                    "engine name is the row key; regenerate the dump")
            if engine not in _KNOWN_ENGINES:
                raise ValueError(
                    f"{path}: unknown engine {engine!r} in a "
                    f"downtime_engine row; known: "
                    f"{', '.join(_KNOWN_ENGINES)}")
    return doc


def main(argv=None, *, strict: bool = True) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("results", help="sweep --json output to check")
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("--sigma", type=float, default=2.0,
                    help="allowed drift in combined standard errors")
    ap.add_argument("--identical", action="store_true",
                    help="require byte-identical rows instead of the "
                         "sigma gate (reproducibility lane)")
    ap.add_argument("--summary-json", metavar="PATH",
                    help="write the per-column verdict list (status / "
                         "drift / z-score) as a JSON artifact")
    args = ap.parse_args(argv if argv is not None else sys.argv[1:])

    notes = []
    new = load_rows(args.results, notes)
    base = load_rows(args.baseline, notes)
    if args.identical:
        failures, checked = compare_identical(new, base)
        records = [{"status": "fail", "key": [], "detail": f}
                   for f in failures]
    else:
        failures, cmp_notes, checked, records = compare(new, base,
                                                        args.sigma)
        notes.extend(cmp_notes)
    if args.summary_json:
        doc = {"sigma": args.sigma, "checked": checked,
               "identical": args.identical,
               "failures": len(failures), "records": records}
        with open(args.summary_json, "w") as fh:
            json.dump(doc, fh, indent=1, sort_keys=True)
    step_summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if step_summary and not args.identical:
        with open(step_summary, "a") as fh:
            fh.write(summary_markdown(records, args.sigma, checked))
    for s in notes:
        print(f"note: {s}")
    if failures:
        if args.identical:
            print(f"NOT IDENTICAL: {len(failures)} difference(s) over "
                  f"{checked} rows")
        else:
            print(f"REGRESSION: {len(failures)} of {checked} gated rows "
                  f"outside {args.sigma:g} sigma")
        for s in failures:
            print(f"  {s}")
        return 1
    if args.identical:
        print(f"ok: {checked} rows byte-identical to baseline")
    else:
        print(f"ok: {checked} rows within {args.sigma:g} sigma of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
