"""Gate a sweep run against a committed baseline (nightly CI regression).

Compares an ``availability_sweep.py --json`` dump row-by-row with a
baseline produced by the same command and exits 1 when any shared row's
gated columns (u_lark/u_maj for availability rows, pause_lark /
pause_quorum for --metric downtime rows, lat_lark/lat_quorum for
--metric latency rows) drift more than --sigma combined standard errors
(CI half-widths are 95% → se = ci/1.96).  Downtime rows are additionally
keyed by rebuild_model, so fixed and reconfig baselines never gate each
other; latency rows are further keyed by the workload knobs
(read_frac/key_zipf/slo_ticks/requests_per_tick/dupres_ticks) — the same
trajectories under a different workload are a different measurement, not
drift.  Loads are strict RFC JSON (``Infinity``/``NaN`` tokens are
rejected); a null gated value (a serialized non-finite) skips that
column's gate with a note.

--summary-json PATH additionally writes a machine-readable per-column
verdict list (status ok/fail/null-skipped plus new-row/missing-row
entries, each with drift, se, and z-score) — the CI workflow renders it
into the GitHub Actions step summary, and when $GITHUB_STEP_SUMMARY is
set the script appends a markdown table there directly.

The Monte Carlo draws counter-based randomness, so an unchanged tree
reproduces the baseline *exactly*; drift within sigma allows for
intentional stopping-rule or scenario retunes, anything beyond it means a
semantic change that should come with a refreshed baseline:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    python benchmarks/availability_sweep.py --backend jax --trials 8 \
        --devices 8 --scenario all --json benchmarks/BENCH_sweep.json

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    python benchmarks/availability_sweep.py --backend jax --trials 8 \
        --devices 8 --metric downtime --smoke --scenario all \
        --json benchmarks/BENCH_downtime.json

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    python benchmarks/availability_sweep.py --backend jax --trials 8 \
        --devices 8 --metric downtime --smoke --rebuild-model reconfig \
        --scenario all --json benchmarks/BENCH_downtime_reconfig.json

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    python benchmarks/availability_sweep.py --backend jax --trials 8 \
        --devices 8 --metric downtime --smoke --rebuild-model reconfig \
        --size-dist zipf --size-skew 1 --node-bandwidth-gibps 1 \
        --scenario all --json benchmarks/BENCH_downtime_skew.json

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    python benchmarks/availability_sweep.py --backend jax --trials 8 \
        --devices 8 --metric latency --smoke --scenario all \
        --json benchmarks/BENCH_latency.json

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    python benchmarks/availability_sweep.py --backend jax --trials 8 \
        --devices 8 --metric downtime --smoke --rebuild-model reconfig \
        --engines lark,quorum,hermes,spinnaker --lease-ticks 40 \
        --view-change-ticks 200 --scenario rolling-restart \
        --json benchmarks/BENCH_shootout.json

Protocol-zoo rows (kind "downtime_engine"/"downtime_engine_scenario",
from --engines hermes/spinnaker) are keyed by their explicit ``engine``
field plus the zoo knobs and gate a single pause/ci_pause column pair;
the loader rejects engine rows whose engine field is missing or unknown
rather than letting them silently match the quorum baseline columns.

Fused-megakernel rows (--packed, bit-packed state + the fused pallas
step kernel) are keyed identically to their unpacked counterparts ON
PURPOSE: packing is layout-only, so a --packed run gated against an
unpacked baseline must land at zero drift — the CI fused lane uses this
as its bit-identity gate, and any nonzero drift on a fused row is a
fusion bug, not noise.  Autotune rows (1-D block_p and the fused 2-D
block_t x block_p race) carry kind "autotune" and are never gated.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import sys

_SE_FLOOR = 1e-12   # deterministic RNG: identical runs pass at se == 0


#: gated value/CI column pairs per row kind ("availability" covers the
#: legacy iid/scenario kinds; "downtime" rows carry pause fractions;
#: "latency" rows carry mean added commit latencies)
_GATED_COLS = {
    "availability": (("u_lark", "ci_lark"), ("u_maj", "ci_maj")),
    "downtime": (("pause_lark", "ci_pause_lark"),
                 ("pause_quorum", "ci_pause_quorum")),
    "downtime_engine": (("pause", "ci_pause"),),
    "latency": (("lat_lark", "ci_lat_lark"),
                ("lat_quorum", "ci_lat_quorum")),
}

#: engine names a "downtime_engine" row may carry — mirrors
#: core.downtime_batched.ENGINES without importing the engine stack
#: (this gate runs before PYTHONPATH=src in some CI lanes)
_KNOWN_ENGINES = ("lark", "quorum", "hermes", "spinnaker")


def row_key(r: dict):
    if r.get("kind") == "scenario":
        return ("scenario", r["scenario"], r["rf"], r["p"])
    if r.get("kind") == "iid":
        return ("iid", r["rf"], r["p"])
    if r.get("kind") in ("downtime_engine", "downtime_engine_scenario"):
        # protocol-zoo rows are keyed by the engine whose pause they
        # measure — without the engine in the key, a hermes row and a
        # spinnaker row at the same grid point would gate each other —
        # plus the zoo knobs (a different lease / view-change window is
        # a different measurement, like the latency workload knobs)
        return ("downtime_engine", r["engine"], r.get("scenario", "iid"),
                r["rf"], r["p"], r.get("rebuild_model", "fixed"),
                r.get("lease_ticks", 0), r.get("view_change_ticks", 0),
                r.get("size_dist", "uniform"), r.get("size_skew", 0.0),
                r.get("node_bandwidth_gibps"))
    if r.get("kind") in ("downtime", "downtime_scenario"):
        # the two quorum-log baselines measure different things; rows from
        # different rebuild models must never be compared (pre-roster
        # baselines carry no rebuild_model field and are all "fixed") —
        # and likewise for the size-distribution / bandwidth knobs (rows
        # predating them are uniform/unshared, matching the defaults; a
        # serialized null bandwidth is the unshared inf)
        return ("downtime", r.get("scenario", "iid"), r["rf"], r["p"],
                r.get("rebuild_model", "fixed"),
                r.get("size_dist", "uniform"), r.get("size_skew", 0.0),
                r.get("node_bandwidth_gibps"))
    if r.get("kind") in ("latency", "latency_scenario"):
        # the workload knobs select the measurement: a different request
        # mix / skew / SLO / cost model is a different row family, never
        # compared against another one's baseline
        return ("latency", r.get("scenario", "iid"), r["rf"], r["p"],
                r.get("rebuild_model", "fixed"),
                r.get("read_frac"), r.get("key_zipf"),
                r.get("slo_ticks"), r.get("requests_per_tick"),
                r.get("dupres_ticks"))
    return None                      # autotune/meta rows are not gated


def row_cols(r: dict):
    kind = r.get("kind", "")
    # engine rows must match before the broader downtime prefix — they
    # carry per-engine pause/ci_pause columns, not the lark/quorum pair
    if kind.startswith("downtime_engine"):
        return _GATED_COLS["downtime_engine"]
    if kind.startswith("downtime"):
        return _GATED_COLS["downtime"]
    if kind.startswith("latency"):
        return _GATED_COLS["latency"]
    return _GATED_COLS["availability"]


def compare(new: dict, base: dict, sigma: float):
    """Row-by-row gate.  Returns (failures, notes, checked, records):
    records is the machine-readable per-column verdict list behind
    --summary-json — one entry per gated column of every shared row
    (status "ok"/"fail"/"null-skipped" with drift/se/z), plus one per
    unmatched row ("new-row"/"missing-row")."""
    base_rows = {row_key(r): r for r in base["rows"]
                 if row_key(r) is not None}
    failures, notes, records = [], [], []
    checked = 0
    seen = set()
    for r in new["rows"]:
        k = row_key(r)
        if k is None:
            continue
        seen.add(k)
        b = base_rows.get(k)
        if b is None:
            notes.append(f"new row (not in baseline, skipped): {k}")
            records.append({"key": list(k), "status": "new-row"})
            continue
        checked += 1
        for col, ci_col in row_cols(r):
            if any(v is None for v in (r[col], r[ci_col],
                                       b[col], b[ci_col])):
                # a null is a serialized non-finite (e.g. a ratio over a
                # zero denominator) — there is nothing to gate
                notes.append(f"null {col} (gate skipped): {k}")
                records.append({"key": list(k), "column": col,
                                "status": "null-skipped"})
                continue
            se = max(math.hypot(r[ci_col] / 1.96, b[ci_col] / 1.96),
                     _SE_FLOOR)
            drift = abs(r[col] - b[col])
            z = drift / se
            status = "fail" if drift > sigma * se else "ok"
            records.append({"key": list(k), "column": col,
                            "new": r[col], "baseline": b[col],
                            "drift": drift, "se": se, "z": z,
                            "status": status})
            if status == "fail":
                failures.append(
                    f"{k} {col}: {b[col]:.4e} -> {r[col]:.4e} "
                    f"(drift {drift:.2e} > {sigma:g}*se {sigma * se:.2e})")
    for k in base_rows:
        if k not in seen:
            failures.append(f"baseline row missing from run: {k}")
            records.append({"key": list(k), "status": "missing-row"})
    return failures, notes, checked, records


def summary_markdown(records, sigma: float, checked: int) -> str:
    """GitHub Actions step-summary table: every non-ok verdict in full,
    ok rows as one roll-up line (a green run should read as one line,
    a red one should show exactly what moved)."""
    bad = [c for c in records if c.get("status") != "ok"]
    n_ok = len(records) - len(bad)
    lines = ["### Regression gate",
             f"- gated rows: {checked}; columns ok: {n_ok}; "
             f"flagged: {len(bad)}; sigma: {sigma:g}", ""]
    if bad:
        lines += ["| row | column | baseline | new | z | status |",
                  "|---|---|---|---|---|---|"]
        for c in bad:
            key = " ".join(str(x) for x in c["key"])
            z = f"{c['z']:.2f}" if "z" in c else "—"
            lines.append(f"| {key} | {c.get('column', '—')} "
                         f"| {c.get('baseline', '—')} | {c.get('new', '—')} "
                         f"| {z} | {c['status']} |")
    return "\n".join(lines) + "\n"


def load_rows(path: str) -> dict:
    """Strict-RFC JSON load: `Infinity`/`NaN`/`-Infinity` tokens (which
    python's json writes and reads happily, but jq and most parsers
    reject) fail loudly — a current sweep serializes non-finite values as
    null, so their presence means a stale or hand-edited dump."""
    def _reject(token):
        raise ValueError(
            f"{path}: non-finite JSON value {token!r} is not RFC JSON — "
            "regenerate the dump with availability_sweep.py --json "
            "(non-finite ratios serialize as null)")
    with open(path) as fh:
        doc = json.load(fh, parse_constant=_reject)
    for r in doc.get("rows", ()):
        if str(r.get("kind", "")).startswith("downtime_engine"):
            engine = r.get("engine")
            if engine is None:
                raise ValueError(
                    f"{path}: downtime_engine row without an 'engine' "
                    f"field (rf={r.get('rf')}, p={r.get('p')}) — the "
                    "engine name is the row key; regenerate the dump")
            if engine not in _KNOWN_ENGINES:
                raise ValueError(
                    f"{path}: unknown engine {engine!r} in a "
                    f"downtime_engine row; known: "
                    f"{', '.join(_KNOWN_ENGINES)}")
    return doc


def main(argv=None, *, strict: bool = True) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("results", help="sweep --json output to check")
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("--sigma", type=float, default=2.0,
                    help="allowed drift in combined standard errors")
    ap.add_argument("--summary-json", metavar="PATH",
                    help="write the per-column verdict list (status / "
                         "drift / z-score) as a JSON artifact")
    args = ap.parse_args(argv if argv is not None else sys.argv[1:])

    new = load_rows(args.results)
    base = load_rows(args.baseline)
    failures, notes, checked, records = compare(new, base, args.sigma)
    if args.summary_json:
        doc = {"sigma": args.sigma, "checked": checked,
               "failures": len(failures), "records": records}
        with open(args.summary_json, "w") as fh:
            json.dump(doc, fh, indent=1, sort_keys=True)
    step_summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if step_summary:
        with open(step_summary, "a") as fh:
            fh.write(summary_markdown(records, args.sigma, checked))
    for s in notes:
        print(f"note: {s}")
    if failures:
        print(f"REGRESSION: {len(failures)} of {checked} gated rows "
              f"outside {args.sigma:g} sigma")
        for s in failures:
            print(f"  {s}")
        return 1
    print(f"ok: {checked} rows within {args.sigma:g} sigma of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
