"""Gate a sweep run against a committed baseline (nightly CI regression).

Compares an ``availability_sweep.py --json`` dump row-by-row with a
baseline produced by the same command and exits 1 when any shared row's
gated columns (u_lark/u_maj for availability rows, pause_lark /
pause_quorum for --metric downtime rows) drift more than --sigma combined
standard errors (CI half-widths are 95% → se = ci/1.96).  Downtime rows
are additionally keyed by rebuild_model, so fixed and reconfig baselines
never gate each other.  Loads are strict RFC JSON (``Infinity``/``NaN``
tokens are rejected); a null gated value (a serialized non-finite) skips
that column's gate with a note.

The Monte Carlo draws counter-based randomness, so an unchanged tree
reproduces the baseline *exactly*; drift within sigma allows for
intentional stopping-rule or scenario retunes, anything beyond it means a
semantic change that should come with a refreshed baseline:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    python benchmarks/availability_sweep.py --backend jax --trials 8 \
        --devices 8 --scenario all --json benchmarks/BENCH_sweep.json

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    python benchmarks/availability_sweep.py --backend jax --trials 8 \
        --devices 8 --metric downtime --smoke --scenario all \
        --json benchmarks/BENCH_downtime.json

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    python benchmarks/availability_sweep.py --backend jax --trials 8 \
        --devices 8 --metric downtime --smoke --rebuild-model reconfig \
        --scenario all --json benchmarks/BENCH_downtime_reconfig.json

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    python benchmarks/availability_sweep.py --backend jax --trials 8 \
        --devices 8 --metric downtime --smoke --rebuild-model reconfig \
        --size-dist zipf --size-skew 1 --node-bandwidth-gibps 1 \
        --scenario all --json benchmarks/BENCH_downtime_skew.json

Fused-megakernel rows (--packed, bit-packed state + the fused pallas
step kernel) are keyed identically to their unpacked counterparts ON
PURPOSE: packing is layout-only, so a --packed run gated against an
unpacked baseline must land at zero drift — the CI fused lane uses this
as its bit-identity gate, and any nonzero drift on a fused row is a
fusion bug, not noise.  Autotune rows (1-D block_p and the fused 2-D
block_t x block_p race) carry kind "autotune" and are never gated.
"""
from __future__ import annotations

import argparse
import json
import math
import sys

_SE_FLOOR = 1e-12   # deterministic RNG: identical runs pass at se == 0


#: gated value/CI column pairs per row kind ("availability" covers the
#: legacy iid/scenario kinds; "downtime" rows carry pause fractions)
_GATED_COLS = {
    "availability": (("u_lark", "ci_lark"), ("u_maj", "ci_maj")),
    "downtime": (("pause_lark", "ci_pause_lark"),
                 ("pause_quorum", "ci_pause_quorum")),
}


def row_key(r: dict):
    if r.get("kind") == "scenario":
        return ("scenario", r["scenario"], r["rf"], r["p"])
    if r.get("kind") == "iid":
        return ("iid", r["rf"], r["p"])
    if r.get("kind") in ("downtime", "downtime_scenario"):
        # the two quorum-log baselines measure different things; rows from
        # different rebuild models must never be compared (pre-roster
        # baselines carry no rebuild_model field and are all "fixed") —
        # and likewise for the size-distribution / bandwidth knobs (rows
        # predating them are uniform/unshared, matching the defaults; a
        # serialized null bandwidth is the unshared inf)
        return ("downtime", r.get("scenario", "iid"), r["rf"], r["p"],
                r.get("rebuild_model", "fixed"),
                r.get("size_dist", "uniform"), r.get("size_skew", 0.0),
                r.get("node_bandwidth_gibps"))
    return None                      # autotune/meta rows are not gated


def row_cols(r: dict):
    kind = "downtime" if r.get("kind", "").startswith("downtime") \
        else "availability"
    return _GATED_COLS[kind]


def compare(new: dict, base: dict, sigma: float):
    base_rows = {row_key(r): r for r in base["rows"]
                 if row_key(r) is not None}
    failures, notes, checked = [], [], 0
    seen = set()
    for r in new["rows"]:
        k = row_key(r)
        if k is None:
            continue
        seen.add(k)
        b = base_rows.get(k)
        if b is None:
            notes.append(f"new row (not in baseline, skipped): {k}")
            continue
        checked += 1
        for col, ci_col in row_cols(r):
            if any(v is None for v in (r[col], r[ci_col],
                                       b[col], b[ci_col])):
                # a null is a serialized non-finite (e.g. a ratio over a
                # zero denominator) — there is nothing to gate
                notes.append(f"null {col} (gate skipped): {k}")
                continue
            se = max(math.hypot(r[ci_col] / 1.96, b[ci_col] / 1.96),
                     _SE_FLOOR)
            drift = abs(r[col] - b[col])
            if drift > sigma * se:
                failures.append(
                    f"{k} {col}: {b[col]:.4e} -> {r[col]:.4e} "
                    f"(drift {drift:.2e} > {sigma:g}*se {sigma * se:.2e})")
    for k in base_rows:
        if k not in seen:
            failures.append(f"baseline row missing from run: {k}")
    return failures, notes, checked


def load_rows(path: str) -> dict:
    """Strict-RFC JSON load: `Infinity`/`NaN`/`-Infinity` tokens (which
    python's json writes and reads happily, but jq and most parsers
    reject) fail loudly — a current sweep serializes non-finite values as
    null, so their presence means a stale or hand-edited dump."""
    def _reject(token):
        raise ValueError(
            f"{path}: non-finite JSON value {token!r} is not RFC JSON — "
            "regenerate the dump with availability_sweep.py --json "
            "(non-finite ratios serialize as null)")
    with open(path) as fh:
        return json.load(fh, parse_constant=_reject)


def main(argv=None, *, strict: bool = True) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("results", help="sweep --json output to check")
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("--sigma", type=float, default=2.0,
                    help="allowed drift in combined standard errors")
    args = ap.parse_args(argv if argv is not None else sys.argv[1:])

    new = load_rows(args.results)
    base = load_rows(args.baseline)
    failures, notes, checked = compare(new, base, args.sigma)
    for s in notes:
        print(f"note: {s}")
    if failures:
        print(f"REGRESSION: {len(failures)} of {checked} gated rows "
              f"outside {args.sigma:g} sigma")
        for s in failures:
            print(f"  {s}")
        return 1
    print(f"ok: {checked} rows within {args.sigma:g} sigma of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
