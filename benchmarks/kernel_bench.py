"""Kernel micro-benchmarks: µs/call of the jnp oracle paths on CPU (the
Pallas kernels themselves target TPU; interpret mode is not a timing proxy).

--autotune additionally races the Pallas PAC block_p candidates on the
Monte Carlo tile shape (measured on TPU; deterministic heuristic fallback
on CPU, where interpret-mode timings would measure the interpreter).
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.ops import (autotune_block_p, downtime_eval_batch,
                               pac_eval_batch, rebuild_node_counts)


def _time(fn, *args, iters=5) -> float:
    jax.block_until_ready(fn(*args))        # warmup (and compile, if jitted)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def main(argv=None, *, strict: bool = True):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0],
                                 allow_abbrev=False)
    ap.add_argument("--autotune", action="store_true",
                    help="race pallas PAC block_p candidates")
    args, extra = ap.parse_known_args(argv if argv is not None
                                      else sys.argv[1:])
    if strict and extra:
        ap.error(f"unrecognized arguments: {' '.join(extra)}")
    rng = np.random.default_rng(0)
    B, H, S, D = 1, 4, 1024, 64
    q, k, v = (jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
               for _ in range(3))
    att = jax.jit(lambda q, k, v: ref.attention_ref(q, k, v))
    print(f"kernel_attention_ref,b{B}s{S}h{H}d{D},{_time(att, q, k, v):.0f},"
          f"flops={4*B*H*S*S*D:.3g}")

    qh = jnp.transpose(q, (0, 2, 1, 3))
    lf = jnp.asarray(rng.standard_normal((B, H, S)), jnp.float32)
    ml = jax.jit(lambda q, k, v, lf, li: ref.mlstm_chunkwise(q, k, v, lf, li)[0])
    print(f"kernel_mlstm_ref,b{B}s{S}h{H}d{D},"
          f"{_time(ml, qh, qh, qh, lf, lf):.0f},chunk=256")

    x = jnp.asarray(rng.standard_normal((2, 2048, 512)), jnp.float32)
    la = -jnp.asarray(rng.uniform(0.01, 1.0, (2, 2048, 512)), jnp.float32)
    rg = jax.jit(ref.rglru_scan_ref)
    print(f"kernel_rglru_ref,b2s2048w512,{_time(rg, x, la):.0f},assoc_scan")

    up = jnp.asarray(rng.random((4096, 256)) < 0.95)
    full = jnp.asarray(rng.random((4096, 256)) < 0.3)
    pc = jax.jit(lambda u, f: ref.pac_eval_rank_ref(u, f, rf=3, voters=5,
                                                    n_real=155))
    print(f"kernel_pac_ref,p4096n155,{_time(pc, up, full):.0f},per_tick_eval")

    # batched Monte Carlo tile: trials*partitions rows through the unified
    # PAC backend layer (the availability_batched.py hot loop)
    R = 8 * 4096
    up_b = rng.random((R, 256)) < 0.95
    full_b = rng.random((R, 256)) < 0.3
    pac_np = lambda u, f: pac_eval_batch(u, f, rf=3, voters=5, n_real=155,
                                         backend="numpy")
    print(f"kernel_pac_batch_numpy,r{R}n155,"
          f"{_time(pac_np, up_b, full_b):.0f},trials=8xp4096")
    upj, fullj = jnp.asarray(up_b), jnp.asarray(full_b)
    pac_j = jax.jit(lambda u, f: pac_eval_batch(u, f, rf=3, voters=5,
                                                n_real=155, backend="jax"))
    print(f"kernel_pac_batch_jax,r{R}n155,"
          f"{_time(pac_j, upj, fullj):.0f},trials=8xp4096")

    # downtime engine per-step evaluation (PAC + quorum replica set +
    # acting leader) on the same Monte Carlo tile
    dt_np = lambda u, f: downtime_eval_batch(u, f, rf=3, n_real=155,
                                             backend="numpy")
    print(f"kernel_downtime_batch_numpy,r{R}n155,"
          f"{_time(dt_np, up_b, full_b):.0f},trials=8xp4096")
    dt_j = jax.jit(lambda u, f: downtime_eval_batch(u, f, rf=3, n_real=155,
                                                    backend="jax"))
    print(f"kernel_downtime_batch_jax,r{R}n155,"
          f"{_time(dt_j, upj, fullj):.0f},trials=8xp4096")

    # roster-aware variant (the reconfiguring quorum-log baseline carries
    # per-partition replica-set ranks instead of the first-rf lanes)
    roster = jnp.asarray(rng.integers(0, 155, (R, 3)), jnp.int32)
    dt_r = jax.jit(lambda u, f, ro: downtime_eval_batch(
        u, f, rf=3, n_real=155, backend="jax", roster=ro))
    print(f"kernel_downtime_roster_jax,r{R}n155,"
          f"{_time(dt_r, upj, fullj, roster):.0f},trials=8xp4096")

    # per-node in-flight rebuild counts (the bandwidth-contended rebuild
    # model's cross-partition reduction; trials x partitions -> nodes)
    rec = rng.integers(0, 156, (8, 4096)).astype(np.int32)
    act = rng.random((8, 4096)) < 0.1
    nc_np = lambda r, a: rebuild_node_counts(r, a, n_real=155,
                                             backend="numpy")
    print(f"kernel_node_counts_numpy,b8p4096n155,"
          f"{_time(nc_np, rec, act):.0f},scatter_add")
    recj, actj = jnp.asarray(rec), jnp.asarray(act)
    nc_j = jax.jit(lambda r, a: rebuild_node_counts(r, a, n_real=155,
                                                    backend="jax"))
    print(f"kernel_node_counts_jax,b8p4096n155,"
          f"{_time(nc_j, recj, actj):.0f},scatter_add")
    if args.autotune:
        res = autotune_block_p(R, 155, rf=3, voters=5, n_real=155)
        print(f"kernel_pac_autotune,r{R}n155,0,"
              f"choice={res.block_p};source={res.source}")
        for bp in sorted(res.timings_us):
            print(f"kernel_pac_block,bp{bp},{res.timings_us[bp]:.0f},"
                  f"autotune_candidate")
    return 0


if __name__ == "__main__":
    main()
