"""Kernel micro-benchmarks: µs/call of the jnp oracle paths on CPU (the
Pallas kernels themselves target TPU; interpret mode is not a timing proxy).

The kernel_step_* rows time the same per-step evaluation through the
unified kernels.ops.step_eval entry point in both layouts — boolean
(R, n) tiles vs bit-packed (B, W, P) words — and the kernel_step_hbm_*
rows print the analytic HBM bytes each layout's pipeline moves per step
(ops.step_hbm_bytes): the fused megakernel's round-trip win, measurable
on CPU because it is a pure function of the shapes.

--autotune additionally races the Pallas PAC block_p candidates on the
Monte Carlo tile shape (measured on TPU; deterministic heuristic fallback
on CPU, where interpret-mode timings would measure the interpreter), plus
the fused megakernel's 2-D (block_t x block_p) race.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import bitpack, ref
from repro.kernels.ops import (StepSpec, autotune_block_p,
                               autotune_fused_blocks, step_eval,
                               step_hbm_bytes)


def _time(fn, *args, iters=5) -> float:
    jax.block_until_ready(fn(*args))        # warmup (and compile, if jitted)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def _build_parser():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0],
                                 allow_abbrev=False)
    ap.add_argument("--autotune", action="store_true",
                    help="race pallas PAC block_p candidates")
    return ap


def cli_options() -> tuple:
    """Option strings this suite accepts (benchmarks/run.py uses the
    union over all suites to reject flags nobody recognizes)."""
    return tuple(o for a in _build_parser()._actions
                 for o in a.option_strings)


def main(argv=None, *, strict: bool = True):
    ap = _build_parser()
    args, extra = ap.parse_known_args(argv if argv is not None
                                      else sys.argv[1:])
    if strict and extra:
        ap.error(f"unrecognized arguments: {' '.join(extra)}")
    rng = np.random.default_rng(0)
    B, H, S, D = 1, 4, 1024, 64
    q, k, v = (jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32)
               for _ in range(3))
    att = jax.jit(lambda q, k, v: ref.attention_ref(q, k, v))
    print(f"kernel_attention_ref,b{B}s{S}h{H}d{D},{_time(att, q, k, v):.0f},"
          f"flops={4*B*H*S*S*D:.3g}")

    qh = jnp.transpose(q, (0, 2, 1, 3))
    lf = jnp.asarray(rng.standard_normal((B, H, S)), jnp.float32)
    ml = jax.jit(lambda q, k, v, lf, li: ref.mlstm_chunkwise(q, k, v, lf, li)[0])
    print(f"kernel_mlstm_ref,b{B}s{S}h{H}d{D},"
          f"{_time(ml, qh, qh, qh, lf, lf):.0f},chunk=256")

    x = jnp.asarray(rng.standard_normal((2, 2048, 512)), jnp.float32)
    la = -jnp.asarray(rng.uniform(0.01, 1.0, (2, 2048, 512)), jnp.float32)
    rg = jax.jit(ref.rglru_scan_ref)
    print(f"kernel_rglru_ref,b2s2048w512,{_time(rg, x, la):.0f},assoc_scan")

    up = jnp.asarray(rng.random((4096, 256)) < 0.95)
    full = jnp.asarray(rng.random((4096, 256)) < 0.3)
    pc = jax.jit(lambda u, f: ref.pac_eval_rank_ref(u, f, rf=3, voters=5,
                                                    n_real=155))
    print(f"kernel_pac_ref,p4096n155,{_time(pc, up, full):.0f},per_tick_eval")

    # batched Monte Carlo tile: trials*partitions rows through the unified
    # step_eval entry point (the availability_batched.py hot loop)
    R = 8 * 4096
    pac_spec = StepSpec(metric="availability", rf=3, voters=5, n_real=155)
    up_b = rng.random((R, 256)) < 0.95
    full_b = rng.random((R, 256)) < 0.3
    pac_np = lambda u, f: step_eval(pac_spec, u, f, backend="numpy")
    print(f"kernel_pac_batch_numpy,r{R}n155,"
          f"{_time(pac_np, up_b, full_b):.0f},trials=8xp4096")
    upj, fullj = jnp.asarray(up_b), jnp.asarray(full_b)
    pac_j = jax.jit(lambda u, f: step_eval(pac_spec, u, f, backend="jax"))
    print(f"kernel_pac_batch_jax,r{R}n155,"
          f"{_time(pac_j, upj, fullj):.0f},trials=8xp4096")

    # downtime engine per-step evaluation (PAC + quorum replica set +
    # acting leader) on the same Monte Carlo tile
    dt_spec = StepSpec(metric="downtime", rf=3, n_real=155)
    dt_np = lambda u, f: step_eval(dt_spec, u, f, backend="numpy")
    print(f"kernel_downtime_batch_numpy,r{R}n155,"
          f"{_time(dt_np, up_b, full_b):.0f},trials=8xp4096")
    dt_j = jax.jit(lambda u, f: step_eval(dt_spec, u, f, backend="jax"))
    print(f"kernel_downtime_batch_jax,r{R}n155,"
          f"{_time(dt_j, upj, fullj):.0f},trials=8xp4096")

    # roster-aware variant (the reconfiguring quorum-log baseline carries
    # per-partition replica-set ranks instead of the first-rf lanes)
    rec_spec = StepSpec(metric="downtime", rf=3, n_real=155,
                        rebuild_model="reconfig")
    roster = jnp.asarray(rng.integers(0, 155, (R, 3)), jnp.int32)
    dt_r = jax.jit(lambda u, f, ro: step_eval(rec_spec, u, f, roster=ro,
                                              backend="jax"))
    print(f"kernel_downtime_roster_jax,r{R}n155,"
          f"{_time(dt_r, upj, fullj, roster):.0f},trials=8xp4096")

    # per-node in-flight rebuild counts (the bandwidth-contended rebuild
    # model's cross-partition reduction; trials x partitions -> nodes),
    # folded into the same step_eval call in the packed rows below
    B, P = 8, 4096
    rec = rng.integers(0, 156, (B, P)).astype(np.int32)
    act = rng.random((B, P)) < 0.1
    recj, actj = jnp.asarray(rec), jnp.asarray(act)

    # bit-packed layout: the same evaluations over (B, W, P) uint32 words
    # (155 nodes -> 5 words).  On TPU the pallas backend runs these as ONE
    # fused megakernel per step; the jax rows here time the identical
    # packed math (bitpack.py) through XLA on CPU.
    packed_pac = StepSpec(metric="availability", rf=3, voters=5, n_real=155,
                          packed=True)
    packed_rec = StepSpec(metric="downtime", rf=3, n_real=155,
                          rebuild_model="reconfig", packed=True)
    upw = jnp.moveaxis(bitpack.pack_words(
        jnp.reshape(upj[:, :155], (B, P, 155)), jnp), -1, 1)
    fullw = jnp.moveaxis(bitpack.pack_words(
        jnp.reshape(fullj[:, :155], (B, P, 155)), jnp), -1, 1)
    roster3 = jnp.reshape(roster, (B, P, 3))
    pac_pk = jax.jit(lambda u, f: step_eval(packed_pac, u, f,
                                            backend="jax"))
    print(f"kernel_pac_packed_jax,b{B}w5p{P},"
          f"{_time(pac_pk, upw, fullw):.0f},bitpacked")
    dt_pk = jax.jit(lambda u, f, ro, rc, ac: step_eval(
        packed_rec, u, f, roster=ro, recruit=rc, active=ac, backend="jax"))
    print(f"kernel_downtime_fused_packed_jax,b{B}w5p{P},"
          f"{_time(dt_pk, upw, fullw, roster3, recj, actj):.0f},"
          f"roster+counts_one_call")

    # analytic HBM traffic per step, unfused-boolean vs fused-packed —
    # the round-trip reduction the megakernel exists for (exact on any
    # host; benchmarks/roofline.py sweeps the full grid)
    for name, spec in (("pac", packed_pac), ("downtime_reconfig",
                                             packed_rec)):
        hbm = step_hbm_bytes(spec, B, P, 155)
        assert hbm["fused_bytes"] <= hbm["unfused_bytes"]
        print(f"kernel_step_hbm_{name},b{B}p{P}n155,0,"
              f"unfused={hbm['unfused_bytes']};fused={hbm['fused_bytes']};"
              f"ratio={hbm['ratio']:.1f}")
    if args.autotune:
        res = autotune_block_p(R, 155, rf=3, voters=5, n_real=155)
        print(f"kernel_pac_autotune,r{R}n155,0,"
              f"choice={res.block_p};source={res.source}")
        for bp in sorted(res.timings_us):
            print(f"kernel_pac_block,bp{bp},{res.timings_us[bp]:.0f},"
                  f"autotune_candidate")
        fres = autotune_fused_blocks(B, P, 155, rf=3, voters=5, n_real=155,
                                     kernel="fused_downtime_roster")
        print(f"kernel_fused_autotune,b{B}p{P}n155,0,"
              f"choice={fres.block_t}x{fres.block_p};source={fres.source}")
        for bt, bp in sorted(fres.timings_us):
            print(f"kernel_fused_block,bt{bt}bp{bp},"
                  f"{fres.timings_us[(bt, bp)]:.0f},autotune_candidate")
    return 0


if __name__ == "__main__":
    main()
