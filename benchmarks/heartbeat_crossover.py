"""Paper §4.1 footnote 6: control-plane message-rate crossover.

LARK full-mesh heartbeats n(n-1) vs quorum-log per-partition heartbeats
P*RF*(RF-1); for P=4096, RF=3 the curves cross at n ~ sqrt(6P) ~ 157.
"""
from __future__ import annotations

import math


def lark_heartbeats(n: int) -> int:
    return n * (n - 1)


def quorum_heartbeats(P: int = 4096, rf: int = 3) -> int:
    return P * rf * (rf - 1)


def crossover(P: int = 4096, rf: int = 3) -> float:
    return math.sqrt(P * rf * (rf - 1))


def cli_options() -> tuple:
    """No flags of its own (benchmarks/run.py unknown-flag contract)."""
    return ()


def main(argv=None, *, strict: bool = True):  # noqa: ARG001 - run.py contract
    P, rf = 4096, 3
    n_star = crossover(P, rf)
    below = lark_heartbeats(150) < quorum_heartbeats(P, rf)
    above = lark_heartbeats(165) > quorum_heartbeats(P, rf)
    print(f"heartbeat_crossover,n_star,0,"
          f"n={n_star:.1f};paper=156.8;below150={below};above165={above}")
    assert abs(n_star - 156.76) < 0.5
    return 0


if __name__ == "__main__":
    main()
