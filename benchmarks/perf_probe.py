"""§Perf experiment harness: re-lower one cell under config/sharding variants
and report the roofline terms + per-device memory.  Used for the
hypothesis -> change -> measure -> validate iterations logged in
EXPERIMENTS.md §Perf.

  PYTHONPATH=src python -m benchmarks.perf_probe --arch internlm2_20b \
      --shape train_4k --variant baseline --variant no_fsdp ...
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json
import time

import jax


def apply_variant(name: str):
    """Monkeypatch-style variant switches (kept out of the core library)."""
    import repro.launch.shardings as sh
    import repro.launch.dryrun as dr
    if name == "baseline":
        return {}
    if name == "no_fsdp":
        orig = sh.param_shardings
        sh.param_shardings = lambda cfg, mesh, tree, fsdp=True: \
            orig(cfg, mesh, tree, fsdp=False)
        dr.param_shardings = sh.param_shardings
        return {}
    if name == "no_zero_grads":
        dr.grad_shardings = lambda cfg, mesh, tree: None
        return {}
    if name.startswith("nmb"):
        return {"microbatches_train": int(name[3:])}
    if name.startswith("rg"):
        return {"remat_group": int(name[2:])}
    if name == "no_remat":
        return {"remat": False}
    raise ValueError(name)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", action="append", default=[])
    ap.add_argument("--multipod", action="store_true")
    args = ap.parse_args()

    for variant in (args.variant or ["baseline"]):
        # fresh import state per variant
        import importlib
        import repro.launch.shardings
        import repro.launch.dryrun
        importlib.reload(repro.launch.shardings)
        importlib.reload(repro.launch.dryrun)
        from repro.launch import dryrun
        overrides = apply_variant(variant)
        t0 = time.time()
        try:
            rec = dryrun.run_cell(args.arch, args.shape, args.multipod,
                                  overrides=overrides)
            h = rec["hlo_analysis"]
            print(f"PROBE {args.arch} {args.shape} {variant}: "
                  f"peak={rec['memory']['peak_bytes_per_device']/1e9:.1f}GB "
                  f"flops={h['flops']:.3e} hbm={h['hbm_bytes']:.3e} "
                  f"coll={h['collective_bytes_total']:.3e} "
                  f"compile={rec['compile_s']}s total={time.time()-t0:.0f}s",
                  flush=True)
        except Exception as e:
            print(f"PROBE {args.arch} {args.shape} {variant}: ERROR {e!r}",
                  flush=True)


if __name__ == "__main__":
    main()
