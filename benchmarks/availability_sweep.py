"""Paper §5.1 / Figure 6 / Table 2: availability vs node-failure probability.

Reduced grid by default (CPU budget); --full sweeps the paper's p range with
n=155, P=4096 and CI early-stopping.  Emits CSV rows:
  availability,<rf>,<p>,u_lark,u_maj,ratio,analytic_ratio,ticks

Backends (--backend):
  event    scalar heapq event engine (core/availability.py); --trials N runs
           N sequential seeds and averages — the seed repo's behavior
  numpy    batched engine (core/availability_batched.py), vectorized numpy
           PAC, python chunk loop
  jax      batched engine, jit + lax.scan, pure-jnp PAC oracle
  pallas   batched engine, PAC through kernels/pac_eval.py (compiled on
           TPU, interpret mode on CPU — slow there; use for validation)

For the batched backends --trials N advances N independent trajectories in
one device program instead of N sequential runs.

--scenarios appends a dual-failure / rolling-restart grid (rf in {2,3,4}:
correlated rack-pair failures and staggered node restarts) on top of the
i.i.d. rows; scenario rows always use the batched engine ("event" maps to
"numpy" — the scalar engine has no correlated/scheduled failure model).
"""
from __future__ import annotations

import argparse
import sys

from repro.core.analytical import (improvement_factor, lark_unavailability,
                                   node_unavailability, raft_unavailability)
from repro.core.availability import simulate_availability
from repro.core.availability_batched import simulate_availability_batched

REDUCED_GRID = [(2, 1e-3), (2, 3e-3), (2, 1e-2), (3, 1e-2), (4, 3e-2)]
FULL_GRID = [(2, 1e-4), (2, 1e-3), (2, 1e-2),
             (3, 2e-4), (3, 1e-3), (3, 1e-2),
             (4, 5e-4), (4, 1e-3), (4, 1e-2)]

# (tag, rf, p, batched-engine kwargs): correlated rack pairs fail together
# half the time; rolling restart cycles one node down every `period` ticks.
SCENARIO_GRID = [
    ("dualfail", 2, 3e-3, {"pair_fail_prob": 0.5}),
    ("dualfail", 3, 1e-2, {"pair_fail_prob": 0.5}),
    ("dualfail", 4, 1e-2, {"pair_fail_prob": 0.5}),
    ("rolling", 2, 1e-3, {"restart_period": 2_000}),
    ("rolling", 3, 3e-3, {"restart_period": 2_000}),
    ("rolling", 4, 3e-3, {"restart_period": 2_000}),
]


def _grid_scale(full: bool):
    """(n, partitions) — one place, so i.i.d. and scenario rows always run
    at the same cluster scale and their u columns stay comparable."""
    return (155, 4096) if full else (63, 512)


def run(full: bool = False, seeds=(0,), backend: str = "event"):
    grid = FULL_GRID if full else REDUCED_GRID
    n, parts = _grid_scale(full)
    max_ticks = 3_000_000 if full else 250_000
    rows = []
    for rf, p in grid:
        if backend == "event":
            us_l, us_m = [], []
            ticks = 0
            for s in seeds:
                r = simulate_availability(n=n, partitions=parts, rf=rf, p=p,
                                          max_ticks=max_ticks,
                                          min_ticks=30_000, seed=s)
                us_l.append(r.u_lark)
                us_m.append(r.u_maj)
                ticks = r.ticks
            u_l = sum(us_l) / len(us_l)
            u_m = sum(us_m) / len(us_m)
        else:
            r = simulate_availability_batched(
                n=n, partitions=parts, rf=rf, p=p, trials=len(seeds),
                max_ticks=max_ticks, min_ticks=30_000, seed=min(seeds),
                backend=backend)
            u_l, u_m, ticks = r.u_lark, r.u_maj, r.ticks
        f = rf - 1
        rows.append({
            "rf": rf, "p": p, "u_lark": u_l, "u_maj": u_m,
            "ratio": u_m / u_l if u_l else float("inf"),
            "analytic_ratio": improvement_factor(f),
            "analytic_u_lark": lark_unavailability(node_unavailability(p), f),
            "ticks": ticks,
        })
    return rows


def run_scenarios(full: bool = False, trials: int = 4,
                  backend: str = "jax", seed: int = 0):
    backend = "numpy" if backend == "event" else backend
    n, parts = _grid_scale(full)
    max_ticks = 1_000_000 if full else 120_000
    rows = []
    for tag, rf, p, kw in SCENARIO_GRID:
        r = simulate_availability_batched(
            n=n, partitions=parts, rf=rf, p=p, trials=trials,
            max_ticks=max_ticks, min_ticks=20_000, seed=seed,
            backend=backend, **kw)
        rows.append({
            "tag": tag, "rf": rf, "p": p, "u_lark": r.u_lark,
            "u_maj": r.u_maj,
            "ratio": r.u_maj / r.u_lark if r.u_lark else float("inf"),
            "ticks": r.ticks, **kw,
        })
    return rows


def main(argv=None):
    # allow_abbrev off: a prefix typo like --ful must fail loudly, not
    # silently launch the hours-long paper-scale grid
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0],
                                 allow_abbrev=False)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--backend", default="event",
                    choices=("event", "numpy", "jax", "pallas"))
    ap.add_argument("--trials", type=int, default=1,
                    help="seeds (event) or batch size (batched backends)")
    ap.add_argument("--scenarios", action="store_true",
                    help="append the dual-failure / rolling-restart grid")
    ap.add_argument("--scenarios-only", action="store_true",
                    help="skip the i.i.d. grid (scenario rows only)")
    args = ap.parse_args(argv if argv is not None else sys.argv[1:])
    if args.trials < 1:
        ap.error("--trials must be >= 1")

    if not args.scenarios_only:
        for r in run(full=args.full, seeds=tuple(range(args.trials)),
                     backend=args.backend):
            print(f"availability,rf{r['rf']}_p{r['p']:g},0,"
                  f"u_lark={r['u_lark']:.3e};u_maj={r['u_maj']:.3e};"
                  f"ratio={r['ratio']:.2f};analytic={r['analytic_ratio']}")
    if args.scenarios or args.scenarios_only:
        for r in run_scenarios(full=args.full, trials=args.trials,
                               backend=args.backend):
            print(f"availability_scenario,{r['tag']}_rf{r['rf']}_"
                  f"p{r['p']:g},0,u_lark={r['u_lark']:.3e};"
                  f"u_maj={r['u_maj']:.3e};ratio={r['ratio']:.2f}")
    return 0


if __name__ == "__main__":
    main()
