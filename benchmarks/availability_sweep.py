"""Paper §5.1 / Figure 6 / Table 2: availability vs node-failure probability.

Reduced grid by default (CPU budget); --full sweeps the paper's p range with
n=155, P=4096 and CI early-stopping.  Emits CSV rows:
  availability,<rf>,<p>,u_lark,u_maj,ratio,analytic_ratio,ticks
"""
from __future__ import annotations

import sys

from repro.core.analytical import (improvement_factor, lark_unavailability,
                                   node_unavailability, raft_unavailability)
from repro.core.availability import simulate_availability

REDUCED_GRID = [(2, 1e-3), (2, 3e-3), (2, 1e-2), (3, 1e-2), (4, 3e-2)]
FULL_GRID = [(2, 1e-4), (2, 1e-3), (2, 1e-2),
             (3, 2e-4), (3, 1e-3), (3, 1e-2),
             (4, 5e-4), (4, 1e-3), (4, 1e-2)]


def run(full: bool = False, seeds=(0,)):
    grid = FULL_GRID if full else REDUCED_GRID
    n = 155 if full else 63
    parts = 4096 if full else 512
    max_ticks = 3_000_000 if full else 250_000
    rows = []
    for rf, p in grid:
        us_l, us_m = [], []
        ticks = 0
        for s in seeds:
            r = simulate_availability(n=n, partitions=parts, rf=rf, p=p,
                                      max_ticks=max_ticks,
                                      min_ticks=30_000, seed=s)
            us_l.append(r.u_lark)
            us_m.append(r.u_maj)
            ticks = r.ticks
        u_l = sum(us_l) / len(us_l)
        u_m = sum(us_m) / len(us_m)
        f = rf - 1
        rows.append({
            "rf": rf, "p": p, "u_lark": u_l, "u_maj": u_m,
            "ratio": u_m / u_l if u_l else float("inf"),
            "analytic_ratio": improvement_factor(f),
            "analytic_u_lark": lark_unavailability(node_unavailability(p), f),
            "ticks": ticks,
        })
    return rows


def main(argv=None):
    full = "--full" in (argv or sys.argv[1:])
    for r in run(full=full):
        print(f"availability,rf{r['rf']}_p{r['p']:g},0,"
              f"u_lark={r['u_lark']:.3e};u_maj={r['u_maj']:.3e};"
              f"ratio={r['ratio']:.2f};analytic={r['analytic_ratio']}")
    return 0


if __name__ == "__main__":
    main()
