"""Paper §5.1 / Figure 6 / Table 2: availability vs node-failure probability,
and (--metric downtime) the §6 commit-pause comparison.

Reduced grid by default (CPU budget); --full sweeps the paper's p range with
n=155, P=4096 and CI early-stopping; --smoke shrinks everything for the CI
pallas-interpret lane.  Emits CSV rows:
  availability,<rf>,<p>,u_lark,u_maj,ratio,analytic_ratio,ticks

--metric downtime swaps the instantaneous engine for the batched
commit-pause engine (core/downtime_batched.py): rows carry the mean
commit-pause fraction of LARK vs the equal-storage quorum-log baseline,
the pause-duration histograms, and the dup-res / rebuild knobs
(--dupres-ticks / --rebuild-steps).  --rebuild-model picks the baseline:
"fixed" (static first-rf replica set, constant rebuild pause) or
"reconfig" (replica-set reconfiguration onto live nodes with a
data-sized catch-up, --rebuild-ticks-per-gib per GiB of per-partition
data; --size-dist/--size-skew shape the per-partition sizes — uniform,
zipf, lognormal at a pinned 1.5 GiB mean — and --node-bandwidth-gibps
makes concurrent catch-ups share each recruit node's ingest bandwidth).
Downtime rows are batched-only ("event" maps to "numpy").
--engines grows the comparison into the protocol zoo: beyond the
lark/quorum pair every downtime row carries, "hermes" (broadcast
replication under membership leases, --lease-ticks write-block window)
and "spinnaker" (Paxos with reconfiguration, --view-change-ticks
log-reconciliation pause on leader loss; reconfig model only) each add
one "downtime_engine" row per grid point, keyed by engine name.  See
docs/BENCHMARKS.md for the full CLI surface.

--metric latency layers the client-traffic request engine
(core/client_latency.py) over the same trajectories: zipf key popularity
(--key-zipf) mapped onto partitions, a --read-frac read/write mix at
--requests-per-tick offered cluster load, per-key dup-res first-touch
charges for LARK vs full rebuild-wait charges for the quorum-log
baseline (and the Hermes-style read-local contrast).  Rows carry
p50/p99/p999 added commit latency, the --slo-ticks violation fraction,
and the quorum wait histogram.  Latency rows accept every downtime knob
(the protocol under the workload is the same) and are batched-only.

Backends (--backend):
  event    scalar heapq event engine (core/availability.py); --trials N runs
           N sequential seeds and averages — the seed repo's behavior
  numpy    batched engine (core/availability_batched.py), vectorized numpy
           PAC, python chunk loop
  jax      batched engine, jit + lax.scan, pure-jnp PAC oracle
  pallas   batched engine, PAC through kernels/pac_eval.py (compiled on
           TPU, interpret mode on CPU — slow there; use for validation)

For the batched backends --trials N advances N independent trajectories in
one device program; --devices D shards them over a 1-D "trials" mesh
(bit-identical to --devices 1 for the same seed; on CPU set
XLA_FLAGS=--xla_force_host_platform_device_count=D).  --autotune (pallas)
races kernel block_p candidates before the sweep and runs the grid at the
winner.

Failure models come from the scenario registry (core/scenarios.py):
--scenario NAME appends that scenario's (rf, p) grid on top of the i.i.d.
rows ('all' = every registered name; repeatable / comma-separated).
--scenarios is the legacy alias for --scenario all; --scenarios-only skips
the i.i.d. grid.  Scenario rows always use the batched engine ("event"
maps to "numpy" — the scalar engine has no correlated/scheduled failure
model).  --json PATH additionally dumps all rows with CI half-widths, the
schema benchmarks/check_regression.py consumes.
"""
from __future__ import annotations

import argparse
import json
import math
import sys

from repro.core.analytical import (improvement_factor, lark_unavailability,
                                   node_unavailability)
from repro.core.availability import simulate_availability
from repro.core.availability_batched import simulate_availability_batched
from repro.core.client_latency import simulate_client_latency
from repro.core.downtime_batched import (ENGINES, SIZE_DISTS, DowntimeParams,
                                         simulate_downtime_batched)
from repro.core.scenarios import get_scenario, scenario_names

REDUCED_GRID = [(2, 1e-3), (2, 3e-3), (2, 1e-2), (3, 1e-2), (4, 3e-2)]
FULL_GRID = [(2, 1e-4), (2, 1e-3), (2, 1e-2),
             (3, 2e-4), (3, 1e-3), (3, 1e-2),
             (4, 5e-4), (4, 1e-3), (4, 1e-2)]
SMOKE_GRID = [(2, 3e-3), (3, 1e-2)]


def _grid_scale(full: bool, smoke: bool = False):
    """(n, partitions) — one place, so i.i.d. and scenario rows always run
    at the same cluster scale and their u columns stay comparable."""
    if smoke:
        return (31, 128)
    return (155, 4096) if full else (63, 512)


def _run_scale(full: bool, smoke: bool, *, scenario: bool):
    """(n, partitions, max_ticks, min_ticks) — single source for both
    metrics, so availability and downtime rows (and their committed
    BENCH_*.json baselines) always use the same tick budgets."""
    n, parts = _grid_scale(full, smoke)
    if scenario:
        max_ticks = 30_000 if smoke else (1_000_000 if full else 120_000)
        min_ticks = 8_000 if smoke else 20_000
    else:
        max_ticks = 40_000 if smoke else (3_000_000 if full else 250_000)
        min_ticks = 10_000 if smoke else 30_000
    return n, parts, max_ticks, min_ticks


def _iid_grid(full: bool, smoke: bool):
    return SMOKE_GRID if smoke else (FULL_GRID if full else REDUCED_GRID)


def _batched_backend(backend: str, devices: int):
    """event rows reuse the numpy math, single-device; an explicit numpy
    backend keeps its own devices so invalid combos still raise."""
    return ("numpy", 1) if backend == "event" else (backend, devices)


def _autotune_row(n: int, parts: int, trials: int, devices: int, *,
                  metric: str = "availability", rf: int = 2,
                  rebuild_model: str = "fixed", packed: bool = False):
    """Race kernel block candidates on the per-device sweep tile shape,
    timing the kernel the grid will actually run — at the grid's rf, not
    a hardcoded rf=2/voters=3.  Unpacked: the 1-D block_p race over
    pac_eval / downtime_eval (or its roster-carrying reconfig variant).
    --packed: the 2-D (block_t x block_p) race over the fused step
    megakernel of the same metric/model (the tagged cache keys guarantee
    the two families can never return each other's entries).  Returns
    (block_p, block_t, row); block_t is None for the unpacked race."""
    voters = 2 * (rf - 1) + 1
    # the latency layer rides on the downtime step — same kernels, same
    # valid block choices, so it reuses the downtime race verbatim
    if packed:
        from repro.kernels.ops import autotune_fused_blocks
        if metric in ("downtime", "latency"):
            kernel = "fused_downtime_roster" if rebuild_model == "reconfig" \
                else "fused_downtime"
        else:
            kernel = "fused_pac"
        res = autotune_fused_blocks(trials // devices, parts, n, rf=rf,
                                    voters=voters, n_real=n, kernel=kernel)
        row = {"kind": "autotune", "block_p": res.block_p,
               "block_t": res.block_t, "source": res.source,
               "kernel": kernel, "rf": rf,
               "timings_us": {f"{bt}x{bp}": v
                              for (bt, bp), v in res.timings_us.items()}}
        print(f"autotune,fused_blocks,0,choice={res.block_t}x{res.block_p};"
              f"source={res.source};kernel={kernel};rf={rf};"
              f"candidates={len(res.timings_us)}")
        return res.block_p, res.block_t, row
    from repro.kernels.ops import autotune_block_p
    R = (trials // devices) * parts
    if metric in ("downtime", "latency"):
        kernel = "downtime_roster" if rebuild_model == "reconfig" \
            else "downtime"
    else:
        kernel = "pac"
    res = autotune_block_p(R, n, rf=rf, voters=voters, n_real=n,
                           kernel=kernel)
    row = {"kind": "autotune", "block_p": res.block_p, "source": res.source,
           "kernel": kernel, "rf": rf,
           "timings_us": {str(k): v for k, v in res.timings_us.items()}}
    print(f"autotune,block_p,0,choice={res.block_p};source={res.source};"
          f"kernel={kernel};rf={rf};candidates={len(res.timings_us)}")
    return res.block_p, None, row


def run(full: bool = False, seeds=(0,), backend: str = "event",
        devices: int = 1, smoke: bool = False, pac_block_p=None,
        packed: bool = False, block_t=None):
    grid = _iid_grid(full, smoke)
    n, parts, max_ticks, min_ticks = _run_scale(full, smoke, scenario=False)
    rows = []
    for rf, p in grid:
        if backend == "event":
            us_l, us_m, cis_l, cis_m = [], [], [], []
            ticks = 0
            for s in seeds:
                r = simulate_availability(n=n, partitions=parts, rf=rf, p=p,
                                          max_ticks=max_ticks,
                                          min_ticks=min_ticks, seed=s)
                us_l.append(r.u_lark)
                us_m.append(r.u_maj)
                cis_l.append(r.ci_lark)
                cis_m.append(r.ci_maj)
                ticks = r.ticks
            N = len(seeds)
            u_l = sum(us_l) / N
            u_m = sum(us_m) / N
            # half-width of the across-seed mean: independent runs, so
            # se_mean = sqrt(sum se_i^2) / N
            ci_l = math.sqrt(sum(c * c for c in cis_l)) / N
            ci_m = math.sqrt(sum(c * c for c in cis_m)) / N
        else:
            r = simulate_availability_batched(
                n=n, partitions=parts, rf=rf, p=p, trials=len(seeds),
                max_ticks=max_ticks, min_ticks=min_ticks, seed=min(seeds),
                backend=backend, devices=devices, pac_block_p=pac_block_p,
                packed=packed, block_t=block_t)
            u_l, u_m, ticks = r.u_lark, r.u_maj, r.ticks
            ci_l, ci_m = r.ci_lark, r.ci_maj
        f = rf - 1
        rows.append({
            "kind": "iid", "rf": rf, "p": p, "u_lark": u_l, "u_maj": u_m,
            "ci_lark": ci_l, "ci_maj": ci_m,
            "ratio": u_m / u_l if u_l else float("inf"),
            "analytic_ratio": improvement_factor(f),
            "analytic_u_lark": lark_unavailability(node_unavailability(p), f),
            "ticks": ticks,
        })
    return rows


def run_scenarios(names, full: bool = False, trials: int = 4,
                  backend: str = "jax", seed: int = 0, devices: int = 1,
                  smoke: bool = False, pac_block_p=None,
                  packed: bool = False, block_t=None):
    backend, devices = _batched_backend(backend, devices)
    n, parts, max_ticks, min_ticks = _run_scale(full, smoke, scenario=True)
    rows = []
    for name in names:
        sc = get_scenario(name)
        for rf, p in sc.grid:
            r = simulate_availability_batched(
                n=n, partitions=parts, rf=rf, p=p, trials=trials,
                max_ticks=max_ticks, min_ticks=min_ticks, seed=seed,
                backend=backend, devices=devices, pac_block_p=pac_block_p,
                packed=packed, block_t=block_t,
                **sc.kwargs(n=n, rf=rf, p=p))
            rows.append({
                "kind": "scenario", "scenario": name, "rf": rf, "p": p,
                "u_lark": r.u_lark, "u_maj": r.u_maj,
                "ci_lark": r.ci_lark, "ci_maj": r.ci_maj,
                "ratio": r.u_maj / r.u_lark if r.u_lark else float("inf"),
                "ticks": r.ticks,
            })
    return rows


def _downtime_row(r, *, kind: str, scenario: str):
    return {
        "kind": kind, "scenario": scenario, "rf": r.rf, "p": r.p,
        "pause_lark": r.pause_lark, "pause_quorum": r.pause_quorum,
        "ci_pause_lark": r.ci_lark, "ci_pause_quorum": r.ci_quorum,
        "ratio": r.availability_ratio,
        "lark_events": r.lark_events, "quorum_events": r.quorum_events,
        "hist_edges": r.hist_edges.tolist(),
        "hist_lark": r.hist_lark.tolist(),
        "hist_quorum": r.hist_quorum.tolist(),
        "dupres_ticks": r.dupres_ticks, "rebuild_steps": r.rebuild_steps,
        "rebuild_model": r.rebuild_model,
        "rebuild_ticks_per_gib": r.rebuild_ticks_per_gib,
        "size_dist": r.size_dist, "size_skew": r.size_skew,
        # inf (no sharing) serializes as null — _json_safe
        "node_bandwidth_gibps": r.node_bandwidth_gibps,
        "ticks": r.ticks,
    }


def _downtime_engine_rows(r, *, kind: str, scenario: str):
    """One row per protocol-zoo engine beyond the lark/quorum pair the
    base downtime row already carries.  Engine rows name their engine
    explicitly — check_regression keys them by it — and repeat the shared
    grid/knob columns so each row is self-describing."""
    rows = []
    for engine in r.engines:
        if engine in ("lark", "quorum"):
            continue
        s = r.engine_stats(engine)
        rows.append({
            "kind": kind, "engine": engine, "scenario": scenario,
            "rf": r.rf, "p": r.p,
            "pause": s["pause"], "ci_pause": s["ci_pause"],
            "events": s["events"],
            "hist_edges": r.hist_edges.tolist(),
            "hist": s["hist"].tolist(),
            "lease_ticks": r.lease_ticks,
            "view_change_ticks": r.view_change_ticks,
            "dupres_ticks": r.dupres_ticks,
            "rebuild_steps": r.rebuild_steps,
            "rebuild_model": r.rebuild_model,
            "rebuild_ticks_per_gib": r.rebuild_ticks_per_gib,
            "size_dist": r.size_dist, "size_skew": r.size_skew,
            "node_bandwidth_gibps": r.node_bandwidth_gibps,
            "ticks": r.ticks,
        })
    return rows


def run_downtime(full: bool = False, trials: int = 4, backend: str = "jax",
                 seed: int = 0, devices: int = 1, smoke: bool = False,
                 pac_block_p=None,
                 params: DowntimeParams = DowntimeParams(),
                 packed: bool = False, block_t=None):
    """§6 commit-pause rows over the i.i.d. grid.  The protocol/rebuild
    knobs travel as one pre-validated DowntimeParams — main() builds it
    exactly once from the CLI flags, so every invalid combination is
    rejected in one place (the dataclass) before any engine runs."""
    backend, devices = _batched_backend(backend, devices)
    grid = _iid_grid(full, smoke)
    n, parts, max_ticks, min_ticks = _run_scale(full, smoke, scenario=False)
    rows = []
    for rf, p in grid:
        r = simulate_downtime_batched(
            n=n, partitions=parts, rf=rf, p=p, trials=trials,
            max_ticks=max_ticks, min_ticks=min_ticks, seed=seed,
            backend=backend, devices=devices, pac_block_p=pac_block_p,
            params=params, packed=packed, block_t=block_t)
        rows.append(_downtime_row(r, kind="downtime", scenario="iid"))
        rows.extend(_downtime_engine_rows(r, kind="downtime_engine",
                                          scenario="iid"))
    return rows


def run_downtime_scenarios(names, full: bool = False, trials: int = 4,
                           backend: str = "jax", seed: int = 0,
                           devices: int = 1, smoke: bool = False,
                           pac_block_p=None,
                           params: DowntimeParams = DowntimeParams(),
                           packed: bool = False, block_t=None):
    backend, devices = _batched_backend(backend, devices)
    n, parts, max_ticks, min_ticks = _run_scale(full, smoke, scenario=True)
    rows = []
    for name in names:
        sc = get_scenario(name)
        for rf, p in sc.grid:
            r = simulate_downtime_batched(
                n=n, partitions=parts, rf=rf, p=p, trials=trials,
                max_ticks=max_ticks, min_ticks=min_ticks, seed=seed,
                backend=backend, devices=devices, pac_block_p=pac_block_p,
                params=params, packed=packed, block_t=block_t,
                **sc.kwargs(n=n, rf=rf, p=p))
            rows.append(_downtime_row(r, kind="downtime_scenario",
                                      scenario=name))
            rows.extend(_downtime_engine_rows(
                r, kind="downtime_engine_scenario", scenario=name))
    return rows


def _latency_row(r, *, kind: str, scenario: str):
    return {
        "kind": kind, "scenario": scenario, "rf": r.rf, "p": r.p,
        "lat_lark": r.lat_lark, "lat_quorum": r.lat_quorum,
        "lat_hermes": r.lat_hermes,
        "ci_lat_lark": r.ci_lat_lark, "ci_lat_quorum": r.ci_lat_quorum,
        "p50_lark": r.p50_lark, "p99_lark": r.p99_lark,
        "p999_lark": r.p999_lark,
        "p50_quorum": r.p50_quorum, "p99_quorum": r.p99_quorum,
        "p999_quorum": r.p999_quorum,
        "p50_hermes": r.p50_hermes, "p99_hermes": r.p99_hermes,
        "p999_hermes": r.p999_hermes,
        "slo_lark": r.slo_lark, "slo_quorum": r.slo_quorum,
        "slo_hermes": r.slo_hermes,
        "req_total": r.req_total,
        "hist_edges": r.hist_edges.tolist(),
        "hist_quorum_req": r.hist_quorum_req.tolist(),
        "dupres_ticks": r.dupres_ticks, "rebuild_model": r.rebuild_model,
        "key_zipf": r.key_zipf, "read_frac": r.read_frac,
        "requests_per_tick": r.requests_per_tick,
        "slo_ticks": r.slo_ticks,
        "ticks": r.ticks,
    }


def run_latency(full: bool = False, trials: int = 4, backend: str = "jax",
                seed: int = 0, devices: int = 1, smoke: bool = False,
                pac_block_p=None, params: DowntimeParams = DowntimeParams(),
                packed: bool = False, block_t=None):
    """Client-latency rows over the i.i.d. grid — same grid/scale/tick
    budgets as the downtime metric, so the two row families describe the
    same trajectories."""
    backend, devices = _batched_backend(backend, devices)
    grid = _iid_grid(full, smoke)
    n, parts, max_ticks, min_ticks = _run_scale(full, smoke, scenario=False)
    rows = []
    for rf, p in grid:
        r = simulate_client_latency(
            n=n, partitions=parts, rf=rf, p=p, trials=trials,
            max_ticks=max_ticks, min_ticks=min_ticks, seed=seed,
            backend=backend, devices=devices, pac_block_p=pac_block_p,
            params=params, packed=packed, block_t=block_t)
        rows.append(_latency_row(r, kind="latency", scenario="iid"))
    return rows


def run_latency_scenarios(names, full: bool = False, trials: int = 4,
                          backend: str = "jax", seed: int = 0,
                          devices: int = 1, smoke: bool = False,
                          pac_block_p=None,
                          params: DowntimeParams = DowntimeParams(),
                          packed: bool = False, block_t=None):
    backend, devices = _batched_backend(backend, devices)
    n, parts, max_ticks, min_ticks = _run_scale(full, smoke, scenario=True)
    rows = []
    for name in names:
        sc = get_scenario(name)
        for rf, p in sc.grid:
            r = simulate_client_latency(
                n=n, partitions=parts, rf=rf, p=p, trials=trials,
                max_ticks=max_ticks, min_ticks=min_ticks, seed=seed,
                backend=backend, devices=devices, pac_block_p=pac_block_p,
                params=params, packed=packed, block_t=block_t,
                **sc.kwargs(n=n, rf=rf, p=p))
            rows.append(_latency_row(r, kind="latency_scenario",
                                     scenario=name))
    return rows


def _resolve_scenarios(args, ap):
    names = []
    for sel in args.scenario or []:
        names.extend(s for s in sel.split(",") if s)
    if (args.scenarios or args.scenarios_only) and not names:
        names = ["all"]
    for name in names:
        if name != "all" and name not in scenario_names():
            ap.error(f"unknown scenario {name!r}; registered: "
                     f"{', '.join(scenario_names())} (or 'all')")
    if "all" in names:
        return list(scenario_names())
    return names


def main(argv=None, *, strict: bool = True):
    # allow_abbrev off: a prefix typo like --ful must fail loudly, not
    # silently launch the hours-long paper-scale grid
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0],
                                 allow_abbrev=False)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid/scale (CI pallas-interpret lane)")
    ap.add_argument("--backend", default="event",
                    choices=("event", "numpy", "jax", "pallas"))
    ap.add_argument("--metric", default="availability",
                    choices=("availability", "downtime", "latency"),
                    help="instantaneous availability (§5.1), commit-pause "
                         "durations (§6), or client-visible commit "
                         "latency under a keyed request workload")
    ap.add_argument("--dupres-ticks", type=int, default=None,
                    help="LARK dup-res round-trip cost in ticks "
                         "(downtime metric only; default 1)")
    ap.add_argument("--rebuild-steps", type=int, default=None,
                    help="quorum-log rebuild pause in ticks after a "
                         "replica loss (--rebuild-model fixed only; "
                         "default 100)")
    ap.add_argument("--rebuild-model", default=None,
                    choices=("fixed", "reconfig"),
                    help="quorum-log baseline: static replica set with a "
                         "constant rebuild pause (fixed, default) or "
                         "reconfiguration onto live nodes with a "
                         "data-sized catch-up (reconfig); downtime "
                         "metric only")
    ap.add_argument("--rebuild-ticks-per-gib", type=int, default=None,
                    help="reconfig catch-up cost per GiB of partition "
                         "data (--rebuild-model reconfig only; "
                         "default 100)")
    ap.add_argument("--size-dist", default=None, choices=SIZE_DISTS,
                    help="per-partition data-size distribution for the "
                         "reconfig catch-ups (default uniform [1, 2) "
                         "GiB; zipf/lognormal skew hot partitions while "
                         "pinning the same 1.5 GiB mean; "
                         "--rebuild-model reconfig only)")
    ap.add_argument("--size-skew", type=float, default=None,
                    help="skew shape of --size-dist zipf/lognormal "
                         "(Pareto exponent / log-sigma; 0 = constant "
                         "sizes; default 1)")
    ap.add_argument("--node-bandwidth-gibps", type=float, default=None,
                    help="per-node catch-up ingest bandwidth in "
                         "full-speed streams; concurrent rebuilds on one "
                         "recruit share it ('inf' disables sharing, the "
                         "default; --rebuild-model reconfig only)")
    ap.add_argument("--engines", default=None, metavar="LIST",
                    help="comma-separated protocol engines to report "
                         f"(subset of {','.join(ENGINES)}; default "
                         "lark,quorum; --metric downtime only)")
    ap.add_argument("--lease-ticks", type=int, default=None,
                    help="Hermes membership-lease expiry window: writes "
                         "block this many ticks after a replica is "
                         "suspected (--engines hermes; default 0)")
    ap.add_argument("--view-change-ticks", type=int, default=None,
                    help="Spinnaker log-reconciliation pause after a "
                         "leader loss (--engines spinnaker, "
                         "--rebuild-model reconfig; default 0)")
    ap.add_argument("--key-zipf", type=float, default=None,
                    help="zipf exponent of the key-popularity workload "
                         "(0 = exactly uniform traffic; --metric latency "
                         "only; default 1)")
    ap.add_argument("--read-frac", type=float, default=None,
                    help="fraction of requests that are reads (the rest "
                         "are writes; --metric latency only; default 0.8)")
    ap.add_argument("--requests-per-tick", type=float, default=None,
                    help="offered cluster-wide request rate "
                         "(--metric latency only; default 32)")
    ap.add_argument("--slo-ticks", type=int, default=None,
                    help="SLO threshold: rows report the fraction of "
                         "requests whose added commit latency exceeds "
                         "this (--metric latency only; default 8)")
    ap.add_argument("--trials", type=int, default=1,
                    help="seeds (event) or batch size (batched backends)")
    ap.add_argument("--devices", type=int, default=1,
                    help="shard trials over this many devices (jax/pallas)")
    ap.add_argument("--scenario", action="append", metavar="NAME",
                    help="append a registered scenario's grid (repeatable, "
                         "comma-separated, or 'all')")
    ap.add_argument("--scenarios", action="store_true",
                    help="legacy alias for --scenario all")
    ap.add_argument("--scenarios-only", action="store_true",
                    help="skip the i.i.d. grid (scenario rows only)")
    ap.add_argument("--packed", action="store_true",
                    help="carry cluster state as bit-packed uint32 words; "
                         "on --backend pallas every step runs the fused "
                         "megakernel (bit-identical to unpacked)")
    ap.add_argument("--autotune", action="store_true",
                    help="race pallas kernel block candidates before the "
                         "sweep (block_p; with --packed the 2-D fused "
                         "block_t x block_p race)")
    ap.add_argument("--json", metavar="PATH",
                    help="also dump rows + CI half-widths as JSON")
    args, extra = ap.parse_known_args(argv if argv is not None
                                      else sys.argv[1:])
    if strict and extra:
        ap.error(f"unrecognized arguments: {' '.join(extra)}")
    if args.trials < 1:
        ap.error("--trials must be >= 1")
    if args.devices < 1:
        ap.error("--devices must be >= 1")
    if args.devices > 1:
        if args.backend in ("event", "numpy"):
            ap.error("--devices > 1 needs --backend jax or pallas")
        if args.trials % args.devices:
            ap.error("--trials must be a multiple of --devices")
    if args.autotune and args.backend != "pallas":
        ap.error("--autotune tunes the pallas kernel block size; "
                 "use --backend pallas")
    if args.packed and args.backend == "event":
        ap.error("--packed runs the batched engines; use --backend "
                 "numpy, jax, or pallas")
    if args.metric not in ("downtime", "latency"):
        if args.dupres_ticks is not None or args.rebuild_steps is not None \
                or args.rebuild_model is not None \
                or args.rebuild_ticks_per_gib is not None \
                or args.size_dist is not None \
                or args.size_skew is not None \
                or args.node_bandwidth_gibps is not None:
            ap.error("--dupres-ticks/--rebuild-steps/--rebuild-model/"
                     "--rebuild-ticks-per-gib/--size-dist/--size-skew/"
                     "--node-bandwidth-gibps only apply to "
                     "--metric downtime or latency")
    if args.metric != "downtime":
        if args.engines is not None or args.lease_ticks is not None \
                or args.view_change_ticks is not None:
            ap.error("--engines/--lease-ticks/--view-change-ticks select "
                     "the protocol zoo; use --metric downtime")
    if args.engines is None:
        args.engines = "lark,quorum"
    if args.lease_ticks is None:
        args.lease_ticks = 0
    if args.view_change_ticks is None:
        args.view_change_ticks = 0
    if args.metric != "latency":
        if args.key_zipf is not None or args.read_frac is not None \
                or args.requests_per_tick is not None \
                or args.slo_ticks is not None:
            ap.error("--key-zipf/--read-frac/--requests-per-tick/"
                     "--slo-ticks model the request workload; use "
                     "--metric latency")
    elif args.backend == "event":
        ap.error("--metric latency runs the batched engines; use "
                 "--backend numpy, jax, or pallas")
    if args.metric == "latency":
        if args.key_zipf is None:
            args.key_zipf = 1.0
        if args.read_frac is None:
            args.read_frac = 0.8
        if args.requests_per_tick is None:
            args.requests_per_tick = 32.0
        if args.slo_ticks is None:
            args.slo_ticks = 8
    else:
        # other metrics never read these; keep the DowntimeParams
        # zero-request defaults so params equality is stable
        args.key_zipf, args.read_frac = 0.0, 1.0
        args.requests_per_tick, args.slo_ticks = 0.0, 0
    if args.rebuild_model is None:
        args.rebuild_model = "fixed"
    if args.rebuild_model == "reconfig" and args.rebuild_steps is not None:
        ap.error("--rebuild-steps is the fixed-model knob; use "
                 "--rebuild-ticks-per-gib with --rebuild-model reconfig")
    if args.rebuild_model == "fixed" \
            and args.rebuild_ticks_per_gib is not None:
        ap.error("--rebuild-ticks-per-gib is the reconfig-model knob; use "
                 "--rebuild-steps with --rebuild-model fixed")
    if args.rebuild_model == "fixed" \
            and (args.size_dist is not None or args.size_skew is not None
                 or args.node_bandwidth_gibps is not None):
        ap.error("--size-dist/--size-skew/--node-bandwidth-gibps model "
                 "the reconfiguring baseline's data-sized catch-ups; use "
                 "--rebuild-model reconfig")
    if args.size_skew is not None \
            and args.size_dist not in ("zipf", "lognormal"):
        ap.error("--size-skew shapes the zipf/lognormal size "
                 "distributions; pass --size-dist zipf|lognormal")
    if args.dupres_ticks is None:
        args.dupres_ticks = 1
    if args.rebuild_steps is None:
        args.rebuild_steps = 100
    if args.rebuild_ticks_per_gib is None:
        args.rebuild_ticks_per_gib = 100
    if args.size_dist is None:
        args.size_dist = "uniform"
    if args.size_skew is None:
        args.size_skew = 1.0
    if args.node_bandwidth_gibps is None:
        args.node_bandwidth_gibps = math.inf
    # the knob *values* are validated in exactly one place — the
    # DowntimeParams dataclass the engine itself consumes — so the CLI,
    # direct simulate_downtime_batched() calls, and the CI smoke lane
    # all raise the identical errors
    try:
        dt_params = DowntimeParams(
            dupres_ticks=args.dupres_ticks,
            rebuild_steps=args.rebuild_steps,
            rebuild_model=args.rebuild_model,
            rebuild_ticks_per_gib=args.rebuild_ticks_per_gib,
            size_dist=args.size_dist, size_skew=args.size_skew,
            node_bandwidth_gibps=args.node_bandwidth_gibps,
            key_zipf=args.key_zipf, read_frac=args.read_frac,
            requests_per_tick=args.requests_per_tick,
            slo_ticks=args.slo_ticks,
            engines=tuple(e.strip() for e in args.engines.split(",")
                          if e.strip()),
            lease_ticks=args.lease_ticks,
            view_change_ticks=args.view_change_ticks)
    except ValueError as e:
        ap.error(str(e))

    names = _resolve_scenarios(args, ap)
    rows = []
    pac_block_p = block_t = None
    if args.autotune:
        n, parts = _grid_scale(args.full, args.smoke)
        # rf of the first row the sweep will actually run (scenario grid
        # when the i.i.d. grid is skipped)
        if args.scenarios_only and names:
            tune_rf = get_scenario(names[0]).grid[0][0]
        else:
            tune_rf = _iid_grid(args.full, args.smoke)[0][0]
        pac_block_p, block_t, row = _autotune_row(
            n, parts, args.trials, args.devices, metric=args.metric,
            rf=tune_rf, rebuild_model=args.rebuild_model,
            packed=args.packed)
        rows.append(row)

    if args.metric == "latency":
        common = dict(full=args.full, trials=args.trials,
                      backend=args.backend, devices=args.devices,
                      smoke=args.smoke, pac_block_p=pac_block_p,
                      params=dt_params, packed=args.packed,
                      block_t=block_t)
        if not args.scenarios_only:
            for r in run_latency(**common):
                rows.append(r)
                print(f"latency,rf{r['rf']}_p{r['p']:g},0,"
                      f"lat_lark={r['lat_lark']:.3e};"
                      f"lat_quorum={r['lat_quorum']:.3e};"
                      f"p999_lark={r['p999_lark']:g};"
                      f"p999_quorum={r['p999_quorum']:g};"
                      f"slo_quorum={r['slo_quorum']:.3e}")
        if names:
            for r in run_latency_scenarios(names, **common):
                rows.append(r)
                print(f"latency_scenario,{r['scenario']}_rf{r['rf']}_"
                      f"p{r['p']:g},0,lat_lark={r['lat_lark']:.3e};"
                      f"lat_quorum={r['lat_quorum']:.3e};"
                      f"p999_quorum={r['p999_quorum']:g};"
                      f"slo_quorum={r['slo_quorum']:.3e}")
    elif args.metric == "downtime":
        common = dict(full=args.full, trials=args.trials,
                      backend=args.backend, devices=args.devices,
                      smoke=args.smoke, pac_block_p=pac_block_p,
                      params=dt_params, packed=args.packed,
                      block_t=block_t)
        if not args.scenarios_only:
            for r in run_downtime(**common):
                rows.append(r)
                if r["kind"] == "downtime_engine":
                    print(f"downtime_engine,{r['engine']}_rf{r['rf']}_"
                          f"p{r['p']:g},0,pause={r['pause']:.3e};"
                          f"events={r['events']}")
                else:
                    print(f"downtime,rf{r['rf']}_p{r['p']:g},0,"
                          f"pause_lark={r['pause_lark']:.3e};"
                          f"pause_quorum={r['pause_quorum']:.3e};"
                          f"ratio={r['ratio']:.2f}")
        if names:
            for r in run_downtime_scenarios(names, **common):
                rows.append(r)
                if r["kind"] == "downtime_engine_scenario":
                    print(f"downtime_engine_scenario,{r['engine']}_"
                          f"{r['scenario']}_rf{r['rf']}_p{r['p']:g},0,"
                          f"pause={r['pause']:.3e};events={r['events']}")
                else:
                    print(f"downtime_scenario,{r['scenario']}_rf{r['rf']}_"
                          f"p{r['p']:g},0,pause_lark={r['pause_lark']:.3e};"
                          f"pause_quorum={r['pause_quorum']:.3e};"
                          f"ratio={r['ratio']:.2f}")
    else:
        if not args.scenarios_only:
            for r in run(full=args.full, seeds=tuple(range(args.trials)),
                         backend=args.backend, devices=args.devices,
                         smoke=args.smoke, pac_block_p=pac_block_p,
                         packed=args.packed, block_t=block_t):
                rows.append(r)
                print(f"availability,rf{r['rf']}_p{r['p']:g},0,"
                      f"u_lark={r['u_lark']:.3e};u_maj={r['u_maj']:.3e};"
                      f"ratio={r['ratio']:.2f};"
                      f"analytic={r['analytic_ratio']}")
        if names:
            for r in run_scenarios(names, full=args.full,
                                   trials=args.trials,
                                   backend=args.backend,
                                   devices=args.devices,
                                   smoke=args.smoke,
                                   pac_block_p=pac_block_p,
                                   packed=args.packed, block_t=block_t):
                rows.append(r)
                print(f"availability_scenario,{r['scenario']}_rf{r['rf']}_"
                      f"p{r['p']:g},0,u_lark={r['u_lark']:.3e};"
                      f"u_maj={r['u_maj']:.3e};ratio={r['ratio']:.2f}")
    if args.json:
        meta = {"backend": args.backend, "trials": args.trials,
                "devices": args.devices, "full": args.full,
                "smoke": args.smoke, "scenarios": names,
                "metric": args.metric, "packed": args.packed}
        if args.metric == "latency":
            meta["key_zipf"] = args.key_zipf
            meta["read_frac"] = args.read_frac
            meta["requests_per_tick"] = args.requests_per_tick
            meta["slo_ticks"] = args.slo_ticks
        # zoo meta only when the zoo is actually in play — a default
        # lark,quorum run keeps emitting the pre-zoo meta byte for byte,
        # so committed baselines regen-diff clean across this change
        if args.metric == "downtime" and (
                args.engines != "lark,quorum" or args.lease_ticks
                or args.view_change_ticks):
            meta["engines"] = args.engines
            meta["lease_ticks"] = args.lease_ticks
            meta["view_change_ticks"] = args.view_change_ticks
        if args.metric in ("downtime", "latency"):
            meta["rebuild_model"] = args.rebuild_model
            meta["size_dist"] = args.size_dist
            # match the result rows' normalization: the skew knob is
            # inert under uniform, so record it as 0 there
            meta["size_skew"] = args.size_skew \
                if args.size_dist in ("zipf", "lognormal") else 0.0
            meta["node_bandwidth_gibps"] = \
                None if math.isinf(args.node_bandwidth_gibps) \
                else args.node_bandwidth_gibps
        doc = {"meta": meta,
               "rows": [_json_safe(r) for r in rows]}
        with open(args.json, "w") as fh:
            json.dump(doc, fh, indent=1, sort_keys=True, allow_nan=False)
    return 0


def _json_safe(row):
    """Non-finite floats (a ratio over a zero pause/unavailability) are not
    RFC-JSON; dump them as null so jq/strict parsers can read the file."""
    return {k: (None if isinstance(v, float) and not math.isfinite(v) else v)
            for k, v in row.items()}


if __name__ == "__main__":
    main()
