"""Paper §5.1 / Figure 6 / Table 2: availability vs node-failure probability,
and (--metric downtime) the §6 commit-pause comparison.

This is a thin CLI over the declarative experiment layer
(src/repro/experiments/): every flag below maps 1:1 onto an
``ExperimentSpec`` field, the validation lives in the spec (gating) and
``DowntimeParams`` (values), and the sweep itself runs through
``ExperimentRunner``.  The same run is therefore expressible three
equivalent ways — flags, ``--config benchmarks/configs/<name>.toml``, or
``ExperimentSpec.create(...)`` — and all three produce byte-identical
rows (pinned per committed baseline by tests/test_experiments.py and
CI's reproducibility lane).

Reduced grid by default (CPU budget); --full sweeps the paper's p range
with n=155, P=4096 and CI early-stopping; --smoke shrinks everything for
the CI pallas-interpret lane.  Emits CSV rows:
  availability,<rf>,<p>,u_lark,u_maj,ratio,analytic_ratio,ticks

--metric downtime swaps the instantaneous engine for the batched
commit-pause engine (core/downtime_batched.py): rows carry the mean
commit-pause fraction of LARK vs the equal-storage quorum-log baseline,
the pause-duration histograms, and the dup-res / rebuild knobs
(--dupres-ticks / --rebuild-steps).  --rebuild-model picks the baseline:
"fixed" (static first-rf replica set, constant rebuild pause) or
"reconfig" (replica-set reconfiguration onto live nodes with a
data-sized catch-up, --rebuild-ticks-per-gib per GiB of per-partition
data; --size-dist/--size-skew shape the per-partition sizes — uniform,
zipf, lognormal at a pinned 1.5 GiB mean — and --node-bandwidth-gibps
makes concurrent catch-ups share each recruit node's ingest bandwidth).
Downtime rows are batched-only ("event" maps to "numpy").
--engines grows the comparison into the protocol zoo: beyond the
lark/quorum pair every downtime row carries, "hermes" (broadcast
replication under membership leases, --lease-ticks write-block window)
and "spinnaker" (Paxos with reconfiguration, --view-change-ticks
log-reconciliation pause on leader loss; reconfig model only) each add
one "downtime_engine" row per grid point, keyed by engine name.

--metric latency layers the client-traffic request engine
(core/client_latency.py) over the same trajectories: zipf key popularity
(--key-zipf) mapped onto partitions, a --read-frac read/write mix at
--requests-per-tick offered cluster load, per-key dup-res first-touch
charges for LARK vs full rebuild-wait charges for the quorum-log
baseline (and the Hermes-style read-local contrast).  Rows carry
p50/p99/p999 added commit latency, the --slo-ticks violation fraction
(strict >; --slo-curve-bins adds the full violation curve over the
2^j - 1 threshold sweep), and the quorum wait histogram.  --write-skew
draws each partition's write fraction around 1 - read_frac (mean-pinned,
independent of key popularity), and --node-bandwidth-gibps makes
fixed-model rebuilds share node ingest bandwidth just like reconfig
catch-ups.  Latency rows accept every downtime knob
(the protocol under the workload is the same) and are batched-only.

Backends (--backend):
  event    scalar heapq event engine (core/availability.py); --trials N runs
           N sequential seeds and averages — the seed repo's behavior
  numpy    batched engine (core/availability_batched.py), vectorized numpy
           PAC, python chunk loop
  jax      batched engine, jit + lax.scan, pure-jnp PAC oracle
  pallas   batched engine, PAC through kernels/pac_eval.py (compiled on
           TPU, interpret mode on CPU — slow there; use for validation)

For the batched backends --trials N advances N independent trajectories in
one device program; --devices D shards them over a 1-D "trials" mesh
(bit-identical to --devices 1 for the same seed; on CPU set
XLA_FLAGS=--xla_force_host_platform_device_count=D).  --autotune (pallas)
races kernel block_p candidates before the sweep and runs the grid at the
winner.

Failure models come from the scenario registry (core/scenarios.py):
--scenario NAME appends that scenario's (rf, p) grid on top of the i.i.d.
rows ('all' = every registered name; repeatable / comma-separated).
--scenarios is the legacy alias for --scenario all; --scenarios-only skips
the i.i.d. grid.  Scenario rows always use the batched engine ("event"
maps to "numpy" — the scalar engine has no correlated/scheduled failure
model).

Artifacts: --json PATH dumps all rows with CI half-widths plus a
provenance-stamped meta (schema version, the full canonical spec, spec
content hash, config path + file hash, git SHA, seed/RNG salts,
backend/device geometry, wall-clock) — the schema
benchmarks/check_regression.py consumes.  --events PATH streams one
JSONL progress record per row with real wall-clock deltas, the input to
tools/perf_baseline.py / tools/perf_delta.py.  --config PATH replaces
the sweep flags with a committed experiment config (TOML or JSON; see
benchmarks/configs/ and docs/BENCHMARKS.md) and is mutually exclusive
with them — only --json/--events/--seed-independent output flags ride
along.
"""
from __future__ import annotations

import argparse
import sys

from repro.core.downtime_batched import ENGINES, SIZE_DISTS
from repro.experiments.runner import (FULL_GRID,  # noqa: F401 — re-exports
                                      REDUCED_GRID, SMOKE_GRID,
                                      ExperimentRunner, _autotune_row,
                                      _batched_backend, _downtime_engine_rows,
                                      _downtime_row, _grid_scale, _iid_grid,
                                      _json_safe, _latency_row, _run_scale,
                                      run, run_downtime,
                                      run_downtime_scenarios, run_latency,
                                      run_latency_scenarios, run_scenarios)
from repro.experiments.spec import ExperimentSpec, SpecError

#: argparse dest → ExperimentSpec field for every sweep flag (the 1:1
#: flag/spec mapping; output flags --json/--events/--config are not
#: spec fields and are absent on purpose)
SPEC_FLAGS = {
    "full": "full", "smoke": "smoke", "backend": "backend",
    "metric": "metric", "trials": "trials", "devices": "devices",
    "seed": "seed", "dupres_ticks": "dupres_ticks",
    "rebuild_steps": "rebuild_steps", "rebuild_model": "rebuild_model",
    "rebuild_ticks_per_gib": "rebuild_ticks_per_gib",
    "size_dist": "size_dist", "size_skew": "size_skew",
    "node_bandwidth_gibps": "node_bandwidth_gibps", "engines": "engines",
    "lease_ticks": "lease_ticks", "view_change_ticks": "view_change_ticks",
    "key_zipf": "key_zipf", "read_frac": "read_frac",
    "requests_per_tick": "requests_per_tick", "slo_ticks": "slo_ticks",
    "write_skew": "write_skew", "slo_curve_bins": "slo_curve_bins",
    "scenario": "scenarios", "scenarios": "scenarios",
    "scenarios_only": "scenarios_only", "packed": "packed",
    "autotune": "autotune",
}


def build_parser() -> argparse.ArgumentParser:
    # allow_abbrev off: a prefix typo like --ful must fail loudly, not
    # silently launch the hours-long paper-scale grid
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0],
                                 allow_abbrev=False)
    ap.add_argument("--config", metavar="PATH",
                    help="run a committed experiment config (TOML/JSON "
                         "spec; benchmarks/configs/) instead of sweep "
                         "flags — mutually exclusive with them")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid/scale (CI pallas-interpret lane)")
    ap.add_argument("--backend", default=None,
                    choices=("event", "numpy", "jax", "pallas"))
    ap.add_argument("--metric", default=None,
                    choices=("availability", "downtime", "latency"),
                    help="instantaneous availability (§5.1), commit-pause "
                         "durations (§6), or client-visible commit "
                         "latency under a keyed request workload")
    ap.add_argument("--dupres-ticks", type=int, default=None,
                    help="LARK dup-res round-trip cost in ticks "
                         "(downtime metric only; default 1)")
    ap.add_argument("--rebuild-steps", type=int, default=None,
                    help="quorum-log rebuild pause in ticks after a "
                         "replica loss (--rebuild-model fixed only; "
                         "default 100)")
    ap.add_argument("--rebuild-model", default=None,
                    choices=("fixed", "reconfig"),
                    help="quorum-log baseline: static replica set with a "
                         "constant rebuild pause (fixed, default) or "
                         "reconfiguration onto live nodes with a "
                         "data-sized catch-up (reconfig); downtime "
                         "metric only")
    ap.add_argument("--rebuild-ticks-per-gib", type=int, default=None,
                    help="reconfig catch-up cost per GiB of partition "
                         "data (--rebuild-model reconfig only; "
                         "default 100)")
    ap.add_argument("--size-dist", default=None, choices=SIZE_DISTS,
                    help="per-partition data-size distribution for the "
                         "reconfig catch-ups (default uniform [1, 2) "
                         "GiB; zipf/lognormal skew hot partitions while "
                         "pinning the same 1.5 GiB mean; "
                         "--rebuild-model reconfig only)")
    ap.add_argument("--size-skew", type=float, default=None,
                    help="skew shape of --size-dist zipf/lognormal "
                         "(Pareto exponent / log-sigma; 0 = constant "
                         "sizes; default 1)")
    ap.add_argument("--node-bandwidth-gibps", type=float, default=None,
                    help="per-node catch-up ingest bandwidth in "
                         "full-speed streams; concurrent rebuilds on one "
                         "node share it ('inf' disables sharing, the "
                         "default; applies to both rebuild models — "
                         "fixed-model rebuilds replay onto the lost "
                         "replica's own node)")
    ap.add_argument("--engines", default=None, metavar="LIST",
                    help="comma-separated protocol engines to report "
                         f"(subset of {','.join(ENGINES)}; default "
                         "lark,quorum; --metric downtime only)")
    ap.add_argument("--lease-ticks", type=int, default=None,
                    help="Hermes membership-lease expiry window: writes "
                         "block this many ticks after a replica is "
                         "suspected (--engines hermes; default 0)")
    ap.add_argument("--view-change-ticks", type=int, default=None,
                    help="Spinnaker log-reconciliation pause after a "
                         "leader loss (--engines spinnaker, "
                         "--rebuild-model reconfig; default 0)")
    ap.add_argument("--key-zipf", type=float, default=None,
                    help="zipf exponent of the key-popularity workload "
                         "(0 = exactly uniform traffic; --metric latency "
                         "only; default 1)")
    ap.add_argument("--read-frac", type=float, default=None,
                    help="fraction of requests that are reads (the rest "
                         "are writes; --metric latency only; default 0.8)")
    ap.add_argument("--requests-per-tick", type=float, default=None,
                    help="offered cluster-wide request rate "
                         "(--metric latency only; default 32)")
    ap.add_argument("--slo-ticks", type=int, default=None,
                    help="SLO threshold: rows report the fraction of "
                         "requests whose added commit latency STRICTLY "
                         "exceeds this (0 counts any added latency; "
                         "--metric latency only; default 8)")
    ap.add_argument("--write-skew", type=float, default=None,
                    help="skew the per-partition write fraction around "
                         "1 - read_frac (mean-pinned Pareto shape, own "
                         "RNG salt, independent of key popularity; 0 = "
                         "exactly uniform mix; --metric latency only; "
                         "default 0)")
    ap.add_argument("--slo-curve-bins", type=int, default=None,
                    help="report the SLO-violation curve over the "
                         "power-of-two thresholds 2^j - 1, j < BINS, "
                         "next to the --slo-ticks scalar (0 = scalar "
                         "only; --metric latency only; default 0)")
    ap.add_argument("--trials", type=int, default=None,
                    help="seeds (event) or batch size (batched backends)")
    ap.add_argument("--devices", type=int, default=None,
                    help="shard trials over this many devices (jax/pallas)")
    ap.add_argument("--seed", type=int, default=None,
                    help="base RNG seed (default 0; event backend runs "
                         "seeds seed..seed+trials-1)")
    ap.add_argument("--scenario", action="append", metavar="NAME",
                    help="append a registered scenario's grid (repeatable, "
                         "comma-separated, or 'all')")
    ap.add_argument("--scenarios", action="store_true",
                    help="legacy alias for --scenario all")
    ap.add_argument("--scenarios-only", action="store_true",
                    help="skip the i.i.d. grid (scenario rows only)")
    ap.add_argument("--packed", action="store_true",
                    help="carry cluster state as bit-packed uint32 words; "
                         "on --backend pallas every step runs the fused "
                         "megakernel (bit-identical to unpacked)")
    ap.add_argument("--autotune", action="store_true",
                    help="race pallas kernel block candidates before the "
                         "sweep (block_p; with --packed the 2-D fused "
                         "block_t x block_p race)")
    ap.add_argument("--json", metavar="PATH",
                    help="dump rows + CI half-widths + provenance-stamped "
                         "meta as JSON")
    ap.add_argument("--events", metavar="PATH",
                    help="append one JSONL progress record per row "
                         "(run_start/row/run_end with wall-clock deltas)")
    return ap


def cli_options() -> tuple:
    """Every option string this suite's parser accepts — the suite-level
    contract benchmarks/run.py uses to flag typo'd flags that no suite
    recognizes."""
    opts = []
    for action in build_parser()._actions:
        opts.extend(action.option_strings)
    return tuple(opts)


def _provided_spec_flags(args: argparse.Namespace) -> dict:
    """The spec kwargs the user explicitly set on the command line:
    store_true flags only when true, everything else only when not None
    — so the spec's metric/engine gating fires exactly on what was
    typed, never on a filled default."""
    provided = {}
    for dest, key in SPEC_FLAGS.items():
        v = getattr(args, dest)
        if v is None or v is False:
            continue
        if dest == "scenario":
            provided["scenarios"] = tuple(v)
        elif dest == "scenarios":
            # legacy alias: --scenarios alone means --scenario all
            provided.setdefault("scenarios", ("all",))
        else:
            provided[key] = v
    return provided


def build_spec(argv=None, *, strict: bool = True):
    """Parse sweep flags into (spec, args).  The seam the equivalence
    tests pin: for every committed config, build_spec() over the
    documented flag line equals ExperimentSpec.from_file(config)."""
    ap = build_parser()
    args, extra = ap.parse_known_args(argv if argv is not None
                                      else sys.argv[1:])
    if strict and extra:
        ap.error(f"unrecognized arguments: {' '.join(extra)}")
    provided = _provided_spec_flags(args)
    try:
        if args.config:
            if provided:
                flags = ", ".join("--" + k.replace("_", "-")
                                  for k in sorted(provided))
                ap.error(f"--config is mutually exclusive with sweep "
                         f"flags (got {flags}); edit the config or drop "
                         "--config")
            spec = ExperimentSpec.from_file(args.config)
        else:
            spec = ExperimentSpec.create(**provided)
    except SpecError as e:
        ap.error(str(e))
    return spec, args


def main(argv=None, *, strict: bool = True) -> int:
    spec, args = build_spec(argv, strict=strict)
    runner = ExperimentRunner(spec, config_path=args.config,
                              events_path=args.events)
    runner.run()
    if args.json:
        runner.write_summary(args.json)
    return 0


if __name__ == "__main__":
    main()
