"""Roofline analysis from the compiled dry-run artifacts (deliverable g).

Per (arch x shape) single-pod cell:
  compute_term    = HLO_FLOPs_per_device / peak_FLOPs     (197 TF/s bf16)
  memory_term     = HLO_bytes_per_device / HBM_bw         (819 GB/s)
  collective_term = collective_bytes_per_device / link_bw (50 GB/s/link)
plus MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) and the useful-compute
ratio MODEL_FLOPS / (HLO_FLOPs * chips).

HLO terms come from launch/hlo_analysis.py (loop-trip-aware; XLA's own
cost_analysis undercounts scan bodies — verified in tests/test_hlo_analysis).

The roofline_mc_step rows cover the Monte Carlo engines' per-step eval
pipeline over the sweep grid: analytic HBM bytes per step
(kernels.ops.step_hbm_bytes) for the unfused boolean path vs the fused
bit-packed megakernel, and the memory-roofline seconds each implies at
HBM_BW.  The fused path must move no more bytes than the unfused path on
every grid cell — asserted here, so a fusion regression fails the
benchmark run itself.
"""
from __future__ import annotations

import json
import math
from pathlib import Path
from typing import Dict, List, Optional

from repro.configs import ARCH_IDS, SHAPES_BY_NAME, get_config
from repro.configs.base import ModelConfig, ShapeConfig

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link
CHIPS = {"pod16x16": 256, "pod2x16x16": 512}

RESULTS_DIR = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def active_params(cfg: ModelConfig) -> float:
    """~Active parameters per token (MoE counts top-k experts only)."""
    d, L, ff, V = cfg.d_model, cfg.num_layers, cfg.d_ff, cfg.vocab_size
    h, kv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    per_layer = 0.0
    for kind in (cfg.block_pattern * (L // len(cfg.block_pattern) + 1))[:L]:
        if kind in ("attn", "local"):
            if cfg.mla is not None:
                m = cfg.mla
                qk = m.qk_nope_head_dim + m.qk_rope_head_dim
                per_layer += (d * m.q_lora_rank + m.q_lora_rank * h * qk
                              + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                              + m.kv_lora_rank * h * (m.qk_nope_head_dim
                                                      + m.v_head_dim)
                              + h * m.v_head_dim * d)
            else:
                per_layer += d * h * dh + 2 * d * kv * dh + h * dh * d
            if cfg.moe is not None:
                mult = 3 if cfg.mlp in ("swiglu", "gelu_glu") else 2
                per_layer += cfg.moe.experts_per_token * mult * d * ff
            elif ff:
                mult = 3 if cfg.mlp in ("swiglu", "gelu_glu") else 2
                per_layer += mult * d * ff
        elif kind == "mlstm":
            inner = int(cfg.proj_factor * d)
            per_layer += 2 * d * inner + 3 * inner * inner + inner * d
        elif kind == "slstm":
            per_layer += 4 * d * d + int(4 * d / 3) * d * 3
        elif kind == "rglru":
            w = cfg.lru_width
            per_layer += 2 * d * w + 2 * w * w + w * d
            mult = 3 if cfg.mlp in ("swiglu", "gelu_glu") else 2
            per_layer += mult * d * ff
    emb = V * d * (1 if cfg.tie_embeddings else 2)
    if cfg.is_encoder_decoder:
        per_layer *= 2  # encoder + cross-attention, roughly
    return per_layer + emb


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    n_act = active_params(cfg)
    if shape.kind == "train":
        return 6.0 * n_act * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n_act * shape.tokens
    return 2.0 * n_act * shape.global_batch  # decode: one token per row


def load_cells(mesh: str = "pod16x16") -> List[Dict]:
    rows = []
    for arch in ARCH_IDS:
        for shape_name in SHAPES_BY_NAME:
            f = RESULTS_DIR / f"{arch}__{shape_name}__{mesh}.json"
            if not f.exists():
                continue
            rec = json.loads(f.read_text())
            if rec["status"] != "ok":
                rows.append({"arch": arch, "shape": shape_name,
                             "status": rec["status"]})
                continue
            cfg = get_config(arch)
            shape = SHAPES_BY_NAME[shape_name]
            h = rec["hlo_analysis"]
            chips = CHIPS[mesh]
            compute_s = h["flops"] / PEAK_FLOPS
            memory_s = h["hbm_bytes"] / HBM_BW
            coll_s = h["collective_bytes_total"] / LINK_BW
            dom = max((compute_s, "compute"), (memory_s, "memory"),
                      (coll_s, "collective"))[1]
            mf = model_flops(cfg, shape)
            rows.append({
                "arch": arch, "shape": shape_name, "status": "ok",
                "compute_s": compute_s, "memory_s": memory_s,
                "collective_s": coll_s, "dominant": dom,
                "model_flops": mf,
                "useful_ratio": mf / max(h["flops"] * chips, 1),
                "roofline_fraction": compute_s / max(compute_s, memory_s,
                                                     coll_s),
                "peak_gb": rec["memory"].get("peak_bytes_per_device", 0) / 1e9,
                "collectives": h["collectives"],
            })
    return rows


#: Monte Carlo step-eval grid: (label, metric, rebuild_model, B, P, n) —
#: the sweep's reduced/full scales plus the ROADMAP million-trial target
MC_STEP_GRID = (
    ("reduced_avail", "availability", "fixed", 8, 512, 63),
    ("full_avail", "availability", "fixed", 8, 4096, 155),
    ("full_downtime", "downtime", "fixed", 8, 4096, 155),
    ("full_reconfig", "downtime", "reconfig", 8, 4096, 155),
    ("mega_reconfig", "downtime", "reconfig", 1024, 4096, 155),
)


def mc_step_rows() -> List[Dict]:
    """Analytic unfused-vs-fused HBM traffic of one Monte Carlo step per
    grid cell, with the memory-roofline time each implies."""
    from repro.kernels.ops import StepSpec, step_hbm_bytes
    rows = []
    for label, metric, model, B, P, n in MC_STEP_GRID:
        spec = StepSpec(metric=metric, rf=3, n_real=n,
                        rebuild_model=model, packed=True)
        hbm = step_hbm_bytes(spec, B, P, n)
        assert hbm["fused_bytes"] <= hbm["unfused_bytes"], \
            f"fused step moves more HBM bytes than unfused on {label}"
        rows.append({
            "label": label, "kernel": spec.fused_kernel, "B": B, "P": P,
            "n": n, "unfused_bytes": hbm["unfused_bytes"],
            "fused_bytes": hbm["fused_bytes"], "ratio": hbm["ratio"],
            "unfused_memory_s": hbm["unfused_bytes"] / HBM_BW,
            "fused_memory_s": hbm["fused_bytes"] / HBM_BW,
        })
    return rows


def cli_options() -> tuple:
    """No flags of its own (benchmarks/run.py unknown-flag contract)."""
    return ()


def main(argv=None, *, strict: bool = True):  # noqa: ARG001 - run.py contract
    rows = load_cells()
    for r in rows:
        if r["status"] != "ok":
            print(f"roofline,{r['arch']}__{r['shape']},0,status={r['status']}")
            continue
        print(f"roofline,{r['arch']}__{r['shape']},0,"
              f"compute_s={r['compute_s']:.4f};memory_s={r['memory_s']:.4f};"
              f"collective_s={r['collective_s']:.4f};dominant={r['dominant']};"
              f"useful={r['useful_ratio']:.3f};"
              f"frac={r['roofline_fraction']:.3f};peakGB={r['peak_gb']:.1f}")
    for r in mc_step_rows():
        print(f"roofline_mc_step,{r['label']},0,"
              f"kernel={r['kernel']};b{r['B']}p{r['P']}n{r['n']};"
              f"unfused_bytes={r['unfused_bytes']};"
              f"fused_bytes={r['fused_bytes']};ratio={r['ratio']:.1f};"
              f"unfused_memory_s={r['unfused_memory_s']:.3e};"
              f"fused_memory_s={r['fused_memory_s']:.3e}")
    return 0


if __name__ == "__main__":
    main()
