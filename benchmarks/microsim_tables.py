"""Paper §5.2 Tables 3-4: per-partition throughput/latency during an outage.

Emits one CSV row per table cell:
  microsim_t<3|4>,row<i>,0,thrL=...;thrB=...;ratio=...;avgL=...;p99L=...;
                         backfill=...;down=...
"""
from __future__ import annotations

import sys

from repro.core.microsim import run_table, table_configs

# (u, lf) per paper table
TABLES = {"t3": (0.5, 0.5), "t4": (0.8, 1.0)}

# published values for drift-checking: (thr_lark, thr_base, backfill, down)
PAPER_T3 = [(2500, 2364, 66, 20), (25000, 24839, 8, 2), (2500, 1356, 135, 200),
            (25000, 23640, 66, 20), (2500, 837, 149, 300),
            (25000, 13547, 135, 200), (250, 236, 65, 20), (2500, 2484, 8, 2),
            (250, 136, 135, 200), (2500, 2364, 66, 20), (250, 84, 149, 300),
            (2500, 1356, 135, 200)]
PAPER_T4 = [(3326, 3153, 69, 20), (33327, 33118, 8, 2), (3316, 1926, 172, 200),
            (33275, 31535, 69, 20), (3313, 1330, 197, 300),
            (33187, 19248, 171, 200), (332, 315, 69, 20), (3333, 3312, 8, 2),
            (331, 193, 172, 200), (3326, 3153, 69, 20), (331, 134, 199, 300),
            (3316, 1926, 172, 200)]


def run(ticks: int = 520_000):
    out = {}
    for name, (u, lf) in TABLES.items():
        out[name] = run_table(table_configs(u, lf), ticks=ticks)
    return out


def cli_options() -> tuple:
    """No flags of its own (benchmarks/run.py unknown-flag contract)."""
    return ()


def main(argv=None, *, strict: bool = True):  # noqa: ARG001 - run.py contract
    ticks = 520_000
    results = run(ticks=ticks)
    paper = {"t3": PAPER_T3, "t4": PAPER_T4}
    for name, rows in results.items():
        for i, r in enumerate(rows):
            pl = paper[name][i]
            print(f"microsim_{name},row{i+1},0,"
                  f"thrL={r['lark']['throughput']:.0f};"
                  f"thrB={r['base']['throughput']:.0f};"
                  f"ratio={r['throughput_ratio']:.2f};"
                  f"avgL={r['lark']['avg_ms']:.1f};avgB={r['base']['avg_ms']:.1f};"
                  f"p99L={r['lark']['p99_ms']};p99B={r['base']['p99_ms']};"
                  f"backfill={r['lark_backfill_s']:.0f};"
                  f"down={r['base_down_s']:.0f};"
                  f"paper_thrL={pl[0]};paper_backfill={pl[2]};paper_down={pl[3]}")
    return 0


if __name__ == "__main__":
    main()
