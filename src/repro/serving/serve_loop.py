"""Batched serving driver: prefill + decode with session checkpointing."""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import build_model
from .kv_session import LarkSessionStore


class ServeLoop:
    def __init__(self, cfg: ModelConfig, params, max_len: int = 256,
                 session_store: Optional[LarkSessionStore] = None,
                 checkpoint_every: int = 8):
        self.cfg = cfg
        self.model = build_model(cfg)
        self.params = params
        self.max_len = max_len
        self.sessions = session_store
        self.checkpoint_every = checkpoint_every
        self._prefill = jax.jit(self.model["prefill"],
                                static_argnames="max_len")
        self._decode = jax.jit(self.model["decode_step"])

    def generate(self, batch: Dict, steps: int, session_id: str = "s0",
                 greedy: bool = True) -> np.ndarray:
        logits, state = self._prefill(self.params, batch, max_len=self.max_len)
        prompt_len = (batch["tokens"].shape[1] if "tokens" in batch
                      else batch["embeds"].shape[1])
        toks: List[np.ndarray] = []
        cur = jnp.argmax(logits, -1).astype(jnp.int32)
        for i in range(steps):
            pos = jnp.int32(prompt_len + i)
            logits, state = self._decode(self.params, state, cur, pos)
            cur = jnp.argmax(logits, -1).astype(jnp.int32)
            toks.append(np.asarray(cur))
            if self.sessions is not None and (i + 1) % self.checkpoint_every == 0:
                self.sessions.save_session(session_id, state,
                                           np.stack(toks, 1), prompt_len + i + 1)
        return np.stack(toks, axis=1)

    def resume(self, session_id: str, steps: int) -> Optional[np.ndarray]:
        """Continue a session from its last committed decode state."""
        if self.sessions is None:
            return None
        ok, blob = self.sessions.load_session(session_id)
        if not ok or blob is None:
            return None
        state = jax.tree.map(jnp.asarray, blob["state"])
        toks = [blob["tokens"][:, i] for i in range(blob["tokens"].shape[1])]
        cur = jnp.asarray(toks[-1])
        for i in range(steps):
            pos = jnp.int32(blob["pos"] + i)
            logits, state = self._decode(self.params, state, cur, pos)
            cur = jnp.argmax(logits, -1).astype(jnp.int32)
            toks.append(np.asarray(cur))
        return np.stack(toks, axis=1)
