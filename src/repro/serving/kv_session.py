"""LARK-replicated serving session store.

Decode sessions (per-request KV caches / recurrent states + generated
prefixes) are exactly the paper's per-key replicated records: linearizable
read/write per session id, immediate availability across server failures
under PAC.  A session bounced to another server after a node loss resumes
from its last committed decode state via a per-key dup-res instead of a
replay log.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint.lark_store import LarkStore


class LarkSessionStore:
    def __init__(self, num_nodes: int = 4, rf: int = 2,
                 num_partitions: int = 32):
        self.store = LarkStore(num_nodes, rf=rf, num_partitions=num_partitions)

    def save_session(self, session_id: str, state, tokens: np.ndarray,
                     pos: int) -> bool:
        blob = {"state": jax.tree.map(np.asarray, state),
                "tokens": np.asarray(tokens), "pos": int(pos)}
        return self.store.put(f"session/{session_id}", blob)

    def load_session(self, session_id: str) -> Tuple[bool, Optional[dict]]:
        return self.store.get(f"session/{session_id}")

    def fail_server(self, node_id: int):
        self.store.fail_node(node_id)

    def recover_server(self, node_id: int):
        self.store.recover_node(node_id)
