from .kv_session import LarkSessionStore
from .serve_loop import ServeLoop

__all__ = ["LarkSessionStore", "ServeLoop"]
