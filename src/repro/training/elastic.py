"""Elastic training: LARK-style regimes applied to the training job itself.

Membership changes (worker loss/join, straggler eviction) mint a new regime:
  1. recluster    — agree on the worker set (exchange number++),
  2. rebalance    — rebuild the device mesh over surviving workers,
  3. restore      — pull the latest committed train state from the
                    LARK-replicated store (no log replay: per-key
                    dup-res gives the newest checkpoint shards),
  4. resume       — re-jit the step for the new mesh and continue.

On this container "workers" are host devices; on a real pod they are
processes — the control flow is identical.  Straggler mitigation is the
same path: a worker exceeding `straggler_timeout` per step is treated as a
membership change (evict -> recluster -> continue at reduced width).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.lark_store import LarkStore


@dataclass
class ElasticState:
    regime: int = 0
    workers: List[int] = field(default_factory=list)
    steps_in_regime: int = 0
    restores: int = 0


class ElasticTrainer:
    def __init__(self, num_workers: int, make_step: Callable[[List[int]], Callable],
                 store: Optional[LarkStore] = None, rf: int = 2,
                 straggler_timeout: float = 60.0):
        """make_step(workers) -> jitted step closure for that worker set."""
        self.all_workers = list(range(num_workers))
        self.make_step = make_step
        self.store = store or LarkStore(num_workers, rf=rf, num_partitions=16)
        self.state = ElasticState(regime=1, workers=list(self.all_workers))
        self.step_fn = make_step(self.state.workers)
        self.straggler_timeout = straggler_timeout

    def on_membership_change(self, workers: List[int], train_state, like):
        """Recluster + rebalance + restore; returns restored train state."""
        self.state.regime += 1
        self.state.workers = list(workers)
        self.state.steps_in_regime = 0
        # store membership follows the job membership
        for w in self.all_workers:
            alive = w in workers
            was_alive = w in self.store.sim.alive
            if alive and not was_alive:
                self.store.recover_node(w)
            elif not alive and was_alive:
                self.store.fail_node(w)
        self.step_fn = self.make_step(workers)
        ok, restored = self.store.get_pytree("train_state", like)
        self.state.restores += 1
        return restored if ok else train_state

    def checkpoint(self, train_state) -> bool:
        ok, total = self.store.put_pytree("train_state", train_state)
        return ok == total

    def run_step(self, *args):
        t0 = time.time()
        out = self.step_fn(*args)
        self.state.steps_in_regime += 1
        if time.time() - t0 > self.straggler_timeout:
            # straggler path: callers may evict and remesh
            pass
        return out
