from .train_loop import make_train_step, make_serve_steps

__all__ = ["make_train_step", "make_serve_steps"]
