"""Error-feedback int8 gradient compression for the cross-pod all-reduce.

Intra-pod reductions stay full-precision over ICI; the pod axis crosses DCN
where bandwidth is ~10-25x scarcer, so cross-pod gradient traffic is
quantized to int8 with per-tensor scales and an error-feedback accumulator
(residual carried to the next step — unbiased in the long run, standard
EF-SGD).  Implemented with shard_map + explicit ppermute-free psum over the
`pod` axis only.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_pod_psum(grads, err, mesh: Mesh):
    """psum over 'pod' with int8 payload + error feedback.

    grads/err: pytrees of f32 arrays already reduced within the pod.
    Returns (reduced_grads, new_err).
    """
    npod = dict(zip(mesh.axis_names, mesh.devices.shape)).get("pod", 1)
    if npod == 1:
        return grads, err

    def leaf_fn(g, e):
        def inner(gl, el):
            x = gl + el
            q, scale = _quantize(x)
            # int8 payload crosses the pod axis; scales are tiny f32
            summed = jax.lax.psum(q.astype(jnp.float32) * scale, "pod")
            new_e = x - q.astype(jnp.float32) * scale
            return summed / npod, new_e

        spec = P(*([None] * g.ndim))
        return shard_map(inner, mesh=mesh,
                         in_specs=(spec, spec), out_specs=(spec, spec),
                         check_rep=False)(g, e)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err)
    out = [leaf_fn(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_e = jax.tree.unflatten(treedef, [o[1] for o in out])
    return new_g, new_e


def init_error_state(grads):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)
