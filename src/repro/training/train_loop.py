"""Training/serving step factories (pure functions; jit/sharding applied by
the launcher).

``make_train_step`` builds ``step(params, opt_state, batch) -> (params,
opt_state, metrics)`` with gradient-accumulation microbatching (lax.scan, f32
accumulators) and global-norm clipping; this is the function the multi-pod
dry-run lowers for ``train_*`` cells.  ``make_serve_steps`` builds the
``prefill`` / ``decode`` serve steps for the inference cells.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import build_model
from repro.optim import clip_by_global_norm, make_optimizer


def make_train_step(cfg: ModelConfig, peak_lr: float = 3e-4,
                    clip_norm: float = 1.0,
                    grad_shardings=None,
                    batch_shardings=None) -> Tuple[Callable, Callable, Any]:
    """Returns (init_fn, step_fn, optimizer).

    init_fn(rng) -> (params, opt_state); step_fn as documented above.
    grad_shardings: optional pytree of NamedShardings for the f32 gradient
    accumulator (ZeRO-style: launcher passes param specs + a `data` shard so
    accumulation happens on reduce-scattered shards, not full replicas).
    batch_shardings: optional pytree of NamedShardings for the *unsplit*
    batch.  CRITICAL with microbatching: after reshape(B) -> (nmb, B/nmb)
    GSPMD may migrate the data-parallel axis onto the microbatch-count dim
    (replicating every row on every device — observed 16x redundant compute
    and per-device S x S f32 score stacks); constraining the reshaped batch
    to P(None, <original batch spec>) pins DP onto the row dim.
    """
    model = build_model(cfg)
    opt = make_optimizer(cfg.optimizer, peak_lr)
    nmb = max(1, cfg.microbatches_train)

    def init_fn(rng):
        params = model["init_params"](rng)
        return params, opt.init(params)

    grad_fn = jax.value_and_grad(lambda p, b: model["loss_fn"](p, b), has_aux=True)

    def _constrain(g):
        if grad_shardings is None:
            return g
        return jax.tree.map(jax.lax.with_sharding_constraint, g, grad_shardings)

    def step_fn(params, opt_state, batch):
        if nmb == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            grads = _constrain(jax.tree.map(
                lambda g: g.astype(jnp.float32), grads))
        else:
            mb = jax.tree.map(
                lambda x: x.reshape((nmb, x.shape[0] // nmb) + x.shape[1:]), batch)
            if batch_shardings is not None:
                from jax.sharding import NamedSharding, PartitionSpec as P

                def shift(x, sh):
                    return jax.lax.with_sharding_constraint(
                        x, NamedSharding(sh.mesh, P(None, *sh.spec)))
                mb = jax.tree.map(shift, mb, batch_shardings)

            def acc_body(acc, micro):
                g_acc, l_acc = acc
                (l, _), g = grad_fn(params, micro)
                g = _constrain(g)   # ZeRO-2: reduce-scatter before accumulate
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (_constrain(g_acc), l_acc + l), None

            g0 = _constrain(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (g_sum, l_sum), _ = jax.lax.scan(
                acc_body, (g0, jnp.zeros((), jnp.float32)), mb)
            grads = jax.tree.map(lambda g: (g / nmb), g_sum)
            loss = l_sum / nmb
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)
        out_metrics = {"loss": loss, "grad_norm": gnorm}
        return params, opt_state, out_metrics

    return init_fn, step_fn, opt


def make_serve_steps(cfg: ModelConfig):
    """Returns (prefill_fn, decode_fn, model) for the inference cells.

    prefill_fn(params, batch, max_len) -> (last_logits, decode_state)
    decode_fn(params, state, tokens, pos) -> (logits, new_state)
    """
    model = build_model(cfg)

    def prefill_fn(params, batch, max_len: int):
        return model["prefill"](params, batch, max_len)

    def decode_fn(params, state, tokens, pos, positions=None):
        return model["decode_step"](params, state, tokens, pos,
                                    positions=positions)

    return prefill_fn, decode_fn, model
