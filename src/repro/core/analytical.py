"""Analytical availability model — paper Appendix C.

u = per-node unavailability; with per-tick failure probability p and fixed
downtime r ticks, u = p*r / (1 + p*r) (alternating renewal).

  Pr[unavail_LARK] ~ u^{f+1}                      (eq. 2)
  Pr[unavail_Raft] ~ C(2f+1, f+1) u^{f+1}         (eq. 3, leading term)
  improvement      ~ C(2f+1, f+1)  = 3, 10, 35 for f = 1, 2, 3   (eq. 4)
"""
from __future__ import annotations

import math


def node_unavailability(p: float, r: int = 10) -> float:
    return p * r / (1.0 + p * r)


def lark_unavailability(u: float, f: int) -> float:
    return u ** (f + 1)


def raft_unavailability(u: float, f: int, exact: bool = False) -> float:
    n = 2 * f + 1
    if not exact:
        return math.comb(n, f + 1) * u ** (f + 1)
    return sum(math.comb(n, k) * u ** k * (1 - u) ** (n - k)
               for k in range(f + 1, n + 1))


def improvement_factor(f: int) -> int:
    return math.comb(2 * f + 1, f + 1)
