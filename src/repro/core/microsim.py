"""Per-partition throughput/latency micro-simulator — paper §5.2.

Discrete-time (1 ms tick) queueing simulation in JAX (lax.scan), reproducing
Tables 3-4:

  * per-partition bandwidth budget bw; RTT = 1 ms; processor sharing: all
    in-flight ops share the foreground bandwidth equally,
  * workload: uniform (deterministic-rate) arrivals, 80/20 read:write;
    reads move rs bytes, writes move 2*lf*rs (client->leader + leader->replica
    legs — this reproduces every throughput cell, see DESIGN.md §9),
  * arrival rate lambda = u * bw / (0.8*rs + 0.2*2*lf*rs),
  * LARK: node fails t=2s, returns t=302s; service continues throughout; on
    return, backfill transfers the keys written during the outage at 20% of
    bw (foreground keeps 80%) — a pending key rewritten by foreground traffic
    leaves the queue (the returned node is a cluster replica again, so new
    writes reach it synchronously).  Key-count dynamics are fluid-modeled:
      outage:   dD/dt = +w_rate * (1 - D/N)          (distinct keys written)
      backfill: dP/dt = -bf_rate - w_rate * P/N      (transfer + rewrites)
  * BASELINE (quorum-log, equal storage): hydrates a replacement at full bw
    and pauses service for min(ps/bw, 300)s; arrivals during the pause are
    rejected.

Implementation: age-cohort processor sharing.  Every op that arrives in the
same tick with the same class (read/write) is identical, so in-flight state
is (AGES x 2) cohort counts + per-op remaining bytes — O(AGES) per tick,
exact PS, exact per-op latencies.  The 12-row table grid is vmapped.

Throughput is measured over [0, W], W = LARK backfill completion (the
paper's measurement window).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

TICKS_PER_S = 1000
FAIL_T = 2 * TICKS_PER_S
RECOVER_T = 302 * TICKS_PER_S
AGES = 512          # max tracked sojourn (ms); completions clamp here
MAX_ARR = 64        # max arrivals per tick (33/tick at bw=50MB/s, rs=1KB)


@dataclass(frozen=True)
class MicroConfig:
    rs: float          # record size, bytes
    ps: float          # partition size, bytes
    bw: float          # bandwidth budget, bytes/s
    u: float           # offered load fraction
    lf: float          # log-bytes fraction (write transfer = lf*rs per leg)
    read_frac: float = 0.8

    @property
    def avg_req_bytes(self) -> float:
        return self.read_frac * self.rs + (1 - self.read_frac) * 2 * self.lf * self.rs

    @property
    def arrival_rate(self) -> float:  # ops per second
        return self.u * self.bw / self.avg_req_bytes


def _simulate_batch(rs, ps, bw, u, lf, read_frac, is_lark, ticks, seed):
    """Vectorized over config rows.  All args are (R,) arrays; is_lark bool."""
    R = rs.shape[0]
    rate_pt = u * bw / (read_frac * rs + (1 - read_frac) * 2 * lf * rs) / TICKS_PER_S
    wbytes = 2 * lf * rs
    n_keys = jnp.maximum(ps / rs, 1.0)
    w_rate = rate_pt * (1 - read_frac)                    # writes per tick
    bf_rate = 0.2 * bw / rs / TICKS_PER_S                 # backfill keys/tick
    base_down = jnp.minimum(ps / bw, 300.0) * TICKS_PER_S  # ticks

    def step(state, t):
        rem, cnt, acc, key, pending, okeys, hist, done_w = state
        # rem/cnt: (R, AGES, 2) per-op remaining bytes / cohort counts
        in_outage = (t >= FAIL_T) & (t < RECOVER_T)
        backfilling = is_lark & (t >= RECOVER_T) & (pending > 0.5)   # (R,)
        base_paused = (~is_lark) & (t >= FAIL_T) & (t < FAIL_T + base_down)

        # ---- arrivals ------------------------------------------------------
        acc = acc + rate_pt
        n_arr = jnp.floor(acc)
        acc = acc - n_arr
        key, sub = jax.random.split(key)
        r_draw = jax.random.uniform(sub, (R, MAX_ARR))
        arr_mask = jnp.arange(MAX_ARR)[None, :] < n_arr[:, None]
        n_read = jnp.sum(arr_mask & (r_draw < read_frac[:, None]),
                         axis=1).astype(jnp.float32)
        n_write = jnp.sum(arr_mask & (r_draw >= read_frac[:, None]),
                          axis=1).astype(jnp.float32)
        n_read = jnp.where(base_paused, 0.0, n_read)
        n_write_eff = jnp.where(base_paused, 0.0, n_write)

        # age-advance: shift cohorts (age 0 = newest)
        rem = jnp.roll(rem, 1, axis=1).at[:, 0].set(0.0)
        cnt = jnp.roll(cnt, 1, axis=1).at[:, 0].set(0.0)
        rem = rem.at[:, 0, 0].set(rs).at[:, 0, 1].set(wbytes)
        cnt = cnt.at[:, 0, 0].set(n_read).at[:, 0, 1].set(n_write_eff)

        # ---- outage / backfill key dynamics (fluid) ------------------------
        okeys = jnp.where(in_outage & is_lark,
                          okeys + w_rate * (1.0 - okeys / n_keys), okeys)
        pending = jnp.where((t == RECOVER_T) & is_lark, okeys, pending)
        pending = jnp.where(
            backfilling,
            jnp.maximum(pending - bf_rate - w_rate * pending / n_keys, 0.0),
            pending)

        # ---- processor sharing ---------------------------------------------
        # Foreground has STRICT PRIORITY over backfill (paper Table-4
        # latencies imply fg rho < 1 during backfill: backfill scavenges
        # idle capacity and still averages 0.2*bw at u <= 0.8, which is
        # what reproduces the backfill durations).
        fg_bw = bw / TICKS_PER_S + 0.0 * backfilling                  # (R,)
        total = jnp.maximum(jnp.sum(cnt, axis=(1, 2)), 1.0)
        share = fg_bw / total                                          # (R,)
        rem = jnp.where(cnt > 0, rem - share[:, None, None], rem)

        # ---- completions (rem<=0 and age >= 1 tick RTT) ---------------------
        age_ok = (jnp.arange(AGES) >= 1)[None, :, None]
        comp = (cnt > 0) & (rem <= 0.0) & age_ok
        comp_cnt = jnp.where(comp, cnt, 0.0)
        lat_hist = jnp.sum(comp_cnt, axis=2)                           # (R,AGES)
        hist = hist + lat_hist
        cnt = jnp.where(comp, 0.0, cnt)
        done_w = done_w + jnp.sum(comp_cnt, axis=(1, 2))

        return (rem, cnt, acc, key, pending, okeys, hist, done_w), \
            (jnp.sum(comp_cnt, axis=(1, 2)), pending)

    state0 = (jnp.zeros((R, AGES, 2)), jnp.zeros((R, AGES, 2)),
              jnp.zeros(R), jax.random.PRNGKey(seed),
              jnp.zeros(R), jnp.zeros(R), jnp.zeros((R, AGES)),
              jnp.zeros(R))
    state, (per_tick, pending_ts) = jax.lax.scan(step, state0,
                                                 jnp.arange(ticks))
    return {"hist": state[6], "per_tick_done": per_tick.T,   # (R, ticks)
            "pending_ts": pending_ts.T, "base_down_ticks": base_down}


_sim_jit = jax.jit(_simulate_batch, static_argnames=("is_lark", "ticks", "seed"))


def run_table(configs: List[MicroConfig], *, ticks: int = 1_000_000,
              seed: int = 0) -> List[Dict]:
    arrs = {f: jnp.asarray([getattr(c, f) for c in configs])
            for f in ("rs", "ps", "bw", "u", "lf", "read_frac")}
    lark = {k: np.asarray(v) for k, v in
            _sim_jit(arrs["rs"], arrs["ps"], arrs["bw"], arrs["u"],
                     arrs["lf"], arrs["read_frac"], True, ticks, seed).items()}
    base = {k: np.asarray(v) for k, v in
            _sim_jit(arrs["rs"], arrs["ps"], arrs["bw"], arrs["u"],
                     arrs["lf"], arrs["read_frac"], False, ticks, seed).items()}

    out = []
    for i, cfg in enumerate(configs):
        pend = lark["pending_ts"][i]
        after = np.where(pend[RECOVER_T + 1:] < 0.5)[0]  # backfilling gate
        backfill_end = RECOVER_T + 1 + (after[0] if len(after) else
                                        len(pend) - RECOVER_T - 1)
        W = min(int(backfill_end), ticks)

        def summary(r):
            done_w = float(r["per_tick_done"][i, :W].sum())
            h = r["hist"][i].astype(np.float64)
            tot = h.sum()
            avg = (h * np.arange(len(h))).sum() / max(tot, 1)
            cum = np.cumsum(h) / max(tot, 1)
            p99 = int(np.searchsorted(cum, 0.99))
            return dict(throughput=done_w / (W / TICKS_PER_S), avg_ms=avg,
                        p99_ms=p99, completed=done_w)

        ls, bs = summary(lark), summary(base)
        out.append({
            "config": cfg, "window_s": W / TICKS_PER_S,
            "lark": ls, "base": bs,
            "throughput_ratio": ls["throughput"] / max(bs["throughput"], 1e-9),
            "lark_backfill_s": (backfill_end - RECOVER_T) / TICKS_PER_S,
            "base_down_s": float(base["base_down_ticks"][i]) / TICKS_PER_S,
            "lark_ts": lark["per_tick_done"][i],
            "base_ts": base["per_tick_done"][i],
        })
    return out


# Paper Tables 3-4 grid: decimal values from §5.2.1 (displayed in the tables
# as binary-prefix: 0.9 GB ≙ 1 GB, 9.3 GB ≙ 10 GB, 48 MB/s ≙ 50 MB/s).
TABLE_GRID = [
    dict(rs=1e3, ps=0.1e9, bw=5e6), dict(rs=1e3, ps=0.1e9, bw=50e6),
    dict(rs=1e3, ps=1e9, bw=5e6), dict(rs=1e3, ps=1e9, bw=50e6),
    dict(rs=1e3, ps=10e9, bw=5e6), dict(rs=1e3, ps=10e9, bw=50e6),
    dict(rs=10e3, ps=0.1e9, bw=5e6), dict(rs=10e3, ps=0.1e9, bw=50e6),
    dict(rs=10e3, ps=1e9, bw=5e6), dict(rs=10e3, ps=1e9, bw=50e6),
    dict(rs=10e3, ps=10e9, bw=5e6), dict(rs=10e3, ps=10e9, bw=50e6),
]


def table_configs(u: float, lf: float) -> List[MicroConfig]:
    return [MicroConfig(u=u, lf=lf, **g) for g in TABLE_GRID]
