"""LARK node: per-node protocol state machine (paper §4).

Transport-agnostic: every handler returns a list of outgoing messages; the
event simulator (core/simulator.py) or the in-process checkpoint store
(repro.checkpoint.lark_store) routes them.  All five Replica-Write guard
conditions, dup-res, regimes (ER/PR/LR), rebalance with PR-match migration,
and duplicates are implemented exactly as in Algorithms 1-4 + §4.2.

Condition toggles (``disable_conditions``) exist ONLY so the Appendix-A
necessity tests can replay each counter-example schedule with one condition
switched off and observe the safety violation.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

from .messages import (CheckRegime, CheckRegimeReply, DuplicateRelease,
                       DupResReply, DupResReq, MarkReplicated, MigrateAck,
                       MigratePush, Msg, ReplicaWrite, ReplicaWriteAck)
from .pac import ALL_CONDITIONS, evaluate_pac
from .succession import cluster_replicas

LC = Tuple[int, int]
ZERO_LC: LC = (-1, -1)

REPLICATED = "replicated"
UNREPLICATED = "unreplicated"


@dataclass
class Version:
    value: Any
    lc: LC
    status: str


@dataclass
class PartitionState:
    pr: int = -1
    lr: int = -1
    leader: int = -1
    acting_leader: bool = False
    nodes_in_cluster: frozenset = frozenset()
    is_replica: bool = False
    full: bool = False
    duplicate: bool = False
    available: bool = False
    condition: Optional[str] = None
    # migration bookkeeping (leader side): duplicates yet to immigrate
    pending_immigration: Set[int] = field(default_factory=set)
    pending_emigration: Set[int] = field(default_factory=set)


@dataclass
class OpResult:
    op_id: int
    kind: str                   # "write" | "read"
    key: str
    ok: Optional[bool] = None   # None = still pending / indeterminate
    value: Any = None
    reason: str = ""


class LarkNode:
    def __init__(self, node_id: int, roster: Sequence[int],
                 successions: Dict[int, Sequence[int]], rf: int,
                 pac_conditions: Sequence[str] = ALL_CONDITIONS,
                 disable_conditions: Sequence[str] = ()):
        self.node_id = node_id
        self.roster = list(roster)
        self.successions = successions
        self.rf = rf
        self.pac_conditions = tuple(pac_conditions)
        self.disabled = set(disable_conditions)
        self.alive = True
        self.er = 0
        self.p: Dict[int, PartitionState] = {
            pid: PartitionState() for pid in successions}
        self.records: Dict[int, Dict[str, Version]] = {pid: {} for pid in successions}
        self.last_replicated: Dict[int, Dict[str, Version]] = {
            pid: {} for pid in successions}
        self.ops: Dict[int, dict] = {}
        self.results: Dict[int, OpResult] = {}
        # audit trail for safety tests: every replica-write accepted here
        self.accept_log: List[Tuple[str, LC, Any, str]] = []

    # ------------------------------------------------------------------
    # Clustering / rebalance (paper §4.1-4.2)
    # ------------------------------------------------------------------

    def predict_full(self, pid: int, new_er: int) -> bool:
        st = self.p[pid]
        return st.pr == new_er - 1 and st.full

    def exchange_info(self, new_er: int) -> dict:
        """Info this node contributes to the reclustering exchange."""
        return {
            "node": self.node_id,
            "predicted_full": {pid: self.predict_full(pid, new_er)
                               for pid in self.p},
            "duplicates": {pid: self.p[pid].duplicate for pid in self.p},
            "leader_view": {pid: (self.p[pid].pr, self.p[pid].leader,
                                  self.p[pid].lr) for pid in self.p},
        }

    def on_recluster(self, new_er: int):
        """Clustering subsystem atomically updates ER; cancels rebalances."""
        if new_er > self.er:
            self.er = new_er
        # in-flight migrations for old regimes are cancelled implicitly by
        # the PR-match check on arrival.

    def rebalance(self, pid: int, members: frozenset,
                  exchange: Dict[int, dict]) -> List[Msg]:
        """Steps 1-6 of §4.2 for one partition.  `exchange` is keyed by node.

        Returns migration messages (step 5/6 kickoff happens lazily via
        request_migrations()).
        """
        assert self.node_id in members
        new_er = self.er
        st = self.p[pid]
        succ = self.successions[pid]
        predicted_full = {n for n in members
                          if exchange[n]["predicted_full"].get(pid, False)}

        # Step 2: availability
        res = evaluate_pac(cluster=set(members), roster=self.roster,
                           succession=succ, rf=self.rf,
                           full_nodes=predicted_full,
                           conditions=self.pac_conditions)
        if not res.available:
            st.full = False
            st.available = False
            st.condition = None
            st.is_replica = False
            # PR is NOT advanced (paper: steps 3-6 skipped).
            return []

        creps = cluster_replicas(succ, set(members), self.rf)

        # Step 3: retain previous leader if it is a member AND cluster replica
        leader = -1
        lr = -1
        acting = False
        prev = [(exchange[n]["leader_view"][pid]) for n in members]
        prev_regime = [(p, l, r) for (p, l, r) in prev if p == new_er - 1]
        if prev_regime:
            cand = max(prev_regime)[1]
            if cand in members and cand in creps:
                leader = cand
                lr = max(r for (p, l, r) in prev_regime if l == cand)
        if leader < 0:
            # first full node by succession order
            fulls = [n for n in succ if n in predicted_full]
            if fulls:
                leader = fulls[0]
                lr = new_er
                acting = leader not in creps
            else:
                avail = [n for n in succ if n in members]
                leader = avail[0]
                lr = new_er

        # Step 4: atomic local update
        was_replica_or_dup = st.duplicate
        st.pr = new_er
        st.lr = lr
        st.leader = leader
        st.acting_leader = acting and leader == self.node_id
        st.nodes_in_cluster = frozenset(members)
        st.is_replica = self.node_id in creps
        st.full = self.node_id in predicted_full
        st.available = True
        st.condition = res.condition
        if st.is_replica:
            st.duplicate = True  # §4.2.2: becomes duplicate on becoming replica

        # Step 5 bookkeeping (leader side): who must immigrate into me?
        if leader == self.node_id and not st.full:
            dups = {n for n in members
                    if n != self.node_id and (
                        exchange[n]["predicted_full"].get(pid, False)
                        or self._claims_duplicate(exchange[n], pid))}
            st.pending_immigration = set(dups)
            if not dups:
                # no node may hold anything newer: trivially full (step 5)
                self._immigration_complete(pid)
        else:
            st.pending_immigration = set()
        if leader == self.node_id and st.full:
            st.pending_emigration = {n for n in creps if n != self.node_id}
        return []

    @staticmethod
    def _claims_duplicate(xinfo: dict, pid: int) -> bool:
        return xinfo.get("duplicates", {}).get(pid, False)

    # ------------------------------------------------------------------
    # Migration (steps 5-6, PR-match constraint)
    # ------------------------------------------------------------------

    def migrate_out(self, pid: int, dst: int, emigration: bool) -> List[Msg]:
        """Push latest record versions into dst (leader or replica)."""
        recs = {k: (v.value, v.lc, v.status)
                for k, v in self.records[pid].items()}
        return [MigratePush(self.node_id, dst, pid, recs, self.p[pid].pr,
                            emigration)]

    def handle_migrate_push(self, m: MigratePush) -> List[Msg]:
        st = self.p[m.partition]
        # PR-match for migration (paper §4.2.1): only accept when sender and
        # receiver share the same partition regime.
        if m.sender_pr != st.pr:
            return []
        for key, (value, lc, status) in m.records.items():
            cur = self.records[m.partition].get(key)
            if cur is None or tuple(lc) > tuple(cur.lc):
                self.records[m.partition][key] = Version(value, tuple(lc), status)
                if status == REPLICATED:
                    self.last_replicated[m.partition][key] = Version(
                        value, tuple(lc), REPLICATED)
        out = [MigrateAck(self.node_id, m.src, m.partition, st.pr, m.emigration)]
        if m.emigration:
            # Step 6 receipt: replica now holds the latest of every record.
            st.full = True
            st.duplicate = True
        else:
            # Step 5 receipt (I am the immigrating leader).
            st.pending_immigration.discard(m.src)
            if not st.pending_immigration and st.leader == self.node_id \
                    and not st.full:
                out += self._immigration_complete(m.partition)
        return out

    def handle_migrate_ack(self, m: MigrateAck) -> List[Msg]:
        st = self.p[m.partition]
        if m.sender_pr != st.pr:
            return []
        if m.emigration and st.leader == self.node_id:
            st.pending_emigration.discard(m.src)
            if not st.pending_emigration:
                return self._emigration_complete(m.partition)
        return []

    def _immigration_complete(self, pid: int) -> List[Msg]:
        """All duplicates have pushed into this (leader) node -> full."""
        st = self.p[pid]
        st.full = True
        st.pending_emigration = {
            n for n in cluster_replicas(self.successions[pid],
                                        set(st.nodes_in_cluster), self.rf)
            if n != self.node_id}
        return []

    def _emigration_complete(self, pid: int) -> List[Msg]:
        """All cluster replicas full: release non-replica duplicates (§4.2.2)."""
        st = self.p[pid]
        creps = set(cluster_replicas(self.successions[pid],
                                     set(st.nodes_in_cluster), self.rf))
        return [DuplicateRelease(self.node_id, n, pid, st.pr)
                for n in st.nodes_in_cluster
                if n not in creps and n != self.node_id]

    def handle_duplicate_release(self, m: DuplicateRelease) -> List[Msg]:
        st = self.p[m.partition]
        if st.pr == m.pr and not st.is_replica:
            st.duplicate = False
        return []

    # ------------------------------------------------------------------
    # Algorithm 1: CLIENT-WRITE (leader side, phased state machine)
    # ------------------------------------------------------------------

    _op_ids = itertools.count(1)

    def client_write(self, pid: int, key: str, value: Any,
                     claimed_leader: Optional[int] = None) -> Tuple[int, List[Msg]]:
        op_id = next(self._op_ids)
        st = self.p[pid]
        leader = claimed_leader if claimed_leader is not None else self.node_id
        res = OpResult(op_id, "write", key)
        self.results[op_id] = res
        rr = st.pr                                  # Read Atomically: RR <- PR
        if leader != st.leader or st.leader != self.node_id or not st.available:
            res.ok = False
            res.reason = "not-leader"
            return op_id, []
        op = {"kind": "write", "pid": pid, "key": key, "value": value,
              "rr": rr, "lr": st.lr, "phase": "start", "pending": set(),
              "dup_replies": []}
        self.ops[op_id] = op
        return op_id, self._write_advance(op_id)

    def _needs_dupres(self, pid: int, key: str) -> bool:
        st = self.p[pid]
        cur = self.records[pid].get(key)
        cur_rr = cur.lc[0] if cur is not None else None
        return (not st.full) and (cur_rr != st.pr)

    def _write_advance(self, op_id: int) -> List[Msg]:
        op = self.ops[op_id]
        pid, key = op["pid"], op["key"]
        st = self.p[pid]
        out: List[Msg] = []

        if op["phase"] == "start":
            if self._needs_dupres(pid, key):             # line 8-10
                targets = self._dupres_targets(pid)
                if targets:
                    op["phase"] = "dupres"
                    op["pending"] = set(targets)
                    return [DupResReq(self.node_id, t, op_id, pid, key,
                                      self.node_id) for t in targets]
            op["phase"] = "after_dupres"

        if op["phase"] == "after_dupres":
            cur = self.records[pid].get(key)
            if cur is not None and cur.status == UNREPLICATED:  # line 12-15
                creps = cluster_replicas(self.successions[pid],
                                         set(st.nodes_in_cluster), self.rf)
                # re-replicate, tagged with the current regime (§4.4.1)
                new_lc = (st.pr, cur.lc[1])
                cur.lc = new_lc
                op["phase"] = "rereplicate"
                op["pending"] = {n for n in creps if n != self.node_id}
                op["rere_lc"] = new_lc
                if not op["pending"]:
                    cur.status = REPLICATED
                    self.last_replicated[pid][key] = Version(cur.value, new_lc,
                                                             REPLICATED)
                    op["phase"] = "write_local"
                else:
                    return [ReplicaWrite(self.node_id, n, op_id, pid, key,
                                         self.node_id, op["rr"], new_lc,
                                         op["lr"], cur.value, True)
                            for n in op["pending"]]
            else:
                op["phase"] = "write_local"

        if op["phase"] == "write_local":                   # lines 17-21
            cur = self.records[pid].get(key)
            vn = (cur.lc[1] + 1) if cur is not None else 0
            lc = (op["rr"], vn)
            self.records[pid][key] = Version(op["value"], lc, UNREPLICATED)
            op["lc"] = lc
            creps = cluster_replicas(self.successions[pid],
                                     set(st.nodes_in_cluster), self.rf)
            op["phase"] = "await_acks"
            op["pending"] = {n for n in creps if n != self.node_id}
            if not op["pending"]:
                return self._write_commit(op_id)
            return [ReplicaWrite(self.node_id, n, op_id, pid, key,
                                 self.node_id, op["rr"], lc, op["lr"],
                                 op["value"], False)
                    for n in op["pending"]]
        return out

    def _dupres_targets(self, pid: int) -> List[int]:
        """Nodes that may hold the latest version: reachable duplicates."""
        st = self.p[pid]
        return [n for n in st.nodes_in_cluster
                if n != self.node_id and n in st.pending_immigration
                or n != self.node_id and self._known_duplicate(pid, n)]

    def _known_duplicate(self, pid: int, n: int) -> bool:
        # The simulator fills per-exchange duplicate claims into
        # pending_immigration; additionally all cluster replicas of the
        # current regime are candidates (they accept writes).
        st = self.p[pid]
        return n in cluster_replicas(self.successions[pid],
                                     set(st.nodes_in_cluster), self.rf)

    def _write_commit(self, op_id: int) -> List[Msg]:
        op = self.ops.pop(op_id)
        pid, key = op["pid"], op["key"]
        cur = self.records[pid].get(key)
        if cur is not None and cur.lc == op.get("lc"):
            cur.status = REPLICATED                        # line 23
            self.last_replicated[pid][key] = Version(cur.value, cur.lc,
                                                     REPLICATED)
        res = self.results[op_id]
        res.ok = True                                      # line 24
        st = self.p[pid]
        creps = cluster_replicas(self.successions[pid],
                                 set(st.nodes_in_cluster), self.rf)
        if self.rf > 2:                                    # line 25 (advice)
            return [MarkReplicated(self.node_id, n, pid, key, op["lc"])
                    for n in creps if n != self.node_id]
        return []

    def _write_abort(self, op_id: int, reason: str) -> List[Msg]:
        op = self.ops.pop(op_id, None)
        res = self.results[op_id]
        res.ok = False
        res.reason = reason
        if op is None:
            return []
        pid, key = op["pid"], op["key"]
        if op.get("lc") is not None:
            cur = self.records[pid].get(key)
            if cur is not None and cur.lc == op["lc"]:
                prev = self.last_replicated[pid].get(key)   # lines 27-28
                if prev is not None:
                    self.records[pid][key] = Version(prev.value, prev.lc,
                                                     REPLICATED)
                else:
                    del self.records[pid][key]
        return []

    # ------------------------------------------------------------------
    # Algorithm 2: DUP-RES replica handler
    # ------------------------------------------------------------------

    def handle_dupres(self, m: DupResReq) -> List[Msg]:
        st = self.p[m.partition]
        if m.leader in st.nodes_in_cluster:                # line 2
            cur = self.records[m.partition].get(m.key)
            if cur is None:
                return [DupResReply(self.node_id, m.src, m.op_id, True,
                                    present=False)]
            return [DupResReply(self.node_id, m.src, m.op_id, True,
                                value=cur.value, lc=cur.lc, status=cur.status,
                                present=True)]
        return [DupResReply(self.node_id, m.src, m.op_id, False)]

    def handle_dupres_reply(self, m: DupResReply) -> List[Msg]:
        if m.op_id not in self.ops:
            return []
        op = self.ops[m.op_id]
        if m.src not in op["pending"]:
            return []
        if not m.ok:
            kind = op["kind"]
            return (self._write_abort(m.op_id, "dupres-failed") if kind == "write"
                    else self._read_abort(m.op_id, "dupres-failed"))
        op["pending"].discard(m.src)
        if m.present:
            op["dup_replies"].append(m)
        if op["pending"]:
            return []
        # all replies in: adopt the max-LC version (line: select largest LC)
        pid, key = op["pid"], op["key"]
        cur = self.records[pid].get(key)
        best = max(op["dup_replies"], key=lambda r: tuple(r.lc),
                   default=None)
        if best is not None and (cur is None or tuple(best.lc) > tuple(cur.lc)):
            self.records[pid][key] = Version(best.value, tuple(best.lc),
                                             best.status)
            if best.status == REPLICATED:
                self.last_replicated[pid][key] = Version(best.value,
                                                         tuple(best.lc),
                                                         REPLICATED)
        op["phase"] = "after_dupres"
        return (self._write_advance(m.op_id) if op["kind"] == "write"
                else self._read_advance(m.op_id))

    # ------------------------------------------------------------------
    # Algorithm 3: REPLICA-WRITE
    # ------------------------------------------------------------------

    def handle_replica_write(self, m: ReplicaWrite) -> List[Msg]:
        pid = m.partition
        st = self.p[pid]
        succ = self.successions[pid]
        # Compute atomically (paper lines 3-8):
        leader_in_cluster = m.leader in st.nodes_in_cluster
        node_in_replica_set = self.node_id in cluster_replicas(
            succ, set(st.nodes_in_cluster), self.rf)
        leader_not_too_old = m.rr + 1 >= self.er
        same_leader_regime = m.lrm == st.lr
        leader_not_too_new = st.pr + 1 >= self.er

        checks = {
            "LeaderInCluster": leader_in_cluster,
            "NodeInReplicaSet": node_in_replica_set,
            "LeaderNotTooOld": leader_not_too_old,
            "SameLeaderRegime": same_leader_regime,
            "LeaderNotTooNew": leader_not_too_new,
        }
        for c in self.disabled:   # appendix-A necessity experiments only
            checks[c] = True

        ok = ((checks["LeaderNotTooOld"] or checks["SameLeaderRegime"])
              and checks["LeaderInCluster"] and checks["LeaderNotTooNew"]
              and checks["NodeInReplicaSet"])
        if not ok:
            return [ReplicaWriteAck(self.node_id, m.src, m.op_id, False,
                                    "conditions")]
        cur = self.records[pid].get(m.key)
        cur_lc = cur.lc if cur is not None else ZERO_LC
        if tuple(m.lc) > tuple(cur_lc):                    # line 11
            status = REPLICATED if self.rf == 2 else UNREPLICATED
            self.records[pid][m.key] = Version(m.value, tuple(m.lc), status)
            if status == REPLICATED:
                self.last_replicated[pid][m.key] = Version(m.value,
                                                           tuple(m.lc),
                                                           REPLICATED)
            st.duplicate = True
            self.accept_log.append((m.key, tuple(m.lc), m.value, status))
            return [ReplicaWriteAck(self.node_id, m.src, m.op_id, True)]
        # Equal LC: idempotent re-replication of the same version is an ack.
        if tuple(m.lc) == tuple(cur_lc) and (cur is None or cur.value == m.value):
            return [ReplicaWriteAck(self.node_id, m.src, m.op_id, True)]
        return [ReplicaWriteAck(self.node_id, m.src, m.op_id, False, "stale-lc")]

    def handle_replica_write_ack(self, m: ReplicaWriteAck) -> List[Msg]:
        if m.op_id not in self.ops:
            return []
        op = self.ops[m.op_id]
        if m.src not in op["pending"]:
            return []
        if not m.ok:
            kind = op["kind"]
            return (self._write_abort(m.op_id, f"replica-reject:{m.reason}")
                    if kind == "write"
                    else self._read_abort(m.op_id, f"replica-reject:{m.reason}"))
        op["pending"].discard(m.src)
        if op["pending"]:
            return []
        if op["phase"] == "rereplicate":
            pid, key = op["pid"], op["key"]
            cur = self.records[pid].get(key)
            if cur is not None and cur.lc == op["rere_lc"]:
                cur.status = REPLICATED
                self.last_replicated[pid][key] = Version(cur.value, cur.lc,
                                                         REPLICATED)
            op["phase"] = "write_local"
            return (self._write_advance(m.op_id) if op["kind"] == "write"
                    else self._read_advance(m.op_id))
        if op["phase"] == "await_acks":
            return self._write_commit(m.op_id)
        return []

    def handle_mark_replicated(self, m: MarkReplicated) -> List[Msg]:
        cur = self.records[m.partition].get(m.key)
        if cur is not None and tuple(cur.lc) == tuple(m.lc):
            cur.status = REPLICATED
            self.last_replicated[m.partition][m.key] = Version(
                cur.value, cur.lc, REPLICATED)
        return []

    # ------------------------------------------------------------------
    # Algorithm 4: CLIENT-READ
    # ------------------------------------------------------------------

    def client_read(self, pid: int, key: str,
                    claimed_leader: Optional[int] = None) -> Tuple[int, List[Msg]]:
        op_id = next(self._op_ids)
        st = self.p[pid]
        leader = claimed_leader if claimed_leader is not None else self.node_id
        res = OpResult(op_id, "read", key)
        self.results[op_id] = res
        if leader != st.leader or st.leader != self.node_id or not st.available:
            res.ok = False
            res.reason = "not-leader"
            return op_id, []
        op = {"kind": "read", "pid": pid, "key": key, "rr": st.pr,
              "lr": st.lr, "phase": "start", "pending": set(),
              "dup_replies": []}
        self.ops[op_id] = op
        return op_id, self._read_advance(op_id)

    def _read_advance(self, op_id: int) -> List[Msg]:
        op = self.ops[op_id]
        pid, key = op["pid"], op["key"]
        st = self.p[pid]

        if op["phase"] == "start":
            if self._needs_dupres(pid, key):               # line 4-6
                targets = self._dupres_targets(pid)
                if targets:
                    op["phase"] = "dupres"
                    op["pending"] = set(targets)
                    return [DupResReq(self.node_id, t, op_id, pid, key,
                                      self.node_id) for t in targets]
            op["phase"] = "after_dupres"

        if op["phase"] == "after_dupres":
            cur = self.records[pid].get(key)
            if cur is not None and cur.status == UNREPLICATED:  # line 8-10
                creps = cluster_replicas(self.successions[pid],
                                         set(st.nodes_in_cluster), self.rf)
                new_lc = (st.pr, cur.lc[1])
                cur.lc = new_lc
                op["phase"] = "rereplicate"
                op["rere_lc"] = new_lc
                op["pending"] = {n for n in creps if n != self.node_id}
                if op["pending"]:
                    return [ReplicaWrite(self.node_id, n, op_id, pid, key,
                                         self.node_id, op["rr"], new_lc,
                                         op["lr"], cur.value, True)
                            for n in op["pending"]]
                cur.status = REPLICATED
            op["phase"] = "write_local"   # reuse label: next = check_regime

        if op["phase"] == "write_local":                   # lines 11-15
            creps = cluster_replicas(self.successions[pid],
                                     set(st.nodes_in_cluster), self.rf)
            op["phase"] = "check_regime"
            op["pending"] = {n for n in creps if n != self.node_id}
            if not op["pending"]:
                return self._read_commit(op_id)
            return [CheckRegime(self.node_id, n, op_id, pid, self.node_id,
                                st.pr) for n in op["pending"]]
        return []

    def handle_check_regime(self, m: CheckRegime) -> List[Msg]:
        st = self.p[m.partition]
        ok = st.pr == m.pr and st.leader == m.leader
        return [CheckRegimeReply(self.node_id, m.src, m.op_id, ok)]

    def handle_check_regime_reply(self, m: CheckRegimeReply) -> List[Msg]:
        if m.op_id not in self.ops:
            return []
        op = self.ops[m.op_id]
        if not m.ok:
            return self._read_abort(m.op_id, "check-regime-failed")
        op["pending"].discard(m.src)
        if op["pending"]:
            return []
        return self._read_commit(m.op_id)

    def _read_commit(self, op_id: int) -> List[Msg]:
        op = self.ops.pop(op_id)
        cur = self.records[op["pid"]].get(op["key"])
        res = self.results[op_id]
        res.ok = True
        res.value = cur.value if cur is not None else None
        return []

    def _read_abort(self, op_id: int, reason: str) -> List[Msg]:
        self.ops.pop(op_id, None)
        res = self.results[op_id]
        res.ok = False
        res.reason = reason
        return []

    # ------------------------------------------------------------------
    # Dispatch
    # ------------------------------------------------------------------

    def handle(self, m: Msg) -> List[Msg]:
        if not self.alive:
            return []
        if isinstance(m, DupResReq):
            return self.handle_dupres(m)
        if isinstance(m, DupResReply):
            return self.handle_dupres_reply(m)
        if isinstance(m, ReplicaWrite):
            return self.handle_replica_write(m)
        if isinstance(m, ReplicaWriteAck):
            return self.handle_replica_write_ack(m)
        if isinstance(m, MarkReplicated):
            return self.handle_mark_replicated(m)
        if isinstance(m, CheckRegime):
            return self.handle_check_regime(m)
        if isinstance(m, CheckRegimeReply):
            return self.handle_check_regime_reply(m)
        if isinstance(m, MigratePush):
            return self.handle_migrate_push(m)
        if isinstance(m, MigrateAck):
            return self.handle_migrate_ack(m)
        if isinstance(m, DuplicateRelease):
            return self.handle_duplicate_release(m)
        raise TypeError(m)
