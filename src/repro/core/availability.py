"""Cluster-scale availability Monte Carlo — paper §5.1.

Event-driven engine with per-tick Bernoulli failure semantics (sampled as
geometric inter-failure gaps — statistically identical, so availability only
needs recomputing at failure/recovery events; between events the unavailable
partition count is constant and accumulates as count x Delta_t).

Model (exactly the paper's):
  * n nodes, P partitions, replication factor RF; i.i.d. failure prob p per
    up-node per tick; fixed downtime r ticks.
  * LARK availability = PAC SimpleMajority only (a lower bound, per §5.1.1):
    database majority up AND >=1 roster replica up AND >=1 latest-copy holder
    up.  Latest-copy holders ("full", data-level): whenever the partition is
    available, holders := the current cluster replicas (migration modeled as
    instantaneous, consistent with Appendix C's leading-order analysis);
    while unavailable the holder set is frozen (no writes can commit).
  * Baseline = majority of the fixed 2f+1 replica-set (first 2f+1 succession
    nodes) reachable.
  * Early stop: checked every `check_every` ticks once >=200 unavailable
    events observed and the 95% CI half-width <= max(eps_abs, eps_rel * U).
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

from ..kernels.pac_np import pac_eval_rank_np
from .succession import succession_matrix_fast


#: two-sided 97.5% Student-t quantiles by degrees of freedom (CI helpers)
T975 = {1: 12.706, 2: 4.303, 3: 3.182, 4: 2.776, 5: 2.571, 6: 2.447,
        7: 2.365, 8: 2.306, 9: 2.262, 10: 2.228, 11: 2.201, 12: 2.179,
        13: 2.160, 14: 2.145, 15: 2.131, 20: 2.086, 30: 2.042}


def t975(dof: int) -> float:
    if dof in T975:
        return T975[dof]
    keys = sorted(T975)
    for k in reversed(keys):
        if dof >= k:
            return T975[k]
    return T975[keys[0]]


def _accumulate_buckets(bl: np.ndarray, bm: np.ndarray, t0: int, t1: int,
                        unl: int, unm: int, bw: int) -> None:
    """Spread a constant-unavailability segment [t0, t1) over time buckets.

    O(1) amortized: a nonzero-unavailability segment ends at the next
    recovery event, so its length is bounded by the downtime scale and
    rarely spans more than two buckets.
    """
    b0, b1 = t0 // bw, (t1 - 1) // bw
    if b0 == b1:
        bl[b0] += unl * (t1 - t0)
        bm[b0] += unm * (t1 - t0)
        return
    first = (b0 + 1) * bw - t0
    bl[b0] += unl * first
    bm[b0] += unm * first
    for b in range(b0 + 1, b1):
        bl[b] += unl * bw
        bm[b] += unm * bw
    last = t1 - b1 * bw
    bl[b1] += unl * last
    bm[b1] += unm * last


def block_ci_halfwidth(bucket_l: np.ndarray, bucket_m: np.ndarray,
                       ticks: int, bw: int, partitions: int,
                       blocks: int = 16) -> tuple:
    """Batch-means 95% CI half-widths from bucketed unavailable
    partition-ticks (per-bucket width bw, accumulated online — O(buckets)
    memory, independent of the event count).

    The binomial CI over partition-ticks badly understates variance here:
    one node failure flips many partitions at once and the whole-cluster
    majority term correlates all of them, so partition-ticks are nowhere
    near independent.  Batch means over ~`blocks` equal time blocks
    captures that correlation (blocks longer than the downtime scale are
    ~i.i.d.).
    """
    m = (ticks + bw - 1) // bw          # buckets covering [0, ticks)
    if ticks <= 0 or m < 2:
        return 0.0, 0.0
    k = min(blocks, m)
    grp = (np.arange(m) * k) // m       # bucket -> block (±1 bucket width)
    widths = np.full(m, float(bw))
    widths[-1] = ticks - (m - 1) * bw
    pt = partitions * np.bincount(grp, weights=widths, minlength=k)
    u_l = np.bincount(grp, weights=bucket_l[:m], minlength=k) / pt
    u_m = np.bincount(grp, weights=bucket_m[:m], minlength=k) / pt
    t = t975(k - 1) / math.sqrt(k)
    return t * float(u_l.std(ddof=1)), t * float(u_m.std(ddof=1))


def evaluate_rank_state(up: np.ndarray, succ: np.ndarray,
                        full_succ: np.ndarray, *, rf: int, voters: int):
    """One availability evaluation step shared by the event engine and the
    cross-backend tests: rank-space PAC via the numpy backend, plus the
    frozen-holder refresh (available partitions adopt the current cluster
    replicas as holders in place; unavailable partitions keep theirs).

    Mutates full_succ.  Returns (unavail_lark, unavail_maj, up_succ).
    """
    up_succ = up[succ]
    lark, maj, creps = pac_eval_rank_np(up_succ, full_succ, rf=rf,
                                        voters=voters, n_real=up.shape[0])
    np.copyto(full_succ, creps, where=lark[:, None])
    return int((~lark).sum()), int((~maj).sum()), up_succ


@dataclass
class AvailabilityResult:
    p: float
    rf: int
    n: int
    partitions: int
    ticks: int
    u_lark: float
    u_maj: float
    lark_events: int
    maj_events: int
    ci_lark: float
    ci_maj: float
    stopped_early: bool

    @property
    def improvement(self) -> float:
        return self.u_maj / self.u_lark if self.u_lark > 0 else math.inf


def simulate_availability(*, n: int = 155, partitions: int = 4096,
                          rf: int = 2, p: float = 1e-3, downtime: int = 10,
                          min_ticks: int = 50_000, max_ticks: int = 3_000_000,
                          eps_abs: float = 5e-6, eps_rel: float = 0.05,
                          check_every: int = 5_000, min_events: int = 200,
                          seed: int = 0) -> AvailabilityResult:
    rng = np.random.default_rng(seed)
    succ = succession_matrix_fast(partitions, range(n), seed=seed)  # (P,n)
    f = rf - 1
    voters = 2 * f + 1

    up = np.ones(n, dtype=bool)
    # succession-rank-space state: column i of row p refers to node succ[p,i]
    up_succ = up[succ]
    full_succ = np.zeros((partitions, n), dtype=bool)
    full_succ[:, :rf] = True          # initially the roster replicas are full

    heap = []  # (tick, seq, kind, node)
    seq = 0
    for node in range(n):
        t = int(rng.geometric(p))
        heapq.heappush(heap, (t, seq, "fail", node))
        seq += 1

    # initial availability
    def evaluate():
        nonlocal up_succ
        unl, unm, up_succ = evaluate_rank_state(up, succ, full_succ,
                                                rf=rf, voters=voters)
        return unl, unm

    unavail_lark, unavail_maj = evaluate()
    lark_pt = 0.0   # unavailable partition-ticks
    maj_pt = 0.0
    lark_events = 0
    maj_events = 0
    prev_t = 0
    now = 0
    stopped = False
    # online time-bucketed unavailable partition-ticks for batch-means CI
    ci_bw = max(1, max_ticks // 4096)
    bucket_l = np.zeros(max_ticks // ci_bw + 2)
    bucket_m = np.zeros(max_ticks // ci_bw + 2)

    while heap and now < max_ticks:
        t, _, kind, node = heapq.heappop(heap)
        t = min(t, max_ticks)
        if t > prev_t:
            lark_pt += unavail_lark * (t - prev_t)
            maj_pt += unavail_maj * (t - prev_t)
            if unavail_lark or unavail_maj:
                _accumulate_buckets(bucket_l, bucket_m, prev_t, t,
                                    unavail_lark, unavail_maj, ci_bw)
            prev_t = t
        now = t
        if t >= max_ticks:
            break
        if kind == "fail":
            if up[node]:
                up[node] = False
                heapq.heappush(heap, (t + downtime, seq, "recover", node))
                seq += 1
        else:
            up[node] = True
            heapq.heappush(heap, (t + int(rng.geometric(p)), seq, "fail", node))
            seq += 1
        new_lark, new_maj = evaluate()
        if new_lark > unavail_lark:
            lark_events += new_lark - unavail_lark
        if new_maj > unavail_maj:
            maj_events += new_maj - unavail_maj
        unavail_lark, unavail_maj = new_lark, new_maj

        # early-stopping check
        if now >= min_ticks and now % check_every < downtime \
                and lark_events >= min_events and maj_events >= min_events:
            pt = partitions * now
            u_l = lark_pt / pt
            u_m = maj_pt / pt
            hw_l = 1.96 * math.sqrt(max(u_l * (1 - u_l), 1e-30) / pt)
            hw_m = 1.96 * math.sqrt(max(u_m * (1 - u_m), 1e-30) / pt)
            if hw_l <= max(eps_abs, eps_rel * u_l) and \
                    hw_m <= max(eps_abs, eps_rel * u_m):
                stopped = True
                break

    ticks = max(prev_t, 1)
    pt = partitions * ticks
    u_l = lark_pt / pt
    u_m = maj_pt / pt
    # honest CI: batch means (captures the node-failure correlation across
    # partitions), floored by the binomial width for the zero-event case
    hw_l, hw_m = block_ci_halfwidth(bucket_l, bucket_m, ticks, ci_bw,
                                    partitions)
    return AvailabilityResult(
        p=p, rf=rf, n=n, partitions=partitions, ticks=ticks,
        u_lark=u_l, u_maj=u_m, lark_events=lark_events,
        maj_events=maj_events,
        ci_lark=max(hw_l, 1.96 * math.sqrt(max(u_l * (1 - u_l), 1e-30) / pt)),
        ci_maj=max(hw_m, 1.96 * math.sqrt(max(u_m * (1 - u_m), 1e-30) / pt)),
        stopped_early=stopped)
