"""Cluster-scale availability Monte Carlo — paper §5.1.

Event-driven engine with per-tick Bernoulli failure semantics (sampled as
geometric inter-failure gaps — statistically identical, so availability only
needs recomputing at failure/recovery events; between events the unavailable
partition count is constant and accumulates as count x Delta_t).

Model (exactly the paper's):
  * n nodes, P partitions, replication factor RF; i.i.d. failure prob p per
    up-node per tick; fixed downtime r ticks.
  * LARK availability = PAC SimpleMajority only (a lower bound, per §5.1.1):
    database majority up AND >=1 roster replica up AND >=1 latest-copy holder
    up.  Latest-copy holders ("full", data-level): whenever the partition is
    available, holders := the current cluster replicas (migration modeled as
    instantaneous, consistent with Appendix C's leading-order analysis);
    while unavailable the holder set is frozen (no writes can commit).
  * Baseline = majority of the fixed 2f+1 replica-set (first 2f+1 succession
    nodes) reachable.
  * Early stop: checked every `check_every` ticks once >=200 unavailable
    events observed and the 95% CI half-width <= max(eps_abs, eps_rel * U).
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

from .succession import succession_matrix_fast


@dataclass
class AvailabilityResult:
    p: float
    rf: int
    n: int
    partitions: int
    ticks: int
    u_lark: float
    u_maj: float
    lark_events: int
    maj_events: int
    ci_lark: float
    ci_maj: float
    stopped_early: bool

    @property
    def improvement(self) -> float:
        return self.u_maj / self.u_lark if self.u_lark > 0 else math.inf


def simulate_availability(*, n: int = 155, partitions: int = 4096,
                          rf: int = 2, p: float = 1e-3, downtime: int = 10,
                          min_ticks: int = 50_000, max_ticks: int = 3_000_000,
                          eps_abs: float = 5e-6, eps_rel: float = 0.05,
                          check_every: int = 5_000, min_events: int = 200,
                          seed: int = 0) -> AvailabilityResult:
    rng = np.random.default_rng(seed)
    succ = succession_matrix_fast(partitions, range(n), seed=seed)  # (P,n)
    f = rf - 1
    voters = 2 * f + 1

    up = np.ones(n, dtype=bool)
    # succession-rank-space state: column i of row p refers to node succ[p,i]
    up_succ = up[succ]
    full_succ = np.zeros((partitions, n), dtype=bool)
    full_succ[:, :rf] = True          # initially the roster replicas are full

    heap = []  # (tick, seq, kind, node)
    seq = 0
    for node in range(n):
        t = int(rng.geometric(p))
        heapq.heappush(heap, (t, seq, "fail", node))
        seq += 1

    # initial availability
    def evaluate():
        nonlocal up_succ
        up_succ = up[succ]
        majority = 2 * int(up.sum()) > n
        roster_up = up_succ[:, :rf].any(axis=1)
        full_up = (full_succ & up_succ).any(axis=1)
        lark = majority & roster_up & full_up
        # instant migration: available partitions refresh their holder set
        rank = np.cumsum(up_succ, axis=1) <= rf
        creps = up_succ & rank
        np.copyto(full_succ, creps, where=lark[:, None])
        maj = up_succ[:, :voters].sum(axis=1) * 2 > voters
        return int((~lark).sum()), int((~maj).sum())

    unavail_lark, unavail_maj = evaluate()
    lark_pt = 0.0   # unavailable partition-ticks
    maj_pt = 0.0
    lark_events = 0
    maj_events = 0
    prev_t = 0
    now = 0
    stopped = False

    while heap and now < max_ticks:
        t, _, kind, node = heapq.heappop(heap)
        t = min(t, max_ticks)
        if t > prev_t:
            lark_pt += unavail_lark * (t - prev_t)
            maj_pt += unavail_maj * (t - prev_t)
            prev_t = t
        now = t
        if t >= max_ticks:
            break
        if kind == "fail":
            if up[node]:
                up[node] = False
                heapq.heappush(heap, (t + downtime, seq, "recover", node))
                seq += 1
        else:
            up[node] = True
            heapq.heappush(heap, (t + int(rng.geometric(p)), seq, "fail", node))
            seq += 1
        new_lark, new_maj = evaluate()
        if new_lark > unavail_lark:
            lark_events += new_lark - unavail_lark
        if new_maj > unavail_maj:
            maj_events += new_maj - unavail_maj
        unavail_lark, unavail_maj = new_lark, new_maj

        # early-stopping check
        if now >= min_ticks and now % check_every < downtime \
                and lark_events >= min_events and maj_events >= min_events:
            pt = partitions * now
            u_l = lark_pt / pt
            u_m = maj_pt / pt
            hw_l = 1.96 * math.sqrt(max(u_l * (1 - u_l), 1e-30) / pt)
            hw_m = 1.96 * math.sqrt(max(u_m * (1 - u_m), 1e-30) / pt)
            if hw_l <= max(eps_abs, eps_rel * u_l) and \
                    hw_m <= max(eps_abs, eps_rel * u_m):
                stopped = True
                break

    ticks = max(prev_t, 1)
    pt = partitions * ticks
    u_l = lark_pt / pt
    u_m = maj_pt / pt
    return AvailabilityResult(
        p=p, rf=rf, n=n, partitions=partitions, ticks=ticks,
        u_lark=u_l, u_maj=u_m, lark_events=lark_events,
        maj_events=maj_events,
        ci_lark=1.96 * math.sqrt(max(u_l * (1 - u_l), 1e-30) / pt),
        ci_maj=1.96 * math.sqrt(max(u_m * (1 - u_m), 1e-30) / pt),
        stopped_early=stopped)
