"""Event-level cluster simulator for the LARK protocol.

Drives LarkNode instances through failures, network partitions, reclustering,
rebalancing and migration, with *controllable* message delivery so the
Appendix-A counter-example schedules (delay a specific Replica-Write across
two reclusters, defer one node's rebalance, ...) are expressible as tests.

Delivery modes:
  auto=True   messages delivered FIFO as part of run()/settle()
  auto=False  tests pull messages out of `sim.net` explicitly (hold/deliver)

History: every client op invocation/response is recorded for the
linearizability checker (values are made unique per write by the caller).
"""
from __future__ import annotations

import itertools
import random
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

from .messages import Msg
from .node import LarkNode, OpResult
from .pac import ALL_CONDITIONS
from .succession import cluster_replicas, succession_list


@dataclass
class HistEvent:
    time: int
    kind: str       # invoke | ok | fail | indeterminate
    op_id: int
    op_kind: str    # write | read
    key: str
    value: Any = None


class Network:
    """Message store with FIFO auto-delivery and test hooks."""

    def __init__(self, rng: Optional[random.Random] = None):
        self.queue: List[Msg] = []
        self.rng = rng
        self.dropped: List[Msg] = []

    def send_all(self, msgs: Sequence[Msg]):
        self.queue.extend(msgs)

    def pop_matching(self, pred: Callable[[Msg], bool]) -> List[Msg]:
        """Remove and return all queued messages matching pred (test hook)."""
        out = [m for m in self.queue if pred(m)]
        self.queue = [m for m in self.queue if not pred(m)]
        return out

    def pop_next(self) -> Optional[Msg]:
        return self.queue.pop(0) if self.queue else None


class LarkSim:
    def __init__(self, num_nodes: int, rf: int, num_partitions: int = 4,
                 pac_conditions: Sequence[str] = ALL_CONDITIONS,
                 disable_conditions: Sequence[str] = (),
                 seed: int = 0):
        self.rf = rf
        self.roster = list(range(num_nodes))
        self.successions = {pid: succession_list(pid, self.roster)
                            for pid in range(num_partitions)}
        self.nodes: Dict[int, LarkNode] = {
            n: LarkNode(n, self.roster, self.successions, rf,
                        pac_conditions, disable_conditions)
            for n in self.roster}
        self.net = Network(random.Random(seed))
        self.rng = random.Random(seed + 1)
        self.er_counter = 0
        self.time = 0
        self.history: List[HistEvent] = []
        self.alive: Set[int] = set(self.roster)
        self._pending_rebalance: List[Tuple[int, int, int, frozenset,
                                            dict]] = []
        self._last_exchange: Dict[int, dict] = {}
        self._last_members: frozenset = frozenset()

    # ------------------------------------------------------------------
    # Cluster membership control
    # ------------------------------------------------------------------

    def set_succession(self, pid: int, order: Sequence[int]):
        """Tests pin succession lists (e.g. lexicographic per Appendix A)."""
        self.successions[pid] = list(order)
        for n in self.nodes.values():
            n.successions = self.successions

    def fail_node(self, node_id: int, recluster: bool = True):
        self.alive.discard(node_id)
        self.nodes[node_id].alive = False
        if recluster:
            self.recluster()

    def recover_node(self, node_id: int, recluster: bool = True):
        self.alive.add(node_id)
        self.nodes[node_id].alive = True
        if recluster:
            self.recluster()

    def recluster(self, members: Optional[Set[int]] = None,
                  defer_rebalance: Sequence[int] = ()) -> int:
        """One reclustering step over `members` (default: all alive nodes).

        Models the single consensus round: mints a new exchange number, runs
        the full-status/leader exchange, then rebalances every (member,
        partition) — except nodes in `defer_rebalance`, whose rebalance is
        queued for the test to release later via run_deferred_rebalance().
        """
        members = frozenset(members if members is not None else self.alive)
        self.er_counter += 1
        er = self.er_counter
        for n in members:
            self.nodes[n].on_recluster(er)
        exchange = {n: self.nodes[n].exchange_info(er) for n in members}
        self._last_exchange = exchange
        self._last_members = members
        for n in members:
            for pid in self.successions:
                if n in defer_rebalance:
                    self._pending_rebalance.append((n, pid, er, members,
                                                    exchange))
                else:
                    self.net.send_all(self.nodes[n].rebalance(pid, members,
                                                              exchange))
        return er

    def run_deferred_rebalance(self, node_id: int, pid: Optional[int] = None):
        """Release rebalances queued by recluster(defer_rebalance=...).

        A deferred rebalance is only valid within the regime that queued it:
        if the node has since observed a newer exchange round (its er moved
        past the one captured at defer time), replaying the old rebalance
        would roll protocol state back to a dead regime — stale entries are
        dropped instead of released.
        """
        keep = []
        for (n, p, er, members, exchange) in self._pending_rebalance:
            if n == node_id and (pid is None or p == pid):
                if self.nodes[n].er == er:        # still the same regime?
                    self.net.send_all(self.nodes[n].rebalance(p, members,
                                                              exchange))
            else:
                keep.append((n, p, er, members, exchange))
        self._pending_rebalance = keep

    # ------------------------------------------------------------------
    # Migration driver (asynchronous steps 5-6)
    # ------------------------------------------------------------------

    def run_migrations(self, max_rounds: int = 8):
        """Kick off & settle immigration/emigration for all partitions."""
        for _ in range(max_rounds):
            sent = False
            for pid in self.successions:
                for n in self.alive:
                    node = self.nodes[n]
                    st = node.p[pid]
                    if st.leader == n and st.available:
                        if not st.full and st.pending_immigration:
                            for d in list(st.pending_immigration):
                                if d in self.alive and \
                                        self.nodes[d].p[pid].pr == st.pr:
                                    self.net.send_all(
                                        self.nodes[d].migrate_out(pid, n, False))
                                    sent = True
                                elif d not in self.alive:
                                    # dead duplicate can't contribute now
                                    st.pending_immigration.discard(d)
                                    if not st.pending_immigration and not st.full:
                                        self.net.send_all(
                                            node._immigration_complete(pid))
                        elif st.full and st.pending_emigration:
                            for r in list(st.pending_emigration):
                                if r in self.alive:
                                    self.net.send_all(
                                        node.migrate_out(pid, r, True))
                                    sent = True
            self.settle()
            if not sent:
                break

    # ------------------------------------------------------------------
    # Client operations
    # ------------------------------------------------------------------

    def leader_of(self, pid: int) -> Optional[int]:
        best = None
        for n in self.alive:
            st = self.nodes[n].p[pid]
            if st.available and st.leader == n:
                if best is None or st.pr > self.nodes[best].p[pid].pr:
                    best = n
        return best

    def client_write(self, pid: int, key: str, value: Any,
                     contact: Optional[int] = None) -> int:
        node_id = contact if contact is not None else self.leader_of(pid)
        if node_id is None:
            op = OpResult(-1, "write", key, ok=False, reason="no-leader")
            self.history.append(HistEvent(self.time, "invoke", -1, "write",
                                          key, value))
            self.history.append(HistEvent(self.time, "fail", -1, "write",
                                          key, value))
            return -1
        self.time += 1
        op_id, msgs = self.nodes[node_id].client_write(pid, key, value)
        self.history.append(HistEvent(self.time, "invoke", op_id, "write",
                                      key, value))
        self.net.send_all(msgs)
        self._op_owner = getattr(self, "_op_owner", {})
        self._op_owner[op_id] = node_id
        return op_id

    def client_read(self, pid: int, key: str,
                    contact: Optional[int] = None) -> int:
        node_id = contact if contact is not None else self.leader_of(pid)
        if node_id is None:
            self.history.append(HistEvent(self.time, "invoke", -1, "read", key))
            self.history.append(HistEvent(self.time, "fail", -1, "read", key))
            return -1
        self.time += 1
        op_id, msgs = self.nodes[node_id].client_read(pid, key)
        self.history.append(HistEvent(self.time, "invoke", op_id, "read", key))
        self.net.send_all(msgs)
        self._op_owner = getattr(self, "_op_owner", {})
        self._op_owner[op_id] = node_id
        return op_id

    def result(self, op_id: int) -> Optional[OpResult]:
        for n in self.nodes.values():
            if op_id in n.results:
                return n.results[op_id]
        return None

    # ------------------------------------------------------------------
    # Delivery
    # ------------------------------------------------------------------

    def deliver(self, m: Msg):
        self.time += 1
        node = self.nodes.get(m.dst)
        if node is None or not node.alive:
            self.net.dropped.append(m)
            return
        self.net.send_all(node.handle(m))

    def settle(self, max_msgs: int = 100_000):
        """Deliver all queued messages FIFO until quiescent."""
        for _ in range(max_msgs):
            m = self.net.pop_next()
            if m is None:
                break
            self.deliver(m)
        self._record_completions()

    def _record_completions(self):
        recorded = {e.op_id for e in self.history if e.kind != "invoke"}
        for n in self.nodes.values():
            for op_id, res in n.results.items():
                if op_id in recorded or res.ok is None:
                    continue
                self.history.append(HistEvent(
                    self.time, "ok" if res.ok else "fail", op_id, res.kind,
                    res.key, res.value))

    def finalize_history(self) -> List[HistEvent]:
        """Mark still-pending ops indeterminate (no client response)."""
        self._record_completions()
        recorded = {e.op_id for e in self.history if e.kind != "invoke"}
        for n in self.nodes.values():
            for op_id, res in n.results.items():
                if op_id not in recorded:
                    self.history.append(HistEvent(
                        self.time, "indeterminate", op_id, res.kind, res.key,
                        res.value))
        return self.history
