"""Protocol messages (transport-agnostic dataclasses)."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

LC = Tuple[int, int]  # (RR, VN) lexicographic logical clock


@dataclass
class Msg:
    src: int
    dst: int


@dataclass
class DupResReq(Msg):
    op_id: int
    partition: int
    key: str
    leader: int


@dataclass
class DupResReply(Msg):
    op_id: int
    ok: bool
    value: Any = None
    lc: Optional[LC] = None
    status: str = "replicated"
    present: bool = False


@dataclass
class ReplicaWrite(Msg):
    op_id: int
    partition: int
    key: str
    leader: int
    rr: int                 # leader PR at client-write start (paper line 4)
    lc: LC                  # new version's logical clock
    lrm: int                # leader's LR piggy-backed (paper: LRM)
    value: Any = None
    rereplication: bool = False


@dataclass
class ReplicaWriteAck(Msg):
    op_id: int
    ok: bool
    reason: str = ""


@dataclass
class MarkReplicated(Msg):
    partition: int
    key: str
    lc: LC


@dataclass
class CheckRegime(Msg):
    op_id: int
    partition: int
    leader: int
    pr: int


@dataclass
class CheckRegimeReply(Msg):
    op_id: int
    ok: bool


@dataclass
class MigratePush(Msg):
    partition: int
    records: Dict[str, Tuple[Any, LC, str]]
    sender_pr: int
    emigration: bool = False   # leader -> replicas (step 6) vs duplicate -> leader


@dataclass
class MigrateAck(Msg):
    partition: int
    sender_pr: int
    emigration: bool = False


@dataclass
class DuplicateRelease(Msg):
    """Leader -> non-replica duplicates after emigration completes (§4.2.2)."""
    partition: int
    pr: int
