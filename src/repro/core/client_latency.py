"""Client-traffic commit-latency engine — what requests see under failover.

The §6 engines measure *partition*-level pause fractions; the paper's
headline claim ("at most a per-key duplicate-resolution round trip when
the new leader lacks the latest copy") is about *client-visible* latency.
This layer runs a batched per-key request workload over the exact
counter-RNG trajectories of core/downtime_batched.py and reports the
commit latency distribution a request stream experiences:

  LARK     a request pays `dupres_ticks` iff it is the FIRST touch of its
           key since a leader change onto a stale leader; every later
           touch commits at zero added latency.  Modeled analytically:
           each (trial, partition) carries a dirty-key fraction per
           key-popularity bucket (N_KEY_BUCKETS zipf-rank bands of the
           partition's KEYS_PER_PARTITION keys), reset to 1 at a
           stale-leader change and decayed per event interval by the
           bucket's touch probability — O(B*P) carry, no per-request
           sampling, and the first-touch count is exact in expectation.
  quorum   every WRITE arriving while a rebuild is in flight (replica
           majority up, commits stalled on the catch-up) waits out the
           remaining rebuild: a write landing tau ticks into the
           interval pays rem - tau ticks.  Reads and writes to
           majority-down partitions are unavailability, not latency, and
           are not charged.
  hermes   the contrast model (Katsarakis et al., PAPERS.md): local
           reads NEVER pay the round trip; the write path pays the same
           per-key first-touch charge as LARK.  Derived host-side as the
           write-fraction share of LARK's charges.

Workload model: a cluster-wide request rate of `requests_per_tick`,
split over partitions by hashing `KEYS_PER_PARTITION * partitions` zipf-
popularity keys (exponent `key_zipf`; 0 = uniform) onto partitions under
a dedicated counter-RNG salt — the node-trajectory randomness stream is
untouched (invariant 3, docs/ARCHITECTURE.md), so every workload replays
the identical failure trajectories.  `read_frac` splits the rate into
reads and writes.  Outputs are p50/p99/p999 commit latency (over the
full request distribution, zeros included — the bucketed percentile is
the smallest power-of-two bucket lower edge whose CDF covers the
quantile, so p999 >= p99 >= p50 by construction), the SLO-violation
fraction (requests strictly over `slo_ticks`; slo_ticks=0 counts every
request with any added latency), and the mean added latency, each
per protocol, plus the quorum latency histogram next to the engine's
pause histograms.

Three sharpening knobs, each byte-identical to the prior model at its
degenerate setting: `write_skew` draws every partition's write fraction
around 1 - read_frac (mean-pinned Pareto factors under _WRITE_SALT,
independent of key popularity; 0 = the exactly-uniform mix),
`slo_curve_bins` reports the full SLO-violation curve over the
power-of-two threshold sweep 2^j - 1 derived from the same bucketed
histograms (the `slo_ticks` scalar IS the curve at its threshold,
exactly; 0 = scalar only), and `node_bandwidth_gibps` applies to
rebuild_model="fixed" as well — concurrent fixed-model rebuilds
replaying onto one node split its bandwidth exactly like the reconfig
catch-ups (inf = the unshared legacy model, bit-for-bit).

Zero-knob limit (pinned exactly by tests/test_client_latency.py):
dupres_ticks=0 never dirties a key, read_frac=1 zeroes the write rate —
p50/p99/p999, means, and SLO fractions are all exactly 0 on every
backend.

Bit-identity: the in-scan state is per-(trial, partition) float32
updated by exactly-rounded elementwise ops (kernels/latency.py has the
full contract); partition pooling happens host-side in float64 at chunk
drains.  Trajectories, raw accumulators, and therefore every reported
number are bit-identical across numpy / jax / pallas, packed and
unpacked carries, and devices 1-vs-N trials sharding.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from ..kernels.latency import decay_pow_tables
from .availability import t975
from .availability_batched import _mix32, _uniforms
from .downtime_batched import (BatchedDowntimeResult, DowntimeParams,
                               simulate_downtime_batched)

#: dedicated counter-RNG salt for the key -> partition hash (invariant 3:
#: per-run constants may draw from the counter-hash family under their own
#: salt without perturbing node trajectories)
_KEY_SALT = 0xC2B2AE35

#: dedicated counter-RNG salt for the per-partition write-fraction draw
#: (`write_skew`) — its own stream, so the write mix is independent of
#: both the node trajectories and the key -> partition hash
_WRITE_SALT = 0x85EBCA6B

#: keys per partition in the workload model.  A module constant, not a
#: knob: it only sets the granularity of the analytic dirty-key carry
#: (the bucket key counts K * f_b), and 1024 keys over N_KEY_BUCKETS
#: zipf-rank bands already separates hot keys (touched — and re-dirtied —
#: within a few ticks of a failover) from the cold tail.
KEYS_PER_PARTITION = 1024

#: zipf-rank bands per partition: bucket b spans ranks
#: (K^(b/4), K^((b+1)/4)] — geometric edges, so the hot head gets its own
#: tiny bucket and the cold tail its own huge one
N_KEY_BUCKETS = 4

#: the reported latency quantiles
LATENCY_QUANTILES = (0.5, 0.99, 0.999)


def partition_request_weights(seed: int, partitions: int, *,
                              key_zipf: float = 0.0,
                              keys_per_partition: int = KEYS_PER_PARTITION
                              ) -> np.ndarray:
    """(P,) float64 request-probability weights, summing to 1.

    Zipf key popularity mapped onto partitions: key rank r (of
    NK = partitions * keys_per_partition cluster-wide keys) carries
    popularity r^-key_zipf and lands on the partition drawn by its
    counter-hash under _KEY_SALT; a partition's weight is its keys'
    popularity share.  key_zipf=0 short-circuits to the exactly-uniform
    1/P table.  The normalization pins the mean weight to exactly 1/P —
    skew moves traffic between partitions, never adds offered load
    (property-tested in tests/test_client_latency.py).  Always host-side
    numpy: every backend receives the identical table."""
    if partitions <= 0:
        raise ValueError("partitions must be >= 1")
    if key_zipf == 0:
        return np.full(partitions, 1.0 / partitions)
    nk = partitions * keys_per_partition
    pop = np.arange(1, nk + 1, dtype=np.float64) ** (-float(key_zipf))
    seed_mix = _mix32(np.asarray([(seed & 0xFFFFFFFF) ^ 0x6A09E667],
                                 dtype=np.uint32), np)
    u = _uniforms(seed_mix, np.asarray(0, dtype=np.uint32), _KEY_SALT,
                  np.zeros(1, dtype=np.uint32), nk, np)[0] \
        .astype(np.float64)
    part = np.minimum((u * partitions).astype(np.int64), partitions - 1)
    w = np.bincount(part, weights=pop, minlength=partitions)
    return w / w.sum()


def partition_write_fractions(seed: int, partitions: int, *,
                              read_frac: float = 0.8,
                              write_skew: float = 0.0) -> np.ndarray:
    """(P,) float64 per-partition write fractions, mean-pinned to
    1 - read_frac.

    write_skew=0 short-circuits to the exactly-constant
    `1 - read_frac` table (the legacy uniform mix, bit-for-bit).
    Otherwise each partition draws a Pareto-shaped factor
    (1 - u)^-write_skew under _WRITE_SALT and the table is
    min(c * draw, 1) with c the unique waterfilling scale that pins the
    MEAN write fraction to `1 - read_frac` exactly — a fraction cannot
    exceed 1 (every request a write), and a naive rescale-then-clip
    would collapse the mean under the heavy Pareto tail, so the scale
    is solved against the saturation (property-tested across skews in
    tests/test_client_latency.py).  The draw is independent of key
    popularity — the partition request *rate* stays
    `partition_request_weights`, only its read/write split moves.
    Always host-side numpy: every backend receives the identical
    table."""
    if partitions <= 0:
        raise ValueError("partitions must be >= 1")
    target = 1.0 - read_frac
    if write_skew == 0 or target == 0.0 or target == 1.0:
        return np.full(partitions, target)
    seed_mix = _mix32(np.asarray([(seed & 0xFFFFFFFF) ^ 0x6A09E667],
                                 dtype=np.uint32), np)
    u = _uniforms(seed_mix, np.asarray(0, dtype=np.uint32), _WRITE_SALT,
                  np.zeros(1, dtype=np.uint32), partitions, np)[0] \
        .astype(np.float64)
    raw = (1.0 - u) ** (-float(write_skew))
    # exact waterfilling: with the m largest draws saturated at 1, the
    # scale solving mean = target is (target*P - m) / sum(rest); the
    # first m where that scale leaves draw m itself unsaturated is
    # consistent, and then mean(w) = (m + (target*P - m)) / P = target
    r = np.sort(raw)[::-1]
    tail = r[::-1].cumsum()[::-1]                 # tail[m] = sum r[m:]
    m = np.arange(partitions, dtype=np.float64)
    cm = (target * partitions - m) / tail
    msat = int(np.argmax(cm * r < 1.0))           # first consistent m
    return np.minimum(cm[msat] * raw, 1.0)


def key_bucket_shares(key_zipf: float, *,
                      keys_per_partition: int = KEYS_PER_PARTITION,
                      n_buckets: int = N_KEY_BUCKETS):
    """Within-partition key-popularity buckets: (f, g) float64 arrays of
    key-count fractions and traffic shares per zipf-rank band (geometric
    edges at K^(b/n)).  key_zipf=0 gives g == f exactly (uniform traffic
    per key), which is what makes the uniform workload's per-key touch
    rate identical across buckets."""
    K = keys_per_partition
    edges = [0]
    for b in range(1, n_buckets):
        e = int(round(K ** (b / n_buckets)))
        edges.append(min(max(e, edges[-1] + 1), K - (n_buckets - b)))
    edges.append(K)
    pop = np.arange(1, K + 1, dtype=np.float64) ** (-float(key_zipf))
    tot = pop.sum()
    f = np.asarray([(edges[b + 1] - edges[b]) / K
                    for b in range(n_buckets)])
    g = np.asarray([pop[edges[b]:edges[b + 1]].sum() / tot
                    for b in range(n_buckets)])
    return f, g


@dataclass(frozen=True)
class _LatencyPlan:
    """Host-precomputed workload tables handed to the downtime driver
    (simulate_downtime_batched's `_lat_plan`): per-bucket key counts,
    per-partition float32 write rates, and the decay power tables —
    everything the in-scan latency update consumes."""
    nbins: int
    slo_ticks: int
    kf: np.ndarray           # (NB,) float32 keys per bucket (K * f_b)
    lamw: np.ndarray         # (P,) float32 write requests/tick
    pow_tables: np.ndarray   # (nbits, P, NB) float32 decay squares
    #: (P,) float64 per-partition write fractions, or None under the
    #: uniform mix (write_skew=0) — consumed host-side at chunk drains
    #: to weight hermes' write-path share of the dup charges
    wfp: Optional[np.ndarray] = None


def _percentile(masses, total: float, q: float) -> float:
    """Smallest latency value whose CDF covers quantile q, over a
    distribution of `total` requests with point `masses` [(value, count)]
    at positive latencies and the rest at exactly 0.  Walking the sorted
    values makes q -> value non-decreasing, so p999 >= p99 >= p50 always
    holds on emitted rows.

    Boundary semantics (pinned by adversarial tests): the walk takes the
    smallest value whose cumulative mass *reaches* q * total (`>=`, not
    `>`), so a CDF landing exactly on the quantile selects that value,
    not the next one; an all-zero-mass distribution returns 0.0 for
    every q; and a total smaller than the charged mass still terminates
    (the zero mass is clamped at 0)."""
    if total <= 0:
        return 0.0
    masses = sorted((m for m in masses if m[1] > 0), key=lambda m: m[0])
    charged = sum(m[1] for m in masses)
    cdf = max(total - charged, 0.0)
    need = q * total
    if cdf >= need:
        return 0.0
    for value, count in masses:
        cdf += count
        if cdf >= need:
            return float(value)
    return float(masses[-1][0]) if masses else 0.0


@dataclass
class BatchedLatencyResult:
    """Client-visible commit-latency summary over `trials` trajectories.

    Latencies are in ticks of *added* commit latency (0 = the request
    committed at baseline speed).  Percentiles are over the full request
    distribution including the zero-latency mass; quorum values are
    power-of-two bucket lower edges (the engine bins remaining rebuild
    waits, it does not keep every distinct wait).  `req_total` is the
    offered load: requests_per_tick x elapsed ticks, summed over trials.
    """
    p: float
    rf: int
    n: int
    partitions: int
    trials: int
    backend: str
    devices: int
    ticks: int
    stopped_early: bool
    rebuild_model: str
    dupres_ticks: int
    key_zipf: float
    read_frac: float
    requests_per_tick: float
    slo_ticks: int
    req_total: float
    lat_lark: float                  # mean added latency, ticks/request
    lat_quorum: float
    lat_hermes: float
    ci_lat_lark: float               # 95% across-trial half-widths
    ci_lat_quorum: float
    p50_lark: float
    p99_lark: float
    p999_lark: float
    p50_quorum: float
    p99_quorum: float
    p999_quorum: float
    p50_hermes: float
    p99_hermes: float
    p999_hermes: float
    slo_lark: float                  # fraction of requests > slo_ticks
    slo_quorum: float
    slo_hermes: float
    write_skew: float = 0.0
    slo_curve_bins: int = 0
    node_bandwidth_gibps: float = math.inf
    #: SLO curves (slo_curve_bins > 0 only): violation fractions over
    #: the power-of-two threshold sweep 2^j - 1, j = 0..bins-1 — each
    #: curve is non-increasing in the threshold, and at the j whose
    #: threshold equals slo_ticks the curve value IS the scalar slo_*
    slo_curve_edges: np.ndarray = field(repr=False, default=None)
    slo_curve_lark: np.ndarray = field(repr=False, default=None)
    slo_curve_quorum: np.ndarray = field(repr=False, default=None)
    slo_curve_hermes: np.ndarray = field(repr=False, default=None)
    hist_edges: np.ndarray = field(repr=False, default=None)
    hist_quorum_req: np.ndarray = field(repr=False, default=None)
    lat_lark_trials: np.ndarray = field(repr=False, default=None)
    lat_quorum_trials: np.ndarray = field(repr=False, default=None)
    downtime: BatchedDowntimeResult = field(repr=False, default=None)


def make_latency_plan(seed: int, partitions: int, params: DowntimeParams,
                      max_ticks: int) -> _LatencyPlan:
    """Build the host-side workload tables for one run (all float32 by
    the time they enter the scan; the float64 -> float32 rounding happens
    once, here, identically for every backend)."""
    w = partition_request_weights(seed, partitions,
                                  key_zipf=params.key_zipf)
    f, g = key_bucket_shares(params.key_zipf)
    lam = params.requests_per_tick * w
    wfp = None
    if params.write_skew > 0:
        wfp = partition_write_fractions(seed, partitions,
                                        read_frac=params.read_frac,
                                        write_skew=params.write_skew)
        lamw = (lam * wfp).astype(np.float32)
    else:
        lamw = (lam * (1.0 - params.read_frac)).astype(np.float32)
    # same subnormal flush as the decay tables (kernels/latency.py):
    # XLA's DAZ would silently zero these, numpy would not
    lamw[lamw < np.float32(1e-30)] = 0.0
    return _LatencyPlan(
        nbins=params.hist_bins, slo_ticks=params.slo_ticks,
        kf=(KEYS_PER_PARTITION * f).astype(np.float32),
        lamw=lamw,
        pow_tables=decay_pow_tables(lam, g, f, KEYS_PER_PARTITION,
                                    max_ticks),
        wfp=wfp)


def simulate_client_latency(
        *, partitions: int = 4096, seed: int = 0,
        max_ticks: int = 3_000_000,
        key_zipf: float = 1.0, read_frac: float = 0.8,
        requests_per_tick: float = 32.0, slo_ticks: int = 8,
        write_skew: float = 0.0, slo_curve_bins: int = 0,
        dupres_ticks: int = 1, rebuild_steps: int = 100,
        hist_bins: int = 16, rebuild_model: str = "fixed",
        rebuild_ticks_per_gib: int = 100, size_dist: str = "uniform",
        size_skew: float = 1.0,
        node_bandwidth_gibps: float = math.inf,
        params: Optional[DowntimeParams] = None,
        **kwargs) -> BatchedLatencyResult:
    """Run the §6 downtime Monte Carlo with the client-latency layer
    attached and summarize what the request stream saw.

    Accepts every simulate_downtime_batched knob (cluster, scenario,
    backend/devices/packed, chunking) via **kwargs, plus the workload
    knobs above — all validated in DowntimeParams, so the CLI, this
    entry point, and tests raise identical errors.  `params` takes
    precedence over the individual protocol/workload keywords when given,
    exactly as in simulate_downtime_batched."""
    if params is None:
        params = DowntimeParams(
            dupres_ticks=dupres_ticks, rebuild_steps=rebuild_steps,
            hist_bins=hist_bins, rebuild_model=rebuild_model,
            rebuild_ticks_per_gib=rebuild_ticks_per_gib,
            size_dist=size_dist, size_skew=size_skew,
            node_bandwidth_gibps=node_bandwidth_gibps,
            key_zipf=key_zipf, read_frac=read_frac,
            requests_per_tick=requests_per_tick, slo_ticks=slo_ticks,
            write_skew=write_skew, slo_curve_bins=slo_curve_bins)
    plan = make_latency_plan(seed, partitions, params, max_ticks)
    res = simulate_downtime_batched(
        partitions=partitions, seed=seed, max_ticks=max_ticks,
        params=params, _lat_plan=plan, **kwargs)

    raw = res.latency_raw
    now = raw["now"].astype(np.float64)                       # (B,)
    req_b = params.requests_per_tick * now
    req = float(req_b.sum())
    dup_b = raw["dup"].sum(axis=1)                            # (B,)
    dup_tot = float(dup_b.sum())
    qhist = raw["qhist"].sum(axis=0)                          # (nbins,)
    qslo_tot = float(raw["qslo"].sum())
    qsum_tot = float(raw["qsum"].sum())
    wf = 1.0 - params.read_frac
    dup_cost = float(params.dupres_ticks)
    # skewed write mix: the engine pooled a second, write-fraction-
    # weighted view of the dup charges; its absence (write_skew=0) keeps
    # the legacy uniform-mix hermes expressions byte-identical
    dupw_tot = float(raw["dupw"].sum()) if "dupw" in raw else None

    if req > 0:
        lat_lark = dup_cost * dup_tot / req
        lat_quorum = qsum_tot / req
        lal_b = dup_cost * dup_b / req_b
        laq_b = raw["qsum"] / req_b
        slo_lark = (dup_tot / req) if dup_cost > params.slo_ticks else 0.0
        slo_quorum = qslo_tot / req
        if dupw_tot is not None:
            lat_hermes = dup_cost * dupw_tot / req
            slo_hermes = (dupw_tot / req) \
                if dup_cost > params.slo_ticks else 0.0
        else:
            lat_hermes = wf * lat_lark
            slo_hermes = wf * slo_lark
    else:
        lat_lark = lat_quorum = slo_lark = slo_quorum = 0.0
        lat_hermes = slo_hermes = 0.0
        lal_b = np.zeros_like(req_b)
        laq_b = np.zeros_like(req_b)
    ci_l = ci_q = 0.0
    B = res.trials
    if B >= 3:
        t = t975(B - 1) / math.sqrt(B)
        ci_l = t * float(lal_b.std(ddof=1))
        ci_q = t * float(laq_b.std(ddof=1))

    hermes_mass = dupw_tot if dupw_tot is not None else wf * dup_tot
    lark_masses = [(params.dupres_ticks, dup_tot)]
    hermes_masses = [(params.dupres_ticks, hermes_mass)]
    quorum_masses = [(1 << k, float(qhist[k]))
                     for k in range(params.hist_bins)]
    pcts = {}
    for name, masses in (("lark", lark_masses), ("quorum", quorum_masses),
                         ("hermes", hermes_masses)):
        for q in LATENCY_QUANTILES:
            key = f"p{q * 1000:g}".replace("p500", "p50").replace(
                "p990", "p99")
            pcts[f"{key}_{name}"] = _percentile(masses, req, q)

    curve_edges = curve_lark = curve_quorum = curve_hermes = None
    if params.slo_curve_bins > 0:
        # violation-fraction curves over the threshold sweep 2^j - 1.
        # A wait pays > 2^j - 1 iff it pays >= 2^j iff it landed in
        # histogram bucket >= j, so the quorum curve is the qhist tail
        # sums.  At the bin whose threshold equals slo_ticks the in-scan
        # scalar (one f32 product per interval) and the tail sum (per-
        # bucket f32 accumulators) agree only up to accumulation order,
        # so the scalar is substituted there — "the old scalar IS the
        # curve at slo_ticks" holds exactly — and the neighbors are
        # clamped (an ulp-level correction at most) to keep the curve
        # monotone non-increasing by construction.
        J = params.slo_curve_bins
        curve_edges = np.asarray([(1 << j) - 1 for j in range(J)],
                                 dtype=np.int64)
        if req > 0:
            tail = qhist[::-1].cumsum()[::-1]
            curve_quorum = tail[:J] / req
            curve_lark = np.asarray(
                [(dup_tot / req) if dup_cost > t else 0.0
                 for t in curve_edges])
            if dupw_tot is not None:
                curve_hermes = np.asarray(
                    [(dupw_tot / req) if dup_cost > t else 0.0
                     for t in curve_edges])
            else:
                curve_hermes = wf * curve_lark
            js = np.flatnonzero(curve_edges == params.slo_ticks)
            if js.size:
                j = int(js[0])
                curve_quorum[j] = slo_quorum
                curve_quorum[:j] = np.maximum(curve_quorum[:j],
                                              slo_quorum)
                curve_quorum[j + 1:] = np.minimum(curve_quorum[j + 1:],
                                                  slo_quorum)
        else:
            curve_lark = np.zeros(J)
            curve_quorum = np.zeros(J)
            curve_hermes = np.zeros(J)

    return BatchedLatencyResult(
        p=res.p, rf=res.rf, n=res.n, partitions=res.partitions,
        trials=res.trials, backend=res.backend, devices=res.devices,
        ticks=res.ticks, stopped_early=res.stopped_early,
        rebuild_model=res.rebuild_model,
        dupres_ticks=params.dupres_ticks, key_zipf=params.key_zipf,
        read_frac=params.read_frac,
        requests_per_tick=params.requests_per_tick,
        slo_ticks=params.slo_ticks, req_total=req,
        write_skew=params.write_skew,
        slo_curve_bins=params.slo_curve_bins,
        node_bandwidth_gibps=params.node_bandwidth_gibps,
        lat_lark=lat_lark, lat_quorum=lat_quorum,
        lat_hermes=lat_hermes,
        ci_lat_lark=ci_l, ci_lat_quorum=ci_q,
        p50_lark=pcts["p50_lark"], p99_lark=pcts["p99_lark"],
        p999_lark=pcts["p999_lark"],
        p50_quorum=pcts["p50_quorum"], p99_quorum=pcts["p99_quorum"],
        p999_quorum=pcts["p999_quorum"],
        p50_hermes=pcts["p50_hermes"], p99_hermes=pcts["p99_hermes"],
        p999_hermes=pcts["p999_hermes"],
        slo_lark=slo_lark, slo_quorum=slo_quorum,
        slo_hermes=slo_hermes,
        slo_curve_edges=curve_edges, slo_curve_lark=curve_lark,
        slo_curve_quorum=curve_quorum, slo_curve_hermes=curve_hermes,
        hist_edges=np.asarray([1 << k for k in range(params.hist_bins)],
                              dtype=np.int64),
        hist_quorum_req=qhist,
        lat_lark_trials=lal_b, lat_quorum_trials=laq_b,
        downtime=res)
