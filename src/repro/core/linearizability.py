"""Per-key register linearizability checking (Wing & Gong style search).

Writes carry unique values (the tests guarantee this).  Ops that FAILED at
the client or never completed are *optional*: under LARK a client-visible
write failure may still take effect later (a replica that accepted the
version can win a future dup-res), so failed/indeterminate writes may
linearize anywhere within their interval or be dropped; reads without a
response impose no constraint and are excluded.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

INF = math.inf


@dataclass(frozen=True)
class Op:
    op_id: int
    kind: str            # "write" | "read"
    value: Any           # written value / returned value
    inv: float
    resp: float          # INF if no response observed
    mandatory: bool      # must appear to take effect (successful ops)


def history_to_ops(history, key: str) -> List[Op]:
    """Convert simulator HistEvents into checker Ops for one key."""
    inv: Dict[int, Tuple[float, str, Any]] = {}
    out: List[Op] = []
    for e in history:
        if e.key != key or e.op_id < 0:
            continue  # op_id -1 = no-leader client error: provably no effect
        if e.kind == "invoke":
            inv[e.op_id] = (e.time, e.op_kind, e.value)
        else:
            t0, kind, wval = inv.get(e.op_id, (0.0, e.op_kind, e.value))
            if e.kind == "ok":
                val = wval if kind == "write" else e.value
                out.append(Op(e.op_id, kind, val, t0, e.time, True))
            elif kind == "write":  # fail / indeterminate write: optional
                out.append(Op(e.op_id, kind, wval, t0,
                              e.time if e.kind == "fail" else INF, False))
            # failed/indeterminate reads impose no constraint
    return out


def check_linearizable(ops: Sequence[Op], initial: Any = None) -> bool:
    ops = list(ops)
    n = len(ops)
    if n == 0:
        return True
    if n > 17:
        raise ValueError("history too large for exhaustive checking")

    resp = [o.resp for o in ops]
    inv = [o.inv for o in ops]
    full = (1 << n) - 1
    seen = set()

    def search(done_mask: int, last: Any) -> bool:
        if done_mask == full:
            return True
        state = (done_mask, last)
        if state in seen:
            return False
        seen.add(state)
        # candidates: undone ops invoked before every undone op's response
        min_resp = min(resp[i] for i in range(n) if not done_mask >> i & 1)
        for i in range(n):
            if done_mask >> i & 1:
                continue
            if inv[i] > min_resp:
                continue
            o = ops[i]
            if o.kind == "write":
                if search(done_mask | 1 << i, o.value):
                    return True
                if not o.mandatory:     # optional write may take no effect
                    if search(done_mask | 1 << i, last):
                        return True
            else:  # read
                if o.value == last and search(done_mask | 1 << i, last):
                    return True
        return False

    return search(0, initial)


def check_history(history, keys: Optional[Sequence[str]] = None,
                  initial: Any = None) -> Dict[str, bool]:
    if keys is None:
        keys = sorted({e.key for e in history})
    return {k: check_linearizable(history_to_ops(history, k), initial)
            for k in keys}
