"""Named, parameterized failure scenarios for the batched Monte Carlos.

Paper anchors: the ``independent`` scenario is §5.1's i.i.d. grid model;
``rolling-restart`` and ``maintenance-wave`` exercise §5.3's
zero-downtime rolling-restart claim; the rest stress PAC (§3) under the
correlated/heterogeneous failure modes real fleets see.  Every scenario
runs under both batched engines — instantaneous availability
(core/availability_batched.py, §5.1) and commit-pause downtime
(core/downtime_batched.py, §6) — because scenarios only parameterize the
shared node-failure *trajectory*, never the protocol evaluation.

The engines expose mechanism knobs; this module gives the *policies*
built on them stable names, so the sweep CLI, CI, and tests all draw
from one registry instead of hard-coded grids:

    from repro.core.scenarios import get_scenario
    sc = get_scenario("rack-pairs")
    r = simulate_availability_batched(n=63, rf=2, p=3e-3,
                                      **sc.kwargs(n=63, rf=2, p=3e-3))

Knobs a scenario may emit (all consumed by the shared node-advance in
availability_batched.py, so trajectories stay bit-identical across
backends/devices/engines):

  pair_fail_prob   correlated dual failures — when node i fails, its pair
                   partner (2i <-> 2i+1) fails at the same tick with this
                   probability (shared rack / power domain).
  restart_period   scheduled maintenance: every `restart_period` ticks
                   the next wave of nodes (in id order, wrapping) is
                   taken down for its configured downtime.
  wave_width       nodes per restart wave; 1 = serial rolling restart
                   (§5.3), >1 = batched maintenance that can swallow a
                   whole roster at once.
  p_node           (n,) per-node failure probability — heterogeneous
                   MTTF.  Implemented as one geometric CDF table per
                   *distinct* probability (per-class tables, selected by
                   node masks), so keep the number of tiers small.
  downtime_node    (n,) per-node downtime ticks (flapping nodes recover
                   fast, slow hardware lingers); overrides the scalar
                   `downtime`.

Each scenario is a function (n, rf, p) -> extra keyword arguments for
the engines; ``grid`` carries the (rf, p) points the sweep evaluates by
default.  Scenarios only ever *add* kwargs on top of the i.i.d.
baseline — never sweep-owned ones like n/rf/p/backend/devices
(``Scenario.kwargs`` enforces this) — so every registered name runs
under every batched backend (numpy / jax / pallas) and shards across
devices unchanged.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Tuple

import numpy as np

_KwargsFn = Callable[..., dict]


@dataclass(frozen=True)
class Scenario:
    name: str
    summary: str
    grid: Tuple[Tuple[int, float], ...]   # default (rf, p) sweep points
    make_kwargs: _KwargsFn = field(repr=False, compare=False, default=None)

    def kwargs(self, *, n: int, rf: int, p: float) -> dict:
        """simulate_availability_batched kwargs beyond (n, rf, p)."""
        kw = self.make_kwargs(n=n, rf=rf, p=p)
        for k in ("n", "rf", "p", "partitions", "trials", "backend",
                  "devices", "seed", "dupres_ticks", "rebuild_steps",
                  "voters"):
            if k in kw:
                raise ValueError(f"scenario {self.name!r} may not override "
                                 f"sweep-owned kwarg {k!r}")
        return kw


SCENARIOS: Dict[str, Scenario] = {}


def register_scenario(name: str, summary: str,
                      grid: Tuple[Tuple[int, float], ...]):
    def deco(fn: _KwargsFn) -> _KwargsFn:
        if name in SCENARIOS:
            raise ValueError(f"duplicate scenario {name!r}")
        SCENARIOS[name] = Scenario(name=name, summary=summary,
                                   grid=tuple(grid), make_kwargs=fn)
        return fn
    return deco


def scenario_names() -> Tuple[str, ...]:
    return tuple(SCENARIOS)


def get_scenario(name: str) -> Scenario:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; registered: "
                       f"{', '.join(SCENARIOS)}") from None


# ---------------------------------------------------------------------------
# Registered scenarios
# ---------------------------------------------------------------------------

@register_scenario(
    "independent",
    "i.i.d. geometric node failures — the paper's §5.1 grid model",
    grid=((2, 1e-3), (3, 3e-3), (4, 1e-2)))
def _independent(*, n: int, rf: int, p: float) -> dict:
    return {}


@register_scenario(
    "rack-pairs",
    "correlated rack/power-domain failures: a failing node takes its pair "
    "partner (2i <-> 2i+1) down at the same tick half the time",
    grid=((2, 3e-3), (3, 1e-2), (4, 1e-2)))
def _rack_pairs(*, n: int, rf: int, p: float) -> dict:
    return {"pair_fail_prob": 0.5}


@register_scenario(
    "rolling-restart",
    "serial maintenance: one node restarted every 2000 ticks — §5.3's "
    "zero-downtime rolling-restart claim as a Monte Carlo scenario",
    grid=((2, 1e-3), (3, 3e-3), (4, 3e-3)))
def _rolling_restart(*, n: int, rf: int, p: float) -> dict:
    return {"restart_period": 2_000, "wave_width": 1}


@register_scenario(
    "maintenance-wave",
    "batched maintenance: waves of 3 id-consecutive nodes restarted "
    "together every 3000 ticks (a wave can swallow a whole roster)",
    grid=((3, 1e-3), (4, 3e-3)))
def _maintenance_wave(*, n: int, rf: int, p: float) -> dict:
    return {"restart_period": 3_000, "wave_width": min(3, n)}


@register_scenario(
    "flapping",
    "every 8th node flaps: 20x the base failure rate with a 2-tick "
    "recovery (crash-loop / NIC-flap behavior)",
    grid=((2, 1e-3), (3, 3e-3)))
def _flapping(*, n: int, rf: int, p: float) -> dict:
    flappy = np.zeros(n, dtype=bool)
    flappy[::8] = True
    return {"p_node": np.where(flappy, np.minimum(20.0 * p, 0.5), p),
            "downtime_node": np.where(flappy, 2, 10)}


@register_scenario(
    "hetero-mttf",
    "heterogeneous hardware: node thirds at 0.5x / 1x / 4x the base "
    "failure rate (mixed-generation fleet)",
    grid=((2, 1e-3), (3, 3e-3), (4, 1e-2)))
def _hetero_mttf(*, n: int, rf: int, p: float) -> dict:
    scale = np.array([0.5, 1.0, 4.0])[np.arange(n) % 3]
    return {"p_node": np.minimum(scale * p, 0.5)}
