"""Batched commit-pause / downtime engine — paper §6 at Monte Carlo scale.

Paper anchor: §6's equal-storage-budget argument.  Both systems keep only
f+1 data copies; LARK keeps committing through data-node failures (PAC
reasons over the whole cluster, partitions are ready immediately after a
leader change, at most a per-key duplicate-resolution round trip when the
new leader lacks the latest copy), while quorum-log protocols
(Raft/Paxos/VR-style) commit through a majority of a *fixed replica set*
and must pause commits to rebuild a replica after losing one.  The
instantaneous engine (core/availability_batched.py) measures how often
each protocol *is* available; this engine measures commit-pause
*durations* — how long writes stall, and why.

Runs B trials x P partitions through the exact counter-RNG trajectories of
the availability engine (the node-advance closure is imported from it, and
consumes the identical randomness stream), then carries two per-partition
protocol state machines per step instead of an instantaneous average:

  LARK         paused iff PAC (SimpleMajority) fails; ready the instant
               PAC holds again.  When the acting leader (first up node in
               succession order) changes while the partition is available
               and the new leader lacks the latest copy, an optional
               dup-res penalty of `dupres_ticks` commit-paused ticks is
               charged (the paper's one-round-trip duplicate resolution).
  quorum-log   paused iff a majority of the f+1-copy replica set is down,
               OR a rebuild is in progress.  Two baseline models
               (`rebuild_model`):

               fixed     the replica set is the first rf succession
                         nodes, statically; every replica loss starts a
                         constant `rebuild_steps`-tick countdown during
                         which commits pause (log-based replica catch-up
                         under an equal storage budget).  A finite
                         `node_bandwidth_gibps` makes concurrent
                         catch-ups replaying onto the same node share
                         its ingest bandwidth exactly like the reconfig
                         model below (the log replays onto the lost
                         replica's own node — the lowest lost
                         succession lane); inf — the default — is the
                         unshared constant-countdown model, bit for
                         bit.
               reconfig  the replica set is a carried per-partition
                         *roster* of succession ranks.  After a replica
                         loss the protocol recruits the next up node in
                         succession order (Spinnaker/VR-style
                         reconfiguration onto live nodes), and the
                         catch-up countdown is proportional to the
                         partition's data size: `rebuild_ticks_per_gib`
                         x a per-partition size in GiB drawn
                         deterministically at t=0 (shared by all trials
                         — one cluster dataset, many failure
                         trajectories) from a configurable `size_dist`:
                         uniform [1, 2) GiB, or hot-partition-skewed
                         zipf / lognormal shapes (`size_skew`), all
                         pinned to the same 1.5 GiB mean so skew moves
                         bytes between partitions without changing the
                         equal-storage total.  Concurrent catch-ups
                         ingesting on one recruit node share its
                         `node_bandwidth_gibps` evenly (each advances
                         min(1, bandwidth / k) countdown-ticks per tick
                         in 1/256 fixed-point quanta; inf — the default
                         — is the unshared parallel-rebuild model, bit
                         for bit).  A loss during catch-up restarts the
                         clock; a down roster member with no up
                         replacement available keeps its seat until one
                         appears (late recruitment does not restart the
                         clock — the catch-up was already charged to the
                         loss).  Sizes come from the same counter-hash
                         family as the trajectory RNG under a dedicated
                         salt, so the node-advance randomness stream is
                         untouched and trajectories stay bit-identical
                         to the fixed model's.

Outputs per protocol: the mean commit-pause fraction (paused
partition-ticks / total partition-ticks — with dupres_ticks=0 and
rebuild_steps=0 these degenerate *exactly* to the instantaneous engine's
u_lark and its voters=rf u_maj, a property tests pin bit-for-bit), pause
event counts, and a histogram of completed pause durations in
power-of-two tick buckets (bucket k counts durations in [2^k, 2^(k+1)),
the top bucket open-ended; runs still open at the horizon are censored
and not counted).

Invariants this engine must preserve (see docs/ARCHITECTURE.md):
  * It consumes no randomness beyond the shared node-advance closure, so
    for equal knobs its node trajectory is bit-identical to the
    availability engine's — and across numpy / jax / pallas backends, and
    across any `devices` sharding of the trials axis (same shard_map over
    launch/mesh.make_trials_mesh, same carried global lane offsets).
  * All per-step protocol state is integer/boolean (pause accumulators
    are float32 counts * dt, matching the availability engine's
    arithmetic), so cross-backend equality is exact, not approximate.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

from ..kernels import bitpack
from ..kernels.ops import (StepSpec, _rebuild_node_counts_impl,
                           client_latency_step, step_eval)
from .availability import t975
from .availability_batched import (_default_max_steps, _engine_setup,
                                   _initial_full_state, _initial_node_state,
                                   _make_chunk_runner, _make_node_advance,
                                   _mix32, _run_chunk_numpy, _uniforms,
                                   _validate_batched_args)

_SIZE_SALT = 0x94D049BB

REBUILD_MODELS = ("fixed", "reconfig")

#: the protocol zoo: every engine one run can report.  lark and quorum are
#: the paper's §6 pair and always simulated; hermes (Katsarakis et al. —
#: broadcast replication, all replicas serve linearizable reads under
#: membership leases, writes block on a suspected replica until the lease
#: epoch advances) and spinnaker (Rao et al. — Paxos with reconfiguration,
#: a view-change log-reconciliation pause on leader loss) are optional
#: contrast engines riding the same node trajectories.
ENGINES = ("lark", "quorum", "hermes", "spinnaker")

#: the necessity hooks: each disables exactly one transition predicate of
#: the zoo state machines so tests can prove the predicate is load-bearing
#: (tests/test_condition_necessity.py style)
DISABLE_PREDICATES = ("lease-expiry", "view-change-trigger",
                      "roster-recruit")

#: per-partition data-size distributions for the reconfiguring baseline.
#: All three pin the same mean (the uniform model's 1.5 GiB), so every
#: distribution describes the same total dataset under the §6
#: equal-storage budget — skew moves bytes between partitions, never
#: adds them.
SIZE_DISTS = ("uniform", "zipf", "lognormal")

_SIZE_MEAN_GIB = 1.5      # the uniform [1, 2) mean every dist is pinned to

#: largest accepted size_skew: (1 - u)^(-skew) reaches 2^(24 * skew) at
#: the 24-bit uniform's top draw, which overflows float64 (and silently
#: NaN-poisons the mean rescale) just past skew ~42 — cap well below it
_SIZE_SKEW_MAX = 32.0

#: fixed-point scale for bandwidth-shared catch-up countdowns: one
#: countdown tick = _REB_SCALE work units, so a contended rebuild can
#: advance in 1/_REB_SCALE-tick quanta while staying pure int32 math
#: (invariant 4 in docs/ARCHITECTURE.md).  An uncontended rebuild
#: advances _REB_SCALE units/tick — arithmetically identical to the
#: plain-tick countdown, which is what makes node_bandwidth_gibps=inf
#: bit-exact against the unshared model.
_REB_SCALE = 256
_REB_BIG = np.int32(2 ** 30)   # "never finishes" remaining-ticks sentinel

#: largest accepted key_zipf (the client-latency workload's key-popularity
#: exponent): beyond this the zipf mass is so concentrated that the
#: float64 rank weights r^-s underflow for all but the first few keys and
#: the partition weight table degenerates to a handful of point masses
_KEY_ZIPF_MAX = 8.0

#: largest accepted write_skew (the client-latency workload's
#: per-partition write-mix Pareto exponent, core/client_latency.py):
#: same concentration rationale as _KEY_ZIPF_MAX — past this the
#: bounded-Pareto draws collapse the write mix onto a handful of
#: saturated (write fraction 1) partitions and the mean pin degenerates
_WRITE_SKEW_MAX = 8.0


@dataclass(frozen=True)
class DowntimeParams:
    """The §6 engine's protocol/rebuild knobs, validated in one place.

    These eight values are mutually constrained (the skew/bandwidth knobs
    describe the reconfiguring baseline's data-sized catch-ups and are
    rejected under rebuild_model="fixed"; bandwidth has a fixed-point
    quantum floor; ...), and they used to be threaded as loose keywords
    from benchmarks/availability_sweep.py all the way into
    simulate_downtime_batched, with the rules enforced at the bottom.
    One frozen dataclass now owns both the values and the rules: every
    entry point (CLI, engine, tests) constructs it and gets the identical
    ValueError set — see simulate_downtime_batched's docstring for
    per-knob semantics.
    """
    dupres_ticks: int = 1
    rebuild_steps: int = 100
    hist_bins: int = 16
    rebuild_model: str = "fixed"
    rebuild_ticks_per_gib: int = 100
    size_dist: str = "uniform"
    size_skew: float = 1.0
    node_bandwidth_gibps: float = math.inf
    # client-latency workload knobs (core/client_latency.py; inert for the
    # plain downtime metric — the defaults are the zero-request limit).
    # slo_ticks uses a strict `>` (a request violates iff its added
    # latency exceeds the threshold), so slo_ticks=0 is a *live* edge
    # threshold — every request with any positive added latency violates
    # — and doubles as the inert non-latency sentinel only because
    # requests_per_tick=0 offers no requests to violate it.
    # write_skew skews the per-partition write fraction around
    # 1 - read_frac (0 = exactly uniform); slo_curve_bins requests a
    # violation-fraction curve over thresholds 2^j - 1, j < bins (0 =
    # the single slo_ticks point only).
    key_zipf: float = 0.0
    read_frac: float = 1.0
    requests_per_tick: float = 0.0
    slo_ticks: int = 0
    write_skew: float = 0.0
    slo_curve_bins: int = 0
    # protocol-zoo knobs: which engines to report, and their pause costs
    # (lease_ticks — Hermes membership-lease epoch length; a suspected
    # replica blocks writes until it elapses.  view_change_ticks —
    # Spinnaker's log-reconciliation pause after a leader loss.)
    engines: tuple = ("lark", "quorum")
    lease_ticks: int = 0
    view_change_ticks: int = 0

    def __post_init__(self):
        object.__setattr__(self, "engines", tuple(self.engines))
        if not self.engines:
            raise ValueError("engines must name at least one protocol")
        for e in self.engines:
            if e not in ENGINES:
                raise ValueError(f"unknown engine {e!r}; expected a "
                                 f"subset of {ENGINES}")
        if len(set(self.engines)) != len(self.engines):
            raise ValueError(f"duplicate engines: {self.engines}")
        if self.lease_ticks < 0 or self.view_change_ticks < 0:
            raise ValueError("lease_ticks and view_change_ticks must "
                             "be >= 0")
        if self.lease_ticks > 0 and not self.hermes:
            raise ValueError("lease_ticks models the hermes engine's "
                             "membership leases; add 'hermes' to engines")
        if self.view_change_ticks > 0 and not self.spinnaker:
            raise ValueError("view_change_ticks models the spinnaker "
                             "engine's view changes; add 'spinnaker' to "
                             "engines")
        if self.spinnaker and not self.reconfig:
            raise ValueError("the spinnaker engine elects among the "
                             "reconfiguring baseline's roster; use "
                             "rebuild_model='reconfig'")
        if self.dupres_ticks < 0 or self.rebuild_steps < 0:
            raise ValueError("dupres_ticks and rebuild_steps must be >= 0")
        if not 2 <= self.hist_bins <= 30:
            raise ValueError("hist_bins must be in [2, 30]")
        if self.rebuild_model not in REBUILD_MODELS:
            raise ValueError(
                f"rebuild_model must be one of {REBUILD_MODELS}")
        if self.rebuild_ticks_per_gib < 0:
            raise ValueError("rebuild_ticks_per_gib must be >= 0")
        if self.size_dist not in SIZE_DISTS:
            raise ValueError(f"size_dist must be one of {SIZE_DISTS}")
        if not 0 <= self.size_skew <= _SIZE_SKEW_MAX:
            raise ValueError(
                f"size_skew must be in [0, {_SIZE_SKEW_MAX:g}]")
        if not self.node_bandwidth_gibps >= 1.0 / _REB_SCALE:
            raise ValueError(
                f"node_bandwidth_gibps must be >= 1/{_REB_SCALE} "
                "(the fixed-point rate quantum — below it even an "
                "uncontended catch-up rounds to zero progress; "
                "inf disables bandwidth sharing)")
        if not self.reconfig and self.size_dist != "uniform":
            raise ValueError(
                "size_dist models the reconfiguring baseline's "
                "data-sized catch-ups; use rebuild_model='reconfig' "
                "(node_bandwidth_gibps applies to both rebuild models)")
        if not 0 <= self.key_zipf <= _KEY_ZIPF_MAX:
            raise ValueError(
                f"key_zipf must be in [0, {_KEY_ZIPF_MAX:g}] (the zipf "
                "key-popularity exponent; 0 is uniform)")
        if not 0 <= self.read_frac <= 1:
            raise ValueError("read_frac must be in [0, 1]")
        if not (self.requests_per_tick >= 0
                and math.isfinite(self.requests_per_tick)):
            raise ValueError("requests_per_tick must be finite and >= 0")
        if self.slo_ticks < 0:
            raise ValueError("slo_ticks must be >= 0 (0 is a live "
                             "threshold under the strict-> rule: every "
                             "request with positive added latency "
                             "violates it)")
        if not 0 <= self.write_skew <= _WRITE_SKEW_MAX:
            raise ValueError(
                f"write_skew must be in [0, {_WRITE_SKEW_MAX:g}] (the "
                "per-partition write-mix Pareto exponent; 0 is exactly "
                "uniform)")
        if not 0 <= self.slo_curve_bins <= self.hist_bins:
            raise ValueError(
                "slo_curve_bins must be in [0, hist_bins] — the curve's "
                "2^j - 1 thresholds are derived from the power-of-two "
                "latency histogram and cannot outrun its buckets")

    @property
    def reconfig(self) -> bool:
        return self.rebuild_model == "reconfig"

    @property
    def bandwidth_shared(self) -> bool:
        return math.isfinite(self.node_bandwidth_gibps)

    @property
    def hermes(self) -> bool:
        return "hermes" in self.engines

    @property
    def spinnaker(self) -> bool:
        return "spinnaker" in self.engines


def _norm_ppf(u: np.ndarray) -> np.ndarray:
    """Inverse standard-normal CDF (Acklam's rational approximation,
    |rel err| < 1.2e-9) — vectorized host-side numpy, no scipy.  Only
    used to shape the deterministic lognormal size table, so approximation
    error just perturbs the (arbitrary) distribution shape; determinism
    is what matters."""
    a = (-3.969683028665376e+01, 2.209460984245205e+02,
         -2.759285104469687e+02, 1.383577518672690e+02,
         -3.066479806614716e+01, 2.506628277459239e+00)
    b = (-5.447609879822406e+01, 1.615858368580409e+02,
         -1.556989798598866e+02, 6.680131188771972e+01,
         -1.328068155288572e+01)
    c = (-7.784894002430293e-03, -3.223964580411365e-01,
         -2.400758277161838e+00, -2.549732539343734e+00,
         4.374664141464968e+00, 2.938163982698783e+00)
    d = (7.784695709041462e-03, 3.224671290700398e-01,
         2.445134137142996e+00, 3.754408661907416e+00)
    u = np.clip(np.asarray(u, dtype=np.float64), 2.0 ** -25, 1 - 2.0 ** -25)
    lo, hi = u < 0.02425, u > 1 - 0.02425
    mid = ~(lo | hi)
    z = np.empty_like(u)
    q = np.sqrt(-2.0 * np.log(np.where(lo, u, 0.5)))
    z_lo = (((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4]) * q
            + c[5]) / ((((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1)
    q = np.sqrt(-2.0 * np.log(np.where(hi, 1 - u, 0.5)))
    z_hi = -(((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4]) * q
             + c[5]) / ((((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1)
    q = u - 0.5
    r = q * q
    z_mid = (((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4]) * r
             + a[5]) * q / \
        (((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r + b[4]) * r + 1)
    z[lo] = z_lo[lo]
    z[hi] = z_hi[hi]
    z[mid] = z_mid[mid]
    return z


def partition_sizes_gib(seed: int, partitions: int, *,
                        dist: str = "uniform",
                        skew: float = 1.0) -> np.ndarray:
    """Deterministic per-partition data sizes in GiB.

    dist selects the shape (SIZE_DISTS):
      uniform    uniform in [1, 2) — the original baseline, byte-identical
                 to the pre-skew table (the skew knob is inert here).
      zipf       bounded Pareto hot-partition skew: raw = (1 - u)^(-skew),
                 rescaled so the sample mean is exactly the uniform mean
                 (1.5 GiB).  skew=0 degenerates to every partition at
                 exactly 1.5 GiB; larger skews concentrate the dataset in
                 a few huge partitions and push the rest below 1 GiB.
      lognormal  raw = exp(skew * z(u)) with z the inverse normal CDF,
                 mean-rescaled the same way (skew is the log-space sigma).

    The mean pin keeps the total dataset — the §6 equal-storage budget —
    identical across distributions: skew redistributes bytes, never adds
    them.  Draws come once at t=0 from the same counter-hash family as
    the trajectory RNG but under a dedicated salt and partition-indexed
    lanes, so the node-advance randomness stream is untouched (invariant
    3 in docs/ARCHITECTURE.md) and every size distribution replays the
    exact node trajectories of every other.  Always computed host-side in
    numpy — every backend receives the identical table.
    """
    if dist not in SIZE_DISTS:
        raise ValueError(f"dist must be one of {SIZE_DISTS}; got {dist!r}")
    if not 0 <= skew <= _SIZE_SKEW_MAX:
        raise ValueError(f"skew must be in [0, {_SIZE_SKEW_MAX:g}] "
                         f"(larger Pareto exponents overflow the float64 "
                         f"size table); got {skew!r}")
    seed_mix = _mix32(np.asarray([(seed & 0xFFFFFFFF) ^ 0x6A09E667],
                                 dtype=np.uint32), np)
    u = _uniforms(seed_mix, np.asarray(0, dtype=np.uint32), _SIZE_SALT,
                  np.zeros(1, dtype=np.uint32), partitions, np)[0] \
        .astype(np.float64)
    if dist == "uniform":
        return 1.0 + u
    if dist == "zipf":
        raw = (1.0 - u) ** (-skew)
    else:                                        # lognormal
        raw = np.exp(skew * _norm_ppf(u))
    return raw * (_SIZE_MEAN_GIB / raw.mean())


def _partition_rebuild_ticks(seed: int, partitions: int,
                             ticks_per_gib: int, *,
                             dist: str = "uniform", skew: float = 1.0,
                             cap: Optional[int] = None) -> np.ndarray:
    """(P,) int32 catch-up countdowns for the reconfiguring baseline:
    floor(ticks_per_gib x size_gib), clamped to >= 1 tick whenever a
    rebuild costs anything at all (skewed draws push partitions below
    1 GiB, and a catch-up of epsilon bytes still takes one tick — without
    the clamp a sub-GiB partition would rebuild for free and its pause
    run would degenerate to the dropped zero-length case).  `cap`
    (the engine passes horizon + 1) bounds the table so the fixed-point
    work units stay in int32; a countdown beyond the horizon can never
    complete in-simulation, so the clamp is observationally invisible.
    With the uniform dist and ticks_per_gib == rebuild_steps every
    catch-up is >= the fixed model's constant (sizes >= 1 GiB), and both
    clamps are no-ops — the pre-skew table, bit for bit."""
    t = np.floor(ticks_per_gib *
                 partition_sizes_gib(seed, partitions, dist=dist, skew=skew))
    if ticks_per_gib > 0:
        t = np.maximum(t, 1.0)
    if cap is not None:
        t = np.minimum(t, float(cap))
    return t.astype(np.int32)


# ---------------------------------------------------------------------------
# Result
# ---------------------------------------------------------------------------

@dataclass
class BatchedDowntimeResult:
    p: float
    rf: int
    n: int
    partitions: int
    trials: int
    backend: str
    ticks: int                       # mean elapsed ticks per trial
    pause_lark: float                # mean commit-pause fraction, pooled
    pause_quorum: float
    lark_events: int                 # pause-start events (incl. dup-res)
    quorum_events: int
    ci_lark: float                   # 95% half-widths on the fractions
    ci_quorum: float
    dupres_ticks: int
    rebuild_steps: int
    stopped_early: bool
    devices: int = 1
    rebuild_model: str = "fixed"
    rebuild_ticks_per_gib: int = 0   # reconfig only; 0 under "fixed"
    size_dist: str = "uniform"       # reconfig only; "uniform" under "fixed"
    size_skew: float = 0.0           # zipf/lognormal only; 0 elsewhere
    node_bandwidth_gibps: float = math.inf   # reconfig only; inf = unshared
    hist_edges: np.ndarray = field(repr=False, default=None)   # (nbins,)
    hist_lark: np.ndarray = field(repr=False, default=None)    # (nbins,)
    hist_quorum: np.ndarray = field(repr=False, default=None)
    pause_lark_trials: np.ndarray = field(repr=False, default=None)
    pause_quorum_trials: np.ndarray = field(repr=False, default=None)
    #: protocol-zoo outputs — None/0 unless the matching engine was in
    #: `engines` (lark/quorum keep their dedicated fields above)
    engines: tuple = ("lark", "quorum")
    lease_ticks: int = 0
    view_change_ticks: int = 0
    pause_hermes: Optional[float] = None
    hermes_events: int = 0
    ci_hermes: float = 0.0
    pause_spinnaker: Optional[float] = None
    spinnaker_events: int = 0
    ci_spinnaker: float = 0.0
    hist_hermes: np.ndarray = field(repr=False, default=None)
    hist_spinnaker: np.ndarray = field(repr=False, default=None)
    pause_hermes_trials: np.ndarray = field(repr=False, default=None)
    pause_spinnaker_trials: np.ndarray = field(repr=False, default=None)
    trajectory: Optional[Dict[str, np.ndarray]] = field(repr=False,
                                                        default=None)
    #: raw per-trial client-latency accumulators (only when the engine is
    #: driven through core/client_latency.py): dup (B, NB) expected LARK
    #: first-touch charges per key bucket, qhist (B, nbins) quorum
    #: rebuild-wait requests per power-of-two latency bucket, qslo (B,)
    #: requests over the SLO, qsum (B,) total latency ticks, now (B,)
    #: elapsed ticks — all pooled over partitions host-side in float64
    latency_raw: Optional[Dict[str, np.ndarray]] = field(repr=False,
                                                         default=None)

    @property
    def availability_ratio(self) -> float:
        """Quorum-log pause over LARK pause — the §6 headline ratio."""
        return self.pause_quorum / self.pause_lark if self.pause_lark > 0 \
            else math.inf

    def engine_stats(self, engine: str) -> Dict[str, object]:
        """Uniform per-engine view: pause fraction, CI half-width, event
        count, duration histogram, and per-trial fractions for any member
        of ENGINES (raises if the engine wasn't simulated)."""
        if engine not in self.engines:
            raise ValueError(f"engine {engine!r} was not simulated "
                             f"(engines={self.engines})")
        by = {
            "lark": (self.pause_lark, self.ci_lark, self.lark_events,
                     self.hist_lark, self.pause_lark_trials),
            "quorum": (self.pause_quorum, self.ci_quorum,
                       self.quorum_events, self.hist_quorum,
                       self.pause_quorum_trials),
            "hermes": (self.pause_hermes, self.ci_hermes,
                       self.hermes_events, self.hist_hermes,
                       self.pause_hermes_trials),
            "spinnaker": (self.pause_spinnaker, self.ci_spinnaker,
                          self.spinnaker_events, self.hist_spinnaker,
                          self.pause_spinnaker_trials),
        }[engine]
        return {"pause": by[0], "ci_pause": by[1], "events": by[2],
                "hist": by[3], "pause_trials": by[4]}


# ---------------------------------------------------------------------------
# The per-event step.
# ---------------------------------------------------------------------------

def _hist_add(xp, hist_bins: int, hist, mask, d):
    """Scatter completed pause durations d (B, P) where mask into
    power-of-two buckets (bucket k counts [2^k, 2^(k+1)), top bucket
    open-ended) — comparisons only, so every backend bins identically.
    Duration-0 runs (opened and closed at the same tick by coincident
    events) are not pauses and are dropped, never mis-binned into the
    [1, 2) bucket."""
    mask = mask & (d > 0)
    b = xp.zeros(d.shape, dtype=xp.int32)
    for k in range(1, hist_bins):
        b = b + (d >= (1 << k)).astype(xp.int32)
    oh = (b[:, :, None] == xp.arange(hist_bins, dtype=xp.int32)
          [None, None, :]) & mask[:, :, None]
    return hist + xp.sum(oh, axis=1).astype(xp.int32)


def _make_step(xp, dt_fn, advance, succ, *, n: int, P: int, rf: int,
               dupres_ticks: int, rebuild_steps: int, hist_bins: int,
               rebuild_model: str = "fixed", rebuild_ticks=None,
               bandwidth_fp=None, cnt_fn=None, rebuild_fp=None,
               packed: bool = False,
               lat_fn=None, engines: tuple = (), lease_ticks: int = 0,
               view_change_ticks: int = 0, disable=frozenset()):
    hermes = "hermes" in engines
    spinnaker = "spinnaker" in engines
    # necessity hooks: each strips one transition predicate so tests can
    # prove it is load-bearing; production runs pass an empty set
    lease_on = "lease-expiry" not in disable
    vc_on = "view-change-trigger" not in disable
    recruit_on = "roster-recruit" not in disable

    def hist_add(hist, mask, d):
        return _hist_add(xp, hist_bins, hist, mask, d)

    def lat_interval(lat, dt_i, ldn, qmaj_prev, rem):
        """Charge the client-latency layer for one event interval from
        interval-start state (requests in [now, t_clamp) see the carried
        protocol state; both protocols only flip at events).  The lat
        leaves ride at the tail of the scan carry; layout-independent
        (consumes only (B, P) row state), so packed and unpacked carries
        charge identically."""
        if lat_fn is None:
            return lat
        return lat_fn(lat, dt_i, ~ldn, qmaj_prev, rem)

    def lat_dirty_reset(lat, pen):
        """A leader change onto a stale leader makes every key of the
        partition dirty: its next touch pays the dup-res round."""
        if lat_fn is None or pen is None:
            return lat
        return (xp.where(pen[:, :, None], xp.float32(1.0), lat[0]),) \
            + lat[1:]

    # -- shared protocol blocks.  Both rebuild models run these verbatim
    # (the models differ only in how the replica set and the rebuild
    # countdown are derived), so a retune lands in both state machines at
    # once — the LARK-bit-identity-across-models and fixed-model-baseline
    # pins in tests/test_downtime_batched.py depend on that.

    def interval_pause(now, dt, dt_i, ldn, qrep, qreb, qdn, qt0, lpt, qpt,
                       qhist, rate=None):
        """Pause time over [now, t_clamp) from interval-start state.
        LARK matches the availability engine's lpt arithmetic exactly
        (count * dt in float32); quorum adds the rebuild overlap —
        min(remaining, dt) extra paused ticks per majority-up partition —
        and a rebuild expiring mid-interval ends a quorum pause run
        between events (PAC state can only flip at events, so LARK runs
        never end mid-interval).

        rate=None is the fixed model's plain-tick countdown (qreb in
        ticks, one tick of progress per tick).  A rate array puts qreb in
        _REB_SCALE fixed-point work units: each partition's catch-up
        advances dt * rate units over the interval (rate is the
        bandwidth share its recruit node grants, <= _REB_SCALE), finishes
        when cumulative progress covers the remaining units, and its
        remaining wall-ticks are ceil(units / rate) — at rate ==
        _REB_SCALE every expression reduces to the plain-tick arithmetic
        exactly, which is what keeps node_bandwidth_gibps=inf
        bit-identical to the unshared model."""
        lpt = lpt + xp.sum(ldn, axis=1).astype(xp.float32) * dt
        qmaj_prev = 2 * xp.sum(qrep, axis=2) > rf             # (B, P)
        qpt = qpt + xp.sum(~qmaj_prev, axis=1).astype(xp.float32) * dt
        if rate is None:
            rem = qreb                       # remaining wall-ticks
            prog = dt_i[:, None]             # progress over the interval
        else:
            # the divisor is floored at 1 only to keep numpy's eager
            # where-evaluation from dividing by zero; rate == 0 (a
            # starved rebuild) still selects the never-finishes sentinel
            safe_rate = xp.maximum(rate, 1)
            rem = xp.where(qreb > 0,
                           xp.where(rate > 0,
                                    (qreb + safe_rate - 1) // safe_rate,
                                    _REB_BIG),
                           0)
            prog = dt_i[:, None] * rate
        qpt = qpt + xp.sum(xp.where(
            qmaj_prev, xp.minimum(rem, dt_i[:, None]), 0)
            .astype(xp.float32), axis=1)
        ends_mid = qdn & qmaj_prev & (qreb > 0) & (prog >= qreb)
        qhist = hist_add(qhist, ends_mid, (now[:, None] + rem) - qt0)
        qdn = qdn & ~ends_mid
        qreb = xp.maximum(qreb - prog, 0)
        # qmaj_prev / rem are the interval-start majority mask and
        # remaining rebuild wall-ticks — the client-latency layer charges
        # this interval's requests from exactly these values
        return lpt, qpt, qreb, qdn, qhist, qmaj_prev, rem

    def lark_transitions(t_clamp, lark, ldr, lfull, ldn, lt0, leader, lpt,
                         lev, lhist):
        """Close LARK runs that came back, open new ones, and charge the
        dup-res penalty: available partition, new acting leader, and the
        leader lacks the latest copy (pre-refresh full mask) -> one round
        trip of paused commits, charged instantaneously.  The baseline
        only tracks the leader *while available* (no commits flow during
        a pause), so a leadership move inside an outage is still charged
        when service resumes under the new stale leader."""
        lhist = hist_add(lhist, ldn & lark, t_clamp[:, None] - lt0)
        lgo = ~ldn & ~lark
        lt0 = xp.where(lgo, t_clamp[:, None], lt0)
        lev = lev + xp.sum(lgo, axis=1).astype(xp.int32)
        ldn = ~lark
        pen = None
        if dupres_ticks > 0:
            pen = (ldr != leader) & lark & ~lfull
            npen = xp.sum(pen, axis=1).astype(xp.int32)
            lpt = lpt + npen.astype(xp.float32) * xp.float32(dupres_ticks)
            lev = lev + npen
            lhist = hist_add(lhist, pen,
                             xp.full(pen.shape, dupres_ticks,
                                     dtype=xp.int32))
        leader = xp.where(lark, ldr, leader)
        return ldn, lt0, leader, lpt, lev, lhist, pen

    def pause_transitions(t_clamp, pause, dn, t0, ev, hist):
        """Close pause runs whose condition cleared, open new ones (a
        pause-start is one counted event) — the post-event transition
        block every protocol-zoo engine shares with the quorum baseline
        (identical op order, so a degenerate-knob engine reproduces the
        baseline's run accounting bit for bit)."""
        hist = hist_add(hist, dn & ~pause, t_clamp[:, None] - t0)
        go = ~dn & pause
        t0 = xp.where(go, t_clamp[:, None], t0)
        ev = ev + xp.sum(go, axis=1).astype(xp.int32)
        return pause, t0, ev, hist

    def quorum_transitions(t_clamp, qmaj, qreb, qdn, qt0, qev, qhist):
        return pause_transitions(t_clamp, ~qmaj | (qreb > 0), qdn, qt0,
                                 qev, qhist)

    # -- protocol-zoo engines.  Each carries 7 leaves of its own —
    # (dn bool, t0 i32, 2 engine-specific (B, P) i32 states, pt f32 (B,),
    # ev i32 (B,), hist i32 (B, hist_bins)) — and consumes NO randomness:
    # both ride the identical node trajectories (invariant 3), which is
    # what makes the degenerate-knob limits exact rather than statistical.

    def knob_interval(now, dt, dt_i, base_dn, rem_x, dn, t0, pt, hist, *,
                      expire=True):
        """Interval pause charge for a knob-pause engine over
        [now, t_clamp): full dt where the engine's base condition held at
        interval start (same expression as the lark/quorum charges, so a
        zero-knob engine accrues bit-identically), plus min(countdown,
        dt) extra paused ticks where it didn't but a knob countdown was
        running; a countdown expiring mid-interval with the base
        condition clear closes the pause run between events."""
        pt = pt + xp.sum(base_dn, axis=1).astype(xp.float32) * dt
        pt = pt + xp.sum(
            xp.where(~base_dn, xp.minimum(rem_x, dt_i[:, None]), 0)
            .astype(xp.float32), axis=1)
        if expire:
            ends_mid = dn & ~base_dn & (rem_x > 0) & \
                (dt_i[:, None] >= rem_x)
            hist = hist_add(hist, ends_mid, (now[:, None] + rem_x) - t0)
            dn = dn & ~ends_mid
        return pt, dn, hist

    def hermes_interval(now, dt, dt_i, ldn, hstate):
        """Hermes charges: down for the whole interval wherever PAC was
        down at interval start (reads need a serving partition just like
        LARK — all replicas serve, so the availability condition is
        LARK's), plus the remaining lease-epoch wait where writes were
        blocked on a suspected replica."""
        hdn, ht0, hmask, hlease, hpt, hev, hhist = hstate
        hpt, hdn, hhist = knob_interval(now, dt, dt_i, ldn, hlease, hdn,
                                        ht0, hpt, hhist, expire=lease_on)
        if lease_on:
            hlease = xp.maximum(hlease - dt_i[:, None], 0)
        return (hdn, ht0, hmask, hlease, hpt, hev, hhist)

    def hermes_post(t_clamp, lark, repm, hstate):
        """Post-event Hermes transition: any member of the carried
        membership view going down is a suspicion — writes block until
        the lease epoch advances (lease_ticks later) — and the view
        re-forms on the surviving replicas."""
        hdn, ht0, hmask, hlease, hpt, hev, hhist = hstate
        loss_h = (hmask & ~repm) != 0
        if lease_ticks > 0:
            hlease = xp.where(loss_h, xp.int32(lease_ticks), hlease)
        hmask = repm
        hpause = ~lark | (hlease > 0)
        hdn, ht0, hev, hhist = pause_transitions(t_clamp, hpause, hdn,
                                                 ht0, hev, hhist)
        return (hdn, ht0, hmask, hlease, hpt, hev, hhist)

    def spinnaker_interval(now, dt, dt_i, qmaj_prev, rem0, sstate):
        """Spinnaker charges: the quorum baseline's interval accounting
        (majority-down + remaining catch-up wall-ticks) with the
        view-change reconciliation countdown overlaid — the pause ends
        when the later of the two clears, so the interval-start remaining
        wait is their max."""
        sdn, st0, sldr, svc, spt, sev, shist = sstate
        spt, sdn, shist = knob_interval(
            now, dt, dt_i, ~qmaj_prev, xp.maximum(rem0, svc), sdn, st0,
            spt, shist)
        svc = xp.maximum(svc - dt_i[:, None], 0)
        return (sdn, st0, sldr, svc, spt, sev, shist)

    def spinnaker_post(t_clamp, qmaj, qreb, rup_post, roster, rlead,
                       sstate):
        """Post-event Spinnaker transition: losing the elected leader
        (no longer an up roster member) triggers a view change — the new
        leader (minimum up roster rank, from the kernel's rleader output)
        pauses commits for view_change_ticks of log reconciliation on
        top of any catch-up."""
        sdn, st0, sldr, svc, spt, sev, shist = sstate
        valid = xp.any((roster == sldr[:, :, None]) & rup_post, axis=2)
        new_sldr = xp.where(valid, sldr, rlead)
        trigger = ~valid & (sldr < n) & (new_sldr < n) & \
            (new_sldr != sldr)
        if view_change_ticks > 0 and vc_on:
            svc = xp.where(trigger, xp.int32(view_change_ticks), svc)
        sldr = new_sldr
        spause = ~qmaj | (qreb > 0) | (svc > 0)
        sdn, st0, sev, shist = pause_transitions(t_clamp, spause, sdn,
                                                 st0, sev, shist)
        return (sdn, st0, sldr, svc, spt, sev, shist)

    def step(carry, s):
        (now, up, ev_t, full, rr_t, rr_idx, lane0, ldn, lt0, qrep, qreb,
         qdn, qt0, leader, lpt, qpt, lev, qev, lhist, qhist) = carry[:20]
        k = 20
        hstate = None
        if hermes:
            hstate = carry[k:k + 7]
            k += 7
        lat = carry[k:]
        B = up.shape[0]               # local trials (a shard of the batch)
        t_clamp, dt, active, up, ev_t, rr_t, rr_idx = advance(
            now, up, ev_t, rr_t, rr_idx, lane0, s)
        dt_i = t_clamp - now                                  # (B,) int32
        lpt, qpt, qreb, qdn, qhist, qmaj_prev, rem0 = interval_pause(
            now, dt, dt_i, ldn, qrep, qreb, qdn, qt0, lpt, qpt, qhist)
        if hermes:
            hstate = hermes_interval(now, dt, dt_i, ldn, hstate)
        lat = lat_interval(lat, dt_i, ldn, qmaj_prev, rem0)
        now = t_clamp

        # -- re-evaluate both protocols on the post-event cluster state
        up_succ = up[:, succ]                                 # (B, P, n)
        rep_new = up_succ[:, :, :rf]                          # replica lanes
        repm = None
        if packed:
            upw = xp.moveaxis(bitpack.pack_words(up_succ, xp), -1, 1)
            out_t = dt_fn(upw, full)
            lark, qmaj, ldr, lfull = out_t[:4]
            crepsw = out_t[-1]
            if hermes:
                repm = out_t[5]
            full = xp.where(lark[:, None, :], crepsw, full)
        else:
            out_t = dt_fn(
                up_succ.reshape(B * P, n), full.reshape(B * P, n))
            lark = out_t[0].reshape(B, P)
            qmaj = out_t[1].reshape(B, P)
            ldr = out_t[2].reshape(B, P)
            lfull = out_t[3].reshape(B, P)
            if hermes:
                repm = out_t[5].reshape(B, P)
            full = xp.where(lark[:, :, None],
                            out_t[-1].reshape(B, P, n), full)

        ldn, lt0, leader, lpt, lev, lhist, pen = lark_transitions(
            t_clamp, lark, ldr, lfull, ldn, lt0, leader, lpt, lev, lhist)
        lat = lat_dirty_reset(lat, pen)

        # -- any replica loss (a replica-set lane going up -> down, even
        # if masked by a simultaneous recovery of another lane)
        # (re)starts the constant rebuild countdown
        if rebuild_steps > 0:
            loss = xp.any(qrep & ~rep_new, axis=2)
            qreb = xp.where(loss, xp.int32(rebuild_steps), qreb)
        qdn, qt0, qev, qhist = quorum_transitions(
            t_clamp, qmaj, qreb, qdn, qt0, qev, qhist)
        qrep = rep_new
        if hermes:
            hstate = hermes_post(t_clamp, lark, repm, hstate)

        carry = (now, up, ev_t, full, rr_t, rr_idx, lane0, ldn, lt0,
                 qrep, qreb, qdn, qt0, leader, lpt, qpt, lev, qev,
                 lhist, qhist) + (hstate if hermes else ()) + lat
        out = (t_clamp, xp.sum(ldn, axis=1).astype(xp.int32),
               xp.sum(qdn, axis=1).astype(xp.int32),
               xp.sum(up, axis=1).astype(xp.int32))
        if hermes:
            out = out + (xp.sum(hstate[0], axis=1).astype(xp.int32),)
        return carry, out

    def step_fixed_bw(carry, s):
        """The fixed model with per-node bandwidth-contended rebuilds:
        `step`'s state machines verbatim, except qreb is carried in
        _REB_SCALE fixed-point work units (restart value `rebuild_fp`)
        and each interval's progress rate is the bandwidth share the
        rebuilding node grants — the identical rate block the reconfig
        steps run, so the two models' contention math can never drift
        apart.  The replica set is static, so the ingesting node is the
        lost replica's own (the log replays onto the lowest lost
        succession lane); it rides in a carried `recruit` leaf exactly
        like the reconfig carry.  bandwidth_fp=None never dispatches
        here — the legacy `step` runs untouched, which is what keeps
        node_bandwidth_gibps=inf bit-identical to the unshared model.
        Like step_reconfig_packed, the post-event evaluation runs before
        the interval charges (one fused dt_fn call on the packed pallas
        path folds eval + node counts); the counts and interval_pause
        still see interval-start carry state, so this is a pure dataflow
        reorder of `step`."""
        (now, up, ev_t, full, rr_t, rr_idx, lane0, ldn, lt0, qrep, qreb,
         qdn, qt0, leader, lpt, qpt, lev, qev, lhist, qhist,
         recruit) = carry[:21]
        k = 21
        hstate = None
        if hermes:
            hstate = carry[k:k + 7]
            k += 7
        lat = carry[k:]
        B = up.shape[0]               # local trials (a shard of the batch)
        t_clamp, dt, active, up, ev_t, rr_t, rr_idx = advance(
            now, up, ev_t, rr_t, rr_idx, lane0, s)
        dt_i = t_clamp - now                                  # (B,) int32

        # -- post-event cluster state + the in-flight node counts from
        # the carried interval-start recruit/qreb (the same reduction as
        # the reconfig steps; one fused call when packed)
        up_succ = up[:, succ]                                 # (B, P, n)
        rep_new = up_succ[:, :, :rf]                          # replica lanes
        inflight = (qreb > 0) & (recruit < n)
        repm = None
        if packed:
            upw = xp.moveaxis(bitpack.pack_words(up_succ, xp), -1, 1)
            out_t = dt_fn(upw, full, None, recruit, inflight)
            lark, qmaj, ldr, lfull = out_t[:4]
            counts = out_t[-1]
            crepsw = out_t[-2]
            if hermes:
                repm = out_t[5]
        else:
            out_t = dt_fn(up_succ.reshape(B * P, n),
                          full.reshape(B * P, n), None, recruit, inflight)
            lark = out_t[0].reshape(B, P)
            qmaj = out_t[1].reshape(B, P)
            ldr = out_t[2].reshape(B, P)
            lfull = out_t[3].reshape(B, P)
            counts = out_t[-1]
            if hermes:
                repm = out_t[5].reshape(B, P)
        kk = xp.take_along_axis(counts,
                                xp.clip(recruit, 0, n - 1), axis=1)
        # sentinel-recruit partitions must not inherit node n-1's
        # in-flight count from the clipped gather (see step_reconfig)
        kk = xp.where(recruit < n, xp.maximum(kk, 1), 1)
        rate = xp.minimum(xp.int32(_REB_SCALE),
                          xp.int32(bandwidth_fp) // kk)

        lpt, qpt, qreb, qdn, qhist, qmaj_prev, rem0 = interval_pause(
            now, dt, dt_i, ldn, qrep, qreb, qdn, qt0, lpt, qpt, qhist,
            rate=rate)
        if hermes:
            hstate = hermes_interval(now, dt, dt_i, ldn, hstate)
        lat = lat_interval(lat, dt_i, ldn, qmaj_prev, rem0)
        now = t_clamp

        if packed:
            full = xp.where(lark[:, None, :], crepsw, full)
        else:
            full = xp.where(lark[:, :, None],
                            out_t[-2].reshape(B, P, n), full)
        ldn, lt0, leader, lpt, lev, lhist, pen = lark_transitions(
            t_clamp, lark, ldr, lfull, ldn, lt0, leader, lpt, lev, lhist)
        lat = lat_dirty_reset(lat, pen)

        # -- a replica loss (re)starts the constant countdown, now in
        # fixed-point units, and pins the rebuild to the lost replica's
        # node: the lowest replica lane that went up -> down this step
        # (simultaneous losses replay onto the first — one log stream
        # per partition, like the reconfig model's single recruit)
        if rebuild_fp is not None and rebuild_fp > 0:
            lost = qrep & ~rep_new                            # (B, P, rf)
            loss = xp.any(lost, axis=2)
            qreb = xp.where(loss, xp.int32(rebuild_fp), qreb)
            rank = xp.min(xp.where(lost,
                                   xp.arange(rf, dtype=xp.int32)
                                   [None, None, :], xp.int32(rf)), axis=2)
            node = succ[xp.arange(P, dtype=xp.int32)[None, :],
                        xp.clip(rank, 0, rf - 1)]
            recruit = xp.where(loss, node, recruit)
        qdn, qt0, qev, qhist = quorum_transitions(
            t_clamp, qmaj, qreb, qdn, qt0, qev, qhist)
        qrep = rep_new
        if hermes:
            hstate = hermes_post(t_clamp, lark, repm, hstate)

        carry = (now, up, ev_t, full, rr_t, rr_idx, lane0, ldn, lt0,
                 qrep, qreb, qdn, qt0, leader, lpt, qpt, lev, qev,
                 lhist, qhist, recruit) + (hstate if hermes else ()) + lat
        out = (t_clamp, xp.sum(ldn, axis=1).astype(xp.int32),
               xp.sum(qdn, axis=1).astype(xp.int32),
               xp.sum(up, axis=1).astype(xp.int32))
        if hermes:
            out = out + (xp.sum(hstate[0], axis=1).astype(xp.int32),)
        return carry, out

    lanes_n = xp.arange(n, dtype=xp.int32)

    def recruit_roster(up_succ, rup, roster):
        """Replace every down roster member with the first up node in
        succession order not already in the roster (if none is up, the
        seat is kept until a later step finds one).  Returns the new
        roster plus (new_rank, took) — the most recent recruit's
        succession rank per partition and whether any seat was filled."""
        if not recruit_on:       # necessity hook: no seat is ever filled
            return (roster, xp.full(rup.shape[:2], n, dtype=xp.int32),
                    xp.zeros(rup.shape[:2], dtype=bool))
        in_roster = xp.zeros(up_succ.shape, dtype=bool)
        for j in range(rf):
            in_roster = in_roster | (lanes_n[None, None, :]
                                     == roster[:, :, j, None])
        slot = xp.arange(rf, dtype=xp.int32)
        new_rank = xp.full(rup.shape[:2], n, dtype=xp.int32)
        took = xp.zeros(rup.shape[:2], dtype=bool)
        for j in range(rf):
            need = ~rup[:, :, j]
            cand = up_succ & ~in_roster
            repl = xp.min(xp.where(cand, lanes_n[None, None, :],
                                   xp.int32(n)), axis=2)
            take = need & (repl < n)
            old_j = roster[:, :, j]
            new_j = xp.where(take, repl, old_j)
            in_roster = in_roster & ~(take[:, :, None] &
                                      (lanes_n[None, None, :]
                                       == old_j[:, :, None]))
            in_roster = in_roster | (take[:, :, None] &
                                     (lanes_n[None, None, :]
                                      == new_j[:, :, None]))
            roster = xp.where((slot == j)[None, None, :],
                              new_j[:, :, None], roster)
            new_rank = xp.where(take, repl, new_rank)
            took = took | take
        return roster, new_rank, took

    def step_reconfig(carry, s):
        """The reconfiguring baseline: identical to `step` (same shared
        protocol blocks) except the quorum-log replica set is the carried
        per-partition roster of succession ranks (reconfigured onto live
        nodes after losses) and the catch-up countdown is the
        per-partition `rebuild_ticks` table, in _REB_SCALE fixed-point
        work units so concurrent catch-ups ingesting on one recruit node
        can share its bandwidth (rate = min(full speed, bandwidth / k)
        recomputed at every event boundary from the carried recruit node
        ids; bandwidth_fp=None skips the reduction and runs every rebuild
        at full speed — the unshared model, bit for bit).  LARK's code
        path is untouched, so LARK outputs are bit-identical across
        rebuild models and bandwidth settings."""
        (now, up, ev_t, full, rr_t, rr_idx, lane0, ldn, lt0, qrep, qreb,
         qdn, qt0, leader, lpt, qpt, lev, qev, lhist, qhist,
         roster, recruit) = carry[:22]
        ke = 22
        hstate = sstate = None
        if hermes:
            hstate = carry[ke:ke + 7]
            ke += 7
        if spinnaker:
            sstate = carry[ke:ke + 7]
            ke += 7
        lat = carry[ke:]
        B = up.shape[0]               # local trials (a shard of the batch)
        t_clamp, dt, active, up, ev_t, rr_t, rr_idx = advance(
            now, up, ev_t, rr_t, rr_idx, lane0, s)
        dt_i = t_clamp - now                                  # (B,) int32
        # -- per-node bandwidth contention over this interval: in-flight
        # catch-ups ingesting on the same recruit node split its
        # bandwidth evenly (the in-flight set only changes at events, so
        # the share is constant within an interval; a catch-up whose
        # recruit is unknown — lost during a no-candidate stretch — runs
        # uncontended).  The node-count reduction is the engine's only
        # cross-partition coupling; it stays within each trial, so
        # trials-axis sharding commutes with it (docs/ARCHITECTURE.md).
        if bandwidth_fp is None:
            rate = xp.full((B, P), _REB_SCALE, dtype=xp.int32)
        else:
            inflight = (qreb > 0) & (recruit < n)
            counts = cnt_fn(recruit, inflight)                # (B, n)
            k = xp.take_along_axis(counts,
                                   xp.clip(recruit, 0, n - 1), axis=1)
            # sentinel-recruit partitions must not inherit node n-1's
            # in-flight count from the clipped gather: no known ingest
            # node means no contention
            k = xp.where(recruit < n, xp.maximum(k, 1), 1)
            rate = xp.minimum(xp.int32(_REB_SCALE),
                              xp.int32(bandwidth_fp) // k)
        lpt, qpt, qreb, qdn, qhist, qmaj_prev, rem0 = interval_pause(
            now, dt, dt_i, ldn, qrep, qreb, qdn, qt0, lpt, qpt, qhist,
            rate=rate)
        if hermes:
            hstate = hermes_interval(now, dt, dt_i, ldn, hstate)
        if spinnaker:
            sstate = spinnaker_interval(now, dt, dt_i, qmaj_prev, rem0,
                                        sstate)
        lat = lat_interval(lat, dt_i, ldn, qmaj_prev, rem0)
        now = t_clamp

        # -- post-event cluster state; fresh losses are roster members
        # that were up at interval start and are down now
        up_succ = up[:, succ]                                 # (B, P, n)
        rup = xp.take_along_axis(up_succ, roster, axis=2)     # (B, P, rf)
        loss_any = xp.any(qrep & ~rup, axis=2)

        # -- recruit: every down roster member is replaced by the first
        # up node in succession order not already in the roster
        roster, new_rank, took = recruit_roster(up_succ, rup, roster)

        # -- each fresh loss (re)starts the data-sized catch-up countdown
        qreb = xp.where(loss_any, rebuild_ticks[None, :], qreb)
        # -- the ingesting node is the most recently recruited member
        # (ranks are per-partition succession indices; bandwidth is per
        # physical node, so map through the succession matrix).  A loss
        # with no candidate leaves the seat — and the ingest node —
        # unknown until late recruitment fills it.
        new_node = succ[xp.arange(P, dtype=xp.int32)[None, :],
                        xp.clip(new_rank, 0, n - 1)]
        recruit = xp.where(took, new_node,
                           xp.where(loss_any, xp.int32(n), recruit))

        # -- roster-aware per-step evaluation on the reconfigured roster
        out_t = dt_fn(
            up_succ.reshape(B * P, n), full.reshape(B * P, n),
            roster.reshape(B * P, rf))
        lark = out_t[0].reshape(B, P)
        qmaj = out_t[1].reshape(B, P)
        ldr = out_t[2].reshape(B, P)
        lfull = out_t[3].reshape(B, P)
        repm = out_t[5].reshape(B, P) if hermes else None
        rlead = out_t[5 + int(hermes)].reshape(B, P) if spinnaker \
            else None
        full = xp.where(lark[:, :, None], out_t[-1].reshape(B, P, n),
                        full)

        ldn, lt0, leader, lpt, lev, lhist, pen = lark_transitions(
            t_clamp, lark, ldr, lfull, ldn, lt0, leader, lpt, lev, lhist)
        lat = lat_dirty_reset(lat, pen)
        qdn, qt0, qev, qhist = quorum_transitions(
            t_clamp, qmaj, qreb, qdn, qt0, qev, qhist)
        qrep = xp.take_along_axis(up_succ, roster, axis=2)
        if hermes:
            hstate = hermes_post(t_clamp, lark, repm, hstate)
        if spinnaker:
            sstate = spinnaker_post(t_clamp, qmaj, qreb, qrep, roster,
                                    rlead, sstate)

        carry = (now, up, ev_t, full, rr_t, rr_idx, lane0, ldn, lt0,
                 qrep, qreb, qdn, qt0, leader, lpt, qpt, lev, qev,
                 lhist, qhist, roster, recruit) \
            + (hstate if hermes else ()) \
            + (sstate if spinnaker else ()) + lat
        out = (t_clamp, xp.sum(ldn, axis=1).astype(xp.int32),
               xp.sum(qdn, axis=1).astype(xp.int32),
               xp.sum(up, axis=1).astype(xp.int32))
        if hermes:
            out = out + (xp.sum(hstate[0], axis=1).astype(xp.int32),)
        if spinnaker:
            out = out + (xp.sum(sstate[0], axis=1).astype(xp.int32),)
        return carry, out

    def step_reconfig_packed(carry, s):
        """step_reconfig over packed (B, W, P) holder words, reordered so
        the whole post-event evaluation — both protocols, the roster
        membership, and the bandwidth model's in-flight node counts — is
        ONE dt_fn call (one fused pallas_call on that backend).  Pure
        dataflow reorder of the unfused step: the reconfiguration runs
        first (it needs only the advanced up mask and carried roster
        state), the counts still see the carried interval-start
        recruit/qreb, and interval_pause still sees interval-start
        protocol state — trajectories are bit-identical."""
        (now, up, ev_t, full, rr_t, rr_idx, lane0, ldn, lt0, qrep, qreb,
         qdn, qt0, leader, lpt, qpt, lev, qev, lhist, qhist,
         roster, recruit) = carry[:22]
        ke = 22
        hstate = sstate = None
        if hermes:
            hstate = carry[ke:ke + 7]
            ke += 7
        if spinnaker:
            sstate = carry[ke:ke + 7]
            ke += 7
        lat = carry[ke:]
        B = up.shape[0]               # local trials (a shard of the batch)
        t_clamp, dt, active, up, ev_t, rr_t, rr_idx = advance(
            now, up, ev_t, rr_t, rr_idx, lane0, s)
        dt_i = t_clamp - now                                  # (B,) int32

        # post-event cluster state + reconfiguration up front (same rules
        # as step_reconfig, via the shared recruit_roster closure)
        up_succ = up[:, succ]                                 # (B, P, n)
        rup = xp.take_along_axis(up_succ, roster, axis=2)     # (B, P, rf)
        loss_any = xp.any(qrep & ~rup, axis=2)
        roster, new_rank, took = recruit_roster(up_succ, rup, roster)

        # the single per-step eval: packed words + reconfigured roster
        # (+ carried recruit/in-flight for the contention counts).  The
        # protocol-zoo extras sit between nrep and crepsw, so the fixed
        # landmarks are out_t[:4] and the crepsw/counts tail offsets.
        ne = int(hermes) + int(spinnaker)
        upw = xp.moveaxis(bitpack.pack_words(up_succ, xp), -1, 1)
        if bandwidth_fp is None:
            out_t = dt_fn(upw, full, roster)
            rate = xp.full((B, P), _REB_SCALE, dtype=xp.int32)
        else:
            inflight = (qreb > 0) & (recruit < n)
            out_t = dt_fn(upw, full, roster, recruit, inflight)
            counts = out_t[6 + ne]
            k = xp.take_along_axis(counts,
                                   xp.clip(recruit, 0, n - 1), axis=1)
            k = xp.where(recruit < n, xp.maximum(k, 1), 1)
            rate = xp.minimum(xp.int32(_REB_SCALE),
                              xp.int32(bandwidth_fp) // k)
        lark, qmaj, ldr, lfull = out_t[:4]
        crepsw = out_t[5 + ne]
        repm = out_t[5] if hermes else None
        rlead = out_t[5 + int(hermes)] if spinnaker else None

        lpt, qpt, qreb, qdn, qhist, qmaj_prev, rem0 = interval_pause(
            now, dt, dt_i, ldn, qrep, qreb, qdn, qt0, lpt, qpt, qhist,
            rate=rate)
        if hermes:
            hstate = hermes_interval(now, dt, dt_i, ldn, hstate)
        if spinnaker:
            sstate = spinnaker_interval(now, dt, dt_i, qmaj_prev, rem0,
                                        sstate)
        lat = lat_interval(lat, dt_i, ldn, qmaj_prev, rem0)
        now = t_clamp

        qreb = xp.where(loss_any, rebuild_ticks[None, :], qreb)
        new_node = succ[xp.arange(P, dtype=xp.int32)[None, :],
                        xp.clip(new_rank, 0, n - 1)]
        recruit = xp.where(took, new_node,
                           xp.where(loss_any, xp.int32(n), recruit))

        full = xp.where(lark[:, None, :], crepsw, full)
        ldn, lt0, leader, lpt, lev, lhist, pen = lark_transitions(
            t_clamp, lark, ldr, lfull, ldn, lt0, leader, lpt, lev, lhist)
        lat = lat_dirty_reset(lat, pen)
        qdn, qt0, qev, qhist = quorum_transitions(
            t_clamp, qmaj, qreb, qdn, qt0, qev, qhist)
        qrep = xp.take_along_axis(up_succ, roster, axis=2)
        if hermes:
            hstate = hermes_post(t_clamp, lark, repm, hstate)
        if spinnaker:
            sstate = spinnaker_post(t_clamp, qmaj, qreb, qrep, roster,
                                    rlead, sstate)

        carry = (now, up, ev_t, full, rr_t, rr_idx, lane0, ldn, lt0,
                 qrep, qreb, qdn, qt0, leader, lpt, qpt, lev, qev,
                 lhist, qhist, roster, recruit) \
            + (hstate if hermes else ()) \
            + (sstate if spinnaker else ()) + lat
        out = (t_clamp, xp.sum(ldn, axis=1).astype(xp.int32),
               xp.sum(qdn, axis=1).astype(xp.int32),
               xp.sum(up, axis=1).astype(xp.int32))
        if hermes:
            out = out + (xp.sum(hstate[0], axis=1).astype(xp.int32),)
        if spinnaker:
            out = out + (xp.sum(sstate[0], axis=1).astype(xp.int32),)
        return carry, out

    if rebuild_model == "reconfig":
        return step_reconfig_packed if packed else step_reconfig
    if bandwidth_fp is not None:
        return step_fixed_bw
    return step


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def simulate_downtime_batched(
        *, n: int = 155, partitions: int = 4096, rf: int = 2,
        p: float = 1e-3, downtime: int = 10, trials: int = 8,
        min_ticks: int = 50_000, max_ticks: int = 3_000_000,
        eps_abs: float = 5e-6, eps_rel: float = 0.05,
        min_events: int = 200, seed: int = 0, backend: str = "jax",
        dupres_ticks: int = 1, rebuild_steps: int = 100,
        hist_bins: int = 16,
        rebuild_model: str = "fixed", rebuild_ticks_per_gib: int = 100,
        size_dist: str = "uniform", size_skew: float = 1.0,
        node_bandwidth_gibps: float = math.inf,
        pair_fail_prob: float = 0.0, restart_period: int = 0,
        wave_width: int = 1, p_node=None, downtime_node=None,
        devices: int = 1, pac_block_p: Optional[int] = None,
        chunk_steps: int = 512, max_steps: Optional[int] = None,
        trajectory: bool = False,
        use_shard_map: Optional[bool] = None,
        params: Optional[DowntimeParams] = None, packed: bool = False,
        block_t: Optional[int] = None,
        engines: tuple = ("lark", "quorum"), lease_ticks: int = 0,
        view_change_ticks: int = 0,
        _disable_predicates: tuple = (),
        _lat_plan=None) -> BatchedDowntimeResult:
    """Batched §6 commit-pause Monte Carlo over `trials` trajectories.

    Accepts the availability engine's cluster/scenario knobs unchanged
    (every core/scenarios.py policy runs here too), plus the protocol/
    rebuild knobs below.  They can be passed individually (legacy
    keywords) or as one pre-validated `params=DowntimeParams(...)` —
    when `params` is given it takes precedence and the individual
    keywords are ignored; either way DowntimeParams owns the validation
    rules, so every entry point raises the identical errors.

    packed=True carries the holder masks as bit-packed (B, W, P) uint32
    words and evaluates each step through kernels/bitpack.py — on
    backend="pallas" via the fused step megakernel (one pallas_call for
    both protocols + roster + rebuild node counts; tile (block_t,
    block_p)).  Layout/fusion only: trajectories are bit-identical to
    packed=False on every backend.

    dupres_ticks   LARK's per-leader-change duplicate-resolution cost in
                   ticks (0 disables; then LARK pause == instantaneous
                   PAC unavailability exactly).  The charge is
                   instantaneous, so a cost comparable to the horizon can
                   push the raw pause integral past wall time; reported
                   fractions are clipped to [0, 1].
    rebuild_model  "fixed" (default): static first-rf replica set with a
                   constant rebuild countdown — the pre-roster baseline,
                   bit-identical to it.  "reconfig": replica-set
                   reconfiguration onto live nodes with a data-sized
                   catch-up (see the module docstring).
    rebuild_steps  fixed-model rebuild countdown after a replica loss
                   (0 disables; then quorum pause == plain
                   majority-of-replica-set unavailability exactly).
                   Ignored under rebuild_model="reconfig".
    rebuild_ticks_per_gib
                   reconfig-model catch-up cost per GiB of partition
                   data; per-partition sizes come from `size_dist`
                   (partition_sizes_gib).  Ignored under
                   rebuild_model="fixed".
    size_dist      per-partition data-size distribution for the reconfig
                   catch-ups (SIZE_DISTS): "uniform" (the [1, 2) GiB
                   baseline, default), "zipf" (hot-partition Pareto
                   skew), or "lognormal" — all pinned to the uniform
                   mean of 1.5 GiB so the equal-storage budget is
                   identical across distributions.  Reconfig only.
    size_skew      shape parameter of the skewed dists (Pareto exponent /
                   log-space sigma); 0 collapses either to a constant
                   1.5 GiB.  Inert under size_dist="uniform".
    node_bandwidth_gibps
                   per-node catch-up ingest bandwidth, in units of
                   full-speed catch-up streams (1 stream == 1 GiB/s at
                   one tick per second; `rebuild_ticks_per_gib` prices a
                   GiB at that full-speed rate).  Concurrent catch-ups
                   recruited onto the same node split it evenly: each
                   advances min(1, bandwidth / k) countdown-ticks per
                   tick, quantized to 1/256 (pure int32 fixed-point, so
                   cross-backend bit-identity holds; a share below the
                   quantum — k > 256 x bandwidth — rounds to zero and
                   the catch-up stalls until contention eases, which is
                   why bandwidth itself must be >= 1/256).  The default
                   inf disables sharing and is bit-identical to the
                   unshared parallel-rebuild model.  Applies to both
                   rebuild models: under rebuild_model="fixed" a lost
                   replica's log replays onto its *own* node (lowest
                   lost succession rank), so concurrent fixed-model
                   rebuilds landing on one node split its bandwidth the
                   same way reconfig catch-ups do.
    hist_bins      power-of-two duration buckets ([1,2), [2,4), ...,
                   top bucket open-ended).

    devices > 1 shards trials over the same 1-D "trials" mesh as the
    availability engine — bit-identical to devices=1 for the same seed.

    engines selects the protocol zoo to report (ENGINES; lark and quorum
    are the paper's pair and always simulated — listing extra engines
    adds their state machines on the *same* node trajectories, changing
    no lark/quorum output bit).  lease_ticks prices the hermes engine's
    membership-lease epoch (0 pins hermes to the zero-knob LARK trace
    exactly, given dupres_ticks=0); view_change_ticks prices the
    spinnaker engine's leader-loss log reconciliation (0 pins spinnaker
    to the reconfig quorum baseline exactly).  _disable_predicates
    (private, DISABLE_PREDICATES) strips single transition predicates for
    the necessity tests.

    _lat_plan (private; set by core/client_latency.py) appends the
    client-latency layer's per-(trial, partition) float32 accumulators to
    the scan carry and fills `latency_raw` on the result — the downtime
    outputs themselves are untouched (the layer reads protocol state,
    never writes it).
    """
    _validate_batched_args(backend=backend, devices=devices, trials=trials,
                           wave_width=wave_width, n=n)
    if params is None:
        params = DowntimeParams(
            dupres_ticks=dupres_ticks, rebuild_steps=rebuild_steps,
            hist_bins=hist_bins, rebuild_model=rebuild_model,
            rebuild_ticks_per_gib=rebuild_ticks_per_gib,
            size_dist=size_dist, size_skew=size_skew,
            node_bandwidth_gibps=node_bandwidth_gibps,
            engines=engines, lease_ticks=lease_ticks,
            view_change_ticks=view_change_ticks)
    dupres_ticks, rebuild_steps = params.dupres_ticks, params.rebuild_steps
    hist_bins, rebuild_model = params.hist_bins, params.rebuild_model
    rebuild_ticks_per_gib = params.rebuild_ticks_per_gib
    size_dist, size_skew = params.size_dist, params.size_skew
    node_bandwidth_gibps = params.node_bandwidth_gibps
    reconfig = params.reconfig
    bandwidth_shared = params.bandwidth_shared
    engines = params.engines
    lease_ticks = params.lease_ticks
    view_change_ticks = params.view_change_ticks
    hermes_on, spinnaker_on = params.hermes, params.spinnaker
    disable = frozenset(_disable_predicates)
    unknown = disable - set(DISABLE_PREDICATES)
    if unknown:
        raise ValueError(f"unknown disable predicates {sorted(unknown)}; "
                         f"expected a subset of {DISABLE_PREDICATES}")
    if (reconfig or bandwidth_shared) \
            and max_ticks > (2 ** 31 - 1) // _REB_SCALE - 2:
        raise ValueError("max_ticks too large for the fixed-point "
                         f"catch-up countdowns (<= "
                         f"{(2 ** 31 - 1) // _REB_SCALE - 2})")
    shard = use_shard_map if use_shard_map is not None else devices > 1
    B, P, horizon = trials, partitions, max_ticks
    (xp, succ, seed_mix, geo_masks, geo_tables, dt_vec, pair_perm,
     p_arr, dt_arr) = _engine_setup(
        backend, n=n, partitions=P, seed=seed, p=p, downtime=downtime,
        p_node=p_node, downtime_node=downtime_node, max_ticks=max_ticks)
    zoo = tuple(e for e in ("hermes", "spinnaker") if e in engines)
    spec = StepSpec(metric="downtime", rf=rf, n_real=n,
                    rebuild_model=rebuild_model, packed=packed,
                    dupres_ticks=dupres_ticks, rebuild_steps=rebuild_steps,
                    engines=zoo)

    def dt_fn(u, f, roster=None, recruit=None, active=None):
        o = step_eval(spec, u, f, roster=roster, recruit=recruit,
                      active=active, backend=backend, block_p=pac_block_p,
                      block_t=block_t)
        extras = ()
        if o.repmask is not None:
            extras = extras + (o.repmask,)
        if o.rleader is not None:
            extras = extras + (o.rleader,)
        base = (o.lark, o.maj, o.leader, o.leader_full, o.nrep) \
            + extras + (o.creps,)
        return (base + (o.counts,)) if recruit is not None else base

    rebuild_ticks = xp.asarray(_partition_rebuild_ticks(
        seed, P, rebuild_ticks_per_gib, dist=size_dist, skew=size_skew,
        cap=max_ticks + 1) * np.int32(_REB_SCALE)) if reconfig else None
    bandwidth_fp = int(min(math.floor(_REB_SCALE * node_bandwidth_gibps),
                           int(_REB_BIG))) if bandwidth_shared else None
    cnt_fn = (lambda rec, act: _rebuild_node_counts_impl(
        rec, act, n_real=n, backend=backend)) if bandwidth_shared else None
    # fixed-model restart value in fixed-point work units; the horizon
    # cap keeps rebuild_steps * _REB_SCALE inside int32 and is
    # observationally invisible (a countdown past the horizon can never
    # complete in-simulation), mirroring _partition_rebuild_ticks's cap
    rebuild_fp = int(min(rebuild_steps, max_ticks + 1)) * _REB_SCALE \
        if (bandwidth_shared and not reconfig) else None
    advance = _make_node_advance(
        xp, n=n, horizon=horizon, dt_vec=dt_vec, geo_masks=geo_masks,
        geo_tables=geo_tables, seed_mix=seed_mix,
        pair_fail_prob=pair_fail_prob, pair_perm=pair_perm,
        restart_period=restart_period, wave_width=wave_width)
    lat_fn = None
    if _lat_plan is not None:
        lat_pow = xp.asarray(_lat_plan.pow_tables)
        lat_kf = xp.asarray(_lat_plan.kf)
        lat_lamw = xp.asarray(_lat_plan.lamw)
        lat_nbins, lat_slo = _lat_plan.nbins, _lat_plan.slo_ticks

        def lat_fn(lat, dt_i, avail, qok, rem):
            nd, di, hi, si, qi = client_latency_step(
                lat[0], dt_i, avail, qok, rem, pow_tables=lat_pow,
                kf=lat_kf, lamw=lat_lamw, nbins=lat_nbins,
                slo_ticks=lat_slo, backend=backend)
            return (nd, lat[1] + di, lat[2] + hi, lat[3] + si,
                    lat[4] + qi)
    step = _make_step(xp, dt_fn, advance, succ, n=n, P=P, rf=rf,
                      dupres_ticks=dupres_ticks,
                      rebuild_steps=rebuild_steps, hist_bins=hist_bins,
                      rebuild_model=rebuild_model,
                      rebuild_ticks=rebuild_ticks,
                      bandwidth_fp=bandwidth_fp, cnt_fn=cnt_fn,
                      rebuild_fp=rebuild_fp,
                      packed=packed, lat_fn=lat_fn, engines=zoo,
                      lease_ticks=lease_ticks,
                      view_change_ticks=view_change_ticks,
                      disable=disable)

    # initial state: everyone up, roster replicas full, both protocols
    # evaluated once at t=0 (identical to the availability engine's init;
    # the t=0 roster is [0..rf-1] per partition, so the non-roster init
    # evaluation is exact for both rebuild models)
    lane0, up0, ev0, rr_t0 = _initial_node_state(
        xp, B=B, n=n, seed_mix=seed_mix, geo_masks=geo_masks,
        geo_tables=geo_tables, restart_period=restart_period,
        horizon=horizon)
    full0, outs0 = _initial_full_state(
        xp, backend, dt_fn, up0, succ, B=B, P=P, n=n, rf=rf, packed=packed)
    lark0 = outs0[0].reshape(B, P)
    qmaj0 = outs0[1].reshape(B, P)
    ldr0 = outs0[2].reshape(B, P)
    zi = xp.zeros((B,), dtype=xp.int32)
    zf = xp.zeros((B,), dtype=xp.float32)
    zbp = xp.zeros((B, P), dtype=xp.int32)
    zh = xp.zeros((B, hist_bins), dtype=xp.int32)
    carry = (zi, up0, ev0, full0, rr_t0, zi, lane0,
             ~lark0, zbp,                              # ldn, lt0
             up0[:, succ[:, :rf]],                     # qrep (all up)
             zbp,                                      # qreb
             ~qmaj0, zbp,                              # qdn, qt0
             ldr0.astype(xp.int32),                    # leader
             zf, zf, zi, zi, zh, zh)
    if reconfig:
        roster0 = xp.broadcast_to(
            xp.arange(rf, dtype=xp.int32)[None, None, :], (B, P, rf))
        if backend == "numpy":
            roster0 = np.ascontiguousarray(roster0)
        # no catch-up in flight at t=0, so no recruit node to ingest on
        recruit0 = xp.full((B, P), n, dtype=xp.int32)
        carry = carry + (roster0, recruit0)
    elif bandwidth_shared:
        # fixed model with bandwidth contention carries only the
        # rebuilding-node leaf (the replica set itself is static)
        carry = carry + (xp.full((B, P), n, dtype=xp.int32),)
    h0 = len(carry)                   # hermes leaves start here (if any)
    if hermes_on:
        # the t=0 membership view is the kernel's repmask on the initial
        # state; the pause mask starts exactly at LARK's (no lease runs)
        hmask0 = outs0[5].reshape(B, P).astype(xp.int32)
        carry = carry + (~lark0, zbp, hmask0, zbp, zf, zi, zh)
    s0_i = len(carry)                 # spinnaker leaves start here
    if spinnaker_on:
        # rank 0 leads at t=0 (everyone up, roster [0..rf-1]); no view
        # change in flight, so the pause mask starts at the quorum
        # baseline's
        carry = carry + (~qmaj0, zbp, zbp, zbp, zf, zi, zh)
    lat_i = len(carry)                # lat leaves ride at the carry tail
    if _lat_plan is not None:
        nb = _lat_plan.kf.shape[0]
        lz_nb = xp.zeros((B, P, nb), dtype=xp.float32)
        lz_hb = xp.zeros((B, P, _lat_plan.nbins), dtype=xp.float32)
        lz_bp = xp.zeros((B, P), dtype=xp.float32)
        # dirty starts clean (no leader has changed yet), charges at zero
        carry = carry + (lz_nb, lz_nb, lz_hb, lz_bp, lz_bp)

    if backend != "numpy":
        import jax.numpy as jnp
        run_chunk = _make_chunk_runner(step, carry, chunk_steps=chunk_steps,
                                       devices=devices, shard=shard,
                                       n_outputs=4 + int(hermes_on)
                                       + int(spinnaker_on))

    if max_steps is None:
        max_steps = _default_max_steps(p_arr, dt_arr, n=n, horizon=horizon,
                                       restart_period=restart_period)

    # per-chunk accumulator reset map: the base protocol accumulators at
    # fixed offsets 14..19 plus, when enabled, each zoo engine's
    # (pause-time, events, histogram) leaves at offset +4..+6 of its block
    acc_reset = {14: zf, 15: zf, 16: zi, 17: zi, 18: zh, 19: zh}
    if hermes_on:
        acc_reset.update({h0 + 4: zf, h0 + 5: zi, h0 + 6: zh})
    if spinnaker_on:
        acc_reset.update({s0_i + 4: zf, s0_i + 5: zi, s0_i + 6: zh})

    lpt_tot = np.zeros(B)
    qpt_tot = np.zeros(B)
    lev_tot = qev_tot = 0
    lhist_tot = np.zeros(hist_bins, dtype=np.int64)
    qhist_tot = np.zeros(hist_bins, dtype=np.int64)
    if hermes_on:
        hpt_tot = np.zeros(B)
        hev_tot = 0
        hhist_tot = np.zeros(hist_bins, dtype=np.int64)
    if spinnaker_on:
        spt_tot = np.zeros(B)
        sev_tot = 0
        shist_tot = np.zeros(hist_bins, dtype=np.int64)
    if _lat_plan is not None:
        lat_dup = np.zeros((B, _lat_plan.kf.shape[0]))
        lat_qhist = np.zeros((B, _lat_plan.nbins))
        lat_qslo = np.zeros(B)
        lat_qsum = np.zeros(B)
        lat_wfp = None
        if _lat_plan.wfp is not None:
            # skewed write mix: pool a second, write-fraction-weighted
            # view of the same dup charges (hermes pays dup-res on writes
            # only, so its share is per-partition under write_skew)
            lat_wfp = np.asarray(_lat_plan.wfp, dtype=np.float64)
            lat_dupw = np.zeros((B, _lat_plan.kf.shape[0]))
    traj = [] if trajectory else None
    stopped = False
    s0 = 1
    while s0 < max_steps:
        if backend == "numpy":
            carry, ys = _run_chunk_numpy(step, carry, s0, chunk_steps)
        else:
            carry, ys = run_chunk(carry, jnp.int32(s0))
        s0 += chunk_steps
        if trajectory:
            traj.append(tuple(np.asarray(c) for c in ys))
        # drain per-chunk accumulators into float64/int totals
        now = np.asarray(carry[0], dtype=np.int64)
        lpt_tot += np.asarray(carry[14], dtype=np.float64)
        qpt_tot += np.asarray(carry[15], dtype=np.float64)
        lev_tot += int(np.asarray(carry[16]).sum())
        qev_tot += int(np.asarray(carry[17]).sum())
        lhist_tot += np.asarray(carry[18], dtype=np.int64).sum(axis=0)
        qhist_tot += np.asarray(carry[19], dtype=np.int64).sum(axis=0)
        if hermes_on:
            hpt_tot += np.asarray(carry[h0 + 4], dtype=np.float64)
            hev_tot += int(np.asarray(carry[h0 + 5]).sum())
            hhist_tot += np.asarray(carry[h0 + 6],
                                    dtype=np.int64).sum(axis=0)
        if spinnaker_on:
            spt_tot += np.asarray(carry[s0_i + 4], dtype=np.float64)
            sev_tot += int(np.asarray(carry[s0_i + 5]).sum())
            shist_tot += np.asarray(carry[s0_i + 6],
                                    dtype=np.int64).sum(axis=0)
        if _lat_plan is not None:
            # pool the per-(trial, partition) float32 charge accumulators
            # over partitions here, host-side in float64 — a fixed
            # summation order independent of backend and device sharding
            # (the dirty fractions persist; the charges restart per chunk)
            lt_ = carry[lat_i:]
            dup_bp = np.asarray(lt_[1], dtype=np.float64)
            lat_dup += dup_bp.sum(axis=1)
            if lat_wfp is not None:
                lat_dupw += (dup_bp * lat_wfp[None, :, None]).sum(axis=1)
            lat_qhist += np.asarray(lt_[2], dtype=np.float64).sum(axis=1)
            lat_qslo += np.asarray(lt_[3], dtype=np.float64).sum(axis=1)
            lat_qsum += np.asarray(lt_[4], dtype=np.float64).sum(axis=1)
            carry = carry[:lat_i] + (lt_[0], lz_nb, lz_hb, lz_bp, lz_bp)
        carry = tuple(acc_reset.get(i, c) for i, c in enumerate(carry))
        if (now >= horizon).all():
            break
        # pooled CI early stop, mirroring the availability engine's rule
        # (nominal binomial width; reported CIs use across-trial spread)
        if now.mean() >= min_ticks and lev_tot >= min_events \
                and qev_tot >= min_events:
            pt = float(P) * float(now.sum())
            u_l = min(lpt_tot.sum() / pt, 1.0)
            u_q = min(qpt_tot.sum() / pt, 1.0)
            hw_l = 1.96 * math.sqrt(max(u_l * (1 - u_l), 1e-30) / pt)
            hw_q = 1.96 * math.sqrt(max(u_q * (1 - u_q), 1e-30) / pt)
            if hw_l <= max(eps_abs, eps_rel * u_l) and \
                    hw_q <= max(eps_abs, eps_rel * u_q):
                stopped = True
                break

    now = np.maximum(np.asarray(carry[0], dtype=np.int64), 1)
    pt_b = P * now.astype(np.float64)
    pt = float(pt_b.sum())
    # fractions by construction, except the instantaneous dup-res charge
    # can overshoot wall time under extreme dupres_ticks — clip so the
    # reported values and the binomial u*(1-u) CI terms stay meaningful
    u_l = min(float(lpt_tot.sum()) / pt, 1.0)
    u_q = min(float(qpt_tot.sum()) / pt, 1.0)
    u_l_trials = np.minimum(lpt_tot / pt_b, 1.0)
    u_q_trials = np.minimum(qpt_tot / pt_b, 1.0)
    hw_l = hw_q = 0.0
    if B >= 3:
        t = t975(B - 1) / math.sqrt(B)
        hw_l = t * float(u_l_trials.std(ddof=1))
        hw_q = t * float(u_q_trials.std(ddof=1))
    traj_out = None
    if trajectory:
        names = ["times", "paused_lark", "paused_quorum", "nodes_up"]
        if hermes_on:
            names.append("paused_hermes")
        if spinnaker_on:
            names.append("paused_spinnaker")
        cols = [np.concatenate([c[i] for c in traj])
                for i in range(len(names))]
        traj_out = dict(zip(names, cols))
    lat_raw = None
    if _lat_plan is not None:
        lat_raw = {"dup": lat_dup, "qhist": lat_qhist, "qslo": lat_qslo,
                   "qsum": lat_qsum, "now": now.copy()}
        if lat_wfp is not None:
            lat_raw["dupw"] = lat_dupw

    def _engine_stats(pt_tot):
        u = min(float(pt_tot.sum()) / pt, 1.0)
        u_trials = np.minimum(pt_tot / pt_b, 1.0)
        hw = 0.0
        if B >= 3:
            hw = t975(B - 1) / math.sqrt(B) * float(u_trials.std(ddof=1))
        ci = max(hw, 1.96 * math.sqrt(max(u * (1 - u), 1e-30) / pt))
        return u, ci, u_trials

    zoo_kw = {}
    if hermes_on:
        u_h, ci_h, u_h_trials = _engine_stats(hpt_tot)
        zoo_kw.update(pause_hermes=u_h, ci_hermes=ci_h,
                      hermes_events=hev_tot, hist_hermes=hhist_tot,
                      pause_hermes_trials=u_h_trials)
    if spinnaker_on:
        u_s, ci_s, u_s_trials = _engine_stats(spt_tot)
        zoo_kw.update(pause_spinnaker=u_s, ci_spinnaker=ci_s,
                      spinnaker_events=sev_tot, hist_spinnaker=shist_tot,
                      pause_spinnaker_trials=u_s_trials)
    return BatchedDowntimeResult(
        p=p, rf=rf, n=n, partitions=P, trials=B, backend=backend,
        ticks=int(now.mean()), pause_lark=u_l, pause_quorum=u_q,
        lark_events=lev_tot, quorum_events=qev_tot,
        ci_lark=max(hw_l,
                    1.96 * math.sqrt(max(u_l * (1 - u_l), 1e-30) / pt)),
        ci_quorum=max(hw_q,
                      1.96 * math.sqrt(max(u_q * (1 - u_q), 1e-30) / pt)),
        dupres_ticks=dupres_ticks, rebuild_steps=rebuild_steps,
        stopped_early=stopped, devices=devices,
        rebuild_model=rebuild_model,
        rebuild_ticks_per_gib=rebuild_ticks_per_gib if reconfig else 0,
        size_dist=size_dist if reconfig else "uniform",
        size_skew=size_skew if size_dist in ("zipf", "lognormal") else 0.0,
        node_bandwidth_gibps=node_bandwidth_gibps,
        hist_edges=np.asarray([1 << k for k in range(hist_bins)],
                              dtype=np.int64),
        hist_lark=lhist_tot, hist_quorum=qhist_tot,
        pause_lark_trials=u_l_trials, pause_quorum_trials=u_q_trials,
        engines=engines, lease_ticks=lease_ticks,
        view_change_ticks=view_change_ticks,
        trajectory=traj_out, latency_raw=lat_raw, **zoo_kw)
