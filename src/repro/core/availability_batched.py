"""Batched device-resident availability Monte Carlo — paper §5.1 at scale.

Advances B independent failure trajectories x P partitions per device step.
Instead of a scalar heapq event loop (core/availability.py), every trial
keeps vectorized state — up mask (B, n), next-event times (B, n), frozen
holder masks (B, P, n) — and each step jumps every trial to its own next
event (``jax.lax.scan`` over event steps, chunked), evaluating PAC /
majority / current-replica conditions as one (B*P, n) rank-space tile
through the unified backend layer in kernels/ops.py:

  backend="numpy"   python chunk loop, vectorized numpy PAC (the event
                    engine's evaluate() math, shared code)
  backend="jax"     jit + lax.scan with the pure-jnp PAC oracle
  backend="pallas"  same scan, PAC via the Pallas kernel (compiled on TPU,
                    interpret mode on CPU)

All backends draw randomness from the same counter-based hash (splitmix-
style, implemented identically in numpy and jnp) keyed by the *global*
(trial, node) lane index, so for a given seed the three produce
bit-identical trajectories — and so do sharded runs: with ``devices=D``
the trials axis is split across a 1-D "trials" mesh (shard_map over
launch/mesh.make_trials_mesh), each shard scanning its B/D trials with its
own slice of the carried lane-offset vector.  Because no step computation
crosses trials and every variate is a pure function of (seed, step, global
lane), a D-device run is bit-identical to the single-device run — the
cross-device agreement tests hold it to that (validate on CPU with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``).

Model semantics match the event engine: geometric inter-failure gaps per
node, fixed downtime, whole-cluster SimpleMajority PAC with frozen holders
while unavailable, majority-of-2f+1 baseline, CI early stopping.  The one
intentional difference: simultaneous same-tick events are applied together
before re-evaluating (the scalar engine interleaves evaluations between
same-tick events), which can freeze a marginally different holder set on
coincident failures — a zero-measure-in-time difference that is invisible
at the CI tolerances used here.

Scenario knobs beyond the paper's i.i.d. grid (named policies over these
live in core/scenarios.py):
  pair_fail_prob  correlated dual failures: when a node fails, its pair
                  partner (2i <-> 2i+1) fails at the same tick with this
                  probability (shared rack / power domain).
  restart_period  rolling restart: every `restart_period` ticks the next
                  `wave_width` nodes in id order are taken down for their
                  downtime (§5.3's zero-downtime rolling-restart claim,
                  as a Monte Carlo scenario).
  wave_width      nodes per restart wave (1 = serial rolling restart).
  p_node          per-node failure probability (heterogeneous MTTF);
                  overrides the scalar `p` for gap scheduling — one
                  geometric CDF table per distinct value (per-class
                  tables selected by node masks), so use a few tiers,
                  not n distinct rates.
  downtime_node   per-node downtime ticks (flapping nodes recover fast);
                  overrides the scalar `downtime`.

The node-trajectory advance (`_make_node_advance` / `_initial_node_state`)
is the single source of randomness for every engine in this stack: the
§6 downtime engine (core/downtime_batched.py) imports it, consumes the
identical variate stream, and therefore replays bit-identical node
trajectories for equal knobs — the invariant that makes its zero-knob
degeneracy tests exact.  Extend the closure rather than drawing ad-hoc
randomness in a new engine; see docs/ARCHITECTURE.md.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

from ..kernels import bitpack
from ..kernels.ops import PAC_BACKENDS, StepSpec, step_eval
from .availability import t975
from .succession import succession_matrix_fast

_GEO_SALT = 0x9E3779B9
_PAIR_SALT = 0x85EBCA6B


# ---------------------------------------------------------------------------
# Counter-based RNG, identical under numpy and jax.numpy (uint32 ops wrap).
# ---------------------------------------------------------------------------

def _mix32(x, xp):
    """lowbias32-style avalanche on uint32 arrays."""
    x = x ^ (x >> 16)
    x = x * xp.uint32(0x21F0AAAD)
    x = x ^ (x >> 15)
    x = x * xp.uint32(0xD35A2D97)
    x = x ^ (x >> 15)
    return x


def _uniforms(seed_mix, step_u32, salt: int, lane0, n: int, xp):
    """(B, n) uniforms in [0, 1) from (seed, step, global lane) — stateless.

    ``lane0[b]`` is trial b's first *global* lane id (global_trial * n), so
    the variate a (trial, node) pair sees depends only on its global index,
    never on how the trials axis is sharded — this is what makes a
    shard_map'd run bit-identical to the single-device run.

    The step is hashed into a per-step *key* rather than multiplied into a
    flat counter: a `step * count + lane` counter wraps mod 2^32 and would
    replay the exact variate stream every 2^32/count steps (reachable on
    full-scale grids); keyed lane hashing has no such period.  Scalars are
    kept as 1-element arrays: numpy warns on wrapping *scalar* uint32
    arithmetic but wraps array arithmetic silently (and wrapping is exactly
    what a counter hash wants).
    """
    step_u32 = xp.reshape(step_u32, (1,)).astype(xp.uint32)
    key = _mix32(step_u32 ^ seed_mix ^ xp.uint32(salt), xp)
    lanes = (lane0[:, None] + xp.arange(n, dtype=xp.uint32)[None, :]) \
        * xp.uint32(0x9E3779B9)
    h = _mix32(_mix32(lanes ^ key, xp) ^ seed_mix, xp)
    return (h >> 8).astype(xp.float32) * xp.float32(1.0 / (1 << 24))


def _geometric_breaks(p: float, gap_cap: int) -> np.ndarray:
    """CDF breakpoints for Geom(p) inversion by searchsorted.

    A log-based inverse (floor(log1p(-u)/log1p(-p))) is NOT bit-stable
    across numpy and XLA (libm log1p differs by ulps, and a flipped floor
    forks the whole trajectory).  searchsorted is pure comparisons against
    a shared constant table, so every backend draws identical variates.

    The table covers every value a 24-bit uniform can reach OR stops at
    `gap_cap` entries, whichever is smaller.  The caller passes gap_cap >
    horizon + downtime: a clamped draw schedules its event past the
    horizon where it can never fire, so the truncation is behaviorally
    invisible while keeping the table O(horizon) instead of O(1/p)
    (p=1e-7 would otherwise build a multi-GB table).
    """
    k_max = int(math.ceil(math.log(2.0 ** -25) / math.log1p(-p))) + 2
    k_max = min(k_max, gap_cap)
    k = np.arange(1, k_max + 1, dtype=np.float64)
    return (-np.expm1(k * math.log1p(-p))).astype(np.float32)  # 1-(1-p)^k


def _geometric(u, breaks, xp):
    """Geom(p) on {1, 2, ...}: g = #{k : cdf(k) <= u} + 1."""
    return (xp.searchsorted(breaks, u, side="right") + 1).astype(xp.int32)


def _geo_tables(p_arr: np.ndarray, gap_cap: int, xp):
    """Per-node-class Geom(p) tables: (node masks, CDF tables) per unique p.

    Heterogeneous MTTF keeps one table per distinct failure probability
    (scenarios use a handful of tiers, never n distinct values) and selects
    per node with a mask — all comparisons, so cross-backend bit-identity
    is preserved.
    """
    uniq, inv = np.unique(p_arr, return_inverse=True)
    masks = [xp.asarray(inv == k) for k in range(len(uniq))]
    tables = [xp.asarray(_geometric_breaks(float(pv), gap_cap))
              for pv in uniq]
    return masks, tables


def _geometric_multi(u, geo_masks, geo_tables, xp):
    geo = _geometric(u, geo_tables[0], xp)
    for m, tbl in zip(geo_masks[1:], geo_tables[1:]):
        geo = xp.where(m[None, :], _geometric(u, tbl, xp), geo)
    return geo


# ---------------------------------------------------------------------------
# Result
# ---------------------------------------------------------------------------

@dataclass
class BatchedAvailabilityResult:
    p: float
    rf: int
    n: int
    partitions: int
    trials: int
    backend: str
    ticks: int                    # mean elapsed ticks per trial
    u_lark: float                 # pooled over trials
    u_maj: float
    lark_events: int
    maj_events: int
    ci_lark: float
    ci_maj: float
    stopped_early: bool
    devices: int = 1
    u_lark_trials: np.ndarray = field(repr=False, default=None)
    u_maj_trials: np.ndarray = field(repr=False, default=None)
    trajectory: Optional[Dict[str, np.ndarray]] = field(repr=False,
                                                        default=None)

    @property
    def improvement(self) -> float:
        return self.u_maj / self.u_lark if self.u_lark > 0 else math.inf


# ---------------------------------------------------------------------------
# Node-trajectory advance, written once for both array namespaces and shared
# with the downtime engine (core/downtime_batched.py): any engine built on it
# replays bit-identical failure/recovery trajectories for the same seed.
# ---------------------------------------------------------------------------

def _make_node_advance(xp, *, n: int, horizon: int, dt_vec, geo_masks,
                       geo_tables, seed_mix, pair_fail_prob: float,
                       pair_perm, restart_period: int, wave_width: int):
    """Closure advancing the node up/down state to the next event.

    advance(now, up, ev_t, rr_t, rr_idx, lane0, s) ->
        (t_clamp, dt, active, up, ev_t, rr_t, rr_idx)

    All randomness is drawn here (geometric gap redraws, correlated-pair
    coin flips), keyed by (seed, step s, global lane) — the invariant every
    engine on top of this must preserve is that it consumes *no* extra
    randomness, so availability and downtime runs with equal knobs see the
    same trajectory, and sharded runs match single-device bit for bit.
    """
    def advance(now, up, ev_t, rr_t, rr_idx, lane0, s):
        node_next = xp.min(ev_t, axis=1)                     # (B,)
        t_next = node_next if not restart_period else \
            xp.minimum(node_next, rr_t)
        active = t_next < horizon
        t_clamp = xp.minimum(t_next, xp.int32(horizon))
        dt = (t_clamp - now).astype(xp.float32)

        hit = (ev_t == t_next[:, None]) & active[:, None]
        fail_hit = hit & up
        rec_hit = hit & ~up
        if restart_period:
            rr_hit = active & (rr_t == t_next)
            offs = (xp.arange(n, dtype=xp.int32)[None, :]
                    - rr_idx[:, None]) % n
            tgt = offs < wave_width
            fail_hit = fail_hit | (tgt & up & rr_hit[:, None])
            rr_idx = xp.where(rr_hit, (rr_idx + wave_width) % n, rr_idx)
            rr_t = xp.where(rr_hit, rr_t + restart_period, rr_t)
        s_u32 = xp.asarray(s).astype(xp.uint32)
        if pair_fail_prob > 0.0:
            u2 = _uniforms(seed_mix, s_u32, _PAIR_SALT, lane0, n, xp)
            pf = fail_hit[:, pair_perm] & up & ~fail_hit & ~rec_hit & \
                (u2 < pair_fail_prob)
            fail_hit = fail_hit | pf
        up = (up & ~fail_hit) | rec_hit
        geo = _geometric_multi(
            _uniforms(seed_mix, s_u32, _GEO_SALT, lane0, n, xp),
            geo_masks, geo_tables, xp)
        ev_t = xp.where(fail_hit, t_clamp[:, None] + dt_vec[None, :],
                        xp.where(rec_hit, t_clamp[:, None] + geo, ev_t))
        return t_clamp, dt, active, up, ev_t, rr_t, rr_idx
    return advance


def _initial_node_state(xp, *, B: int, n: int, seed_mix, geo_masks,
                        geo_tables, restart_period: int, horizon: int):
    """(lane0, up0, ev0, rr_t0) — everyone up, first failures at geometric
    gaps drawn at step counter 0 (scan steps start at 1).  lane0 is the
    global first-lane index per trial, carried so each shard keeps its
    global identity after the trials axis is split."""
    lane0 = xp.arange(B, dtype=xp.uint32) * xp.uint32(n)
    up0 = xp.ones((B, n), dtype=bool)
    ev0 = _geometric_multi(
        _uniforms(seed_mix, xp.asarray(0, dtype=xp.uint32), _GEO_SALT,
                  lane0, n, xp),
        geo_masks, geo_tables, xp)
    rr_t0 = xp.full((B,), restart_period if restart_period else horizon + 1,
                    dtype=xp.int32)
    return lane0, up0, ev0, rr_t0


def _initial_full_state(xp, backend: str, eval_fn, up0, succ, *, B: int,
                        P: int, n: int, rf: int, packed: bool = False):
    """t=0 'has the latest copy' mask, shared by both engines: roster
    replicas full, one evaluation on that state, then available (PAC-ok)
    partitions refresh to the committed replica set.  eval_fn is pac_fn or
    dt_fn — both return the LARK mask first and creps last.  Returns
    (full0, eval outputs).

    packed=True carries the holder mask as (B, W, P) uint32 words instead
    of (B, P, n) bool; eval_fn then takes/returns word tensors and
    (B, P)-shaped rows (layout only — same bits)."""
    if packed:
        masks = bitpack.prefix_masks(rf, n)
        full0 = (xp.zeros((B, len(masks), P), dtype=xp.uint32)
                 + xp.asarray(masks, dtype=xp.uint32)[None, :, None])
        upw = xp.moveaxis(bitpack.pack_words(up0[:, succ], xp), -1, 1)
        outs = eval_fn(upw, full0)
        lark0, creps0 = outs[0], outs[-1]
        full0 = xp.where(lark0[:, None, :], creps0, full0)
        return full0, outs
    full0 = xp.zeros((B, P, n), dtype=bool)
    if backend == "numpy":
        full0[:, :, :rf] = True
    else:
        full0 = full0.at[:, :, :rf].set(True)
    outs = eval_fn(up0[:, succ].reshape(B * P, n), full0.reshape(B * P, n))
    lark0, creps0 = outs[0], outs[-1]
    full0 = xp.where(lark0.reshape(B, P)[:, :, None],
                     creps0.reshape(B, P, n), full0)
    return full0, outs


# ---------------------------------------------------------------------------
# Shared driver scaffolding: argument validation, per-run constants, and the
# chunk runners.  The downtime engine reuses all of it, so a retune of any
# trajectory-affecting constant (seed mixing, geometric tables, max_steps
# heuristic, shard specs) lands in both engines at once — a drift here would
# break the exact cross-engine degeneracies tests/test_downtime_batched.py
# pins.
# ---------------------------------------------------------------------------

def _validate_batched_args(*, backend: str, devices: int, trials: int,
                           wave_width: int, n: int):
    if backend not in PAC_BACKENDS:
        raise ValueError(f"backend must be one of {PAC_BACKENDS}")
    if devices < 1:
        raise ValueError("devices must be >= 1")
    if devices > 1 and backend == "numpy":
        raise ValueError("multi-device sharding needs a jax backend "
                         "('jax' or 'pallas'); numpy has no device mesh")
    if trials % devices:
        raise ValueError(f"trials ({trials}) must divide evenly across "
                         f"devices ({devices})")
    if not 1 <= wave_width <= n:
        raise ValueError("wave_width must be in [1, n]")


def _engine_setup(backend: str, *, n: int, partitions: int, seed: int,
                  p: float, downtime: int, p_node, downtime_node,
                  max_ticks: int):
    """(xp, succ, seed_mix, geo_masks, geo_tables, dt_vec, pair_perm,
    p_arr, dt_arr) — every deterministic per-run constant both engines
    share."""
    succ_np = succession_matrix_fast(partitions, range(n), seed=seed)
    if backend == "numpy":
        xp, succ = np, succ_np
    else:
        import jax.numpy as jnp
        xp, succ = jnp, jnp.asarray(succ_np)

    p_arr = np.full(n, p, dtype=np.float64) if p_node is None \
        else np.asarray(p_node, dtype=np.float64)
    dt_arr = np.full(n, downtime, dtype=np.int64) if downtime_node is None \
        else np.asarray(downtime_node, dtype=np.int64)
    if p_arr.shape != (n,) or dt_arr.shape != (n,):
        raise ValueError("p_node / downtime_node must have shape (n,)")
    if not ((p_arr > 0) & (p_arr < 1)).all() or (dt_arr < 1).any():
        raise ValueError("p_node must lie in (0, 1) and downtime_node >= 1")

    seed_mix = _mix32(xp.asarray([(seed & 0xFFFFFFFF) ^ 0x6A09E667],
                                 dtype=xp.uint32), xp)
    geo_masks, geo_tables = _geo_tables(
        p_arr, max_ticks + int(dt_arr.max()) + 2, xp)
    dt_vec = xp.asarray(dt_arr, dtype=xp.int32)
    pair_perm = np.arange(n)
    pair_perm[:n - n % 2] ^= 1
    return (xp, succ, seed_mix, geo_masks, geo_tables, dt_vec, pair_perm,
            p_arr, dt_arr)


def _default_max_steps(p_arr, dt_arr, *, n: int, horizon: int,
                       restart_period: int) -> int:
    """Step budget: ~3x the expected event count plus slack."""
    p_eff = float(p_arr.mean())
    per_trial = 2.0 * n * horizon / (1.0 / p_eff + float(dt_arr.mean()))
    if restart_period:
        per_trial += 2.0 * horizon / restart_period
    return int(3 * per_trial) + 2000


def _make_chunk_runner(step, carry, *, chunk_steps: int, devices: int,
                       shard: bool, n_outputs: int):
    """jit'd (carry, s0) -> (carry, ys) scanning `chunk_steps` steps,
    optionally shard_map'd over the trials mesh (dim 0 of every carry
    leaf; outputs stack steps in front)."""
    import jax
    import jax.numpy as jnp

    def _chunk(c, s0):
        return jax.lax.scan(
            step, c, s0 + jnp.arange(chunk_steps, dtype=jnp.int32))

    if shard:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec

        from ..launch.mesh import make_trials_mesh
        mesh = make_trials_mesh(devices)
        cspec = tuple(PartitionSpec("trials") for _ in carry)
        yspec = tuple(PartitionSpec(None, "trials")
                      for _ in range(n_outputs))
        return jax.jit(shard_map(
            _chunk, mesh=mesh,
            in_specs=(cspec, PartitionSpec()),
            out_specs=(cspec, yspec), check_rep=False))
    return jax.jit(_chunk)


def _run_chunk_numpy(step, carry, s0: int, chunk_steps: int):
    """The numpy backends' python chunk loop (same contract as the jit'd
    runner)."""
    ys = []
    for s in range(s0, s0 + chunk_steps):
        carry, y = step(carry, np.int32(s))
        ys.append(y)
    return carry, tuple(np.stack(col) for col in zip(*ys))


# ---------------------------------------------------------------------------
# The per-event step, written once for both array namespaces.
# ---------------------------------------------------------------------------

def _make_step(xp, pac_fn, succ, *, n: int, P: int, horizon: int,
               dt_vec, geo_masks, geo_tables, seed_mix,
               pair_fail_prob: float, pair_perm, restart_period: int,
               wave_width: int, packed: bool = False):
    advance = _make_node_advance(
        xp, n=n, horizon=horizon, dt_vec=dt_vec, geo_masks=geo_masks,
        geo_tables=geo_tables, seed_mix=seed_mix,
        pair_fail_prob=pair_fail_prob, pair_perm=pair_perm,
        restart_period=restart_period, wave_width=wave_width)

    def step(carry, s):
        (now, up, ev_t, full, dnl, dnm, lpt, mpt, le, me, rr_t, rr_idx,
         lane0) = carry
        B = up.shape[0]               # local trials (a shard of the batch)
        t_clamp, dt, active, up, ev_t, rr_t, rr_idx = advance(
            now, up, ev_t, rr_t, rr_idx, lane0, s)
        lpt = lpt + xp.sum(dnl, axis=1).astype(xp.float32) * dt
        mpt = mpt + xp.sum(dnm, axis=1).astype(xp.float32) * dt
        now = t_clamp

        if packed:
            # packed variant: the node advance is unchanged (it works in
            # (B, n) node space); only the per-partition holder state and
            # its eval move to (B, W, P) uint32 words
            upw = xp.moveaxis(bitpack.pack_words(up[:, succ], xp), -1, 1)
            lark, maj, crepsw = pac_fn(upw, full)
            full = xp.where(lark[:, None, :], crepsw, full)
        else:
            lark, maj, creps = pac_fn(up[:, succ].reshape(B * P, n),
                                      full.reshape(B * P, n))
            lark = lark.reshape(B, P)
            maj = maj.reshape(B, P)
            full = xp.where(lark[:, :, None], creps.reshape(B, P, n), full)
        # outage events are per-partition down-transitions (the downtime
        # engine's lgo/qgo rule): a net per-trial count delta would cancel
        # a partition recovering in the same step another fails and
        # undercount, starving the min_events early-stop
        le = le + xp.sum(~dnl & ~lark, axis=1).astype(xp.int32)
        me = me + xp.sum(~dnm & ~maj, axis=1).astype(xp.int32)
        dnl = ~lark
        dnm = ~maj
        new_unl = xp.sum(dnl, axis=1).astype(xp.int32)
        new_unm = xp.sum(dnm, axis=1).astype(xp.int32)
        nodes_up = xp.sum(up, axis=1).astype(xp.int32)
        carry = (now, up, ev_t, full, dnl, dnm, lpt, mpt, le, me,
                 rr_t, rr_idx, lane0)
        return carry, (t_clamp, new_unl, new_unm, nodes_up)
    return step


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def simulate_availability_batched(
        *, n: int = 155, partitions: int = 4096, rf: int = 2,
        p: float = 1e-3, downtime: int = 10, trials: int = 8,
        min_ticks: int = 50_000, max_ticks: int = 3_000_000,
        eps_abs: float = 5e-6, eps_rel: float = 0.05,
        min_events: int = 200, seed: int = 0, backend: str = "jax",
        pair_fail_prob: float = 0.0, restart_period: int = 0,
        wave_width: int = 1, p_node=None, downtime_node=None,
        devices: int = 1, pac_block_p: Optional[int] = None,
        chunk_steps: int = 512, max_steps: Optional[int] = None,
        trajectory: bool = False, voters: Optional[int] = None,
        use_shard_map: Optional[bool] = None, packed: bool = False,
        block_t: Optional[int] = None) -> BatchedAvailabilityResult:
    """Batched Monte Carlo over `trials` trajectories sharing one succession
    matrix (seeded); failure randomness is independent per trial.

    devices > 1 shards the trials axis over a 1-D "trials" mesh
    (launch/mesh.make_trials_mesh) via shard_map — bit-identical to
    devices=1 for the same seed.  `use_shard_map` forces the shard_map
    code path even on one device (tests).

    voters overrides the baseline quorum size (default 2*(rf-1)+1, the
    paper's 2f+1 voter set).  voters=rf evaluates majority over the f+1
    roster replicas — the instantaneous-availability limit of the
    downtime engine's equal-storage quorum-log baseline, which the
    property tests in tests/test_downtime_batched.py pin exactly.

    packed=True switches the carried holder masks and the per-step eval
    to the bit-packed (B, W, P) uint32 word layout (kernels/bitpack.py);
    on backend="pallas" the step then runs the fused megakernel
    (kernels/fused_step.py) with tile (block_t, block_p) — layout and
    fusion only, trajectories bit-identical to packed=False.
    """
    _validate_batched_args(backend=backend, devices=devices, trials=trials,
                           wave_width=wave_width, n=n)
    shard = use_shard_map if use_shard_map is not None else devices > 1
    B, P, horizon = trials, partitions, max_ticks
    voters = voters if voters is not None else 2 * (rf - 1) + 1
    if not 1 <= voters <= n:
        raise ValueError("voters must be in [1, n]")
    (xp, succ, seed_mix, geo_masks, geo_tables, dt_vec, pair_perm,
     p_arr, dt_arr) = _engine_setup(
        backend, n=n, partitions=P, seed=seed, p=p, downtime=downtime,
        p_node=p_node, downtime_node=downtime_node, max_ticks=max_ticks)
    spec = StepSpec(metric="availability", rf=rf, voters=voters, n_real=n,
                    packed=packed)

    def pac_fn(u, f):
        o = step_eval(spec, u, f, backend=backend, block_p=pac_block_p,
                      block_t=block_t)
        return o.lark, o.maj, o.creps

    step = _make_step(xp, pac_fn, succ, n=n, P=P, horizon=horizon,
                      dt_vec=dt_vec, geo_masks=geo_masks,
                      geo_tables=geo_tables, seed_mix=seed_mix,
                      pair_fail_prob=pair_fail_prob, pair_perm=pair_perm,
                      restart_period=restart_period, wave_width=wave_width,
                      packed=packed)

    # initial state: everyone up, roster replicas full
    lane0, up0, ev0, rr_t0 = _initial_node_state(
        xp, B=B, n=n, seed_mix=seed_mix, geo_masks=geo_masks,
        geo_tables=geo_tables, restart_period=restart_period,
        horizon=horizon)
    full0, (lark0, maj0, _creps0) = _initial_full_state(
        xp, backend, pac_fn, up0, succ, B=B, P=P, n=n, rf=rf,
        packed=packed)
    zi = xp.zeros((B,), dtype=xp.int32)
    zf = xp.zeros((B,), dtype=xp.float32)
    carry = (zi, up0, ev0, full0,
             ~lark0.reshape(B, P),                 # dnl (per-partition)
             ~maj0.reshape(B, P),                  # dnm
             zf, zf, zi, zi, rr_t0, zi, lane0)

    if backend != "numpy":
        import jax.numpy as jnp
        run_chunk = _make_chunk_runner(step, carry, chunk_steps=chunk_steps,
                                       devices=devices, shard=shard,
                                       n_outputs=4)

    if max_steps is None:
        max_steps = _default_max_steps(p_arr, dt_arr, n=n, horizon=horizon,
                                       restart_period=restart_period)

    lpt_tot = np.zeros(B)
    mpt_tot = np.zeros(B)
    le_tot = me_tot = 0
    traj = [] if trajectory else None
    stopped = False
    s0 = 1
    while s0 < max_steps:
        if backend == "numpy":
            carry, ys = _run_chunk_numpy(step, carry, s0, chunk_steps)
        else:
            carry, ys = run_chunk(carry, jnp.int32(s0))
        s0 += chunk_steps
        if trajectory:
            traj.append(tuple(np.asarray(c) for c in ys))
        # drain per-chunk accumulators into float64/int totals
        now = np.asarray(carry[0], dtype=np.int64)
        lpt_tot += np.asarray(carry[6], dtype=np.float64)
        mpt_tot += np.asarray(carry[7], dtype=np.float64)
        le_tot += int(np.asarray(carry[8]).sum())
        me_tot += int(np.asarray(carry[9]).sum())
        carry = carry[:6] + (zf, zf, zi, zi) + carry[10:]
        if (now >= horizon).all():
            break
        # pooled CI early stop, mirroring the event engine's rule.  This is
        # deliberately the NOMINAL binomial width — the same stopping
        # semantics (and therefore comparable tick counts / wall-clock) as
        # the scalar engine — while the *reported* ci_lark/ci_maj use the
        # honest across-trial spread, which is typically wider.
        if now.mean() >= min_ticks and le_tot >= min_events \
                and me_tot >= min_events:
            pt = float(P) * float(now.sum())
            u_l, u_m = lpt_tot.sum() / pt, mpt_tot.sum() / pt
            hw_l = 1.96 * math.sqrt(max(u_l * (1 - u_l), 1e-30) / pt)
            hw_m = 1.96 * math.sqrt(max(u_m * (1 - u_m), 1e-30) / pt)
            if hw_l <= max(eps_abs, eps_rel * u_l) and \
                    hw_m <= max(eps_abs, eps_rel * u_m):
                stopped = True
                break

    now = np.maximum(np.asarray(carry[0], dtype=np.int64), 1)
    pt_b = P * now.astype(np.float64)
    pt = float(pt_b.sum())
    u_l = float(lpt_tot.sum()) / pt
    u_m = float(mpt_tot.sum()) / pt
    u_l_trials = lpt_tot / pt_b
    u_m_trials = mpt_tot / pt_b
    # honest CI from the spread of independent trials (captures the
    # node-failure correlation across partitions that the binomial width
    # misses), floored by the pooled binomial width for tiny batches
    hw_l = hw_m = 0.0
    if B >= 3:
        t = t975(B - 1) / math.sqrt(B)
        hw_l = t * float(u_l_trials.std(ddof=1))
        hw_m = t * float(u_m_trials.std(ddof=1))
    traj_out = None
    if trajectory:
        cols = [np.concatenate([c[i] for c in traj]) for i in range(4)]
        traj_out = {"times": cols[0], "unavail_lark": cols[1],
                    "unavail_maj": cols[2], "nodes_up": cols[3]}
    return BatchedAvailabilityResult(
        p=p, rf=rf, n=n, partitions=P, trials=B, backend=backend,
        ticks=int(now.mean()), u_lark=u_l, u_maj=u_m,
        lark_events=le_tot, maj_events=me_tot,
        ci_lark=max(hw_l,
                    1.96 * math.sqrt(max(u_l * (1 - u_l), 1e-30) / pt)),
        ci_maj=max(hw_m,
                   1.96 * math.sqrt(max(u_m * (1 - u_m), 1e-30) / pt)),
        stopped_early=stopped, devices=devices,
        u_lark_trials=u_l_trials, u_maj_trials=u_m_trials,
        trajectory=traj_out)
