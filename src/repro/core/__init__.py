# The paper's primary contribution — implement the SYSTEM here
# (scheduler, optimizer, data path, serving loop, etc.) in the
# host framework. Add sibling subpackages for substrates.

_LAZY = {
    "simulate_availability": ("availability", "simulate_availability"),
    "AvailabilityResult": ("availability", "AvailabilityResult"),
    "simulate_availability_batched": (
        "availability_batched", "simulate_availability_batched"),
    "BatchedAvailabilityResult": (
        "availability_batched", "BatchedAvailabilityResult"),
}

__all__ = list(_LAZY)


def __getattr__(name):
    # lazy: the availability engines pull in jax; keep `import repro.core`
    # cheap for protocol-only users (pac, succession, simulator)
    if name in _LAZY:
        import importlib
        mod, attr = _LAZY[name]
        return getattr(importlib.import_module(f".{mod}", __name__), attr)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
