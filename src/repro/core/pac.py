"""Partition Availability Conditions — paper §3.

A partition P is available in a cluster C (a maximal fully-connected node
set agreeing on ClusterMembers) iff any of:

  1. SuperMajority:      |C ∩ roster| > |roster|/2  and  |roster \\ C| < RF
  2. AllRosterReplicas:  all RF roster replicas of P are in C
  3. SimpleMajority:     |C ∩ roster| > |roster|/2, >=1 roster replica in C,
                         and >=1 node in C is *full* for P
  4. HalfRoster:         |C ∩ roster| == |roster|/2, roster leader in C,
                         and >=1 node in C is *full* for P

This module is the scalar/protocol-level form used by the event simulator and
the LARK checkpoint store; the vectorized (P x n) form for the §5.1 Monte
Carlo lives in repro.kernels.ref.pac_eval_ref (+ the Pallas kernel).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import FrozenSet, Iterable, Optional, Sequence, Set, Tuple

ALL_CONDITIONS = ("super_majority", "all_roster_replicas", "simple_majority",
                  "half_roster")


@dataclass(frozen=True)
class PACResult:
    available: bool
    condition: Optional[str]  # first satisfied condition, in paper order


def evaluate_pac(*, cluster: Set[int], roster: Sequence[int],
                 succession: Sequence[int], rf: int,
                 full_nodes: Set[int],
                 conditions: Iterable[str] = ALL_CONDITIONS) -> PACResult:
    """Evaluate PAC for one partition.

    cluster: node ids in the (agreed) cluster view
    succession: the partition's succession list over the roster
    full_nodes: nodes *predicted full* for this partition (paper §4.2 step 1)
    """
    roster_set = set(roster)
    present = cluster & roster_set
    missing = len(roster_set) - len(present)
    majority = 2 * len(present) > len(roster_set)
    half = 2 * len(present) == len(roster_set)
    roster_replicas = list(succession[:rf])
    any_rr = any(n in cluster for n in roster_replicas)
    all_rr = all(n in cluster for n in roster_replicas)
    leader_in = succession[0] in cluster
    any_full = any(n in cluster for n in full_nodes)

    checks = {
        "super_majority": majority and missing < rf,
        "all_roster_replicas": all_rr,
        "simple_majority": majority and any_rr and any_full,
        "half_roster": half and leader_in and any_full,
    }
    for name in ALL_CONDITIONS:  # paper order for attribution
        if name in conditions and checks[name]:
            return PACResult(True, name)
    return PACResult(False, None)


def majority_quorum_available(cluster: Set[int], succession: Sequence[int],
                              rf: int, voters: Optional[int] = None) -> bool:
    """Quorum-log baseline: majority of the fixed 2f+1 voter set reachable."""
    nv = voters if voters is not None else 2 * (rf - 1) + 1
    voter_set = list(succession[:nv])
    return 2 * sum(1 for n in voter_set if n in cluster) > nv
