"""Data partitioning & placement (paper §2.1-§2.2).

Keys hash to a 160-bit RIPEMD-160 digest (hashlib) -> 4096 partitions; each
partition orders all roster nodes by Rendezvous hashing [22] into a
*succession list*: first RF nodes = roster replicas, first = roster leader.
Given a cluster (set of reachable nodes), *cluster replicas* are the first RF
succession-list nodes present in the cluster.

The paper's key placement properties hold by construction and are verified in
tests/test_succession.py: (i) deterministic; (ii) uniform load; (iii) minimal
disruption — removing a node only left-shifts lists where it appeared,
adding a node right-shifts lower-ranked nodes only.
"""
from __future__ import annotations

import hashlib
import struct
from typing import List, Sequence, Tuple

import numpy as np

NUM_PARTITIONS = 4096


def key_digest(key: bytes | str) -> bytes:
    if isinstance(key, str):
        key = key.encode()
    return hashlib.new("ripemd160", key).digest() if "ripemd160" in \
        hashlib.algorithms_available else hashlib.sha1(key).digest()


def key_partition(key: bytes | str, num_partitions: int = NUM_PARTITIONS) -> int:
    d = key_digest(key)
    return int.from_bytes(d[:4], "little") % num_partitions


def rendezvous_score(partition: int, node: int) -> int:
    """Collision-resistant hash score on (P, N) (paper: any such hash works)."""
    h = hashlib.blake2b(struct.pack("<II", partition, node), digest_size=8)
    return int.from_bytes(h.digest(), "little")


def succession_list(partition: int, roster: Sequence[int]) -> List[int]:
    """Roster node ids sorted by descending rendezvous score (stable)."""
    return sorted(roster, key=lambda n: (-rendezvous_score(partition, n), n))


def succession_matrix(num_partitions: int, roster: Sequence[int]) -> np.ndarray:
    """(P, n) int32 matrix of node ids by rank — the vectorized-sim layout."""
    roster = list(roster)
    scores = np.empty((num_partitions, len(roster)), dtype=np.uint64)
    for j, n in enumerate(roster):
        for p in range(num_partitions):
            scores[p, j] = rendezvous_score(p, n)
    order = np.argsort(-scores.astype(np.int64), axis=1, kind="stable")
    return np.asarray(roster, dtype=np.int32)[order]


def succession_matrix_fast(num_partitions: int, roster: Sequence[int],
                           seed: int = 0) -> np.ndarray:
    """Vectorized stand-in (splitmix-style integer hash) for large sims."""
    roster_arr = np.asarray(list(roster), dtype=np.uint64)
    p = np.arange(num_partitions, dtype=np.uint64)[:, None]
    x = (p << np.uint64(32)) ^ roster_arr[None, :] \
        ^ np.uint64((seed * 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF)
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    x = x ^ (x >> np.uint64(31))
    order = np.argsort(x, axis=1, kind="stable")
    return np.asarray(list(roster), dtype=np.int32)[order]


def cluster_replicas(succ: Sequence[int], cluster: set, rf: int) -> List[int]:
    """First RF succession-list nodes present in the cluster (paper §2.2)."""
    out = []
    for n in succ:
        if n in cluster:
            out.append(n)
            if len(out) == rf:
                break
    return out
