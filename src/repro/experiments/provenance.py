"""Provenance stamping for experiment artifacts.

Every summary JSON the runner writes answers "what exactly produced
this?" without archaeology: the spec itself (canonical mapping + content
hash), the config file it came from (path + file sha256, when one was
used), the git tree (HEAD sha + dirty bit), the RNG identity (seed and
the engine salt constants — the values that, with the spec, pin every
drawn variate), the backend/device geometry actually seen at run time,
and wall-clock accounting.  Rows carry none of this — a provenance-
stamped regen of a committed baseline stays byte-identical row for row.
"""
from __future__ import annotations

import hashlib
import subprocess
import sys
import time


def file_sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as fh:
        for chunk in iter(lambda: fh.read(1 << 16), b""):
            h.update(chunk)
    return h.hexdigest()


def git_revision(cwd: str = "."):
    """(sha, dirty) of the enclosing checkout, or (None, None) outside
    one — provenance must never make a run fail."""
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=cwd, capture_output=True,
            text=True, check=True).stdout.strip()
        dirty = bool(subprocess.run(
            ["git", "status", "--porcelain"], cwd=cwd,
            capture_output=True, text=True, check=True).stdout.strip())
        return sha, dirty
    except (OSError, subprocess.CalledProcessError):
        return None, None


def rng_salts() -> dict:
    """The counter-RNG salt constants that, together with the seed,
    identify every variate stream an experiment draws (ARCHITECTURE
    invariant 1).  Salts are compile-time constants; recording them
    makes a stale artifact detectable if one ever changes."""
    from ..core.client_latency import _KEY_SALT
    from ..core.downtime_batched import _SIZE_SALT
    return {"size": _SIZE_SALT, "key": _KEY_SALT}


def device_geometry() -> dict:
    """Backend platform and visible device count as jax actually sees
    them (the spec records what was *asked for*; this records what the
    process *got* — e.g. a forced 8-host-device CPU mesh)."""
    try:
        import jax
        return {"platform": jax.default_backend(),
                "device_count": jax.device_count()}
    except Exception:  # noqa: BLE001 — provenance must never fail a run
        return {"platform": None, "device_count": None}


def build_provenance(spec, *, config_path=None, wall_s=None,
                     started_unix=None) -> dict:
    """The ``meta.provenance`` mapping for one run of ``spec``."""
    sha, dirty = git_revision()
    prov = {
        "spec_sha256": spec.content_hash(),
        "config_path": str(config_path) if config_path else None,
        "config_sha256": (file_sha256(config_path)
                          if config_path else None),
        "git_sha": sha,
        "git_dirty": dirty,
        "seed": spec.seed,
        "rng_salts": rng_salts(),
        "requested": {"backend": spec.backend, "devices": spec.devices,
                      "trials": spec.trials},
        "observed": device_geometry(),
        "python": sys.version.split()[0],
        "started_unix": started_unix if started_unix is not None
        else time.time(),
        "wall_s": wall_s,
    }
    return prov
