"""Row/artifact schema shared by the sweep, the runner, and the gate.

One declarative table per row family replaces the per-metric keying logic
that used to be re-derived inside ``benchmarks/check_regression.py``: the
key fields (with the defaults that keep pre-knob baselines loadable) and
the gated value/CI column pairs live here, next to the spec that produces
the rows.  This module is deliberately stdlib-only — the regression gate
imports it in CI lanes that never install jax.

Artifact (summary JSON) schema versions:

* pre-provenance (no ``meta.schema_version``): the PR-1..PR-8 dumps;
  still loadable during the transition, with a deprecation note.
* ``SCHEMA_VERSION`` 1: ``meta`` additionally carries ``spec`` (the full
  canonical experiment-spec mapping) and ``provenance`` (git SHA, spec
  content hash, config path + file hash, seed/RNG salts, backend/device
  geometry, wall-clock).  Rows are unchanged — a v1 regen of a committed
  baseline stays byte-identical row for row.
"""
from __future__ import annotations

#: current summary-JSON schema version (``meta.schema_version``)
SCHEMA_VERSION = 1

#: every schema version the strict loader accepts
KNOWN_SCHEMA_VERSIONS = (1,)

#: engine names a "downtime_engine" row may carry — pinned equal to
#: core.downtime_batched.ENGINES by tests/test_experiments.py without
#: making the gate import the engine stack
KNOWN_ENGINES = ("lark", "quorum", "hermes", "spinnaker")

#: gated value/CI column pairs per row family ("availability" covers the
#: legacy iid/scenario kinds; "downtime" rows carry pause fractions;
#: "latency" rows carry mean added commit latencies)
GATED_COLS = {
    "availability": (("u_lark", "ci_lark"), ("u_maj", "ci_maj")),
    "downtime": (("pause_lark", "ci_pause_lark"),
                 ("pause_quorum", "ci_pause_quorum")),
    "downtime_engine": (("pause", "ci_pause"),),
    "latency": (("lat_lark", "ci_lat_lark"),
                ("lat_quorum", "ci_lat_quorum")),
}

#: key fields per row family beyond the family label itself, as
#: (field, default) pairs — a ``_REQUIRED`` default means the row must
#: carry the field (grid coordinates), anything else keeps rows from
#: before that knob existed loadable (e.g. pre-roster downtime rows are
#: all rebuild_model "fixed").  The protocol-zoo engine rows are keyed by
#: the engine whose pause they measure plus the zoo knobs — a hermes row
#: and a spinnaker row at the same grid point are different measurements;
#: latency rows are keyed by the workload knobs for the same reason.
_REQUIRED = object()

ROW_KEY_FIELDS = {
    "iid": (("rf", _REQUIRED), ("p", _REQUIRED)),
    "scenario": (("scenario", _REQUIRED), ("rf", _REQUIRED),
                 ("p", _REQUIRED)),
    "downtime": (("scenario", "iid"), ("rf", _REQUIRED), ("p", _REQUIRED),
                 ("rebuild_model", "fixed"), ("size_dist", "uniform"),
                 ("size_skew", 0.0), ("node_bandwidth_gibps", None)),
    "downtime_engine": (("engine", _REQUIRED), ("scenario", "iid"),
                        ("rf", _REQUIRED), ("p", _REQUIRED),
                        ("rebuild_model", "fixed"), ("lease_ticks", 0),
                        ("view_change_ticks", 0), ("size_dist", "uniform"),
                        ("size_skew", 0.0), ("node_bandwidth_gibps", None)),
    "latency": (("scenario", "iid"), ("rf", _REQUIRED), ("p", _REQUIRED),
                ("rebuild_model", "fixed"), ("read_frac", None),
                ("key_zipf", None), ("slo_ticks", None),
                ("requests_per_tick", None), ("dupres_ticks", None),
                ("write_skew", 0.0), ("node_bandwidth_gibps", None),
                ("slo_curve_bins", 0)),
}

#: row ``kind`` value → (key family, gated-column family); scenario
#: variants share their iid family's knob columns
KIND_FAMILIES = {
    "iid": ("iid", "availability"),
    "scenario": ("scenario", "availability"),
    "downtime": ("downtime", "downtime"),
    "downtime_scenario": ("downtime", "downtime"),
    "downtime_engine": ("downtime_engine", "downtime_engine"),
    "downtime_engine_scenario": ("downtime_engine", "downtime_engine"),
    "latency": ("latency", "latency"),
    "latency_scenario": ("latency", "latency"),
}


def row_key(r: dict):
    """Stable identity tuple for a result row, or None for rows that are
    never gated (autotune/meta rows).  The tuple leads with the key
    family label, then the declared key fields in order — identical to
    the tuples the gate produced before this table existed, so committed
    summary artifacts and their recorded verdict keys stay comparable."""
    kind = r.get("kind")
    fam = KIND_FAMILIES.get(kind)
    if fam is None:
        return None
    key_family, _ = fam
    key = [key_family]
    for field, default in ROW_KEY_FIELDS[key_family]:
        key.append(r[field] if default is _REQUIRED
                   else r.get(field, default))
    return tuple(key)


def row_cols(r: dict):
    """Gated (value, ci) column pairs for a result row."""
    fam = KIND_FAMILIES.get(r.get("kind"))
    if fam is None:
        return ()
    return GATED_COLS[fam[1]]
