"""Config-driven experiment runner: one ExperimentSpec in, CSV progress
out, JSONL events + a provenance-stamped summary JSON on request.

This module owns the sweep execution that used to live inline in
``benchmarks/availability_sweep.py`` — the grid/scale tables, the
per-metric row producers, the autotune pre-pass, and the CSV row
formats.  The sweep is now a thin flag→spec CLI over this runner, so a
flag invocation and a ``benchmarks/configs/*.toml`` run of the same
spec execute literally the same code path and produce byte-identical
rows (the committed BENCH_*.json baselines are pinned to this in CI's
reproducibility lane).

Execution layers:

* ``iter_rows(spec)`` — generator of result-row dicts in the exact
  order (autotune row, i.i.d. grid, scenario grids) and the exact
  shapes the sweep has always emitted.
* ``ExperimentRunner`` — drives ``iter_rows``, prints the legacy CSV
  progress lines, streams one JSONL event per row (with real wall-clock
  deltas — the raw material for tools/perf_baseline.py /
  tools/perf_delta.py), and assembles the summary document:
  ``meta`` = the byte-compatible legacy keys plus ``schema_version``,
  the full canonical ``spec``, and a ``provenance`` stamp
  (src/repro/experiments/provenance.py).
* ``run_batch(specs)`` — executes several specs back to back (one
  events stream, one summary each).

The legacy list-returning entry points (``run``, ``run_scenarios``,
``run_downtime``, …) survive as keyword-argument wrappers over the
generators, re-exported by benchmarks/availability_sweep.py.
"""
from __future__ import annotations

import json
import math
import time

from ..core.analytical import (improvement_factor, lark_unavailability,
                               node_unavailability)
from ..core.availability import simulate_availability
from ..core.availability_batched import simulate_availability_batched
from ..core.client_latency import simulate_client_latency
from ..core.downtime_batched import (DowntimeParams,
                                     simulate_downtime_batched)
from ..core.scenarios import get_scenario
from .provenance import build_provenance
from .schema import SCHEMA_VERSION, row_key
from .spec import ExperimentSpec

REDUCED_GRID = [(2, 1e-3), (2, 3e-3), (2, 1e-2), (3, 1e-2), (4, 3e-2)]
FULL_GRID = [(2, 1e-4), (2, 1e-3), (2, 1e-2),
             (3, 2e-4), (3, 1e-3), (3, 1e-2),
             (4, 5e-4), (4, 1e-3), (4, 1e-2)]
SMOKE_GRID = [(2, 3e-3), (3, 1e-2)]


def _grid_scale(full: bool, smoke: bool = False):
    """(n, partitions) — one place, so i.i.d. and scenario rows always run
    at the same cluster scale and their u columns stay comparable."""
    if smoke:
        return (31, 128)
    return (155, 4096) if full else (63, 512)


def _run_scale(full: bool, smoke: bool, *, scenario: bool):
    """(n, partitions, max_ticks, min_ticks) — single source for both
    metrics, so availability and downtime rows (and their committed
    BENCH_*.json baselines) always use the same tick budgets."""
    n, parts = _grid_scale(full, smoke)
    if scenario:
        max_ticks = 30_000 if smoke else (1_000_000 if full else 120_000)
        min_ticks = 8_000 if smoke else 20_000
    else:
        max_ticks = 40_000 if smoke else (3_000_000 if full else 250_000)
        min_ticks = 10_000 if smoke else 30_000
    return n, parts, max_ticks, min_ticks


def _iid_grid(full: bool, smoke: bool):
    return SMOKE_GRID if smoke else (FULL_GRID if full else REDUCED_GRID)


def _batched_backend(backend: str, devices: int):
    """event rows reuse the numpy math, single-device; an explicit numpy
    backend keeps its own devices so invalid combos still raise."""
    return ("numpy", 1) if backend == "event" else (backend, devices)


def _autotune_row(n: int, parts: int, trials: int, devices: int, *,
                  metric: str = "availability", rf: int = 2,
                  rebuild_model: str = "fixed", packed: bool = False):
    """Race kernel block candidates on the per-device sweep tile shape,
    timing the kernel the grid will actually run — at the grid's rf, not
    a hardcoded rf=2/voters=3.  Unpacked: the 1-D block_p race over
    pac_eval / downtime_eval (or its roster-carrying reconfig variant).
    packed: the 2-D (block_t x block_p) race over the fused step
    megakernel of the same metric/model (the tagged cache keys guarantee
    the two families can never return each other's entries).  Returns
    (block_p, block_t, row); block_t is None for the unpacked race."""
    voters = 2 * (rf - 1) + 1
    # the latency layer rides on the downtime step — same kernels, same
    # valid block choices, so it reuses the downtime race verbatim
    if packed:
        from ..kernels.ops import autotune_fused_blocks
        if metric in ("downtime", "latency"):
            kernel = "fused_downtime_roster" if rebuild_model == "reconfig" \
                else "fused_downtime"
        else:
            kernel = "fused_pac"
        res = autotune_fused_blocks(trials // devices, parts, n, rf=rf,
                                    voters=voters, n_real=n, kernel=kernel)
        row = {"kind": "autotune", "block_p": res.block_p,
               "block_t": res.block_t, "source": res.source,
               "kernel": kernel, "rf": rf,
               "timings_us": {f"{bt}x{bp}": v
                              for (bt, bp), v in res.timings_us.items()}}
        print(f"autotune,fused_blocks,0,choice={res.block_t}x{res.block_p};"
              f"source={res.source};kernel={kernel};rf={rf};"
              f"candidates={len(res.timings_us)}")
        return res.block_p, res.block_t, row
    from ..kernels.ops import autotune_block_p
    R = (trials // devices) * parts
    if metric in ("downtime", "latency"):
        kernel = "downtime_roster" if rebuild_model == "reconfig" \
            else "downtime"
    else:
        kernel = "pac"
    res = autotune_block_p(R, n, rf=rf, voters=voters, n_real=n,
                           kernel=kernel)
    row = {"kind": "autotune", "block_p": res.block_p, "source": res.source,
           "kernel": kernel, "rf": rf,
           "timings_us": {str(k): v for k, v in res.timings_us.items()}}
    print(f"autotune,block_p,0,choice={res.block_p};source={res.source};"
          f"kernel={kernel};rf={rf};candidates={len(res.timings_us)}")
    return res.block_p, None, row


def _gen_run(full: bool = False, seeds=(0,), backend: str = "event",
             devices: int = 1, smoke: bool = False, pac_block_p=None,
             packed: bool = False, block_t=None):
    grid = _iid_grid(full, smoke)
    n, parts, max_ticks, min_ticks = _run_scale(full, smoke, scenario=False)
    for rf, p in grid:
        if backend == "event":
            us_l, us_m, cis_l, cis_m = [], [], [], []
            ticks = 0
            for s in seeds:
                r = simulate_availability(n=n, partitions=parts, rf=rf, p=p,
                                          max_ticks=max_ticks,
                                          min_ticks=min_ticks, seed=s)
                us_l.append(r.u_lark)
                us_m.append(r.u_maj)
                cis_l.append(r.ci_lark)
                cis_m.append(r.ci_maj)
                ticks = r.ticks
            N = len(seeds)
            u_l = sum(us_l) / N
            u_m = sum(us_m) / N
            # half-width of the across-seed mean: independent runs, so
            # se_mean = sqrt(sum se_i^2) / N
            ci_l = math.sqrt(sum(c * c for c in cis_l)) / N
            ci_m = math.sqrt(sum(c * c for c in cis_m)) / N
        else:
            r = simulate_availability_batched(
                n=n, partitions=parts, rf=rf, p=p, trials=len(seeds),
                max_ticks=max_ticks, min_ticks=min_ticks, seed=min(seeds),
                backend=backend, devices=devices, pac_block_p=pac_block_p,
                packed=packed, block_t=block_t)
            u_l, u_m, ticks = r.u_lark, r.u_maj, r.ticks
            ci_l, ci_m = r.ci_lark, r.ci_maj
        f = rf - 1
        yield {
            "kind": "iid", "rf": rf, "p": p, "u_lark": u_l, "u_maj": u_m,
            "ci_lark": ci_l, "ci_maj": ci_m,
            "ratio": u_m / u_l if u_l else float("inf"),
            "analytic_ratio": improvement_factor(f),
            "analytic_u_lark": lark_unavailability(node_unavailability(p), f),
            "ticks": ticks,
        }


def _gen_run_scenarios(names, full: bool = False, trials: int = 4,
                       backend: str = "jax", seed: int = 0, devices: int = 1,
                       smoke: bool = False, pac_block_p=None,
                       packed: bool = False, block_t=None):
    backend, devices = _batched_backend(backend, devices)
    n, parts, max_ticks, min_ticks = _run_scale(full, smoke, scenario=True)
    for name in names:
        sc = get_scenario(name)
        for rf, p in sc.grid:
            r = simulate_availability_batched(
                n=n, partitions=parts, rf=rf, p=p, trials=trials,
                max_ticks=max_ticks, min_ticks=min_ticks, seed=seed,
                backend=backend, devices=devices, pac_block_p=pac_block_p,
                packed=packed, block_t=block_t,
                **sc.kwargs(n=n, rf=rf, p=p))
            yield {
                "kind": "scenario", "scenario": name, "rf": rf, "p": p,
                "u_lark": r.u_lark, "u_maj": r.u_maj,
                "ci_lark": r.ci_lark, "ci_maj": r.ci_maj,
                "ratio": r.u_maj / r.u_lark if r.u_lark else float("inf"),
                "ticks": r.ticks,
            }


def _downtime_row(r, *, kind: str, scenario: str):
    return {
        "kind": kind, "scenario": scenario, "rf": r.rf, "p": r.p,
        "pause_lark": r.pause_lark, "pause_quorum": r.pause_quorum,
        "ci_pause_lark": r.ci_lark, "ci_pause_quorum": r.ci_quorum,
        "ratio": r.availability_ratio,
        "lark_events": r.lark_events, "quorum_events": r.quorum_events,
        "hist_edges": r.hist_edges.tolist(),
        "hist_lark": r.hist_lark.tolist(),
        "hist_quorum": r.hist_quorum.tolist(),
        "dupres_ticks": r.dupres_ticks, "rebuild_steps": r.rebuild_steps,
        "rebuild_model": r.rebuild_model,
        "rebuild_ticks_per_gib": r.rebuild_ticks_per_gib,
        "size_dist": r.size_dist, "size_skew": r.size_skew,
        # inf (no sharing) serializes as null — _json_safe
        "node_bandwidth_gibps": r.node_bandwidth_gibps,
        "ticks": r.ticks,
    }


def _downtime_engine_rows(r, *, kind: str, scenario: str):
    """One row per protocol-zoo engine beyond the lark/quorum pair the
    base downtime row already carries.  Engine rows name their engine
    explicitly — check_regression keys them by it — and repeat the shared
    grid/knob columns so each row is self-describing."""
    rows = []
    for engine in r.engines:
        if engine in ("lark", "quorum"):
            continue
        s = r.engine_stats(engine)
        rows.append({
            "kind": kind, "engine": engine, "scenario": scenario,
            "rf": r.rf, "p": r.p,
            "pause": s["pause"], "ci_pause": s["ci_pause"],
            "events": s["events"],
            "hist_edges": r.hist_edges.tolist(),
            "hist": s["hist"].tolist(),
            "lease_ticks": r.lease_ticks,
            "view_change_ticks": r.view_change_ticks,
            "dupres_ticks": r.dupres_ticks,
            "rebuild_steps": r.rebuild_steps,
            "rebuild_model": r.rebuild_model,
            "rebuild_ticks_per_gib": r.rebuild_ticks_per_gib,
            "size_dist": r.size_dist, "size_skew": r.size_skew,
            "node_bandwidth_gibps": r.node_bandwidth_gibps,
            "ticks": r.ticks,
        })
    return rows


def _gen_run_downtime(full: bool = False, trials: int = 4,
                      backend: str = "jax", seed: int = 0, devices: int = 1,
                      smoke: bool = False, pac_block_p=None,
                      params: DowntimeParams = DowntimeParams(),
                      packed: bool = False, block_t=None):
    """§6 commit-pause rows over the i.i.d. grid.  The protocol/rebuild
    knobs travel as one pre-validated DowntimeParams — the spec builds it
    exactly once, so every invalid combination is rejected in one place
    (the dataclass) before any engine runs."""
    backend, devices = _batched_backend(backend, devices)
    grid = _iid_grid(full, smoke)
    n, parts, max_ticks, min_ticks = _run_scale(full, smoke, scenario=False)
    for rf, p in grid:
        r = simulate_downtime_batched(
            n=n, partitions=parts, rf=rf, p=p, trials=trials,
            max_ticks=max_ticks, min_ticks=min_ticks, seed=seed,
            backend=backend, devices=devices, pac_block_p=pac_block_p,
            params=params, packed=packed, block_t=block_t)
        yield _downtime_row(r, kind="downtime", scenario="iid")
        yield from _downtime_engine_rows(r, kind="downtime_engine",
                                         scenario="iid")


def _gen_run_downtime_scenarios(names, full: bool = False, trials: int = 4,
                                backend: str = "jax", seed: int = 0,
                                devices: int = 1, smoke: bool = False,
                                pac_block_p=None,
                                params: DowntimeParams = DowntimeParams(),
                                packed: bool = False, block_t=None):
    backend, devices = _batched_backend(backend, devices)
    n, parts, max_ticks, min_ticks = _run_scale(full, smoke, scenario=True)
    for name in names:
        sc = get_scenario(name)
        for rf, p in sc.grid:
            r = simulate_downtime_batched(
                n=n, partitions=parts, rf=rf, p=p, trials=trials,
                max_ticks=max_ticks, min_ticks=min_ticks, seed=seed,
                backend=backend, devices=devices, pac_block_p=pac_block_p,
                params=params, packed=packed, block_t=block_t,
                **sc.kwargs(n=n, rf=rf, p=p))
            yield _downtime_row(r, kind="downtime_scenario", scenario=name)
            yield from _downtime_engine_rows(
                r, kind="downtime_engine_scenario", scenario=name)


def _latency_row(r, *, kind: str, scenario: str):
    row = {
        "kind": kind, "scenario": scenario, "rf": r.rf, "p": r.p,
        "lat_lark": r.lat_lark, "lat_quorum": r.lat_quorum,
        "lat_hermes": r.lat_hermes,
        "ci_lat_lark": r.ci_lat_lark, "ci_lat_quorum": r.ci_lat_quorum,
        "p50_lark": r.p50_lark, "p99_lark": r.p99_lark,
        "p999_lark": r.p999_lark,
        "p50_quorum": r.p50_quorum, "p99_quorum": r.p99_quorum,
        "p999_quorum": r.p999_quorum,
        "p50_hermes": r.p50_hermes, "p99_hermes": r.p99_hermes,
        "p999_hermes": r.p999_hermes,
        "slo_lark": r.slo_lark, "slo_quorum": r.slo_quorum,
        "slo_hermes": r.slo_hermes,
        "req_total": r.req_total,
        "hist_edges": r.hist_edges.tolist(),
        "hist_quorum_req": r.hist_quorum_req.tolist(),
        "dupres_ticks": r.dupres_ticks, "rebuild_model": r.rebuild_model,
        "key_zipf": r.key_zipf, "read_frac": r.read_frac,
        "requests_per_tick": r.requests_per_tick,
        "slo_ticks": r.slo_ticks,
        "ticks": r.ticks,
    }
    # the sharpening knobs only add columns when set, so rows at their
    # degenerate settings stay byte-identical to the pre-knob baselines
    # (the schema's row-key defaults supply the absent values)
    if r.write_skew:
        row["write_skew"] = r.write_skew
    if math.isfinite(r.node_bandwidth_gibps):
        row["node_bandwidth_gibps"] = r.node_bandwidth_gibps
    if r.slo_curve_bins:
        row["slo_curve_bins"] = r.slo_curve_bins
        row["slo_curve_edges"] = r.slo_curve_edges.tolist()
        row["slo_curve_lark"] = r.slo_curve_lark.tolist()
        row["slo_curve_quorum"] = r.slo_curve_quorum.tolist()
        row["slo_curve_hermes"] = r.slo_curve_hermes.tolist()
    return row


def _gen_run_latency(full: bool = False, trials: int = 4,
                     backend: str = "jax", seed: int = 0, devices: int = 1,
                     smoke: bool = False, pac_block_p=None,
                     params: DowntimeParams = DowntimeParams(),
                     packed: bool = False, block_t=None):
    """Client-latency rows over the i.i.d. grid — same grid/scale/tick
    budgets as the downtime metric, so the two row families describe the
    same trajectories."""
    backend, devices = _batched_backend(backend, devices)
    grid = _iid_grid(full, smoke)
    n, parts, max_ticks, min_ticks = _run_scale(full, smoke, scenario=False)
    for rf, p in grid:
        r = simulate_client_latency(
            n=n, partitions=parts, rf=rf, p=p, trials=trials,
            max_ticks=max_ticks, min_ticks=min_ticks, seed=seed,
            backend=backend, devices=devices, pac_block_p=pac_block_p,
            params=params, packed=packed, block_t=block_t)
        yield _latency_row(r, kind="latency", scenario="iid")


def _gen_run_latency_scenarios(names, full: bool = False, trials: int = 4,
                               backend: str = "jax", seed: int = 0,
                               devices: int = 1, smoke: bool = False,
                               pac_block_p=None,
                               params: DowntimeParams = DowntimeParams(),
                               packed: bool = False, block_t=None):
    backend, devices = _batched_backend(backend, devices)
    n, parts, max_ticks, min_ticks = _run_scale(full, smoke, scenario=True)
    for name in names:
        sc = get_scenario(name)
        for rf, p in sc.grid:
            r = simulate_client_latency(
                n=n, partitions=parts, rf=rf, p=p, trials=trials,
                max_ticks=max_ticks, min_ticks=min_ticks, seed=seed,
                backend=backend, devices=devices, pac_block_p=pac_block_p,
                params=params, packed=packed, block_t=block_t,
                **sc.kwargs(n=n, rf=rf, p=p))
            yield _latency_row(r, kind="latency_scenario", scenario=name)


# legacy list-returning entry points (availability_sweep re-exports)

def run(**kw):
    return list(_gen_run(**kw))


def run_scenarios(names, **kw):
    return list(_gen_run_scenarios(names, **kw))


def run_downtime(**kw):
    return list(_gen_run_downtime(**kw))


def run_downtime_scenarios(names, **kw):
    return list(_gen_run_downtime_scenarios(names, **kw))


def run_latency(**kw):
    return list(_gen_run_latency(**kw))


def run_latency_scenarios(names, **kw):
    return list(_gen_run_latency_scenarios(names, **kw))


def _json_safe(row):
    """Non-finite floats (a ratio over a zero pause/unavailability) are not
    RFC-JSON; dump them as null so jq/strict parsers can read the file."""
    return {k: (None if isinstance(v, float) and not math.isfinite(v) else v)
            for k, v in row.items()}


def row_csv_line(r: dict):
    """The progress line the sweep has always printed for a result row
    (None for autotune rows — those print inside the race itself)."""
    kind = r["kind"]
    if kind == "iid":
        return (f"availability,rf{r['rf']}_p{r['p']:g},0,"
                f"u_lark={r['u_lark']:.3e};u_maj={r['u_maj']:.3e};"
                f"ratio={r['ratio']:.2f};"
                f"analytic={r['analytic_ratio']}")
    if kind == "scenario":
        return (f"availability_scenario,{r['scenario']}_rf{r['rf']}_"
                f"p{r['p']:g},0,u_lark={r['u_lark']:.3e};"
                f"u_maj={r['u_maj']:.3e};ratio={r['ratio']:.2f}")
    if kind == "downtime":
        return (f"downtime,rf{r['rf']}_p{r['p']:g},0,"
                f"pause_lark={r['pause_lark']:.3e};"
                f"pause_quorum={r['pause_quorum']:.3e};"
                f"ratio={r['ratio']:.2f}")
    if kind == "downtime_scenario":
        return (f"downtime_scenario,{r['scenario']}_rf{r['rf']}_"
                f"p{r['p']:g},0,pause_lark={r['pause_lark']:.3e};"
                f"pause_quorum={r['pause_quorum']:.3e};"
                f"ratio={r['ratio']:.2f}")
    if kind == "downtime_engine":
        return (f"downtime_engine,{r['engine']}_rf{r['rf']}_"
                f"p{r['p']:g},0,pause={r['pause']:.3e};"
                f"events={r['events']}")
    if kind == "downtime_engine_scenario":
        return (f"downtime_engine_scenario,{r['engine']}_"
                f"{r['scenario']}_rf{r['rf']}_p{r['p']:g},0,"
                f"pause={r['pause']:.3e};events={r['events']}")
    if kind == "latency":
        return (f"latency,rf{r['rf']}_p{r['p']:g},0,"
                f"lat_lark={r['lat_lark']:.3e};"
                f"lat_quorum={r['lat_quorum']:.3e};"
                f"p999_lark={r['p999_lark']:g};"
                f"p999_quorum={r['p999_quorum']:g};"
                f"slo_quorum={r['slo_quorum']:.3e}")
    if kind == "latency_scenario":
        return (f"latency_scenario,{r['scenario']}_rf{r['rf']}_"
                f"p{r['p']:g},0,lat_lark={r['lat_lark']:.3e};"
                f"lat_quorum={r['lat_quorum']:.3e};"
                f"p999_quorum={r['p999_quorum']:g};"
                f"slo_quorum={r['slo_quorum']:.3e}")
    return None


def iter_rows(spec: ExperimentSpec):
    """Every result row of one spec, in emission order: the autotune row
    (when spec.autotune), then the i.i.d. grid, then each scenario grid,
    dispatched per metric exactly as the flag CLI always has."""
    names = list(spec.scenarios)
    pac_block_p = block_t = None
    if spec.autotune:
        n, parts = _grid_scale(spec.full, spec.smoke)
        # rf of the first row the sweep will actually run (scenario grid
        # when the i.i.d. grid is skipped)
        if spec.scenarios_only and names:
            tune_rf = get_scenario(names[0]).grid[0][0]
        else:
            tune_rf = _iid_grid(spec.full, spec.smoke)[0][0]
        pac_block_p, block_t, row = _autotune_row(
            n, parts, spec.trials, spec.devices, metric=spec.metric,
            rf=tune_rf, rebuild_model=spec.rebuild_model,
            packed=spec.packed)
        yield row

    if spec.metric == "availability":
        if not spec.scenarios_only:
            yield from _gen_run(
                full=spec.full,
                seeds=tuple(range(spec.seed, spec.seed + spec.trials)),
                backend=spec.backend, devices=spec.devices,
                smoke=spec.smoke, pac_block_p=pac_block_p,
                packed=spec.packed, block_t=block_t)
        if names:
            yield from _gen_run_scenarios(
                names, full=spec.full, trials=spec.trials,
                backend=spec.backend, seed=spec.seed,
                devices=spec.devices, smoke=spec.smoke,
                pac_block_p=pac_block_p, packed=spec.packed,
                block_t=block_t)
        return

    common = dict(full=spec.full, trials=spec.trials, backend=spec.backend,
                  seed=spec.seed, devices=spec.devices, smoke=spec.smoke,
                  pac_block_p=pac_block_p, params=spec.downtime_params(),
                  packed=spec.packed, block_t=block_t)
    if spec.metric == "downtime":
        if not spec.scenarios_only:
            yield from _gen_run_downtime(**common)
        if names:
            yield from _gen_run_downtime_scenarios(names, **common)
    else:
        if not spec.scenarios_only:
            yield from _gen_run_latency(**common)
        if names:
            yield from _gen_run_latency_scenarios(names, **common)


class ExperimentRunner:
    """Execute one spec: stream rows (CSV progress + JSONL events),
    assemble the provenance-stamped summary.

    ``events_path`` appends one JSON object per line:
      run_start  spec identity (name, metric, geometry, spec/config
                 hashes, git sha) and the start timestamp
      row        per result row: index, kind, the row-key label, and
                 real wall-clock position/delta (t_s / dt_s seconds)
      run_end    row count, total wall_s, and rows_per_s

    Timestamps live only in the events and the summary's provenance —
    never in rows, which stay exactly reproducible.
    """

    def __init__(self, spec: ExperimentSpec, *, config_path=None,
                 events_path=None, emit=print):
        self.spec = spec
        self.config_path = config_path
        self.events_path = events_path
        self.emit = emit
        self.rows = None
        self._started_unix = None
        self._wall_s = None

    def _event(self, fh, record: dict):
        if fh is not None:
            fh.write(json.dumps(record, sort_keys=True,
                                allow_nan=False) + "\n")
            fh.flush()

    def run(self) -> list:
        spec = self.spec
        fh = open(self.events_path, "a") if self.events_path else None
        t0 = time.monotonic()
        self._started_unix = time.time()
        try:
            self._event(fh, {
                "event": "run_start", "schema_version": SCHEMA_VERSION,
                "name": spec.name, "metric": spec.metric,
                "backend": spec.backend, "trials": spec.trials,
                "devices": spec.devices, "packed": spec.packed,
                "spec_sha256": spec.content_hash(),
                "config_path": (str(self.config_path)
                                if self.config_path else None),
                "t_unix": self._started_unix})
            rows = []
            t_prev = t0
            for r in iter_rows(spec):
                rows.append(r)
                line = row_csv_line(r)
                if line is not None and self.emit is not None:
                    self.emit(line)
                t_now = time.monotonic()
                key = row_key(r)
                label = "_".join(str(k) for k in key) if key \
                    else r.get("kind", "?")
                self._event(fh, {
                    "event": "row", "i": len(rows) - 1,
                    "kind": r.get("kind"), "label": label,
                    "t_s": t_now - t0, "dt_s": t_now - t_prev})
                t_prev = t_now
            self._wall_s = time.monotonic() - t0
            self._event(fh, {
                "event": "run_end", "name": spec.name,
                "rows": len(rows), "wall_s": self._wall_s,
                "rows_per_s": (len(rows) / self._wall_s
                               if self._wall_s > 0 else None)})
        finally:
            if fh is not None:
                fh.close()
        self.rows = rows
        return rows

    def summary(self, rows=None) -> dict:
        """The dump document: legacy meta keys at the top level (byte
        compatible), plus schema_version, the canonical spec, and the
        provenance stamp."""
        if rows is None:
            rows = self.rows if self.rows is not None else self.run()
        meta = self.spec.legacy_meta()
        meta["schema_version"] = SCHEMA_VERSION
        meta["spec"] = {"name": self.spec.name, **self.spec.canonical()}
        meta["provenance"] = build_provenance(
            self.spec, config_path=self.config_path, wall_s=self._wall_s,
            started_unix=self._started_unix)
        return {"meta": meta, "rows": [_json_safe(r) for r in rows]}

    def write_summary(self, path: str, rows=None) -> dict:
        doc = self.summary(rows)
        with open(path, "w") as fh:
            json.dump(doc, fh, indent=1, sort_keys=True, allow_nan=False)
        return doc


def run_batch(specs, *, events_path=None, emit=print) -> list:
    """Execute several specs back to back (one shared events stream);
    returns their summary documents in order."""
    out = []
    for item in specs:
        config_path = None
        if isinstance(item, (str, bytes)):
            config_path, item = item, ExperimentSpec.from_file(item)
        runner = ExperimentRunner(item, config_path=config_path,
                                  events_path=events_path, emit=emit)
        runner.run()
        out.append(runner.summary())
    return out
