"""Declarative experiment layer: frozen specs, a config-driven runner,
and provenance-stamped artifacts.

Import shape matters here: ``schema`` is dependency-free (the regression
gate loads it without jax on the path), ``spec`` pulls in the core engine
constants for validation, and ``runner`` pulls in the full engine stack.
Import the submodule you need rather than relying on package-level
re-exports, so cheap consumers stay cheap.
"""
