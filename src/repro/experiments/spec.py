"""Frozen, declarative experiment specifications.

``ExperimentSpec`` is the single description of one sweep run: the grid
scale, backend/device geometry, metric, scenario selection, and the full
§6 / protocol-zoo / latency-workload knob set.  Every field maps 1:1
onto a ``benchmarks/availability_sweep.py`` flag, and a spec can be
built three equivalent ways:

* ``ExperimentSpec.create(**provided)`` — programmatic; ``provided``
  holds only the keys the caller actually chose, so the metric-gated
  rules ("engines selects the protocol zoo; use metric='downtime'")
  fire exactly like the old CLI did for explicitly-passed flags.
* ``ExperimentSpec.from_file(path)`` — a TOML or JSON config; keys are
  the field names below, unknown keys are rejected with a
  nearest-match suggestion.
* the sweep CLI, which forwards its explicitly-set flags into
  ``create`` — so a CLI-built spec equals the config-built spec for the
  same choices (pinned per committed baseline config in
  tests/test_experiments.py).

Validation lives in exactly two places and nowhere else: the
*metric/engine/reconfig gating* of which knobs may be set at all is
here (``create``), and every *value* rule is delegated to
``core.downtime_batched.DowntimeParams`` plus ``__post_init__`` — the
CLI no longer owns any rule of its own.

Specs are frozen and hashable; ``canonical()`` is the stable mapping
embedded in provenance-stamped artifacts (``meta.spec``) and
``content_hash()`` its sha256 — the round trip
``ExperimentSpec.create(**spec.canonical())`` is lossless.
"""
from __future__ import annotations

import difflib
import hashlib
import json
import math
from dataclasses import dataclass, field, fields

from ..core.downtime_batched import (ENGINES, REBUILD_MODELS, SIZE_DISTS,
                                     DowntimeParams)
from ..core.scenarios import scenario_names

BACKENDS = ("event", "numpy", "jax", "pallas")
METRICS = ("availability", "downtime", "latency")

#: spec keys that only make sense for --metric downtime/latency (the §6
#: protocol/rebuild knob set)
_DOWNTIME_KEYS = ("dupres_ticks", "rebuild_steps", "rebuild_model",
                  "rebuild_ticks_per_gib", "size_dist", "size_skew",
                  "node_bandwidth_gibps")
#: spec keys that select the protocol zoo (--metric downtime only)
_ZOO_KEYS = ("engines", "lease_ticks", "view_change_ticks")
#: spec keys that model the request workload (--metric latency only)
_LATENCY_KEYS = ("key_zipf", "read_frac", "requests_per_tick", "slo_ticks",
                 "write_skew", "slo_curve_bins")
#: reconfig-only knobs among _DOWNTIME_KEYS (node_bandwidth_gibps left
#: this set when fixed-model rebuilds gained bandwidth contention — it
#: now applies to both rebuild models)
_RECONFIG_KEYS = ("size_dist", "size_skew")

#: per-metric defaults for the latency workload knobs — the non-latency
#: values are the zero-request limit DowntimeParams defaults to, so
#: params equality across metrics is stable
_LATENCY_DEFAULTS = {"key_zipf": 1.0, "read_frac": 0.8,
                     "requests_per_tick": 32.0, "slo_ticks": 8}
_NO_LATENCY_DEFAULTS = {"key_zipf": 0.0, "read_frac": 1.0,
                        "requests_per_tick": 0.0, "slo_ticks": 0}


class SpecError(ValueError):
    """An experiment spec that can never run: unknown key, a knob set
    for a metric that does not read it, or an invalid value (the latter
    re-raised from DowntimeParams so every entry point shares one error
    set)."""


def _suggest(key: str, valid) -> str:
    close = difflib.get_close_matches(key, list(valid), n=1)
    return f" (did you mean {close[0]!r}?)" if close else ""


@dataclass(frozen=True)
class ExperimentSpec:
    """One sweep run, fully specified.  Field order mirrors the CLI
    surface; every default equals the resolved CLI default."""

    #: display/artifact name — configs set it; never part of identity
    name: str = field(default="", compare=False)
    metric: str = "availability"
    backend: str = "event"
    trials: int = 1
    devices: int = 1
    full: bool = False
    smoke: bool = False
    seed: int = 0
    scenarios: tuple = ()
    scenarios_only: bool = False
    packed: bool = False
    autotune: bool = False
    # §6 protocol/rebuild knobs (downtime + latency metrics)
    dupres_ticks: int = 1
    rebuild_steps: int = 100
    rebuild_model: str = "fixed"
    rebuild_ticks_per_gib: int = 100
    size_dist: str = "uniform"
    size_skew: float = 1.0
    node_bandwidth_gibps: float = math.inf
    # protocol zoo (downtime metric)
    engines: tuple = ("lark", "quorum")
    lease_ticks: int = 0
    view_change_ticks: int = 0
    # client-request workload (latency metric).  slo_ticks=0 doubles as
    # the non-latency sentinel default AND a live strict-> threshold
    # under metric 'latency' (every request with any added latency
    # violates) — the per-metric default tables below keep the two
    # readings from colliding: a latency spec defaults to 8, so 0 there
    # is always an explicit caller choice
    key_zipf: float = 0.0
    read_frac: float = 1.0
    requests_per_tick: float = 0.0
    slo_ticks: int = 0
    write_skew: float = 0.0
    slo_curve_bins: int = 0

    def __post_init__(self):
        object.__setattr__(self, "scenarios", tuple(self.scenarios))
        object.__setattr__(self, "engines", tuple(self.engines))
        if self.backend not in BACKENDS:
            raise SpecError(f"backend must be one of {BACKENDS}, "
                            f"got {self.backend!r}")
        if self.metric not in METRICS:
            raise SpecError(f"metric must be one of {METRICS}, "
                            f"got {self.metric!r}")
        if self.trials < 1:
            raise SpecError("trials must be >= 1")
        if self.devices < 1:
            raise SpecError("devices must be >= 1")
        if self.devices > 1:
            if self.backend in ("event", "numpy"):
                raise SpecError("devices > 1 needs backend 'jax' or "
                                "'pallas'")
            if self.trials % self.devices:
                raise SpecError("trials must be a multiple of devices")
        if self.autotune and self.backend != "pallas":
            raise SpecError("autotune tunes the pallas kernel block "
                            "size; use backend 'pallas'")
        if self.packed and self.backend == "event":
            raise SpecError("packed runs the batched engines; use "
                            "backend 'numpy', 'jax', or 'pallas'")
        if self.metric == "latency" and self.backend == "event":
            raise SpecError("metric 'latency' runs the batched engines; "
                            "use backend 'numpy', 'jax', or 'pallas'")
        known = scenario_names()
        for s in self.scenarios:
            if s not in known:
                raise SpecError(
                    f"unknown scenario {s!r}; registered: "
                    f"{', '.join(known)} (or 'all')" + _suggest(s, known))
        if len(set(self.scenarios)) != len(self.scenarios):
            raise SpecError(f"duplicate scenarios: {self.scenarios}")
        # every value rule for the knob set lives in DowntimeParams —
        # constructing it here means spec building and engine entry see
        # the identical ValueError set
        try:
            self.downtime_params()
        except ValueError as e:
            raise SpecError(str(e)) from e

    def downtime_params(self) -> DowntimeParams:
        """The validated engine-knob bundle this spec configures."""
        return DowntimeParams(
            dupres_ticks=self.dupres_ticks,
            rebuild_steps=self.rebuild_steps,
            rebuild_model=self.rebuild_model,
            rebuild_ticks_per_gib=self.rebuild_ticks_per_gib,
            size_dist=self.size_dist, size_skew=self.size_skew,
            node_bandwidth_gibps=self.node_bandwidth_gibps,
            key_zipf=self.key_zipf, read_frac=self.read_frac,
            requests_per_tick=self.requests_per_tick,
            slo_ticks=self.slo_ticks, write_skew=self.write_skew,
            slo_curve_bins=self.slo_curve_bins, engines=self.engines,
            lease_ticks=self.lease_ticks,
            view_change_ticks=self.view_change_ticks)

    # -- construction ----------------------------------------------------

    @classmethod
    def field_names(cls) -> tuple:
        return tuple(f.name for f in fields(cls))

    @classmethod
    def create(cls, **provided) -> "ExperimentSpec":
        """Build a spec from only the keys the caller chose.

        Applies the metric-gated rules the CLI used to own (a knob that
        its metric never reads is an error, not a silent no-op), fills
        per-metric defaults, and normalizes representations (comma
        strings / lists → tuples, 'all' scenario expansion, 'inf'
        strings → float).  Value validation then runs in __post_init__.
        """
        valid = cls.field_names()
        for key in provided:
            if key not in valid:
                raise SpecError(f"unknown spec key {key!r}"
                                + _suggest(key, valid)
                                + f"; valid keys: {', '.join(valid)}")
        values = {k: v for k, v in provided.items() if v is not None}
        # normalize representations before gating so a canonical()
        # round trip and a config file compare like with like
        engines = values.get("engines")
        if isinstance(engines, str):
            engines = tuple(e.strip() for e in engines.split(",")
                            if e.strip())
        if engines is not None:
            values["engines"] = tuple(engines)
        nbw = values.get("node_bandwidth_gibps")
        if isinstance(nbw, str):
            try:
                nbw = float(nbw)
            except ValueError:
                raise SpecError("node_bandwidth_gibps must be a number "
                                f"or 'inf', got {nbw!r}") from None
            values["node_bandwidth_gibps"] = nbw
        metric = values.get("metric", "availability")

        # a knob is only *set* if it differs from its default — so
        # embedding the full canonical mapping (which spells out every
        # field) round-trips, while any meaningful knob for a metric
        # that never reads it stays an error exactly like the old CLI
        defaults = {f.name: f.default for f in fields(cls)}
        significant = {k for k, v in values.items()
                       if v != defaults.get(k, object())}

        def _reject(keys, rule):
            bad = sorted(k for k in keys if k in significant)
            if bad:
                raise SpecError(f"{'/'.join(bad)} {rule}")

        if metric not in ("downtime", "latency"):
            _reject(_DOWNTIME_KEYS, "only apply to metric 'downtime' or "
                    "'latency' (--metric downtime|latency)")
        if metric != "downtime":
            _reject(_ZOO_KEYS, "select the protocol zoo; use metric "
                    "'downtime' (--metric downtime)")
        if metric != "latency":
            _reject(_LATENCY_KEYS, "model the request workload; use "
                    "metric 'latency' (--metric latency)")
        rebuild_model = values.get("rebuild_model", "fixed")
        if rebuild_model == "reconfig":
            _reject(("rebuild_steps",),
                    "is the fixed-model knob; use rebuild_ticks_per_gib "
                    "with rebuild_model 'reconfig'")
        elif rebuild_model == "fixed":
            _reject(("rebuild_ticks_per_gib",),
                    "is the reconfig-model knob; use rebuild_steps with "
                    "rebuild_model 'fixed'")
            _reject(_RECONFIG_KEYS,
                    "model the reconfiguring baseline's data-sized "
                    "catch-ups; use rebuild_model 'reconfig'")
        if "size_skew" in significant and values.get("size_dist") \
                not in ("zipf", "lognormal"):
            raise SpecError("size_skew shapes the zipf/lognormal size "
                            "distributions; set size_dist "
                            "'zipf'|'lognormal'")

        workload = (_LATENCY_DEFAULTS if metric == "latency"
                    else _NO_LATENCY_DEFAULTS)
        for k, v in workload.items():
            values.setdefault(k, v)
        if values.get("scenarios_only") and not values.get("scenarios"):
            # scenario-only with no selection means every registered
            # scenario — the legacy --scenarios-only CLI behavior
            values["scenarios"] = ("all",)
        values["scenarios"] = _resolve_scenarios(
            values.get("scenarios", ()))
        return cls(**values)

    @classmethod
    def from_file(cls, path: str) -> "ExperimentSpec":
        """Load a spec from a TOML (or JSON) config file.  Keys are the
        spec field names; unknown keys are rejected with a nearest-match
        suggestion, and every gating/value rule applies exactly as for a
        programmatic or CLI build."""
        with open(path, "rb") as fh:
            raw = fh.read()
        if str(path).endswith(".json"):
            data = json.loads(raw.decode("utf-8"))
        else:
            data = _loads_toml(raw.decode("utf-8"))
        if not isinstance(data, dict):
            raise SpecError(f"{path}: config must be a table of "
                            "spec keys")
        try:
            return cls.create(**data)
        except SpecError as e:
            raise SpecError(f"{path}: {e}") from None

    # -- serialization ---------------------------------------------------

    def canonical(self) -> dict:
        """JSON-safe mapping of every identity field — the exact form
        embedded in provenance-stamped artifacts as ``meta.spec``.
        Lossless: ``ExperimentSpec.create(**spec.canonical())`` (plus
        the non-identity ``name``) reproduces ``spec`` exactly."""
        out = {}
        for f in fields(self):
            if not f.compare:
                continue
            v = getattr(self, f.name)
            if isinstance(v, tuple):
                v = list(v)
            elif isinstance(v, float) and math.isinf(v):
                v = "inf"
            out[f.name] = v
        return out

    def content_hash(self) -> str:
        """sha256 of the canonical mapping (sorted-key JSON) — the
        spec's stable identity, independent of where it was loaded from
        or the key order it was written with."""
        blob = json.dumps(self.canonical(), sort_keys=True,
                          separators=(",", ":"))
        return hashlib.sha256(blob.encode("utf-8")).hexdigest()

    def zoo_live(self) -> bool:
        """Whether the protocol zoo is in play — the condition under
        which summary meta carries the zoo keys (a default lark,quorum
        run keeps emitting the pre-zoo meta byte for byte)."""
        return (self.engines != ("lark", "quorum")
                or bool(self.lease_ticks) or bool(self.view_change_ticks))

    def legacy_meta(self) -> dict:
        """The pre-provenance ``meta`` mapping, key for key and value
        for value — provenance-stamped summaries keep emitting these at
        the top level so every meta consumer from before the experiments
        layer keeps working unchanged."""
        meta = {"backend": self.backend, "trials": self.trials,
                "devices": self.devices, "full": self.full,
                "smoke": self.smoke, "scenarios": list(self.scenarios),
                "metric": self.metric, "packed": self.packed}
        if self.metric == "latency":
            meta["key_zipf"] = self.key_zipf
            meta["read_frac"] = self.read_frac
            meta["requests_per_tick"] = self.requests_per_tick
            meta["slo_ticks"] = self.slo_ticks
            meta["write_skew"] = self.write_skew
            meta["slo_curve_bins"] = self.slo_curve_bins
        if self.metric == "downtime" and self.zoo_live():
            meta["engines"] = ",".join(self.engines)
            meta["lease_ticks"] = self.lease_ticks
            meta["view_change_ticks"] = self.view_change_ticks
        if self.metric in ("downtime", "latency"):
            meta["rebuild_model"] = self.rebuild_model
            meta["size_dist"] = self.size_dist
            # match the result rows' normalization: the skew knob is
            # inert under uniform, so record it as 0 there
            meta["size_skew"] = self.size_skew \
                if self.size_dist in ("zipf", "lognormal") else 0.0
            meta["node_bandwidth_gibps"] = \
                None if math.isinf(self.node_bandwidth_gibps) \
                else self.node_bandwidth_gibps
        return meta


def _resolve_scenarios(selection) -> tuple:
    """Expand a scenario selection (a name list / comma string, possibly
    containing 'all') into the resolved registry-name tuple."""
    if isinstance(selection, str):
        selection = [selection]
    names = []
    for sel in selection:
        names.extend(s for s in str(sel).split(",") if s)
    if "all" in names:
        return tuple(scenario_names())
    return tuple(names)


def _loads_toml(text: str) -> dict:
    """Parse TOML via tomllib (3.11+) / tomli when available, else a
    minimal flat-table fallback covering the committed configs' subset
    (top-level ``key = value`` with strings, numbers incl. ``inf``,
    booleans, and one-line arrays) — the runtime floor is 3.10 and the
    experiment layer must not grow a dependency for it."""
    try:
        import tomllib
    except ImportError:
        try:
            import tomli as tomllib
        except ImportError:
            return _loads_flat_toml(text)
    return tomllib.loads(text)


def _scalar(tok: str):
    tok = tok.strip()
    if (tok.startswith('"') and tok.endswith('"') and len(tok) >= 2) or \
            (tok.startswith("'") and tok.endswith("'") and len(tok) >= 2):
        return tok[1:-1]
    if tok == "true":
        return True
    if tok == "false":
        return False
    if tok in ("inf", "+inf"):
        return math.inf
    try:
        return int(tok)
    except ValueError:
        pass
    try:
        return float(tok)
    except ValueError:
        raise SpecError(f"cannot parse TOML value {tok!r} "
                        "(fallback parser)") from None


def _strip_comment(line: str) -> str:
    out, quote = [], None
    for ch in line:
        if quote:
            out.append(ch)
            if ch == quote:
                quote = None
        elif ch in "\"'":
            quote = ch
            out.append(ch)
        elif ch == "#":
            break
        else:
            out.append(ch)
    return "".join(out).strip()


def _loads_flat_toml(text: str) -> dict:
    data = {}
    for lineno, raw in enumerate(text.splitlines(), 1):
        line = _strip_comment(raw)
        if not line:
            continue
        if line.startswith("["):
            raise SpecError(f"line {lineno}: tables are not supported "
                            "by the fallback TOML parser; use flat "
                            "key = value entries")
        if "=" not in line:
            raise SpecError(f"line {lineno}: expected key = value, "
                            f"got {line!r}")
        key, val = (s.strip() for s in line.split("=", 1))
        if val.startswith("[") and val.endswith("]"):
            body = val[1:-1].strip()
            items = []
            if body:
                items = [_scalar(tok) for tok in _split_array(body)]
            data[key] = items
        else:
            data[key] = _scalar(val)
    return data


def _split_array(body: str):
    toks, cur, quote = [], [], None
    for ch in body:
        if quote:
            cur.append(ch)
            if ch == quote:
                quote = None
        elif ch in "\"'":
            quote = ch
            cur.append(ch)
        elif ch == ",":
            toks.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    tail = "".join(cur).strip()
    if tail:
        toks.append(tail)
    return [t for t in (tok.strip() for tok in toks) if t]


#: re-exported engine constants so config consumers need one import
__all__ = ["ExperimentSpec", "SpecError", "BACKENDS", "METRICS",
           "ENGINES", "REBUILD_MODELS", "SIZE_DISTS"]
