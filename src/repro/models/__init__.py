from .model import batch_specs, build_model, decode_input_specs, input_specs, make_batch

__all__ = ["build_model", "input_specs", "batch_specs", "decode_input_specs",
           "make_batch"]
