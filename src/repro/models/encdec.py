"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

``input_specs`` supplies precomputed frame embeddings (B, enc_seq, d_model);
the conv/mel frontend is out of scope per the assignment.  Sinusoidal
positions on both stacks (deviation: original whisper uses learned decoder
positions; sinusoidal keeps parameter shapes independent of seq_len).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .layers import (apply_norm, cross_entropy, dtype_of, embed_init,
                     embed_tokens, norm_init, sinusoidal_positions, unembed)
from .transformer import segments_apply, segments_init, segments_state_shape


def build_encdec(cfg: ModelConfig):
    enc_cfg = cfg.replace(num_layers=cfg.enc_layers, is_encoder_decoder=False)

    def init_params(rng):
        k1, k2, k3 = jax.random.split(rng, 3)
        return {
            "embed": embed_init(cfg, k1),
            "encoder": segments_init(enc_cfg, k2, causal=False),
            "enc_ln": norm_init(cfg),
            "decoder": segments_init(cfg, k3, cross=True),
            "ln_f": norm_init(cfg),
        }

    def _encode(params, audio_embeds):
        x = audio_embeds.astype(dtype_of(cfg))
        x = x + sinusoidal_positions(jnp.arange(x.shape[1]), cfg.d_model
                                     ).astype(x.dtype)[None]
        x, _, _ = segments_apply(enc_cfg, params["encoder"], x, mode="train",
                                 causal=False)
        return apply_norm(cfg, params["enc_ln"], x)

    def _embed_dec(params, tokens, offset=0):
        x = embed_tokens(cfg, params["embed"], tokens)
        pos = jnp.arange(tokens.shape[1]) + offset
        return x + sinusoidal_positions(pos, cfg.d_model).astype(x.dtype)[None]

    def loss_fn(params, batch):
        enc = _encode(params, batch["audio_embeds"])
        x = _embed_dec(params, batch["tokens"])
        x, _, aux = segments_apply(cfg, params["decoder"], x, mode="train",
                                   enc_out=enc)
        x = apply_norm(cfg, params["ln_f"], x)
        logits = unembed(cfg, params["embed"], x)
        loss = cross_entropy(logits, batch["labels"], batch.get("loss_mask"))
        return loss, {"loss": loss, "aux_loss": aux,
                      "tokens": jnp.asarray(batch["labels"].size, jnp.float32)}

    def prefill(params, batch, max_len: int):
        enc = _encode(params, batch["audio_embeds"])
        x = _embed_dec(params, batch["tokens"])
        x, states, _ = segments_apply(cfg, params["decoder"], x, mode="prefill",
                                      enc_out=enc, max_len=max_len)
        x = apply_norm(cfg, params["ln_f"], x)
        logits = unembed(cfg, params["embed"], x[:, -1:])
        return logits[:, 0], states

    def decode_step(params, states, tokens, pos, positions=None):
        x = _embed_dec(params, tokens[:, None], offset=pos)
        x, states, _ = segments_apply(cfg, params["decoder"], x, mode="decode",
                                      states=states, pos=pos)
        x = apply_norm(cfg, params["ln_f"], x)
        logits = unembed(cfg, params["embed"], x)
        return logits[:, 0], states

    def decode_state_shape(batch: int, max_len: int):
        return segments_state_shape(cfg, batch, max_len, cross=True)

    return dict(config=cfg, init_params=init_params, loss_fn=loss_fn,
                prefill=prefill, decode_step=decode_step,
                decode_state_shape=decode_state_shape)
