"""Attention: GQA/MQA/MHA (full, sliding-window, local) and MLA, with KV caches.

Memory discipline: sequence-level attention is q-chunked (``lax.scan`` over
query blocks) so the S x S score matrix is never materialized — this is what
lets prefill_32k fit HBM in the dry-run, and it is the pure-jnp oracle for the
Pallas flash kernel (``repro.kernels``).  On TPU the kernel path is selected
by ``repro.kernels.ops``.

Cache layouts
  full:  k/v (B, S_alloc, KV, D), decode writes at ``pos``.
  ring:  k/v (B, W, KV, D) + slot->global-position map; used for SWA/local.
  mla:   c_kv (B, S, kv_rank) + k_pe (B, S, rope_dim)  (latent cache).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .layers import apply_rope, dense_init, pdtype_of, rms_norm_headwise, rope_angles

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)

# Chunk size for q-blocked attention; S x S materialization above this.
_QCHUNK = 512
_DENSE_LIMIT = 4096  # S_q*S_k <= limit^2 -> single dense block


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def attn_init(cfg: ModelConfig, key):
    d, h, kv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    pd = pdtype_of(cfg)
    if cfg.mla is not None:
        m = cfg.mla
        ks = jax.random.split(key, 7)
        qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
        return {
            "wq_a": dense_init(ks[0], (d, m.q_lora_rank), pd),
            "q_norm": jnp.ones((m.q_lora_rank,), pd),
            "wq_b": dense_init(ks[1], (m.q_lora_rank, h * qk_dim), pd),
            "wkv_a": dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim), pd),
            "kv_norm": jnp.ones((m.kv_lora_rank,), pd),
            "wk_b": dense_init(ks[3], (m.kv_lora_rank, h * m.qk_nope_head_dim), pd),
            "wv_b": dense_init(ks[4], (m.kv_lora_rank, h * m.v_head_dim), pd),
            "wo": dense_init(ks[5], (h * m.v_head_dim, d), pd),
        }
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h * dh), pd),
        "wk": dense_init(ks[1], (d, kv * dh), pd),
        "wv": dense_init(ks[2], (d, kv * dh), pd),
        "wo": dense_init(ks[3], (h * dh, d), pd),
    }
    if cfg.qk_norm:
        p["q_scale"] = jnp.ones((dh,), pd)
        p["k_scale"] = jnp.ones((dh,), pd)
    return p


# ---------------------------------------------------------------------------
# Core masked GQA attention (dense block + q-chunked scan)
# ---------------------------------------------------------------------------

def _gqa_block(q, k, v, *, scale, q_pos, k_pos, causal, window, cross=False):
    """q (B,Sq,H,D) k/v (B,Sk,KV,D); q_pos (Sq,), k_pos (Sk,) global indices."""
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    qf = q.reshape(B, Sq, KV, G, D)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qf, k).astype(jnp.float32) * scale
    if not cross:
        mask = jnp.ones((Sq, k.shape[1]), bool)
        if causal:
            mask &= k_pos[None, :] <= q_pos[:, None]
        if window:
            mask &= k_pos[None, :] > q_pos[:, None] - window
        mask &= k_pos[None, :] >= 0  # ring-cache empty slots carry pos=-1
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v)
    return out.reshape(B, Sq, H, v.shape[-1])


def mha(q, k, v, *, scale=None, causal=True, window=0, q_offset=0, cross=False):
    """Sequence attention, q-chunked when large.  Shapes as in _gqa_block."""
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    q_pos0 = jnp.arange(Sq) + q_offset
    k_pos = jnp.arange(Sk)
    if Sq * Sk <= _DENSE_LIMIT ** 2 or Sq % _QCHUNK or cross:
        return _gqa_block(q, k, v, scale=scale, q_pos=q_pos0, k_pos=k_pos,
                          causal=causal, window=window, cross=cross)

    nchunk = Sq // _QCHUNK
    qc = q.reshape(B, nchunk, _QCHUNK, H, D).transpose(1, 0, 2, 3, 4)

    if window and window + _QCHUNK < Sk:
        # local attention: each q-chunk only sees the trailing `window` keys.
        span = window + _QCHUNK

        def body(_, args):
            i, qi = args
            start = jnp.maximum(i * _QCHUNK - window, 0)
            # clamp so the static-size slice stays in bounds
            start = jnp.minimum(start, Sk - span)
            ks = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
            kp = start + jnp.arange(span)
            qp = i * _QCHUNK + jnp.arange(_QCHUNK) + q_offset
            o = _gqa_block(qi, ks, vs, scale=scale, q_pos=qp, k_pos=kp,
                           causal=causal, window=window)
            return (), o

        _, out = jax.lax.scan(body, (), (jnp.arange(nchunk), qc))
    else:
        def body(_, args):
            i, qi = args
            qp = i * _QCHUNK + jnp.arange(_QCHUNK) + q_offset
            o = _gqa_block(qi, k, v, scale=scale, q_pos=qp, k_pos=k_pos,
                           causal=causal, window=window)
            return (), o

        _, out = jax.lax.scan(body, (), (jnp.arange(nchunk), qc))
    return out.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, v.shape[-1])


def decode_mha(q, k_cache, v_cache, k_pos, *, scale=None, cur_pos=None, window=0):
    """One-step decode: q (B,1,H,D) vs cache (B,T,KV,D); k_pos (T,) globals."""
    D = q.shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    B, T = k_cache.shape[0], k_cache.shape[1]
    H, KV = q.shape[2], k_cache.shape[2]
    G = H // KV
    qf = q.reshape(B, 1, KV, G, D)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qf, k_cache).astype(jnp.float32) * scale
    mask = (k_pos <= cur_pos) & (k_pos >= 0)
    if window:
        mask &= k_pos > cur_pos - window
    scores = jnp.where(mask[None, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v_cache)
    return out.reshape(B, 1, H, v_cache.shape[-1])


# ---------------------------------------------------------------------------
# Cache constructors
# ---------------------------------------------------------------------------

def kv_cache_shape(cfg: ModelConfig, batch: int, max_len: int, window: int = 0):
    alloc = min(max_len, window) if window else max_len
    kv, dh = cfg.num_kv_heads, cfg.head_dim
    dt = jnp.dtype(cfg.act_dtype)
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "c_kv": jax.ShapeDtypeStruct((batch, alloc, m.kv_lora_rank), dt),
            "k_pe": jax.ShapeDtypeStruct((batch, alloc, m.qk_rope_head_dim), dt),
            "pos": jax.ShapeDtypeStruct((alloc,), jnp.int32),
        }
    return {
        "k": jax.ShapeDtypeStruct((batch, alloc, kv, dh), dt),
        "v": jax.ShapeDtypeStruct((batch, alloc, kv, dh), dt),
        "pos": jax.ShapeDtypeStruct((alloc,), jnp.int32),
    }


def empty_kv_cache(cfg: ModelConfig, batch: int, max_len: int, window: int = 0):
    return jax.tree.map(lambda s: jnp.full(s.shape, -1, s.dtype)
                        if s.dtype == jnp.int32 else jnp.zeros(s.shape, s.dtype),
                        kv_cache_shape(cfg, batch, max_len, window),
                        is_leaf=lambda s: isinstance(s, jax.ShapeDtypeStruct))


def _ring_write(buf, val, pos, alloc):
    """Write val (B,1,...) into ring buffer at slot pos % alloc."""
    slot = jnp.mod(pos, alloc)
    return jax.lax.dynamic_update_slice_in_dim(buf, val.astype(buf.dtype), slot, axis=1)


def _ring_fill_prefill(buf, vals, alloc):
    """Store the trailing `alloc` positions of vals (B,S,...) ring-aligned."""
    S = vals.shape[1]
    if S <= alloc:
        pad = [(0, 0)] * vals.ndim
        pad[1] = (0, alloc - S)
        return jnp.pad(vals, pad).astype(buf.dtype)
    tail = vals[:, S - alloc:]
    # global position p lives at slot p % alloc: roll so slots line up
    shift = (S - alloc) % alloc
    return jnp.roll(tail, shift, axis=1).astype(buf.dtype)


def _ring_positions(S, alloc):
    """Global positions per slot after prefilling S tokens."""
    if S <= alloc:
        return jnp.where(jnp.arange(alloc) < S, jnp.arange(alloc), -1)
    base = jnp.arange(alloc)
    # slot s holds the largest p < S with p % alloc == s
    last = S - 1
    off = jnp.mod(last - base, alloc)
    return last - off


# ---------------------------------------------------------------------------
# Full attention block apply (standard / GQA path)
# ---------------------------------------------------------------------------

def apply_attention(cfg: ModelConfig, params, x, *, mode: str,
                    window: int = 0, cache=None, pos=None,
                    positions=None, max_len: int = 0,
                    cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
                    causal: bool = True):
    """Returns (out, new_cache).  mode in {train, prefill, decode}.

    positions: (B, 3, S) M-RoPE ids when cfg.mrope_sections, else None
    (positions default to arange).  pos: int32 scalar current index (decode).
    """
    if cfg.mla is not None:
        return _apply_mla(cfg, params, x, mode=mode, cache=cache, pos=pos,
                          max_len=max_len)
    B, S, d = x.shape
    h, kv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ params["wq"]).reshape(B, S, h, dh)
    if cross_kv is None:
        k = (x @ params["wk"]).reshape(B, S, kv, dh)
        v = (x @ params["wv"]).reshape(B, S, kv, dh)
    else:
        xk, xv = cross_kv
        k = (xk @ params["wk"]).reshape(B, xk.shape[1], kv, dh)
        v = (xv @ params["wv"]).reshape(B, xv.shape[1], kv, dh)
    if cfg.qk_norm:
        q = rms_norm_headwise(q, params["q_scale"])
        k = rms_norm_headwise(k, params["k_scale"])

    if cfg.rope_theta and cross_kv is None:
        if cfg.mrope_sections:
            from .layers import mrope_angles
            if positions is None:
                base = (jnp.arange(S) if mode != "decode" else pos + jnp.arange(1))
                positions = jnp.broadcast_to(base[None, None, :], (B, 3, S))
            cos, sin = mrope_angles(positions, dh, cfg.rope_theta, cfg.mrope_sections)
        else:
            p = (jnp.arange(S) if mode != "decode" else pos + jnp.arange(1))
            cos, sin = rope_angles(p, dh, cfg.rope_theta)
            cos, sin = cos[None], sin[None]
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)

    if mode == "decode":
        assert cache is not None
        alloc = cache["k"].shape[1]
        new_cache = {
            "k": _ring_write(cache["k"], k, pos, alloc) if window else
                 jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), pos, axis=1),
            "v": _ring_write(cache["v"], v, pos, alloc) if window else
                 jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), pos, axis=1),
            "pos": cache["pos"].at[jnp.mod(pos, alloc) if window else pos].set(pos),
        }
        out = decode_mha(q, new_cache["k"], new_cache["v"], new_cache["pos"],
                         cur_pos=pos, window=window)
    else:
        out = mha(q, k, v, causal=causal and cross_kv is None, window=window,
                  cross=cross_kv is not None)
        new_cache = None
        if mode == "prefill" and cross_kv is None:
            alloc = min(max_len, window) if window else max_len
            new_cache = {
                "k": _ring_fill_prefill(jnp.zeros((B, alloc, kv, dh), k.dtype), k, alloc)
                     if window else _pad_to(k, alloc),
                "v": _ring_fill_prefill(jnp.zeros((B, alloc, kv, dh), v.dtype), v, alloc)
                     if window else _pad_to(v, alloc),
                "pos": _ring_positions(S, alloc) if window else
                       jnp.where(jnp.arange(alloc) < S, jnp.arange(alloc), -1),
            }
    return out.reshape(B, S, h * dh) @ params["wo"], new_cache


def _pad_to(arr, alloc):
    pad = [(0, 0)] * arr.ndim
    pad[1] = (0, alloc - arr.shape[1])
    return jnp.pad(arr, pad)


# ---------------------------------------------------------------------------
# MLA (Multi-head Latent Attention) — absorbed decode path
# ---------------------------------------------------------------------------

def _apply_mla(cfg: ModelConfig, params, x, *, mode, cache, pos, max_len):
    m = cfg.mla
    B, S, d = x.shape
    h = cfg.num_heads
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    scale = 1.0 / math.sqrt(qk_dim)

    cq = rms_norm_headwise(x @ params["wq_a"], params["q_norm"])
    q = (cq @ params["wq_b"]).reshape(B, S, h, qk_dim)
    q_nope, q_pe = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]

    ckv_full = x @ params["wkv_a"]
    c_kv = rms_norm_headwise(ckv_full[..., : m.kv_lora_rank], params["kv_norm"])
    k_pe = ckv_full[..., m.kv_lora_rank:]

    if mode == "decode":
        p = pos + jnp.arange(1)
    else:
        p = jnp.arange(S)
    cos, sin = rope_angles(p, m.qk_rope_head_dim, cfg.rope_theta)
    q_pe = apply_rope(q_pe, cos[None], sin[None])
    k_pe = apply_rope(k_pe[:, :, None, :], cos[None], sin[None])[:, :, 0, :]

    if mode == "decode":
        alloc = cache["c_kv"].shape[1]
        new_cache = {
            "c_kv": jax.lax.dynamic_update_slice_in_dim(
                cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), pos, axis=1),
            "k_pe": jax.lax.dynamic_update_slice_in_dim(
                cache["k_pe"], k_pe.astype(cache["k_pe"].dtype), pos, axis=1),
            "pos": cache["pos"].at[pos].set(pos),
        }
        # absorbed: q_nope' = q_nope @ Wk_b^T  -> score against latent cache
        wk = params["wk_b"].reshape(m.kv_lora_rank, h, m.qk_nope_head_dim)
        q_lat = jnp.einsum("bqhd,chd->bqhc", q_nope, wk)      # (B,1,h,rank)
        scores = (jnp.einsum("bqhc,btc->bhqt", q_lat, new_cache["c_kv"])
                  + jnp.einsum("bqhd,btd->bhqt", q_pe, new_cache["k_pe"]))
        scores = scores.astype(jnp.float32) * scale
        mask = (new_cache["pos"] <= pos) & (new_cache["pos"] >= 0)
        scores = jnp.where(mask[None, None, None, :], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
        o_lat = jnp.einsum("bhqt,btc->bqhc", probs, new_cache["c_kv"])
        wv = params["wv_b"].reshape(m.kv_lora_rank, h, m.v_head_dim)
        out = jnp.einsum("bqhc,chv->bqhv", o_lat, wv)
    else:
        k_nope = (c_kv @ params["wk_b"]).reshape(B, S, h, m.qk_nope_head_dim)
        v = (c_kv @ params["wv_b"]).reshape(B, S, h, m.v_head_dim)
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_pe[:, :, None, :], (B, S, h, m.qk_rope_head_dim))],
            axis=-1)
        qf = jnp.concatenate([q_nope, q_pe], axis=-1)
        out = mha(qf, k, v, scale=scale, causal=True)
        new_cache = None
        if mode == "prefill":
            new_cache = {
                "c_kv": _pad_to(c_kv, max_len),
                "k_pe": _pad_to(k_pe, max_len),
                "pos": jnp.where(jnp.arange(max_len) < S, jnp.arange(max_len), -1),
            }
    out = out.reshape(B, S, h * m.v_head_dim) @ params["wo"]
    return out, new_cache
