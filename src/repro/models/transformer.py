"""Model assembly: block zoo -> scanned segments -> decoder-only LM.

Layers are grouped into segments of (pattern, repeats); parameters/states are
stacked along a leading repeats axis and the segment body runs under
``lax.scan`` (keeps HLO size O(pattern), critical for 94-96 layer configs),
with ``jax.checkpoint`` rematerialization in training.

Entry points produced by ``build_lm``:
  init_params(rng)                     -> params pytree
  loss_fn(params, batch)               -> (loss, metrics)
  prefill(params, batch, max_len)      -> (last_logits, decode_state)
  decode_step(params, state, tok, pos) -> (logits, decode_state)
  decode_state_shape(batch, max_len)   -> ShapeDtypeStruct pytree (dry-run)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN, LOCAL_ATTN, MLSTM, RGLRU, SLSTM, ModelConfig
from . import attention as attn
from . import ssm
from .layers import (apply_mlp, apply_norm, cross_entropy, dtype_of,
                     embed_init, embed_tokens, mlp_init, norm_init,
                     sinusoidal_positions, unembed)
from .moe import apply_moe, moe_init


# ---------------------------------------------------------------------------
# Single block: init / state-shape / apply
# ---------------------------------------------------------------------------

def block_init(cfg: ModelConfig, kind: str, key, *, causal: bool = True,
               cross: bool = False):
    ks = jax.random.split(key, 4)
    if kind in (ATTN, LOCAL_ATTN):
        p = {"ln1": norm_init(cfg), "attn": attn.attn_init(cfg, ks[0])}
        if cross:
            p["ln_x"] = norm_init(cfg)
            p["xattn"] = attn.attn_init(cfg, ks[3])
        if cfg.moe is not None:
            p["ln2"] = norm_init(cfg)
            p["moe"] = moe_init(cfg, ks[1])
        elif cfg.d_ff:
            p["ln2"] = norm_init(cfg)
            p["mlp"] = mlp_init(cfg, ks[1])
        return p
    if kind == MLSTM:
        return {"ln": norm_init(cfg), "cell": ssm.mlstm_init(cfg, ks[0])}
    if kind == SLSTM:
        return {"ln": norm_init(cfg), "cell": ssm.slstm_init(cfg, ks[0])}
    if kind == RGLRU:
        return {"ln1": norm_init(cfg), "cell": ssm.rglru_init(cfg, ks[0]),
                "ln2": norm_init(cfg), "mlp": mlp_init(cfg, ks[1])}
    raise ValueError(kind)


def block_state_shape(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                      cross: bool = False):
    if kind in (ATTN, LOCAL_ATTN):
        window = cfg.window if kind == ATTN else cfg.local_window
        st = {"kv": attn.kv_cache_shape(cfg, batch, max_len, window)}
        if cross:
            dt = jnp.dtype(cfg.act_dtype)
            kvd = (batch, cfg.enc_seq, cfg.num_kv_heads, cfg.head_dim)
            st["ck"] = jax.ShapeDtypeStruct(kvd, dt)
            st["cv"] = jax.ShapeDtypeStruct(kvd, dt)
        return st
    if kind == MLSTM:
        return {"cell": ssm.mlstm_state_shape(cfg, batch)}
    if kind == SLSTM:
        return {"cell": ssm.slstm_state_shape(cfg, batch)}
    if kind == RGLRU:
        return {"cell": ssm.rglru_state_shape(cfg, batch)}
    raise ValueError(kind)


def block_apply(cfg: ModelConfig, kind: str, params, x, *, mode: str,
                state=None, pos=None, positions=None, max_len: int = 0,
                enc_out=None, causal: bool = True):
    """Returns (x, new_state, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in (ATTN, LOCAL_ATTN):
        window = cfg.window if kind == ATTN else cfg.local_window
        h, kv = attn.apply_attention(
            cfg, params["attn"], apply_norm(cfg, params["ln1"], x), mode=mode,
            window=window, cache=None if state is None else state["kv"],
            pos=pos, positions=positions, max_len=max_len, causal=causal)
        x = x + h
        new_state = None if kv is None else {"kv": kv}
        if "xattn" in params:
            if mode == "decode":
                ck, cv = state["ck"], state["cv"]
                xh = _cross_decode(cfg, params["xattn"],
                                   apply_norm(cfg, params["ln_x"], x), ck, cv)
            else:
                xh, _ = attn.apply_attention(
                    cfg, params["xattn"], apply_norm(cfg, params["ln_x"], x),
                    mode="train", cross_kv=(enc_out, enc_out), causal=False)
                if mode == "prefill":
                    ck = (enc_out @ params["xattn"]["wk"]).reshape(
                        enc_out.shape[0], enc_out.shape[1], cfg.num_kv_heads, cfg.head_dim)
                    cv = (enc_out @ params["xattn"]["wv"]).reshape(
                        enc_out.shape[0], enc_out.shape[1], cfg.num_kv_heads, cfg.head_dim)
                    new_state = dict(new_state or {}, ck=ck.astype(dtype_of(cfg)),
                                     cv=cv.astype(dtype_of(cfg)))
            x = x + xh
            if mode == "decode":
                new_state = dict(new_state or {}, ck=state["ck"], cv=state["cv"])
        if "moe" in params:
            h, aux = apply_moe(cfg, params["moe"], apply_norm(cfg, params["ln2"], x))
            x = x + h
        elif "mlp" in params:
            x = x + apply_mlp(cfg, params["mlp"], apply_norm(cfg, params["ln2"], x))
        return x, new_state, aux

    if kind in (MLSTM, SLSTM):
        fn = ssm.apply_mlstm if kind == MLSTM else ssm.apply_slstm
        h, st = fn(cfg, params["cell"], apply_norm(cfg, params["ln"], x),
                   mode=mode, state=None if state is None else state["cell"])
        return x + h, None if st is None else {"cell": st}, aux

    if kind == RGLRU:
        h, st = ssm.apply_rglru(cfg, params["cell"],
                                apply_norm(cfg, params["ln1"], x), mode=mode,
                                state=None if state is None else state["cell"])
        x = x + h
        x = x + apply_mlp(cfg, params["mlp"], apply_norm(cfg, params["ln2"], x))
        return x, None if st is None else {"cell": st}, aux
    raise ValueError(kind)


def _cross_decode(cfg, params, x, ck, cv):
    B, S, d = x.shape
    q = (x @ params["wq"]).reshape(B, S, cfg.num_heads, cfg.head_dim)
    pos = jnp.arange(ck.shape[1])
    out = attn.decode_mha(q, ck, cv, pos, cur_pos=jnp.int32(ck.shape[1] - 1))
    return out.reshape(B, S, cfg.num_heads * cfg.head_dim) @ params["wo"]


# ---------------------------------------------------------------------------
# Segments: stacked params + lax.scan over repeats
# ---------------------------------------------------------------------------

def segments_init(cfg: ModelConfig, key, *, causal: bool = True,
                  cross: bool = False):
    segs = []
    for si, (pattern, repeats) in enumerate(cfg.layout):
        kseg = jax.random.fold_in(key, si)
        stacked = []
        for bi, kind in enumerate(pattern):
            kk = jax.random.fold_in(kseg, bi)
            init_one = lambda k, kind=kind: block_init(cfg, kind, k,
                                                       causal=causal, cross=cross)
            stacked.append(jax.vmap(init_one)(jax.random.split(kk, repeats)))
        segs.append(tuple(stacked))
    return tuple(segs)


def segments_state_shape(cfg: ModelConfig, batch: int, max_len: int,
                         cross: bool = False):
    segs = []
    for pattern, repeats in cfg.layout:
        st = tuple(
            jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((repeats,) + s.shape, s.dtype),
                block_state_shape(cfg, kind, batch, max_len, cross),
                is_leaf=lambda s: isinstance(s, jax.ShapeDtypeStruct))
            for kind in pattern)
        segs.append(st)
    return tuple(segs)


def segments_apply(cfg: ModelConfig, seg_params, x, *, mode: str,
                   states=None, pos=None, positions=None, max_len: int = 0,
                   enc_out=None, causal: bool = True):
    """Run all segments.  Returns (x, new_states, aux)."""
    total_aux = jnp.zeros((), jnp.float32)
    new_states = []
    for si, (pattern, repeats) in enumerate(cfg.layout):
        params = seg_params[si]
        st = None if states is None else states[si]
        # remat grouping (train): scan over repeats/g steps of g pattern
        # instances each — g x fewer stored checkpoints, g x recompute depth.
        group = cfg.remat_group if (mode == "train" and cfg.remat
                                    and repeats % max(cfg.remat_group, 1) == 0) \
            else 1

        def apply_one(xx, aux, p_i, s_i):
            outs = []
            for bi, kind in enumerate(pattern):
                xx, ns, a = block_apply(cfg, kind, p_i[bi], xx, mode=mode,
                                        state=None if s_i is None else s_i[bi],
                                        pos=pos, positions=positions,
                                        max_len=max_len, enc_out=enc_out,
                                        causal=causal)
                outs.append(ns)
                aux = aux + a
            return xx, aux, tuple(outs)

        def body(carry, xs):
            xx, aux = carry
            if group > 1:
                for j in range(group):
                    p_j = jax.tree.map(lambda a: a[j], xs)
                    xx, aux, _ = apply_one(xx, aux, p_j, None)
                return (xx, aux), None
            p_i = xs[: len(pattern)]
            s_i = None if st is None else xs[len(pattern):]
            xx, aux, outs = apply_one(xx, aux, p_i, s_i)
            return (xx, aux), outs if mode != "train" else None

        if cfg.remat and mode == "train":
            body = jax.checkpoint(body, prevent_cse=False)
        if group > 1:
            xs = jax.tree.map(
                lambda a: a.reshape((repeats // group, group) + a.shape[1:]),
                params)
        else:
            xs = params if st is None else params + st
        (x, total_aux), seg_out = jax.lax.scan(
            body, (x, total_aux), xs)
        new_states.append(seg_out)
    return x, (tuple(new_states) if mode != "train" else None), total_aux


# ---------------------------------------------------------------------------
# Decoder-only LM
# ---------------------------------------------------------------------------

def build_lm(cfg: ModelConfig):
    if cfg.is_encoder_decoder:
        from .encdec import build_encdec
        return build_encdec(cfg)

    def init_params(rng):
        k1, k2, k3 = jax.random.split(rng, 3)
        return {
            "embed": embed_init(cfg, k1),
            "blocks": segments_init(cfg, k2),
            "ln_f": norm_init(cfg),
        }

    def _inputs_to_x(params, batch, mode):
        positions = batch.get("positions") if cfg.position_inputs else None
        if cfg.embeds_input:
            x = batch["embeds"].astype(dtype_of(cfg))
        else:
            x = embed_tokens(cfg, params["embed"], batch["tokens"])
        return x, positions

    def _backbone(params, x, *, mode, states=None, pos=None, positions=None,
                  max_len=0):
        x, new_states, aux = segments_apply(
            cfg, params["blocks"], x, mode=mode, states=states, pos=pos,
            positions=positions, max_len=max_len)
        x = apply_norm(cfg, params["ln_f"], x)
        return x, new_states, aux

    def loss_fn(params, batch):
        x, positions = _inputs_to_x(params, batch, "train")
        x, _, aux = _backbone(params, x, mode="train", positions=positions)
        logits = unembed(cfg, params["embed"], x)
        mask = batch.get("loss_mask")
        loss = cross_entropy(logits, batch["labels"], mask)
        total = loss + 0.01 * aux
        return total, {"loss": loss, "aux_loss": aux,
                       "tokens": jnp.asarray(batch["labels"].size, jnp.float32)}

    def prefill(params, batch, max_len: int):
        x, positions = _inputs_to_x(params, batch, "prefill")
        x, states, _ = _backbone(params, x, mode="prefill", positions=positions,
                                 max_len=max_len)
        logits = unembed(cfg, params["embed"], x[:, -1:])
        return logits[:, 0], states

    def decode_step(params, states, tokens, pos, positions=None):
        """tokens (B,) int32 (or embeds (B,d) for stub frontends); pos scalar."""
        if cfg.embeds_input:
            x = tokens.astype(dtype_of(cfg))[:, None, :]
        else:
            x = embed_tokens(cfg, params["embed"], tokens[:, None])
        x, states, _ = _backbone(params, x, mode="decode", states=states,
                                 pos=pos, positions=positions)
        logits = unembed(cfg, params["embed"], x)
        return logits[:, 0], states

    def decode_state_shape(batch: int, max_len: int):
        return segments_state_shape(cfg, batch, max_len)

    return dict(config=cfg, init_params=init_params, loss_fn=loss_fn,
                prefill=prefill, decode_step=decode_step,
                decode_state_shape=decode_state_shape)
