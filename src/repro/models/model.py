"""Public model facade: ``build_model(cfg)`` + per-shape ``input_specs``.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every model
input of that cell (weak-type-correct, shardable, no device allocation) — the
dry-run lowers against these; ``make_batch`` materializes small concrete
batches for smoke tests.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from .transformer import build_lm

build_model = build_lm


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Specs for the *batch* argument of loss_fn/prefill (not decode)."""
    B = shape.global_batch
    S = shape.seq_len
    d = cfg.d_model
    act = cfg.act_dtype
    specs: Dict[str, Any] = {}
    if cfg.is_encoder_decoder:
        specs["audio_embeds"] = _sds((B, cfg.enc_seq, d), act)
        specs["tokens"] = _sds((B, S), "int32")
    elif cfg.embeds_input:
        specs["embeds"] = _sds((B, S, d), act)
        if cfg.position_inputs:
            specs["positions"] = _sds((B, 3, S), "int32")
    else:
        specs["tokens"] = _sds((B, S), "int32")
    if shape.kind == "train":
        specs["labels"] = _sds((B, S), "int32")
    return specs


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Specs for serve_step(params, state, tokens, pos)."""
    model = build_model(cfg)
    B, S = shape.global_batch, shape.seq_len
    if cfg.embeds_input and not cfg.is_encoder_decoder:
        tok = _sds((B, cfg.d_model), cfg.act_dtype)
    else:
        tok = _sds((B,), "int32")
    return {
        "state": model["decode_state_shape"](B, S),
        "tokens": tok,
        "pos": _sds((), "int32"),
    }


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    if shape.kind == "decode":
        return decode_input_specs(cfg, shape)
    return batch_specs(cfg, shape)


def make_batch(cfg: ModelConfig, shape: ShapeConfig, rng: np.random.Generator):
    """Concrete random batch (smoke tests; CPU-sized shapes only)."""
    out = {}
    for name, s in batch_specs(cfg, shape).items():
        if s.dtype == jnp.int32:
            if name == "positions":
                base = np.arange(s.shape[-1], dtype=np.int32)
                out[name] = np.broadcast_to(base, s.shape).copy()
            else:
                out[name] = rng.integers(0, cfg.vocab_size, s.shape).astype(np.int32)
        else:
            out[name] = rng.standard_normal(s.shape).astype(np.dtype(s.dtype))
    return jax.tree.map(jnp.asarray, out)
