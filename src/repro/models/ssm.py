"""Recurrent blocks: xLSTM (mLSTM + sLSTM) and RG-LRU (Griffin/RecurrentGemma).

Sequence processing uses the chunk-parallel / associative-scan forms (the
Pallas kernels' oracles in ``repro.kernels``); decode uses O(1) recurrent
state — this is what makes ``long_500k`` tractable for these families.

Adaptations vs the source papers (documented in DESIGN.md): mLSTM i/f gates
are computed from the conv branch (not the stacked qkv), and RG-LRU gates use
dense instead of block-diagonal projections.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from .layers import dense_init, pdtype_of, rms_norm_headwise

_RG_C = 8.0  # RG-LRU decay sharpness constant


# ---------------------------------------------------------------------------
# causal depthwise conv1d
# ---------------------------------------------------------------------------

def causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """x (B,S,ch), w (cw,ch) -> (B,S,ch)."""
    cw = w.shape[0]
    xp = jnp.pad(x, [(0, 0), (cw - 1, 0), (0, 0)])
    out = sum(xp[:, j: j + x.shape[1]] * w[j] for j in range(cw))
    return out.astype(x.dtype)


def conv_step(x1: jax.Array, w: jax.Array, state: jax.Array):
    """x1 (B,1,ch); state (B,cw-1,ch) -> (out (B,1,ch), new_state)."""
    win = jnp.concatenate([state, x1.astype(state.dtype)], axis=1)  # (B,cw,ch)
    out = jnp.einsum("bcw,cw->bw", win.astype(jnp.float32),
                     w.astype(jnp.float32))[:, None]
    return out.astype(x1.dtype), win[:, 1:]


# ---------------------------------------------------------------------------
# mLSTM block (xLSTM matrix memory)
# ---------------------------------------------------------------------------

def mlstm_inner(cfg: ModelConfig) -> int:
    return int(cfg.proj_factor * cfg.d_model)


def mlstm_init(cfg: ModelConfig, key):
    d, H = cfg.d_model, cfg.num_heads
    inner = mlstm_inner(cfg)
    pd = pdtype_of(cfg)
    ks = jax.random.split(key, 8)
    return {
        "w_up": dense_init(ks[0], (d, 2 * inner), pd),
        "conv": dense_init(ks[1], (cfg.conv_width, inner), pd, scale=0.3),
        "wq": dense_init(ks[2], (inner, inner), pd),
        "wk": dense_init(ks[3], (inner, inner), pd),
        "wv": dense_init(ks[4], (inner, inner), pd),
        "w_i": dense_init(ks[5], (inner, H), jnp.float32),
        "w_f": dense_init(ks[6], (inner, H), jnp.float32),
        "b_f": jnp.full((H,), 3.0, jnp.float32),   # bias toward remembering
        "b_i": jnp.zeros((H,), jnp.float32),
        "skip": jnp.ones((inner,), pd),
        "out_scale": jnp.ones((inner,), pd),
        "w_down": dense_init(ks[7], (inner, d), pd),
    }


def mlstm_state_shape(cfg: ModelConfig, batch: int):
    H = cfg.num_heads
    inner = mlstm_inner(cfg)
    dh = inner // H
    return {
        "C": jax.ShapeDtypeStruct((batch, H, dh, dh), jnp.float32),
        "n": jax.ShapeDtypeStruct((batch, H, dh), jnp.float32),
        "m": jax.ShapeDtypeStruct((batch, H), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, cfg.conv_width - 1, inner),
                                     jnp.dtype(cfg.act_dtype)),
    }


def _mlstm_qkv_gates(cfg, params, c_in, c_act):
    B, S, inner = c_in.shape
    H = cfg.num_heads
    dh = inner // H
    heads = lambda a: a.reshape(B, S, H, dh).transpose(0, 2, 1, 3)  # (B,H,S,dh)
    q = heads(c_act @ params["wq"])
    k = heads(c_act @ params["wk"])
    v = heads(c_in @ params["wv"])
    gf = c_act.astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(gf @ params["w_f"] + params["b_f"])  # (B,S,H)
    log_i = gf @ params["w_i"] + params["b_i"]
    return q, k, v, log_f.transpose(0, 2, 1), log_i.transpose(0, 2, 1)


def _mlstm_out(cfg, params, h, c_act, g):
    """h (B,H,S,dh) -> block output (B,S,d)."""
    B, H, S, dh = h.shape
    hs = h.transpose(0, 2, 1, 3)                                    # (B,S,H,dh)
    hn = rms_norm_headwise(hs, jnp.ones((dh,), jnp.float32)).reshape(B, S, H * dh)
    hn = hn * params["out_scale"] + c_act * params["skip"]
    return ((hn * jax.nn.silu(g)) @ params["w_down"])


def apply_mlstm(cfg: ModelConfig, params, x, *, mode: str, state=None):
    B, S, d = x.shape
    inner = mlstm_inner(cfg)
    up = x @ params["w_up"]
    c_in, g = up[..., :inner], up[..., inner:]

    if mode == "decode":
        c_out, conv_state = conv_step(c_in, params["conv"], state["conv"])
        c_act = jax.nn.silu(c_out)
        q, k, v, log_f, log_i = _mlstm_qkv_gates(cfg, params, c_in, c_act)
        h1, (C, n, m) = ops.mlstm_step(
            q[:, :, 0], k[:, :, 0], v[:, :, 0], log_f[:, :, 0], log_i[:, :, 0],
            (state["C"], state["n"], state["m"]))
        h = h1[:, :, None, :]                                       # (B,H,1,dh)
        new_state = {"C": C, "n": n, "m": m, "conv": conv_state}
    else:
        c_act = jax.nn.silu(causal_conv(c_in, params["conv"]))
        q, k, v, log_f, log_i = _mlstm_qkv_gates(cfg, params, c_in, c_act)
        h, (C, n, m) = ops.mlstm_chunkwise(q, k, v, log_f, log_i)
        new_state = None
        if mode == "prefill":
            tail = c_in[:, max(S - (cfg.conv_width - 1), 0):]
            if tail.shape[1] < cfg.conv_width - 1:
                tail = jnp.pad(tail, [(0, 0), (cfg.conv_width - 1 - tail.shape[1], 0), (0, 0)])
            new_state = {"C": C, "n": n, "m": m,
                         "conv": tail.astype(jnp.dtype(cfg.act_dtype))}
    return _mlstm_out(cfg, params, h, c_act, g), new_state


# ---------------------------------------------------------------------------
# sLSTM block (scalar memory, strictly sequential)
# ---------------------------------------------------------------------------

def slstm_init(cfg: ModelConfig, key):
    d, H = cfg.d_model, cfg.num_heads
    dh = d // H
    ff = int(4 * d / 3)
    pd = pdtype_of(cfg)
    ks = jax.random.split(key, 6)
    return {
        "conv": dense_init(ks[0], (cfg.conv_width, d), pd, scale=0.3),
        "w": dense_init(ks[1], (d, 4 * d), jnp.float32),
        "r": (jax.random.truncated_normal(ks[2], -2, 2, (H, dh, 4 * dh))
              / math.sqrt(dh)).astype(jnp.float32),
        "b": jnp.concatenate([jnp.zeros((d,)), jnp.full((d,), 3.0),
                              jnp.zeros((2 * d,))]).astype(jnp.float32),
        "wu_g": dense_init(ks[3], (d, ff), pd),
        "wu": dense_init(ks[4], (d, ff), pd),
        "wd": dense_init(ks[5], (ff, d), pd),
    }


def slstm_state_shape(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    return {
        "c": jax.ShapeDtypeStruct((batch, d), jnp.float32),
        "n": jax.ShapeDtypeStruct((batch, d), jnp.float32),
        "h": jax.ShapeDtypeStruct((batch, d), jnp.float32),
        "m": jax.ShapeDtypeStruct((batch, d), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, cfg.conv_width - 1, d),
                                     jnp.dtype(cfg.act_dtype)),
    }


def _slstm_cell(cfg, params, xc_t, carry):
    """xc_t (B,d) conv'd input; carry (c,n,h,m) each (B,d) f32."""
    c, n, h, m = carry
    B, d = xc_t.shape
    H = cfg.num_heads
    dh = d // H
    gx = xc_t.astype(jnp.float32) @ params["w"] + params["b"]       # (B,4d)
    hr = h.reshape(B, H, dh)
    gr = jnp.einsum("bhd,hde->bhe", hr, params["r"]).reshape(B, 4 * d)
    g = gx + gr
    gi, gf, gz, go = jnp.split(g, 4, axis=-1)
    z = jnp.tanh(gz)
    o = jax.nn.sigmoid(go)
    lf = jax.nn.log_sigmoid(gf)
    m_new = jnp.maximum(lf + m, gi)
    ip = jnp.exp(gi - m_new)
    fp = jnp.exp(lf + m - m_new)
    c_new = fp * c + ip * z
    n_new = fp * n + ip
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new)


def _slstm_ffn(params, h, dtype):
    hn = rms_norm_headwise(h.astype(jnp.float32), jnp.ones((h.shape[-1],))).astype(dtype)
    return (jax.nn.gelu(hn @ params["wu_g"]) * (hn @ params["wu"])) @ params["wd"]


def apply_slstm(cfg: ModelConfig, params, x, *, mode: str, state=None):
    B, S, d = x.shape
    if mode == "decode":
        xc, conv_state = conv_step(x, params["conv"], state["conv"])
        carry = (state["c"], state["n"], state["h"], state["m"])
        carry = _slstm_cell(cfg, params, xc[:, 0], carry)
        h = carry[2][:, None]
        new_state = {"c": carry[0], "n": carry[1], "h": carry[2], "m": carry[3],
                     "conv": conv_state}
    else:
        xc = causal_conv(x, params["conv"])

        def step(carry, xt):
            carry = _slstm_cell(cfg, params, xt, carry)
            return carry, carry[2]

        z = jnp.zeros((B, d), jnp.float32)
        init = (z, z, z, jnp.full((B, d), -1e30, jnp.float32))
        carry, hs = jax.lax.scan(step, init, xc.swapaxes(0, 1))
        h = hs.swapaxes(0, 1)                                       # (B,S,d)
        new_state = None
        if mode == "prefill":
            tail = x[:, max(S - (cfg.conv_width - 1), 0):]
            if tail.shape[1] < cfg.conv_width - 1:
                tail = jnp.pad(tail, [(0, 0), (cfg.conv_width - 1 - tail.shape[1], 0), (0, 0)])
            new_state = {"c": carry[0], "n": carry[1], "h": carry[2],
                         "m": carry[3], "conv": tail.astype(jnp.dtype(cfg.act_dtype))}
    return _slstm_ffn(params, h, x.dtype), new_state


# ---------------------------------------------------------------------------
# RG-LRU block (Griffin recurrent block)
# ---------------------------------------------------------------------------

def rglru_init(cfg: ModelConfig, key):
    d, w = cfg.d_model, cfg.lru_width
    pd = pdtype_of(cfg)
    ks = jax.random.split(key, 6)
    # Lambda init so that a = exp(-8*softplus(L)*r) lands in ~[0.9, 0.999]
    u = jax.random.uniform(ks[5], (w,), jnp.float32, 0.1, 0.9)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _RG_C))
    return {
        "w_x": dense_init(ks[0], (d, w), pd),
        "w_gate": dense_init(ks[1], (d, w), pd),
        "conv": dense_init(ks[2], (cfg.conv_width, w), pd, scale=0.3),
        "w_rg": dense_init(ks[3], (w, w), jnp.float32),
        "w_ig": dense_init(ks[4], (w, w), jnp.float32),
        "lam": lam,
        "w_out": dense_init(jax.random.fold_in(key, 7), (w, d), pd),
    }


def rglru_state_shape(cfg: ModelConfig, batch: int):
    w = cfg.lru_width
    return {
        "h": jax.ShapeDtypeStruct((batch, w), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, cfg.conv_width - 1, w),
                                     jnp.dtype(cfg.act_dtype)),
    }


def apply_rglru(cfg: ModelConfig, params, x, *, mode: str, state=None):
    B, S, d = x.shape
    u = x @ params["w_x"]
    g = jax.nn.gelu(x @ params["w_gate"])

    if mode == "decode":
        uc, conv_state = conv_step(u, params["conv"], state["conv"])
        ucf = uc[:, 0].astype(jnp.float32)
        r = jax.nn.sigmoid(ucf @ params["w_rg"])
        i = jax.nn.sigmoid(ucf @ params["w_ig"])
        log_a = -_RG_C * jax.nn.softplus(params["lam"]) * r
        h = ops.rglru_step(i * ucf, log_a, state["h"])
        y = h[:, None].astype(x.dtype)
        new_state = {"h": h, "conv": conv_state}
    else:
        uc = causal_conv(u, params["conv"])
        ucf = uc.astype(jnp.float32)
        r = jax.nn.sigmoid(ucf @ params["w_rg"])
        i = jax.nn.sigmoid(ucf @ params["w_ig"])
        log_a = -_RG_C * jax.nn.softplus(params["lam"]) * r
        h = ops.rglru_scan(i * ucf, log_a)                          # (B,S,w) f32
        y = h.astype(x.dtype)
        new_state = None
        if mode == "prefill":
            tail = u[:, max(S - (cfg.conv_width - 1), 0):]
            if tail.shape[1] < cfg.conv_width - 1:
                tail = jnp.pad(tail, [(0, 0), (cfg.conv_width - 1 - tail.shape[1], 0), (0, 0)])
            new_state = {"h": h[:, -1], "conv": tail.astype(jnp.dtype(cfg.act_dtype))}
    return (y * g) @ params["w_out"], new_state
