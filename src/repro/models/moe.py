"""Mixture-of-Experts: top-k router + capacity-bounded scatter dispatch.

Dispatch strategy (EP-friendly, no S x S x E x C one-hot einsums):
  1. route each token to top-k experts (router in f32),
  2. rank slots per (sequence row, expert) via a one-hot cumsum,
  3. scatter tokens into a (B, E, C, d) buffer (capacity overflow -> drop),
  4. batched expert FFN einsum over the E axis (sharded over `model` => the
     resharding from batch-sharded scatter output to expert-sharded matmul is
     where GSPMD inserts the all-to-alls),
  5. gather back + weighted combine.

Returns a switch-style load-balancing aux loss alongside the output.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from .layers import dense_init, pdtype_of


def moe_init(cfg: ModelConfig, key):
    assert cfg.moe is not None
    e, d, ff = cfg.moe.num_experts, cfg.d_model, cfg.d_ff
    pd = pdtype_of(cfg)
    ks = jax.random.split(key, 4)
    scale = 1.0 / math.sqrt(d)
    return {
        "router": dense_init(ks[0], (d, e), jnp.float32, scale=scale),
        "wi_gate": (jax.random.truncated_normal(ks[1], -2, 2, (e, d, ff)) * scale).astype(pd),
        "wi_up": (jax.random.truncated_normal(ks[2], -2, 2, (e, d, ff)) * scale).astype(pd),
        "wo": (jax.random.truncated_normal(ks[3], -2, 2, (e, ff, d)) / math.sqrt(ff)).astype(pd),
    }


_RANK_CHUNK = 8192


def _slot_ranks(slot_e: jax.Array, E: int) -> jax.Array:
    """Rank of each slot within its (row, expert) group.

    Chunked over the slot axis: the naive one-hot cumsum materializes
    (B, S*K, E) int32 — 67 GB for mixtral prefill_32k — so we scan
    _RANK_CHUNK-slot blocks carrying per-expert counts.
    """
    B, SK = slot_e.shape
    if SK <= _RANK_CHUNK:
        onehot = jax.nn.one_hot(slot_e, E, dtype=jnp.int32)
        return jnp.take_along_axis(jnp.cumsum(onehot, axis=1),
                                   slot_e[..., None], axis=2)[..., 0] - 1
    pad = (-SK) % _RANK_CHUNK
    se = jnp.pad(slot_e, ((0, 0), (0, pad)))
    nch = se.shape[1] // _RANK_CHUNK
    se = se.reshape(B, nch, _RANK_CHUNK).transpose(1, 0, 2)

    def body(counts, se_c):                     # counts (B, E)
        oh = jax.nn.one_hot(se_c, E, dtype=jnp.int32)
        cs = jnp.cumsum(oh, axis=1) + counts[:, None, :]
        p = jnp.take_along_axis(cs, se_c[..., None], axis=2)[..., 0] - 1
        return counts + oh.sum(axis=1), p

    _, ps = jax.lax.scan(body, jnp.zeros((B, E), jnp.int32), se)
    return ps.transpose(1, 0, 2).reshape(B, -1)[:, :SK]


def apply_moe(cfg: ModelConfig, params, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    m = cfg.moe
    B, S, d = x.shape
    E, K = m.num_experts, m.experts_per_token
    C = max(1, int(math.ceil(S * K * m.capacity_factor / E)))

    logits = (x.astype(jnp.float32) @ params["router"])            # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, K)                          # (B,S,K)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)

    # aux loss: E * mean_e( frac_tokens_e * mean_prob_e )
    frac = jnp.mean(
        jnp.sum(jax.nn.one_hot(top_e, E, dtype=jnp.float32), axis=2), axis=(0, 1)) / K
    pmean = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac * pmean)

    # --- slot ranking per sequence row ------------------------------------
    slot_e = top_e.reshape(B, S * K)                                # (B,SK)
    slot_w = top_w.reshape(B, S * K)
    pos = _slot_ranks(slot_e, E)                                    # (B,SK)
    keep = pos < C
    pos_safe = jnp.where(keep, pos, C)                              # C -> dropped

    # --- scatter into expert buffers ---------------------------------------
    xs = jnp.repeat(x, K, axis=1)                                   # (B,SK,d)
    bidx = jnp.broadcast_to(jnp.arange(B)[:, None], (B, S * K))
    buf = jnp.zeros((B, E, C, d), x.dtype)
    buf = buf.at[bidx, slot_e, pos_safe].add(
        jnp.where(keep[..., None], xs, 0), mode="drop")

    # --- expert FFN (E axis sharded over `model`) ---------------------------
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, params["wi_gate"])) \
            * jnp.einsum("becd,edf->becf", buf, params["wi_up"])
    else:
        h = jax.nn.gelu(jnp.einsum("becd,edf->becf", buf, params["wi_up"]))
    out_buf = jnp.einsum("becf,efd->becd", h, params["wo"])

    # --- gather + combine ----------------------------------------------------
    y = out_buf[bidx, slot_e, jnp.minimum(pos_safe, C - 1)]         # (B,SK,d)
    y = jnp.where(keep[..., None], y, 0) * slot_w[..., None].astype(y.dtype)
    y = y.reshape(B, S, K, d).sum(axis=2)
    return y.astype(x.dtype), aux
