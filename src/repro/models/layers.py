"""Shared layers: norms, RoPE/M-RoPE, MLPs, embeddings, cross-entropy.

Pure-functional JAX: params are nested dicts of arrays; every apply function
is shape-polymorphic over batch/sequence so the same code serves train_4k,
prefill_32k, decode and the reduced smoke configs.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def dtype_of(cfg: ModelConfig) -> jnp.dtype:
    return jnp.dtype(cfg.act_dtype)


def pdtype_of(cfg: ModelConfig) -> jnp.dtype:
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init (std = scale or 1/sqrt(fan_in))."""
    fan_in = shape[0] if len(shape) > 1 else shape[-1]
    std = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


# ---------------------------------------------------------------------------
# Norms (f32 internal accumulation)
# ---------------------------------------------------------------------------

def norm_init(cfg: ModelConfig, width: Optional[int] = None):
    width = width or cfg.d_model
    p = {"scale": jnp.ones((width,), pdtype_of(cfg))}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((width,), pdtype_of(cfg))
    return p


def apply_norm(cfg: ModelConfig, params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + eps) * params["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_norm_headwise(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Per-head q/k norm (qwen3)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (plain + multimodal M-RoPE)
# ---------------------------------------------------------------------------

def rope_angles(positions: jax.Array, dim: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """cos/sin of shape positions.shape + (dim//2,)."""
    inv_freq = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv_freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (..., S, H, D); cos/sin: (..., S, D//2) broadcast over heads."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    c = cos[..., None, :].astype(jnp.float32)
    s = sin[..., None, :].astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([x1f * c - x2f * s, x2f * c + x1f * s], axis=-1)
    return out.astype(x.dtype)


def mrope_angles(pos_thw: jax.Array, dim: int, theta: float,
                 sections: Tuple[int, ...]) -> Tuple[jax.Array, jax.Array]:
    """M-RoPE (qwen2-vl): pos_thw (B, 3, S); sections sum to dim//2.

    Frequency slot f uses the (t|h|w) position row of its section.
    Returns cos/sin of shape (B, S, dim//2).
    """
    assert sum(sections) == dim // 2, (sections, dim)
    inv_freq = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    # section id per frequency slot
    sec_id = jnp.repeat(jnp.arange(len(sections)), jnp.array(sections),
                        total_repeat_length=dim // 2)          # (dim//2,)
    pos = jnp.take_along_axis(
        pos_thw.astype(jnp.float32),                            # (B, 3, S)
        jnp.broadcast_to(sec_id[None, :, None], (pos_thw.shape[0], dim // 2, pos_thw.shape[2])).astype(jnp.int32),
        axis=1)                                                 # (B, dim//2, S)
    ang = jnp.swapaxes(pos, 1, 2) * inv_freq                    # (B, S, dim//2)
    return jnp.cos(ang), jnp.sin(ang)


def sinusoidal_positions(positions: jax.Array, dim: int) -> jax.Array:
    """Whisper-style sinusoidal embeddings; positions (...,) -> (..., dim)."""
    half = dim // 2
    freqs = jnp.exp(-math.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / (half - 1))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Feed-forward blocks
# ---------------------------------------------------------------------------

def mlp_init(cfg: ModelConfig, key, d_ff: Optional[int] = None):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    pd = pdtype_of(cfg)
    ks = jax.random.split(key, 3)
    if cfg.mlp in ("swiglu", "gelu_glu"):
        return {"wi_gate": dense_init(ks[0], (d, ff), pd),
                "wi_up": dense_init(ks[1], (d, ff), pd),
                "wo": dense_init(ks[2], (ff, d), pd)}
    return {"wi_up": dense_init(ks[1], (d, ff), pd),
            "wo": dense_init(ks[2], (ff, d), pd)}


def apply_mlp(cfg: ModelConfig, params, x: jax.Array) -> jax.Array:
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(x @ params["wi_gate"]) * (x @ params["wi_up"])
    elif cfg.mlp == "gelu_glu":
        h = jax.nn.gelu(x @ params["wi_gate"]) * (x @ params["wi_up"])
    elif cfg.mlp == "relu2":
        h = jnp.square(jax.nn.relu(x @ params["wi_up"]))
    elif cfg.mlp == "gelu":
        h = jax.nn.gelu(x @ params["wi_up"])
    else:
        raise ValueError(cfg.mlp)
    return h @ params["wo"]


# ---------------------------------------------------------------------------
# Embedding / unembedding / loss
# ---------------------------------------------------------------------------

def embed_init(cfg: ModelConfig, key):
    pd = pdtype_of(cfg)
    k1, k2 = jax.random.split(key)
    p = {"embedding": dense_init(k1, (cfg.vocab_size, cfg.d_model), pd, scale=0.02)}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(k2, (cfg.d_model, cfg.vocab_size), pd)
    return p


def embed_tokens(cfg: ModelConfig, params, tokens: jax.Array) -> jax.Array:
    emb = jnp.take(params["embedding"], tokens, axis=0).astype(dtype_of(cfg))
    if cfg.scale_embeddings:
        emb = emb * math.sqrt(cfg.d_model)
    return emb


def unembed(cfg: ModelConfig, params, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        return x @ params["embedding"].T.astype(x.dtype)
    return x @ params["unembed"].astype(x.dtype)


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token CE in f32.  logits (..., V); labels (...) int32."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
