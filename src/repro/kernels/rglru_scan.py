"""Pallas TPU RG-LRU linear-recurrence scan.

h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * x_t, per channel.

Grid = (B, W/block_w, S/block_s); the sequence axis is innermost, carrying
h (block_w,) in VMEM scratch; within a block the recurrence runs as an
unrolled log-depth Blelloch-style composition over (a, b) pairs — pure VPU
work on (block_s, block_w) tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(x_ref, la_ref, h_ref, carry_ref, *, block_s: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    la = la_ref[0].astype(jnp.float32)                 # (L, Wb)
    x = x_ref[0].astype(jnp.float32)
    a = jnp.exp(la)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * la), 0.0)) * x

    # associative scan over the block (log-depth, unrolled shifts)
    shift = 1
    while shift < block_s:
        a_prev = jnp.pad(a, ((shift, 0), (0, 0)), constant_values=1.0)[:block_s]
        b_prev = jnp.pad(b, ((shift, 0), (0, 0)))[:block_s]
        b = b_prev * a + b
        a = a_prev * a
        shift *= 2

    h0 = carry_ref[...]
    h = a * h0[None, :] + b
    h_ref[0] = h.astype(h_ref.dtype)
    carry_ref[...] = h[-1]


def rglru_scan(x, log_a, *, block_s: int = 256, block_w: int = 512,
               interpret: bool = False):
    """x, log_a: (B, S, W) -> h (B, S, W) float32 (matches ref oracle)."""
    B, S, W = x.shape
    block_s = min(block_s, S)
    block_w = min(block_w, W)
    assert S % block_s == 0 and W % block_w == 0, (S, W, block_s, block_w)

    kernel = functools.partial(_rglru_kernel, block_s=block_s)
    return pl.pallas_call(
        kernel,
        grid=(B, W // block_w, S // block_s),
        in_specs=[
            pl.BlockSpec((1, block_s, block_w), lambda b, w, s: (b, s, w)),
            pl.BlockSpec((1, block_s, block_w), lambda b, w, s: (b, s, w)),
        ],
        out_specs=pl.BlockSpec((1, block_s, block_w), lambda b, w, s: (b, s, w)),
        out_shape=jax.ShapeDtypeStruct((B, S, W), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_w,), jnp.float32)],
        interpret=interpret,
    )(x, log_a)
