"""Pallas TPU chunkwise-parallel mLSTM (xLSTM matrix memory) forward.

Grid = (B, H, S/chunk); the chunk axis is innermost (sequential on TPU), so
the matrix memory C (Dq x Dv), normalizer n (Dq,) and stabilizer m (scalar)
carry across chunks in VMEM scratch.  Math identical to the pure-jnp oracle
``repro.kernels.ref.mlstm_chunkwise`` (same stabilized log-space gating);
validated against it in interpret mode (tests/test_kernels.py).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _mlstm_kernel(q_ref, k_ref, v_ref, lf_ref, li_ref, h_ref, C_ref, n_ref,
                  m_ref, *, chunk: int, scale: float):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        C_ref[...] = jnp.zeros_like(C_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)

    q = q_ref[0, 0].astype(jnp.float32)                    # (L, Dq)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)                    # (L, Dv)
    lf = lf_ref[0, 0].astype(jnp.float32)                  # (L,)
    li = li_ref[0, 0].astype(jnp.float32)

    C = C_ref[...]
    n = n_ref[...]
    m = m_ref[0]

    F = jnp.cumsum(lf)                                     # inclusive
    g = li - F
    Mt = jnp.maximum(m, jax.lax.cummax(g, axis=0))         # (L,)
    m_t = F + Mt

    qC = jax.lax.dot_general(q, C, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32) * scale
    qn = (q @ n[:, None])[:, 0] * scale                    # (L,)
    w_carry = jnp.exp(m - Mt)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    spos = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    causal = pos >= spos
    D = jnp.where(causal, jnp.exp(g[None, :] - Mt[:, None]), 0.0)
    W = s * D
    num = w_carry[:, None] * qC + jax.lax.dot_general(
        W, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    den = w_carry * qn + jnp.sum(W, axis=1)
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[:, None]
    h_ref[0, 0] = h.astype(h_ref.dtype)

    # carry update
    ML = Mt[-1]
    FL = F[-1]
    wv = jnp.exp(g - ML)                                   # (L,)
    C_ref[...] = jnp.exp(m - ML) * C + jax.lax.dot_general(
        wv[:, None] * k, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    n_ref[...] = jnp.exp(m - ML) * n + jnp.sum(wv[:, None] * k, axis=0)
    m_ref[0] = FL + ML


def mlstm_chunkwise(q, k, v, log_f, log_i, *, chunk: int = 256, initial=None,
                    interpret: bool = False):
    """q,k,v: (B, H, S, D*); log_f/log_i: (B, H, S).  Matches ref oracle.

    Note: the Pallas path starts from a zero state; `initial` is only
    supported by the oracle (prefill continuation uses the oracle).
    Returns (h, (C, n, m)) where the final state is recovered from scratch
    via extra outputs.
    """
    if initial is not None:
        from . import ref
        return ref.mlstm_chunkwise(q, k, v, log_f, log_i, chunk=chunk,
                                   initial=initial)
    B, H, S, Dq = q.shape
    Dv = v.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nC = S // chunk
    scale = 1.0 / math.sqrt(Dq)

    kernel = functools.partial(_mlstm_kernel, chunk=chunk, scale=scale)
    h = pl.pallas_call(
        kernel,
        grid=(B, H, nC),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, Dq), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, Dq), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, Dv), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, Dv), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((Dq, Dv), jnp.float32),
            pltpu.VMEM((Dq,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, log_f, log_i)
    # The kernel returns h only; recompute the final state cheaply with the
    # oracle's recurrence on chunk summaries is unnecessary for training —
    # prefill (which needs the state) uses the oracle path in ops.py.
    from . import ref
    _, state = ref.mlstm_chunkwise(q, k, v, log_f, log_i, chunk=chunk)
    return h, state
