"""Bit-packed cluster-state layout + packed PAC/downtime evaluation math.

The Monte Carlo engines' dominant state is boolean rank-space tiles —
up/full masks over n nodes per (trial, partition) lane.  This module packs
the node axis into uint32 words (n=155 -> five words per lane) and
re-states every per-step protocol predicate as mask-AND + popcount /
lowest-set-bit arithmetic over those words.  Packing is *layout only*: all
outputs are bit-identical to the boolean implementations in pac_np.py /
ref.py / pac_eval.py — the invariant docs/ARCHITECTURE.md states and
tests/test_bitpack.py pins property-style.

Written once over an ``xp`` array namespace (numpy or jax.numpy) and —
deliberately — over *lists of word planes* rather than a stacked word
axis, so the exact same functions run

  * host-side numpy (backend="numpy" engines),
  * inside jit/lax.scan (backend="jax"),
  * inside the fused Pallas megakernel body (kernels/fused_step.py), where
    each plane is a (block_t, block_p) tile slice and every constant below
    folds into the kernel as an immediate.

Everything is integer/bit math (shifts, ANDs, SWAR popcount, two's-
complement lowest-set-bit), so cross-backend equality is exact, never
approximate.  This module never imports jax: the numpy event engine and
pac_np.py stay jax-import-free.
"""
from __future__ import annotations

WORD_BITS = 32

_M1 = 0x55555555
_M2 = 0x33333333
_M4 = 0x0F0F0F0F
_H01 = 0x01010101


def n_words(n_bits: int) -> int:
    """Words needed to hold n_bits lanes (ceil division)."""
    return -(-n_bits // WORD_BITS)


def popcount32(v, xp):
    """SWAR popcount of a uint32 array -> int32 counts.

    Three masked shift-adds + one multiply-shift — no lookup tables, no
    dtype casts beyond the final int32, safe inside a Pallas kernel body.
    (numpy 2.x has bitwise_count and jax has lax.population_count, but a
    single shared implementation is what keeps all call sites provably
    identical.)  Array arithmetic wraps mod 2^32 silently in both
    namespaces, which is exactly what the final multiply wants.
    """
    v = v - ((v >> xp.uint32(1)) & xp.uint32(_M1))
    v = (v & xp.uint32(_M2)) + ((v >> xp.uint32(2)) & xp.uint32(_M2))
    v = (v + (v >> xp.uint32(4))) & xp.uint32(_M4)
    return ((v * xp.uint32(_H01)) >> xp.uint32(24)).astype(xp.int32)


def prefix_masks(count: int, n_bits: int):
    """Per-word uint32 masks selecting the first `count` of n_bits lanes.

    Returned as a tuple of python ints so they weave into any context —
    numpy, jnp, or a Pallas kernel body — as compile-time constants (the
    packed kernels need no `valid` input tensor, unlike the boolean ones).
    """
    W = n_words(n_bits)
    full, rem = divmod(min(count, n_bits), WORD_BITS)
    masks = [0xFFFFFFFF] * full + [0] * (W - full)
    if full < W and rem:
        masks[full] = (1 << rem) - 1
    return tuple(masks)


def pack_words(bools, xp):
    """(..., n) bool -> (..., W) uint32, bit b of word k = lane 32k+b.

    Lanes beyond n (the top word's padding bits) are zero.  Vectorized —
    one reshape + shift + sum — so the per-step pack in the engines is a
    single fused XLA op under jit.
    """
    n = bools.shape[-1]
    W = n_words(n)
    pad = W * WORD_BITS - n
    b = bools.astype(xp.uint32)
    if pad:
        b = xp.concatenate(
            [b, xp.zeros(b.shape[:-1] + (pad,), dtype=xp.uint32)], axis=-1)
    b = b.reshape(b.shape[:-1] + (W, WORD_BITS))
    shifts = xp.arange(WORD_BITS, dtype=xp.uint32)
    return xp.sum(b << shifts, axis=-1, dtype=xp.uint32)


def unpack_words(words, n_bits: int, xp):
    """(..., W) uint32 -> (..., n_bits) bool — pack_words' exact inverse."""
    shifts = xp.arange(WORD_BITS, dtype=xp.uint32)
    bits = (words[..., None] >> shifts) & xp.uint32(1)
    flat = bits.reshape(bits.shape[:-2] + (-1,))
    return flat[..., :n_bits] != 0


def _mask_planes(planes, masks, xp):
    return [w & xp.uint32(m) for w, m in zip(planes, masks)]


def _popcount_sum(planes, xp):
    total = popcount32(planes[0], xp)
    for w in planes[1:]:
        total = total + popcount32(w, xp)
    return total


def _any_bit(planes, xp):
    acc = planes[0]
    for w in planes[1:]:
        acc = acc | w
    return acc != xp.uint32(0)


def lowest_set_bits(planes, k: int, xp):
    """Keep the k lowest set bits across a word-plane list (lane order).

    This is the packed form of ``up & (cumsum(up) <= rf)`` — the
    cluster-replica mask of the first rf *up* nodes in succession order.
    k rounds of two's-complement lowest-set-bit extraction (lsb =
    v & (~v + 1), clear via v & (v - 1)), each round walking the words in
    order and taking from the first non-empty one.  k and the word count
    are small static ints, so this unrolls to pure elementwise VPU work.
    """
    v = list(planes)
    taken = [xp.zeros_like(w) for w in v]
    for _ in range(k):
        done = None
        for i, w in enumerate(v):
            nz = w != xp.uint32(0)
            pick = nz if done is None else (nz & ~done)
            lsb = w & ((~w) + xp.uint32(1))
            taken[i] = xp.where(pick, taken[i] | lsb, taken[i])
            v[i] = xp.where(pick, w & (w - xp.uint32(1)), w)
            done = nz if done is None else (done | nz)
    return taken


def select_bit(planes, rank, xp):
    """Bit `rank` across a word-plane list -> int32 0/1 per element.

    rank: int32 array (any shape matching the planes).  The word is picked
    by a one-hot compare-sum over the (static, small) word list — no
    gather — then shifted down by rank mod 32.  Out-of-range ranks (>=
    32*W) select no word and return 0, matching how the boolean
    implementations' masked tiles read padding lanes as False.
    """
    widx = rank // WORD_BITS
    word = xp.zeros_like(planes[0])
    for kk, w in enumerate(planes):
        word = xp.where(widx == kk, w, word)
    bit = (rank % WORD_BITS).astype(xp.uint32)
    return ((word >> bit) & xp.uint32(1)).astype(xp.int32)


def pac_eval_packed(up_words, full_words, *, rf: int, voters: int,
                    n_real: int, xp):
    """Packed-word PAC — bit-identical to pac_np.pac_eval_rank_np.

    up_words/full_words: length-W lists of identically-shaped uint32
    arrays (word k, bit b = succession rank 32k+b).  Lanes >= n_real are
    masked by compile-time prefix masks.  Returns (lark, maj,
    creps_words) with lark/maj bool of the plane shape and creps_words a
    length-W list of uint32 planes.
    """
    W = len(up_words)
    n_pad = W * WORD_BITS
    u = _mask_planes(up_words, prefix_masks(n_real, n_pad), xp)
    f = _mask_planes(full_words, prefix_masks(n_real, n_pad), xp)
    n_up = _popcount_sum(u, xp)
    majority = 2 * n_up > n_real
    any_roster = _any_bit(_mask_planes(u, prefix_masks(rf, n_pad), xp), xp)
    full_up = _any_bit([a & b for a, b in zip(u, f)], xp)
    lark = majority & any_roster & full_up
    nv = _popcount_sum(_mask_planes(u, prefix_masks(voters, n_pad), xp), xp)
    maj = 2 * nv > voters
    creps = lowest_set_bits(u, rf, xp)
    return lark, maj, creps


def downtime_eval_packed(up_words, full_words, *, rf: int, n_real: int,
                         roster=None, want_repmask: bool = False,
                         want_rleader: bool = False, xp):
    """Packed-word §6 per-step eval — bit-identical to
    pac_np.downtime_eval_rank_np.

    Same word-plane contract as pac_eval_packed.  roster, optional: a
    length-rf list of int32 rank arrays (plane-shaped) — the
    reconfiguring baseline's carried replica-set ranks; qmaj/nrep are
    then evaluated over those ranks (select_bit per slot) instead of the
    first-rf prefix mask.  Returns (lark, qmaj, leader, leader_full,
    nrep, *extras, creps_words).

    The protocol-zoo extras land between nrep and creps:
      want_repmask  int32 bitmask of the first-rf lanes' up bits — the
                    Hermes membership view; free in the packed layout
                    (the mask is word 0 under the rf prefix mask, rf <=
                    30 < 32 by StepSpec validation).
      want_rleader  int32 minimum up roster rank (n_real sentinel) — the
                    Spinnaker electable leader; requires roster and rides
                    the same select_bit pass as nrep.

    The leader scan folds three boolean-tile reductions into one pass:
    the first non-empty word's lowest set bit gives the leader's rank
    (32k + popcount(lsb - 1)) and, tested against the full word, the
    leader-holds-latest-copy bit — no lane iota, no (.., n) broadcast.
    """
    if want_rleader and roster is None:
        raise ValueError("rleader needs a roster (it elects among "
                         "roster members)")
    W = len(up_words)
    n_pad = W * WORD_BITS
    u = _mask_planes(up_words, prefix_masks(n_real, n_pad), xp)
    f = _mask_planes(full_words, prefix_masks(n_real, n_pad), xp)
    n_up = _popcount_sum(u, xp)
    majority = 2 * n_up > n_real
    any_roster = _any_bit(_mask_planes(u, prefix_masks(rf, n_pad), xp), xp)
    full_up = _any_bit([a & b for a, b in zip(u, f)], xp)
    lark = majority & any_roster & full_up

    rleader = None
    if roster is None:
        nrep = _popcount_sum(
            _mask_planes(u, prefix_masks(rf, n_pad), xp), xp)
    else:
        if want_rleader:
            rleader = xp.full(u[0].shape, n_real, dtype=xp.int32)
        nrep = xp.zeros(u[0].shape, dtype=xp.int32)
        for r in roster:
            bit = select_bit(u, r, xp)
            nrep = nrep + bit
            if want_rleader:
                rleader = xp.minimum(
                    rleader, xp.where(bit > 0, r.astype(xp.int32),
                                      xp.int32(n_real)))
    qmaj = 2 * nrep > rf

    leader = xp.full(u[0].shape, n_pad, dtype=xp.int32)
    leader_full = xp.zeros(u[0].shape, dtype=bool)
    done = None
    for k in range(W):
        w = u[k]
        nz = w != xp.uint32(0)
        lsb = w & ((~w) + xp.uint32(1))
        tz = popcount32(lsb - xp.uint32(1), xp)
        pick = nz if done is None else (nz & ~done)
        leader = xp.where(pick, xp.int32(WORD_BITS * k) + tz, leader)
        leader_full = xp.where(pick, (f[k] & lsb) != xp.uint32(0),
                               leader_full)
        done = nz if done is None else (done | nz)
    leader = xp.minimum(leader, xp.int32(n_real))

    extras = ()
    if want_repmask:
        repmask = (u[0] & xp.uint32((1 << rf) - 1)).astype(xp.int32)
        extras = extras + (repmask,)
    if want_rleader:
        extras = extras + (rleader,)

    creps = lowest_set_bits(u, rf, xp)
    return (lark, qmaj, leader, leader_full, nrep) + extras + (creps,)


def packed_state_bytes(B: int, P: int, n_pad: int) -> int:
    """Carried holder-mask bytes at (B, W, P) uint32 vs (B, P, n_pad) bool —
    the memory-capacity half of the megakernel story (ROADMAP's
    million-trial grids per device): n=155 packs 5 words against 155+
    bool bytes, a ~7.8x reduction of the engine's dominant carry."""
    return B * n_words(n_pad) * P * 4
