"""Pure-jnp oracles for every Pallas kernel (and the canonical impls used on CPU).

Contents
  attention_ref      dense softmax attention (flash_attention oracle)
  mlstm_chunkwise    xLSTM matrix-memory, chunk-parallel (mlstm_chunk oracle)
  mlstm_step         single-step mLSTM recurrence (decode)
  rglru_scan_ref     RG-LRU linear recurrence via associative scan
  rglru_step             single-step RG-LRU (decode)
  pac_eval_ref           PAC availability over (partitions x nodes) masks
  pac_eval_rank_ref      rank-space PAC tile (oracle for kernels/pac_eval.py)
  downtime_eval_rank_ref rank-space per-step protocol eval for the §6
                         downtime engine (PAC + quorum-log replica set +
                         acting leader)
  rebuild_node_counts_ref per-node in-flight rebuild counts (oracle for
                         the bandwidth-contended rebuild reduction)
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG = -1e30


# ---------------------------------------------------------------------------
# Flash-attention oracle: plain dense softmax attention (small shapes only).
# ---------------------------------------------------------------------------

def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  scale: Optional[float] = None) -> jax.Array:
    """q (B,Sq,H,D), k/v (B,Sk,H,D) — same head count (no GQA grouping here)."""
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    qp, kp = jnp.arange(Sq)[:, None], jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kp <= qp + (Sk - Sq)   # right-aligned when Sq < Sk
    if window:
        mask &= kp > qp + (Sk - Sq) - window
    s = jnp.where(mask[None, None], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)).astype(q.dtype)


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix memory with exponential gating, stabilized)
# ---------------------------------------------------------------------------

def mlstm_chunkwise(q, k, v, log_f, log_i, *, chunk: int = 256,
                    initial: Optional[Tuple] = None):
    """Chunk-parallel mLSTM forward.

    q,k,v: (B, H, S, Dq|Dv); log_f/log_i: (B, H, S) log-space gates
    (log_f = logsigmoid(f_raw)).  Returns (h (B,H,S,Dv), (C, n, m) final state)
    with C (B,H,Dq,Dv), n (B,H,Dq), m (B,H).

    Math (per head; F_t local cumsum of log_f, g_s = log_i_s - F_s,
    M_t = max(m_prev, cummax g), m_t = F_t + M_t):
      h_t = [e^{m_prev - M_t} qC~ + sum_{s<=t} e^{g_s - M_t}(q.k_s) v_s]
            / max(|den_t|, e^{-m_t})
    """
    B, H, S, Dq = q.shape
    Dv = v.shape[-1]
    if S % chunk:
        pad = chunk - S % chunk
        q, k, v = (jnp.pad(a, [(0, 0), (0, 0), (0, pad), (0, 0)]) for a in (q, k, v))
        log_f = jnp.pad(log_f, [(0, 0), (0, 0), (0, pad)])           # f = 1
        log_i = jnp.pad(log_i, [(0, 0), (0, 0), (0, pad)],
                        constant_values=NEG)                          # i = 0
        Sp = S + pad
    else:
        Sp = S
    nC = Sp // chunk
    reshape = lambda a: a.reshape(B, H, nC, chunk, *a.shape[3:]).swapaxes(0, 2)
    qc, kc, vc = reshape(q), reshape(k), reshape(v)      # (nC, H, B, L, D)
    lfc = log_f.reshape(B, H, nC, chunk).swapaxes(0, 2)  # (nC, H, B, L)
    lic = log_i.reshape(B, H, nC, chunk).swapaxes(0, 2)

    if initial is None:
        C0 = jnp.zeros((B, H, Dq, Dv), jnp.float32)
        n0 = jnp.zeros((B, H, Dq), jnp.float32)
        m0 = jnp.full((B, H), NEG, jnp.float32)
    else:
        C0, n0, m0 = (x.astype(jnp.float32) for x in initial)

    scale = 1.0 / math.sqrt(Dq)

    def chunk_body(carry, xs):
        C, n, m = carry                                   # (B,H,Dq,Dv),(B,H,Dq),(B,H)
        qi, ki, vi, lf, li = xs                           # (H,B,L,*) / (H,B,L)
        qi, ki, vi = (a.swapaxes(0, 1).astype(jnp.float32) for a in (qi, ki, vi))
        lf = lf.swapaxes(0, 1).astype(jnp.float32)        # (B,H,L)
        li = li.swapaxes(0, 1).astype(jnp.float32)
        F = jnp.cumsum(lf, axis=-1)                       # inclusive
        g = li - F
        Mt = jnp.maximum(m[..., None], jax.lax.cummax(g, axis=g.ndim - 1))  # (B,H,L)
        m_t = F + Mt
        # inter-chunk (carry) contribution
        qCf = jnp.einsum("bhld,bhdv->bhlv", qi, C) * scale
        qnf = jnp.einsum("bhld,bhd->bhl", qi, n) * scale
        w_carry = jnp.exp(m[..., None] - Mt)              # (B,H,L)
        # intra-chunk
        sc = jnp.einsum("bhld,bhsd->bhls", qi, ki) * scale
        lpos = jnp.arange(chunk)
        causal = lpos[:, None] >= lpos[None, :]
        D = jnp.where(causal[None, None], jnp.exp(g[:, :, None, :] - Mt[..., None]), 0.0)
        W = sc * D
        num = w_carry[..., None] * qCf + jnp.einsum("bhls,bhsv->bhlv", W, vi)
        den = w_carry * qnf + jnp.sum(W, axis=-1)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # carry update
        ML = Mt[..., -1]                                  # (B,H)
        FL = F[..., -1]
        wv = jnp.exp(g - ML[..., None])                   # (B,H,L)
        C_new = jnp.exp(m - ML)[..., None, None] * C + \
            jnp.einsum("bhld,bhlv->bhdv", wv[..., None] * ki, vi)
        n_new = jnp.exp(m - ML)[..., None] * n + jnp.sum(wv[..., None] * ki, axis=-2)
        m_new = FL + ML
        return (C_new, n_new, m_new), h.swapaxes(0, 1)    # back to (H,B,L,Dv)

    (Cf, nf, mf), hs = jax.lax.scan(chunk_body, (C0, n0, m0), (qc, kc, vc, lfc, lic))
    h = hs.swapaxes(0, 2).reshape(B, H, Sp, Dv)[:, :, :S]
    return h.astype(q.dtype), (Cf, nf, mf)


def mlstm_step(q, k, v, log_f, log_i, state):
    """Single decode step.  q/k/v (B,H,D*); log_f/log_i (B,H); state (C,n,m)."""
    C, n, m = state
    Dq = q.shape[-1]
    scale = 1.0 / math.sqrt(Dq)
    qf, kf, vf = (a.astype(jnp.float32) for a in (q, k, v))
    m_new = jnp.maximum(log_f + m, log_i)
    wf = jnp.exp(log_f + m - m_new)
    wi = jnp.exp(log_i - m_new)
    C_new = wf[..., None, None] * C + wi[..., None, None] * (kf[..., :, None] * vf[..., None, :])
    n_new = wf[..., None] * n + wi[..., None] * kf
    num = jnp.einsum("bhd,bhdv->bhv", qf, C_new) * scale
    den = jnp.einsum("bhd,bhd->bh", qf, n_new) * scale
    h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_new))[..., None]
    return h.astype(q.dtype), (C_new, n_new, m_new)


# ---------------------------------------------------------------------------
# RG-LRU (Griffin/RecurrentGemma)
# ---------------------------------------------------------------------------

def rglru_scan_ref(x, log_a):
    """h_t = a_t * h_{t-1} + sqrt(1-a_t^2) * x_t  via associative scan.

    x (B, S, W) gated input; log_a (B, S, W) (negative).  Returns h (B,S,W) f32.
    """
    a = jnp.exp(log_a.astype(jnp.float32))
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a.astype(jnp.float32)), 0.0)) \
        * x.astype(jnp.float32)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_step(x, log_a, h):
    """One step: x/log_a (B, W); h (B, W) f32 carry."""
    a = jnp.exp(log_a.astype(jnp.float32))
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 0.0)) * x.astype(jnp.float32)
    return a * h + b


# ---------------------------------------------------------------------------
# PAC evaluation (the §5.1 availability hot loop)
# ---------------------------------------------------------------------------

def pac_eval_rank_ref(up_succ, full_succ, *, rf: int, voters: int,
                      n_real: int):
    """Succession-rank-space PAC (oracle for kernels/pac_eval.py).

    up_succ/full_succ: (P, n_pad) bool where column i of row p refers to the
    node of rank i in partition p's succession list; columns >= n_real are
    padding.  Returns (lark_simple_majority, maj_baseline, cluster_replicas).
    """
    valid = (jnp.arange(up_succ.shape[1]) < n_real)[None, :]
    up = up_succ & valid
    full = full_succ & valid
    n_up = jnp.sum(up, axis=1)
    majority = 2 * n_up > n_real
    any_roster = jnp.any(up[:, :rf], axis=1)
    full_up = jnp.any(full & up, axis=1)
    lark = majority & any_roster & full_up
    maj = 2 * jnp.sum(up[:, :voters], axis=1) > voters
    rank = jnp.cumsum(up.astype(jnp.int32), axis=1)
    creps = up & (rank <= rf)
    return lark, maj, creps


def downtime_eval_rank_ref(up_succ, full_succ, *, rf: int, n_real: int,
                           roster=None, want_repmask: bool = False,
                           want_rleader: bool = False):
    """Pure-jnp oracle of kernels.pac_np.downtime_eval_rank_np (§6 downtime
    engine per-step evaluation) — see that function for the contract,
    including the optional (R, rf) `roster` of replica-set ranks for the
    reconfiguring baseline and the protocol-zoo extras (want_repmask →
    Hermes membership bitmask, want_rleader → Spinnaker electable-leader
    rank; both inserted before creps).  All outputs are comparisons/
    cumsums over the same masked tiles, so the two implementations (and
    the Pallas kernel) are bit-identical."""
    n_pad = up_succ.shape[1]
    valid = (jnp.arange(n_pad) < n_real)[None, :]
    up = up_succ & valid
    full = full_succ & valid
    lark, qmaj, creps = pac_eval_rank_ref(up_succ, full_succ, rf=rf,
                                          voters=rf, n_real=n_real)
    if roster is None:
        nrep = jnp.sum(up[:, :rf], axis=1).astype(jnp.int32)
    else:
        nrep = jnp.sum(jnp.take_along_axis(up, roster, axis=1),
                       axis=1).astype(jnp.int32)
    qmaj = 2 * nrep > rf
    lanes = jnp.arange(n_pad, dtype=jnp.int32)
    leader = jnp.min(jnp.where(up, lanes[None, :], jnp.int32(n_pad)),
                     axis=1).astype(jnp.int32)
    leader = jnp.minimum(leader, jnp.int32(n_real))
    leader_full = jnp.any((full & up) & (lanes[None, :] == leader[:, None]),
                          axis=1)
    extras = ()
    if want_repmask:
        bits = jnp.int32(1) << jnp.arange(rf, dtype=jnp.int32)
        repmask = jnp.sum(up[:, :rf].astype(jnp.int32) * bits[None, :],
                          axis=1).astype(jnp.int32)
        extras = extras + (repmask,)
    if want_rleader:
        if roster is None:
            raise ValueError("rleader needs a roster (it elects among "
                             "roster members)")
        rup = jnp.take_along_axis(up, roster, axis=1)
        rleader = jnp.min(jnp.where(rup, roster.astype(jnp.int32),
                                    jnp.int32(n_real)), axis=1) \
            .astype(jnp.int32)
        extras = extras + (rleader,)
    return (lark, qmaj, leader, leader_full, nrep) + extras + (creps,)


def rebuild_node_counts_ref(recruit, active, *, n_real: int):
    """Pure-jnp oracle of pac_np.rebuild_node_counts_np: (B, P) recruit
    node ids + active mask -> (B, n_real) int32 per-node in-flight rebuild
    counts.  A row-wise scatter-add — it reduces across *partitions* of
    one trial, never across trials, which is why the downtime engine's
    bandwidth model still commutes with trials-axis sharding."""
    ok = active & (recruit >= 0) & (recruit < n_real)
    idx = jnp.clip(recruit, 0, n_real - 1)
    rows = jnp.arange(recruit.shape[0], dtype=jnp.int32)[:, None]
    counts = jnp.zeros((recruit.shape[0], n_real), dtype=jnp.int32)
    return counts.at[rows, idx].add(ok.astype(jnp.int32))


def pac_eval_ref(up, succ, full, rf: int, *, voters: Optional[int] = None,
                 conditions: Tuple[str, ...] = ("simple_majority",)):
    """Vectorized Partition Availability Conditions.

    up:   (n,) bool — node reachability (the cluster = all up nodes).
    succ: (P, n) int32 — succession lists (node ids by rendezvous rank).
    full: (P, n) bool — full[p, node] = node holds latest copy of all keys in p.
    rf:   replication factor (roster replicas = first rf of each succession list).
    voters: baseline quorum size (default 2*(rf-1)+1).

    Returns dict with per-partition bools: lark availability under the chosen
    condition set, each individual PAC condition, the majority baseline, and
    the (P, n) cluster-replica mask (first rf *up* nodes per succession list).
    """
    n = up.shape[0]
    P = succ.shape[0]
    up_succ = jnp.take(up, succ)                        # (P, n) up by rank
    roster_up = up_succ[:, :rf]                         # roster replicas present?
    n_up = jnp.sum(up)
    majority = n_up * 2 > n
    half = n_up * 2 == n

    full_succ = jnp.take_along_axis(full, succ, axis=1)  # full by rank
    any_full_up = jnp.any(full_succ & up_succ, axis=1)   # (P,)
    any_roster_up = jnp.any(roster_up, axis=1)
    all_roster_up = jnp.all(roster_up, axis=1)
    leader_up = up_succ[:, 0]

    missing = n - n_up
    cond = {
        "super_majority": jnp.broadcast_to(majority & (missing < rf), (P,)),
        "all_roster_replicas": all_roster_up,
        "simple_majority": majority & any_roster_up & any_full_up,
        "half_roster": half & leader_up & any_full_up,
    }
    lark = jnp.zeros((P,), bool)
    for c in conditions:
        lark = lark | cond[c]

    nv = voters if voters is not None else 2 * (rf - 1) + 1
    maj_baseline = jnp.sum(up_succ[:, :nv], axis=1) * 2 > nv

    # cluster replicas: first rf up nodes per succession list
    rank_up = jnp.cumsum(up_succ.astype(jnp.int32), axis=1)
    cr_in_succ = up_succ & (rank_up <= rf)              # (P, n) in rank space
    rows = jnp.arange(P)[:, None]
    cr_mask = jnp.zeros((P, n), bool).at[rows, succ].set(cr_in_succ)

    return {"lark": lark, "baseline": maj_baseline, "cluster_replicas": cr_mask,
            **cond}
