"""Pallas TPU PAC-evaluation kernel — the §5.1 availability hot loop.

Evaluates, for a block of partitions at a time (succession lists resident in
VMEM), LARK availability (SimpleMajority et al.), the majority baseline, and
the refreshed full-holder masks.  Pure VPU integer/boolean work on
(block_p, n) tiles; the node axis is padded to a lane multiple by ops.py.

Inputs are in succession-rank space: up_succ[p, i] = up[succ[p, i]],
full_succ likewise — the same layout the vectorized numpy engine uses, so
the Monte Carlo can call either implementation interchangeably.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import latency as _lat


def _pac_kernel(up_ref, full_ref, valid_ref, lark_ref, maj_ref, creps_ref, *,
                rf: int, voters: int, n_real: int):
    up = up_ref[...].astype(jnp.int32)            # (bp, n)
    full = full_ref[...].astype(jnp.int32)
    valid = valid_ref[...].astype(jnp.int32)      # 1 for real node columns
    up = up * valid
    full = full * valid

    lanes = jax.lax.broadcasted_iota(jnp.int32, up.shape, 1)
    n_up = jnp.sum(up, axis=1, keepdims=True)
    majority = (2 * n_up > n_real).astype(jnp.int32)

    roster_up = jnp.sum(jnp.where(lanes < rf, up, 0), axis=1, keepdims=True)
    any_roster = (roster_up > 0).astype(jnp.int32)
    full_up = (jnp.sum(full * up, axis=1, keepdims=True) > 0).astype(jnp.int32)

    lark = majority * any_roster * full_up
    lark_ref[...] = (lark[:, 0] > 0)

    voter_up = jnp.sum(jnp.where(lanes < voters, up, 0), axis=1, keepdims=True)
    maj_ref[...] = (2 * voter_up[:, 0] > voters)

    rank = jnp.cumsum(up, axis=1)
    creps = (up > 0) & (rank <= rf)
    creps_ref[...] = creps


def pac_eval(up_succ, full_succ, *, rf: int, voters: int, n_real: int,
             block_p: int = 256, interpret: bool = False):
    """up_succ/full_succ: (P, n_pad) bool.  Returns (lark, maj, creps)."""
    P, n_pad = up_succ.shape
    block_p = min(block_p, P)
    if P % block_p:
        raise ValueError(
            f"block_p={block_p} must tile the row count P={P} exactly — "
            "pick a candidate from ops.block_p_candidates(P, n_pad)")
    valid = (jnp.arange(n_pad) < n_real)[None, :].astype(jnp.bool_)
    valid = jnp.broadcast_to(valid, (block_p, n_pad))

    kernel = functools.partial(_pac_kernel, rf=rf, voters=voters,
                               n_real=n_real)
    lark, maj, creps = pl.pallas_call(
        kernel,
        grid=(P // block_p,),
        in_specs=[
            pl.BlockSpec((block_p, n_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_p, n_pad), lambda i: (i, 0)),
            pl.BlockSpec((block_p, n_pad), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_p,), lambda i: (i,)),
            pl.BlockSpec((block_p,), lambda i: (i,)),
            pl.BlockSpec((block_p, n_pad), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((P,), jnp.bool_),
            jax.ShapeDtypeStruct((P,), jnp.bool_),
            jax.ShapeDtypeStruct((P, n_pad), jnp.bool_),
        ],
        interpret=interpret,
    )(up_succ, full_succ, valid)
    return lark, maj, creps


def _downtime_kernel(up_ref, full_ref, valid_ref, *out_refs,
                     rf: int, n_real: int, want_repmask: bool = False):
    """PAC + quorum-log replica set + acting leader for one (bp, n) block —
    the §6 downtime engine's per-step evaluation (downtime_eval_rank_np is
    the contract; everything is integer/boolean VPU work, so outputs are
    bit-identical to the numpy and jnp implementations).  want_repmask
    adds the Hermes membership bitmask (bit j = first-rf lane j up) as an
    extra int32 row output between nrep and creps."""
    lark_ref, qmaj_ref, leader_ref, lfull_ref, nrep_ref = out_refs[:5]
    creps_ref = out_refs[-1]
    up = up_ref[...].astype(jnp.int32)            # (bp, n)
    full = full_ref[...].astype(jnp.int32)
    valid = valid_ref[...].astype(jnp.int32)
    up = up * valid
    full = full * valid

    lanes = jax.lax.broadcasted_iota(jnp.int32, up.shape, 1)
    n_up = jnp.sum(up, axis=1, keepdims=True)
    majority = (2 * n_up > n_real).astype(jnp.int32)
    nrep = jnp.sum(jnp.where(lanes < rf, up, 0), axis=1)          # (bp,)
    any_roster = (nrep[:, None] > 0).astype(jnp.int32)
    full_up = (jnp.sum(full * up, axis=1, keepdims=True) > 0).astype(jnp.int32)
    lark_ref[...] = ((majority * any_roster * full_up)[:, 0] > 0)

    qmaj_ref[...] = (2 * nrep > rf)
    nrep_ref[...] = nrep

    leader = jnp.min(jnp.where(up > 0, lanes, up.shape[1]), axis=1)
    leader = jnp.minimum(leader, n_real).astype(jnp.int32)
    leader_ref[...] = leader
    lfull_ref[...] = (jnp.sum(
        jnp.where(lanes == leader[:, None], full * up, 0), axis=1) > 0)

    if want_repmask:
        # the shift is clamped so the dead branch of the where never
        # shifts past the int32 width (rf <= 30 by StepSpec validation)
        shift = jnp.minimum(lanes, rf)
        out_refs[5][...] = jnp.sum(
            jnp.where(lanes < rf, up << shift, 0), axis=1).astype(jnp.int32)

    rank = jnp.cumsum(up, axis=1)
    creps_ref[...] = (up > 0) & (rank <= rf)


def _downtime_roster_kernel(up_ref, full_ref, valid_ref, roster_ref,
                            *out_refs, rf: int, n_real: int,
                            want_repmask: bool = False,
                            want_rleader: bool = False):
    """Roster-aware variant of _downtime_kernel for the §6 reconfiguring
    quorum-log baseline: the replica set is the given per-row roster of
    succession ranks rather than the implicit first rf lanes.  The gather
    up[roster[j]] is a one-hot compare-and-sum per roster slot (rf is
    small and static), so the kernel stays pure VPU integer work and
    bit-identical to the numpy/jnp take_along_axis implementations.
    want_repmask / want_rleader add the protocol-zoo extras (Hermes
    first-rf membership bitmask; Spinnaker electable leader = minimum up
    roster rank, n_real sentinel) as int32 rows between nrep and creps."""
    lark_ref, qmaj_ref, leader_ref, lfull_ref, nrep_ref = out_refs[:5]
    creps_ref = out_refs[-1]
    k = 5
    repmask_ref = rleader_ref = None
    if want_repmask:
        repmask_ref = out_refs[k]
        k += 1
    if want_rleader:
        rleader_ref = out_refs[k]
    up = up_ref[...].astype(jnp.int32)            # (bp, n)
    full = full_ref[...].astype(jnp.int32)
    valid = valid_ref[...].astype(jnp.int32)
    roster = roster_ref[...]                      # (bp, rf_pad) int32
    up = up * valid
    full = full * valid

    lanes = jax.lax.broadcasted_iota(jnp.int32, up.shape, 1)
    n_up = jnp.sum(up, axis=1, keepdims=True)
    majority = (2 * n_up > n_real).astype(jnp.int32)
    roster_up = jnp.sum(jnp.where(lanes < rf, up, 0), axis=1, keepdims=True)
    any_roster = (roster_up > 0).astype(jnp.int32)
    full_up = (jnp.sum(full * up, axis=1, keepdims=True) > 0).astype(jnp.int32)
    lark_ref[...] = ((majority * any_roster * full_up)[:, 0] > 0)

    # replica-set up-count over the carried roster ranks (only the first
    # rf roster columns are real; the rest is lane padding, never read) —
    # the same one-hot pass also elects the minimum up roster rank
    nrep = jnp.zeros(up.shape[:1], dtype=jnp.int32)
    rlead = jnp.full(up.shape[:1], n_real, dtype=jnp.int32)
    for j in range(rf):
        member = roster[:, j:j + 1]               # (bp, 1)
        mem_up = jnp.sum(jnp.where(lanes == member, up, 0), axis=1)
        nrep = nrep + mem_up
        if want_rleader:
            rlead = jnp.minimum(rlead, jnp.where(mem_up > 0, member[:, 0],
                                                 n_real))
    qmaj_ref[...] = (2 * nrep > rf)
    nrep_ref[...] = nrep
    if want_rleader:
        rleader_ref[...] = rlead.astype(jnp.int32)

    leader = jnp.min(jnp.where(up > 0, lanes, up.shape[1]), axis=1)
    leader = jnp.minimum(leader, n_real).astype(jnp.int32)
    leader_ref[...] = leader
    lfull_ref[...] = (jnp.sum(
        jnp.where(lanes == leader[:, None], full * up, 0), axis=1) > 0)

    if want_repmask:
        shift = jnp.minimum(lanes, rf)
        repmask_ref[...] = jnp.sum(
            jnp.where(lanes < rf, up << shift, 0), axis=1).astype(jnp.int32)

    rank = jnp.cumsum(up, axis=1)
    creps_ref[...] = (up > 0) & (rank <= rf)


def _node_count_kernel(rec_ref, act_ref, cnt_ref, *, P: int):
    """Per-node in-flight rebuild counts for one (block_b, P) tile of
    recruit node ids — the §6 bandwidth-contended rebuild reduction.
    cnt[b, node] = #{p : act[b, p] and rec[b, p] == node}.  A fori_loop of
    (block_b, n_lanes) one-hot compare-accumulates over the partition
    columns: pure VPU integer work with no scatter, so the result is
    bit-identical to the numpy/jnp scatter-add implementations.  Ids
    outside [0, n_lanes) never match a lane and ids in [n_real, n_lanes)
    land in padding columns the wrapper slices off — both vanish, exactly
    as the other backends mask them."""
    block_b, n_lanes = cnt_ref.shape
    lanes = jax.lax.broadcasted_iota(jnp.int32, (block_b, n_lanes), 1)

    def body(j, cnt):
        rec_j = rec_ref[:, pl.ds(j, 1)]               # (block_b, 1)
        act_j = act_ref[:, pl.ds(j, 1)].astype(jnp.int32)
        return cnt + jnp.where(lanes == rec_j, act_j, 0)

    cnt_ref[...] = jax.lax.fori_loop(
        0, P, body, jnp.zeros((block_b, n_lanes), dtype=jnp.int32))


def _node_count_block_b(B: int) -> int:
    """Largest power-of-two row-block <= 8 that divides the trial count
    (trials per device are small; 8 keeps the (block_b, P) tile under the
    VMEM budget at the paper's P=4096)."""
    bb = 1
    while bb < 8 and B % (bb * 2) == 0:
        bb *= 2
    return bb


def node_count(recruit, active, *, n_real: int, interpret: bool = False,
               block_b: int = 0):
    """recruit (B, P) int32 node ids, active (B, P) bool ->
    (B, n_lanes) int32 per-node counts (columns >= n_real are padding the
    caller slices off; see ops.rebuild_node_counts)."""
    B, P = recruit.shape
    n_lanes = n_real + (-n_real % 128)
    ppad = -P % 128                    # partition axis to a lane multiple
    if ppad:
        # pad columns carry an id no lane matches and are inactive anyway
        recruit = jnp.pad(recruit, ((0, 0), (0, ppad)),
                          constant_values=n_lanes)
        active = jnp.pad(active, ((0, 0), (0, ppad)))
    block_b = block_b or _node_count_block_b(B)
    if B % block_b:
        raise ValueError(f"block_b={block_b} must tile the trial count "
                         f"B={B} exactly")
    kernel = functools.partial(_node_count_kernel, P=P + ppad)
    return pl.pallas_call(
        kernel,
        grid=(B // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, P + ppad), lambda i: (i, 0)),
            pl.BlockSpec((block_b, P + ppad), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, n_lanes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, n_lanes), jnp.int32),
        interpret=interpret,
    )(recruit.astype(jnp.int32), active)


def _latency_kernel(dirty_ref, decay_ref, kf_ref, avail_ref, qok_ref,
                    rem_ref, dt_ref, lamw_ref, ndirty_ref, dup_ref,
                    qhist_ref, qslo_ref, qsum_ref, *, nbins: int,
                    slo_ticks: int):
    """Client-latency interval charges for one (block_r, ...) tile of
    flattened (trial, partition) rows — the §6 per-key request layer's
    post-step op.  Purely elementwise float32/int32 work via the shared
    kernels/latency.py math (the decay factors arrive precomputed), so
    the outputs are bit-identical to the numpy/jnp reference — see that
    module's bit-identity contract."""
    dirty = dirty_ref[...]                        # (br, nbl) f32
    decay = decay_ref[...]
    kf = kf_ref[...]                              # (1, nbl) f32
    avail = avail_ref[...][:, None]               # (br, 1) bool
    nd, dup = _lat.dirty_step(dirty, decay, avail, kf, jnp)
    ndirty_ref[...] = nd
    dup_ref[...] = dup

    rem = rem_ref[...][:, None]                   # (br, 1) i32
    dt = dt_ref[...][:, None]
    qok = qok_ref[...][:, None]
    lamw = lamw_ref[...][:, None]                 # (br, 1) f32
    lanes = jax.lax.broadcasted_iota(jnp.int32, qhist_ref.shape, 1)
    qh, qs, qq = _lat.quorum_step(rem, dt, qok, lamw, lanes, nbins=nbins,
                                  slo_ticks=slo_ticks, xp=jnp)
    qhist_ref[...] = qh
    qslo_ref[...] = qs[:, 0]
    qsum_ref[...] = qq[:, 0]


def latency_charge(dirty, decay, avail, qok, rem, dt, lamw, kf, *,
                   nbins: int, slo_ticks: int, block_r: int = 256,
                   interpret: bool = False):
    """dirty/decay (R, NB) f32, avail/qok (R,) bool, rem/dt (R,) i32,
    lamw (R,) f32, kf (NB,) f32 -> (new_dirty, dup, qhist, qslo, qsum)
    with qhist (R, nbins).  Rows are flattened (trial, partition) pairs;
    the bucket axes are padded to VPU lane multiples (padding lanes carry
    kf=0 / lanes >= nbins and yield exact zeros, sliced off here)."""
    R, NB = dirty.shape
    nbl = NB + (-NB % 128)
    hbl = nbins + (-nbins % 128)
    block_r = min(block_r, R)
    rpad = -R % block_r
    if nbl > NB:
        dirty = jnp.pad(dirty, ((0, 0), (0, nbl - NB)))
        decay = jnp.pad(decay, ((0, 0), (0, nbl - NB)),
                        constant_values=1.0)
    if rpad:
        dirty = jnp.pad(dirty, ((0, rpad), (0, 0)))
        decay = jnp.pad(decay, ((0, rpad), (0, 0)), constant_values=1.0)
        avail = jnp.pad(avail, (0, rpad))
        qok = jnp.pad(qok, (0, rpad))
        rem = jnp.pad(rem, (0, rpad))
        dt = jnp.pad(dt, (0, rpad))
        lamw = jnp.pad(lamw, (0, rpad))
    kf2 = jnp.pad(kf.astype(jnp.float32), (0, nbl - NB))[None, :]
    Rp = R + rpad

    kernel = functools.partial(_latency_kernel, nbins=nbins,
                               slo_ticks=slo_ticks)
    row_spec = pl.BlockSpec((block_r,), lambda i: (i,))
    tile_spec = pl.BlockSpec((block_r, nbl), lambda i: (i, 0))
    nd, dup, qh, qs, qq = pl.pallas_call(
        kernel,
        grid=(Rp // block_r,),
        in_specs=[
            tile_spec, tile_spec,
            pl.BlockSpec((1, nbl), lambda i: (0, 0)),
            row_spec, row_spec, row_spec, row_spec, row_spec,
        ],
        out_specs=[
            tile_spec, tile_spec,
            pl.BlockSpec((block_r, hbl), lambda i: (i, 0)),
            row_spec, row_spec,
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Rp, nbl), jnp.float32),
            jax.ShapeDtypeStruct((Rp, nbl), jnp.float32),
            jax.ShapeDtypeStruct((Rp, hbl), jnp.float32),
            jax.ShapeDtypeStruct((Rp,), jnp.float32),
            jax.ShapeDtypeStruct((Rp,), jnp.float32),
        ],
        interpret=interpret,
    )(dirty, decay, kf2, avail, qok, rem.astype(jnp.int32),
      dt.astype(jnp.int32), lamw.astype(jnp.float32))
    return (nd[:R, :NB], dup[:R, :NB], qh[:R, :nbins], qs[:R], qq[:R])


def downtime_eval(up_succ, full_succ, *, rf: int, n_real: int,
                  block_p: int = 256, interpret: bool = False,
                  roster=None, want_repmask: bool = False,
                  want_rleader: bool = False):
    """up_succ/full_succ: (P, n_pad) bool.  Returns (lark, qmaj, leader,
    leader_full, nrep, *extras, creps) — see pac_np.downtime_eval_rank_np.

    roster (P, rf_pad) int32, optional: per-row replica-set ranks for the
    reconfiguring baseline (columns >= rf are lane padding).  qmaj/nrep
    are then evaluated over those ranks instead of the first rf lanes.

    want_repmask / want_rleader add the protocol-zoo int32 row outputs
    (Hermes membership bitmask; Spinnaker electable roster leader —
    requires roster) between nrep and creps, matching the numpy/jnp
    contracts bit-for-bit."""
    if want_rleader and roster is None:
        raise ValueError("rleader needs a roster (it elects among "
                         "roster members)")
    P, n_pad = up_succ.shape
    block_p = min(block_p, P)
    if P % block_p:
        raise ValueError(
            f"block_p={block_p} must tile the row count P={P} exactly — "
            "pick a candidate from ops.block_p_candidates(P, n_pad)")
    valid = (jnp.arange(n_pad) < n_real)[None, :].astype(jnp.bool_)
    valid = jnp.broadcast_to(valid, (block_p, n_pad))

    row_spec = pl.BlockSpec((block_p,), lambda i: (i,))
    tile_spec = pl.BlockSpec((block_p, n_pad), lambda i: (i, 0))
    in_specs = [tile_spec, tile_spec,
                pl.BlockSpec((block_p, n_pad), lambda i: (0, 0))]
    operands = [up_succ, full_succ, valid]
    if roster is None:
        kernel = functools.partial(_downtime_kernel, rf=rf, n_real=n_real,
                                   want_repmask=want_repmask)
    else:
        kernel = functools.partial(_downtime_roster_kernel, rf=rf,
                                   n_real=n_real,
                                   want_repmask=want_repmask,
                                   want_rleader=want_rleader)
        in_specs.append(pl.BlockSpec((block_p, roster.shape[1]),
                                     lambda i: (i, 0)))
        operands.append(roster)
    n_extra = int(want_repmask) + int(want_rleader and roster is not None)
    out_specs = [row_spec] * (5 + n_extra) + [tile_spec]
    out_shape = [
        jax.ShapeDtypeStruct((P,), jnp.bool_),
        jax.ShapeDtypeStruct((P,), jnp.bool_),
        jax.ShapeDtypeStruct((P,), jnp.int32),
        jax.ShapeDtypeStruct((P,), jnp.bool_),
        jax.ShapeDtypeStruct((P,), jnp.int32),
    ] + [jax.ShapeDtypeStruct((P,), jnp.int32)] * n_extra + [
        jax.ShapeDtypeStruct((P, n_pad), jnp.bool_),
    ]
    return pl.pallas_call(
        kernel,
        grid=(P // block_p,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*operands)
