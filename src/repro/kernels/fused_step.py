"""Fused Pallas step megakernel over bit-packed cluster state.

One ``pallas_call`` per Monte Carlo step evaluates a 2-D
(block_t x block_p) grid of (trials, partitions) tiles directly on packed
uint32 words — where the unfused path launches separate PAC/downtime,
roster-gather and node-count kernels over boolean (R, n) tiles, this
kernel reads each packed word once and emits every per-step output in a
single pass:

  * PAC (SimpleMajority) / majority-baseline / quorum-log predicates as
    mask-AND + SWAR-popcount over the word planes (kernels/bitpack.py —
    the same functions the numpy and jnp backends run, so bit-identity
    is by construction, not by parallel implementation);
  * the reconfiguring baseline's roster membership via one-hot word
    select + shift (no gather);
  * the acting-leader rank + latest-copy bit via a lowest-set-bit scan;
  * the refreshed cluster-replica words via rf rounds of lowest-set-bit
    extraction;
  * optionally, the per-(trial, node) in-flight rebuild counts for the
    bandwidth-contended rebuild model, accumulated *across the partition
    grid axis* into a (block_t, n_lanes) output block that is revisited
    by every partition tile of the same trial block (initialized at
    partition-grid index 0, per the standard Pallas accumulation
    pattern) — the reduction that previously cost its own kernel launch
    and an extra HBM round trip.

Array layout: packed state is (B, W, P) uint32 — partitions on the minor
(lane) axis, words on the sublane axis — so a (block_t, W, block_p) tile
is VPU-shaped with block_p a lane multiple, and the packed node axis
never occupies lanes (the boolean kernels pad n to 128 lanes; here five
words replace 256 bool lanes).  Rosters arrive as (B, rf, P) int32 and
recruit/active as (B, P).  Validity masking uses compile-time prefix-mask
constants, so there is no `valid` input tensor at all.

ops.step_eval dispatches here for StepSpec(packed=True) on the pallas
backend; block sizes come from ops.autotune_step_blocks (2-D fused
autotuner with fused-kernel VMEM accounting).  Interpret mode runs the
same kernel on CPU for the CI smoke rows and the bit-identity matrix.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import bitpack


def _check_tiles(B: int, P: int, block_t: int, block_p: int):
    if B % block_t:
        raise ValueError(
            f"block_t={block_t} must tile the trial count B={B} exactly — "
            "pick a candidate from ops.fused_block_candidates")
    if P % block_p:
        raise ValueError(
            f"block_p={block_p} must tile the partition count P={P} "
            "exactly — pick a candidate from ops.fused_block_candidates")


def _fused_pac_kernel(upw_ref, fullw_ref, lark_ref, maj_ref, crepsw_ref, *,
                      rf: int, voters: int, n_real: int, W: int):
    upw = upw_ref[...]                         # (bt, W, bp) uint32
    fullw = fullw_ref[...]
    u = [upw[:, k, :] for k in range(W)]
    f = [fullw[:, k, :] for k in range(W)]
    lark, maj, creps = bitpack.pac_eval_packed(
        u, f, rf=rf, voters=voters, n_real=n_real, xp=jnp)
    lark_ref[...] = lark
    maj_ref[...] = maj
    crepsw_ref[...] = jnp.stack(creps, axis=1)


def fused_pac_eval(upw, fullw, *, rf: int, voters: int, n_real: int,
                   block_t: int, block_p: int, interpret: bool = False):
    """upw/fullw: (B, W, P) uint32 packed rank-space state.  Returns
    (lark (B, P) bool, maj (B, P) bool, crepsw (B, W, P) uint32) — the
    packed image of kernels/pac_eval.pac_eval, bit for bit."""
    B, W, P = upw.shape
    block_t = min(block_t, B)
    block_p = min(block_p, P)
    _check_tiles(B, P, block_t, block_p)
    kernel = functools.partial(_fused_pac_kernel, rf=rf, voters=voters,
                               n_real=n_real, W=W)
    word_spec = pl.BlockSpec((block_t, W, block_p), lambda i, j: (i, 0, j))
    row_spec = pl.BlockSpec((block_t, block_p), lambda i, j: (i, j))
    return pl.pallas_call(
        kernel,
        grid=(B // block_t, P // block_p),
        in_specs=[word_spec, word_spec],
        out_specs=[row_spec, row_spec, word_spec],
        out_shape=[
            jax.ShapeDtypeStruct((B, P), jnp.bool_),
            jax.ShapeDtypeStruct((B, P), jnp.bool_),
            jax.ShapeDtypeStruct((B, W, P), jnp.uint32),
        ],
        interpret=interpret,
    )(upw, fullw)


def _node_count_block(rec, act, n_lanes: int, bp_cols: int):
    """(bt, bp) recruit ids + active mask -> (bt, n_lanes) int32 one-hot
    accumulation over this tile's partition columns (the same
    compare-and-sum loop as pac_eval._node_count_kernel, folded into the
    fused body).  Ids outside [0, n_lanes) match no lane; ids in
    [n_real, n_lanes) land in padding columns the wrapper slices off."""
    bt = rec.shape[0]
    lanes = jax.lax.broadcasted_iota(jnp.int32, (bt, n_lanes), 1)

    def body(j, cnt):
        rec_j = jax.lax.dynamic_slice_in_dim(rec, j, 1, axis=1)
        act_j = jax.lax.dynamic_slice_in_dim(act, j, 1, axis=1) \
            .astype(jnp.int32)
        return cnt + jnp.where(lanes == rec_j, act_j, 0)

    return jax.lax.fori_loop(
        0, bp_cols, body, jnp.zeros((bt, n_lanes), dtype=jnp.int32))


def _fused_downtime_kernel(refs, *, rf: int, n_real: int, W: int,
                           with_roster: bool, with_counts: bool,
                           with_repmask: bool, with_rleader: bool,
                           n_lanes: int, bp_cols: int):
    it = iter(refs)
    upw_ref, fullw_ref = next(it), next(it)
    roster_ref = next(it) if with_roster else None
    rec_ref, act_ref = (next(it), next(it)) if with_counts else (None, None)
    lark_ref, qmaj_ref, ldr_ref, lfull_ref, nrep_ref = \
        (next(it) for _ in range(5))
    repmask_ref = next(it) if with_repmask else None
    rleader_ref = next(it) if with_rleader else None
    crepsw_ref = next(it)
    cnt_ref = next(it) if with_counts else None

    upw = upw_ref[...]                         # (bt, W, bp) uint32
    fullw = fullw_ref[...]
    u = [upw[:, k, :] for k in range(W)]
    f = [fullw[:, k, :] for k in range(W)]
    roster = None
    if with_roster:
        rost = roster_ref[...]                 # (bt, rf, bp) int32
        roster = [rost[:, j, :] for j in range(rf)]
    outs = bitpack.downtime_eval_packed(
        u, f, rf=rf, n_real=n_real, roster=roster,
        want_repmask=with_repmask, want_rleader=with_rleader, xp=jnp)
    lark, qmaj, leader, lfull, nrep = outs[:5]
    creps = outs[-1]
    lark_ref[...] = lark
    qmaj_ref[...] = qmaj
    ldr_ref[...] = leader
    lfull_ref[...] = lfull
    nrep_ref[...] = nrep
    k = 5
    if with_repmask:
        repmask_ref[...] = outs[k]
        k += 1
    if with_rleader:
        rleader_ref[...] = outs[k]
    crepsw_ref[...] = jnp.stack(creps, axis=1)

    if with_counts:
        # counts accumulate across the (innermost, sequential) partition
        # grid axis: initialize at the first partition tile of each trial
        # block, then add this tile's one-hot contribution
        j_id = pl.program_id(1)

        @pl.when(j_id == 0)
        def _init():
            cnt_ref[...] = jnp.zeros(cnt_ref.shape, dtype=jnp.int32)

        cnt_ref[...] = cnt_ref[...] + _node_count_block(
            rec_ref[...].astype(jnp.int32), act_ref[...], n_lanes, bp_cols)


def fused_downtime_eval(upw, fullw, *, rf: int, n_real: int, block_t: int,
                        block_p: int, interpret: bool = False, roster=None,
                        recruit=None, active=None,
                        want_repmask: bool = False,
                        want_rleader: bool = False):
    """upw/fullw: (B, W, P) uint32.  Returns (lark, qmaj, leader,
    leader_full, nrep (all (B, P)), *extras, crepsw (B, W, P)[, counts
    (B, n_lanes)]) — the packed image of kernels/pac_eval.downtime_eval
    (+ node_count when recruit/active are given), in one pallas_call.

    roster (B, rf, P) int32, optional: the reconfiguring baseline's
    carried replica-set ranks, words-on-sublanes like the state.
    recruit (B, P) int32 + active (B, P) bool, optional (together): also
    emit the per-(trial, node) in-flight rebuild counts, accumulated
    across partition tiles; counts columns >= n_real are padding for the
    caller to slice (ops.step_eval does).
    want_repmask / want_rleader: protocol-zoo int32 (B, P) extras between
    nrep and crepsw (Hermes membership bitmask; Spinnaker electable
    roster leader — requires roster)."""
    if want_rleader and roster is None:
        raise ValueError("rleader needs a roster (it elects among "
                         "roster members)")
    B, W, P = upw.shape
    block_t = min(block_t, B)
    block_p = min(block_p, P)
    _check_tiles(B, P, block_t, block_p)
    with_roster = roster is not None
    with_counts = recruit is not None
    if with_counts and active is None:
        raise ValueError("recruit and active must be passed together")
    n_lanes = n_real + (-n_real % 128)

    word_spec = pl.BlockSpec((block_t, W, block_p), lambda i, j: (i, 0, j))
    row_spec = pl.BlockSpec((block_t, block_p), lambda i, j: (i, j))
    in_specs = [word_spec, word_spec]
    operands = [upw, fullw]
    if with_roster:
        in_specs.append(pl.BlockSpec((block_t, rf, block_p),
                                     lambda i, j: (i, 0, j)))
        operands.append(roster.astype(jnp.int32))
    if with_counts:
        in_specs += [row_spec, row_spec]
        operands += [recruit.astype(jnp.int32), active]
    n_extra = int(want_repmask) + int(want_rleader)
    out_specs = [row_spec] * (5 + n_extra) + [word_spec]
    out_shape = [
        jax.ShapeDtypeStruct((B, P), jnp.bool_),
        jax.ShapeDtypeStruct((B, P), jnp.bool_),
        jax.ShapeDtypeStruct((B, P), jnp.int32),
        jax.ShapeDtypeStruct((B, P), jnp.bool_),
        jax.ShapeDtypeStruct((B, P), jnp.int32),
    ] + [jax.ShapeDtypeStruct((B, P), jnp.int32)] * n_extra + [
        jax.ShapeDtypeStruct((B, W, P), jnp.uint32),
    ]
    if with_counts:
        # revisited across the partition grid axis (index map pins j -> 0)
        out_specs.append(pl.BlockSpec((block_t, n_lanes),
                                      lambda i, j: (i, 0)))
        out_shape.append(jax.ShapeDtypeStruct((B, n_lanes), jnp.int32))

    kernel = functools.partial(
        _fused_downtime_kernel, rf=rf, n_real=n_real, W=W,
        with_roster=with_roster, with_counts=with_counts,
        with_repmask=want_repmask, with_rleader=want_rleader,
        n_lanes=n_lanes, bp_cols=block_p)

    def kernel_splat(*refs):
        kernel(refs)

    return pl.pallas_call(
        kernel_splat,
        grid=(B // block_t, P // block_p),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(*operands)
