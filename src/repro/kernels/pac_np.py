"""Numpy rank-space PAC evaluation — the event engine's evaluate(),
factored out so the scalar Monte Carlo (core/availability.py) shares the
exact math with the batched backends in ops.py without importing jax.
"""
from __future__ import annotations

import numpy as np


def pac_eval_rank_np(up_succ, full_succ, *, rf: int, voters: int,
                     n_real: int):
    """(R, n_pad) bool tiles -> (lark (R,), maj (R,), creps (R, n_pad)).

    Columns >= n_real are padding.  Whole-cluster majority uses any row's
    up-count: each row of up_succ is a permutation of the same node set,
    so row sums all equal the cluster's up-count.
    """
    up = np.asarray(up_succ, dtype=bool)
    full = np.asarray(full_succ, dtype=bool)
    if up.shape[1] > n_real:                      # mask padding columns
        valid = np.arange(up.shape[1]) < n_real
        up = up & valid
        full = full & valid
    n_up = up.sum(axis=1)
    majority = 2 * n_up > n_real
    roster_up = up[:, :rf].any(axis=1)
    full_up = (full & up).any(axis=1)
    lark = majority & roster_up & full_up
    maj = 2 * up[:, :voters].sum(axis=1) > voters
    rank = np.cumsum(up, axis=1) <= rf
    creps = up & rank
    return lark, maj, creps


def downtime_eval_rank_np(up_succ, full_succ, *, rf: int, n_real: int,
                          roster=None, want_repmask: bool = False,
                          want_rleader: bool = False):
    """Per-step protocol evaluation for the downtime engine (§6).

    Same (R, n_pad) rank-space tiles as pac_eval_rank_np.  Returns
      lark        (R,)   bool — PAC SimpleMajority (identical math)
      qmaj        (R,)   bool — majority of the f+1-copy replica set
                         (the first rf succession columns, or the given
                         roster's ranks; equal storage either way)
      leader      (R,)   int32 — succession rank of the acting leader
                         (first up node; n_real when no node is up)
      leader_full (R,)   bool — leader holds the latest copy (pre-refresh
                         full mask, so a fresh leader is visibly stale)
      nrep        (R,)   int32 — up-count within the replica set
      creps       (R, n_pad) bool — cluster replicas (holder refresh)

    roster (R, rf) int32, optional: per-row succession ranks (< n_real) of
    the quorum-log replica set — the reconfiguring baseline's carried
    state.  When given, qmaj/nrep are evaluated over those ranks instead
    of the implicit first-rf lanes (roster=None is exactly the static
    baseline: a roster of [0, ..., rf-1] gives identical outputs).  All
    other outputs are roster-independent.

    The protocol-zoo engines request extra outputs, inserted *before*
    creps (so creps stays last — the contract _initial_full_state keys
    on):
      want_repmask  repmask (R,) int32, bit j set iff the first-rf lane j
                    is up — the Hermes engine's membership view (requires
                    rf <= 30 so the mask fits a non-negative int32)
      want_rleader  rleader (R,) int32, the minimum succession rank among
                    *up roster members* (n_real when none is up) — the
                    Spinnaker engine's electable leader; requires roster
    """
    up = np.asarray(up_succ, dtype=bool)
    full = np.asarray(full_succ, dtype=bool)
    lark, qmaj, creps = pac_eval_rank_np(up, full, rf=rf, voters=rf,
                                         n_real=n_real)
    if up.shape[1] > n_real:
        valid = np.arange(up.shape[1]) < n_real
        up = up & valid
        full = full & valid
    if roster is None:
        nrep = up[:, :rf].sum(axis=1).astype(np.int32)
    else:
        roster = np.asarray(roster)
        if roster.shape != (up.shape[0], rf):
            raise ValueError(f"roster must have shape (R, rf)="
                             f"({up.shape[0]}, {rf}); got {roster.shape}")
        nrep = np.take_along_axis(up, roster, axis=1) \
            .sum(axis=1).astype(np.int32)
    qmaj = 2 * nrep > rf
    lanes = np.arange(up.shape[1], dtype=np.int32)
    leader = np.where(up, lanes[None, :], np.int32(up.shape[1])) \
        .min(axis=1).astype(np.int32)
    leader = np.minimum(leader, np.int32(n_real))
    leader_full = ((full & up) & (lanes[None, :] == leader[:, None])) \
        .any(axis=1)
    extras = ()
    if want_repmask:
        bits = np.int32(1) << np.arange(rf, dtype=np.int32)
        repmask = (up[:, :rf].astype(np.int32) * bits[None, :]) \
            .sum(axis=1, dtype=np.int32)
        extras = extras + (repmask,)
    if want_rleader:
        if roster is None:
            raise ValueError("rleader needs a roster (it elects among "
                             "roster members)")
        rup = np.take_along_axis(up, roster, axis=1)
        rleader = np.where(rup, roster.astype(np.int32),
                           np.int32(n_real)).min(axis=1).astype(np.int32)
        extras = extras + (rleader,)
    return (lark, qmaj, leader, leader_full, nrep) + extras + (creps,)


def rebuild_node_counts_np(recruit, active, *, n_real: int):
    """(B, P) recruit node ids + (B, P) active mask -> (B, n_real) int32.

    counts[b, node] = number of partitions in trial b whose active
    catch-up is ingesting on `node` — the per-node reduction behind the
    downtime engine's bandwidth-contended rebuild model (§6).  Ids outside
    [0, n_real) (the engine's no-recruit sentinel) and inactive entries
    contribute nothing.  The reduction never crosses trials (rows), so it
    commutes with trials-axis sharding.
    """
    recruit = np.asarray(recruit)
    active = np.asarray(active, dtype=bool)
    if recruit.shape != active.shape or recruit.ndim != 2:
        raise ValueError(f"recruit/active must share a (B, P) shape; got "
                         f"{recruit.shape} vs {active.shape}")
    ok = active & (recruit >= 0) & (recruit < n_real)
    counts = np.zeros((recruit.shape[0], n_real), dtype=np.int32)
    rows = np.arange(recruit.shape[0])[:, None]
    np.add.at(counts, (rows, np.clip(recruit, 0, n_real - 1)),
              ok.astype(np.int32))
    return counts
