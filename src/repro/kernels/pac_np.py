"""Numpy rank-space PAC evaluation — the event engine's evaluate(),
factored out so the scalar Monte Carlo (core/availability.py) shares the
exact math with the batched backends in ops.py without importing jax.
"""
from __future__ import annotations

import numpy as np


def pac_eval_rank_np(up_succ, full_succ, *, rf: int, voters: int,
                     n_real: int):
    """(R, n_pad) bool tiles -> (lark (R,), maj (R,), creps (R, n_pad)).

    Columns >= n_real are padding.  Whole-cluster majority uses any row's
    up-count: each row of up_succ is a permutation of the same node set,
    so row sums all equal the cluster's up-count.
    """
    up = np.asarray(up_succ, dtype=bool)
    full = np.asarray(full_succ, dtype=bool)
    if up.shape[1] > n_real:                      # mask padding columns
        valid = np.arange(up.shape[1]) < n_real
        up = up & valid
        full = full & valid
    n_up = up.sum(axis=1)
    majority = 2 * n_up > n_real
    roster_up = up[:, :rf].any(axis=1)
    full_up = (full & up).any(axis=1)
    lark = majority & roster_up & full_up
    maj = 2 * up[:, :voters].sum(axis=1) > voters
    rank = np.cumsum(up, axis=1) <= rf
    creps = up & rank
    return lark, maj, creps
