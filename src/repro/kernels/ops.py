"""Jit'd dispatch wrappers: Pallas kernel on TPU, pure-jnp oracle elsewhere.

Two layers live here:

*Model kernels* (flash_attention, mlstm, rglru): the CPU container
validates them in ``interpret=True`` mode (tests) while
models/benchmarks/dry-runs use the jnp oracle path — identical math, so
the lowered HLO is an honest stand-in and the TPU kernel is a drop-in
swap.  Set ``REPRO_FORCE_PALLAS=interpret`` to route model code through
the interpreted kernels (slow; tests only).

*Monte Carlo batch ops* (paper §5.1 / §6 hot loops): ``pac_eval_batch``
and ``downtime_eval_batch`` evaluate (R, n_pad) rank-space cluster-state
tiles under a uniform three-backend contract —

  backend="numpy"   vectorized numpy (the scalar event engine's math,
                    shared via pac_np.py, jax-import-free)
  backend="jax"     pure-jnp oracle (jit-friendly; used inside lax.scan)
  backend="pallas"  kernels/pac_eval.py — compiled on TPU, interpret
                    mode on CPU

Invariants (pinned by tests/test_availability_batched.py and
tests/test_downtime_batched.py, stated in docs/ARCHITECTURE.md): all
three backends are bit-identical (comparisons/cumsums only, no float
math); padding columns >= n_real never affect outputs; and the Pallas
``block_p`` tiling — including the deterministic ``autotune_block_p``
choice — changes throughput, never results.
"""
from __future__ import annotations

import functools
import os
from dataclasses import dataclass
from typing import Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

from . import ref

_FORCE = os.environ.get("REPRO_FORCE_PALLAS", "")

PAC_BACKENDS = ("numpy", "jax", "pallas")


def _mode() -> str:
    """'kernel' | 'interpret' | 'ref'."""
    if _FORCE == "interpret":
        return "interpret"
    if _FORCE == "ref":
        return "ref"
    return "kernel" if jax.default_backend() == "tpu" else "ref"


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: Optional[float] = None):
    mode = _mode()
    if mode != "ref":
        from . import flash_attention as fk
        return fk.flash_attention(q, k, v, causal=causal, window=window,
                                  scale=scale, interpret=(mode == "interpret"))
    return ref.attention_ref(q, k, v, causal=causal, window=window, scale=scale)


def mlstm_chunkwise(q, k, v, log_f, log_i, *, chunk: int = 256, initial=None):
    mode = _mode()
    if mode != "ref":
        from . import mlstm_chunk as mk
        return mk.mlstm_chunkwise(q, k, v, log_f, log_i, chunk=chunk,
                                  initial=initial, interpret=(mode == "interpret"))
    return ref.mlstm_chunkwise(q, k, v, log_f, log_i, chunk=chunk, initial=initial)


def mlstm_step(q, k, v, log_f, log_i, state):
    return ref.mlstm_step(q, k, v, log_f, log_i, state)


def rglru_scan(x, log_a):
    mode = _mode()
    if mode != "ref":
        from . import rglru_scan as rk
        return rk.rglru_scan(x, log_a, interpret=(mode == "interpret"))
    return ref.rglru_scan_ref(x, log_a)


def rglru_step(x, log_a, h):
    return ref.rglru_step(x, log_a, h)


def pac_eval(up, succ, full, rf: int, *, voters=None,
             conditions: Tuple[str, ...] = ("simple_majority",)):
    """Node-space PAC over (P, n) (protocol-level users)."""
    return ref.pac_eval_ref(up, succ, full, rf, voters=voters,
                            conditions=conditions)


# ---------------------------------------------------------------------------
# Unified PAC backend layer (§5.1 availability Monte Carlo).
#
# All three backends evaluate the same rank-space tile contract as
# ref.pac_eval_rank_ref: inputs (R, n_pad) bool where R is any flattened
# batch (e.g. trials * partitions) and columns >= n_real are padding;
# outputs (lark (R,), maj (R,), creps (R, n_pad)).  "numpy" is the
# vectorized refactor of the event engine's evaluate() and is shared with
# core/availability.py, so the scalar event loop and the batched device
# loop literally run the same availability math.  It lives in pac_np.py
# (numpy-only) so the event engine never pays the jax import.
# ---------------------------------------------------------------------------

from .pac_np import (downtime_eval_rank_np,  # noqa: E402  (re-export)
                     pac_eval_rank_np, rebuild_node_counts_np)


def _pallas_block_p(R: int) -> int:
    """Largest power-of-two block size <= 256 that divides the row count."""
    bp = 1
    while bp < 256 and R % (bp * 2) == 0:
        bp *= 2
    return bp


def _pac_lane_pad(n_pad: int) -> int:
    """Node axis padded up to a lane multiple for the VPU tile."""
    return n_pad + (-n_pad % 128)


def pac_vmem_bytes(block_p: int, n_pad: int) -> int:
    """VMEM the PAC kernel holds live for one (block_p, n_lanes) block:
    three int32 input tiles (up, full, valid), the int32 cumsum/creps
    working tile, and the bool outputs — the budget the autotuner's
    candidate enumeration respects."""
    n_lanes = _pac_lane_pad(n_pad)
    return block_p * n_lanes * 4 * 4 + block_p * (2 + n_lanes)


def block_p_candidates(R: int, n_pad: int, *, max_block: int = 1024,
                       vmem_limit_bytes: int = 8 * 2 ** 20):
    """Power-of-two block_p values that tile R rows within the VMEM budget.

    Deterministic pure function of its arguments — the autotuner measures
    exactly this set, so two runs on the same shape always race the same
    candidates.
    """
    cands = []
    bp = 8
    while bp <= min(R, max_block):
        if R % bp == 0 and pac_vmem_bytes(bp, n_pad) <= vmem_limit_bytes:
            cands.append(bp)
        bp *= 2
    return tuple(cands) or (_pallas_block_p(R),)


@dataclass(frozen=True)
class AutotuneResult:
    block_p: int
    timings_us: Mapping[int, float]   # candidate -> median µs/call
    source: str                       # "measured" | "heuristic-fallback"


_AUTOTUNE_CACHE: dict = {}


#: kernels the block_p autotuner can race — the §5.1 PAC kernel and the
#: §6 downtime kernel (plus its roster-carrying reconfig variant); all
#: three share the (R, n_pad) tile contract, so candidate sets transfer
AUTOTUNE_KERNELS = ("pac", "downtime", "downtime_roster")


def _measure_pac_block(R: int, n_pad: int, bp: int, *, rf: int, voters: int,
                       n_real: int, iters: int,
                       kernel: str = "pac") -> float:
    """Median µs/call of one Pallas Monte Carlo kernel (`kernel` selects
    pac_eval / downtime_eval / its roster variant) at one block size, on a
    deterministic synthetic tile (counter-hash density pattern, no RNG
    state)."""
    import time

    from . import pac_eval as pk
    n_lanes = _pac_lane_pad(n_pad)
    idx = (jnp.arange(R, dtype=jnp.uint32)[:, None] * jnp.uint32(n_lanes)
           + jnp.arange(n_lanes, dtype=jnp.uint32)[None, :])
    up = (idx * jnp.uint32(2654435761) % jnp.uint32(97)) < 90   # ~93% up,
    full = (idx * jnp.uint32(40503) % jnp.uint32(89)) < 30      # fixed pattern
    interpret = jax.default_backend() != "tpu"
    if kernel == "pac":
        fn = jax.jit(functools.partial(
            pk.pac_eval, rf=rf, voters=voters, n_real=n_real, block_p=bp,
            interpret=interpret))
        args = (up, full)
    elif kernel in ("downtime", "downtime_roster"):
        kw = dict(rf=rf, n_real=n_real, block_p=bp, interpret=interpret)
        if kernel == "downtime_roster":
            # identity roster, rank axis lane-padded with the sentinel the
            # engine's pallas path uses (ops.downtime_eval_batch)
            rf_pad = rf + (-rf % 128)
            ranks = jnp.arange(rf_pad, dtype=jnp.int32)[None, :]
            kw["roster"] = jnp.broadcast_to(
                jnp.where(ranks < rf, ranks, jnp.int32(n_lanes)),
                (R, rf_pad))
        fn = jax.jit(functools.partial(pk.downtime_eval, **kw))
        args = (up, full)
    else:
        raise ValueError(f"unknown autotune kernel {kernel!r}; expected "
                         f"one of {AUTOTUNE_KERNELS}")
    jax.block_until_ready(fn(*args))           # compile + warmup
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def autotune_block_p(R: int, n_pad: int, *, rf: int, voters: int,
                     n_real: int, candidates=None, iters: int = 9,
                     measure=None, force: bool = False,
                     kernel: str = "pac") -> AutotuneResult:
    """Pick the fastest Pallas block_p for an (R, n_pad) Monte Carlo tile.

    `kernel` selects which kernel is raced: "pac" (§5.1 availability),
    "downtime" (§6 commit-pause), or "downtime_roster" (the reconfiguring
    baseline's roster-carrying variant) — the sweep threads its --metric /
    --rebuild-model so the tuner times the kernel the grid will actually
    run.  Deterministic by construction: the candidate set is a pure
    function of the shape, each candidate's time is a median over `iters`
    calls, ties break toward the smaller block, and the choice is cached
    per (shape, params, kernel, candidates) so every later call in the
    process returns the same answer.  Off-TPU the Pallas kernel runs in
    interpret mode, where timings measure the interpreter rather than the
    kernel — so without `force` (or an injected `measure` fn, used by
    tests) the tuner falls back to the static heuristic instead of
    publishing noise.
    """
    if kernel not in AUTOTUNE_KERNELS:
        raise ValueError(f"unknown autotune kernel {kernel!r}; expected "
                         f"one of {AUTOTUNE_KERNELS}")
    cands = tuple(candidates) if candidates is not None \
        else block_p_candidates(R, n_pad)
    if not cands:
        raise ValueError("autotune_block_p needs at least one candidate")
    for bp in cands:
        if R % bp:
            raise ValueError(f"candidate block_p {bp} does not divide R={R}")
    # injected-measure calls (tests) bypass the cache: a deterministic fake
    # is repeatable on its own, and caching across *different* fakes with
    # the same shape would return stale choices
    key = (R, n_pad, rf, voters, n_real, cands, force, kernel)
    if measure is None and key in _AUTOTUNE_CACHE:
        return _AUTOTUNE_CACHE[key]
    if measure is None:
        if jax.default_backend() != "tpu" and not force:
            res = AutotuneResult(block_p=_pallas_block_p(R), timings_us={},
                                 source="heuristic-fallback")
            _AUTOTUNE_CACHE[key] = res
            return res
        measure = functools.partial(_measure_pac_block, rf=rf,
                                    voters=voters, n_real=n_real,
                                    iters=iters, kernel=kernel)
        timings = {bp: measure(R, n_pad, bp) for bp in cands}
        best = min(sorted(timings), key=lambda bp: (timings[bp], bp))
        res = AutotuneResult(block_p=best, timings_us=timings,
                             source="measured")
        _AUTOTUNE_CACHE[key] = res
        return res
    timings = {bp: float(measure(R, n_pad, bp)) for bp in cands}
    best = min(sorted(timings), key=lambda bp: (timings[bp], bp))
    return AutotuneResult(block_p=best, timings_us=timings,
                          source="measured")


def pac_eval_batch(up_succ, full_succ, *, rf: int, voters: int, n_real: int,
                   backend: str = "jax", block_p: Optional[int] = None):
    """Dispatch a (R, n_pad) rank-space PAC tile to the chosen backend.

    backend:
      numpy   vectorized numpy (the event engine's evaluate logic)
      jax     pure-jnp oracle (jit-friendly; use inside lax.scan)
      pallas  kernels/pac_eval.py — compiled on TPU, interpret mode on CPU

    block_p (pallas only) overrides the static block-size heuristic —
    typically an `autotune_block_p(...)` choice.  Results are elementwise,
    so every block size yields identical outputs; only throughput changes.
    """
    if backend == "numpy":
        return pac_eval_rank_np(up_succ, full_succ, rf=rf, voters=voters,
                                n_real=n_real)
    if backend == "jax":
        return ref.pac_eval_rank_ref(up_succ, full_succ, rf=rf,
                                     voters=voters, n_real=n_real)
    if backend == "pallas":
        from . import pac_eval as pk
        R, n_pad = up_succ.shape
        lanes = -n_pad % 128                      # pad node axis to a lane
        if lanes:                                 # multiple for the VPU tile
            up_succ = jnp.pad(up_succ, ((0, 0), (0, lanes)))
            full_succ = jnp.pad(full_succ, ((0, 0), (0, lanes)))
        interpret = jax.default_backend() != "tpu"
        lark, maj, creps = pk.pac_eval(up_succ, full_succ, rf=rf,
                                       voters=voters, n_real=n_real,
                                       block_p=block_p or _pallas_block_p(R),
                                       interpret=interpret)
        return lark, maj, creps[:, :n_pad]
    raise ValueError(f"unknown PAC backend {backend!r}; "
                     f"expected one of {PAC_BACKENDS}")


def downtime_eval_batch(up_succ, full_succ, *, rf: int, n_real: int,
                        backend: str = "jax",
                        block_p: Optional[int] = None, roster=None):
    """Dispatch the §6 downtime engine's per-step evaluation of a
    (R, n_pad) rank-space tile to the chosen backend.

    Extends the pac_eval_batch contract with the state the commit-pause
    engine (core/downtime_batched.py) tracks between steps — the
    quorum-log baseline's f+1-copy replica-set majority and up-count, and
    the acting leader's rank and latest-copy bit (for the dup-res
    penalty).  Returns (lark, qmaj, leader, leader_full, nrep, creps);
    see pac_np.downtime_eval_rank_np for per-output semantics.

    roster (R, rf) int32, optional: the reconfiguring baseline's carried
    replica-set ranks — qmaj/nrep are then evaluated over those ranks
    instead of the implicit first rf lanes (`--rebuild-model reconfig`).
    Passing the identity roster [0..rf-1] reproduces the static baseline
    bit for bit.

    The same invariants as pac_eval_batch hold: all three backends are
    bit-identical (pure comparisons/cumsums, no float math), and block_p
    (pallas) only tiles the rows — any autotune_block_p choice for an
    (R, n_pad) PAC tile is valid here, which is why the sweep reuses one
    autotuned block size for both metrics.
    """
    if backend == "numpy":
        return downtime_eval_rank_np(up_succ, full_succ, rf=rf,
                                     n_real=n_real, roster=roster)
    if backend == "jax":
        return ref.downtime_eval_rank_ref(up_succ, full_succ, rf=rf,
                                          n_real=n_real, roster=roster)
    if backend == "pallas":
        from . import pac_eval as pk
        R, n_pad = up_succ.shape
        lanes = -n_pad % 128
        if lanes:
            up_succ = jnp.pad(up_succ, ((0, 0), (0, lanes)))
            full_succ = jnp.pad(full_succ, ((0, 0), (0, lanes)))
        if roster is not None:
            # pad the rank axis to a lane multiple; the pad value is the
            # tile width, a rank no lane iota ever matches (never read:
            # the kernel only visits the first rf roster columns)
            rpad = -roster.shape[1] % 128
            roster = jnp.pad(roster.astype(jnp.int32),
                             ((0, 0), (0, rpad)),
                             constant_values=n_pad + lanes)
        interpret = jax.default_backend() != "tpu"
        lark, qmaj, leader, lfull, nrep, creps = pk.downtime_eval(
            up_succ, full_succ, rf=rf, n_real=n_real,
            block_p=block_p or _pallas_block_p(R), interpret=interpret,
            roster=roster)
        return lark, qmaj, leader, lfull, nrep, creps[:, :n_pad]
    raise ValueError(f"unknown PAC backend {backend!r}; "
                     f"expected one of {PAC_BACKENDS}")


def rebuild_node_counts(recruit, active, *, n_real: int,
                        backend: str = "jax"):
    """Per-node in-flight rebuild counts for the §6 bandwidth-contended
    rebuild model: recruit (B, P) int32 node ids (values outside
    [0, n_real) — the engine's no-recruit sentinel — are ignored), active
    (B, P) bool -> counts (B, n_real) int32, where counts[b, node] is the
    number of partitions of trial b whose active catch-up ingests on
    `node`.

    This is the downtime engine's first *cross-partition* reduction
    inside a step — but it stays strictly within a trial (rows never
    mix), so it commutes with trials-axis sharding; the 8-device proof
    lives in tests/test_sharded.py.  All three backends are bit-identical:
    the numpy/jnp implementations scatter-add, the Pallas kernel
    (kernels/pac_eval.py: node_count) accumulates one-hot compares over
    the partition columns — pure integer work either way.
    """
    if backend == "numpy":
        return rebuild_node_counts_np(recruit, active, n_real=n_real)
    if backend == "jax":
        return ref.rebuild_node_counts_ref(recruit, active, n_real=n_real)
    if backend == "pallas":
        from . import pac_eval as pk
        counts = pk.node_count(recruit, active, n_real=n_real,
                               interpret=jax.default_backend() != "tpu")
        return counts[:, :n_real]
    raise ValueError(f"unknown PAC backend {backend!r}; "
                     f"expected one of {PAC_BACKENDS}")
