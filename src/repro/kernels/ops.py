"""Jit'd dispatch wrappers: Pallas kernel on TPU, pure-jnp oracle elsewhere.

The CPU container validates kernels in ``interpret=True`` mode (tests) while
models/benchmarks/dry-runs use the jnp oracle path — identical math, so the
lowered HLO is an honest stand-in and the TPU kernel is a drop-in swap.

Set ``REPRO_FORCE_PALLAS=interpret`` to route model code through the
interpreted kernels (slow; tests only).
"""
from __future__ import annotations

import functools
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ref

_FORCE = os.environ.get("REPRO_FORCE_PALLAS", "")

PAC_BACKENDS = ("numpy", "jax", "pallas")


def _mode() -> str:
    """'kernel' | 'interpret' | 'ref'."""
    if _FORCE == "interpret":
        return "interpret"
    if _FORCE == "ref":
        return "ref"
    return "kernel" if jax.default_backend() == "tpu" else "ref"


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: Optional[float] = None):
    mode = _mode()
    if mode != "ref":
        from . import flash_attention as fk
        return fk.flash_attention(q, k, v, causal=causal, window=window,
                                  scale=scale, interpret=(mode == "interpret"))
    return ref.attention_ref(q, k, v, causal=causal, window=window, scale=scale)


def mlstm_chunkwise(q, k, v, log_f, log_i, *, chunk: int = 256, initial=None):
    mode = _mode()
    if mode != "ref":
        from . import mlstm_chunk as mk
        return mk.mlstm_chunkwise(q, k, v, log_f, log_i, chunk=chunk,
                                  initial=initial, interpret=(mode == "interpret"))
    return ref.mlstm_chunkwise(q, k, v, log_f, log_i, chunk=chunk, initial=initial)


def mlstm_step(q, k, v, log_f, log_i, state):
    return ref.mlstm_step(q, k, v, log_f, log_i, state)


def rglru_scan(x, log_a):
    mode = _mode()
    if mode != "ref":
        from . import rglru_scan as rk
        return rk.rglru_scan(x, log_a, interpret=(mode == "interpret"))
    return ref.rglru_scan_ref(x, log_a)


def rglru_step(x, log_a, h):
    return ref.rglru_step(x, log_a, h)


def pac_eval(up, succ, full, rf: int, *, voters=None,
             conditions: Tuple[str, ...] = ("simple_majority",)):
    """Node-space PAC over (P, n) (protocol-level users)."""
    return ref.pac_eval_ref(up, succ, full, rf, voters=voters,
                            conditions=conditions)


# ---------------------------------------------------------------------------
# Unified PAC backend layer (§5.1 availability Monte Carlo).
#
# All three backends evaluate the same rank-space tile contract as
# ref.pac_eval_rank_ref: inputs (R, n_pad) bool where R is any flattened
# batch (e.g. trials * partitions) and columns >= n_real are padding;
# outputs (lark (R,), maj (R,), creps (R, n_pad)).  "numpy" is the
# vectorized refactor of the event engine's evaluate() and is shared with
# core/availability.py, so the scalar event loop and the batched device
# loop literally run the same availability math.  It lives in pac_np.py
# (numpy-only) so the event engine never pays the jax import.
# ---------------------------------------------------------------------------

from .pac_np import pac_eval_rank_np  # noqa: E402  (re-export)


def _pallas_block_p(R: int) -> int:
    """Largest power-of-two block size <= 256 that divides the row count."""
    bp = 1
    while bp < 256 and R % (bp * 2) == 0:
        bp *= 2
    return bp


def pac_eval_batch(up_succ, full_succ, *, rf: int, voters: int, n_real: int,
                   backend: str = "jax"):
    """Dispatch a (R, n_pad) rank-space PAC tile to the chosen backend.

    backend:
      numpy   vectorized numpy (the event engine's evaluate logic)
      jax     pure-jnp oracle (jit-friendly; use inside lax.scan)
      pallas  kernels/pac_eval.py — compiled on TPU, interpret mode on CPU
    """
    if backend == "numpy":
        return pac_eval_rank_np(up_succ, full_succ, rf=rf, voters=voters,
                                n_real=n_real)
    if backend == "jax":
        return ref.pac_eval_rank_ref(up_succ, full_succ, rf=rf,
                                     voters=voters, n_real=n_real)
    if backend == "pallas":
        from . import pac_eval as pk
        R, n_pad = up_succ.shape
        lanes = -n_pad % 128                      # pad node axis to a lane
        if lanes:                                 # multiple for the VPU tile
            up_succ = jnp.pad(up_succ, ((0, 0), (0, lanes)))
            full_succ = jnp.pad(full_succ, ((0, 0), (0, lanes)))
        interpret = jax.default_backend() != "tpu"
        lark, maj, creps = pk.pac_eval(up_succ, full_succ, rf=rf,
                                       voters=voters, n_real=n_real,
                                       block_p=_pallas_block_p(R),
                                       interpret=interpret)
        return lark, maj, creps[:, :n_pad]
    raise ValueError(f"unknown PAC backend {backend!r}; "
                     f"expected one of {PAC_BACKENDS}")
