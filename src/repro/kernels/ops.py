"""Jit'd dispatch wrappers: Pallas kernel on TPU, pure-jnp oracle elsewhere.

Two layers live here:

*Model kernels* (flash_attention, mlstm, rglru): the CPU container
validates them in ``interpret=True`` mode (tests) while
models/benchmarks/dry-runs use the jnp oracle path — identical math, so
the lowered HLO is an honest stand-in and the TPU kernel is a drop-in
swap.  Set ``REPRO_FORCE_PALLAS=interpret`` to route model code through
the interpreted kernels (slow; tests only).

*Monte Carlo batch ops* (paper §5.1 / §6 hot loops): ``pac_eval_batch``
and ``downtime_eval_batch`` evaluate (R, n_pad) rank-space cluster-state
tiles under a uniform three-backend contract —

  backend="numpy"   vectorized numpy (the scalar event engine's math,
                    shared via pac_np.py, jax-import-free)
  backend="jax"     pure-jnp oracle (jit-friendly; used inside lax.scan)
  backend="pallas"  kernels/pac_eval.py — compiled on TPU, interpret
                    mode on CPU

Invariants (pinned by tests/test_availability_batched.py and
tests/test_downtime_batched.py, stated in docs/ARCHITECTURE.md): all
three backends are bit-identical (comparisons/cumsums only, no float
math); padding columns >= n_real never affect outputs; and the Pallas
``block_p`` tiling — including the deterministic ``autotune_block_p``
choice — changes throughput, never results.

The Monte Carlo ops are consolidated behind one entry point: a frozen
``StepSpec`` (metric, rf/voters, rebuild model, packed layout) dispatched
by ``step_eval(spec, up, full, ...)``.  ``StepSpec(packed=True)`` selects
the bit-packed (B, W, P) uint32 word layout (kernels/bitpack.py) and, on
the pallas backend, the fused step megakernel (kernels/fused_step.py)
that folds eval + roster gather + rebuild node counts into one
pallas_call.  The legacy per-kernel functions ``pac_eval_batch`` /
``downtime_eval_batch`` / ``rebuild_node_counts`` remain as thin
deprecated wrappers over step_eval.
"""
from __future__ import annotations

import functools
import os
import warnings
from dataclasses import dataclass
from typing import Mapping, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import bitpack, ref
from . import latency as _latency

_FORCE = os.environ.get("REPRO_FORCE_PALLAS", "")

PAC_BACKENDS = ("numpy", "jax", "pallas")


def _mode() -> str:
    """'kernel' | 'interpret' | 'ref'."""
    if _FORCE == "interpret":
        return "interpret"
    if _FORCE == "ref":
        return "ref"
    return "kernel" if jax.default_backend() == "tpu" else "ref"


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: Optional[float] = None):
    mode = _mode()
    if mode != "ref":
        from . import flash_attention as fk
        return fk.flash_attention(q, k, v, causal=causal, window=window,
                                  scale=scale, interpret=(mode == "interpret"))
    return ref.attention_ref(q, k, v, causal=causal, window=window, scale=scale)


def mlstm_chunkwise(q, k, v, log_f, log_i, *, chunk: int = 256, initial=None):
    mode = _mode()
    if mode != "ref":
        from . import mlstm_chunk as mk
        return mk.mlstm_chunkwise(q, k, v, log_f, log_i, chunk=chunk,
                                  initial=initial, interpret=(mode == "interpret"))
    return ref.mlstm_chunkwise(q, k, v, log_f, log_i, chunk=chunk, initial=initial)


def mlstm_step(q, k, v, log_f, log_i, state):
    return ref.mlstm_step(q, k, v, log_f, log_i, state)


def rglru_scan(x, log_a):
    mode = _mode()
    if mode != "ref":
        from . import rglru_scan as rk
        return rk.rglru_scan(x, log_a, interpret=(mode == "interpret"))
    return ref.rglru_scan_ref(x, log_a)


def rglru_step(x, log_a, h):
    return ref.rglru_step(x, log_a, h)


def pac_eval(up, succ, full, rf: int, *, voters=None,
             conditions: Tuple[str, ...] = ("simple_majority",)):
    """Node-space PAC over (P, n) (protocol-level users)."""
    return ref.pac_eval_ref(up, succ, full, rf, voters=voters,
                            conditions=conditions)


# ---------------------------------------------------------------------------
# Unified PAC backend layer (§5.1 availability Monte Carlo).
#
# All three backends evaluate the same rank-space tile contract as
# ref.pac_eval_rank_ref: inputs (R, n_pad) bool where R is any flattened
# batch (e.g. trials * partitions) and columns >= n_real are padding;
# outputs (lark (R,), maj (R,), creps (R, n_pad)).  "numpy" is the
# vectorized refactor of the event engine's evaluate() and is shared with
# core/availability.py, so the scalar event loop and the batched device
# loop literally run the same availability math.  It lives in pac_np.py
# (numpy-only) so the event engine never pays the jax import.
# ---------------------------------------------------------------------------

from .pac_np import (downtime_eval_rank_np,  # noqa: E402  (re-export)
                     pac_eval_rank_np, rebuild_node_counts_np)


def _pallas_block_p(R: int) -> int:
    """Largest power-of-two block size <= 256 that divides the row count."""
    bp = 1
    while bp < 256 and R % (bp * 2) == 0:
        bp *= 2
    return bp


def _pac_lane_pad(n_pad: int) -> int:
    """Node axis padded up to a lane multiple for the VPU tile."""
    return n_pad + (-n_pad % 128)


def pac_vmem_bytes(block_p: int, n_pad: int) -> int:
    """VMEM the PAC kernel holds live for one (block_p, n_lanes) block:
    three int32 input tiles (up, full, valid), the int32 cumsum/creps
    working tile, and the bool outputs — the budget the autotuner's
    candidate enumeration respects."""
    n_lanes = _pac_lane_pad(n_pad)
    return block_p * n_lanes * 4 * 4 + block_p * (2 + n_lanes)


def block_p_candidates(R: int, n_pad: int, *, max_block: int = 1024,
                       vmem_limit_bytes: int = 8 * 2 ** 20):
    """Power-of-two block_p values that tile R rows within the VMEM budget.

    Deterministic pure function of its arguments — the autotuner measures
    exactly this set, so two runs on the same shape always race the same
    candidates.
    """
    cands = []
    bp = 8
    while bp <= min(R, max_block):
        if R % bp == 0 and pac_vmem_bytes(bp, n_pad) <= vmem_limit_bytes:
            cands.append(bp)
        bp *= 2
    return tuple(cands) or (_pallas_block_p(R),)


@dataclass(frozen=True)
class AutotuneResult:
    block_p: int
    timings_us: Mapping[int, float]   # candidate -> median µs/call
    source: str                       # "measured" | "heuristic-fallback"


_AUTOTUNE_CACHE: dict = {}


#: kernels the block_p autotuner can race — the §5.1 PAC kernel and the
#: §6 downtime kernel (plus its roster-carrying reconfig variant); all
#: three share the (R, n_pad) tile contract, so candidate sets transfer
AUTOTUNE_KERNELS = ("pac", "downtime", "downtime_roster")


def _measure_pac_block(R: int, n_pad: int, bp: int, *, rf: int, voters: int,
                       n_real: int, iters: int,
                       kernel: str = "pac") -> float:
    """Median µs/call of one Pallas Monte Carlo kernel (`kernel` selects
    pac_eval / downtime_eval / its roster variant) at one block size, on a
    deterministic synthetic tile (counter-hash density pattern, no RNG
    state)."""
    import time

    from . import pac_eval as pk
    n_lanes = _pac_lane_pad(n_pad)
    idx = (jnp.arange(R, dtype=jnp.uint32)[:, None] * jnp.uint32(n_lanes)
           + jnp.arange(n_lanes, dtype=jnp.uint32)[None, :])
    up = (idx * jnp.uint32(2654435761) % jnp.uint32(97)) < 90   # ~93% up,
    full = (idx * jnp.uint32(40503) % jnp.uint32(89)) < 30      # fixed pattern
    interpret = jax.default_backend() != "tpu"
    if kernel == "pac":
        fn = jax.jit(functools.partial(
            pk.pac_eval, rf=rf, voters=voters, n_real=n_real, block_p=bp,
            interpret=interpret))
        args = (up, full)
    elif kernel in ("downtime", "downtime_roster"):
        kw = dict(rf=rf, n_real=n_real, block_p=bp, interpret=interpret)
        if kernel == "downtime_roster":
            # identity roster, rank axis lane-padded with the sentinel the
            # engine's pallas path uses (ops.downtime_eval_batch)
            rf_pad = rf + (-rf % 128)
            ranks = jnp.arange(rf_pad, dtype=jnp.int32)[None, :]
            kw["roster"] = jnp.broadcast_to(
                jnp.where(ranks < rf, ranks, jnp.int32(n_lanes)),
                (R, rf_pad))
        fn = jax.jit(functools.partial(pk.downtime_eval, **kw))
        args = (up, full)
    else:
        raise ValueError(f"unknown autotune kernel {kernel!r}; expected "
                         f"one of {AUTOTUNE_KERNELS}")
    jax.block_until_ready(fn(*args))           # compile + warmup
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def autotune_block_p(R: int, n_pad: int, *, rf: int, voters: int,
                     n_real: int, candidates=None, iters: int = 9,
                     measure=None, force: bool = False,
                     kernel: str = "pac") -> AutotuneResult:
    """Pick the fastest Pallas block_p for an (R, n_pad) Monte Carlo tile.

    `kernel` selects which kernel is raced: "pac" (§5.1 availability),
    "downtime" (§6 commit-pause), or "downtime_roster" (the reconfiguring
    baseline's roster-carrying variant) — the sweep threads its --metric /
    --rebuild-model so the tuner times the kernel the grid will actually
    run.  Deterministic by construction: the candidate set is a pure
    function of the shape, each candidate's time is a median over `iters`
    calls, ties break toward the smaller block, and the choice is cached
    per (shape, params, kernel, candidates) so every later call in the
    process returns the same answer.  Off-TPU the Pallas kernel runs in
    interpret mode, where timings measure the interpreter rather than the
    kernel — so without `force` (or an injected `measure` fn, used by
    tests) the tuner falls back to the static heuristic instead of
    publishing noise.
    """
    if kernel not in AUTOTUNE_KERNELS:
        raise ValueError(f"unknown autotune kernel {kernel!r}; expected "
                         f"one of {AUTOTUNE_KERNELS}")
    cands = tuple(candidates) if candidates is not None \
        else block_p_candidates(R, n_pad)
    if not cands:
        raise ValueError("autotune_block_p needs at least one candidate")
    for bp in cands:
        if R % bp:
            raise ValueError(f"candidate block_p {bp} does not divide R={R}")
    # injected-measure calls (tests) bypass the cache: a deterministic fake
    # is repeatable on its own, and caching across *different* fakes with
    # the same shape would return stale choices.  The key leads with the
    # tuner family + kernel kind + the full tile geometry, so a fused-2D
    # race and a block_p race on the same shape can never alias (the PR 4
    # wrong-kernel race fix, generalized to the fused tuner below)
    key = ("block_p", kernel, R, n_pad, rf, voters, n_real, cands, force)
    if measure is None and key in _AUTOTUNE_CACHE:
        return _AUTOTUNE_CACHE[key]
    if measure is None:
        if jax.default_backend() != "tpu" and not force:
            res = AutotuneResult(block_p=_pallas_block_p(R), timings_us={},
                                 source="heuristic-fallback")
            _AUTOTUNE_CACHE[key] = res
            return res
        measure = functools.partial(_measure_pac_block, rf=rf,
                                    voters=voters, n_real=n_real,
                                    iters=iters, kernel=kernel)
        timings = {bp: measure(R, n_pad, bp) for bp in cands}
        best = min(sorted(timings), key=lambda bp: (timings[bp], bp))
        res = AutotuneResult(block_p=best, timings_us=timings,
                             source="measured")
        _AUTOTUNE_CACHE[key] = res
        return res
    timings = {bp: float(measure(R, n_pad, bp)) for bp in cands}
    best = min(sorted(timings), key=lambda bp: (timings[bp], bp))
    return AutotuneResult(block_p=best, timings_us=timings,
                          source="measured")


def _pac_eval_unpacked(up_succ, full_succ, *, rf: int, voters: int,
                       n_real: int, backend: str = "jax",
                       block_p: Optional[int] = None):
    """Dispatch a (R, n_pad) rank-space PAC tile to the chosen backend.

    backend:
      numpy   vectorized numpy (the event engine's evaluate logic)
      jax     pure-jnp oracle (jit-friendly; use inside lax.scan)
      pallas  kernels/pac_eval.py — compiled on TPU, interpret mode on CPU

    block_p (pallas only) overrides the static block-size heuristic —
    typically an `autotune_block_p(...)` choice.  Results are elementwise,
    so every block size yields identical outputs; only throughput changes.
    """
    if backend == "numpy":
        return pac_eval_rank_np(up_succ, full_succ, rf=rf, voters=voters,
                                n_real=n_real)
    if backend == "jax":
        return ref.pac_eval_rank_ref(up_succ, full_succ, rf=rf,
                                     voters=voters, n_real=n_real)
    if backend == "pallas":
        from . import pac_eval as pk
        R, n_pad = up_succ.shape
        lanes = -n_pad % 128                      # pad node axis to a lane
        if lanes:                                 # multiple for the VPU tile
            up_succ = jnp.pad(up_succ, ((0, 0), (0, lanes)))
            full_succ = jnp.pad(full_succ, ((0, 0), (0, lanes)))
        interpret = jax.default_backend() != "tpu"
        lark, maj, creps = pk.pac_eval(up_succ, full_succ, rf=rf,
                                       voters=voters, n_real=n_real,
                                       block_p=block_p or _pallas_block_p(R),
                                       interpret=interpret)
        return lark, maj, creps[:, :n_pad]
    raise ValueError(f"unknown PAC backend {backend!r}; "
                     f"expected one of {PAC_BACKENDS}")


def _downtime_eval_unpacked(up_succ, full_succ, *, rf: int, n_real: int,
                            backend: str = "jax",
                            block_p: Optional[int] = None, roster=None,
                            want_repmask: bool = False,
                            want_rleader: bool = False):
    """Dispatch the §6 downtime engine's per-step evaluation of a
    (R, n_pad) rank-space tile to the chosen backend.

    Extends the pac_eval_batch contract with the state the commit-pause
    engine (core/downtime_batched.py) tracks between steps — the
    quorum-log baseline's f+1-copy replica-set majority and up-count, and
    the acting leader's rank and latest-copy bit (for the dup-res
    penalty).  Returns (lark, qmaj, leader, leader_full, nrep, *extras,
    creps); see pac_np.downtime_eval_rank_np for per-output semantics
    (want_repmask / want_rleader are the protocol-zoo extras — Hermes
    membership bitmask, Spinnaker electable roster leader).

    roster (R, rf) int32, optional: the reconfiguring baseline's carried
    replica-set ranks — qmaj/nrep are then evaluated over those ranks
    instead of the implicit first rf lanes (`--rebuild-model reconfig`).
    Passing the identity roster [0..rf-1] reproduces the static baseline
    bit for bit.

    The same invariants as pac_eval_batch hold: all three backends are
    bit-identical (pure comparisons/cumsums, no float math), and block_p
    (pallas) only tiles the rows — any autotune_block_p choice for an
    (R, n_pad) PAC tile is valid here, which is why the sweep reuses one
    autotuned block size for both metrics.
    """
    if backend == "numpy":
        return downtime_eval_rank_np(up_succ, full_succ, rf=rf,
                                     n_real=n_real, roster=roster,
                                     want_repmask=want_repmask,
                                     want_rleader=want_rleader)
    if backend == "jax":
        return ref.downtime_eval_rank_ref(up_succ, full_succ, rf=rf,
                                          n_real=n_real, roster=roster,
                                          want_repmask=want_repmask,
                                          want_rleader=want_rleader)
    if backend == "pallas":
        from . import pac_eval as pk
        R, n_pad = up_succ.shape
        lanes = -n_pad % 128
        if lanes:
            up_succ = jnp.pad(up_succ, ((0, 0), (0, lanes)))
            full_succ = jnp.pad(full_succ, ((0, 0), (0, lanes)))
        if roster is not None:
            # pad the rank axis to a lane multiple; the pad value is the
            # tile width, a rank no lane iota ever matches (never read:
            # the kernel only visits the first rf roster columns)
            rpad = -roster.shape[1] % 128
            roster = jnp.pad(roster.astype(jnp.int32),
                             ((0, 0), (0, rpad)),
                             constant_values=n_pad + lanes)
        interpret = jax.default_backend() != "tpu"
        outs = pk.downtime_eval(
            up_succ, full_succ, rf=rf, n_real=n_real,
            block_p=block_p or _pallas_block_p(R), interpret=interpret,
            roster=roster, want_repmask=want_repmask,
            want_rleader=want_rleader)
        return tuple(outs[:-1]) + (outs[-1][:, :n_pad],)
    raise ValueError(f"unknown PAC backend {backend!r}; "
                     f"expected one of {PAC_BACKENDS}")


def _rebuild_node_counts_impl(recruit, active, *, n_real: int,
                              backend: str = "jax"):
    """Per-node in-flight rebuild counts for the §6 bandwidth-contended
    rebuild model: recruit (B, P) int32 node ids (values outside
    [0, n_real) — the engine's no-recruit sentinel — are ignored), active
    (B, P) bool -> counts (B, n_real) int32, where counts[b, node] is the
    number of partitions of trial b whose active catch-up ingests on
    `node`.

    This is the downtime engine's first *cross-partition* reduction
    inside a step — but it stays strictly within a trial (rows never
    mix), so it commutes with trials-axis sharding; the 8-device proof
    lives in tests/test_sharded.py.  All three backends are bit-identical:
    the numpy/jnp implementations scatter-add, the Pallas kernel
    (kernels/pac_eval.py: node_count) accumulates one-hot compares over
    the partition columns — pure integer work either way.
    """
    if backend == "numpy":
        return rebuild_node_counts_np(recruit, active, n_real=n_real)
    if backend == "jax":
        return ref.rebuild_node_counts_ref(recruit, active, n_real=n_real)
    if backend == "pallas":
        from . import pac_eval as pk
        counts = pk.node_count(recruit, active, n_real=n_real,
                               interpret=jax.default_backend() != "tpu")
        return counts[:, :n_real]
    raise ValueError(f"unknown PAC backend {backend!r}; "
                     f"expected one of {PAC_BACKENDS}")


def client_latency_step(dirty, dt_i, avail, qok, rem, *, pow_tables, kf,
                        lamw, nbins: int, slo_ticks: int,
                        backend: str = "jax"):
    """The client-latency layer's post-step op (core/client_latency.py):
    one event interval of dirty-key decay + LARK first-touch charges and
    closed-form quorum rebuild-wait charges, under the same uniform
    three-backend contract as the other Monte Carlo batch ops.

    dirty (B, P, NB) float32 carried dirty-key fractions; dt_i (B,) int32
    interval lengths; avail / qok (B, P) bool (partition serving / replica
    majority up, both at interval start); rem (B, P) int32 remaining
    rebuild wall-ticks at interval start.  pow_tables / kf / lamw are the
    host-precomputed float32 workload tables (kernels/latency.py).
    Returns (new_dirty, dup, qhist, qslo, qsum) — see
    latency_step_ref for shapes.

    All three backends are bit-identical: the math is elementwise
    exactly-rounded float32 (shared verbatim from kernels/latency.py;
    the pallas path precomputes the decay factors with the identical jnp
    chain, then runs the charge kernel over flattened (trial, partition)
    rows).  No reduction crosses partitions — pooling happens host-side
    at chunk drains — so trials-axis sharding commutes exactly.
    """
    if backend == "numpy":
        return _latency.latency_step_ref(
            dirty, dt_i, avail, qok, rem, pow_tables=pow_tables, kf=kf,
            lamw=lamw, nbins=nbins, slo_ticks=slo_ticks, xp=np)
    if backend == "jax":
        out = _latency.latency_step_ref(
            dirty, dt_i, avail, qok, rem, pow_tables=pow_tables, kf=kf,
            lamw=lamw, nbins=nbins, slo_ticks=slo_ticks, xp=jnp)
        # XLA's CPU backend contracts `acc + rate * (a - b)` into an FMA,
        # which rounds differently from numpy's separate mul-then-add.
        # The engine accumulates every charge we return, so pin the op
        # boundary: nothing may fuse across it.
        return jax.lax.optimization_barrier(out)
    if backend == "pallas":
        from . import pac_eval as pk
        B, P, NB = dirty.shape
        R = B * P
        decay = _latency.decay_from_dt(dt_i, pow_tables, jnp)
        nd, dup, qh, qs, qq = pk.latency_charge(
            dirty.reshape(R, NB), decay.reshape(R, NB),
            avail.reshape(R), qok.reshape(R), rem.reshape(R),
            jnp.broadcast_to(dt_i[:, None], (B, P)).reshape(R),
            jnp.broadcast_to(lamw[None, :], (B, P)).reshape(R),
            kf, nbins=nbins, slo_ticks=slo_ticks,
            interpret=jax.default_backend() != "tpu")
        # same FMA-contraction pin as the jax branch: in interpret mode
        # the kernel body inlines into the surrounding jit
        return jax.lax.optimization_barrier(
            (nd.reshape(B, P, NB), dup.reshape(B, P, NB),
             qh.reshape(B, P, nbins), qs.reshape(B, P),
             qq.reshape(B, P)))
    raise ValueError(f"unknown PAC backend {backend!r}; "
                     f"expected one of {PAC_BACKENDS}")


# ---------------------------------------------------------------------------
# Unified step API: StepSpec -> step_eval.
#
# One frozen spec names everything the per-step evaluation depends on —
# metric, replication/voter counts, rebuild model, and the state layout
# (boolean tiles vs bit-packed words) — and one dispatcher maps it onto
# the backend matrix.  The three legacy entry points below are thin
# deprecated wrappers over this.
# ---------------------------------------------------------------------------

STEP_METRICS = ("availability", "downtime")
STEP_REBUILD_MODELS = ("fixed", "reconfig")
#: protocol-zoo engines a downtime StepSpec can additionally evaluate —
#: each adds one int32 row output between nrep and creps
STEP_ENGINES = ("hermes", "spinnaker")


@dataclass(frozen=True)
class StepSpec:
    """Everything the per-step kernel dispatch depends on, in one frozen
    value (hashable: usable as a cache/jit key).

    metric         "availability" (§5.1 PAC + majority baseline) or
                   "downtime" (§6 commit-pause: + leader/nrep outputs)
    rf             replication factor (roster width)
    n_real         real node count; lanes/bits >= n_real are padding
    voters         majority-baseline voter count; None resolves to the
                   paper's 2*(rf-1)+1 for availability and rf for
                   downtime (the quorum-log replica-set vote)
    rebuild_model  "fixed" or "reconfig"; reconfig is what carries a
                   roster into the eval and (with bandwidth contention)
                   folds rebuild node counts into the step
    packed         False: boolean (R, n_pad) tiles.  True: bit-packed
                   (B, W, P) uint32 words (kernels/bitpack.py) — layout
                   only, every output bit-identical
    dupres_ticks / rebuild_steps
                   §6 engine knobs carried for provenance (they shape
                   the step *around* the eval, not the eval itself;
                   kept here so one spec names the whole step)
    engines        protocol-zoo engines riding the downtime eval
                   (subset of STEP_ENGINES).  "hermes" requests the
                   first-rf membership bitmask (repmask; needs rf <= 30
                   so the mask fits a non-negative int32); "spinnaker"
                   requests the electable roster leader (rleader; needs
                   rebuild_model="reconfig" — it elects among the
                   carried roster).  Both extras land between nrep and
                   creps in every kernel body.
    """
    metric: str
    rf: int
    n_real: int
    voters: Optional[int] = None
    rebuild_model: str = "fixed"
    packed: bool = False
    dupres_ticks: int = 0
    rebuild_steps: int = 0
    engines: tuple = ()

    def __post_init__(self):
        if self.metric not in STEP_METRICS:
            raise ValueError(f"unknown step metric {self.metric!r}; "
                             f"expected one of {STEP_METRICS}")
        if self.rebuild_model not in STEP_REBUILD_MODELS:
            raise ValueError(
                f"unknown rebuild_model {self.rebuild_model!r}; "
                f"expected one of {STEP_REBUILD_MODELS}")
        if not 1 <= self.rf <= self.n_real:
            raise ValueError(
                f"rf={self.rf} must be in [1, n_real={self.n_real}]")
        if self.voters is not None and self.voters < 1:
            raise ValueError(f"voters={self.voters} must be >= 1")
        if self.dupres_ticks < 0 or self.rebuild_steps < 0:
            raise ValueError("dupres_ticks / rebuild_steps must be >= 0")
        object.__setattr__(self, "engines", tuple(self.engines))
        for e in self.engines:
            if e not in STEP_ENGINES:
                raise ValueError(f"unknown step engine {e!r}; "
                                 f"expected a subset of {STEP_ENGINES}")
        if len(set(self.engines)) != len(self.engines):
            raise ValueError(f"duplicate step engines: {self.engines}")
        if self.engines and self.metric != "downtime":
            raise ValueError("protocol-zoo engines are downtime-metric "
                             "outputs; availability spec can't request "
                             f"{self.engines}")
        if "hermes" in self.engines and self.rf > 30:
            raise ValueError(f"hermes needs rf <= 30 (membership bitmask "
                             f"in a non-negative int32); got rf={self.rf}")
        if "spinnaker" in self.engines and self.rebuild_model != "reconfig":
            raise ValueError("spinnaker elects among the carried roster; "
                             "it requires rebuild_model='reconfig'")

    @property
    def resolved_voters(self) -> int:
        if self.voters is not None:
            return self.voters
        return 2 * (self.rf - 1) + 1 if self.metric == "availability" \
            else self.rf

    @property
    def fused_kernel(self) -> str:
        """The fused-kernel kind this spec dispatches to (autotune key)."""
        if self.metric == "availability":
            return "fused_pac"
        return "fused_downtime_roster" if self.rebuild_model == "reconfig" \
            else "fused_downtime"

    @property
    def want_repmask(self) -> bool:
        return "hermes" in self.engines

    @property
    def want_rleader(self) -> bool:
        return "spinnaker" in self.engines


class StepOutputs(NamedTuple):
    """step_eval's full output surface; slots a spec doesn't produce are
    None (availability: leader/leader_full/nrep; no recruit: counts;
    engines without hermes/spinnaker: repmask/rleader — and rleader stays
    None on roster-less calls even under a spinnaker spec, since it
    elects among the carried roster)."""
    lark: object
    maj: object
    leader: object = None
    leader_full: object = None
    nrep: object = None
    creps: object = None
    counts: object = None
    repmask: object = None
    rleader: object = None


def _fused_block_t(B: int) -> int:
    """Heuristic trial-block: largest power of two <= 8 dividing B."""
    bt = 1
    while bt < 8 and B % (bt * 2) == 0:
        bt *= 2
    return bt


def _packed_planes(words, xp):
    W = words.shape[1]
    return [words[:, k, :] for k in range(W)]


def _take_extras(outs, want_repmask: bool, want_rleader: bool):
    """Pull the protocol-zoo extras out of a kernel's (lark, qmaj, leader,
    leader_full, nrep, *extras, creps[, counts]) tuple."""
    k = 5
    repmask = rleader = None
    if want_repmask:
        repmask = outs[k]
        k += 1
    if want_rleader:
        rleader = outs[k]
    return repmask, rleader


def step_eval(spec: StepSpec, up, full, *, roster=None, recruit=None,
              active=None, backend: str = "jax",
              block_p: Optional[int] = None,
              block_t: Optional[int] = None) -> StepOutputs:
    """Evaluate one Monte Carlo step under `spec` on the chosen backend.

    Boolean layout (spec.packed=False): up/full are (R, n_pad) bool
    rank-space tiles, roster (R, rf) int32, and outputs are (R,) /
    (R, n_pad) — exactly the legacy pac_eval_batch / downtime_eval_batch
    contract.  recruit/active ((B, P) int32/bool) additionally request
    the bandwidth-model node counts (legacy rebuild_node_counts).

    Packed layout (spec.packed=True): up/full are (B, W, P) uint32 word
    planes (bit b of word k = succession rank 32k+b; pack with
    bitpack.pack_words + moveaxis), roster is the engine's carried
    (B, P, rf) int32 rank tensor, and row outputs are (B, P) with creps
    returned as (B, W, P) words.  backend="pallas" runs the fused step
    megakernel — one pallas_call for eval + roster + counts; numpy/jax
    run the identical bitpack.py math plane-wise.  Counts inputs stay
    unpacked (B, P) in every layout.

    Every cell of the (metric x backend x layout) matrix is bit-identical
    to every other; packing and fusion change bytes moved, never results
    (tests/test_bitpack.py, tests/test_step_api.py).
    """
    if spec.metric == "downtime" and spec.rebuild_model != "reconfig" \
            and roster is not None:
        raise ValueError("roster is only meaningful for "
                         "rebuild_model='reconfig'")
    if (recruit is None) != (active is None):
        raise ValueError("recruit and active must be passed together")
    if spec.metric == "availability" and recruit is not None:
        raise ValueError("rebuild node counts are a downtime-engine "
                         "output; availability spec can't request them")

    # rleader elects among the carried roster, so a roster-less call
    # (e.g. the engines' t=0 init eval) simply doesn't produce it
    want_rm = spec.want_repmask
    want_rl = spec.want_rleader and roster is not None

    if not spec.packed:
        counts = None
        if recruit is not None:
            counts = _rebuild_node_counts_impl(recruit, active,
                                               n_real=spec.n_real,
                                               backend=backend)
        if spec.metric == "availability":
            lark, maj, creps = _pac_eval_unpacked(
                up, full, rf=spec.rf, voters=spec.resolved_voters,
                n_real=spec.n_real, backend=backend, block_p=block_p)
            return StepOutputs(lark=lark, maj=maj, creps=creps,
                               counts=counts)
        outs = _downtime_eval_unpacked(
            up, full, rf=spec.rf, n_real=spec.n_real, backend=backend,
            block_p=block_p, roster=roster, want_repmask=want_rm,
            want_rleader=want_rl)
        repmask, rleader = _take_extras(outs, want_rm, want_rl)
        return StepOutputs(lark=outs[0], maj=outs[1], leader=outs[2],
                           leader_full=outs[3], nrep=outs[4],
                           creps=outs[-1], counts=counts,
                           repmask=repmask, rleader=rleader)

    # ---- packed (B, W, P) word layout ----
    if backend not in PAC_BACKENDS:
        raise ValueError(f"unknown PAC backend {backend!r}; "
                         f"expected one of {PAC_BACKENDS}")
    B, W, P = up.shape
    if backend == "pallas":
        from . import fused_step
        interpret = jax.default_backend() != "tpu"
        bt = block_t or _fused_block_t(B)
        bp = block_p or _pallas_block_p(P)
        if spec.metric == "availability":
            lark, maj, crepsw = fused_step.fused_pac_eval(
                up, full, rf=spec.rf, voters=spec.resolved_voters,
                n_real=spec.n_real, block_t=bt, block_p=bp,
                interpret=interpret)
            return StepOutputs(lark=lark, maj=maj, creps=crepsw)
        rost = None if roster is None else jnp.moveaxis(roster, -1, 1)
        outs = fused_step.fused_downtime_eval(
            up, full, rf=spec.rf, n_real=spec.n_real, block_t=bt,
            block_p=bp, interpret=interpret, roster=rost,
            recruit=recruit, active=active, want_repmask=want_rm,
            want_rleader=want_rl)
        repmask, rleader = _take_extras(outs, want_rm, want_rl)
        ncr = 6 + int(want_rm) + int(want_rl)
        counts = outs[ncr][:, :spec.n_real] if recruit is not None \
            else None
        return StepOutputs(lark=outs[0], maj=outs[1], leader=outs[2],
                           leader_full=outs[3], nrep=outs[4],
                           creps=outs[ncr - 1], counts=counts,
                           repmask=repmask, rleader=rleader)

    xp = np if backend == "numpy" else jnp
    u, f = _packed_planes(up, xp), _packed_planes(full, xp)
    counts = None
    if recruit is not None:
        counts = _rebuild_node_counts_impl(recruit, active,
                                           n_real=spec.n_real,
                                           backend=backend)
    if spec.metric == "availability":
        lark, maj, creps = bitpack.pac_eval_packed(
            u, f, rf=spec.rf, voters=spec.resolved_voters,
            n_real=spec.n_real, xp=xp)
        return StepOutputs(lark=lark, maj=maj,
                           creps=xp.stack(creps, axis=1), counts=counts)
    rost = None if roster is None else \
        [roster[..., j] for j in range(spec.rf)]
    outs = bitpack.downtime_eval_packed(
        u, f, rf=spec.rf, n_real=spec.n_real, roster=rost,
        want_repmask=want_rm, want_rleader=want_rl, xp=xp)
    repmask, rleader = _take_extras(outs, want_rm, want_rl)
    return StepOutputs(lark=outs[0], maj=outs[1], leader=outs[2],
                       leader_full=outs[3], nrep=outs[4],
                       creps=xp.stack(outs[-1], axis=1), counts=counts,
                       repmask=repmask, rleader=rleader)


# ---------------------------------------------------------------------------
# 2-D fused-kernel autotuner (block_trials x block_p) with fused-kernel
# VMEM accounting — the block_p tuner generalized to the megakernel.
# ---------------------------------------------------------------------------

#: fused-kernel kinds the 2-D tuner can race (StepSpec.fused_kernel)
FUSED_KERNELS = ("fused_pac", "fused_downtime", "fused_downtime_roster")


def fused_vmem_bytes(block_t: int, block_p: int, n_pad: int, *,
                     rf: int = 3, kernel: str = "fused_pac") -> int:
    """VMEM live for one fused (block_t, W, block_p) step tile: packed
    up/full inputs + creps output (3 word tiles, uint32), the row
    outputs, and — per kernel kind — the roster tile, recruit/active
    rows, and the revisited (block_t, n_lanes) counts block.  The packed
    budget is dominated by 3*W words where the boolean kernel held
    4 n_lanes-wide int32 tiles — the fusion's VMEM headroom is what lets
    block_t * block_p grow past the 1-D tuner's ceiling."""
    W = bitpack.n_words(n_pad)
    n_lanes = _pac_lane_pad(n_pad)
    words = 3 * block_t * W * block_p * 4
    rows = 6 * block_t * block_p * 4
    if kernel == "fused_downtime_roster":
        rows += block_t * rf * block_p * 4            # roster tile
        rows += 2 * block_t * block_p * 4             # recruit + active
        rows += block_t * n_lanes * 4                 # counts accumulator
    return words + rows


def fused_block_candidates(B: int, P: int, n_pad: int, *, rf: int = 3,
                           kernel: str = "fused_pac",
                           max_block_t: int = 16, max_block_p: int = 1024,
                           vmem_limit_bytes: int = 8 * 2 ** 20):
    """Power-of-two (block_t, block_p) pairs that tile (B, P) within the
    fused-kernel VMEM budget — deterministic pure function of the shape,
    like block_p_candidates."""
    cands = []
    bt = 1
    while bt <= min(B, max_block_t):
        if B % bt == 0:
            bp = 8
            while bp <= min(P, max_block_p):
                if P % bp == 0 and fused_vmem_bytes(
                        bt, bp, n_pad, rf=rf,
                        kernel=kernel) <= vmem_limit_bytes:
                    cands.append((bt, bp))
                bp *= 2
        bt *= 2
    return tuple(cands) or ((_fused_block_t(B), _pallas_block_p(P)),)


@dataclass(frozen=True)
class FusedAutotuneResult:
    block_t: int
    block_p: int
    timings_us: Mapping[Tuple[int, int], float]
    source: str                       # "measured" | "heuristic-fallback"


def _measure_fused_block(B: int, P: int, n_pad: int, bt: int, bp: int, *,
                         rf: int, voters: int, n_real: int, iters: int,
                         kernel: str) -> float:
    """Median µs/call of the fused megakernel at one (bt, bp) tile, on the
    same deterministic counter-hash density pattern the 1-D tuner uses,
    packed to words."""
    import time

    from . import fused_step
    idx = (jnp.arange(B * P, dtype=jnp.uint32)[:, None]
           * jnp.uint32(n_pad)
           + jnp.arange(n_pad, dtype=jnp.uint32)[None, :])
    up = ((idx * jnp.uint32(2654435761) % jnp.uint32(97)) < 90) \
        .reshape(B, P, n_pad)
    full = ((idx * jnp.uint32(40503) % jnp.uint32(89)) < 30) \
        .reshape(B, P, n_pad)
    upw = jnp.moveaxis(bitpack.pack_words(up, jnp), -1, 1)
    fullw = jnp.moveaxis(bitpack.pack_words(full, jnp), -1, 1)
    interpret = jax.default_backend() != "tpu"
    if kernel == "fused_pac":
        fn = jax.jit(functools.partial(
            fused_step.fused_pac_eval, rf=rf, voters=voters,
            n_real=n_real, block_t=bt, block_p=bp, interpret=interpret))
        args = (upw, fullw)
    elif kernel in ("fused_downtime", "fused_downtime_roster"):
        kw = dict(rf=rf, n_real=n_real, block_t=bt, block_p=bp,
                  interpret=interpret)
        fn = jax.jit(functools.partial(fused_step.fused_downtime_eval,
                                       **kw))
        if kernel == "fused_downtime_roster":
            roster = jnp.broadcast_to(
                jnp.arange(rf, dtype=jnp.int32)[None, :, None],
                (B, rf, P))
            recruit = (jnp.arange(B * P, dtype=jnp.int32) % (n_real + 1)) \
                .reshape(B, P)
            active = (recruit % 3) != 0
            args = (upw, fullw)
            fn = jax.jit(functools.partial(
                fused_step.fused_downtime_eval, roster=roster,
                recruit=recruit, active=active, **kw))
        else:
            args = (upw, fullw)
    else:
        raise ValueError(f"unknown fused autotune kernel {kernel!r}; "
                         f"expected one of {FUSED_KERNELS}")
    jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def autotune_fused_blocks(B: int, P: int, n_pad: int, *, rf: int,
                          voters: int, n_real: int, candidates=None,
                          iters: int = 9, measure=None,
                          force: bool = False,
                          kernel: str = "fused_pac") -> FusedAutotuneResult:
    """Pick the fastest (block_t, block_p) pair for the fused megakernel
    on a (B, P) packed grid.

    Mirrors autotune_block_p's determinism contract: pure-function
    candidate set, median-of-iters timing, ties toward the smaller tile
    (block_t then block_p), per-(shape, params, kernel) process cache,
    heuristic fallback off-TPU unless forced.  The cache key is tagged
    "fused" and includes the kernel kind and the full 2-D geometry, so it
    can never alias a 1-D block_p entry — the wrong-kernel race fix
    extends to the fused family.
    """
    if kernel not in FUSED_KERNELS:
        raise ValueError(f"unknown fused autotune kernel {kernel!r}; "
                         f"expected one of {FUSED_KERNELS}")
    cands = tuple(candidates) if candidates is not None else \
        fused_block_candidates(B, P, n_pad, rf=rf, kernel=kernel)
    if not cands:
        raise ValueError("autotune_fused_blocks needs at least one "
                         "candidate")
    for bt, bp in cands:
        if B % bt or P % bp:
            raise ValueError(f"candidate ({bt}, {bp}) does not tile "
                             f"(B={B}, P={P})")
    key = ("fused", kernel, B, P, n_pad, rf, voters, n_real, cands, force)
    if measure is None and key in _AUTOTUNE_CACHE:
        return _AUTOTUNE_CACHE[key]
    if measure is None:
        if jax.default_backend() != "tpu" and not force:
            res = FusedAutotuneResult(block_t=_fused_block_t(B),
                                      block_p=_pallas_block_p(P),
                                      timings_us={},
                                      source="heuristic-fallback")
            _AUTOTUNE_CACHE[key] = res
            return res
        measure = functools.partial(_measure_fused_block, rf=rf,
                                    voters=voters, n_real=n_real,
                                    iters=iters, kernel=kernel)
        timings = {c: measure(B, P, n_pad, *c) for c in cands}
        best = min(sorted(timings), key=lambda c: (timings[c], c))
        res = FusedAutotuneResult(block_t=best[0], block_p=best[1],
                                  timings_us=timings, source="measured")
        _AUTOTUNE_CACHE[key] = res
        return res
    timings = {c: float(measure(B, P, n_pad, *c)) for c in cands}
    best = min(sorted(timings), key=lambda c: (timings[c], c))
    return FusedAutotuneResult(block_t=best[0], block_p=best[1],
                               timings_us=timings, source="measured")


def step_hbm_bytes(spec: StepSpec, B: int, P: int, n_pad: int) -> dict:
    """Analytic HBM bytes one step's eval pipeline moves, unfused-boolean
    vs fused-packed — the roofline story behind the megakernel.

    Unfused counts every separate launch the boolean path pays: the eval
    kernel reads up/full/valid int32 lane tiles and writes the creps lane
    tile (+ row outputs), the reconfig roster rides as a lane-padded
    int32 tile, and the bandwidth model's node-count kernel re-reads
    recruit/active in its own pass.  Fused-packed moves three W-word
    uint32 tensors (up, full, creps) plus rows — once.  Ratio ~= the
    round-trip win the kernel_bench fused rows measure.
    """
    R = B * P
    n_lanes = _pac_lane_pad(n_pad)
    reconfig = spec.metric == "downtime" and spec.rebuild_model == "reconfig"
    rows_out = (2 if spec.metric == "availability" else 5) * R * 4
    # boolean path: pac_eval.py materializes up/full/valid as int32 lanes
    unfused = 3 * R * n_lanes * 4 + R * n_lanes * 4 + rows_out
    if reconfig:
        rf_pad = spec.rf + (-spec.rf % 128)
        unfused += R * rf_pad * 4                       # roster tile
        unfused += 2 * R * 4 + B * n_lanes * 4          # node_count pass
    W = bitpack.n_words(n_pad)
    fused = 3 * B * W * P * 4 + rows_out
    if reconfig:
        fused += B * spec.rf * P * 4                    # unpadded roster
        fused += 2 * B * P * 4 + B * n_lanes * 4        # folded counts
    return {"unfused_bytes": unfused, "fused_bytes": fused,
            "ratio": unfused / fused}


# ---------------------------------------------------------------------------
# Legacy per-kernel entry points — thin deprecated wrappers over step_eval.
# ---------------------------------------------------------------------------

def _deprecated(old: str):
    warnings.warn(
        f"kernels.ops.{old} is deprecated; build a StepSpec and call "
        "kernels.ops.step_eval (one entry point for every metric/"
        "backend/layout)", DeprecationWarning, stacklevel=3)


def pac_eval_batch(up_succ, full_succ, *, rf: int, voters: int, n_real: int,
                   backend: str = "jax", block_p: Optional[int] = None):
    """Deprecated: StepSpec(metric="availability") + step_eval.

    Kept as a thin wrapper so existing callers get the identical
    (lark, maj, creps) tuple; see _pac_eval_unpacked for the contract.
    """
    _deprecated("pac_eval_batch")
    spec = StepSpec(metric="availability", rf=rf, voters=voters,
                    n_real=n_real)
    out = step_eval(spec, up_succ, full_succ, backend=backend,
                    block_p=block_p)
    return out.lark, out.maj, out.creps


def downtime_eval_batch(up_succ, full_succ, *, rf: int, n_real: int,
                        backend: str = "jax",
                        block_p: Optional[int] = None, roster=None):
    """Deprecated: StepSpec(metric="downtime") + step_eval.

    Kept as a thin wrapper so existing callers get the identical
    (lark, qmaj, leader, leader_full, nrep, creps) tuple; see
    _downtime_eval_unpacked for the contract.
    """
    _deprecated("downtime_eval_batch")
    spec = StepSpec(metric="downtime", rf=rf, n_real=n_real,
                    rebuild_model="reconfig" if roster is not None
                    else "fixed")
    out = step_eval(spec, up_succ, full_succ, roster=roster,
                    backend=backend, block_p=block_p)
    return (out.lark, out.maj, out.leader, out.leader_full, out.nrep,
            out.creps)


def rebuild_node_counts(recruit, active, *, n_real: int,
                        backend: str = "jax"):
    """Deprecated: thin wrapper over the counts path step_eval folds into
    the fused kernel; see _rebuild_node_counts_impl for the contract."""
    _deprecated("rebuild_node_counts")
    return _rebuild_node_counts_impl(recruit, active, n_real=n_real,
                                     backend=backend)
