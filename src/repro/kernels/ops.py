"""Jit'd dispatch wrappers: Pallas kernel on TPU, pure-jnp oracle elsewhere.

The CPU container validates kernels in ``interpret=True`` mode (tests) while
models/benchmarks/dry-runs use the jnp oracle path — identical math, so the
lowered HLO is an honest stand-in and the TPU kernel is a drop-in swap.

Set ``REPRO_FORCE_PALLAS=interpret`` to route model code through the
interpreted kernels (slow; tests only).
"""
from __future__ import annotations

import functools
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import ref

_FORCE = os.environ.get("REPRO_FORCE_PALLAS", "")


def _mode() -> str:
    """'kernel' | 'interpret' | 'ref'."""
    if _FORCE == "interpret":
        return "interpret"
    if _FORCE == "ref":
        return "ref"
    return "kernel" if jax.default_backend() == "tpu" else "ref"


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    scale: Optional[float] = None):
    mode = _mode()
    if mode != "ref":
        from . import flash_attention as fk
        return fk.flash_attention(q, k, v, causal=causal, window=window,
                                  scale=scale, interpret=(mode == "interpret"))
    return ref.attention_ref(q, k, v, causal=causal, window=window, scale=scale)


def mlstm_chunkwise(q, k, v, log_f, log_i, *, chunk: int = 256, initial=None):
    mode = _mode()
    if mode != "ref":
        from . import mlstm_chunk as mk
        return mk.mlstm_chunkwise(q, k, v, log_f, log_i, chunk=chunk,
                                  initial=initial, interpret=(mode == "interpret"))
    return ref.mlstm_chunkwise(q, k, v, log_f, log_i, chunk=chunk, initial=initial)


def mlstm_step(q, k, v, log_f, log_i, state):
    return ref.mlstm_step(q, k, v, log_f, log_i, state)


def rglru_scan(x, log_a):
    mode = _mode()
    if mode != "ref":
        from . import rglru_scan as rk
        return rk.rglru_scan(x, log_a, interpret=(mode == "interpret"))
    return ref.rglru_scan_ref(x, log_a)


def rglru_step(x, log_a, h):
    return ref.rglru_step(x, log_a, h)


def pac_eval(up, succ, full, rf: int, *, voters=None,
             conditions: Tuple[str, ...] = ("simple_majority",)):
    """Node-space PAC over (P, n) (protocol-level users)."""
    return ref.pac_eval_ref(up, succ, full, rf, voters=voters,
                            conditions=conditions)


def pac_eval_rank(up_succ, full_succ, *, rf: int, voters: int, n_real: int):
    """Rank-space PAC (availability Monte Carlo hot loop)."""
    mode = _mode()
    if mode != "ref":
        from . import pac_eval as pk
        return pk.pac_eval(up_succ, full_succ, rf=rf, voters=voters,
                           n_real=n_real, interpret=(mode == "interpret"))
    return ref.pac_eval_rank_ref(up_succ, full_succ, rf=rf, voters=voters,
                                 n_real=n_real)
