"""Client-latency charge math for the §6 per-key request layer.

core/client_latency.py layers a batched per-key request workload over the
downtime engine's trajectories: zipf key popularity mapped onto
partitions, a configurable read/write mix, and per-request commit-latency
charges drawn from the partition's protocol state each event interval.
The per-step state is one analytic "dirty key fraction" per
(trial, partition, key-popularity bucket) — O(B*P) carry, never a
per-request sample — so the whole layer is deterministic elementwise
float32/int32 arithmetic on the same counter-RNG trajectories every
backend replays.

This module holds the xp-generic math shared verbatim by the numpy and
jnp implementations AND by the Pallas kernel body
(kernels/pac_eval.py: latency_charge) — the bitpack.py pattern: one
source of truth, three executors.  It is jax-import-free so the numpy
path stays hermetic.

Bit-identity contract (docs/ARCHITECTURE.md, client-latency section):
every in-graph float op here is an exactly-rounded IEEE float32
multiply / add / subtract of values that are either carried state or
host-precomputed float32 constants (the per-(partition, bucket)
single-tick decay factors and their successive squares).  No
transcendental is ever evaluated in-graph — exp() happens once on the
host in float64 — and no float reduction crosses partitions inside the
scan (accumulators stay per-(B, P, ...); pooling over partitions happens
host-side in float64 at chunk drains).  That is what makes the latency
layer bit-identical across numpy / jax / pallas, across packed and
unpacked carries, and across any trials-axis device sharding.

Charge model per event interval of length dt (interval-start state):

  LARK    after a leader change onto a stale leader ("pen" in
          core/downtime_batched.py) every key of the partition is dirty:
          its first touch pays one dup-res round (`dupres_ticks`);
          later touches pay 0.  Carried per-bucket dirty fraction d_b
          decays as d_b * rho_b^dt while the partition serves, where
          rho_b = exp(-mu_b) is the per-tick probability a given bucket-b
          key is NOT touched (mu_b = lam_j * g_b / (K * f_b): partition
          request rate, bucket traffic share, keys per bucket).  The
          expected first-touch count charged over the interval is
          K * f_b * (d_b - d_b * rho_b^dt) <= the bucket's offered
          requests (1 - e^-x <= x).
  quorum  every write arriving while a rebuild is in flight (and the
          replica majority is up, i.e. commits would otherwise flow)
          waits out the remaining rebuild: a write landing tau ticks
          into the interval pays rem - tau ticks.  Writes arrive at
          lamw_j per tick (under `write_skew` that rate already carries
          the per-partition mix — the skew needs no in-scan change);
          paying ticks, power-of-two latency buckets,
          the SLO-violation count, and the latency sum are all closed
          forms in (rem, dt) — integer comparisons plus float32 scaling.
  hermes  reads never pay (local reads); the write path is derived
          host-side as the write-fraction share of LARK's first-touch
          charges (core/client_latency.py).
"""
from __future__ import annotations

import numpy as np

#: int32 "open-ended top bucket" upper edge
_I32_MAX = 2 ** 31 - 1

#: subnormal guard: XLA's CPU/TPU backends run float32 math with
#: FTZ/DAZ (subnormals flush to zero), numpy honors gradual underflow —
#: the one way "exactly-rounded elementwise f32" can still diverge.  The
#: dirty-fraction state decays geometrically toward 0, so it WILL cross
#: the subnormal range; we flush it to exact 0 at a floor comfortably
#: above 2^-126, identically on every backend, before the difference can
#: reach a charge.  Host-built decay tables get the same flush so DAZ
#: never sees a subnormal input either.
_SUBNORMAL_FLOOR = np.float32(1e-30)


# ---------------------------------------------------------------------------
# Host-side (numpy, float64 -> float32) precomputation
# ---------------------------------------------------------------------------

def decay_pow_tables(lam, g, f, keys_per_partition: int,
                     max_ticks: int) -> np.ndarray:
    """(nbits, P, NB) float32 successive squares of the per-tick key
    survival probability rho_{j,b} = exp(-lam_j * g_b / (K * f_b)).

    Table i holds rho^(2^i); `decay_from_dt` selects the bits of dt and
    multiplies, so rho^dt is a fixed-order chain of exactly-rounded
    float32 multiplies — identical on every backend.  The exp() runs
    here, host-side, in float64; the in-graph math never sees a
    transcendental.  nbits covers dt <= max_ticks (an event interval
    never exceeds the horizon)."""
    lam = np.asarray(lam, dtype=np.float64)
    g = np.asarray(g, dtype=np.float64)
    f = np.asarray(f, dtype=np.float64)
    mu = lam[:, None] * g[None, :] / (keys_per_partition * f[None, :])
    rho = np.exp(-mu).astype(np.float32)                     # (P, NB)
    nbits = max(1, int(max_ticks).bit_length())
    tabs = np.empty((nbits,) + rho.shape, dtype=np.float32)
    t = np.where(rho >= _SUBNORMAL_FLOOR, rho, np.float32(0.0))
    for i in range(nbits):
        tabs[i] = t
        t = t * t                                            # float32
        t = np.where(t >= _SUBNORMAL_FLOOR, t, np.float32(0.0))
    return tabs


# ---------------------------------------------------------------------------
# xp-generic in-graph math (numpy / jnp / Pallas kernel body)
# ---------------------------------------------------------------------------

def decay_from_dt(dt, pow_tables, xp):
    """rho^dt per (trial, partition, bucket): dt (B,) int32,
    pow_tables (nbits, P, NB) float32 -> (B, P, NB) float32 via binary
    exponentiation over the precomputed squares.  Multiplying by an exact
    1.0 where a bit is clear is the identity in IEEE float32, so the
    chain length is static and the product order fixed."""
    nbits = pow_tables.shape[0]
    one = xp.float32(1.0)
    dec = None
    for i in range(nbits):
        bit = ((dt >> i) & 1) > 0                             # (B,)
        fac = xp.where(bit[:, None, None], pow_tables[i][None], one)
        dec = fac if dec is None else dec * fac
    return dec


def dirty_step(dirty, decay, avail, kf, xp):
    """One interval of dirty-fraction decay + LARK first-touch charges.

    dirty, decay: (..., NB) float32; avail broadcastable bool (requests
    only flow — and keys only get cleaned — while the partition serves);
    kf broadcastable float32 keys-per-bucket (K * f_b).  Returns
    (new_dirty, dup): dup is the expected first-touch request count
    charged this interval, computed as kf * (dirty - new_dirty) — the
    SAME subtraction on every backend, so the rounding is too.  The
    decayed fraction is flushed to exact 0 below _SUBNORMAL_FLOOR before
    the charge is taken — see the constant's note: without this, XLA's
    FTZ and numpy's gradual underflow round the geometric decay
    differently once it crosses 2^-126."""
    one = xp.float32(1.0)
    zero = xp.float32(0.0)
    dec = xp.where(avail, decay, one)
    new_dirty = dirty * dec
    new_dirty = xp.where(new_dirty >= xp.float32(_SUBNORMAL_FLOOR),
                         new_dirty, zero)
    # max(x, 0) is the identity (dirty >= new_dirty >= 0) but also an
    # FMA fence: the engine accumulates this charge with a float32 add,
    # and XLA's CPU codegen contracts a bare `acc + rate * (a - b)` into
    # an FMA whose rounding numpy cannot reproduce — even across an
    # optimization_barrier.  An fmax between the multiply and the add
    # pins the product to an exactly-rounded float32 on every backend.
    dup = xp.maximum(kf * (dirty - new_dirty), zero)
    return new_dirty, dup


def quorum_step(rem, dt, qok, lamw, lanes, *, nbins: int, slo_ticks: int,
                xp):
    """Quorum-side closed-form charges for one interval.

    rem, dt, qok, lamw: (..., 1); lanes: broadcastable int32 bucket
    indices (iota over the last axis).  A write arriving tau in [0, dt)
    ticks into the interval pays max(rem - tau, 0) remaining rebuild
    wall-ticks, gated on the replica majority being up (qok — otherwise
    the partition is down outright and the request is not a commit).

    Returns (qhist, qslo, qsum):
      qhist  (..., L) float32 expected requests landing in power-of-two
             latency bucket k = [2^k, 2^(k+1)) (top bucket open-ended);
             lanes >= nbins are padding and yield exact 0.
      qslo   (..., 1) expected requests with latency STRICTLY > slo_ticks
             (slo_cnt = max(min(dt, rem - slo_ticks), 0): a write paying
             exactly slo_ticks does not violate; slo_ticks=0 therefore
             counts every request with any added latency — a live
             threshold, not a disable switch, pinned by
             tests/test_client_latency.py).
      qsum   (..., 1) expected total latency ticks (for the mean).
    All counts are integer tick arithmetic scaled once by the float32
    write rate — deterministic on every backend."""
    zero = xp.float32(0.0)
    half = xp.float32(0.5)
    onef = xp.float32(1.0)
    pay = xp.maximum(xp.minimum(dt, rem), 0)          # paying ticks
    k = xp.minimum(lanes, nbins - 1)
    lo = xp.left_shift(xp.int32(1), k)
    hi = xp.where(k == nbins - 1, xp.int32(_I32_MAX), 2 * lo - 1)
    # paying writes see remaining values rem, rem-1, ..., rem-pay+1;
    # the count inside [lo, hi] is a clipped interval intersection
    cnt = xp.minimum(rem, hi) - xp.maximum(rem - pay + 1, lo) + 1
    cnt = xp.where(qok & (lanes < nbins), xp.maximum(cnt, 0), 0)
    # every return below is accumulated by a float32 add in the engine;
    # the trailing max(x, 0) (exact — all charges are >= 0) is an FMA
    # fence, see dirty_step.
    qhist = xp.maximum(lamw * cnt.astype(xp.float32), zero)
    payf = pay.astype(xp.float32)
    remf = rem.astype(xp.float32)
    qsum = xp.where(qok, lamw * (payf * remf - half * payf * (payf - onef)),
                    zero)
    qsum = xp.maximum(qsum, zero)
    slo_cnt = xp.maximum(xp.minimum(dt, rem - slo_ticks), 0)
    qslo = xp.maximum(xp.where(qok, lamw * slo_cnt.astype(xp.float32), zero),
                      zero)
    return qhist, qslo, qsum


def latency_step_ref(dirty, dt_i, avail, qok, rem, *, pow_tables, kf,
                     lamw, nbins: int, slo_ticks: int, xp):
    """The full per-interval latency update on (B, P)-shaped state —
    the numpy/jnp reference the Pallas path must match bit for bit.

    dirty (B, P, NB) f32; dt_i (B,) i32; avail, qok (B, P) bool;
    rem (B, P) i32 remaining rebuild wall-ticks at interval start;
    pow_tables (nbits, P, NB) f32; kf (NB,) f32; lamw (P,) f32.
    Returns (new_dirty, dup, qhist, qslo, qsum) with shapes
    (B,P,NB), (B,P,NB), (B,P,nbins), (B,P), (B,P)."""
    decay = decay_from_dt(dt_i, pow_tables, xp)
    new_dirty, dup = dirty_step(dirty, decay, avail[:, :, None],
                                kf[None, None, :], xp)
    lanes = xp.arange(nbins, dtype=xp.int32)
    qhist, qslo, qsum = quorum_step(
        rem[:, :, None], dt_i[:, None, None], qok[:, :, None],
        lamw[None, :, None], lanes, nbins=nbins, slo_ticks=slo_ticks,
        xp=xp)
    return new_dirty, dup, qhist, qslo[:, :, 0], qsum[:, :, 0]
