"""Pallas TPU flash attention (forward): causal / sliding-window, online
softmax, f32 accumulation.

Tiling: grid = (B, H, Sq/block_q, Sk/block_k); the k-block axis is the
innermost (sequential on TPU), so the (block_q, D) accumulator, running max
and denominator live in VMEM scratch across k iterations — the standard
grid-accumulate flash pattern.  Inputs are (B, H, S, D); the ops.py wrapper
transposes from the model's (B, S, H, D) layout and expands GQA groups.

Backward runs through the jnp oracle via custom_vjp (recompute; the paper's
contribution is protocol-level — fwd is the serving hot path).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale: float, causal: bool, window: int,
                  block_q: int, block_k: int, num_k_blocks: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)                   # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)                   # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)                   # (bk, Dv)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones(s.shape, jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG)

    m_prev = m_ref[...]                                   # (bq,)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == num_k_blocks - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal=True, window=0,
                        scale: Optional[float] = None,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = False):
    """q,k,v: (B, H, S, D) same head count.  Returns (B, H, Sq, Dv)."""
    B, H, Sq, D = q.shape
    Sk, Dv = k.shape[2], v.shape[3]
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    nq, nk = Sq // block_q, Sk // block_k

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, num_k_blocks=nk)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, Dv), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, Dv),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, Dv), jnp.float32),   # acc
            pltpu.VMEM((block_q,), jnp.float32),      # running max
            pltpu.VMEM((block_q,), jnp.float32),      # denominator
        ],
        interpret=interpret,
    )(q, k, v)


def flash_attention(q, k, v, *, causal=True, window=0, scale=None,
                    interpret=False, block_q=128, block_k=128):
    return flash_attention_fwd(q, k, v, causal=causal, window=window,
                               scale=scale, block_q=block_q, block_k=block_k,
                               interpret=interpret)
