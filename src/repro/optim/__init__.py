from .optimizer import adafactor, adamw, clip_by_global_norm, make_optimizer, warmup_cosine

__all__ = ["adamw", "adafactor", "make_optimizer", "warmup_cosine",
           "clip_by_global_norm"]
