"""Optimizers (no external deps): AdamW and Adafactor, schedules, clipping.

Interface mirrors optax: ``opt.init(params) -> state``,
``opt.update(grads, state, params) -> (updates, state)`` where updates are
*added* to params.  Moment dtypes are configurable so big-model configs
(nemotron-340b, qwen3-235b) fit the 16 GB/chip HBM budget.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params) -> (updates, new_state)


def warmup_cosine(peak_lr: float, warmup: int = 100, total: int = 10_000,
                  floor: float = 0.1):
    def lr(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
        warm = peak_lr * (step + 1) / warmup
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


def adamw(lr: Callable, *, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
          moment_dtype="float32") -> Optimizer:
    mdt = jnp.dtype(moment_dtype)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, mdt)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        c = state["count"] + 1
        cf = c.astype(jnp.float32)
        bc1 = 1 - b1 ** cf
        bc2 = 1 - b2 ** cf
        step_lr = lr(c)

        treedef = jax.tree.structure(params)
        flat_p = jax.tree.leaves(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        us, ms, vs = [], [], []
        for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
            gf = g.astype(jnp.float32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(gf)
            u = -step_lr * (m_new / bc1 / (jnp.sqrt(v_new / bc2) + eps)
                            + weight_decay * p.astype(jnp.float32))
            us.append(u.astype(p.dtype))
            ms.append(m_new.astype(mdt))
            vs.append(v_new.astype(mdt))
        unf = lambda leaves: jax.tree.unflatten(treedef, leaves)
        return unf(us), {"m": unf(ms), "v": unf(vs), "count": c}

    return Optimizer(init, update)


def adafactor(lr: Callable, *, eps=1e-30, clip_threshold=1.0, decay=0.8,
              momentum: Optional[float] = 0.9, momentum_dtype="bfloat16",
              weight_decay=0.0) -> Optimizer:
    """Factored second moments for >=2D params; optional bf16 momentum.

    Second-moment factors are stored as a flat list aligned with
    ``jax.tree.leaves(params)`` (leaf-aligned lists avoid tree-structure
    mismatches between params and the ragged factored state).
    """
    mdt = jnp.dtype(momentum_dtype)

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        vs = []
        for p in jax.tree.leaves(params):
            if _factored(p):
                vs.append({"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                           "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)})
            else:
                vs.append({"v": jnp.zeros(p.shape, jnp.float32)})
        st = {"v": vs, "count": jnp.zeros((), jnp.int32)}
        if momentum is not None:
            st["m"] = jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params)
        return st

    def update(grads, state, params):
        c = state["count"] + 1
        cf = c.astype(jnp.float32)
        beta2 = 1.0 - cf ** (-decay)
        step_lr = lr(c)

        treedef = jax.tree.structure(params)
        flat_p = jax.tree.leaves(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = (treedef.flatten_up_to(state["m"]) if momentum is not None
                  else [None] * len(flat_p))

        new_u, new_v, new_m = [], [], []
        for g, v, p, m in zip(flat_g, state["v"], flat_p, flat_m):
            gf = jnp.square(g.astype(jnp.float32)) + eps
            if _factored(p):
                vr = beta2 * v["vr"] + (1 - beta2) * jnp.mean(gf, axis=-1)
                vc = beta2 * v["vc"] + (1 - beta2) * jnp.mean(gf, axis=-2)
                rfac = jax.lax.rsqrt(vr / jnp.maximum(
                    jnp.mean(vr, axis=-1, keepdims=True), eps))[..., None]
                cfac = jax.lax.rsqrt(vc)[..., None, :]   # (..., 1, last)
                u = g.astype(jnp.float32) * rfac * cfac
                v_out = {"vr": vr, "vc": vc}
            else:
                vv = beta2 * v["v"] + (1 - beta2) * gf
                u = g.astype(jnp.float32) * jax.lax.rsqrt(vv)
                v_out = {"v": vv}
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            if momentum is not None:
                mf = momentum * m.astype(jnp.float32) + (1 - momentum) * u
                u = mf
                new_m.append(mf.astype(mdt))
            u = -step_lr * (u + weight_decay * p.astype(jnp.float32))
            new_u.append(u.astype(p.dtype))
            new_v.append(v_out)

        new = {"v": new_v, "count": c}
        if momentum is not None:
            new["m"] = jax.tree.unflatten(treedef, new_m)
        return jax.tree.unflatten(treedef, new_u), new

    return Optimizer(init, update)


def make_optimizer(name: str, peak_lr: float = 3e-4, **kw) -> Optimizer:
    lr = warmup_cosine(peak_lr)
    if name == "adamw":
        return adamw(lr, **kw)
    if name == "adafactor":
        return adafactor(lr, **kw)
    raise ValueError(name)
