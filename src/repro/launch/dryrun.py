import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first — jax locks the device count on first
init, and the production meshes need 512 placeholder host devices.

Per cell this records (to results/dryrun/<arch>__<shape>__<mesh>.json):
  * compiled.memory_analysis()  — proves the cell fits per-device memory,
  * compiled.cost_analysis()    — XLA's flops/bytes (while-bodies counted 1x),
  * analyze_hlo(compiled HLO)   — loop-aware flops / HBM-traffic / collective
    bytes (the roofline inputs; see launch/hlo_analysis.py),
  * lower/compile wall times, batch axes, parameter counts.

Usage:
  python -m repro.launch.dryrun --arch smollm_360m --shape train_4k
  python -m repro.launch.dryrun --all [--multipod|--singlepod]
"""
import argparse
import gc
import json
import math
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, SHAPES_BY_NAME, get_config
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.shardings import (batch_axes, batch_shardings,
                                    grad_shardings, opt_state_shardings,
                                    param_shardings, state_shardings,
                                    with_shardings)
from repro.models import batch_specs, decode_input_specs
from repro.training import make_serve_steps, make_train_step

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _tree_bytes(tree) -> int:
    return sum(math.prod(l.shape) * l.dtype.itemsize for l in jax.tree.leaves(tree))


def _tree_params(tree) -> int:
    return sum(math.prod(l.shape) for l in jax.tree.leaves(tree))


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             overrides=None) -> dict:
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = SHAPES_BY_NAME[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind, "status": "ok"}
    if not cfg.supports(shape):
        rec["status"] = "skipped"
        rec["reason"] = ("long_500k needs sub-quadratic attention; "
                         "skipped for pure full-attention archs (DESIGN.md)")
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec["batch_axes"] = list(batch_axes(cfg, mesh, shape.global_batch))

    t0 = time.time()
    if shape.kind == "train":
        init_fn, step_fn, _ = make_train_step(cfg)
        params_s, opt_s = jax.eval_shape(init_fn, jax.random.PRNGKey(0))
        gshard = grad_shardings(cfg, mesh, params_s)
        pshard = param_shardings(cfg, mesh, params_s)
        oshard = opt_state_shardings(cfg, mesh, params_s, opt_s)
        bspecs = batch_specs(cfg, shape)
        bshard = batch_shardings(cfg, mesh, bspecs, shape.global_batch)
        init_fn, step_fn, _ = make_train_step(cfg, grad_shardings=gshard,
                                              batch_shardings=bshard)
        args = (with_shardings(params_s, pshard),
                with_shardings(opt_s, oshard),
                with_shardings(bspecs, bshard))
        fn = jax.jit(step_fn, donate_argnums=(0, 1),
                     out_shardings=(pshard, oshard, None))
    elif shape.kind == "prefill":
        prefill_fn, _, model = make_serve_steps(cfg)
        params_s = jax.eval_shape(model["init_params"], jax.random.PRNGKey(0))
        # inference cells always FSDP the (read-only) params: gathering per
        # layer is the standard serving trade and keeps giants under HBM;
        # it also pins the data axis so GSPMD can't replicate batch rows
        # around the MoE scatter (6.5x redundant flops observed without it).
        pshard = param_shardings(cfg, mesh, params_s,
                                 fsdp=cfg.tensor_parallel)
        bspecs = batch_specs(cfg, shape)
        bshard = batch_shardings(cfg, mesh, bspecs, shape.global_batch)
        args = (with_shardings(params_s, pshard),
                with_shardings(bspecs, bshard))
        # pin the emitted decode state to the serving layout (cache sequence
        # dim sharded over `model`) — otherwise GSPMD materializes the full
        # KV cache batch-sharded only (8+ GB/device for the big archs).
        # Ring-cache (SWA) archs skip the pin: the ring roll/slice forces a
        # resharding transpose that regresses peak memory (measured).
        if os.environ.get("REPRO_PIN_PREFILL_OUT", "1") == "1" and not cfg.window:
            state_s = model["decode_state_shape"](shape.global_batch,
                                                  shape.seq_len)
            sshard = state_shardings(cfg, mesh, state_s, shape.global_batch)
            fn = jax.jit(lambda p, b: prefill_fn(p, b, shape.seq_len),
                         out_shardings=(None, sshard))
        else:
            fn = jax.jit(lambda p, b: prefill_fn(p, b, shape.seq_len))
    else:  # decode
        _, decode_fn, model = make_serve_steps(cfg)
        params_s = jax.eval_shape(model["init_params"], jax.random.PRNGKey(0))
        pshard = param_shardings(cfg, mesh, params_s,
                                 fsdp=cfg.tensor_parallel)
        specs = decode_input_specs(cfg, shape)
        sshard = state_shardings(cfg, mesh, specs["state"], shape.global_batch)
        tshard = batch_shardings(cfg, mesh, {"t": specs["tokens"]},
                                 shape.global_batch)["t"]
        args = [with_shardings(params_s, pshard),
                with_shardings(specs["state"], sshard),
                jax.ShapeDtypeStruct(specs["tokens"].shape, specs["tokens"].dtype,
                                     sharding=tshard),
                jax.ShapeDtypeStruct((), jnp.int32,
                                     sharding=jax.NamedSharding(
                                         mesh, jax.sharding.PartitionSpec()))]
        if cfg.position_inputs:
            B = shape.global_batch
            posn = jax.ShapeDtypeStruct(
                (B, 3, 1), jnp.int32,
                sharding=jax.NamedSharding(
                    mesh, jax.sharding.PartitionSpec(
                        rec["batch_axes"] or None, None, None)))
            args.append(posn)
            fn = jax.jit(lambda p, s, t, pos, posns:
                         decode_fn(p, s, t, pos, positions=posns),
                         donate_argnums=(1,))
        else:
            fn = jax.jit(decode_fn, donate_argnums=(1,))
        args = tuple(args)

    rec["param_count"] = _tree_params(params_s)
    rec["param_bytes_global"] = _tree_bytes(params_s)

    with mesh:
        lowered = fn.lower(*args)
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

    try:
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_bytes_per_device": int(ma.argument_size_in_bytes
                                         + ma.output_size_in_bytes
                                         + ma.temp_size_in_bytes
                                         - ma.alias_size_in_bytes),
        }
    except Exception as e:  # pragma: no cover
        rec["memory"] = {"error": repr(e)}
    try:
        ca = compiled.cost_analysis()
        rec["cost_analysis"] = {k: float(v) for k, v in ca.items()
                                if k in ("flops", "bytes accessed",
                                         "transcendentals", "optimal_seconds")}
    except Exception as e:  # pragma: no cover
        rec["cost_analysis"] = {"error": repr(e)}
    t2 = time.time()
    hlo = compiled.as_text()
    rec["hlo_chars"] = len(hlo)
    rec["hlo_analysis"] = analyze_hlo(hlo)
    rec["analyze_s"] = round(time.time() - t2, 2)
    del compiled, lowered, hlo
    gc.collect()
    return rec


def cell_path(arch, shape_name, multi_pod) -> Path:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    return RESULTS_DIR / f"{arch}__{shape_name}__{mesh_name}.json"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--singlepod", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    archs = ARCH_IDS if (args.all or args.arch in (None, "all")) else [args.arch]
    shapes = (list(SHAPES_BY_NAME) if (args.all or args.shape in (None, "all"))
              else [args.shape])
    pods = [False, True]
    if args.multipod and not args.singlepod:
        pods = [True]
    if args.singlepod and not args.multipod:
        pods = [False]

    failures = 0
    for arch in archs:
        for shape_name in shapes:
            for mp in pods:
                out = cell_path(arch, shape_name, mp)
                if out.exists() and not args.force:
                    print(f"[cached] {out.name}")
                    continue
                print(f"[dryrun] {arch} x {shape_name} x "
                      f"{'2x16x16' if mp else '16x16'} ...", flush=True)
                try:
                    rec = run_cell(arch, shape_name, mp)
                except Exception:
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": "pod2x16x16" if mp else "pod16x16",
                           "status": "error",
                           "traceback": traceback.format_exc()}
                    failures += 1
                out.write_text(json.dumps(rec, indent=2))
                print(f"  -> {rec['status']} "
                      f"(lower {rec.get('lower_s', '-')}s, "
                      f"compile {rec.get('compile_s', '-')}s)", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
