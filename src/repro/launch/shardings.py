"""Sharding rules: param/batch/state PartitionSpecs per (arch x shape x mesh).

Parallelism scheme
  * batch dim        -> ("pod","data") [+ "model" for non-TP archs]; axes are
                        greedily dropped (right-first) until they divide B.
  * TP (tensor)      -> "model" axis on head/ff/vocab/expert dims for archs
                        with cfg.tensor_parallel (embedding vocab-sharded,
                        up-projections column-, down-projections row-sharded,
                        MoE expert dim sharded => GSPMD emits EP all-to-alls).
  * SP (sequence)    -> long-context decode (B=1): KV/recurrent state sequence
                        or feature dims shard over "data" (+"model").
  * ZeRO             -> optimizer moments inherit the param specs (and are
                        additionally sharded by GSPMD where profitable).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig

# param leaf names whose LAST dim is the parallel (output) dim
_COL = {"wq", "wk", "wv", "wi_gate", "wi_up", "w_up", "w_x", "w_gate",
        "wu_g", "wu", "wq_b", "wk_b", "wv_b", "wq_a", "w_rg", "w_ig", "conv"}
# param leaf names whose FIRST-of-last-two dim is parallel (input/row dim)
_ROW = {"wo", "w_down", "wd", "w_out"}


def has_pod(mesh: Mesh) -> bool:
    return "pod" in mesh.axis_names


def batch_axes(cfg: ModelConfig, mesh: Mesh, global_batch: int) -> Tuple[str, ...]:
    axes = (("pod",) if has_pod(mesh) else ()) + ("data",)
    if not cfg.tensor_parallel:
        axes = axes + ("model",)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    while axes and global_batch % math.prod(sizes[a] for a in axes):
        axes = axes[:-1]
    return axes


def _spec_for_param(cfg: ModelConfig, path: Tuple[str, ...], shape,
                    msize: int) -> P:
    """Divisibility-aware TP rules (the mesh `model` axis has msize ways)."""
    if not cfg.tensor_parallel:
        return P()
    names = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
    leaf = names[-1]
    ndim = len(shape)
    div = lambda i: shape[i] % msize == 0
    in_moe = "moe" in names
    trailing: Tuple = ()
    if in_moe:
        if leaf == "router":
            trailing = ()
        elif ndim >= 3 and shape[-3] % msize == 0:
            trailing = ("model", None, None)     # expert-parallel
        elif leaf in ("wi_gate", "wi_up") and div(ndim - 1):
            trailing = (None, None, "model")     # few experts: TP the ff dim
        elif leaf == "wo" and div(ndim - 2):
            trailing = (None, "model", None)
        else:
            trailing = ()
    elif leaf == "embedding":
        # prefer vocab-parallel; odd vocab sizes fall back to d_model-parallel
        trailing = ("model", None) if div(ndim - 2) else \
            ((None, "model") if div(ndim - 1) else ())
    elif leaf == "unembed":
        trailing = (None, "model") if div(ndim - 1) else ()
    elif leaf == "wkv_a":          # MLA latent projection feeds the shared cache
        trailing = ()
    elif leaf in _COL:
        trailing = (None, "model") if div(ndim - 1) else ()
    elif leaf in _ROW:
        trailing = ("model", None) if div(ndim - 2) else ()
    elif leaf == "lam":
        trailing = ("model",) if div(ndim - 1) else ()
    pad = ndim - len(trailing)
    if pad < 0:
        return P()
    return P(*([None] * pad + list(trailing)))


def _add_axis(spec: P, shape, axis: str, size: int) -> P:
    """ZeRO/FSDP: place `axis` on the largest free, divisible dim."""
    entries = list(spec) + [None] * (len(shape) - len(spec))
    free = [i for i in range(len(shape))
            if entries[i] is None and shape[i] % size == 0 and shape[i] >= size]
    if not free:
        return P(*entries)
    i = max(free, key=lambda j: shape[j])
    entries[i] = axis
    return P(*entries)


def param_shardings(cfg: ModelConfig, mesh: Mesh, params_tree,
                    fsdp: Optional[bool] = None):
    """TP over `model` + (where cfg.fsdp) FSDP over `data` on a free dim —
    GSPMD all-gathers weights just-in-time per layer (ZeRO-3 style)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    msize = sizes["model"]
    use_fsdp = cfg.fsdp if fsdp is None else fsdp

    def spec(path, leaf):
        s = _spec_for_param(cfg, path, leaf.shape, msize)
        if use_fsdp and cfg.tensor_parallel and leaf.ndim >= 2:
            s = _add_axis(s, leaf.shape, "data", sizes["data"])
        return NamedSharding(mesh, s)
    return jax.tree_util.tree_map_with_path(spec, params_tree)


def grad_shardings(cfg: ModelConfig, mesh: Mesh, params_tree):
    """f32 gradient-accumulator specs: param specs + ZeRO over data(/model)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    msize = sizes["model"]

    def spec(path, leaf):
        s = _spec_for_param(cfg, path, leaf.shape, msize)
        s = _add_axis(s, leaf.shape, "data", sizes["data"])
        if not cfg.tensor_parallel:
            s = _add_axis(s, leaf.shape, "model", msize)
        return NamedSharding(mesh, s)
    return jax.tree_util.tree_map_with_path(spec, params_tree)


def opt_state_shardings(cfg: ModelConfig, mesh: Mesh, params_tree, opt_state_tree):
    """Moments mirror param specs; adafactor factored moments drop dims."""
    msize = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    pspecs = {}

    def record(path, leaf):
        pspecs[tuple(str(p) for p in path)] = (
            _spec_for_param(cfg, path, leaf.shape, msize), leaf.shape)
        return None
    jax.tree_util.tree_map_with_path(record, params_tree)
    by_shape: Dict[tuple, P] = {}
    for spec, shape in pspecs.values():
        by_shape.setdefault(shape, spec)

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def zero(spec: P, shape) -> P:
        """ZeRO: moments are elementwise -> also shard over data (+model)."""
        spec = _add_axis(spec, shape, "data", sizes["data"])
        if not cfg.tensor_parallel:
            spec = _add_axis(spec, shape, "model", sizes["model"])
        return spec

    def spec_for(leaf):
        shape = leaf.shape
        if shape in by_shape:
            return NamedSharding(mesh, zero(by_shape[shape], shape))
        # factored moments: match a param shape with one trailing dim removed
        for pshape, spec in by_shape.items():
            if shape == pshape[:-1] and len(pshape) >= 1:
                return NamedSharding(mesh, zero(P(*list(spec)[:-1]), shape)) \
                    if len(spec) else NamedSharding(mesh, zero(P(), shape))
            if shape == pshape[:-2] + pshape[-1:] and len(spec) >= 2:
                return NamedSharding(
                    mesh, zero(P(*(list(spec)[:-2] + [list(spec)[-1]])), shape))
        return NamedSharding(mesh, P())

    return jax.tree.map(spec_for, opt_state_tree,
                        is_leaf=lambda x: hasattr(x, "shape"))


def batch_shardings(cfg: ModelConfig, mesh: Mesh, specs, global_batch: int):
    baxes = batch_axes(cfg, mesh, global_batch)
    bspec = baxes if baxes else None
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    # Sequence parallelism: non-TP attention archs whose batch doesn't cover
    # the model axis shard the sequence dim over it instead (prefill/train).
    recurrent = any(k in ("mlstm", "slstm", "rglru") for k in cfg.block_pattern)
    use_sp = (not cfg.tensor_parallel) and ("model" not in baxes) \
        and not recurrent

    def spec(path, leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        names = [getattr(p, "key", str(p)) for p in path]
        entries = [bspec] + [None] * (leaf.ndim - 1)
        if use_sp:
            sdim = 2 if names and names[-1] == "positions" else 1
            if leaf.ndim > sdim and leaf.shape[sdim] % sizes["model"] == 0 \
                    and leaf.shape[sdim] >= sizes["model"]:
                entries[sdim] = "model"
        return NamedSharding(mesh, P(*entries))

    return jax.tree_util.tree_map_with_path(spec, specs)


def state_shardings(cfg: ModelConfig, mesh: Mesh, state_tree, global_batch: int):
    """Decode-state specs.  Leaves have a leading segment-stack dim R."""
    baxes = batch_axes(cfg, mesh, global_batch)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def _sanitize(spec: P, shape) -> P:
        """Drop axis assignments that don't divide the dimension."""
        out = []
        for i, entry in enumerate(spec):
            if entry is None:
                out.append(None)
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            keep = []
            for a in axes:
                if shape[i] % (math.prod(sizes[x] for x in keep) * sizes[a]) == 0:
                    keep.append(a)
            out.append(tuple(keep) if len(keep) > 1 else (keep[0] if keep else None))
        return P(*out)

    # KV caches shard their *sequence* dim over "model" (flash-decode style:
    # the decode softmax reduces over the sharded axis via GSPMD collectives).
    # Sharding kv-heads instead would pad 1-8 heads up to 16 (2-16x HBM waste).
    baxes_nm = tuple(a for a in baxes if a != "model")
    bspec = baxes_nm if baxes_nm else None
    tp = "model" if cfg.tensor_parallel else None
    seq_par = global_batch == 1          # long-context: shard state, not batch

    def raw_spec(path, leaf) -> P:
        names = [getattr(p, "key", str(p)) for p in path]
        leaf_name = names[-1]
        nd = leaf.ndim
        if leaf_name == "pos":
            return P()
        if leaf_name in ("ck", "cv"):                # (R,B,enc,KV,dh) small
            return P(None, bspec, None, None, None)
        if leaf_name in ("k", "v"):                  # (R,B,T,KV,dh)
            if seq_par:
                return P(None, None, ("data", "model"), None, None)
            return P(None, bspec, "model", None, None)
        if leaf_name in ("c_kv", "k_pe"):            # (R,B,T,r) MLA latent
            if seq_par:
                return P(None, None, ("data", "model"), None)
            return P(None, bspec, "model", None)
        if leaf_name == "C":                          # (R,B,H,dq,dv) mLSTM
            if seq_par:
                return P(None, None, None, "data", "model")
            return P(None, bspec, None, tp, None)
        if leaf_name == "n" and nd == 4:              # (R,B,H,dq)
            if seq_par:
                return P(None, None, None, ("data", "model"))
            return P(None, bspec, None, tp)
        if leaf_name == "conv":                       # (R,B,cw-1,ch)
            if seq_par:
                return P(None, None, None, ("data", "model"))
            return P(None, bspec, None, tp)
        if leaf_name == "h" and nd == 3:              # (R,B,w) rglru
            if seq_par:
                return P(None, None, ("data", "model"))
            return P(None, bspec, tp)
        if nd == 3:                                   # (R,B,d) slstm c/n/h/m
            if seq_par:
                return P(None, None, ("data", "model"))
            return P(None, bspec, None)
        if nd >= 2:
            return P(None, bspec, *([None] * (nd - 2)))
        return P()

    def spec(path, leaf):
        return NamedSharding(mesh, _sanitize(raw_spec(path, leaf), leaf.shape))

    return jax.tree_util.tree_map_with_path(spec, state_tree)


def with_shardings(struct_tree, sharding_tree):
    """Attach NamedShardings to ShapeDtypeStructs (dry-run inputs)."""
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        struct_tree, sharding_tree,
        is_leaf=lambda s: isinstance(s, jax.ShapeDtypeStruct) or hasattr(s, "shape"))
