"""Production mesh construction.

A FUNCTION (not module-level constant) so importing this module never touches
jax device state.  Single pod: (data=16, model=16) = 256 chips (TPU v5e-256);
multi-pod: (pod=2, data=16, model=16) = 512 chips across 2 pods over DCN.
"""
from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}; have {len(devices)} — the "
            "dry-run entrypoint must set XLA_FLAGS=--xla_force_host_platform_"
            "device_count=512 before any jax import")
    dev_array = np.asarray(devices[:n]).reshape(shape)
    from jax.sharding import Mesh
    return Mesh(dev_array, axes)


def make_trials_mesh(devices: int):
    """1-D mesh over the first `devices` devices, axis name "trials".

    The batched Monte Carlo engines shard their independent trials across
    this axis (shard_map in core/availability_batched.py and
    core/downtime_batched.py).  The sharding proof is layout-independent:
    every carried tensor — boolean (B, P, n) masks or the packed
    (B, W, P) uint32 words the fused step megakernel consumes — has
    trials as its leading axis, the counter-based RNG keys each lane by
    its *global* trial index (lane0 is carried per shard), and the only
    cross-partition reduction (the bandwidth model's per-node in-flight
    counts, fused into the same kernel when packed) stays within one
    trial.  So splitting the leading axis commutes with every step for
    both layouts, and devices=D is bit-identical to devices=1
    (tests/test_sharded.py pins this for unpacked, packed, and the fused
    pallas path).  On CPU, validate with
    XLA_FLAGS=--xla_force_host_platform_device_count=<D> set before any
    jax import.
    """
    import jax

    devs = jax.devices()
    if len(devs) < devices:
        raise RuntimeError(
            f"need {devices} devices for a trials mesh; have {len(devs)} — "
            "on CPU set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{devices} before any jax import")
    from jax.sharding import Mesh
    return Mesh(np.asarray(devs[:devices]), ("trials",))


def make_host_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over however many host devices exist (tests)."""
    import jax

    n = int(np.prod(shape))
    devices = jax.devices()[:n]
    from jax.sharding import Mesh
    return Mesh(np.asarray(devices).reshape(shape), axes)
