"""Serving driver: batched prefill/decode with LARK session failover.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm_360m --reduced \
      --prompt-len 16 --gen 24 --fail-server
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, reduced_config
from repro.data import SyntheticLMData
from repro.models import build_model
from repro.serving import LarkSessionStore, ServeLoop


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--fail-server", action="store_true")
    args = ap.parse_args(argv)

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg)
    params = model["init_params"](jax.random.PRNGKey(0))
    sessions = LarkSessionStore(num_nodes=4, rf=2)
    loop = ServeLoop(cfg, params, max_len=args.prompt_len + args.gen,
                     session_store=sessions, checkpoint_every=4)

    data = SyntheticLMData(cfg, args.batch, args.prompt_len)
    batch = {k: v for k, v in data.batch_at(0).items() if k != "labels"}
    toks = loop.generate(batch, steps=args.gen // 2, session_id="req-0")
    print("generated (phase 1):", toks[:, :8], "...")

    if args.fail_server:
        sessions.fail_server(0)
        print("server 0 failed; sessions available:",
              sessions.store.available_fraction())
    resumed = loop.resume("req-0", steps=args.gen // 2)
    print("resumed generation:", None if resumed is None else resumed.shape)
    return toks, resumed


if __name__ == "__main__":
    main()
