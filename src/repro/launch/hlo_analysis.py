"""Post-SPMD HLO analysis: per-device FLOPs, HBM-traffic estimate, collective
bytes — with while-loop trip-count multipliers.

XLA's built-in ``compiled.cost_analysis()`` visits while bodies ONCE (verified
empirically: a 10-iteration scan reports 1 iteration of flops), so scanned-
layer models would be undercounted ~num_layers x.  This walker multiplies
every computation by the product of enclosing loop trip counts, read from the
``backend_config={"known_trip_count":{"n":"N"}}`` annotation (fallback: max
constant in the loop condition).

Methodology notes (also in EXPERIMENTS.md):
  * flops: 2*prod(result_shape)*prod(lhs_contracting_dims) per `dot`.
  * collective bytes: sum of operand sizes per collective instruction
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute), i.e. per-device payload.
  * hbm bytes: sum of (operand + result) sizes over top-level non-bookkeeping
    instructions — an XLA-style bytes-accessed model of the fused module.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "fp8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "collective-broadcast", "ragged-all-to-all")

_BOOKKEEPING = {"tuple", "get-tuple-element", "parameter", "constant",
                "bitcast", "after-all", "partition-id", "replica-id", "iota",
                "reshape"}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([\d,]*)\]")


def _parse_instr(line: str):
    """'%name = TYPE op(operands), attrs' -> (name, type_str, op, rest).

    TYPE may be a tuple '(f32[..], /*index=5*/ f32[..])' — paren-matched.
    """
    s = line.strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%"):
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[1:eq]
    rest = s[eq + 3:]
    if rest.startswith("("):
        depth = 0
        end = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        type_str, rem = rest[: end + 1], rest[end + 1:]
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str, rem = rest[:sp], rest[sp:]
    rem = rem.lstrip()
    p = rem.find("(")
    if p < 0:
        return None
    op = rem[:p].strip()
    if not op or not op[0].isalpha():
        return None
    return name, type_str, op, rem[p + 1:]
_TRIP_RE = re.compile(r'known_trip_count[\\"=:{\s]+n[\\"\s:]+(\d+)')
_CALL_REF_RE = re.compile(r"(body|condition|calls|to_apply|branch_computations)="
                          r"(?:%([\w\.\-]+)|\{([^}]*)\})")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _parse_computations(hlo: str):
    """Returns ({name: [instruction lines]}, entry_name).

    A computation header is a non-indented line containing '->' and ending
    with '{'; the name is the first token (sans ENTRY/%%).
    """
    comps: Dict[str, List[str]] = {}
    entry = None
    cur = None
    for line in hlo.splitlines():
        if line and not line[0].isspace() and "->" in line and line.rstrip().endswith("{"):
            tok = line.split()[0]
            if tok == "ENTRY":
                tok = line.split()[1]
            cur = tok.lstrip("%").split("(")[0].strip()
            comps[cur] = []
            if line.startswith("ENTRY"):
                entry = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None and "=" in line:
            comps[cur].append(line)
    return comps, entry


def analyze_hlo(hlo: str) -> Dict:
    comps, entry = _parse_computations(hlo)
    # Parse instructions per computation.
    parsed: Dict[str, List[dict]] = {}
    shapes: Dict[str, Dict[str, str]] = {}
    for cname, lines in comps.items():
        instrs = []
        smap: Dict[str, str] = {}
        for line in lines:
            m = _parse_instr(line)
            if not m:
                continue
            name, type_str, op, rest = m
            smap[name] = type_str
            instrs.append({"name": name, "type": type_str, "op": op,
                           "rest": rest, "line": line})
        parsed[cname] = instrs
        shapes[cname] = smap

    # Build call edges + loop trips.
    edges: Dict[str, List[Tuple[str, str, int]]] = defaultdict(list)
    for cname, instrs in parsed.items():
        for ins in instrs:
            line = ins["line"]
            trip = 1
            tm = _TRIP_RE.search(line)
            if ins["op"] == "while":
                if tm:
                    trip = int(tm.group(1))
                else:  # fallback: max constant in the condition computation
                    cm = re.search(r"condition=%([\w\.\-]+)", line)
                    if cm and cm.group(1) in comps:
                        consts = re.findall(r"constant\((\d+)\)",
                                            "\n".join(comps[cm.group(1)]))
                        trip = max((int(c) for c in consts), default=1)
            for kind, single, multi in _CALL_REF_RE.findall(line):
                targets = [single] if single else \
                    [t.strip().lstrip("%") for t in multi.split(",")]
                for t in targets:
                    if not t or t not in comps:
                        continue
                    if kind == "body":
                        edges[cname].append((t, "loop", trip))
                    elif kind == "condition":
                        edges[cname].append((t, "loop", trip))
                    elif kind in ("calls", "to_apply"):
                        edges[cname].append((t, "inline", 1))
                    else:
                        edges[cname].append((t, "branch", 1))

    # Execution-count multipliers via topological propagation from ENTRY
    # (HLO call graph is a DAG).  `inline` computations are fusion interiors /
    # reducers: their *flops* count (dots get fusion-wrapped on some backends)
    # but their interior byte traffic does not (fused => no HBM round trip).
    inline: set = set()
    for cname, es in edges.items():
        for t, kind, _ in es:
            if kind == "inline":
                inline.add(t)

    mult: Dict[str, float] = defaultdict(float)
    if entry:
        order: List[str] = []
        seen: set = set()

        def dfs(c):
            if c in seen:
                return
            seen.add(c)
            for t, _, _ in edges.get(c, []):
                dfs(t)
            order.append(c)

        dfs(entry)
        mult[entry] = 1.0
        for c in reversed(order):          # callers before callees
            for t, kind, trip in edges.get(c, []):
                mult[t] += mult[c] * (trip if kind == "loop" else 1)

    flops = 0.0
    hbm_bytes = 0.0
    coll = defaultdict(lambda: {"bytes": 0.0, "count": 0.0})
    for cname, instrs in parsed.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        is_inline = cname in inline
        smap = shapes[cname]
        for ins in instrs:
            op = ins["op"]
            out_bytes = _shape_bytes(ins["type"])
            operand_names = re.findall(r"%([\w\.\-]+)", ins["rest"].split("), ")[0])
            in_bytes = sum(_shape_bytes(smap.get(o, "")) for o in operand_names)
            if op == "dot" or (op == "convolution"):
                res_elems = 1
                sm = _SHAPE_RE.search(ins["type"])
                if sm and sm.group(2):
                    for d in sm.group(2).split(","):
                        res_elems *= int(d)
                cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins["rest"])
                lhs_name = operand_names[0] if operand_names else None
                cprod = 1
                if cdims and lhs_name and lhs_name in smap:
                    lm = _SHAPE_RE.search(smap[lhs_name])
                    if lm and lm.group(2):
                        ldims = [int(d) for d in lm.group(2).split(",")]
                        for ci in cdims.group(1).split(","):
                            if ci != "":
                                cprod *= ldims[int(ci)]
                flops += 2.0 * res_elems * cprod * m
            if is_inline:
                continue  # fusion interiors: flops above, no HBM/collectives
            if op in _COLLECTIVES:
                coll[op]["bytes"] += in_bytes * m
                coll[op]["count"] += m
            if op not in _BOOKKEEPING:
                # HBM-traffic model: slicing ops move only the sliced region,
                # not their (possibly scan-stacked) operand buffers.
                name = ins["name"]
                opsizes = [_shape_bytes(smap.get(o, "")) for o in operand_names]
                if op == "dynamic-slice" or (
                        op == "fusion" and "dynamic-slice" in name
                        and "update" not in name):
                    in_bytes = 0
                elif op == "dynamic-update-slice" or (
                        op == "fusion" and "dynamic-update-slice" in name):
                    upd = sorted(opsizes)[-2] if len(opsizes) >= 2 else 0
                    in_bytes, out_bytes = upd, upd
                elif op in ("gather",):
                    in_bytes = out_bytes + (opsizes[1] if len(opsizes) > 1 else 0)
                elif op in ("scatter",):
                    small = sum(opsizes) - max(opsizes) if opsizes else 0
                    in_bytes, out_bytes = small, small
                hbm_bytes += (out_bytes + in_bytes) * m
    return {
        "flops": flops,
        "hbm_bytes": hbm_bytes,
        "collectives": {k: dict(v) for k, v in coll.items()},
        "collective_bytes_total": sum(v["bytes"] for v in coll.values()),
        "num_computations": len(comps),
    }
