"""Training driver.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch smollm_360m --reduced \
      --steps 30 --batch 4 --seq 64 --fail-worker-at 12
  PYTHONPATH=src python -m repro.launch.train --arch mixtral_8x7b --reduced ...

Full (non-reduced) configs are for the production mesh; on this CPU
container they are exercised via the dry-run (`repro.launch.dryrun`).

The driver demonstrates the integrated stack: synthetic pipeline -> jitted
train step (µbatch accumulation) -> LARK-replicated checkpoint store (+ the
quorum-log baseline store for comparison) -> async disk shards -> simulated
worker failure mid-run: LARK keeps committing checkpoints, the baseline
pauses for its hydration window.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointer, LarkStore, QuorumLogStore
from repro.configs import SHAPES_BY_NAME, get_config, reduced_config
from repro.configs.base import ShapeConfig
from repro.data import SyntheticLMData
from repro.training import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--checkpoint-every", type=int, default=5)
    ap.add_argument("--fail-worker-at", type=int, default=-1)
    ap.add_argument("--recover-worker-at", type=int, default=-1)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--rf", type=int, default=2)
    ap.add_argument("--out", default="results/train")
    args = ap.parse_args(argv)

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    cfg = cfg.replace(microbatches_train=min(cfg.microbatches_train, 2))
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    data = SyntheticLMData(cfg, args.batch, args.seq)
    init_fn, step_fn, _ = make_train_step(cfg, peak_lr=args.lr)
    params, opt_state = init_fn(jax.random.PRNGKey(0))
    step_jit = jax.jit(step_fn, donate_argnums=(0, 1))

    lark = LarkStore(args.workers, rf=args.rf, num_partitions=16)
    base = QuorumLogStore(args.workers, rf=args.rf, num_partitions=16,
                          partition_bytes=1e8, bandwidth=5e6)
    out_dir = Path(args.out) / args.arch
    disk = AsyncCheckpointer(out_dir / "ckpt")
    metrics_log = []

    t_start = time.time()
    for step in range(args.steps):
        if step == args.fail_worker_at:
            lark.fail_node(args.workers - 1)
            base.fail_node(args.workers - 1)
            print(f"[step {step}] worker {args.workers-1} failed; "
                  f"LARK availability {lark.available_fraction():.2f}, "
                  f"regime {lark.regime}")
        if step == args.recover_worker_at:
            lark.recover_node(args.workers - 1)
            base.recover_node(args.workers - 1)
        batch = jax.tree.map(jnp.asarray, data.batch_at(step))
        params, opt_state, m = step_jit(params, opt_state, batch)
        base.advance(1.0)  # 1 simulated second per step
        rec = {"step": step, "loss": float(m["loss"]),
               "grad_norm": float(m["grad_norm"])}
        if step % args.checkpoint_every == 0:
            ok_l, tot = lark.put_pytree(f"ckpt/{step}", {"loss": np.float32(rec["loss"])})
            ok_b = base.put(f"ckpt/{step}", rec["loss"])
            disk.save({"p": params}, step=step, regime=lark.regime)
            rec.update(lark_commit=ok_l == tot, baseline_commit=bool(ok_b))
        metrics_log.append(rec)
        print(json.dumps(rec))
    disk.close()
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / "metrics.json").write_text(json.dumps(metrics_log))
    print(f"done in {time.time()-t_start:.1f}s; final loss "
          f"{metrics_log[-1]['loss']:.4f} (first {metrics_log[0]['loss']:.4f})")
    return metrics_log


if __name__ == "__main__":
    main()
