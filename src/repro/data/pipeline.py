"""Deterministic synthetic data pipeline.

Tokens are a cheap stateless hash of (seed, step, row, position) so any
worker can regenerate any shard after elastic remapping or restart — the
data pipeline itself needs no checkpoint beyond the step counter (this is
the property real deterministic loaders provide and what the LARK-replicated
checkpoint relies on for exactly-once semantics).

The stream embeds a learnable structure (token t+1 depends on t) so smoke
training runs show decreasing loss.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


def _hash2(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    x = (a.astype(np.uint64) << np.uint64(32)) ^ b.astype(np.uint64)
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    return x ^ (x >> np.uint64(31))


@dataclass
class SyntheticLMData:
    cfg: ModelConfig
    batch: int
    seq: int
    seed: int = 0
    # markov-ish structure: next token = (prev * A + noise) % V
    structure: int = 31

    def batch_at(self, step: int, host_id: int = 0, num_hosts: int = 1) -> Dict:
        rows = self.batch // num_hosts
        row0 = host_id * rows
        ridx = np.arange(row0, row0 + rows, dtype=np.uint64)[:, None]
        base = _hash2(np.uint64(self.seed * 1_000_003 + step), ridx)
        noise = _hash2(base, np.arange(self.seq + 1, dtype=np.uint64)[None, :])
        v = self.cfg.vocab_size
        toks = np.empty((rows, self.seq + 1), dtype=np.int64)
        toks[:, 0] = noise[:, 0] % v
        for t in range(1, self.seq + 1):
            toks[:, t] = (toks[:, t - 1] * self.structure
                          + (noise[:, t] % 17)) % v
        out: Dict = {}
        if self.cfg.is_encoder_decoder:
            rng = np.random.default_rng(self.seed * 7919 + step)
            out["audio_embeds"] = rng.standard_normal(
                (rows, self.cfg.enc_seq, self.cfg.d_model)).astype(np.float32)
            out["tokens"] = toks[:, :-1].astype(np.int32)
        elif self.cfg.embeds_input:
            rng = np.random.default_rng(self.seed * 7919 + step)
            out["embeds"] = rng.standard_normal(
                (rows, self.seq, self.cfg.d_model)).astype(np.float32)
            if self.cfg.position_inputs:
                pos = np.broadcast_to(np.arange(self.seq, dtype=np.int32),
                                      (rows, 3, self.seq))
                out["positions"] = np.ascontiguousarray(pos)
        else:
            out["tokens"] = toks[:, :-1].astype(np.int32)
        out["labels"] = toks[:, 1:].astype(np.int32)
        return out

    def __iter__(self) -> Iterator[Dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
