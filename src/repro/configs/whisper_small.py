"""whisper-small [audio] — encoder-decoder, conv frontend stubbed (arXiv:2212.04356).

12L (x2: encoder+decoder) d_model=768 12H (kv=12) d_ff=3072 vocab=51865.
The conv/mel frontend is a STUB: input_specs() provides precomputed frame
embeddings (B, 1500, d_model).  Assigned seq_len applies to the decoder
backbone.  LayerNorm + GELU + learned positions, per the paper.
Full attention decoder => long_500k skipped.
"""
from .base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="whisper_small",
    family="audio",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    block_pattern=(ATTN,),
    is_encoder_decoder=True,
    enc_layers=12,
    enc_seq=1500,
    embeds_input=True,          # encoder consumes stub frame embeddings
    norm="layernorm",
    mlp="gelu",
    rope_theta=0.0,             # learned absolute positions, no RoPE
    tie_embeddings=True,
    tensor_parallel=False,
    optimizer="adamw",
    microbatches_train=1,
    skip_shapes=("long_500k",),
)

REDUCED_OVERRIDES = dict(num_layers=2)
