"""nemotron-4-340b [dense] — GQA, squared-ReLU MLP (arXiv:2402.16819).

96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000.  ~340B params:
Adafactor + 16 microbatches + remat so train_4k fits 16 GB/chip on 256 chips.
Full attention => long_500k skipped.
"""
from .base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="nemotron_4_340b",
    family="dense",
    num_layers=96,
    d_model=18432,
    num_heads=96,
    num_kv_heads=8,
    head_dim=192,
    d_ff=73728,
    vocab_size=256_000,
    block_pattern=(ATTN,),
    mlp="relu2",
    tie_embeddings=False,
    optimizer="adafactor",
    fsdp=True,
    microbatches_train=32,
    skip_shapes=("long_500k",),
)
