"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution (arXiv:2409.12191).

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.  The vision frontend
is a STUB: input_specs() provides fused precomputed token/patch embeddings
(B, S, d_model) plus (t,h,w) M-RoPE position ids (B, 3, S).  M-RoPE sections
(16,24,24) over the 64 half-dims of head_dim=128.
Full attention => long_500k skipped.
"""
from .base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="qwen2_vl_2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    block_pattern=(ATTN,),
    mrope_sections=(16, 24, 24),
    rope_theta=1e6,
    embeds_input=True,
    position_inputs=True,
    mlp="swiglu",
    tie_embeddings=True,
    tensor_parallel=False,
    optimizer="adamw",
    microbatches_train=4,
    skip_shapes=("long_500k",),
)

REDUCED_OVERRIDES = dict(mrope_sections=(2, 3, 3))  # sums to head_dim//2 = 8

