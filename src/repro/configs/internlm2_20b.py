"""internlm2-20b [dense] — GQA llama-style (arXiv:2403.17297).

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92544.  Full attention
=> long_500k skipped.
"""
from .base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="internlm2_20b",
    family="dense",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92544,
    block_pattern=(ATTN,),
    rope_theta=1e6,
    mlp="swiglu",
    tie_embeddings=False,
    optimizer="adamw",
    microbatches_train=16,
    skip_shapes=("long_500k",),
)
