"""Model/shape configuration dataclasses for the assigned architecture pool.

Every architecture in the pool is described by a single frozen ``ModelConfig``.
The model zoo (``repro.models``) consumes these configs; the launcher
(``repro.launch``) pairs them with ``ShapeConfig`` cells for the dry-run.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

# Block kinds understood by the model assembly.
ATTN = "attn"            # global self-attention (causal for decoder LMs)
LOCAL_ATTN = "local"     # sliding-window / local attention
MLSTM = "mlstm"          # xLSTM matrix-memory block
SLSTM = "slstm"          # xLSTM scalar-memory block
RGLRU = "rglru"          # RG-LRU recurrent block (Griffin/RecurrentGemma)


@dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell from the assignment."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

ALL_SHAPES: Tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    experts_per_token: int
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek/MiniCPM3 style)."""

    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # Block layout: a pattern of block kinds tiled to num_layers.  For plain
    # transformers this is ("attn",).  Hybrids use e.g. ("rglru","rglru","local").
    block_pattern: Tuple[str, ...] = (ATTN,)

    # Attention options.
    window: int = 0                  # sliding-window size (0 = full attention)
    local_window: int = 0            # window for LOCAL_ATTN blocks
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, ...] = ()   # (t,h,w) M-RoPE half-dim sections

    # Feed-forward.
    mlp: str = "swiglu"              # swiglu | relu2 | gelu
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None

    # xLSTM / RG-LRU options.
    proj_factor: float = 2.0         # mLSTM inner projection factor
    conv_width: int = 4              # temporal conv width (ssm/hybrid blocks)
    lru_width: int = 0               # RG-LRU width (0 -> d_model)

    # Encoder-decoder (whisper).
    is_encoder_decoder: bool = False
    enc_layers: int = 0
    enc_seq: int = 0                 # stub-frontend sequence length (e.g. 1500 frames)

    # Modality frontend stub: inputs are precomputed embeddings, not token ids.
    embeds_input: bool = False
    # Provide (t, h, w) position ids alongside embeddings (qwen2-vl M-RoPE).
    position_inputs: bool = False

    norm: str = "rmsnorm"            # rmsnorm | layernorm
    tie_embeddings: bool = True
    scale_embeddings: bool = False   # gemma-style sqrt(d_model) input scaling
    act_dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"

    # Training policy (per-arch, chosen so the dry-run fits 16 GB/chip HBM).
    optimizer: str = "adamw"         # adamw | adafactor
    remat: bool = True
    remat_group: int = 1             # layers per remat block (smaller ckpt set)
    microbatches_train: int = 1      # gradient-accumulation microbatches
    # TP over the `model` mesh axis; False => fully-data-parallel (small archs
    # whose head/ff dims don't tile 16 ways: batch shards over data x model).
    tensor_parallel: bool = True
    # FSDP (ZeRO-3) over the `data` axis for params: required only for models
    # whose bf16 params exceed HBM at TP-16 (nemotron-340b, qwen3-235b); it
    # costs backward re-gathers (~2.5x flops observed), so default off.
    fsdp: bool = False

    # Which shape cells are supported (long_500k only for sub-quadratic archs).
    skip_shapes: Tuple[str, ...] = ()

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.lru_width == 0:
            object.__setattr__(self, "lru_width", self.d_model)
        assert self.num_heads % max(self.num_kv_heads, 1) == 0, self.name

    @property
    def layout(self) -> Tuple[Tuple[Tuple[str, ...], int], ...]:
        """Segments of (pattern, repeats) covering num_layers.

        The main body is a scan over ``repeats`` of the full pattern; a
        remainder (num_layers % len(pattern)) becomes a trailing segment so
        configs like recurrentgemma's 38 = 12*3 + 2 are representable.
        """
        p = len(self.block_pattern)
        segs = []
        if self.num_layers // p:
            segs.append((self.block_pattern, self.num_layers // p))
        if self.num_layers % p:
            segs.append((self.block_pattern[: self.num_layers % p], 1))
        return tuple(segs)

    def supports(self, shape: ShapeConfig) -> bool:
        return shape.name not in self.skip_shapes

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)
