"""Architecture registry: ``get_config(arch_id)`` / ``reduced_config(arch_id)``.

One module per assigned architecture lives alongside this file; each exposes
``CONFIG`` (the exact public configuration) and optionally ``REDUCED_OVERRIDES``
for the CPU smoke tests.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict

from .base import ModelConfig

ARCH_IDS = (
    "xlstm_350m",
    "mixtral_8x7b",
    "qwen3_moe_235b_a22b",
    "recurrentgemma_9b",
    "internlm2_20b",
    "smollm_360m",
    "minicpm3_4b",
    "nemotron_4_340b",
    "whisper_small",
    "qwen2_vl_2b",
)

# Canonical ids as listed in the assignment (dash form) -> module name.
ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}


def normalize(arch: str) -> str:
    arch = arch.replace("-", "_")
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCH_IDS}")
    return arch


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{normalize(arch)}")
    return mod.CONFIG


def reduced_config(arch: str) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests (small layers/width/experts)."""
    mod = importlib.import_module(f"repro.configs.{normalize(arch)}")
    cfg: ModelConfig = mod.CONFIG
    over: Dict = dict(getattr(mod, "REDUCED_OVERRIDES", {}))
    base = dict(
        num_layers=len(cfg.block_pattern),
        d_model=64,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) or 1,
        head_dim=16,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        act_dtype="float32",
        param_dtype="float32",
        microbatches_train=1,
        remat=False,
    )
    if cfg.moe is not None:
        base["moe"] = dataclasses.replace(cfg.moe, num_experts=4, experts_per_token=2)
    if cfg.mla is not None:
        base["mla"] = dataclasses.replace(
            cfg.mla, q_lora_rank=32, kv_lora_rank=16,
            qk_nope_head_dim=8, qk_rope_head_dim=8, v_head_dim=8)
    if cfg.is_encoder_decoder:
        base["enc_layers"] = 2
        base["enc_seq"] = 16
    if cfg.window:
        base["window"] = 32
    if cfg.local_window:
        base["local_window"] = 32
    base.update(over)
    return cfg.replace(**base)


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
