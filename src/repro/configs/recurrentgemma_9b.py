"""recurrentgemma-9b [hybrid] — RG-LRU + local attention, 2:1 (arXiv:2402.19427).

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000.  Griffin layout:
two RG-LRU residual blocks per local-attention block (window 2048), each
temporal-mix block followed by a gated-GELU MLP.  38 = 12*3 + 2.
Sub-quadratic (bounded window + recurrent state) => long_500k runs.
"""
from .base import LOCAL_ATTN, RGLRU, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma_9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    num_heads=16,
    num_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256_000,
    block_pattern=(RGLRU, RGLRU, LOCAL_ATTN),
    local_window=2048,
    conv_width=4,
    lru_width=4096,
    mlp="gelu_glu",
    tie_embeddings=True,
    scale_embeddings=True,
    optimizer="adamw",
    microbatches_train=8,
    skip_shapes=(),
)
