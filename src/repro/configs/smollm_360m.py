"""smollm-360m [dense] — llama-arch small (hf:HuggingFaceTB/SmolLM-360M).

32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152.  Full attention
=> long_500k skipped.
"""
from .base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="smollm_360m",
    family="dense",
    num_layers=32,
    d_model=960,
    num_heads=15,
    num_kv_heads=5,
    head_dim=64,
    d_ff=2560,
    vocab_size=49152,
    block_pattern=(ATTN,),
    mlp="swiglu",
    tie_embeddings=True,
    tensor_parallel=False,
    optimizer="adamw",
    microbatches_train=1,
    skip_shapes=("long_500k",),
)
