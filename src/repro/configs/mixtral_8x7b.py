"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention (arXiv:2401.04088).

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000, SWA window 4096.
SWA bounds the KV cache => long_500k runs with a ring cache.
"""
from .base import ATTN, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral_8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    block_pattern=(ATTN,),
    window=4096,
    rope_theta=1e6,
    mlp="swiglu",
    moe=MoEConfig(num_experts=8, experts_per_token=2, capacity_factor=1.25),
    tie_embeddings=False,
    optimizer="adamw",
    microbatches_train=16,
    skip_shapes=(),
)
