from .base import (ALL_SHAPES, SHAPES_BY_NAME, ModelConfig, ShapeConfig,
                   TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
from .registry import ARCH_IDS, all_configs, get_config, normalize, reduced_config

__all__ = ["ModelConfig", "ShapeConfig", "ALL_SHAPES", "SHAPES_BY_NAME",
           "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
           "ARCH_IDS", "get_config", "reduced_config", "all_configs", "normalize"]
