"""minicpm3-4b [dense] — Multi-head Latent Attention (hf:openbmb/MiniCPM3-4B).

62L d_model=2560 40H d_ff=6400 vocab=73448.  MLA: q_lora 768, kv_lora 256,
qk_nope 64, qk_rope 32, v_head 64 (the "kv=40" in the assignment reflects
that MLA has no GQA grouping - every head reads the shared latent).
Full attention => long_500k skipped.
"""
from .base import ATTN, MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3_4b",
    family="dense",
    num_layers=62,
    d_model=2560,
    num_heads=40,
    num_kv_heads=40,
    head_dim=64,
    d_ff=6400,
    vocab_size=73448,
    block_pattern=(ATTN,),
    mla=MLAConfig(
        q_lora_rank=768,
        kv_lora_rank=256,
        qk_nope_head_dim=64,
        qk_rope_head_dim=32,
        v_head_dim=64,
    ),
    mlp="swiglu",
    tie_embeddings=True,
    optimizer="adamw",
    microbatches_train=8,
    skip_shapes=("long_500k",),
)
