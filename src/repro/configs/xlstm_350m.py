"""xlstm-350m [ssm] — sLSTM + mLSTM blocks (arXiv:2405.04517).

24L d_model=1024 4H (kv=4) d_ff=0 vocab=50304.  xLSTM[7:1]: one sLSTM block
per 7 mLSTM blocks (period-8 pattern, 24 = 3 x 8).  d_ff=0: the blocks carry
their own up/down projections (post-up-projection layout), no separate FFN.
Sub-quadratic: O(1) recurrent state => long_500k runs.
"""
from .base import MLSTM, SLSTM, ModelConfig

CONFIG = ModelConfig(
    name="xlstm_350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    head_dim=1024 // 4,
    d_ff=0,
    vocab_size=50304,
    block_pattern=(MLSTM,) * 7 + (SLSTM,),
    proj_factor=2.0,
    conv_width=4,
    mlp="none",
    tie_embeddings=True,
    tensor_parallel=False,
    optimizer="adamw",
    microbatches_train=1,
    skip_shapes=(),
)

REDUCED_OVERRIDES = dict(num_layers=8, head_dim=16)
