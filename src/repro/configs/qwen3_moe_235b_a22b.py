"""qwen3-moe-235b-a22b [moe] — 128 experts top-8 (hf:Qwen/Qwen3-*).

94L d_model=4096 64H (GQA kv=4) d_ff=1536 (per expert) vocab=151936.
Qwen3 uses head_dim=128 (decoupled from d_model/num_heads) and q/k RMSNorm.
Full attention => long_500k skipped (see DESIGN.md §Arch-applicability).
Adafactor + 8 microbatches to fit 16 GB/chip for train_4k.
"""
from .base import ATTN, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3_moe_235b_a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151936,
    block_pattern=(ATTN,),
    qk_norm=True,
    rope_theta=1e6,
    mlp="swiglu",
    moe=MoEConfig(num_experts=128, experts_per_token=8, capacity_factor=1.25),
    tie_embeddings=False,
    optimizer="adafactor",
    fsdp=True,
    microbatches_train=8,
    skip_shapes=("long_500k",),
)
