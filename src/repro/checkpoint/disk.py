"""On-disk checkpointing: npz shards + JSON manifest, with async writes.

The manifest carries the LARK metadata (regime, logical clocks) so a restart
can verify it restores the latest committed state — the disk layer is the
durable tier beneath the LARK-replicated in-memory tier.
"""
from __future__ import annotations

import json
import queue
import threading
import time
from pathlib import Path
from typing import Any, Optional, Tuple

import jax
import numpy as np


def save_pytree(path: str | Path, tree, *, step: int, regime: int = 0):
    path = Path(path)
    path.mkdir(parents=True, exist_ok=True)
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, arrays = [], {}
    for i, (p, leaf) in enumerate(flat):
        name = f"leaf_{i:05d}"
        names.append("/".join(str(getattr(k, "key", k)) for k in p))
        arrays[name] = np.asarray(leaf)
    np.savez(path / f"shards_{step:08d}.npz", **arrays)
    manifest = {"step": step, "regime": regime, "paths": names,
                "time": time.time()}
    (path / f"manifest_{step:08d}.json").write_text(json.dumps(manifest))
    (path / "latest").write_text(str(step))


def load_pytree(path: str | Path, like, step: Optional[int] = None):
    path = Path(path)
    if step is None:
        step = int((path / "latest").read_text())
    data = np.load(path / f"shards_{step:08d}.npz")
    leaves = [data[f"leaf_{i:05d}"] for i in range(len(data.files))]
    manifest = json.loads((path / f"manifest_{step:08d}.json").read_text())
    return jax.tree.unflatten(jax.tree.structure(like), leaves), manifest


class AsyncCheckpointer:
    """Background-thread writer: training never blocks on checkpoint I/O."""

    def __init__(self, path: str | Path):
        self.path = Path(path)
        self.q: "queue.Queue" = queue.Queue(maxsize=2)
        self.errors: list = []
        self._t = threading.Thread(target=self._worker, daemon=True)
        self._t.start()

    def _worker(self):
        while True:
            item = self.q.get()
            if item is None:
                return
            tree, step, regime = item
            try:
                save_pytree(self.path, tree, step=step, regime=regime)
            except Exception as e:  # pragma: no cover
                self.errors.append(e)

    def save(self, tree, *, step: int, regime: int = 0):
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot off-device
        self.q.put((host_tree, step, regime))

    def close(self):
        self.q.put(None)
        self._t.join(timeout=30)
