from .lark_store import LarkStore
from .baseline_store import QuorumLogStore
from .disk import load_pytree, save_pytree, AsyncCheckpointer

__all__ = ["LarkStore", "QuorumLogStore", "save_pytree", "load_pytree",
           "AsyncCheckpointer"]
