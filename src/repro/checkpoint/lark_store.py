"""LARK-replicated in-memory KV store — the framework's fault-tolerance layer.

This is the paper's protocol (repro.core) embedded as a service: "nodes" are
(possibly simulated) workers, keys are checkpoint shard names / serving
session ids, values are arbitrary blobs (ndarray bytes).  Every read/write
goes through Algorithms 1-4 — linearizable per key, log-free, PAC-governed
availability — so a training job keeps committing checkpoints through
worker failures whenever PAC holds (vs the quorum-log baseline which pauses;
see checkpoint/baseline_store.py and examples/outage_timeseries.py).

put/get return (ok, value) and never block: an unavailable partition fails
fast, exactly like the production system's client-visible behavior.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

from repro.core.pac import ALL_CONDITIONS
from repro.core.simulator import LarkSim
from repro.core.succession import key_partition


class LarkStore:
    def __init__(self, num_nodes: int, rf: int = 2, num_partitions: int = 64,
                 pac_conditions=ALL_CONDITIONS, seed: int = 0):
        self.sim = LarkSim(num_nodes=num_nodes, rf=rf,
                           num_partitions=num_partitions,
                           pac_conditions=pac_conditions, seed=seed)
        self.num_partitions = num_partitions
        self.sim.recluster()
        self.sim.settle()
        self.sim.run_migrations()

    # -- membership ------------------------------------------------------
    def fail_node(self, node_id: int):
        self.sim.fail_node(node_id)
        self.sim.settle()
        self.sim.run_migrations()

    def recover_node(self, node_id: int):
        self.sim.recover_node(node_id)
        self.sim.settle()
        self.sim.run_migrations()

    @property
    def regime(self) -> int:
        return self.sim.er_counter

    def available_fraction(self) -> float:
        avail = 0
        for pid in range(self.num_partitions):
            if self.sim.leader_of(pid) is not None:
                avail += 1
        return avail / self.num_partitions

    # -- KV API ------------------------------------------------------------
    def _pid(self, key: str) -> int:
        return key_partition(key, self.num_partitions)

    def put(self, key: str, value: Any) -> bool:
        pid = self._pid(key)
        op = self.sim.client_write(pid, key, value)
        self.sim.settle()
        res = self.sim.result(op)
        return bool(res and res.ok)

    def get(self, key: str) -> Tuple[bool, Any]:
        pid = self._pid(key)
        op = self.sim.client_read(pid, key)
        self.sim.settle()
        res = self.sim.result(op)
        if res and res.ok:
            return True, res.value
        return False, None

    # -- pytree checkpointing --------------------------------------------
    def put_pytree(self, prefix: str, tree) -> Tuple[int, int]:
        """Store every leaf under '<prefix>/<leafpath>'.  Returns (ok, total)."""
        import jax
        ok = total = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
            name = prefix + "/" + "/".join(str(getattr(p, "key", p)) for p in path)
            total += 1
            ok += self.put(name, leaf)
        return ok, total

    def get_pytree(self, prefix: str, like) -> Tuple[bool, Any]:
        import jax
        leaves = []
        for path, leaf in jax.tree_util.tree_flatten_with_path(like)[0]:
            name = prefix + "/" + "/".join(str(getattr(p, "key", p)) for p in path)
            good, val = self.get(name)
            if not good:
                return False, None
            leaves.append(val)
        return True, jax.tree.unflatten(jax.tree.structure(like), leaves)
